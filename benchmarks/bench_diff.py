"""Compare two ``BENCH_*.json`` documents and flag timing regressions
(`benchmarks/run.py bench-diff OLD.json NEW.json`).

Every benchmark in this harness emits a nested JSON document whose timing
leaves follow one naming convention: wall-clock microseconds carry a
``_us`` token (``compile_us``, ``wall_us_per_window``, ``p50_us``) and
modeled times a ``seconds`` token (``roofline_seconds``).  This tool
flattens both documents, pairs the common timing leaves by dotted path,
and flags every leaf where the new value exceeds the old by more than the
threshold (default +25%) AND by an absolute floor (default 50 us — tiny
CPU timings jitter by more than any sane relative threshold).

Non-timing leaves (counts, accuracies, rates) are ignored: those are
correctness signals with their own asserts inside each benchmark.

Advisory by default (exit 0 with a report); ``--strict`` exits 1 on any
regression so CI can gate on it.
"""
from __future__ import annotations

import argparse
import json
import math
import sys

DEFAULT_THRESHOLD = 0.25  # +25% relative
DEFAULT_FLOOR_US = 50.0   # ignore absolute deltas below this


def _flatten(node, prefix: str = "") -> dict[str, float]:
    """Dotted-path -> numeric-leaf map (bools excluded)."""
    out: dict[str, float] = {}
    if isinstance(node, dict):
        for k, v in node.items():
            out.update(_flatten(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(node, (list, tuple)):
        for i, v in enumerate(node):
            out.update(_flatten(v, f"{prefix}.{i}"))
    elif isinstance(node, bool):
        pass
    elif isinstance(node, (int, float)):
        out[prefix] = float(node)
    return out


def _is_timing(path: str) -> bool:
    """True when any underscore-token of any path segment is a timing unit."""
    tokens: list[str] = []
    for seg in path.split("."):
        tokens.extend(seg.split("_"))
    return "us" in tokens or "seconds" in tokens


def diff(old_doc: dict, new_doc: dict,
         threshold: float = DEFAULT_THRESHOLD,
         floor_us: float = DEFAULT_FLOOR_US) -> dict:
    """Pair common timing leaves; return all rows + the regressed subset."""
    old = {k: v for k, v in _flatten(old_doc).items() if _is_timing(k)}
    new = {k: v for k, v in _flatten(new_doc).items() if _is_timing(k)}
    rows, regressions = [], []
    for path in sorted(set(old) & set(new)):
        o, n = old[path], new[path]
        if not (math.isfinite(o) and math.isfinite(n)):
            continue
        # modeled roofline terms are in seconds; lift to us for the floor
        delta = (n - o) * (1e6 if "seconds" in path else 1.0)
        ratio = (n / o) if o > 0 else math.inf
        regressed = bool(n > o * (1.0 + threshold) and delta > floor_us)
        row = {"path": path, "old": o, "new": n, "ratio": ratio,
               "regressed": regressed}
        rows.append(row)
        if regressed:
            regressions.append(row)
    return {
        "n_compared": len(rows),
        "n_old_only": len(set(old) - set(new)),
        "n_new_only": len(set(new) - set(old)),
        "threshold": threshold,
        "rows": rows,
        "regressions": regressions,
    }


def run(old_path: str, new_path: str,
        threshold: float = DEFAULT_THRESHOLD,
        floor_us: float = DEFAULT_FLOOR_US,
        strict: bool = False) -> dict:
    with open(old_path) as f:
        old_doc = json.load(f)
    with open(new_path) as f:
        new_doc = json.load(f)
    rep = diff(old_doc, new_doc, threshold=threshold, floor_us=floor_us)
    print(f"# bench-diff {old_path} -> {new_path}: "
          f"{rep['n_compared']} timing leaves compared "
          f"({rep['n_old_only']} only-old, {rep['n_new_only']} only-new), "
          f"threshold +{threshold:.0%}")
    print("path,old,new,ratio,flag")
    for row in rep["rows"]:
        flag = "REGRESSED" if row["regressed"] else "ok"
        print(f"{row['path']},{row['old']:.1f},{row['new']:.1f},"
              f"{row['ratio']:.2f},{flag}")
    if rep["regressions"]:
        print(f"# {len(rep['regressions'])} timing regression(s) flagged",
              file=sys.stderr)
        if strict:
            sys.exit(1)
    else:
        print("# no timing regressions")
    return rep


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", help="baseline BENCH_*.json")
    ap.add_argument("new", help="candidate BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative regression threshold (default 0.25)")
    ap.add_argument("--floor-us", type=float, default=DEFAULT_FLOOR_US,
                    help="ignore absolute deltas below this many us")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when any regression is flagged")
    args = ap.parse_args(argv)
    run(args.old, args.new, threshold=args.threshold,
        floor_us=args.floor_us, strict=args.strict)


if __name__ == "__main__":
    main()
