"""Serving-tier smoke benchmark (`benchmarks/run.py serve-smoke`).

Three parts, mirroring what the ROADMAP Serving section promises:

1. **Correctness probes** (asserted, not timed): a bf16-resident snapshot
   is EXACTLY half the fp32 snapshot's resident bytes — in the live
   ``PosteriorSnapshot.nbytes()`` and in the analytic
   ``serve_roofline`` model; the padding-bucket apply cache compiles one
   program per touched ``(bucket, shape, mc)`` key and a replayed request
   stream adds ZERO retraces; the f32 snapshot serves the L=0 point
   estimate identically to ``Session.predictive(n_mc=0)``.
2. **MC ensemble sweep** (the paper's L knob, Sec 4.2): p50/p99 serving
   latency and warm queries/sec vs ``mc_samples`` over a fixed ragged
   request stream, next to the roofline's per-batch apply bytes (serving
   is posterior-row bound, so modeled bytes scale ~linearly in L).
3. **Bucket-policy sweep**: the same stream under different
   ``bucket_sizes`` policies — trace count, pad-row overhead, and warm
   latency trade off against each other (one big bucket = 1 trace but max
   padding; fine-grained buckets = more traces, less padding).

Output: ``BENCH_serve.json`` + the harness's ``name,us_per_call,derived``
CSV rows.  Latency numbers are CPU smoke values — the relative shape
(latency vs L, padding vs policy) is the load-bearing part, as for the
other BENCH_*.json documents.
"""
from __future__ import annotations

import json

import jax
import numpy as np

from repro.api import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    RunSpec,
    ServeSpec,
    TopologySpec,
    build_session,
)
from repro.launch.costmodel import serve_roofline

DEFAULT_JSON = "BENCH_serve.json"

N_AGENTS = 3
N_ROUNDS = 4


def _session():
    spec = ExperimentSpec(
        topology=TopologySpec.gossip("ring", {"n": N_AGENTS}),
        data=DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
            partition_params=dict(n_agents=N_AGENTS),
            batch_size=4,
            local_updates=2,
        ),
        inference=InferenceSpec(hidden=8, depth=1, lr=1e-2),
        run=RunSpec(n_rounds=N_ROUNDS, seed=0),
        serve=ServeSpec(max_staleness=None),
    )
    sess = build_session(spec)
    sess.run()
    return sess


def _request_stream(sess, n_requests: int = 24, seed: int = 0):
    """A fixed ragged stream: sizes 1..9, round-robined over the agents."""
    rng = np.random.default_rng(seed)
    x = np.asarray(sess.data.x_test)
    sizes = rng.integers(1, 10, size=n_requests)
    return [
        (x[rng.integers(0, x.shape[0], size=int(n))], i % N_AGENTS)
        for i, n in enumerate(sizes)
    ]


def _probes(sess) -> dict:
    """The asserted serving contracts."""
    snap32 = sess.snapshot(dtype="f32")
    snap16 = sess.snapshot(dtype="bf16")
    live_ratio = snap32.nbytes() / snap16.nbytes()
    n_params = int(snap32.posterior.mean.shape[1])
    r32 = serve_roofline(N_AGENTS, n_params, snapshot_dtype="f32")
    r16 = serve_roofline(N_AGENTS, n_params, snapshot_dtype="bf16")
    model_ratio = r32["snapshot_hbm_bytes"] / r16["snapshot_hbm_bytes"]
    assert live_ratio == 2.0, f"bf16 snapshot not half: {live_ratio}"
    assert model_ratio == 2.0, f"modeled bf16 HBM not half: {model_ratio}"

    # replay determinism of the apply cache: a second pass over the same
    # stream must add ZERO retraces
    sess.snapshot(dtype="f32")
    server = sess.attach_server(mc_samples=2, bucket_sizes=(4, 16))
    stream = _request_stream(sess)
    for rows, agent in stream:
        server.query(rows, agent=agent)
    traces_first = server.n_traces
    for rows, agent in stream:
        server.query(rows, agent=agent)
    assert server.n_traces == traces_first, (
        f"replay retraced: {server.n_traces} != {traces_first}"
    )
    assert traces_first == 2, f"expected 1 trace per bucket, {traces_first}"

    # the served L=0 point estimate equals the Session's own predictive
    x = np.asarray(sess.data.x_test[:6])
    served0, _ = server.query(x, agent=0, mc_samples=0)
    direct0 = sess.predictive(0, x, n_mc=0)
    np.testing.assert_allclose(
        np.asarray(served0), np.asarray(direct0), rtol=1e-6, atol=1e-7
    )
    print(f"serve_probe_bf16_halving,0.0,live={live_ratio};model={model_ratio}")
    print(f"serve_probe_trace_pin,0.0,traces={traces_first};replay_delta=0")
    print("serve_probe_point_estimate,0.0,matches_session_predictive=1")
    return {
        "bf16_snapshot_ratio_live": live_ratio,
        "bf16_snapshot_ratio_model": model_ratio,
        "snapshot_bytes": {"f32": snap32.nbytes(), "bf16": snap16.nbytes()},
        "trace_pin": {"buckets": [4, 16], "traces": traces_first,
                      "replay_delta": 0},
    }


def _serve_stream(server, stream):
    for rows, agent in stream:
        probs, _ = server.query(rows, agent=agent)
    jax.block_until_ready(probs)


def _mc_sweep(sess, mc_grid=(0, 1, 4, 8)) -> list[dict]:
    """p50/p99 latency + warm QPS vs the MC ensemble size L."""
    sess.snapshot(dtype="f32")
    n_params = int(sess.posterior().mean.shape[1])
    out = []
    stream = _request_stream(sess)
    for mc in mc_grid:
        server = sess.attach_server(mc_samples=mc, bucket_sizes=(4, 16))
        _serve_stream(server, stream)  # cold pass: compiles the buckets
        server._lat_us.clear()
        _serve_stream(server, stream)  # warm pass: the measured one
        lat = server.latency_percentiles()
        qps = 1e6 / lat["mean_us"]
        model = serve_roofline(
            N_AGENTS, n_params, mc_samples=mc, batch=8,
            dim=int(np.asarray(sess.data.x_test).shape[1]), n_classes=3,
        )
        rec = {
            "mc_samples": mc,
            "p50_us": lat["p50_us"],
            "p99_us": lat["p99_us"],
            "qps": qps,
            "rows": server.n_rows // 2,
            "model_apply_bytes_per_batch": model["apply_bytes_per_batch"],
        }
        out.append(rec)
        print(f"serve_mc_L{mc},{lat['p50_us']:.1f},"
              f"p99={lat['p99_us']:.1f};qps={qps:.1f}")
    return out


def _bucket_sweep(sess, mc: int = 4) -> list[dict]:
    """Trace count / padding overhead / warm latency per bucket policy."""
    sess.snapshot(dtype="f32")
    policies = {
        "single_big": (16,),
        "pow2_small": (1, 2, 4, 8),
        "pow2_full": (1, 2, 4, 8, 16, 32),
    }
    stream = _request_stream(sess)
    out = []
    for name, buckets in policies.items():
        server = sess.attach_server(mc_samples=mc, bucket_sizes=buckets)
        _serve_stream(server, stream)
        server._lat_us.clear()
        pad_before, rows_before = server.n_padded_rows, server.n_rows
        _serve_stream(server, stream)
        lat = server.latency_percentiles()
        pad_frac = (server.n_padded_rows - pad_before) / (
            server.n_rows - rows_before
        )
        rec = {
            "policy": name,
            "bucket_sizes": list(buckets),
            "traces": server.n_traces,
            "pad_rows_per_row": pad_frac,
            "p50_us": lat["p50_us"],
            "p99_us": lat["p99_us"],
        }
        out.append(rec)
        print(f"serve_buckets_{name},{lat['p50_us']:.1f},"
              f"traces={server.n_traces};pad_frac={pad_frac:.2f}")
    return out


def run(json_out: str | None = DEFAULT_JSON) -> dict:
    print("name,us_per_call,derived")
    sess = _session()
    doc = {
        "n_agents": N_AGENTS,
        "n_params": int(sess.posterior().mean.shape[1]),
        "probes": _probes(sess),
        "mc_sweep": _mc_sweep(sess),
        "bucket_sweep": _bucket_sweep(sess),
    }
    if json_out:
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {json_out}")
    return doc


if __name__ == "__main__":
    run()
