"""Paper Sec 1.4.3 / Table 3: asynchronous decentralized learning on
TIME-VARYING star networks.  N+1 agents; per round only N0 edge agents are
connected to the center; the union over the schedule is strongly connected.
IID data split.  Expected: high average accuracy with only ~n/N samples per
agent; more agents (same data) -> slightly lower accuracy (paper: 96.5% ->
92.3%)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, network_accuracy, train_network
from repro.core.graphs import time_varying_star_schedule
from repro.data.partition import partition_iid
from repro.data.synthetic import make_synthetic_classification


def run(rounds: int = 30) -> None:
    ds = make_synthetic_classification(
        n_classes=10, dim=64, n_train_per_class=260, noise=0.55, seed=0
    )
    results = {}
    for n_agents, n_active in ((10, 2), (20, 4)):
        t = Timer()
        mats = time_varying_star_schedule(n_agents, n_active, a=0.5)
        shards = partition_iid(ds.x_train, ds.y_train, n_agents + 1)
        state, _ = train_network(
            shards, [np.asarray(m) for m in mats], rounds, seed=0,
            local_updates=2,
        )
        accs = network_accuracy(state, ds.x_test, ds.y_test, per_agent=True)
        avg = float(np.mean(accs))
        results[n_agents] = avg
        emit(
            f"table3_timevarying_N{n_agents}", t.us(),
            f"avg_acc={avg:.4f};center_acc={accs[0]:.4f};"
            f"samples_per_agent={len(ds.y_train) // (n_agents + 1)}",
        )
    assert results[10] > 0.6, results
