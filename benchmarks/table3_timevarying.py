"""Paper Sec 1.4.3 / Table 3: asynchronous decentralized learning on
TIME-VARYING star networks.  N+1 agents; per round only N0 edge agents are
connected to the center; the union over the schedule is strongly connected.
IID data split.  Expected: high average accuracy with only ~n/N samples per
agent; more agents (same data) -> slightly lower accuracy (paper: 96.5% ->
92.3%).

Runs on the first-class round-indexed topology form: the per-slot W's from
``time_varying_star_schedule`` are fed to ``Session.run`` as a
``Callable[[int], W]``."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, classification_spec, emit, run_classification
from repro.api import TopologySpec
from repro.core.graphs import time_varying_star_schedule

DATASET = dict(n_classes=10, dim=64, n_train_per_class=260, noise=0.55, seed=0)


def run(rounds: int = 30) -> None:
    results = {}
    for n_agents, n_active in ((10, 2), (20, 4)):
        t = Timer()
        mats = time_varying_star_schedule(n_agents, n_active, a=0.5)
        spec = classification_spec(
            TopologySpec.time_varying_star(n_agents, n_active, a=0.5),
            rounds=rounds,
            dataset_params=DATASET,
            partition="iid",
            partition_params=dict(n_agents=n_agents + 1),
            local_updates=2,
        )
        # round-indexed callable form of the same schedule (first-class in
        # Session.run / run_rounds; equivalent to the spec topology's cycle)
        session = run_classification(
            spec, w_schedule=lambda r: mats[r % len(mats)]
        )
        accs = session.evaluate()["acc"]
        avg = float(np.mean(accs))
        results[n_agents] = avg
        n_train = len(session.data.dataset.y_train)
        emit(
            f"table3_timevarying_N{n_agents}", t.us(),
            f"avg_acc={avg:.4f};center_acc={accs[0]:.4f};"
            f"samples_per_agent={n_train // (n_agents + 1)}",
        )
    assert results[10] > 0.6, results
