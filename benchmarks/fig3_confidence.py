"""Paper Fig 3: confidence on ID vs OOD labels at the central and edge
agents.  Expected: ID confidence > OOD confidence at every agent, and the
edge agents' OOD confidence increases with the center's centrality a."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, agent_confidence, classification_spec, emit, run_classification
from repro.api import TopologySpec

N_EDGE = 8
DATASET = dict(n_classes=10, dim=64, n_train_per_class=200, noise=0.55, seed=0)
PARTITION = dict(center_labels=list(range(2, 10)), edge_labels=[0, 1], n_edge=N_EDGE)


def run(rounds: int = 18) -> None:
    edge_ood_by_a = []
    for a in (0.3, 0.5, 0.7):
        t = Timer()
        session = run_classification(classification_spec(
            TopologySpec.star(N_EDGE, a),
            rounds=rounds,
            dataset_params=DATASET,
            partition="star",
            partition_params=PARTITION,
        ))
        ds = session.data.dataset
        # label 2: ID at the center, OOD at the edges; label 0: vice versa
        x_lbl2 = ds.x_test[ds.y_test == 2]
        x_lbl0 = ds.x_test[ds.y_test == 0]
        c_center_id = agent_confidence(session, 0, x_lbl2, 2)
        c_center_ood = agent_confidence(session, 0, x_lbl0, 0)
        c_edge_id = agent_confidence(session, 1, x_lbl0, 0)
        c_edge_ood = agent_confidence(session, 1, x_lbl2, 2)
        edge_ood_by_a.append(c_edge_ood)
        emit(
            f"fig3_confidence_a{a}", t.us(),
            f"center_id={c_center_id:.3f};center_ood={c_center_ood:.3f};"
            f"edge_id={c_edge_id:.3f};edge_ood={c_edge_ood:.3f}",
        )
    assert edge_ood_by_a[-1] > edge_ood_by_a[0] - 0.02, edge_ood_by_a
