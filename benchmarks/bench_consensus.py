"""Consensus-path benchmark: leaf-loop einsum vs flat-fused network kernel.

Sweeps (N_agents x P x topology) and times one eq.-(6) round through

  * ``leaf_loop``    — the paper-faithful reference: Python loop over the
    model pytree's leaves, one einsum chain per leaf
    (``core.posterior.consensus_all_agents`` on a ``GaussianPosterior``);
  * ``flat_fused``   — the same math on the contiguous [N, P]
    ``FlatPosterior`` buffers as ONE fused computation
    (``core.flat.consensus_flat``: Pallas network kernel on TPU, single
    fused XLA einsum elsewhere);
  * ``flat_sparse``  — the CSR-neighbor-list variant on sparse topologies.

Wall-clock (median of ``iters`` jitted calls, after warmup) is reported per
path, together with the analytic roofline (``launch.costmodel
.consensus_roofline``): on CPU the Pallas kernels run in interpreter mode,
whose wall-clock says nothing about TPU, so the HBM-pass model is the
load-bearing number there — the interpreter run is kept only as a
correctness probe (max |err| vs the fused XLA reference).

Since the wire-dtype PR the doc also carries a ``wire`` block: the fused
network consensus timed at each wire dtype (fp32 / bf16 exchange of the
(prec, prec*mu) sufficient statistics, fp32 accumulate) next to the
modeled collective bytes (``consensus_roofline``'s ``wire`` term — bf16
halves them) and the measured max deviation vs the fp32 reference.

Output: ``BENCH_consensus.json`` — see ROADMAP.md "Performance" for how to
read it; the perf trajectory is tracked from this file PR-over-PR.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flat import (
    FlatLayout,
    FlatPosterior,
    consensus_flat,
    consensus_flat_reference,
    consensus_flat_segments,
    consensus_flat_sparse,
    flat_posterior_from_pytree,
    neighbor_tables,
)
from repro.core.graphs import (
    bidirectional_ring_w,
    complete_w,
    star_w,
    watts_strogatz_sparse,
)
from repro.core.posterior import GaussianPosterior, consensus_all_agents
from repro.launch.costmodel import consensus_roofline

DEFAULT_JSON = "BENCH_consensus.json"


def _ragged_params(key, n_agents: int, p_target: int, n_leaves: int):
    """A deliberately ragged mixed-shape parameter pytree of ~p_target
    scalars per agent, mimicking a real model's many differently-shaped
    leaves (the case where per-leaf dispatch overhead hurts most)."""
    ks = jax.random.split(key, n_leaves)
    per = max(p_target // n_leaves, 8)
    tree = {}
    for i, k in enumerate(ks):
        # cycle through 1-D / 2-D / odd-sized shapes
        if i % 3 == 0:
            shape = (per,)
        elif i % 3 == 1:
            shape = (max(per // 16, 2), 16)
        else:
            shape = (max(per // 7, 1), 7)
        tree[f"leaf_{i:02d}"] = jax.random.normal(k, (n_agents,) + shape)
    return tree


def _posts_for(key, n_agents: int, p_target: int, n_leaves: int):
    k1, k2 = jax.random.split(key)
    mean = _ragged_params(k1, n_agents, p_target, n_leaves)
    rho = jax.tree.map(
        lambda m, k: jax.random.normal(k, m.shape) * 0.3 - 1.0,
        mean,
        dict(zip(mean, jax.random.split(k2, len(mean)))),
    )
    return GaussianPosterior(mean=mean, rho=rho)


def _topology(name: str, n: int) -> np.ndarray:
    if name == "complete":
        return complete_w(n)
    if name == "ring":
        return bidirectional_ring_w(n)
    if name == "star":
        return star_w(n - 1, a=0.5)
    raise ValueError(f"unknown topology {name!r}")


def _time(fn, args, iters: int) -> float:
    """Median wall-clock us of ``fn(*args)``.

    ``fn`` must be jitted with the posteriors passed as ARGUMENTS — a jitted
    closure capturing them as constants lets XLA constant-fold the whole
    consensus at compile time and times nothing.
    """
    jax.block_until_ready(fn(*args))  # warmup / compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def bench_one(
    n_agents: int,
    p_target: int,
    topology: str,
    n_leaves: int = 32,
    iters: int = 10,
    check_interpret: bool = False,
    seed: int = 0,
) -> dict:
    posts = _posts_for(jax.random.key(seed), n_agents, p_target, n_leaves)
    flat = flat_posterior_from_pytree(posts, leading_axes=1)
    W = jnp.asarray(_topology(topology, n_agents), jnp.float32)
    nbr_np, wts_np = neighbor_tables(np.asarray(W))
    nbr, wts = jnp.asarray(nbr_np), jnp.asarray(wts_np)
    p = flat.layout.n_params

    leaf_fn = jax.jit(lambda po, w: consensus_all_agents(po, w).mean)
    flat_fn = jax.jit(lambda fp, w: consensus_flat(fp, w).mean)
    sparse_fn = jax.jit(lambda fp, i, v: consensus_flat_sparse(fp, i, v).mean)

    rec = {
        "n_agents": n_agents,
        "p": p,
        "n_leaves": n_leaves,
        "topology": topology,
        "max_degree": int((np.asarray(W) > 0).sum(1).max()),
        "backend": jax.default_backend(),
        "us": {
            "leaf_loop": _time(leaf_fn, (posts, W), iters),
            "flat_fused": _time(flat_fn, (flat, W), iters),
            "flat_sparse": _time(sparse_fn, (flat, nbr, wts), iters),
        },
        "roofline": consensus_roofline(
            n_agents, p, n_leaves, max_degree=int((np.asarray(W) > 0).sum(1).max())
        ),
    }
    rec["speedup_flat_vs_leaf_loop"] = rec["us"]["leaf_loop"] / rec["us"]["flat_fused"]
    # the flat-fused path FOR a sparse topology is the sparse-neighborhood
    # kernel (dense matmul form is the complete-graph case) — best-of both
    rec["speedup_best_flat_vs_leaf_loop"] = rec["us"]["leaf_loop"] / min(
        rec["us"]["flat_fused"], rec["us"]["flat_sparse"]
    )
    if check_interpret:
        # correctness probe only: the Pallas interpreter is not timed
        ref = consensus_flat(flat, W, mode="xla")
        kern = consensus_flat(flat, W, mode="interpret", block=256)
        sref = consensus_flat_sparse(flat, nbr, wts, mode="xla")
        skern = consensus_flat_sparse(flat, nbr, wts, mode="interpret", block=256)
        rec["interpret_max_err"] = {
            "dense_mean": float(jnp.max(jnp.abs(ref.mean - kern.mean))),
            "dense_rho": float(jnp.max(jnp.abs(ref.rho - kern.rho))),
            "sparse_mean": float(jnp.max(jnp.abs(sref.mean - skern.mean))),
            "sparse_rho": float(jnp.max(jnp.abs(sref.rho - skern.rho))),
        }
    return rec


def wire_sweep(
    n_agents: int = 8,
    p: int = 1 << 15,
    iters: int = 5,
    seed: int = 2,
) -> list[dict]:
    """Fused network consensus per wire dtype: wall-clock, modeled
    collective bytes, and max |err| vs the fp32 reference (which must be
    EXACTLY 0.0 for the f32 wire — the structural no-op contract)."""
    from repro.core.numerics import wire_error_bound

    posts = _posts_for(jax.random.key(seed), n_agents, p, 16)
    flat = flat_posterior_from_pytree(posts, leading_axes=1)
    W = jnp.asarray(_topology("ring", n_agents), jnp.float32)
    ref = consensus_flat(flat, W)
    out = []
    for wire in ("f32", "bf16"):
        fn = jax.jit(
            lambda fp, w, wd=wire: consensus_flat(fp, w, wire_dtype=wd).mean
        )
        got = consensus_flat(flat, W, wire_dtype=wire)
        max_err = max(
            float(jnp.max(jnp.abs(got.mean - ref.mean))),
            float(jnp.max(jnp.abs(got.rho - ref.rho))),
        )
        if wire == "f32":
            assert max_err == 0.0, f"f32 wire is not a structural no-op: {max_err}"
        rec = {
            "wire_dtype": wire,
            "us_flat_fused": _time(fn, (flat, W), iters),
            "max_err_vs_f32": max_err,
            "error_bound_u": wire_error_bound(wire),
            "roofline_wire": consensus_roofline(
                n_agents, flat.layout.n_params, 16, wire_dtype=wire
            )["wire"],
        }
        out.append(rec)
    assert (
        out[1]["roofline_wire"]["collective_bytes"]
        == 0.5 * out[0]["roofline_wire"]["collective_bytes"]
    )
    return out


def assert_no_dense_square(closed_jaxpr, n: int) -> None:
    """Assert the jaxpr allocates NO [n, n] intermediate anywhere — the
    O(E)-memory contract of the sparse path, checked on the actual traced
    computation rather than trusted.  Recurses into sub-jaxprs (scan / cond
    / pjit bodies)."""

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                shape = getattr(getattr(v, "aval", None), "shape", ())
                if tuple(shape).count(n) >= 2:
                    raise AssertionError(
                        f"sparse path allocated a dense {tuple(shape)} "
                        f"intermediate (n={n}) in {eqn.primitive}"
                    )
            for param in eqn.params.values():
                for sub in param if isinstance(param, (list, tuple)) else [param]:
                    inner = getattr(sub, "jaxpr", None)
                    if inner is not None:
                        walk(inner)

    walk(closed_jaxpr.jaxpr)


def _flat_posts(seed: int, n: int, p: int) -> FlatPosterior:
    """Plain [N, P] posterior buffers (no ragged pytree: the population-scale
    sweep times the consensus, not the flatten)."""
    rng = np.random.default_rng(seed)
    layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
    return FlatPosterior(
        mean=jnp.asarray(rng.normal(size=(n, p)), jnp.float32),
        rho=jnp.asarray(rng.normal(size=(n, p)) * 0.3 - 1.0, jnp.float32),
        layout=layout,
    )


def _segments_equivalence(n: int = 24, p: int = 96, seed: int = 5) -> dict:
    """Pin ``consensus_flat_segments`` against the dense reference on a
    small Watts-Strogatz graph, per wire dtype.  The wire-rounded exchange
    values are bitwise the reference's (same ``wire_roundtrip`` chain); the
    scatter accumulates in edge order vs the matmul's column order, so the
    comparison is elementwise at fp32 reduction-order tolerance."""
    g = watts_strogatz_sparse(n, k=4, beta=0.3, seed=seed)
    posts = _flat_posts(seed, n, p)
    W = jnp.asarray(g.to_dense(), jnp.float32)
    dst, src, w = (jnp.asarray(a) for a in g.edge_arrays())
    out = {}
    for wire in ("f32", "bf16", "f16"):
        ref_m, ref_r = consensus_flat_reference(
            posts.mean, posts.rho, W, wire_dtype=wire
        )
        got = consensus_flat_segments(posts, dst, src, w, wire_dtype=wire)
        err = max(
            float(jnp.max(jnp.abs(got.mean - ref_m))),
            float(jnp.max(jnp.abs(got.rho - ref_r))),
        )
        tol = 1e-4  # fp32 reduction-order tolerance (per-element)
        assert err <= tol, f"segments vs dense reference ({wire}): {err} > {tol}"
        out[wire] = err
    return out


# Population-scale sparse sweep: (n_agents, p, k, beta) on Watts-Strogatz.
# Only O(E) representations exist on this path — asserted on the jaxpr.
SEGMENTS_QUICK_SWEEP = [(10_000, 32, 6, 0.1)]
SEGMENTS_FULL_SWEEP = [
    (10_000, 64, 6, 0.1),
    (30_000, 64, 6, 0.1),
    (100_000, 32, 6, 0.1),  # N = 10^5: ~7e5 directed edges, still O(E)
]


def segments_sweep(quick: bool = False, iters: int = 5, seed: int = 0) -> dict:
    """The N = 10^4..10^5 edge-native sweep: time
    ``consensus_flat_segments`` on sparse small-world graphs no dense path
    could even allocate, against the E-parameterized roofline."""
    sweep = SEGMENTS_QUICK_SWEEP if quick else SEGMENTS_FULL_SWEEP
    entries = []
    for n, p, k, beta in sweep:
        t0 = time.perf_counter()
        g = watts_strogatz_sparse(n, k=k, beta=beta, seed=seed)
        build_s = time.perf_counter() - t0
        # host-side O(E) contract: every graph array is E- or N-sized
        for arr in (g.indptr, g.indices, g.weights):
            assert arr.size <= max(g.n_edges, n + 1)
        dst, src, w = (jnp.asarray(a) for a in g.edge_arrays())
        posts = _flat_posts(seed, n, p)
        fn = jax.jit(
            lambda fp, d, s, ww: consensus_flat_segments(fp, d, s, ww).mean
        )
        # device-side O(E) contract: no [N, N] aval anywhere in the trace
        assert_no_dense_square(jax.make_jaxpr(fn)(posts, dst, src, w), n)
        us = _time(fn, (posts, dst, src, w), iters)
        roof = consensus_roofline(
            n, p, 1, max_degree=g.max_in_degree, n_edges=g.n_edges
        )
        entries.append({
            "n_agents": n,
            "p": p,
            "k": k,
            "beta": beta,
            "n_edges": g.n_edges,
            "max_in_degree": g.max_in_degree,
            "graph_build_seconds": build_s,
            "us_flat_segments": us,
            "roofline": roof,
            "no_dense_alloc_asserted": True,
        })
        print(
            f"bench_consensus_segments[{n}x{p}:ws{k}],"
            f"{us:.1f},"
            f"E={g.n_edges};model_bytes={roof['hbm_bytes']['flat_segments']:.0f}"
        )
    # measured-vs-modeled scaling between consecutive sweep points: the
    # E-parameterized model should track the measured growth far better
    # than any N^2 law (recorded, not asserted — CI wall-clock is noisy)
    scaling = []
    for a, b in zip(entries, entries[1:]):
        scaling.append({
            "from": f"{a['n_agents']}x{a['p']}",
            "to": f"{b['n_agents']}x{b['p']}",
            "measured_ratio": b["us_flat_segments"] / a["us_flat_segments"],
            "modeled_ratio": (
                b["roofline"]["hbm_bytes"]["flat_segments"]
                / a["roofline"]["hbm_bytes"]["flat_segments"]
            ),
            "n2_ratio": (b["n_agents"] / a["n_agents"]) ** 2,
        })
    return {
        "equivalence_max_err": _segments_equivalence(),
        "sweep": entries,
        "scaling": scaling,
    }


# (n_agents, p, topology, n_leaves) — n_leaves is a first-class axis: the
# leaf-loop baseline pays per-leaf dispatch, so shallow pytrees (few big
# leaves) are its best case and deep-model pytrees (hundreds of leaves, the
# realistic regime — e.g. whisper-tiny has ~700) its worst.
QUICK_SWEEP = [(4, 4096, "ring", 8)]
FULL_SWEEP = [
    (4, 1 << 16, "complete", 32),
    (4, 1 << 16, "ring", 32),
    (9, 1 << 16, "star", 64),
    (9, 1 << 18, "ring", 32),
    (16, 1 << 18, "complete", 64),
    (16, 1 << 18, "ring", 128),
    (26, 1 << 16, "star", 420),
    (26, 1 << 18, "star", 420),  # largest CPU-feasible config
]


def run(quick: bool = False, json_out: str | None = DEFAULT_JSON) -> dict:
    """Execute the sweep; returns (and optionally writes) the JSON document.

    Also prints the harness's usual ``name,us_per_call,derived`` CSV rows so
    ``benchmarks/run.py`` aggregation keeps working.
    """
    sweep = QUICK_SWEEP if quick else FULL_SWEEP
    results = []
    for i, (n, p, topo, n_leaves) in enumerate(sweep):
        rec = bench_one(
            n, p, topo,
            n_leaves=n_leaves,
            iters=3 if quick else 10,
            check_interpret=(i == 0),  # one interpreter correctness probe
        )
        results.append(rec)
        print(
            f"bench_consensus[{n}x{rec['p']}:{topo}],"
            f"{rec['us']['flat_fused']:.1f},"
            f"speedup={rec['speedup_flat_vs_leaf_loop']:.2f}x"
        )
    segments = segments_sweep(quick=quick, iters=3 if quick else 5)
    wire = wire_sweep(iters=3 if quick else 5)
    for rec in wire:
        print(
            f"bench_consensus_wire[{rec['wire_dtype']}],"
            f"{rec['us_flat_fused']:.1f},"
            f"collective_bytes={rec['roofline_wire']['collective_bytes']:.0f};"
            f"max_err={rec['max_err_vs_f32']:.2e}"
        )
    doc = {
        "benchmark": "consensus_eq6",
        "backend": jax.default_backend(),
        "quick": quick,
        "results": results,
        "segments": segments,
        "wire": wire,
        "summary": {
            "max_speedup_flat_vs_leaf_loop": max(
                r["speedup_flat_vs_leaf_loop"] for r in results
            ),
            "largest_config_speedup_best_flat_vs_leaf_loop": results[-1][
                "speedup_best_flat_vs_leaf_loop"
            ],
            "model_speedup_fused_vs_leaf_loop": results[-1]["roofline"][
                "model_speedup_fused_vs_leaf_loop"
            ],
        },
    }
    if json_out:
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {json_out}")
    return doc


if __name__ == "__main__":
    run()
