"""Paper Fig 5 / Sec 4.2.2 "effect of the type of data partition":
Assumption 2 in practice.  With a CONFUSABLE class pair ({4,9}-analogue)
split so that no agent sees both, the network cannot learn to distinguish
them (low OOD confidence / accuracy on the pair); a clean partition that
keeps the confusable pair co-located learns fine."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, agent_confidence, emit, network_accuracy, train_network
from repro.core.graphs import star_w
from repro.data.partition import star_partition
from repro.data.synthetic import make_synthetic_classification

N_EDGE = 8


def run(rounds: int = 18) -> None:
    ds = make_synthetic_classification(
        n_classes=10, dim=64, n_train_per_class=200, noise=0.5,
        confusable_pairs=((4, 9),), confusable_gap=2.5, seed=0,
    )
    W = np.asarray(star_w(N_EDGE, 0.5))
    pair_mask = np.isin(ds.y_test, [4, 9])

    # ambiguous: center {0..7} (has 4), edges {8,9} (have 9) -> nobody sees both
    t = Timer()
    shards_bad = star_partition(
        ds.x_train, ds.y_train, center_labels=list(range(8)),
        edge_labels=[8, 9], n_edge=N_EDGE,
    )
    state_bad, _ = train_network(shards_bad, W, rounds, seed=0)
    acc_bad = network_accuracy(state_bad, ds.x_test, ds.y_test)
    pair_bad = network_accuracy(
        state_bad, ds.x_test[pair_mask], ds.y_test[pair_mask]
    )
    conf_bad = agent_confidence(state_bad, 0, ds.x_test[ds.y_test == 9], 9)
    emit("fig5_partition_ambiguous", t.us(),
         f"acc={acc_bad:.4f};pair_acc={pair_bad:.4f};center_conf_9={conf_bad:.3f}")

    # clean: the confusable pair lives together at the center
    t = Timer()
    shards_ok = star_partition(
        ds.x_train, ds.y_train, center_labels=[2, 3, 4, 5, 6, 7, 8, 9],
        edge_labels=[0, 1], n_edge=N_EDGE,
    )
    state_ok, _ = train_network(shards_ok, W, rounds, seed=0)
    acc_ok = network_accuracy(state_ok, ds.x_test, ds.y_test)
    pair_ok = network_accuracy(state_ok, ds.x_test[pair_mask], ds.y_test[pair_mask])
    emit("fig5_partition_clean", t.us(), f"acc={acc_ok:.4f};pair_acc={pair_ok:.4f}")

    assert pair_ok > pair_bad + 0.05, (pair_ok, pair_bad)

    # FMNIST analogue (paper Fig 5b vs 5c): the shirt-like family
    # {t-shirt 0, pullover 2, dress 3, coat 4, shirt 6} is clustered.
    # Setup2 splits pullover AWAY from its family (edges hold it with shoes)
    # -> family members confuse; Setup1 keeps the family together at the
    # center -> clean.
    from repro.data.synthetic import fmnist_like

    fm = fmnist_like(dim=64, n_train_per_class=200, noise=0.8, seed=1)
    shirt_family = [0, 2, 3, 4, 6]
    fam_mask = np.isin(fm.y_test, shirt_family)
    for tag, center, edge in (
        ("setup1", [0, 2, 3, 4, 6, 8], [1, 5, 7, 9]),  # family together
        ("setup2", [0, 1, 3, 4, 6, 8], [2, 5, 7, 9]),  # pullover split out
    ):
        t = Timer()
        sh = star_partition(fm.x_train, fm.y_train, center, edge, n_edge=N_EDGE)
        st, _ = train_network(sh, W, rounds, seed=0)
        fam_acc = network_accuracy(st, fm.x_test[fam_mask], fm.y_test[fam_mask])
        acc = network_accuracy(st, fm.x_test, fm.y_test)
        emit(f"fig5_fmnist_{tag}", t.us(),
             f"acc={acc:.4f};shirt_family_acc={fam_acc:.4f}")
        if tag == "setup1":
            fam_setup1 = fam_acc
    assert fam_setup1 > fam_acc + 0.1, (fam_setup1, fam_acc)
