"""Paper Fig 5 / Sec 4.2.2 "effect of the type of data partition":
Assumption 2 in practice.  With a CONFUSABLE class pair ({4,9}-analogue)
split so that no agent sees both, the network cannot learn to distinguish
them (low OOD confidence / accuracy on the pair); a clean partition that
keeps the confusable pair co-located learns fine."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    Timer,
    agent_confidence,
    classification_spec,
    emit,
    network_accuracy,
    run_classification,
)
from repro.api import TopologySpec

N_EDGE = 8
TOPOLOGY = TopologySpec.star(N_EDGE, 0.5)


def _star_session(rounds, dataset, dataset_params, center, edge):
    return run_classification(classification_spec(
        TOPOLOGY,
        rounds=rounds,
        dataset=dataset,
        dataset_params=dataset_params,
        partition="star",
        partition_params=dict(center_labels=center, edge_labels=edge, n_edge=N_EDGE),
    ))


def run(rounds: int = 18) -> None:
    mnist_params = dict(
        n_classes=10, dim=64, n_train_per_class=200, noise=0.5,
        confusable_pairs=[[4, 9]], confusable_gap=2.5, seed=0,
    )

    # ambiguous: center {0..7} (has 4), edges {8,9} (have 9) -> nobody sees both
    t = Timer()
    sess_bad = _star_session(rounds, "synthetic_classification", mnist_params,
                             list(range(8)), [8, 9])
    ds = sess_bad.data.dataset
    pair_mask = np.isin(ds.y_test, [4, 9])
    acc_bad = network_accuracy(sess_bad, ds.x_test, ds.y_test)
    pair_bad = network_accuracy(sess_bad, ds.x_test[pair_mask], ds.y_test[pair_mask])
    conf_bad = agent_confidence(sess_bad, 0, ds.x_test[ds.y_test == 9], 9)
    emit("fig5_partition_ambiguous", t.us(),
         f"acc={acc_bad:.4f};pair_acc={pair_bad:.4f};center_conf_9={conf_bad:.3f}")

    # clean: the confusable pair lives together at the center
    t = Timer()
    sess_ok = _star_session(rounds, "synthetic_classification", mnist_params,
                            [2, 3, 4, 5, 6, 7, 8, 9], [0, 1])
    acc_ok = network_accuracy(sess_ok, ds.x_test, ds.y_test)
    pair_ok = network_accuracy(sess_ok, ds.x_test[pair_mask], ds.y_test[pair_mask])
    emit("fig5_partition_clean", t.us(), f"acc={acc_ok:.4f};pair_acc={pair_ok:.4f}")

    assert pair_ok > pair_bad + 0.05, (pair_ok, pair_bad)

    # FMNIST analogue (paper Fig 5b vs 5c): the shirt-like family
    # {t-shirt 0, pullover 2, dress 3, coat 4, shirt 6} is clustered.
    # Setup2 splits pullover AWAY from its family (edges hold it with shoes)
    # -> family members confuse; Setup1 keeps the family together at the
    # center -> clean.
    fm_params = dict(dim=64, n_train_per_class=200, noise=0.8, seed=1)
    shirt_family = [0, 2, 3, 4, 6]
    for tag, center, edge in (
        ("setup1", [0, 2, 3, 4, 6, 8], [1, 5, 7, 9]),  # family together
        ("setup2", [0, 1, 3, 4, 6, 8], [2, 5, 7, 9]),  # pullover split out
    ):
        t = Timer()
        st = _star_session(rounds, "fmnist_like", fm_params, center, edge)
        fm = st.data.dataset
        fam_mask = np.isin(fm.y_test, shirt_family)
        fam_acc = network_accuracy(st, fm.x_test[fam_mask], fm.y_test[fam_mask])
        acc = network_accuracy(st, fm.x_test, fm.y_test)
        emit(f"fig5_fmnist_{tag}", t.us(),
             f"acc={acc:.4f};shirt_family_acc={fam_acc:.4f}")
        if tag == "setup1":
            fam_setup1 = fam_acc
    assert fam_setup1 > fam_acc + 0.1, (fam_setup1, fam_acc)
