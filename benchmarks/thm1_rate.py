"""Theorem 1: the predicted rate K(Theta) (eq. 7) vs the empirical
exponential decay of the max wrong-parameter belief, across topologies.
Expected: empirical slope tracks K's ORDERING across W's, and the belief
stays below the exp(-n(K-eps)) envelope asymptotically."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit
from repro.core.discrete import run_social_learning, wrong_belief_trajectory
from repro.core.graphs import complete_w, ring_w, star_w
from repro.core.theory import rate_K, stationary_distribution

BATCH = 4
NOISE = 1.0


def _empirical_slope(W, means, rounds=150, seed=0):
    n_agents, n_theta = means.shape

    def sampler(k):
        y = means[:, 0:1] + NOISE * jax.random.normal(k, (n_agents, BATCH))
        return -0.5 * jnp.sum(
            ((y[:, :, None] - means[:, None, :]) / NOISE) ** 2, axis=1
        )

    traj = run_social_learning(
        jax.random.key(seed), jnp.asarray(W), sampler, rounds, n_theta
    )
    wrong = np.asarray(wrong_belief_trajectory(traj, jnp.arange(1, n_theta)))
    tail = np.arange(rounds // 3, rounds)
    valid = wrong[tail] > 1e-300
    if valid.sum() < 5:
        return float("inf"), wrong
    slope = -np.polyfit(tail[valid], np.log(wrong[tail][valid]), 1)[0]
    return slope, wrong


def run() -> None:
    rng = np.random.default_rng(0)
    n, t = 5, 3
    means = rng.normal(0, 0.8, (n, t)).astype(np.float32)
    means[:, 0] = 0.0
    means_j = jnp.asarray(means)

    predicted, measured = [], []
    for name, W in (
        ("complete", complete_w(n)),
        ("star_a0.5", star_w(n - 1, 0.5)),
        ("ring", ring_w(n)),
    ):
        timer = Timer()
        v = stationary_distribution(W)
        I = np.zeros((n, 1, t - 1))
        for j in range(n):
            for tt in range(1, t):
                I[j, 0, tt - 1] = BATCH * (means[j, 0] - means[j, tt]) ** 2 / (2 * NOISE**2)
        K = rate_K(v, I)
        slopes = [_empirical_slope(W, means_j, seed=s)[0] for s in range(3)]
        slope = float(np.mean([s for s in slopes if np.isfinite(s)]))
        predicted.append(K)
        measured.append(slope)
        emit(f"thm1_rate_{name}", timer.us(), f"K={K:.4f};empirical_slope={slope:.4f}")
    # Theorem 1 is a lower bound on the decay: empirical >= ~K
    for K, s in zip(predicted, measured):
        assert s > 0.5 * K, (K, s)
