"""Paper Fig 4/9: 3x3 grid, degree-uniform W.  The informative (Type-1)
agent placed at the CENTER (highest eigenvector centrality) vs a CORNER.
Expected: center placement converges faster / higher accuracy."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, classification_spec, emit, run_classification
from repro.api import TopologySpec
from repro.core.graphs import grid_w
from repro.core.theory import stationary_distribution

DATASET = dict(n_classes=10, dim=64, n_train_per_class=200, noise=0.55, seed=0)


def run(rounds: int = 18) -> None:
    v = stationary_distribution(grid_w(3, 3))
    results = {}
    for name, pos in (("center", 4), ("corner", 0)):
        t = Timer()
        session = run_classification(classification_spec(
            TopologySpec.grid(3, 3),
            rounds=rounds,
            dataset_params=DATASET,
            partition="grid",
            partition_params=dict(
                type1_labels=list(range(2, 10)), type2_labels=[0, 1],
                type1_position=pos,
            ),
        ))
        acc = session.evaluate()["avg_acc"]
        results[name] = acc
        emit(f"fig4_grid_{name}", t.us(), f"acc={acc:.4f};v_type1={v[pos]:.3f}")
    assert results["center"] > results["corner"] - 0.01, results
