"""Paper Fig 4/9: 3x3 grid, degree-uniform W.  The informative (Type-1)
agent placed at the CENTER (highest eigenvector centrality) vs a CORNER.
Expected: center placement converges faster / higher accuracy."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit, network_accuracy, train_network
from repro.core.graphs import grid_w
from repro.core.theory import stationary_distribution
from repro.data.partition import grid_partition
from repro.data.synthetic import make_synthetic_classification


def run(rounds: int = 18) -> None:
    ds = make_synthetic_classification(
        n_classes=10, dim=64, n_train_per_class=200, noise=0.55, seed=0
    )
    W = grid_w(3, 3)
    v = stationary_distribution(W)
    results = {}
    for name, pos in (("center", 4), ("corner", 0)):
        t = Timer()
        shards = grid_partition(
            ds.x_train, ds.y_train, type1_labels=list(range(2, 10)),
            type2_labels=[0, 1], type1_position=pos,
        )
        state, _ = train_network(shards, np.asarray(W), rounds, seed=0)
        acc = network_accuracy(state, ds.x_test, ds.y_test)
        results[name] = acc
        emit(f"fig4_grid_{name}", t.us(), f"acc={acc:.4f};v_type1={v[pos]:.3f}")
    assert results["center"] > results["corner"] - 0.01, results
