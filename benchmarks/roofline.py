"""Roofline report: aggregates the dry-run JSONs (launch/dryrun.py) into the
EXPERIMENTS.md §Roofline table and emits CSV rows.  Also benchmarks the
consensus + gauss_vi kernels (interpret mode) at model-scale parameter
counts as microbenchmarks."""
from __future__ import annotations

import glob
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, emit

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load_results(mesh: str = "single") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"dryrun_*_{mesh}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def run() -> None:
    for mesh in ("single", "multi"):
        rows = load_results(mesh)
        ok = 0
        for r in rows:
            name = f"roofline_{r['arch']}_{r['shape']}_{mesh}"
            if r["status"] != "ok":
                emit(name, 0.0, f"status={r['status']}")
                continue
            ok += 1
            t = r["roofline_seconds"]
            emit(
                name,
                t[r["dominant"]] * 1e6,  # dominant-term seconds -> us
                f"dominant={r['dominant']};compute_s={t['compute']:.3e};"
                f"memory_s={t['memory']:.3e};collective_s={t['collective']:.3e};"
                f"useful_flops={r['useful_flops_ratio']:.2f}",
            )
        if rows:
            emit(f"roofline_{mesh}_summary", 0.0, f"ok={ok}/{len(rows)}")

    # kernel microbenchmarks (interpret mode: correctness-path timing only)
    p = 1 << 20
    n = 9
    ks = jax.random.split(jax.random.key(0), 3)
    w = jax.nn.softmax(jax.random.normal(ks[0], (n,)))
    mean = jax.random.normal(ks[1], (n, p))
    rho = jax.random.normal(ks[2], (n, p)) * 0.3
    from repro.kernels.consensus import consensus_fused

    consensus_fused(w, mean, rho)  # compile
    t = Timer()
    reps = 3
    for _ in range(reps):
        jax.block_until_ready(consensus_fused(w, mean, rho))
    emit("kernel_consensus_1M_params", t.us(reps), f"n_neighbors={n};interpret=True")

    from repro.kernels.gauss_vi import sample_and_kl_fused

    mu = mean[0]
    eps = mean[1]
    sample_and_kl_fused(mu, rho[0], eps, mu * 0, rho[1])
    t = Timer()
    for _ in range(reps):
        jax.block_until_ready(sample_and_kl_fused(mu, rho[0], eps, mu * 0, rho[1]))
    emit("kernel_gauss_vi_1M_params", t.us(reps), "interpret=True")
