"""Chaos-harness smoke (`benchmarks/run.py chaos-smoke`).

Five parts, mirroring what the ROADMAP Robustness section promises:

1. **Combined chaos run** (the tentpole scenario): a Poisson ring under
   agent churn (Markov crash/recover), link drops (``failure_injected``),
   delivery latency (``delayed``) and payload corruption (NaN/Inf/huge
   garbage on the wire), defended by ``fault_policy="quarantine"`` — every
   trained-agent loss finite, every resident posterior finite
   (``Session.health()`` all-ok: the injected garbage never propagates),
   fault telemetry populated, one jitted call per window.
2. **Strict counter-demo**: the SAME chaos with the undefended
   ``fault_policy="strict"`` — the injected NaN/Inf reaches and poisons
   agents (asserted: strictly fewer healthy posteriors than quarantine,
   which keeps all N).
3. **Zero-fault bitwise ladder**: with no fault model, the quarantined
   session's trajectory must be BIT-identical to the strict session's on
   the same spec — the guard is structurally free when healthy.
4. **Consensus contraction under churn**: an lr=0 probe (local steps are
   no-ops, only consensus acts) — the across-agent posterior spread must
   contract over the run despite crash/recover churn, because quarantined
   W-tilde rows stay row-stochastic (mass moves to self, never leaks).
5. **Degradation-vs-fault-rate sweep**: the same ring at increasing crash
   rates — uptime falls and merges thin out gracefully; losses stay
   finite at every rate (no cliff, no NaN).

Output: ``BENCH_chaos.json`` + the harness's ``name,us_per_call,derived``
CSV rows.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.obs.trace import CompileWarmTimer

DEFAULT_JSON = "BENCH_chaos.json"

_FAULTS = {
    "crash_rate": 0.15,
    "recover_rate": 0.5,
    "corrupt_rate": 0.25,
    "corrupt_kind": "mix",
    "seed": 7,
}


def _chaos_spec(
    n: int,
    policy: str,
    faults: dict | None,
    n_rounds: int = 8,
    lr: float = 1e-2,
    delayed: bool = True,
):
    """The combined-chaos ExperimentSpec: Poisson activations, dropped
    links, delivery latency, and (optionally) the agent fault model."""
    from repro.api import (
        DataSpec, ExperimentSpec, InferenceSpec, RunSpec, TopologySpec,
    )

    inner = {
        "kind": "failure_injected",
        "inner": {"kind": "poisson", "rate": 0.8, "seed": 1},
        "drop_rate": 0.1,
    }
    clock: dict = (
        {"kind": "delayed", "inner": inner,
         "latency": {"kind": "geometric", "p": 0.5, "max_delay": 2,
                     "seed": 5}}
        if delayed else dict(inner)
    )
    if faults is not None:
        clock["faults"] = dict(faults)
    return ExperimentSpec(
        topology=TopologySpec.gossip("bidirectional_ring", {"n": n},
                                     clock=clock),
        data=DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
            partition="iid", partition_params=dict(n_agents=n),
            batch_size=4, local_updates=2,
        ),
        inference=InferenceSpec(hidden=8, depth=1, lr=lr,
                                fault_policy=policy),
        run=RunSpec(n_rounds=n_rounds, seed=0),
    )


def _combined_chaos(n: int = 6, n_rounds: int = 8) -> dict:
    from repro.api import build_session

    s = build_session(_chaos_spec(n, "quarantine", _FAULTS,
                                  n_rounds=n_rounds))
    t = CompileWarmTimer()
    with t.compile():
        recs = [s.round()]
    with t.warm():
        for _ in range(n_rounds - 1):
            recs.append(s.round())
    compile_us, wall_us = t.compile_us, t.warm_us
    # every reported (trained-agent) loss finite; idle/crashed windows may
    # legitimately report None
    losses = [r["loss"] for r in recs if r["loss"] is not None]
    assert losses and all(np.isfinite(v) for v in losses), \
        f"non-finite chaos losses: {losses}"
    health = s.health()
    assert health["all_ok"], \
        f"quarantine let garbage reach a resident posterior: {health}"
    assert s.engine.n_traces == 1, "guarded window retraced"
    tel = s.evaluate(n_mc=1)
    faults = tel["engine"]["faults"]
    assert faults["quarantined"]["total"] > 0, \
        "chaos run quarantined nothing — the injection is not exercising " \
        "the guard"
    assert any(r.get("n_crashed", 0) > 0 for r in recs), \
        "chaos run never crashed an agent"
    return {
        "n_agents": n,
        "windows": int(tel["engine"]["windows"]),
        "final_loss": losses[-1],
        "n_crashed_per_round": [int(r.get("n_crashed", 0)) for r in recs],
        "health": health,
        "faults": faults,
        "staleness": tel["engine"]["staleness"],
        "merges": tel["engine"]["merges"],
        "n_traces": int(s.engine.n_traces),
        "compile_us": compile_us,
        "wall_us_per_window": wall_us / (n_rounds - 1),
    }


def _strict_poison_demo(n: int = 6, n_rounds: int = 8) -> dict:
    """The undefended baseline on the same chaos: injected garbage
    propagates through the trusting consensus and poisons posteriors."""
    from repro.api import build_session

    s = build_session(_chaos_spec(n, "strict", _FAULTS, n_rounds=n_rounds))
    for _ in range(n_rounds):
        s.round()
    health = s.health()
    assert health["n_healthy"] < n, (
        "strict consensus survived the corruption injection — the chaos "
        "scenario is too weak to demonstrate the failure mode"
    )
    return {"n_healthy": health["n_healthy"], "n_agents": n,
            "ok": health["ok"]}


def _zero_fault_bitwise(n: int = 6, n_rounds: int = 5) -> dict:
    """No fault model: quarantine must be bitwise the strict trajectory
    (both on the instant and the delayed clock paths)."""
    from repro.api import build_session

    out = {}
    for delayed in (False, True):
        posts = {}
        for policy in ("strict", "quarantine"):
            s = build_session(_chaos_spec(n, policy, None,
                                          n_rounds=n_rounds,
                                          delayed=delayed))
            for _ in range(n_rounds):
                s.round()
            posts[policy] = s.posterior()
        np.testing.assert_array_equal(
            np.asarray(posts["strict"].mean),
            np.asarray(posts["quarantine"].mean),
        )
        np.testing.assert_array_equal(
            np.asarray(posts["strict"].rho),
            np.asarray(posts["quarantine"].rho),
        )
        out["delayed" if delayed else "instant"] = True
    return out


def _contraction_probe(n: int = 6, n_rounds: int = 10) -> dict:
    """lr=0: only the consensus acts.  Quarantined churned consensus must
    still CONTRACT the across-agent spread — the conserve rule keeps every
    W-tilde row row-stochastic, so averaging never diverges."""
    from repro.api import build_session

    faults = dict(_FAULTS, corrupt_rate=0.0)  # churn-only probe
    spec = _chaos_spec(n, "quarantine", faults, n_rounds=n_rounds, lr=0.0,
                       delayed=False)
    spec = dataclasses.replace(
        spec, inference=dataclasses.replace(spec.inference, shared_init=False)
    )
    s = build_session(spec)
    mean0 = np.asarray(s.posterior().mean)
    spread_start = float(np.max(np.ptp(mean0, axis=0)))
    for _ in range(n_rounds):
        s.round()
    mean1 = np.asarray(s.posterior().mean)
    spread_end = float(np.max(np.ptp(mean1, axis=0)))
    assert spread_end < spread_start, (
        f"churned quarantined consensus failed to contract: "
        f"{spread_start} -> {spread_end}"
    )
    return {"spread_start": spread_start, "spread_end": spread_end,
            "contraction": spread_end / spread_start}


def _fault_rate_sweep(n: int = 6, n_rounds: int = 8) -> list[dict]:
    from repro.api import build_session

    out = []
    for crash_rate in (0.0, 0.1, 0.3):
        faults = dict(_FAULTS, crash_rate=crash_rate)
        s = build_session(_chaos_spec(n, "quarantine", faults,
                                      n_rounds=n_rounds))
        losses = []
        for _ in range(n_rounds):
            rec = s.round()
            if rec["loss"] is not None:
                losses.append(rec["loss"])
        assert losses and all(np.isfinite(v) for v in losses), \
            f"non-finite losses at crash_rate={crash_rate}"
        assert s.health()["all_ok"], \
            f"unhealthy posterior at crash_rate={crash_rate}"
        tel = s.evaluate(n_mc=1)
        out.append({
            "crash_rate": crash_rate,
            "final_loss": losses[-1],
            "uptime_frac_mean": tel["engine"]["faults"].get("uptime", {}).get(
                "frac_mean", 1.0),
            "merges_total": tel["engine"]["merges"]["total"],
            "quarantined_total": tel["engine"]["faults"].get(
                "quarantined", {}).get("total", 0),
            "avg_acc": tel["avg_acc"],
        })
    # graceful degradation: more churn => fewer windows up, fewer merges
    assert out[0]["merges_total"] >= out[-1]["merges_total"], \
        "crash churn did not thin the merge count"
    return out


def run(json_out: str | None = DEFAULT_JSON) -> dict:
    import jax

    chaos = _combined_chaos()
    print(f"chaos_combined,{chaos['wall_us_per_window']:.1f},"
          f"windows={chaos['windows']};loss={chaos['final_loss']:.4f};"
          f"quarantined={chaos['faults']['quarantined']['total']};"
          f"healthy={chaos['health']['n_healthy']}/{chaos['n_agents']};"
          f"traces={chaos['n_traces']}")
    strict = _strict_poison_demo()
    print(f"chaos_strict_poison,0.0,"
          f"healthy={strict['n_healthy']}/{strict['n_agents']}")
    bitwise = _zero_fault_bitwise()
    print(f"chaos_zero_fault_bitwise,0.0,"
          f"instant={int(bitwise['instant'])};"
          f"delayed={int(bitwise['delayed'])}")
    contraction = _contraction_probe()
    print(f"chaos_contraction,0.0,"
          f"ratio={contraction['contraction']:.4f}")
    sweep = _fault_rate_sweep()
    for rec in sweep:
        print(f"chaos_rate[c={rec['crash_rate']}],0.0,"
              f"loss={rec['final_loss']:.4f};"
              f"uptime={rec['uptime_frac_mean']:.3f};"
              f"merges={rec['merges_total']};"
              f"quarantined={rec['quarantined_total']}")
    doc = {
        "benchmark": "gossip_chaos_harness",
        "backend": jax.default_backend(),
        "combined_chaos": chaos,
        "strict_poison_demo": strict,
        "zero_fault_bitwise": bitwise,
        "contraction_probe": contraction,
        "fault_rate_sweep": sweep,
    }
    if json_out:
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {json_out}")
    return doc


if __name__ == "__main__":
    run()
