"""Paper Fig 1: decentralized Bayesian linear regression, 4 agents, extreme
non-IID feature partition.  Compares (i) centralized, (ii) isolated
(no cooperation), (iii) decentralized consensus — test MSE on the global
distribution.  Expected: (iii) ~= (i) ~= noise floor, (ii) far worse.

The decentralized arms are two ``ExperimentSpec``s differing ONLY in the
consensus mode (the isolation baseline is ``consensus="none"`` — a
disconnected W would be rejected by the spec validator, by design)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit
from repro.api import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    RunSpec,
    TopologySpec,
    build_session,
)
from repro.core.posterior import FullCovGaussian, linreg_bayes_update
from repro.data.linreg import make_linreg_task


def _decentralized_mse(consensus: str, rounds: int) -> float:
    session = build_session(ExperimentSpec(
        topology=TopologySpec.complete(4),
        data=DataSpec(dataset="linreg", batch_size=10),
        inference=InferenceSpec(method="conjugate_linreg", consensus=consensus),
        run=RunSpec(n_rounds=rounds, seed=0),
    ))
    session.run()
    return session.evaluate()["avg_mse"]


def run() -> None:
    task = make_linreg_task()
    rng = np.random.default_rng(1)
    rounds = 150

    t = Timer()
    # (i) centralized: one agent sees everything (exact conjugate posterior)
    phi_all, y_all = [], []
    for i in range(4):
        p, y = task.sample_local(rng, i, 10 * rounds)
        phi_all.append(p)
        y_all.append(y)
    phi_all = np.concatenate(phi_all)
    y_all = np.concatenate(y_all)
    central = linreg_bayes_update(
        FullCovGaussian(jnp.zeros(task.d), jnp.eye(task.d) / 0.5),
        jnp.asarray(phi_all), jnp.asarray(y_all), task.noise_std**2,
    )
    phi_t, y_t = task.sample_global(rng, 4000)
    mse_central = float(np.mean((phi_t @ np.asarray(central.mean) - y_t) ** 2))

    mse_coop = _decentralized_mse("gaussian", rounds)
    mse_iso = _decentralized_mse("none", rounds)
    noise_floor = task.noise_std**2
    emit("fig1_linreg_central", t.us(), f"mse={mse_central:.4f};floor={noise_floor:.3f}")
    emit("fig1_linreg_cooperative", t.us(), f"mse={mse_coop:.4f}")
    emit("fig1_linreg_isolated", t.us(), f"mse={mse_iso:.4f}")
    assert mse_coop < noise_floor * 1.15, "cooperation must reach the floor"
    assert mse_iso > mse_coop * 1.2, "isolation must be worse"
