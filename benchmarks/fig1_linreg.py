"""Paper Fig 1: decentralized Bayesian linear regression, 4 agents, extreme
non-IID feature partition.  Compares (i) centralized, (ii) isolated
(no cooperation), (iii) decentralized consensus — test MSE on the global
distribution.  Expected: (iii) ~= (i) ~= noise floor, (ii) far worse."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import Timer, emit
from repro.core.posterior import FullCovGaussian, consensus_full_cov, linreg_bayes_update
from repro.core.graphs import complete_w
from repro.data.linreg import make_linreg_task


def _run(W, rounds, task, seed=0):
    rng = np.random.default_rng(seed)
    n, d = 4, task.d
    posts = FullCovGaussian(
        mean=jnp.zeros((n, d)),
        prec=jnp.broadcast_to(jnp.eye(d) / 0.5, (n, d, d)),
    )
    Wj = jnp.asarray(W)
    for _ in range(rounds):
        means, precs = [], []
        for i in range(n):
            phi, y = task.sample_local(rng, i, 10)
            p = linreg_bayes_update(
                FullCovGaussian(posts.mean[i], posts.prec[i]),
                jnp.asarray(phi), jnp.asarray(y), task.noise_std**2,
            )
            means.append(p.mean)
            precs.append(p.prec)
        posts = consensus_full_cov(FullCovGaussian(jnp.stack(means), jnp.stack(precs)), Wj)
    phi_t, y_t = task.sample_global(rng, 4000)
    return float(np.mean([
        np.mean((phi_t @ np.asarray(posts.mean[i]) - y_t) ** 2) for i in range(n)
    ]))


def run() -> None:
    task = make_linreg_task()
    rng = np.random.default_rng(1)
    rounds = 150

    t = Timer()
    # (i) centralized: one agent sees everything
    phi_all, y_all = [], []
    for i in range(4):
        p, y = task.sample_local(rng, i, 10 * rounds)
        phi_all.append(p)
        y_all.append(y)
    phi_all = np.concatenate(phi_all)
    y_all = np.concatenate(y_all)
    central = linreg_bayes_update(
        FullCovGaussian(jnp.zeros(task.d), jnp.eye(task.d) / 0.5),
        jnp.asarray(phi_all), jnp.asarray(y_all), task.noise_std**2,
    )
    phi_t, y_t = task.sample_global(rng, 4000)
    mse_central = float(np.mean((phi_t @ np.asarray(central.mean) - y_t) ** 2))

    mse_coop = _run(complete_w(4), rounds, task)
    mse_iso = _run(np.eye(4), rounds, task)
    noise_floor = task.noise_std**2
    emit("fig1_linreg_central", t.us(), f"mse={mse_central:.4f};floor={noise_floor:.3f}")
    emit("fig1_linreg_cooperative", t.us(), f"mse={mse_coop:.4f}")
    emit("fig1_linreg_isolated", t.us(), f"mse={mse_iso:.4f}")
    assert mse_coop < noise_floor * 1.15, "cooperation must reach the floor"
    assert mse_iso > mse_coop * 1.2, "isolation must be worse"
