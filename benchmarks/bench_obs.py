"""Observability-layer smoke (`benchmarks/run.py obs-smoke`).

Four parts, pinning the ROADMAP "Observability" contracts:

1. **Disabled-span overhead**: with tracing off ``Tracer.span`` returns a
   shared null context manager, so instrumentation left in hot host loops
   is free — the per-span overhead is measured and asserted tiny.
2. **Zero-perturbation bitwise ladder**: the SAME gossip spec run with
   ``ObsSpec(enabled=True)`` vs unset must produce bitwise-identical
   posteriors and identical jit trace counts — observation never perturbs
   the training math (the engine-level twin of ``tests/test_obs.py``).
3. **Theory-vs-measured convergence**: on a static 4-agent bidirectional
   ring with ``lr=0`` and per-agent inits the round map reduces to the
   plain W-average, so network disagreement must decay at the spectral
   rate ``-log lambda_max(W)`` (``core.theory.consensus_contraction_rate``).
   The tracker's measured log-linear slope is asserted a finite O(1)
   multiple of theory (``rate_attainment``); bounds are loose because the
   least-squares fit includes the faster-decaying transient modes.
   This run also emits the sample JSONL trace CI uploads.
4. **Exporter golden**: the Prometheus rendering of a deterministic
   registry is compared byte-for-byte against a golden string — export
   stability is part of the ``obs.metrics`` contract.

Output: ``BENCH_obs.json`` + the sample trace ``BENCH_obs_trace.jsonl``
and the harness's ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import json
import time

import numpy as np

DEFAULT_JSON = "BENCH_obs.json"
DEFAULT_TRACE = "BENCH_obs_trace.jsonl"

# max tolerated per-span overhead of a DISABLED tracer.  The null span is a
# shared contextlib.nullcontext, so the real cost is one method call + the
# with-statement (~0.1-0.3 us on CPython); 5 us leaves slack for a loaded
# CI host while still catching any accidental allocation on the off path.
MAX_DISABLED_SPAN_US = 5.0

_EXPORTER_GOLDEN = (
    '# TYPE gossip_quorum counter\n'
    'gossip_quorum_total{graph="ws \\"k=6\\"\\nbeta=0.1"} 2\n'
    '# TYPE serve_latency_us histogram\n'
    'serve_latency_us_bucket{mc="1",le="10"} 0\n'
    'serve_latency_us_bucket{mc="1",le="100"} 1\n'
    'serve_latency_us_bucket{mc="1",le="1000"} 1\n'
    'serve_latency_us_bucket{mc="1",le="+Inf"} 1\n'
    'serve_latency_us_sum{mc="1"} 40\n'
    'serve_latency_us_count{mc="1"} 1\n'
    'serve_latency_us_bucket{mc="8",le="10"} 1\n'
    'serve_latency_us_bucket{mc="8",le="100"} 1\n'
    'serve_latency_us_bucket{mc="8",le="1000"} 2\n'
    'serve_latency_us_bucket{mc="8",le="+Inf"} 2\n'
    'serve_latency_us_sum{mc="8"} 257\n'
    'serve_latency_us_count{mc="8"} 2\n'
    '# TYPE session_loss gauge\n'
    'session_loss 0.25\n'
    '# HELP session_rounds training rounds completed\n'
    '# TYPE session_rounds counter\n'
    'session_rounds_total 3\n'
    '# TYPE build_flags_info gauge\n'
    'build_flags_info{value="x=\\"1\\"\\\\y"} 1\n'
    '# TYPE engine_name_info gauge\n'
    'engine_name_info{value="gossip"} 1\n'
)


def _span_overhead(n: int = 50_000) -> dict:
    """Per-span cost of the disabled vs enabled tracer (host-only loop)."""
    from repro.obs.trace import Tracer

    off = Tracer(enabled=False)
    t0 = time.perf_counter()
    for _ in range(n):
        with off.span("probe"):
            pass
    off_us = (time.perf_counter() - t0) * 1e6 / n
    assert not off.spans, "disabled tracer recorded spans"
    assert off_us < MAX_DISABLED_SPAN_US, (
        f"disabled span overhead {off_us:.3f} us/span exceeds the "
        f"{MAX_DISABLED_SPAN_US} us budget — the off path is no longer free"
    )

    on = Tracer(enabled=True)
    t0 = time.perf_counter()
    for _ in range(n):
        with on.span("probe"):
            pass
    on_us = (time.perf_counter() - t0) * 1e6 / n
    assert len(on.spans) == n
    return {"disabled_us_per_span": off_us, "enabled_us_per_span": on_us,
            "n_spans": n}


def _gossip_spec(n: int = 6, n_rounds: int = 6, obs: bool = False):
    """A small async-gossip spec; the instrumented engine's bitwise probe."""
    from repro.api import (
        DataSpec, ExperimentSpec, InferenceSpec, ObsSpec, RunSpec,
        TopologySpec,
    )

    clock = {
        "kind": "failure_injected",
        "inner": {"kind": "poisson", "rate": 0.8, "seed": 1},
        "drop_rate": 0.1,
    }
    return ExperimentSpec(
        topology=TopologySpec.gossip("bidirectional_ring", {"n": n},
                                     clock=clock),
        data=DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
            partition="iid", partition_params=dict(n_agents=n),
            batch_size=4, local_updates=2,
        ),
        inference=InferenceSpec(hidden=8, depth=1, lr=1e-2),
        run=RunSpec(n_rounds=n_rounds, seed=0),
        obs=ObsSpec(enabled=obs),
    )


def _zero_perturbation(n_rounds: int = 6) -> dict:
    """obs-enabled vs unset on the gossip engine: bitwise posteriors,
    identical jit trace counts."""
    from repro.api import build_session

    posts, traces = {}, {}
    for enabled in (False, True):
        s = build_session(_gossip_spec(n_rounds=n_rounds, obs=enabled))
        for _ in range(n_rounds):
            s.round()
        posts[enabled] = s.posterior()
        traces[enabled] = int(s.engine.n_traces)
    np.testing.assert_array_equal(
        np.asarray(posts[False].mean), np.asarray(posts[True].mean)
    )
    np.testing.assert_array_equal(
        np.asarray(posts[False].rho), np.asarray(posts[True].rho)
    )
    assert traces[False] == traces[True], (
        f"observability changed the trace count: {traces}"
    )
    return {"bitwise": True, "n_traces": traces[True]}


def _rate_experiment(
    n: int = 4, n_rounds: int = 12, trace_out: str | None = DEFAULT_TRACE
) -> dict:
    """Static ring, lr=0, per-agent inits: consensus is the plain W-average,
    so measured disagreement decay must track -log lambda_max(W)."""
    from repro.api import (
        DataSpec, ExperimentSpec, InferenceSpec, ObsSpec, RunSpec,
        TopologySpec, build_session,
    )

    spec = ExperimentSpec(
        topology=TopologySpec(kind="bidirectional_ring", params={"n": n}),
        data=DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
            partition="iid", partition_params=dict(n_agents=n),
            batch_size=4, local_updates=1,
        ),
        inference=InferenceSpec(hidden=8, depth=1, lr=0.0, shared_init=False),
        run=RunSpec(n_rounds=n_rounds, seed=0),
        obs=ObsSpec(enabled=True, jsonl_path=trace_out),
    )
    s = build_session(spec)
    s.run()
    rep = s.obs.convergence.report()
    theory = rep["theory_rate"]
    att = rep["rate_attainment"]
    assert theory is not None and np.isfinite(theory) and theory > 0, (
        f"static ring must yield a finite spectral rate, got {theory}"
    )
    assert att is not None and np.isfinite(att), (
        f"rate_attainment must be finite on the static ring, got {att}"
    )
    # loose O(1) bounds: the least-squares slope over the whole run includes
    # the faster-contracting non-dominant eigenmodes, so attainment sits
    # above 1 early and approaches 1 from above as the run lengthens
    assert 0.5 < att < 4.0, (
        f"measured/theory contraction ratio {att:.3f} outside loose bounds "
        f"(measured {rep['measured_rate']:.4f}, theory {theory:.4f})"
    )
    dashboard = s.dashboard()  # renders from the registry, flushes the sink
    n_events = s.obs.sink.n_events if s.obs.sink is not None else 0
    if trace_out:
        assert n_events > 0, "JSONL sink recorded no events"
    return {
        "n_agents": n,
        "n_rounds": n_rounds,
        "theory_rate": theory,
        "measured_rate": rep["measured_rate"],
        "rate_attainment": att,
        "overlay": rep["overlay"],
        "latest": rep["latest"],
        "trace_events": n_events,
        "trace_path": trace_out,
        "dashboard_lines": len(dashboard.splitlines()),
    }


def _exporter_golden() -> dict:
    """Byte-for-byte golden check of the Prometheus text exporter."""
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    reg.counter("session.rounds", help="training rounds completed").inc(3)
    reg.gauge("session.loss").set(0.25)
    h = reg.histogram("serve.latency_us", buckets=(10.0, 100.0, 1000.0))
    h.observe(7.0, mc="8")
    h.observe(250.0, mc="8")
    h.observe(40.0, mc="1")
    reg.info("engine.name", "gossip")
    # exercise every escape the exposition format requires in label values:
    # double-quote, newline (counter label) and backslash (info value)
    reg.counter("gossip.quorum").inc(2, graph='ws "k=6"\nbeta=0.1')
    reg.info("build.flags", 'x="1"\\y')
    text = reg.to_prometheus()
    assert text == _EXPORTER_GOLDEN, (
        "exporter output drifted from the golden:\n"
        + "".join(
            f"  {'==' if a == b else '!='} {a!r} vs {b!r}\n"
            for a, b in zip(text.splitlines(), _EXPORTER_GOLDEN.splitlines())
        )
    )
    return {"ok": True, "n_lines": len(text.splitlines())}


def run(json_out: str | None = DEFAULT_JSON,
        trace_out: str | None = DEFAULT_TRACE) -> dict:
    import jax

    overhead = _span_overhead()
    print(f"obs_span_overhead,{overhead['disabled_us_per_span']:.4f},"
          f"enabled={overhead['enabled_us_per_span']:.4f}us;"
          f"budget={MAX_DISABLED_SPAN_US}us")
    bitwise = _zero_perturbation()
    print(f"obs_zero_perturbation,0.0,bitwise=1;"
          f"n_traces={bitwise['n_traces']}")
    rate = _rate_experiment(trace_out=trace_out)
    print(f"obs_rate_attainment,0.0,"
          f"measured={rate['measured_rate']:.4f};"
          f"theory={rate['theory_rate']:.4f};"
          f"attainment={rate['rate_attainment']:.3f};"
          f"trace_events={rate['trace_events']}")
    golden = _exporter_golden()
    print(f"obs_exporter_golden,0.0,ok=1;lines={golden['n_lines']}")
    doc = {
        "benchmark": "observability_layer",
        "backend": jax.default_backend(),
        "span_overhead": overhead,
        "zero_perturbation": bitwise,
        "rate_experiment": rate,
        "exporter_golden": golden,
    }
    if json_out:
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {json_out}")
    return doc


if __name__ == "__main__":
    run()
