"""Gossip-runtime smoke benchmark (`benchmarks/run.py gossip-smoke`).

Five parts, mirroring what the ROADMAP Async section promises:

1. **Equivalence probes** (correctness, not timed): the all-edges-active
   window must equal the synchronous fused consensus BIT-identically, at
   the kernel level (``consensus_fused_masked`` vs
   ``consensus_fused_network``, interpret mode) and at the engine level
   (all-edges TraceClock GossipEngine vs SimulatedEngine).
2. **Tiny Poisson run**: a few event windows on a ring through the full
   ``repro.api`` surface — losses finite, staleness telemetry populated,
   one jitted call per window (trace-count assertion).  The first window
   is warmed up BEFORE the timer starts and reported as ``compile_us``;
   ``wall_us_total`` is the warm steady-state cost of the remaining
   windows (the seed benchmark timed the jit compile inside the loop and
   reported ~4 s for 5 tiny CPU windows).
3. **Window-consensus sweep**: masked-consensus wall-clock vs the dense
   fused pass at several active fractions, next to the analytic
   ``gossip_window_roofline`` (on CPU the model numbers are load-bearing,
   as for BENCH_consensus.json).
4. **Delay sweep**: the delivery-latency engine (``DelayedClock`` +
   [K, N, P] history ring) at several delay depths — staleness grows with
   depth while per-window wall time stays flat (one extra ring write), and
   the roofline's history term tracks the depth.
5. **Shard sweep**: the sharded window consensus
   (``consensus_ppermute_window``) vs the dense masked pass for every
   shard count the local device pool supports (CI runs this step under
   ``--xla_force_host_platform_device_count=8``), asserting BIT-identity
   per shard count and reporting the per-window cross-shard offset
   schedule next to the ICI roofline.
6. **Wire sweep**: the masked window and the sharded ppermute window per
   wire dtype (fp32 vs bf16 exchange of (prec, prec*mu), fp32
   accumulate): wall-clock, modeled ICI bytes (bf16 halves them), the
   f32 wire asserted bitwise-identical to the no-wire baseline, and the
   bf16 path asserted bitwise-consistent ACROSS executions (masked ==
   ppermute — the equivalence ladder per wire dtype).
7. **Sparse scale sweep** (``sparse_scale``): edge-native gossip windows
   at N >= 10^4 on Watts-Strogatz graphs.  Each window is a pure
   function of ``(seed, round)``: thinned-Poisson fired-edge indices
   (``gossip.clocks.thinned_poisson_indices``, O(fired) work), a
   conserve-rule window edge list (fired in-edges at their graph
   weights + a self edge absorbing the unfired in-mass of each active
   row), and ``consensus_flat_segments`` over those [E_w] arrays with
   the active-row mask.  No [N, N] object exists on host (array-size
   assertion) or on device (jaxpr walk via
   ``bench_consensus.assert_no_dense_square``); the
   ``gossip_window_roofline(..., n_event_edges=...)`` EDGE-NATIVE model
   is recorded next to measured wall-clock, plus a small-N equivalence
   probe against the dense masked reference.
8. **Engine sparse smoke** (``engine_sparse_smoke``): the FULL
   ``repro.api`` surface at N=10^4 — a Watts-Strogatz Poisson
   ``TopologySpec(kind="sparse", clock=...)`` session on
   ``consensus_impl="segments"`` runs round/evaluate/save/load end to
   end.  The jitted window program is re-traced on the engine's OWN
   captured arguments and walked with ``assert_no_dense_square`` (no
   [N, N] on device), every host array the window carries is asserted
   O(E) (nothing [N, N]-shaped on host either), and the clock's
   window-build host time is measured at N=1e4 vs N=3e4 and asserted to
   scale with the fired-edge count, not N^2.  A small-N probe pins the
   segments engine to the dense masked engine per wire dtype (fp32
   reduction-order tolerance — both sum the same wire-quantized values).

Output: ``BENCH_gossip.json`` + the harness's ``name,us_per_call,derived``
CSV rows.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flat import (
    FlatLayout,
    FlatPosterior,
    consensus_flat,
    consensus_flat_masked,
    consensus_flat_segments,
)
from repro.core.graphs import (
    SparseGraph,
    bidirectional_ring_w,
    watts_strogatz_sparse,
)
from repro.gossip.clocks import (
    PoissonClock,
    SparsePoissonClock,
    _directed_edges,
    thinned_poisson_indices,
)
from repro.kernels.consensus import (
    consensus_fused_masked,
    consensus_fused_network,
)
from repro.launch.consensus_opt import (
    consensus_ppermute_window,
    window_shard_offsets,
)
from repro.launch.costmodel import gossip_window_roofline
from repro.obs.trace import CompileWarmTimer, median_us

DEFAULT_JSON = "BENCH_gossip.json"


def _time(fn, args, iters: int = 5) -> float:
    # warm once (compile), then the obs.trace median-of-warm-calls helper
    jax.block_until_ready(fn(*args))
    return float(median_us(fn, *args, iters=iters))


def _all_active_equivalence() -> dict:
    """Bit-identity probes: max |err| must be EXACTLY 0.0."""
    n, p = 6, 4096
    ks = jax.random.split(jax.random.key(0), 2)
    mean = jax.random.normal(ks[0], (n, p))
    rho = jax.random.normal(ks[1], (n, p)) * 0.4 - 1.0
    W = jnp.asarray(bidirectional_ring_w(n), jnp.float32)
    allmask = jnp.ones((n,), bool)
    mm, rm = consensus_fused_masked(W, allmask, mean, rho, block=512,
                                    interpret=True)
    mn, rn = consensus_fused_network(W, mean, rho, block=512, interpret=True)
    kernel_err = max(
        float(jnp.max(jnp.abs(mm - mn))), float(jnp.max(jnp.abs(rm - rn)))
    )

    from repro.api import (
        DataSpec, ExperimentSpec, InferenceSpec, RunSpec, TopologySpec,
        build_session,
    )

    n_agents = 4
    edges = [[int(i), int(j)]
             for i, j in _directed_edges(bidirectional_ring_w(n_agents))]
    data = DataSpec(
        dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
        partition="iid", partition_params=dict(n_agents=n_agents),
        batch_size=4, local_updates=2,
    )
    inf = InferenceSpec(hidden=8, depth=1, lr=1e-2)
    run = RunSpec(n_rounds=3, seed=0)
    s_g = build_session(ExperimentSpec(
        topology=TopologySpec(
            kind="gossip",
            params={"base": "bidirectional_ring",
                    "base_params": {"n": n_agents}},
            clock={"kind": "trace", "trace": [edges]},
        ),
        data=data, inference=inf, run=run,
    ))
    s_s = build_session(ExperimentSpec(
        topology=TopologySpec(kind="bidirectional_ring",
                              params={"n": n_agents}),
        data=data, inference=inf, run=run,
    ))
    s_g.run()
    s_s.run()
    engine_err = max(
        float(jnp.max(jnp.abs(s_g.posterior().mean - s_s.posterior().mean))),
        float(jnp.max(jnp.abs(s_g.posterior().rho - s_s.posterior().rho))),
    )
    assert kernel_err == 0.0, f"masked kernel all-active err {kernel_err}"
    assert engine_err == 0.0, f"gossip-engine all-active err {engine_err}"
    return {"kernel_max_err": kernel_err, "engine_max_err": engine_err}


def _smoke_spec(n: int, clock: dict, n_rounds: int = 5):
    from repro.api import (
        DataSpec, ExperimentSpec, InferenceSpec, RunSpec, TopologySpec,
    )

    return ExperimentSpec(
        topology=TopologySpec.gossip("bidirectional_ring", {"n": n},
                                     clock=clock),
        data=DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
            partition="iid", partition_params=dict(n_agents=n),
            batch_size=4, local_updates=2,
        ),
        inference=InferenceSpec(hidden=8, depth=1, lr=1e-2),
        run=RunSpec(n_rounds=n_rounds, seed=0),
    )


def _poisson_smoke() -> dict:
    from repro.api import build_session

    n, n_rounds = 6, 5
    spec = _smoke_spec(
        n,
        clock={"kind": "failure_injected",
               "inner": {"kind": "poisson", "rate": 0.8, "seed": 1},
               "drop_rate": 0.1},
        n_rounds=n_rounds,
    )
    s = build_session(spec)
    # warm up ONE window before the timer: the first call pays the jit
    # compile, which on tiny CPU shapes dwarfs the run (the seed benchmark's
    # 4.09 s "wall" was ~all compile) — report it separately via the
    # obs.trace compile/warm split (the reusable form of this very pattern)
    t = CompileWarmTimer()
    with t.compile():
        first = s.round()
    with t.warm():
        hist = s.run(n_rounds - 1, eval_every=n_rounds - 1)
    compile_us, wall_us = t.compile_us, t.warm_us
    tel = s.evaluate()
    assert np.isfinite(hist[-1]["loss"])
    assert s.engine.n_traces == 1, "window retraced: not one jitted call"
    return {
        "windows": tel["engine"]["windows"],
        "loss": hist[-1]["loss"],
        "n_trained": hist[-1]["n_trained"],
        "avg_acc": tel["avg_acc"],
        "staleness": tel["engine"]["staleness"],
        "merges": tel["engine"]["merges"],
        "n_traces": s.engine.n_traces,
        "compile_us": compile_us,
        "wall_us_total": wall_us,  # warm: windows 2..n_rounds only
        "wall_us_per_window": wall_us / (n_rounds - 1),
        "first_round_loss": first["loss"],
    }


def _window_sweep(n: int = 16, p: int = 1 << 15) -> list[dict]:
    ks = jax.random.split(jax.random.key(3), 2)
    mean = jax.random.normal(ks[0], (n, p))
    rho = jax.random.normal(ks[1], (n, p)) * 0.4 - 1.0
    layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
    posts = FlatPosterior(mean=mean, rho=rho, layout=layout)
    W_base = bidirectional_ring_w(n)
    dense_fn = jax.jit(lambda q, w: consensus_flat(q, w).mean)
    masked_fn = jax.jit(lambda q, w, a: consensus_flat_masked(q, w, a).mean)
    Wj = jnp.asarray(W_base, jnp.float32)
    us_dense = _time(dense_fn, (posts, Wj))
    out = []
    for rate in (0.1, 0.5, 2.0):
        win = PoissonClock(W_base, rate=rate, seed=5).window(0)
        rec = {
            "rate": rate,
            "n_events": win.n_events,
            "active_fraction": win.active_fraction,
            "us": {
                "dense_fused": us_dense,
                "window_masked": _time(
                    masked_fn,
                    (posts, jnp.asarray(win.w_eff, jnp.float32),
                     jnp.asarray(win.active)),
                ),
            },
            "roofline": gossip_window_roofline(
                n, p,
                n_participating=int(win.participating().sum()),
                n_merging=int(win.active.sum()),
            ),
        }
        out.append(rec)
    return out


def _delay_sweep() -> list[dict]:
    """Delivery-latency depths: the [K, N, P] history ring costs one extra
    network write per window; staleness telemetry grows with depth."""
    from repro.api import build_session

    n, n_rounds = 6, 6
    out = []
    for delay in (0, 1, 3):
        clock = {"kind": "delayed",
                 "inner": {"kind": "poisson", "rate": 0.8, "seed": 1},
                 "latency": {"kind": "constant", "delay": delay}}
        s = build_session(_smoke_spec(n, clock, n_rounds=n_rounds))
        t = CompileWarmTimer()
        with t.compile():
            s.round()
        with t.warm():
            hist = s.run(n_rounds - 1, eval_every=n_rounds - 1)
        compile_us, wall_us = t.compile_us, t.warm_us
        tel = s.evaluate()
        assert s.engine.n_traces == 1, "delayed window retraced"
        win = s.engine.clock.window(n_rounds - 1)
        out.append({
            "delay": delay,
            "hist_slots": s.engine.hist_slots,
            "loss": hist[-1]["loss"],
            "staleness": tel["engine"]["staleness"],
            "merges_total": tel["engine"]["merges"]["total"],
            "compile_us": compile_us,
            "wall_us_per_window": wall_us / (n_rounds - 1),
            "roofline": gossip_window_roofline(
                n, int(s.posterior().mean.shape[-1]),
                n_participating=int(win.participating().sum()),
                n_merging=int(win.active.sum()),
                delay_depth=delay,
                n_stale_events=win.n_events,
            ),
        })
    return out


def _shard_sweep(n: int = 8, p: int = 1 << 14) -> list[dict]:
    """Sharded window consensus vs the dense masked pass, per shard count
    the local device pool supports — bit-identity asserted at every S."""
    ks = jax.random.split(jax.random.key(7), 2)
    mean = jax.random.normal(ks[0], (n, p))
    rho = jax.random.normal(ks[1], (n, p)) * 0.4 - 1.0
    layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
    posts = FlatPosterior(mean=mean, rho=rho, layout=layout)
    W_base = bidirectional_ring_w(n)
    win = PoissonClock(W_base, rate=0.5, seed=9).window(0)
    Wj = jnp.asarray(win.w_eff, jnp.float32)
    act = jnp.asarray(win.active)
    masked_fn = jax.jit(
        lambda q, w, a: consensus_flat_masked(q, w, a).mean
    )
    us_masked = _time(masked_fn, (posts, Wj, act))
    ref = consensus_flat_masked(posts, Wj, act)
    devices = jax.devices()
    out = []
    for shards in (1, 2, 4, 8):
        if shards > len(devices) or n % shards:
            continue
        mesh = jax.sharding.Mesh(np.asarray(devices[:shards]), ("agents",))
        sharded = consensus_ppermute_window(posts, win, mesh, "agents")
        bit_equal = bool(
            jnp.all(sharded.mean == ref.mean) & jnp.all(sharded.rho == ref.rho)
        )
        assert bit_equal, f"sharded window != masked reference at S={shards}"
        offsets = window_shard_offsets(win, shards)
        out.append({
            "n_shards": shards,
            "n_cross_offsets": len(offsets),
            "offsets": list(offsets),
            "bit_identical_vs_masked": bit_equal,
            "us": {
                "window_masked": us_masked,
                "window_ppermute": _time(
                    lambda q: consensus_ppermute_window(
                        q, win, mesh, "agents"
                    ).mean,
                    (posts,),
                ),
            },
            "roofline": gossip_window_roofline(
                n, p,
                n_participating=int(win.participating().sum()),
                n_merging=int(win.active.sum()),
                n_shards=shards,
                n_cross_offsets=len(offsets),
            ),
        })
    return out


def _wire_sweep(n: int = 8, p: int = 1 << 14) -> list[dict]:
    """fp32 vs bf16 wire: masked window + sharded ppermute window
    wall-clock next to the modeled ICI bytes; f32 bitwise vs baseline and
    masked==ppermute bitwise per wire dtype asserted."""
    ks = jax.random.split(jax.random.key(11), 2)
    mean = jax.random.normal(ks[0], (n, p))
    rho = jax.random.normal(ks[1], (n, p)) * 0.4 - 1.0
    layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
    posts = FlatPosterior(mean=mean, rho=rho, layout=layout)
    W_base = bidirectional_ring_w(n)
    win = PoissonClock(W_base, rate=0.7, seed=13).window(0)
    Wj = jnp.asarray(win.w_eff, jnp.float32)
    act = jnp.asarray(win.active)
    baseline = consensus_flat_masked(posts, Wj, act)
    devices = jax.devices()
    shards = max(s for s in (1, 2, 4, 8) if s <= len(devices) and n % s == 0)
    mesh = jax.sharding.Mesh(np.asarray(devices[:shards]), ("agents",))
    offsets = window_shard_offsets(win, shards)
    out = []
    for wire in ("f32", "bf16"):
        masked_fn = jax.jit(
            lambda q, w, a, wd=wire: consensus_flat_masked(
                q, w, a, wire_dtype=wd
            ).mean
        )
        got = consensus_flat_masked(posts, Wj, act, wire_dtype=wire)
        if wire == "f32":
            assert bool(
                jnp.all(got.mean == baseline.mean)
                & jnp.all(got.rho == baseline.rho)
            ), "f32 wire is not a structural no-op"
        sharded = consensus_ppermute_window(
            posts, win, mesh, "agents", wire_dtype=wire
        )
        assert bool(
            jnp.all(sharded.mean == got.mean)
            & jnp.all(sharded.rho == got.rho)
        ), f"ppermute != masked at wire {wire}"
        out.append({
            "wire_dtype": wire,
            "n_shards": shards,
            "us": {
                "window_masked": _time(masked_fn, (posts, Wj, act)),
                "window_ppermute": _time(
                    lambda q, wd=wire: consensus_ppermute_window(
                        q, win, mesh, "agents", wire_dtype=wd
                    ).mean,
                    (posts,),
                ),
            },
            "bitwise_masked_eq_ppermute": True,
            "roofline": gossip_window_roofline(
                n, p,
                n_participating=int(win.participating().sum()),
                n_merging=int(win.active.sum()),
                n_shards=max(shards, 2),  # ici terms need >= 2 shards
                n_cross_offsets=len(offsets) if shards > 1 else 1,
                wire_dtype=wire,
            ),
        })
    f32_ici = out[0]["roofline"]["ici_bytes"]["window_ppermute"]
    bf16_ici = out[1]["roofline"]["ici_bytes"]["window_ppermute"]
    assert bf16_ici == 0.5 * f32_ici, "bf16 wire must halve the ICI bytes"
    return out


def _sparse_window(g: SparseGraph, nonself, rate: float, seed: int, r: int):
    """Conserve-rule gossip window — a pure function of ``(seed, r)``.

    ``nonself`` is the precomputed ``(dst, src, w)`` triple of the graph's
    non-self directed edges.  Fired edges are drawn by thinned-Poisson
    index sampling (O(fired) work, never a per-edge [E] coin-flip pass
    materialised per round — though here even [E] would be fine; the point
    is the shared (seed, round) keying with the engine clocks).  Each
    fired in-edge keeps its graph weight; every active row gets one self
    edge absorbing its unfired in-mass so window rows stay row-stochastic.

    Returns ``(dst, src, w, active)``: window edge arrays (fired edges
    first, then the per-active-row self edges) and the [N] bool merge
    mask.  Inactive rows contribute no edges at all — the segment-sum
    consensus passes them through via the mask.
    """
    dst_ns, src_ns, w_ns = nonself
    rng = np.random.default_rng([seed, r])
    fired = thinned_poisson_indices(rng, int(dst_ns.shape[0]), rate)
    f_dst = dst_ns[fired]
    f_src = src_ns[fired]
    f_w = w_ns[fired]
    active = np.zeros(g.n_agents, dtype=bool)
    active[f_dst] = True
    rows = np.nonzero(active)[0].astype(np.int32)
    in_mass = np.zeros(g.n_agents, dtype=np.float64)
    np.add.at(in_mass, f_dst, f_w.astype(np.float64))
    self_w = (1.0 - in_mass[rows]).astype(np.float32)
    dst = np.concatenate([f_dst, rows])
    src = np.concatenate([f_src, rows])
    w = np.concatenate([f_w, self_w])
    return dst, src, w, active


def _sparse_window_equivalence(n: int = 24, p: int = 64,
                               seed: int = 3) -> float:
    """Small-N probe: the edge-native window must match the dense masked
    reference on the SAME conserve-rule effective weights (fp32
    reduction-order tolerance — scatter adds in edge order, the dense
    pass in column order)."""
    from benchmarks.bench_consensus import _flat_posts

    g = watts_strogatz_sparse(n, k=4, beta=0.3, seed=seed)
    dst, src, w = g.edge_arrays()
    ns = dst != src
    nonself = (dst[ns], src[ns], w[ns])
    max_err = 0.0
    for r in range(3):
        d, s, ww, active = _sparse_window(g, nonself, 0.3, seed, r)
        posts = _flat_posts(seed + r, n, p)
        got = consensus_flat_segments(
            posts, jnp.asarray(d), jnp.asarray(s), jnp.asarray(ww),
            active=jnp.asarray(active),
        )
        n_fired = int(d.shape[0]) - int(active.sum())
        W_eff = np.eye(n, dtype=np.float32)
        W_eff[d[:n_fired], s[:n_fired]] = ww[:n_fired]
        rows = d[n_fired:]
        W_eff[rows, rows] = ww[n_fired:]
        ref = consensus_flat_masked(
            posts, jnp.asarray(W_eff), jnp.asarray(active))
        err = max(float(jnp.max(jnp.abs(got.mean - ref.mean))),
                  float(jnp.max(jnp.abs(got.rho - ref.rho))))
        max_err = max(max_err, err)
    assert max_err <= 1e-4, f"sparse window vs dense masked err {max_err}"
    return max_err


# (n_agents, p, ws_k, ws_beta, per-edge rate) — sparse-only scale points;
# the dense engine cannot even allocate W at these sizes (N=1e5 f32 W
# would be 40 GB), which is exactly the point of the edge-native path.
_SPARSE_SCALE_QUICK = [(10_000, 32, 6, 0.1, 0.05)]
_SPARSE_SCALE_FULL = [
    (10_000, 64, 6, 0.1, 0.05),
    (30_000, 64, 6, 0.1, 0.05),
    (100_000, 32, 6, 0.1, 0.05),
]


def sparse_scale_sweep(quick: bool = False, iters: int = 5,
                       seed: int = 0) -> dict:
    """Edge-native gossip windows at N >= 10^4: Watts-Strogatz graphs,
    thinned-Poisson fired edges, segment-sum window consensus.  Asserts
    O(E) peak graph memory on host (array-size bound) and the absence of
    any [N, N] intermediate on device (jaxpr walk)."""
    from benchmarks.bench_consensus import _flat_posts, assert_no_dense_square

    equivalence_max_err = _sparse_window_equivalence()
    configs = _SPARSE_SCALE_QUICK if quick else _SPARSE_SCALE_FULL
    entries = []
    for n, p, k, beta, rate in configs:
        t0 = time.perf_counter()
        g = watts_strogatz_sparse(n, k=k, beta=beta, seed=seed)
        graph_build_s = time.perf_counter() - t0
        dst, src, w = g.edge_arrays()
        ns = dst != src
        nonself = (dst[ns], src[ns], w[ns])
        t0 = time.perf_counter()
        d, s, ww, active = _sparse_window(g, nonself, rate, seed, 0)
        window_build_s = time.perf_counter() - t0
        d2, s2, w2, a2 = _sparse_window(g, nonself, rate, seed, 0)
        assert (np.array_equal(d, d2) and np.array_equal(s, s2)
                and np.array_equal(ww, w2) and np.array_equal(active, a2)), \
            "window is not a pure function of (seed, round)"
        # peak graph memory is O(E): every host array the window touches
        # is bounded by the edge count (or N+1 for indptr / the mask) —
        # nothing [N, N]-shaped exists anywhere in this sweep
        for arr in (g.indptr, g.indices, g.weights, dst, src, w, d, s, ww):
            assert arr.size <= max(g.n_edges, n + 1), "graph array not O(E)"
        assert active.size == n
        posts = _flat_posts(seed, n, p)
        dj, sj, wj = jnp.asarray(d), jnp.asarray(s), jnp.asarray(ww)
        aj = jnp.asarray(active)
        fn = jax.jit(lambda q, dd, ss, wv, aa: consensus_flat_segments(
            q, dd, ss, wv, active=aa).mean)
        assert_no_dense_square(jax.make_jaxpr(fn)(posts, dj, sj, wj, aj), n)
        us = _time(fn, (posts, dj, sj, wj, aj), iters=iters)
        participating = np.zeros(n, dtype=bool)
        participating[d] = True
        participating[s] = True
        roof = gossip_window_roofline(
            n, p,
            n_participating=int(participating.sum()),
            n_merging=int(active.sum()),
            n_event_edges=int(d.shape[0]),
        )
        entries.append({
            "n_agents": n,
            "p": p,
            "ws_k": k,
            "ws_beta": beta,
            "rate": rate,
            "n_edges": g.n_edges,
            "n_window_edges": int(d.shape[0]),
            "n_merging": int(active.sum()),
            "graph_build_seconds": graph_build_s,
            "window_build_seconds": window_build_s,
            "us_window_segments": us,
            "roofline": roof,
            "no_dense_alloc_asserted": True,
            "window_pure_fn_of_seed_round": True,
        })
        print(f"gossip_sparse[n={n};p={p};Ew={int(d.shape[0])}],{us:.1f},"
              f"merging={int(active.sum())};"
              f"model_s={roof['roofline_seconds']['window_segments']:.2e}")
    # measured-vs-modeled scaling between consecutive points: the
    # E-parameterized window model should track measured growth far
    # better than any N^2 law (recorded, not asserted — CI noise)
    scaling = []
    for a, b in zip(entries, entries[1:]):
        scaling.append({
            "from": f"{a['n_agents']}x{a['p']}",
            "to": f"{b['n_agents']}x{b['p']}",
            "measured_ratio": (
                b["us_window_segments"] / a["us_window_segments"]
            ),
            "modeled_ratio": (
                b["roofline"]["hbm_bytes"]["window_segments"]
                / a["roofline"]["hbm_bytes"]["window_segments"]
            ),
            "n2_ratio": (b["n_agents"] / a["n_agents"]) ** 2,
        })
    return {
        "equivalence_max_err": equivalence_max_err,
        "sweep": entries,
        "scaling": scaling,
    }


def _engine_session_spec(n: int, k: int, beta: float, rate: float,
                         e_max: int | None, n_rounds: int,
                         impl: str = "segments", wire: str = "f32"):
    """A spec-driven sparse-clock gossip session: 2 training rows per agent
    (the sweep times the window machinery, not SGD) on a Watts-Strogatz
    graph with a thinned-Poisson edge clock.  ``e_max`` declares the
    per-window fired-edge cap, shrinking the engine's static [E_max]
    buffers below the all-edges default."""
    from repro.api import (
        DataSpec, ExperimentSpec, InferenceSpec, RunSpec, TopologySpec,
    )

    return ExperimentSpec(
        topology=TopologySpec.sparse(
            "watts_strogatz", n=n, k=k, beta=beta, seed=1,
            clock={"kind": "poisson", "rate": rate, "seed": 3,
                   "e_max": e_max},
        ),
        data=DataSpec(
            dataset_params=dict(n_classes=2, dim=8, n_train_per_class=n,
                                seed=0),
            partition="iid", partition_params=dict(n_agents=n),
            batch_size=2, local_updates=1,
        ),
        inference=InferenceSpec(hidden=8, depth=1, lr=1e-2,
                                consensus_impl=impl, wire_dtype=wire),
        run=RunSpec(n_rounds=n_rounds, seed=0),
    )


def _engine_wire_equivalence(n: int = 16, n_rounds: int = 2) -> list[dict]:
    """Below SPARSE_DENSE_GUARD the same SparseWindow runs edge-native
    (segments) or densified via ``w_eff`` (masked) — per wire dtype, both
    cast payloads to the wire BEFORE reduction, so the posteriors must
    agree to fp32 reduction-order tolerance (not wire tolerance)."""
    from repro.api import build_session

    out = []
    for wire in ("f32", "bf16", "f16"):
        posts = {}
        for impl in ("segments", "masked"):
            s = build_session(_engine_session_spec(
                n, k=4, beta=0.2, rate=1.0, e_max=None,
                n_rounds=n_rounds, impl=impl, wire=wire))
            s.run()
            posts[impl] = s.posterior()
        err = max(
            float(jnp.max(jnp.abs(
                posts["segments"].mean - posts["masked"].mean))),
            float(jnp.max(jnp.abs(
                posts["segments"].rho - posts["masked"].rho))),
        )
        assert err <= 1e-4, \
            f"segments vs masked engine err {err} at wire {wire}"
        out.append({"wire_dtype": wire, "n_agents": n,
                    "n_rounds": n_rounds, "max_err": err})
    return out


def _window_build_seconds(n: int, k: int, beta: float, rate: float,
                          windows: int = 10, reps: int = 3) -> dict:
    """Median host seconds to build ``windows`` consecutive SparseWindows
    (memo defeated by distinct rounds), warm — the O(fired + N) claim,
    measured."""
    g = watts_strogatz_sparse(n, k=k, beta=beta, seed=1)
    clock = SparsePoissonClock(g, rate=rate, seed=3)
    clock._build_window(0)  # warm (rng/bincount setup paths)
    times = []
    n_events = 0
    for rep in range(reps):
        t0 = time.perf_counter()
        for r in range(windows):
            win = clock._build_window(1 + rep * windows + r)
            n_events += win.n_events
        times.append(time.perf_counter() - t0)
    return {
        "n_agents": n,
        "n_edges": clock.n_edges,
        "avg_fired": n_events / (reps * windows),
        "seconds_per_window": float(np.median(times)) / windows,
    }


def engine_sparse_smoke(full: bool = False) -> dict:
    """Acceptance probe: the GossipEngine runs a Watts-Strogatz Poisson
    session at N=10^4 end to end — round, evaluate, save, load — with no
    [N, N] object on the window path (device: jaxpr walk over the
    engine's own traced window program; host: array-size bound on the
    SparseWindow)."""
    import os
    import tempfile

    from benchmarks.bench_consensus import assert_no_dense_square
    from repro.api import Session, build_session

    n, k, beta, rate, e_max = 10_000, 6, 0.1, 0.05, 8192
    spec = _engine_session_spec(n, k, beta, rate, e_max, n_rounds=4)
    t0 = time.perf_counter()
    s = build_session(spec)
    build_s = time.perf_counter() - t0
    assert s.engine.consensus_impl == "segments"  # auto would pick it too

    # capture the EXACT arguments the engine hands its jitted window fn,
    # so the jaxpr walk certifies the program that actually ran
    orig = s.engine._window
    cap = {}

    def shim(*args):
        cap["args"] = args
        return orig(*args)

    s.engine._window = shim
    t0 = time.perf_counter()
    first = s.round()
    compile_s = time.perf_counter() - t0
    s.engine._window = orig
    assert_no_dense_square(jax.make_jaxpr(orig)(*cap["args"]), n)

    # host side: every array a SparseWindow carries is O(E_max) or O(N) —
    # nothing [N, N]-shaped exists anywhere on the window path
    win = s.engine.clock.window(0)
    for arr in (win.dst, win.src, win.weights):
        assert arr.size == e_max, "window edge buffer not at the e_max cap"
    for arr in (win.self_weight, win.active):
        assert arr.size == n
    assert not hasattr(win, "_w_eff_cache"), "dense w_eff was derived"

    t0 = time.perf_counter()
    warm = [s.round() for _ in range(2)]
    warm_s = (time.perf_counter() - t0) / 2
    assert all(np.isfinite(r["loss"]) for r in [first] + warm)
    assert s.engine.n_traces == 1, "sparse window retraced"

    t0 = time.perf_counter()
    tel = s.evaluate(n_mc=0)  # deterministic point predictive per agent
    evaluate_s = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ckpt.msgpack")
        t0 = time.perf_counter()
        s.save(path)
        s2 = Session.load(path)
        save_load_s = time.perf_counter() - t0
        assert bool(jnp.all(s2.posterior().mean == s.posterior().mean)
                    & jnp.all(s2.posterior().rho == s.posterior().rho)), \
            "checkpoint round-trip is not bitwise"
        resumed = s2.round()  # the loaded session keeps gossiping
        assert np.isfinite(resumed["loss"])

    # window-build host time must scale with the fired-edge count: tripling
    # N (and E, and the expected fired count) may not cost anywhere near
    # the 9x an [N, N] build would
    small = _window_build_seconds(n, k, beta, rate)
    big = _window_build_seconds(3 * n, k, beta, rate)
    ratio = big["seconds_per_window"] / small["seconds_per_window"]
    assert ratio < 4.5, \
        f"window build scaled {ratio:.1f}x for 3x N (O(N^2) would be 9x)"

    p = int(s.posterior().mean.shape[-1])
    roof = gossip_window_roofline(
        n, p,
        n_participating=int(win.participating().sum()),
        n_merging=int(win.active.sum()),
        n_event_edges=win.n_events,
        n_padded_edges=win.e_max,
    )
    return {
        "n_agents": n, "ws_k": k, "ws_beta": beta, "rate": rate,
        "e_max": e_max, "p": p,
        "n_window_events": win.n_events,
        "n_merging": int(win.active.sum()),
        "build_seconds": build_s,
        "compile_seconds": compile_s,
        "round_seconds_warm": warm_s,
        "evaluate_seconds": evaluate_s,
        "save_load_seconds": save_load_s,
        "loss": warm[-1]["loss"],
        "avg_acc": tel["avg_acc"],
        "n_traces": s.engine.n_traces,
        "no_dense_square_on_device": True,
        "checkpoint_bitwise": True,
        "window_build": {"small": small, "big": big,
                         "ratio_for_3x_n": ratio},
        "wire_equivalence": _engine_wire_equivalence(),
        "roofline": roof,
    }


def run(json_out: str | None = DEFAULT_JSON, full: bool = False) -> dict:
    equiv = _all_active_equivalence()
    print(f"gossip_equivalence,0.0,"
          f"kernel_err={equiv['kernel_max_err']};"
          f"engine_err={equiv['engine_max_err']}")
    smoke = _poisson_smoke()
    print(f"gossip_poisson_smoke,{smoke['wall_us_per_window']:.1f},"
          f"windows={smoke['windows']};loss={smoke['loss']:.4f};"
          f"staleness_p90={smoke['staleness']['p90']};"
          f"traces={smoke['n_traces']};"
          f"compile_us={smoke['compile_us']:.0f}")
    sweep = _window_sweep()
    for rec in sweep:
        print(f"gossip_window[f={rec['active_fraction']:.2f}],"
              f"{rec['us']['window_masked']:.1f},"
              f"model_passes="
              f"{rec['roofline']['hbm_passes']['window_masked']:.3f}")
    delay = _delay_sweep()
    for rec in delay:
        print(f"gossip_delay[k={rec['delay']}],"
              f"{rec['wall_us_per_window']:.1f},"
              f"staleness_p90={rec['staleness']['p90']};"
              f"hist_slots={rec['hist_slots']}")
    shard = _shard_sweep()
    for rec in shard:
        print(f"gossip_shard[S={rec['n_shards']}],"
              f"{rec['us']['window_ppermute']:.1f},"
              f"offsets={rec['n_cross_offsets']};bitwise=1")
    wire = _wire_sweep()
    for rec in wire:
        print(f"gossip_wire[{rec['wire_dtype']}],"
              f"{rec['us']['window_masked']:.1f},"
              f"ici_bytes="
              f"{rec['roofline']['ici_bytes']['window_ppermute']:.0f};"
              f"bitwise_masked_eq_ppermute=1")
    sparse = sparse_scale_sweep(quick=not full, iters=5 if full else 3)
    engine_sparse = engine_sparse_smoke(full=full)
    print(f"gossip_engine_sparse[n={engine_sparse['n_agents']}],"
          f"{engine_sparse['round_seconds_warm'] * 1e6:.0f},"
          f"events={engine_sparse['n_window_events']};"
          f"traces={engine_sparse['n_traces']};"
          f"build_ratio_3x={engine_sparse['window_build']['ratio_for_3x_n']:.2f};"
          f"no_dense=1")
    doc = {
        "benchmark": "gossip_event_windows",
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "equivalence": equiv,
        "poisson_smoke": smoke,
        "window_sweep": sweep,
        "delay_sweep": delay,
        "shard_sweep": shard,
        "wire_sweep": wire,
        "sparse_scale": sparse,
        "engine_sparse": engine_sparse,
    }
    if json_out:
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {json_out}")
    return doc


if __name__ == "__main__":
    run()
