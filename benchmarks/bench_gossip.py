"""Gossip-runtime smoke benchmark (`benchmarks/run.py gossip-smoke`).

Three parts, mirroring what the ROADMAP Async section promises:

1. **Equivalence probes** (correctness, not timed): the all-edges-active
   window must equal the synchronous fused consensus BIT-identically, at
   the kernel level (``consensus_fused_masked`` vs
   ``consensus_fused_network``, interpret mode) and at the engine level
   (all-edges TraceClock GossipEngine vs SimulatedEngine).
2. **Tiny Poisson run**: a few event windows on a ring through the full
   ``repro.api`` surface — losses finite, staleness telemetry populated,
   one jitted call per window (trace-count assertion).
3. **Window-consensus sweep**: masked-consensus wall-clock vs the dense
   fused pass at several active fractions, next to the analytic
   ``gossip_window_roofline`` (on CPU the model numbers are load-bearing,
   as for BENCH_consensus.json).

Output: ``BENCH_gossip.json`` + the harness's ``name,us_per_call,derived``
CSV rows.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flat import (
    FlatLayout,
    FlatPosterior,
    consensus_flat,
    consensus_flat_masked,
)
from repro.core.graphs import bidirectional_ring_w
from repro.gossip.clocks import PoissonClock, _directed_edges
from repro.kernels.consensus import (
    consensus_fused_masked,
    consensus_fused_network,
)
from repro.launch.costmodel import gossip_window_roofline

DEFAULT_JSON = "BENCH_gossip.json"


def _time(fn, args, iters: int = 5) -> float:
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _all_active_equivalence() -> dict:
    """Bit-identity probes: max |err| must be EXACTLY 0.0."""
    n, p = 6, 4096
    ks = jax.random.split(jax.random.key(0), 2)
    mean = jax.random.normal(ks[0], (n, p))
    rho = jax.random.normal(ks[1], (n, p)) * 0.4 - 1.0
    W = jnp.asarray(bidirectional_ring_w(n), jnp.float32)
    allmask = jnp.ones((n,), bool)
    mm, rm = consensus_fused_masked(W, allmask, mean, rho, block=512,
                                    interpret=True)
    mn, rn = consensus_fused_network(W, mean, rho, block=512, interpret=True)
    kernel_err = max(
        float(jnp.max(jnp.abs(mm - mn))), float(jnp.max(jnp.abs(rm - rn)))
    )

    from repro.api import (
        DataSpec, ExperimentSpec, InferenceSpec, RunSpec, TopologySpec,
        build_session,
    )

    n_agents = 4
    edges = [[int(i), int(j)]
             for i, j in _directed_edges(bidirectional_ring_w(n_agents))]
    data = DataSpec(
        dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
        partition="iid", partition_params=dict(n_agents=n_agents),
        batch_size=4, local_updates=2,
    )
    inf = InferenceSpec(hidden=8, depth=1, lr=1e-2)
    run = RunSpec(n_rounds=3, seed=0)
    s_g = build_session(ExperimentSpec(
        topology=TopologySpec(
            kind="gossip",
            params={"base": "bidirectional_ring",
                    "base_params": {"n": n_agents}},
            clock={"kind": "trace", "trace": [edges]},
        ),
        data=data, inference=inf, run=run,
    ))
    s_s = build_session(ExperimentSpec(
        topology=TopologySpec(kind="bidirectional_ring",
                              params={"n": n_agents}),
        data=data, inference=inf, run=run,
    ))
    s_g.run()
    s_s.run()
    engine_err = max(
        float(jnp.max(jnp.abs(s_g.posterior().mean - s_s.posterior().mean))),
        float(jnp.max(jnp.abs(s_g.posterior().rho - s_s.posterior().rho))),
    )
    assert kernel_err == 0.0, f"masked kernel all-active err {kernel_err}"
    assert engine_err == 0.0, f"gossip-engine all-active err {engine_err}"
    return {"kernel_max_err": kernel_err, "engine_max_err": engine_err}


def _poisson_smoke() -> dict:
    from repro.api import (
        DataSpec, ExperimentSpec, InferenceSpec, RunSpec, TopologySpec,
        build_session,
    )

    n = 6
    spec = ExperimentSpec(
        topology=TopologySpec.gossip(
            "bidirectional_ring", {"n": n},
            clock={"kind": "failure_injected",
                   "inner": {"kind": "poisson", "rate": 0.8, "seed": 1},
                   "drop_rate": 0.1},
        ),
        data=DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
            partition="iid", partition_params=dict(n_agents=n),
            batch_size=4, local_updates=2,
        ),
        inference=InferenceSpec(hidden=8, depth=1, lr=1e-2),
        run=RunSpec(n_rounds=5, seed=0),
    )
    s = build_session(spec)
    t0 = time.perf_counter()
    hist = s.run(eval_every=5)
    wall_us = (time.perf_counter() - t0) * 1e6
    tel = s.evaluate()
    assert np.isfinite(hist[-1]["loss"])
    assert s.engine.n_traces == 1, "window retraced: not one jitted call"
    return {
        "windows": tel["windows"],
        "loss": hist[-1]["loss"],
        "avg_acc": tel["avg_acc"],
        "staleness": tel["staleness"],
        "merges": tel["merges"],
        "n_traces": s.engine.n_traces,
        "wall_us_total": wall_us,
    }


def _window_sweep(n: int = 16, p: int = 1 << 15) -> list[dict]:
    ks = jax.random.split(jax.random.key(3), 2)
    mean = jax.random.normal(ks[0], (n, p))
    rho = jax.random.normal(ks[1], (n, p)) * 0.4 - 1.0
    layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
    posts = FlatPosterior(mean=mean, rho=rho, layout=layout)
    W_base = bidirectional_ring_w(n)
    dense_fn = jax.jit(lambda q, w: consensus_flat(q, w).mean)
    masked_fn = jax.jit(lambda q, w, a: consensus_flat_masked(q, w, a).mean)
    Wj = jnp.asarray(W_base, jnp.float32)
    us_dense = _time(dense_fn, (posts, Wj))
    out = []
    for rate in (0.1, 0.5, 2.0):
        win = PoissonClock(W_base, rate=rate, seed=5).window(0)
        rec = {
            "rate": rate,
            "n_events": win.n_events,
            "active_fraction": win.active_fraction,
            "us": {
                "dense_fused": us_dense,
                "window_masked": _time(
                    masked_fn,
                    (posts, jnp.asarray(win.w_eff, jnp.float32),
                     jnp.asarray(win.active)),
                ),
            },
            "roofline": gossip_window_roofline(
                n, p,
                n_participating=int(win.participating().sum()),
                n_merging=int(win.active.sum()),
            ),
        }
        out.append(rec)
    return out


def run(json_out: str | None = DEFAULT_JSON) -> dict:
    equiv = _all_active_equivalence()
    print(f"gossip_equivalence,0.0,"
          f"kernel_err={equiv['kernel_max_err']};"
          f"engine_err={equiv['engine_max_err']}")
    smoke = _poisson_smoke()
    print(f"gossip_poisson_smoke,{smoke['wall_us_total']:.1f},"
          f"windows={smoke['windows']};loss={smoke['loss']:.4f};"
          f"staleness_p90={smoke['staleness']['p90']};"
          f"traces={smoke['n_traces']}")
    sweep = _window_sweep()
    for rec in sweep:
        print(f"gossip_window[f={rec['active_fraction']:.2f}],"
              f"{rec['us']['window_masked']:.1f},"
              f"model_passes="
              f"{rec['roofline']['hbm_passes']['window_masked']:.3f}")
    doc = {
        "benchmark": "gossip_event_windows",
        "backend": jax.default_backend(),
        "equivalence": equiv,
        "poisson_smoke": smoke,
        "window_sweep": sweep,
    }
    if json_out:
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {json_out}")
    return doc


if __name__ == "__main__":
    run()
