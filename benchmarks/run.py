"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  fig1   decentralized Bayesian linear regression (central/isolated/coop)
  fig2   star topology: accuracy vs center centrality a
  fig3   ID/OOD confidence vs a
  fig4   grid: informative-agent placement (center vs corner)
  fig5   data-partition ambiguity (Assumption 2 violation)
  table3 asynchronous time-varying star networks
  thm1   predicted rate K(Theta) vs empirical decay slope
  calib  (beyond-paper) ECE calibration of the Bayesian MC predictive
  roofline  dry-run roofline terms per (arch x shape x mesh) + kernel bench
  consensus leaf-loop einsum vs flat-fused network consensus kernel
            (writes BENCH_consensus.json; see ROADMAP.md "Performance")

Subcommands:
  run.py [figures] [--only ...] [--json-out F]   paper figures (default)
  run.py bench [--full] [--json-out F]           quick consensus sweep — the
            CI smoke test of the benchmark harness itself (interpret-mode
            kernel probe + tiny shapes; --full for the real sweep)
  run.py api-smoke                               headless exercise of the
            declarative repro.api surface: builds a tiny ExperimentSpec,
            runs BOTH engines (simulated + launch), asserts their posteriors
            agree, round-trips a self-describing session checkpoint
  run.py gossip-smoke [--json-out F]             event-driven gossip runtime
            smoke: all-edges-active window must equal the synchronous fused
            consensus bit-identically, tiny Poisson+link-failure run with
            staleness telemetry (compile_us split from the warm wall time),
            window-consensus / delivery-latency / shard-count sweeps (the
            shard sweep asserts consensus_ppermute_window bit-identity per
            shard count — run under
            XLA_FLAGS=--xla_force_host_platform_device_count=8 to cover
            S>1), plus the edge-native sparse tier: a N=1e4
            Watts-Strogatz Poisson session end to end on
            consensus_impl="segments" (round/evaluate/save/load, jaxpr
            walked for the no-[N,N] contract, window-build host time
            asserted O(fired) — not O(N^2) — across N=1e4 vs 3e4);
            emits BENCH_gossip.json
  run.py chaos-smoke [--json-out F]              fault-tolerance chaos
            harness: combined crash/recover churn + link drops + delivery
            latency + NaN/Inf/huge payload corruption under
            fault_policy="quarantine" (healthy posteriors asserted), the
            strict counter-demo (corruption poisons), the zero-fault
            quarantine==strict bitwise ladder, an lr=0 consensus
            contraction probe under churn, and a degradation-vs-crash-rate
            sweep; emits BENCH_chaos.json
  run.py serve-smoke [--json-out F]              posterior serving tier
            smoke: bf16 snapshot halving asserted live + in the roofline
            model, padding-bucket trace-count pinning with a zero-retrace
            replay, served point estimate vs Session.predictive, then
            p50/p99 latency + QPS sweeps vs MC ensemble size L and bucket
            policy; emits BENCH_serve.json
  run.py obs-smoke [--json-out F]                observability layer smoke:
            disabled-span overhead asserted free, obs-enabled vs unset
            bitwise ladder on the gossip engine, theory-vs-measured
            convergence rate_attainment on a static ring, Prometheus
            exporter golden check; emits BENCH_obs.json + a sample JSONL
            trace (BENCH_obs_trace.jsonl)
  run.py bench-diff OLD.json NEW.json            compare two BENCH_*.json
            documents and flag timing regressions (advisory; --strict to
            gate)
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

from benchmarks import (
    bench_chaos,
    bench_consensus,
    bench_diff,
    bench_gossip,
    bench_obs,
    bench_serve,
    calibration,
    fig1_linreg,
    fig2_star_centrality,
    fig3_confidence,
    fig4_grid_placement,
    fig5_partition,
    roofline,
    table3_timevarying,
    thm1_rate,
)

ALL = {
    "fig1": fig1_linreg.run,
    "fig2": fig2_star_centrality.run,
    "fig3": fig3_confidence.run,
    "fig4": fig4_grid_placement.run,
    "fig5": fig5_partition.run,
    "table3": table3_timevarying.run,
    "thm1": thm1_rate.run,
    "calib": calibration.run,
    "roofline": roofline.run,
    # quick sweep, no JSON side-effect: the figures path must not silently
    # overwrite the tracked BENCH_consensus.json (use the `bench` subcommand
    # for that)
    "consensus": lambda: bench_consensus.run(quick=True, json_out=None),
}


def api_smoke() -> None:
    """Exercise the repro.api spec/session surface end-to-end on a tiny
    experiment: eager validation, both engines, engine agreement, evaluate,
    and the self-describing checkpoint round trip."""
    import dataclasses
    import os
    import tempfile

    import numpy as np

    from repro.api import (
        DataSpec, ExperimentSpec, InferenceSpec, RunSpec, Session,
        TopologySpec, build_session,
    )

    spec = ExperimentSpec(
        topology=TopologySpec.star(n_edge=2, a=0.5),
        data=DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
            partition="star",
            partition_params=dict(center_labels=[1, 2], edge_labels=[0], n_edge=2),
            batch_size=4, local_updates=2,
        ),
        inference=InferenceSpec(hidden=8, depth=1, lr=1e-2),
        run=RunSpec(n_rounds=3, seed=0),
    )
    sessions = {}
    for engine in ("simulated", "launch"):
        s = build_session(
            dataclasses.replace(spec, run=dataclasses.replace(spec.run, engine=engine))
        )
        s.run()
        sessions[engine] = s
        print(f"api-smoke,{engine},avg_acc={s.evaluate()['avg_acc']:.4f}")
    p_sim = sessions["simulated"].posterior()
    p_launch = sessions["launch"].posterior()
    np.testing.assert_allclose(
        np.asarray(p_sim.mean), np.asarray(p_launch.mean), atol=1e-5, rtol=1e-5
    )
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "session.ckpt")
        sessions["simulated"].save(path)
        resumed = Session.load(path)
        np.testing.assert_array_equal(
            np.asarray(resumed.posterior().mean), np.asarray(p_sim.mean)
        )
        assert resumed.round_idx == 3
    print("api-smoke,ok,engines_agree=1;ckpt_roundtrip=1")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "cmd", nargs="?",
        choices=["figures", "bench", "api-smoke", "gossip-smoke",
                 "chaos-smoke", "serve-smoke", "obs-smoke", "bench-diff"],
        default="figures",
        help="figures (default): paper figures; bench: consensus perf "
        "sweep; api-smoke: declarative-API smoke; gossip-smoke: async "
        "gossip runtime smoke (all-active equivalence + Poisson run + "
        "edge-native N=1e4 segments session); "
        "chaos-smoke: fault-tolerance chaos harness (churn + corruption "
        "under quarantine); serve-smoke: posterior serving tier (snapshot "
        "halving + trace pinning + latency/QPS sweeps); obs-smoke: "
        "observability layer (span overhead + bitwise ladder + "
        "rate_attainment + exporter golden); bench-diff: compare two "
        "BENCH_*.json for timing regressions",
    )
    ap.add_argument(
        "paths", nargs="*",
        help="bench-diff only: the OLD.json NEW.json pair to compare",
    )
    ap.add_argument("--only", nargs="*", choices=list(ALL), default=None)
    ap.add_argument(
        "--json-out", default=None,
        help="write a JSON result document (bench: the BENCH_consensus.json "
        "path; figures: {name: ok|failed} status map)",
    )
    ap.add_argument(
        "--full", action="store_true",
        help="bench / gossip-smoke: run the full sweep (segment-sum and "
        "sparse-scale points up to N=1e5) instead of the quick CI smoke",
    )
    ap.add_argument(
        "--strict", action="store_true",
        help="bench-diff only: exit 1 when a timing regression is flagged",
    )
    args = ap.parse_args(argv)

    if args.cmd == "api-smoke":
        api_smoke()
        return
    if args.cmd == "gossip-smoke":
        bench_gossip.run(json_out=args.json_out or bench_gossip.DEFAULT_JSON,
                         full=args.full)
        return
    if args.cmd == "chaos-smoke":
        bench_chaos.run(json_out=args.json_out or bench_chaos.DEFAULT_JSON)
        return
    if args.cmd == "serve-smoke":
        bench_serve.run(json_out=args.json_out or bench_serve.DEFAULT_JSON)
        return
    if args.cmd == "obs-smoke":
        bench_obs.run(json_out=args.json_out or bench_obs.DEFAULT_JSON)
        return
    if args.cmd == "bench-diff":
        if len(args.paths) != 2:
            ap.error("bench-diff needs exactly two paths: OLD.json NEW.json")
        bench_diff.run(args.paths[0], args.paths[1], strict=args.strict)
        return
    if args.cmd == "bench":
        bench_consensus.run(
            quick=not args.full,
            json_out=args.json_out or bench_consensus.DEFAULT_JSON,
        )
        return

    names = args.only or list(ALL)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            ALL[name]()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc(file=sys.stderr)
            print(f"{name},0.0,FAILED")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(
                {n: ("failed" if n in failed else "ok") for n in names}, f, indent=2
            )
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
