"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  fig1   decentralized Bayesian linear regression (central/isolated/coop)
  fig2   star topology: accuracy vs center centrality a
  fig3   ID/OOD confidence vs a
  fig4   grid: informative-agent placement (center vs corner)
  fig5   data-partition ambiguity (Assumption 2 violation)
  table3 asynchronous time-varying star networks
  thm1   predicted rate K(Theta) vs empirical decay slope
  calib  (beyond-paper) ECE calibration of the Bayesian MC predictive
  roofline  dry-run roofline terms per (arch x shape x mesh) + kernel bench
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    calibration,
    fig1_linreg,
    fig2_star_centrality,
    fig3_confidence,
    fig4_grid_placement,
    fig5_partition,
    roofline,
    table3_timevarying,
    thm1_rate,
)

ALL = {
    "fig1": fig1_linreg.run,
    "fig2": fig2_star_centrality.run,
    "fig3": fig3_confidence.run,
    "fig4": fig4_grid_placement.run,
    "fig5": fig5_partition.run,
    "table3": table3_timevarying.run,
    "thm1": thm1_rate.run,
    "calib": calibration.run,
    "roofline": roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", choices=list(ALL), default=None)
    args = ap.parse_args()
    names = args.only or list(ALL)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            ALL[name]()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc(file=sys.stderr)
            print(f"{name},0.0,FAILED")
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
