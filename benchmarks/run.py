"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  fig1   decentralized Bayesian linear regression (central/isolated/coop)
  fig2   star topology: accuracy vs center centrality a
  fig3   ID/OOD confidence vs a
  fig4   grid: informative-agent placement (center vs corner)
  fig5   data-partition ambiguity (Assumption 2 violation)
  table3 asynchronous time-varying star networks
  thm1   predicted rate K(Theta) vs empirical decay slope
  calib  (beyond-paper) ECE calibration of the Bayesian MC predictive
  roofline  dry-run roofline terms per (arch x shape x mesh) + kernel bench
  consensus leaf-loop einsum vs flat-fused network consensus kernel
            (writes BENCH_consensus.json; see ROADMAP.md "Performance")

Subcommands:
  run.py [figures] [--only ...] [--json-out F]   paper figures (default)
  run.py bench [--full] [--json-out F]           quick consensus sweep — the
            CI smoke test of the benchmark harness itself (interpret-mode
            kernel probe + tiny shapes; --full for the real sweep)
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

from benchmarks import (
    bench_consensus,
    calibration,
    fig1_linreg,
    fig2_star_centrality,
    fig3_confidence,
    fig4_grid_placement,
    fig5_partition,
    roofline,
    table3_timevarying,
    thm1_rate,
)

ALL = {
    "fig1": fig1_linreg.run,
    "fig2": fig2_star_centrality.run,
    "fig3": fig3_confidence.run,
    "fig4": fig4_grid_placement.run,
    "fig5": fig5_partition.run,
    "table3": table3_timevarying.run,
    "thm1": thm1_rate.run,
    "calib": calibration.run,
    "roofline": roofline.run,
    # quick sweep, no JSON side-effect: the figures path must not silently
    # overwrite the tracked BENCH_consensus.json (use the `bench` subcommand
    # for that)
    "consensus": lambda: bench_consensus.run(quick=True, json_out=None),
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "cmd", nargs="?", choices=["figures", "bench"], default="figures",
        help="figures (default): paper figures; bench: consensus perf sweep",
    )
    ap.add_argument("--only", nargs="*", choices=list(ALL), default=None)
    ap.add_argument(
        "--json-out", default=None,
        help="write a JSON result document (bench: the BENCH_consensus.json "
        "path; figures: {name: ok|failed} status map)",
    )
    ap.add_argument(
        "--full", action="store_true",
        help="bench only: run the full sweep instead of the quick CI smoke",
    )
    args = ap.parse_args(argv)

    if args.cmd == "bench":
        bench_consensus.run(
            quick=not args.full,
            json_out=args.json_out or bench_consensus.DEFAULT_JSON,
        )
        return

    names = args.only or list(ALL)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            ALL[name]()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc(file=sys.stderr)
            print(f"{name},0.0,FAILED")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(
                {n: ("failed" if n in failed else "ok") for n in names}, f, indent=2
            )
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
