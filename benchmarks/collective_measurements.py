"""Standalone collective A/B measurements used by EXPERIMENTS.md §Perf.

NOT part of ``benchmarks.run`` (needs 512 placeholder devices — run it as a
fresh process):

    PYTHONPATH=src python -m benchmarks.collective_measurements

Measurements (exact — all ops are scan-exterior):
  1. MoE layer: GSPMD-inferred dispatch vs explicit expert-parallel
     all_to_all (launch/expert_parallel.py) at olmoe train_4k shard sizes.
  2. 16-agent ring consensus: dense einsum (GSPMD) vs hand-written
     shard_map ring ppermute, f32 and bf16 wire.
Outputs JSON next to the other dry-run results.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.graphs import bidirectional_ring_w  # noqa: E402
from repro.core.posterior import GaussianPosterior, consensus_all_agents  # noqa: E402
from repro.launch.consensus_opt import consensus_ppermute_ring  # noqa: E402
from repro.launch.dryrun import parse_collectives  # noqa: E402
from repro.launch.expert_parallel import moe_ffn_expert_parallel  # noqa: E402
from repro.models.moe import moe_ffn, moe_init  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "results")


def _total(c):
    return sum(v["bytes"] for v in c.values())


def measure_moe() -> dict:
    mesh = jax.make_mesh((16, 16), ("data", "model"))
    cfg = get_config("olmoe-1b-7b")
    p_shape = jax.eval_shape(lambda k: moe_init(k, cfg), jax.random.key(0))
    psh_base = {
        "router": NamedSharding(mesh, P(None, None)),
        "w_gate": NamedSharding(mesh, P("model", "data", None)),
        "w_up": NamedSharding(mesh, P("model", "data", None)),
        "w_down": NamedSharding(mesh, P("model", "data", None)),
    }
    psh_ep = {
        "router": NamedSharding(mesh, P(None, None)),
        "w_gate": NamedSharding(mesh, P("model", None, None)),
        "w_up": NamedSharding(mesh, P("model", None, None)),
        "w_down": NamedSharding(mesh, P("model", None, None)),
    }
    x_sds = jax.ShapeDtypeStruct(
        (256, 4096, 2048), jnp.bfloat16, sharding=NamedSharding(mesh, P("data", None, None))
    )
    res = {}
    with mesh:
        p_sds = {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=psh_base[k])
                 for k, v in p_shape.items()}
        low = jax.jit(lambda p, x: moe_ffn(p, x, cfg)).lower(p_sds, x_sds)
        res["gspmd_baseline"] = parse_collectives(low.compile().as_text())
        p_sds2 = {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=psh_ep[k])
                  for k, v in p_shape.items()}
        low2 = jax.jit(
            lambda p, x: moe_ffn_expert_parallel(p, x, cfg, mesh)
        ).lower(p_sds2, x_sds)
        res["expert_parallel"] = parse_collectives(low2.compile().as_text())
    return res


def measure_ring_consensus() -> dict:
    mesh = jax.make_mesh((16, 16), ("data", "model"))
    a, pn = 16, 16 * 1024 * 1024
    sh = NamedSharding(mesh, P("data", "model"))
    sds = jax.ShapeDtypeStruct((a, pn), jnp.float32, sharding=sh)
    posts = GaussianPosterior(mean={"w": sds}, rho={"w": sds})
    W = jnp.asarray(bidirectional_ring_w(a), jnp.float32)
    res = {}
    with mesh:
        low = jax.jit(lambda q: consensus_all_agents(q, W)).lower(posts)
        res["dense_einsum_ring_W"] = parse_collectives(low.compile().as_text())
        for name, dt in (("sparse_ppermute_f32", jnp.float32),
                         ("sparse_ppermute_bf16", jnp.bfloat16)):
            low2 = jax.jit(
                lambda q, dt=dt: consensus_ppermute_ring(q, mesh, "data", wire_dtype=dt)
            ).lower(posts)
            res[name] = parse_collectives(low2.compile().as_text())
    return res


def main() -> None:
    moe = measure_moe()
    ring = measure_ring_consensus()
    for group, res in (("moe_ep", moe), ("ring_consensus", ring)):
        for name, c in res.items():
            print(f"{group}/{name},{_total(c):.1f},bytes_per_device")
        with open(os.path.join(OUT, f"{group}_collectives.json"), "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
