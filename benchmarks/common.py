"""Shared helpers for the paper-figure benchmarks (CSV output contract:
``name,us_per_call,derived``)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulated import init_network, make_round_fn, run_rounds
from repro.data.pipeline import AgentDataset, make_round_batches
from repro.optim import adam
from repro.optim.schedules import exponential_decay
from repro.vi.bayes_by_backprop import mc_predict


def mlp_init(dim, hidden, n_classes):
    """The paper's 2-hidden-layer ReLU MLP (200 units on MNIST; scaled via
    ``hidden`` for the synthetic stand-in)."""

    def init(key):
        ks = jax.random.split(key, 3)
        return {
            "w1": jax.random.normal(ks[0], (dim, hidden)) / np.sqrt(dim),
            "b1": jnp.zeros((hidden,)),
            "w2": jax.random.normal(ks[1], (hidden, hidden)) / np.sqrt(hidden),
            "b2": jnp.zeros((hidden,)),
            "w3": jax.random.normal(ks[2], (hidden, n_classes)) / np.sqrt(hidden),
            "b3": jnp.zeros((n_classes,)),
        }

    return init


def mlp_logits(theta, x):
    h = jax.nn.relu(x @ theta["w1"] + theta["b1"])
    h = jax.nn.relu(h @ theta["w2"] + theta["b2"])
    return h @ theta["w3"] + theta["b3"]


def mlp_nll(theta, batch):
    logits = mlp_logits(theta, batch["x"])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][..., None], axis=-1)[..., 0]
    return jnp.sum(logz - gold)


def train_network(
    shards,
    W_schedule,
    rounds,
    *,
    hidden=48,
    n_classes=10,
    dim=64,
    batch_size=16,
    local_updates=4,
    lr=5e-3,
    kl_scale=1e-3,
    consensus="gaussian",
    seed=0,
    eval_fn=None,
    eval_every=0,
):
    data = AgentDataset.from_shards(
        [(x.astype(np.float32), y.astype(np.int32)) for x, y in shards]
    )
    n_agents = data.n_agents
    sampler = make_round_batches(data, batch_size, local_updates)
    opt = adam()
    round_fn = make_round_fn(
        mlp_nll, opt, exponential_decay(lr, 0.99), kl_scale=kl_scale,
        consensus=consensus,
    )
    state = init_network(
        jax.random.key(seed), n_agents, mlp_init(dim, hidden, n_classes), opt,
        init_sigma=0.05,
    )
    return run_rounds(
        round_fn, state, sampler, W_schedule, rounds, jax.random.key(seed + 1),
        eval_fn=eval_fn, eval_every=eval_every,
    )


def network_accuracy(state, x_test, y_test, n_mc=4, per_agent=False, key=None):
    xt = jnp.asarray(x_test)
    yt = np.asarray(y_test)
    n_agents = jax.tree.leaves(state.posterior.mean)[0].shape[0]
    key = key if key is not None else jax.random.key(99)
    accs = []
    for i in range(n_agents):
        post_i = jax.tree.map(lambda l: l[i], state.posterior)
        probs = mc_predict(post_i, mlp_logits, xt, key, n_mc=n_mc)
        pred = np.asarray(jnp.argmax(probs, -1))
        accs.append(float((pred == yt).mean()))
    return accs if per_agent else float(np.mean(accs))


def agent_confidence(state, agent, x, label, n_mc=8, key=None):
    """Paper's confidence metric: mean posterior-predictive probability of
    ``label`` on inputs x (Figs 3/5)."""
    post = jax.tree.map(lambda l: l[agent], state.posterior)
    key = key if key is not None else jax.random.key(7)
    probs = mc_predict(post, mlp_logits, jnp.asarray(x), key, n_mc=n_mc)
    return float(np.mean(np.asarray(probs[:, label])))


class Timer:
    def __init__(self):
        self.t0 = time.perf_counter()

    def us(self, n_calls=1):
        return (time.perf_counter() - self.t0) * 1e6 / n_calls


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
