"""Shared helpers for the paper-figure benchmarks (CSV output contract:
``name,us_per_call,derived``).

All NN drivers run on the declarative API (``repro.api``): build one
``ExperimentSpec`` per figure configuration with ``classification_spec``,
then ``run_classification`` -> a finished ``Session``.  The MLP definition
lives in ``repro.api.models`` (re-exported here for the drivers/tests that
evaluate posteriors directly).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    RunSpec,
    Session,
    TopologySpec,
    build_session,
)
from repro.api.models import mlp_init, mlp_logits, mlp_nll  # noqa: F401  (re-export)
from repro.vi.bayes_by_backprop import mc_predict


def classification_spec(
    topology: TopologySpec,
    *,
    rounds: int,
    dataset: str = "synthetic_classification",
    dataset_params: dict | None = None,
    partition: str = "iid",
    partition_params: dict | None = None,
    hidden: int = 48,
    batch_size: int = 16,
    local_updates: int = 4,
    lr: float = 5e-3,
    kl_scale: float = 1e-3,
    consensus: str = "gaussian",
    seed: int = 0,
    engine: str = "simulated",
) -> ExperimentSpec:
    """The benchmark drivers' common configuration (the paper's NN training
    recipe: Adam, per-round lr decay 0.99, u local steps of batch 16)."""
    return ExperimentSpec(
        topology=topology,
        data=DataSpec(
            dataset=dataset,
            dataset_params=dataset_params or {},
            partition=partition,
            partition_params=partition_params or {},
            batch_size=batch_size,
            local_updates=local_updates,
        ),
        inference=InferenceSpec(
            hidden=hidden,
            lr=lr,
            kl_scale=kl_scale,
            consensus=consensus,
        ),
        run=RunSpec(n_rounds=rounds, seed=seed, engine=engine),
    )


def run_classification(spec: ExperimentSpec, w_schedule=None) -> Session:
    """build + run; ``w_schedule`` (static / list / Callable[[int], W])
    overrides the spec topology round-by-round."""
    session = build_session(spec)
    session.run(w_schedule=w_schedule)
    return session


def network_accuracy(state, x_test, y_test, n_mc=4, per_agent=False, key=None):
    """Per-agent (or network-average) MC-predictive accuracy.  ``state`` is
    an engine state (``NetworkState``/``BayesTrainState``) or a ``Session``."""
    posterior = state.posterior() if isinstance(state, Session) else state.posterior
    xt = jnp.asarray(x_test)
    yt = np.asarray(y_test)
    n_agents = jax.tree.leaves(posterior.mean)[0].shape[0]
    key = key if key is not None else jax.random.key(99)
    accs = []
    for i in range(n_agents):
        post_i = jax.tree.map(lambda l: l[i], posterior)
        probs = mc_predict(post_i, mlp_logits, xt, key, n_mc=n_mc)
        pred = np.asarray(jnp.argmax(probs, -1))
        accs.append(float((pred == yt).mean()))
    return accs if per_agent else float(np.mean(accs))


def agent_confidence(state, agent, x, label, n_mc=8, key=None):
    """Paper's confidence metric: mean posterior-predictive probability of
    ``label`` on inputs x (Figs 3/5)."""
    posterior = state.posterior() if isinstance(state, Session) else state.posterior
    post = jax.tree.map(lambda l: l[agent], posterior)
    key = key if key is not None else jax.random.key(7)
    probs = mc_predict(post, mlp_logits, jnp.asarray(x), key, n_mc=n_mc)
    return float(np.mean(np.asarray(probs[:, label])))


class Timer:
    def __init__(self):
        self.t0 = time.perf_counter()

    def us(self, n_calls=1):
        return (time.perf_counter() - self.t0) * 1e6 / n_calls


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
