"""Paper Fig 2: star topology, informative center.  Average test accuracy as
the edge agents' confidence ``a`` on the center (= the center's eigenvector
centrality) increases.  Expected: accuracy increases with a."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, classification_spec, emit, run_classification
from repro.api import TopologySpec
from repro.core.graphs import star_w
from repro.core.theory import stationary_distribution

A_VALUES = (0.1, 0.3, 0.5, 0.7)
N_EDGE = 8

# MNIST-Setup1 analogue: center holds labels 2..9, edges share {0,1}
DATASET = dict(n_classes=10, dim=64, n_train_per_class=200, noise=0.55, seed=0)
PARTITION = dict(center_labels=list(range(2, 10)), edge_labels=[0, 1], n_edge=N_EDGE)


def run(rounds: int = 18) -> None:
    accs = []
    for a in A_VALUES:
        t = Timer()
        v1 = stationary_distribution(star_w(N_EDGE, a))[0]
        session = run_classification(classification_spec(
            TopologySpec.star(N_EDGE, a),
            rounds=rounds,
            dataset_params=DATASET,
            partition="star",
            partition_params=PARTITION,
        ))
        acc = session.evaluate()["avg_acc"]
        accs.append(acc)
        emit(f"fig2_star_a{a}", t.us(), f"acc={acc:.4f};v_center={v1:.2f}")
    # the paper's qualitative claim: higher centrality of the informative
    # agent -> higher accuracy (allow small noise between adjacent points)
    assert accs[-1] > accs[0] + 0.02, accs
