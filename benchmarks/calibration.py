"""Beyond-paper: CALIBRATION of the decentralized Bayesian network.

The paper argues its Bayesian formulation "has the added advantage of
obtaining confidence values over agents' predictions" but never quantifies
confidence QUALITY.  We do: expected calibration error (ECE, 10 bins) of the
MC posterior-predictive vs a deterministic decentralized baseline
(mean-only consensus, softmax confidence), same topology/partition/rounds.
Expected: the Bayesian predictive is better calibrated (lower ECE),
especially on OOD labels where single-softmax models are overconfident.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Timer, classification_spec, emit, run_classification
from repro.api import TopologySpec

N_EDGE = 8


def ece(probs: np.ndarray, labels: np.ndarray, n_bins: int = 10) -> float:
    conf = probs.max(-1)
    pred = probs.argmax(-1)
    correct = (pred == labels).astype(np.float64)
    bins = np.clip((conf * n_bins).astype(int), 0, n_bins - 1)
    total = len(labels)
    err = 0.0
    for b in range(n_bins):
        m = bins == b
        if m.sum() == 0:
            continue
        err += m.sum() / total * abs(correct[m].mean() - conf[m].mean())
    return float(err)


def _network_probs(session, x, n_mc, key):
    # n_mc <= 1 is the point-estimate baseline: a single softmax at the
    # posterior MEAN (session.predictive(n_mc=0)), deliberately NOT one
    # posterior sample
    out = [
        np.asarray(session.predictive(i, x, n_mc=(n_mc if n_mc > 1 else 0), key=key))
        for i in range(session.data.n_agents)
    ]
    return np.stack(out)


def run(rounds: int = 12) -> None:
    # hard regime (test accuracy ~0.65): calibration only differentiates
    # models when they actually make errors
    results = {}
    for name, consensus, n_mc in (
        ("bayes_mc", "gaussian", 8),
        ("bayes_mean", "gaussian", 1),
        ("deterministic", "mean_only", 1),
    ):
        t = Timer()
        session = run_classification(classification_spec(
            TopologySpec.star(N_EDGE, 0.5),
            rounds=rounds,
            dataset_params=dict(
                n_classes=10, dim=64, n_train_per_class=80, noise=1.6, seed=0
            ),
            partition="star",
            partition_params=dict(
                center_labels=list(range(2, 10)), edge_labels=[0, 1],
                n_edge=N_EDGE,
            ),
            consensus=consensus,
        ))
        ds = session.data.dataset
        # the MC predictive's ECE estimate is noisy in the theta samples
        # (~±0.03 across eval keys); average over keys so the comparison
        # reflects the predictive, not one draw
        eces, accs = [], []
        for k in range(5 if n_mc > 1 else 1):
            probs = _network_probs(session, ds.x_test, n_mc, jax.random.key(k))
            eces += [ece(probs[i], ds.y_test) for i in range(probs.shape[0])]
            accs += [float((probs[i].argmax(-1) == ds.y_test).mean())
                     for i in range(probs.shape[0])]
        results[name] = float(np.mean(eces))
        emit(f"calibration_{name}", t.us(),
             f"ece={np.mean(eces):.4f};acc={np.mean(accs):.4f};n_mc={n_mc}")
    # the Bayesian MC predictive should not be worse-calibrated than the
    # deterministic point-estimate confidence
    assert results["bayes_mc"] <= results["deterministic"] + 0.01, results
