"""Beyond-paper: CALIBRATION of the decentralized Bayesian network.

The paper argues its Bayesian formulation "has the added advantage of
obtaining confidence values over agents' predictions" but never quantifies
confidence QUALITY.  We do: expected calibration error (ECE, 10 bins) of the
MC posterior-predictive vs a deterministic decentralized baseline
(mean-only consensus, softmax confidence), same topology/partition/rounds.
Expected: the Bayesian predictive is better calibrated (lower ECE),
especially on OOD labels where single-softmax models are overconfident.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit, mlp_logits, train_network
from repro.core.graphs import star_w
from repro.data.partition import star_partition
from repro.data.synthetic import make_synthetic_classification
from repro.vi.bayes_by_backprop import mc_predict

N_EDGE = 8


def ece(probs: np.ndarray, labels: np.ndarray, n_bins: int = 10) -> float:
    conf = probs.max(-1)
    pred = probs.argmax(-1)
    correct = (pred == labels).astype(np.float64)
    bins = np.clip((conf * n_bins).astype(int), 0, n_bins - 1)
    total = len(labels)
    err = 0.0
    for b in range(n_bins):
        m = bins == b
        if m.sum() == 0:
            continue
        err += m.sum() / total * abs(correct[m].mean() - conf[m].mean())
    return float(err)


def _network_probs(state, x, n_mc, key):
    n_agents = jax.tree.leaves(state.posterior.mean)[0].shape[0]
    out = []
    for i in range(n_agents):
        post = jax.tree.map(lambda l: l[i], state.posterior)
        if n_mc > 1:
            probs = mc_predict(post, mlp_logits, jnp.asarray(x), key, n_mc=n_mc)
        else:
            probs = jax.nn.softmax(mlp_logits(post.mean, jnp.asarray(x)), -1)
        out.append(np.asarray(probs))
    return np.stack(out)


def run(rounds: int = 12) -> None:
    # hard regime (test accuracy ~0.65): calibration only differentiates
    # models when they actually make errors
    ds = make_synthetic_classification(
        n_classes=10, dim=64, n_train_per_class=80, noise=1.6, seed=0
    )
    shards = star_partition(
        ds.x_train, ds.y_train, center_labels=list(range(2, 10)),
        edge_labels=[0, 1], n_edge=N_EDGE,
    )
    W = np.asarray(star_w(N_EDGE, 0.5))
    results = {}
    for name, consensus, n_mc in (
        ("bayes_mc", "gaussian", 8),
        ("bayes_mean", "gaussian", 1),
        ("deterministic", "mean_only", 1),
    ):
        t = Timer()
        state, _ = train_network(shards, W, rounds, seed=0, consensus=consensus)
        probs = _network_probs(state, ds.x_test, n_mc, jax.random.key(5))
        eces = [ece(probs[i], ds.y_test) for i in range(probs.shape[0])]
        accs = [float((probs[i].argmax(-1) == ds.y_test).mean())
                for i in range(probs.shape[0])]
        results[name] = float(np.mean(eces))
        emit(f"calibration_{name}", t.us(),
             f"ece={np.mean(eces):.4f};acc={np.mean(accs):.4f};n_mc={n_mc}")
    # the Bayesian MC predictive should not be worse-calibrated than the
    # deterministic point-estimate confidence
    assert results["bayes_mc"] <= results["deterministic"] + 0.01, results
