"""Asynchronous gossip quickstart: Poisson clocks, link failures, delayed
delivery, and the sharded window consensus.

Eight agents on a bidirectional ring learn a synthetic classification task
with NO global synchronization: every directed link carries its own Poisson
activation clock, and each fired link additionally FAILS with probability
0.1 (dropped message).  Time is discretized into event windows
(``repro.gossip.clocks``); each window executes as one jitted program —
local Bayes-by-Backprop steps, then the masked active-edge consensus in
which idle agents pass through bit-untouched.

Two more regimes ride the same declarative spec:

* **Delayed delivery** — wrapping the clock in ``{"kind": "delayed", ...}``
  makes every fired message arrive k windows late, merging the sender's
  posterior AS OF FIRE TIME (a bounded [K, N, P] history ring buffer in the
  engine).  Latency 0 is bit-identical to the instant runtime.
* **Sharded consensus** — ``InferenceSpec(consensus_impl="ppermute")``
  shards the agent axis over the local devices and executes each window as
  one ``shard_map`` that ppermutes only the window's fired shard offsets
  (bit-identical to the dense path; wire bytes scale with cross-shard
  activity).  This script forces 4 virtual CPU devices so the demo is real
  on any host.
* **Wire precision** — ``InferenceSpec(wire_dtype="bf16")`` exchanges the
  consensus sufficient statistics (prec, prec*mu) in bfloat16 (cast at the
  exchange boundary, accumulated fp32), halving every merge's wire bytes;
  the posterior stays within the analytic bound of the fp32 run
  (``core.numerics.wire_error_bound``; ROADMAP "Wire precision").
* **Fault tolerance** — adding ``"faults": {...}`` to the clock crashes
  and recovers agents (Markov churn) and corrupts wire payloads with
  NaN/Inf garbage; ``InferenceSpec(fault_policy="quarantine")`` validates
  every incoming contribution at the exchange boundary and drops invalid
  sources, so the garbage never reaches a resident posterior (ROADMAP
  "Robustness").
* **Small-world topology** — swapping the ring base for
  ``TopologySpec.gossip("watts_strogatz", {...})`` runs the same engine
  on a Watts-Strogatz graph: shortcut edges collapse the ring's O(N)
  information diameter, so gossip mixes in far fewer windows.  The same
  generator scales to N = 10^4+ agents through the edge-native sparse
  path (``TopologySpec.sparse`` + ``consensus_flat_segments``) — shown
  at the end without ever materializing an [N, N] matrix.

To serve predictions from the posteriors these runs produce, see the
serving quickstart ``examples/serve_batched.py`` (snapshots carry this
runtime's staleness telemetry into the serving SLO).

    PYTHONPATH=src python examples/async_gossip.py
"""
import os

# sharded demo substrate: 4 virtual CPU devices (must be set before jax
# initializes; harmless when a real multi-device backend is present)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

from repro.api import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    ObsSpec,
    RunSpec,
    TopologySpec,
    build_session,
)

N_AGENTS = 8

# ring base graph; Poisson link clocks (rate 0.8 firings/window) with 10% of
# fired messages dropped — the unreliable-network scenario
UNRELIABLE_CLOCK = {
    "kind": "failure_injected",
    "inner": {"kind": "poisson", "rate": 0.8, "seed": 0},
    "drop_rate": 0.1,
}

SPEC = ExperimentSpec(
    topology=TopologySpec.gossip(
        "bidirectional_ring", {"n": N_AGENTS}, clock=UNRELIABLE_CLOCK
    ),
    data=DataSpec(
        dataset_params=dict(n_classes=4, dim=32, n_train_per_class=120),
        # non-IID: each pair of ring neighbors holds ONE label; only gossip
        # spreads the other three around the ring
        partition="by_label",
        partition_params=dict(label_sets=[[c] for c in range(4) for _ in range(2)]),
        batch_size=16,
        local_updates=4,
    ),
    inference=InferenceSpec(hidden=32, depth=1, lr=5e-3, kl_scale=1e-3),
    run=RunSpec(n_rounds=30, seed=0, eval_every=10),
)


def _print_history(hist):
    for rec in hist:
        st = rec["engine"]["staleness"]
        loss = "  idle " if rec["loss"] is None else f"{rec['loss']:7.3f}"
        print(
            f"window {rec['round']:3d}  loss {loss}  "
            f"trained {rec['n_trained']:2d}/{N_AGENTS}  "
            f"avg_acc {rec['avg_acc']:.3f}  "
            f"staleness p50/p90/max {st['p50']:.0f}/{st['p90']:.0f}/{st['max']}"
        )


def main():
    import dataclasses

    import jax

    session = build_session(SPEC)  # validates the activation union eagerly
    hist = session.run(eval_fn=lambda s: s.evaluate())
    _print_history(hist)
    tel = session.evaluate()["engine"]
    print(
        f"\n{tel['windows']} event windows, "
        f"{tel['merges']['total']} merges "
        f"({tel['merges']['per_agent_mean']:.1f}/agent, "
        f"min {tel['merges']['min']}); one jitted call per window "
        f"(traced {session.engine.n_traces}x).\n"
        "Despite asynchronous, unreliable links every agent classifies all "
        "labels — the paper's consensus claim survives the gossip regime.\n"
    )
    # the same numbers, observed live: rerun with the observability layer
    # attached (ObsSpec is a pure observer — bit-identical trajectories)
    observed = build_session(dataclasses.replace(
        SPEC, obs=ObsSpec(enabled=True),
    ))
    observed.run()
    print(observed.dashboard(), "\n")

    # -- delayed delivery: every message arrives 2 windows late -------------
    delayed_spec = dataclasses.replace(
        SPEC,
        topology=TopologySpec.gossip(
            "bidirectional_ring", {"n": N_AGENTS},
            clock={"kind": "delayed", "inner": UNRELIABLE_CLOCK,
                   "latency": {"kind": "constant", "delay": 2}},
        ),
    )
    delayed = build_session(delayed_spec)
    d_hist = delayed.run(eval_fn=lambda s: s.evaluate())
    d_tel = delayed.evaluate()["engine"]
    print(
        f"Delayed delivery (k={d_tel['max_delay']} windows, "
        f"{delayed.engine.hist_slots}-slot posterior history ring): "
        f"final avg_acc {d_hist[-1]['avg_acc']:.3f} vs instant "
        f"{hist[-1]['avg_acc']:.3f} — consensus still mixes, only later."
    )

    # -- sharded window consensus: agent axis over the local devices --------
    sharded_spec = dataclasses.replace(
        SPEC,
        inference=dataclasses.replace(SPEC.inference, consensus_impl="ppermute"),
    )
    sharded = build_session(sharded_spec)
    s_hist = sharded.run(eval_fn=lambda s: s.evaluate())
    s_tel = sharded.evaluate()["engine"]
    import numpy as np

    bitwise = bool(
        np.array_equal(
            np.asarray(sharded.posterior().mean),
            np.asarray(session.posterior().mean),
        )
    )
    print(
        f"Sharded windows ({s_tel['consensus_shards']} shards over "
        f"{len(jax.devices())} devices, ppermute on fired offsets only): "
        f"avg_acc {s_hist[-1]['avg_acc']:.3f}, bit-identical to the dense "
        f"run: {bitwise}."
    )

    # -- bf16 wire: half the exchange bytes, error-bounded posterior --------
    from repro.launch.costmodel import gossip_window_roofline

    wire_spec = dataclasses.replace(
        SPEC,
        inference=dataclasses.replace(SPEC.inference, wire_dtype="bf16"),
    )
    wired = build_session(wire_spec)
    w_hist = wired.run(eval_fn=lambda s: s.evaluate())
    w_tel = wired.evaluate()["engine"]
    dev = float(
        np.abs(
            np.asarray(wired.posterior().mean)
            - np.asarray(session.posterior().mean)
        ).max()
    )
    n_params = int(wired.posterior().mean.shape[-1])
    model = {
        wd: gossip_window_roofline(
            N_AGENTS, n_params, n_participating=N_AGENTS,
            n_shards=4, n_cross_offsets=2, wire_dtype=wd,
        )["ici_bytes"]["window_ppermute"]
        for wd in ("f32", "bf16")
    }
    print(
        f"bf16 wire ({w_tel['wire_dtype']} exchange, fp32 accumulate): "
        f"avg_acc {w_hist[-1]['avg_acc']:.3f} vs fp32 "
        f"{hist[-1]['avg_acc']:.3f}; max posterior deviation {dev:.2e}; "
        f"modeled window wire bytes {model['f32']:.0f} -> {model['bf16']:.0f} "
        f"({model['f32'] / model['bf16']:.0f}x fewer)."
    )

    # -- chaos: agent churn + payload corruption under quarantine -----------
    chaos_spec = dataclasses.replace(
        SPEC,
        topology=TopologySpec.gossip(
            "bidirectional_ring", {"n": N_AGENTS},
            clock=dict(
                UNRELIABLE_CLOCK,
                faults={"crash_rate": 0.15, "recover_rate": 0.5,
                        "corrupt_rate": 0.2, "corrupt_kind": "mix",
                        "seed": 7},
            ),
        ),
        inference=dataclasses.replace(SPEC.inference,
                                      fault_policy="quarantine"),
    )
    chaotic = build_session(chaos_spec)
    c_hist = chaotic.run(eval_fn=lambda s: s.evaluate())
    c_tel = chaotic.evaluate()["engine"]
    faults = c_tel["faults"]
    health = chaotic.health()
    n_crashed = sum(rec.get("n_crashed", 0) for rec in c_hist)
    print(
        f"Chaos run (15% crash / 50% recover churn, 20% payload "
        f"corruption, quarantine defense): avg_acc "
        f"{c_hist[-1]['avg_acc']:.3f} vs undisturbed "
        f"{hist[-1]['avg_acc']:.3f};\n"
        f"  {n_crashed} crashed agent-windows "
        f"(mean uptime {faults['uptime']['frac_mean']:.2f}, "
        f"least-up agent {faults['uptime']['min']}/{c_tel['windows']} "
        f"windows), "
        f"{faults['quarantined']['total']} contributions quarantined "
        f"(per agent: {faults['quarantined']['per_agent']});\n"
        f"  healthy posteriors {health['n_healthy']}/{N_AGENTS} — the "
        f"injected NaN/Inf garbage never reached a resident posterior."
    )

    # -- small-world gossip: Watts-Strogatz base instead of the ring --------
    ws_spec = dataclasses.replace(
        SPEC,
        topology=TopologySpec.gossip(
            "watts_strogatz",
            {"n": N_AGENTS, "k": 4, "beta": 0.3, "seed": 0},
            clock=UNRELIABLE_CLOCK,
        ),
    )
    ws = build_session(ws_spec)
    ws_hist = ws.run(eval_fn=lambda s: s.evaluate())
    print(
        f"Watts-Strogatz base (k=4, beta=0.3 — ring + shortcut rewires): "
        f"avg_acc {ws_hist[-1]['avg_acc']:.3f} vs ring "
        f"{hist[-1]['avg_acc']:.3f}; shortcuts shrink the gossip mixing "
        f"diameter the label-partitioned data has to cross."
    )

    # -- the same generator at population scale: no [N, N], ever ------------
    # above ~10^3 agents the dense W is the bottleneck (N=1e5 would be a
    # 40 GB matrix).  TopologySpec.sparse keeps the topology as CSR edge
    # arrays end to end: validation, consensus, and the gossip windows all
    # run on [E]-shaped buffers (see BENCH_gossip.json "sparse_scale").
    import jax.numpy as jnp

    from repro.core.flat import FlatLayout, FlatPosterior, consensus_flat_segments

    big = TopologySpec.sparse("watts_strogatz", n=10_000, k=6, beta=0.1, seed=0)
    big.validate()  # row-stochasticity + strong connectivity, all on CSR
    g = big.sparse_graph()
    dst, src, w = g.edge_arrays()
    layout = FlatLayout.for_pytree({"w": jnp.zeros((8,))})
    posts = FlatPosterior(
        mean=jnp.zeros((g.n_agents, 8)),
        rho=jnp.ones((g.n_agents, 8)),
        layout=layout,
    )
    merged = consensus_flat_segments(
        posts, jnp.asarray(dst), jnp.asarray(src), jnp.asarray(w)
    )
    print(
        f"Population scale: one eq.-(6) consensus round over "
        f"N={g.n_agents} agents / E={g.n_edges} directed edges via "
        f"segment-sum — peak graph memory {g.indices.nbytes + g.weights.nbytes + g.indptr.nbytes:,} "
        f"bytes (O(E); the dense W would be {8 * g.n_agents**2:,}), "
        f"output finite: {bool(jnp.isfinite(merged.mean).all())}."
    )


if __name__ == "__main__":
    main()
