"""Asynchronous gossip quickstart: Poisson clocks + 10% link failures.

Eight agents on a bidirectional ring learn a synthetic classification task
with NO global synchronization: every directed link carries its own Poisson
activation clock, and each fired link additionally FAILS with probability
0.1 (dropped message).  Time is discretized into event windows
(``repro.gossip.clocks``); each window executes as one jitted program —
local Bayes-by-Backprop steps, then the masked active-edge consensus in
which idle agents pass through bit-untouched.

Everything is the same declarative spec as the synchronous runs — only the
``TopologySpec`` changes — and ``Session.evaluate`` now also reports
per-agent staleness percentiles (windows since last merge).

    PYTHONPATH=src python examples/async_gossip.py
"""
from repro.api import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    RunSpec,
    TopologySpec,
    build_session,
)

N_AGENTS = 8

SPEC = ExperimentSpec(
    # ring base graph; Poisson link clocks (rate 0.8 firings/window) with
    # 10% of fired messages dropped — the unreliable-network scenario
    topology=TopologySpec.gossip(
        "bidirectional_ring",
        {"n": N_AGENTS},
        clock={
            "kind": "failure_injected",
            "inner": {"kind": "poisson", "rate": 0.8, "seed": 0},
            "drop_rate": 0.1,
        },
    ),
    data=DataSpec(
        dataset_params=dict(n_classes=4, dim=32, n_train_per_class=120),
        # non-IID: each pair of ring neighbors holds ONE label; only gossip
        # spreads the other three around the ring
        partition="by_label",
        partition_params=dict(label_sets=[[c] for c in range(4) for _ in range(2)]),
        batch_size=16,
        local_updates=4,
    ),
    inference=InferenceSpec(hidden=32, depth=1, lr=5e-3, kl_scale=1e-3),
    run=RunSpec(n_rounds=30, seed=0, eval_every=10),
)


def main():
    session = build_session(SPEC)  # validates the activation union eagerly
    hist = session.run(eval_fn=lambda s: s.evaluate())
    for rec in hist:
        st = rec["staleness"]
        print(
            f"window {rec['round']:3d}  loss {rec['loss']:7.3f}  "
            f"avg_acc {rec['avg_acc']:.3f}  "
            f"staleness p50/p90/max {st['p50']:.0f}/{st['p90']:.0f}/{st['max']}"
        )
    tel = session.evaluate()
    print(
        f"\n{tel['windows']} event windows, "
        f"{tel['merges']['total']} merges "
        f"({tel['merges']['per_agent_mean']:.1f}/agent, "
        f"min {tel['merges']['min']}); one jitted call per window "
        f"(traced {session.engine.n_traces}x).\n"
        "Despite asynchronous, unreliable links every agent classifies all "
        "labels — the paper's consensus claim survives the gossip regime."
    )


if __name__ == "__main__":
    main()
