"""Serving example: batched prefill + decode against a KV cache with the
production serve steps (the same functions the decode_32k / long_500k
dry-runs lower), on a CPU-reduced qwen3-8b.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.steps import make_agent_cache, make_decode_step, make_prefill_step
from repro.models import init_params


def main():
    cfg = get_config("qwen3-8b").reduced()
    a, b = 1, 8  # one model replica, 8 concurrent requests
    prompt_len, gen = 48, 24
    key = jax.random.key(0)
    params = jax.vmap(lambda k: init_params(cfg, k))(jax.random.split(key, a))
    params = jax.tree.map(
        lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p, params
    )
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    cache = make_agent_cache(cfg, a, b, capacity=prompt_len + gen)

    prompts = jax.random.randint(jax.random.key(1), (a, b, prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts}, cache)
    tok = jnp.argmax(logits[..., -1, : cfg.vocab_size], -1).astype(jnp.int32)
    print(f"prefill: {b} x {prompt_len} tokens in {time.time() - t0:.2f}s")

    outs = [tok]
    t0 = time.time()
    for i in range(gen - 1):
        logits, cache = decode(params, tok[..., None],
                               jnp.asarray(prompt_len + i, jnp.int32), cache)
        tok = jnp.argmax(logits[..., -1, : cfg.vocab_size], -1).astype(jnp.int32)
        outs.append(tok)
    dt = time.time() - t0
    print(f"decode: {gen - 1} steps x {b} requests in {dt:.2f}s "
          f"= {b * (gen - 1) / dt:.1f} tok/s (CPU, reduced config)")
    gen_ids = jnp.stack(outs, -1)
    print("request 0 generated ids:", gen_ids[0, 0].tolist())


if __name__ == "__main__":
    main()
