"""Serving quickstart: snapshot-isolated batched MC-predictive inference.

The supported serving path end to end (``repro.serve``, ROADMAP
"Serving"): train a small gossip network, publish the consensus posterior
into an immutable double-buffered snapshot (optionally bf16-resident —
half the serving HBM), attach a ``PredictiveServer``, and stream ragged
request batches through its compiled-once padding-bucket apply cache under
a bounded-staleness SLO.

Runs headlessly on CPU in well under a minute:

    PYTHONPATH=src python examples/serve_batched.py

Expected output (losses/timings vary with the platform; the structure and
every count do not):

    trained 6 windows, final loss <float>
    snapshot: window=6 dtype=bf16 bytes=1188 telemetry={'window': 6, ...}
    served 12 ragged requests through 12 bucket slabs -> 2 traces (one per bucket)
    point estimate (L=0) probs row sums: [1.0, 1.0, 1.0, 1.0, 1.0]
    after 3 more windows: snapshot_age=3 slo_ok=False
    after republish: snapshot_age=0 slo_ok=True
    evaluate() serving block: published=2 slo_breaches=1
    === session dashboard ... ===       (observability summary: loop/gossip/
    ...                                  serving counters + warm/compile
                                         span table; ObsSpec is enabled in
                                         the spec below as a pure observer)
"""
import numpy as np

from repro.api import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    ObsSpec,
    RunSpec,
    ServeSpec,
    TopologySpec,
    build_session,
)


def main():
    n_agents = 3
    spec = ExperimentSpec(
        topology=TopologySpec.gossip("ring", {"n": n_agents}),
        data=DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=40),
            partition_params=dict(n_agents=n_agents),
            batch_size=4,
            local_updates=2,
        ),
        inference=InferenceSpec(hidden=8, depth=1, lr=1e-2),
        run=RunSpec(n_rounds=6, seed=0),
        serve=ServeSpec(
            snapshot_dtype="bf16",   # half the serving HBM, fp32 decode
            mc_samples=8,            # paper Sec 4.2 ensemble size L
            bucket_sizes=(4, 16),    # the compiled padding buckets
            max_staleness=2,         # SLO: refuse/flag >2-window-old answers
            staleness_policy="flag",
        ),
        # pure observer: request spans + serve counters land in the
        # registry, and the dashboard below reads them — the trained
        # posteriors are bitwise what they'd be without it
        obs=ObsSpec(enabled=True),
    )
    sess = build_session(spec)
    hist = sess.run(eval_every=spec.run.n_rounds)  # history: final round only
    print(f"trained {spec.run.n_rounds} windows, "
          f"final loss {hist[-1]['loss']:.3f}")

    # publish the serving copy: an immutable, decoupled, bf16-resident
    # snapshot — training keeps mutating its own buffers untouched
    snap = sess.snapshot()
    print(f"snapshot: window={snap.window} dtype={snap.dtype} "
          f"bytes={snap.nbytes()} telemetry={snap.telemetry}")

    server = sess.attach_server()
    rng = np.random.default_rng(0)
    x_test = np.asarray(sess.data.x_test)

    # a ragged stream: request sizes 1..9 all route through the two
    # compiled buckets (4 and 16) — watch the trace count stay put
    for i in range(12):
        n = int(rng.integers(1, 10))
        rows = x_test[rng.integers(0, x_test.shape[0], size=n)]
        probs, meta = server.query(rows, agent=i % n_agents)
        assert np.allclose(np.asarray(probs).sum(-1), 1.0, atol=1e-5)
    print(f"served 12 ragged requests through {server.n_batches} bucket "
          f"slabs -> {server.n_traces} traces (one per bucket)")

    # the L=0 point estimate: one softmax at the posterior mean
    probs0, _ = server.query(x_test[:5], mc_samples=0)
    print(f"point estimate (L=0) probs row sums: "
          f"{np.asarray(probs0).sum(-1).round(4).tolist()}")

    # age the snapshot past the SLO: policy="flag" keeps serving but marks
    # the answer (policy="strict" would raise serve.StalenessSLOError)
    sess.run(n_rounds=3)
    _, meta = server.query(x_test[:2])
    print(f"after 3 more windows: snapshot_age={meta['snapshot_age']} "
          f"slo_ok={meta['slo_ok']}")

    # republish -> back inside the SLO
    sess.snapshot()
    _, meta = server.query(x_test[:2])
    print(f"after republish: snapshot_age={meta['snapshot_age']} "
          f"slo_ok={meta['slo_ok']}")

    serving = sess.evaluate(n_mc=2)["serving"]
    print(f"evaluate() serving block: published={serving['published']} "
          f"slo_breaches={serving['slo']['breaches']}")

    # the same numbers from the metrics registry, as a terminal summary:
    # loop counters, gossip staleness, serving state, and the span table
    print()
    print(sess.dashboard())


if __name__ == "__main__":
    main()
