"""Quickstart: decentralized Bayesian learning in ONE declarative spec.

Four agents, a star network, non-IID label partition of a synthetic
classification task.  Each round every agent runs a few Bayes-by-Backprop
steps on its LOCAL data, then precision-averages posteriors with its
neighbors (eq. 6).  Watch the edge agents learn labels they have NEVER
seen.

The whole experiment is the ~15-line ``ExperimentSpec`` below —
``build_session`` validates it eagerly (connectivity, row-stochasticity,
agent counts) and returns an engine-backed ``Session``; swap
``RunSpec(engine="launch")`` to run the identical experiment on the
production ``launch.steps`` path, or change ``TopologySpec`` to move the
same run onto any other graph.

To watch a run instead of just reading its result, attach the
observability layer — ``ExperimentSpec(obs=ObsSpec(enabled=True))`` gives
``session.obs`` (metrics registry, wall-clock spans, live convergence
tracking vs theory) and ``session.dashboard()``; the pure-observer
contract keeps the trajectory bitwise identical.  The
``convergence_demo`` below overlays a measured disagreement decay against
the ring's spectral prediction in ~15 lines.

Next steps: ``examples/async_gossip.py`` (event-driven asynchronous
runtime) and ``examples/serve_batched.py`` (the serving quickstart —
publish a posterior snapshot and serve batched MC-predictive traffic
under a staleness SLO).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    ObsSpec,
    RunSpec,
    TopologySpec,
    build_session,
)
from repro.core.theory import stationary_distribution

SPEC = ExperimentSpec(
    # star: agent 0 (center) holds labels {1,2,3}; 3 edge agents share label 0
    topology=TopologySpec.star(n_edge=3, a=0.5),
    data=DataSpec(
        dataset_params=dict(n_classes=4, dim=32, n_train_per_class=150),
        partition="star",
        partition_params=dict(center_labels=[1, 2, 3], edge_labels=[0], n_edge=3),
        batch_size=16,
        local_updates=4,
    ),
    inference=InferenceSpec(hidden=32, depth=1, lr=5e-3, kl_scale=1e-3),
    run=RunSpec(n_rounds=20, seed=0, eval_every=5),
)


def convergence_demo():
    """Theory-vs-measured in ~15 lines: on a static ring with lr=0 and
    per-agent inits, consensus is a plain W-average, so disagreement must
    decay at the spectral rate -log lambda_max(W) — watch it happen."""
    spec = ExperimentSpec(
        topology=TopologySpec(kind="bidirectional_ring", params={"n": 4}),
        data=DataSpec(dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
                      partition="iid", partition_params=dict(n_agents=4),
                      batch_size=4, local_updates=1),
        inference=InferenceSpec(hidden=8, depth=1, lr=0.0, shared_init=False),
        run=RunSpec(n_rounds=10, seed=0),
        obs=ObsSpec(enabled=True),
    )
    session = build_session(spec)
    session.run()
    report = session.obs.convergence.report()
    for row in report["overlay"]:
        print(f"  round {row['round']:2d}  measured {row['measured']:.3e}  "
              f"predicted {row['predicted']:.3e}")
    print(f"  measured rate {report['measured_rate']:.4f} vs theory "
          f"{report['theory_rate']:.4f} -> attainment "
          f"{report['rate_attainment']:.2f}")


def main():
    session = build_session(SPEC)
    W = SPEC.topology.w_schedule()(0)
    print("eigenvector centrality:", np.round(stationary_distribution(W), 3))

    hist = session.run(eval_fn=lambda s: s.evaluate())
    for rec in hist:
        accs = ", ".join(f"{a:.2f}" for a in rec["acc"])
        print(f"round {rec['round']:3d}  loss {rec['loss']:7.3f}  per-agent acc [{accs}]")
    final = hist[-1]["avg_acc"]
    print(f"\nfinal average accuracy {final:.3f} — edge agents classify labels "
          "1-3 they never observed locally (the paper's central claim).")

    print("\nconvergence overlay (lr=0 ring: measured decay vs Theorem-1 "
          "spectral rate):")
    convergence_demo()


if __name__ == "__main__":
    main()
