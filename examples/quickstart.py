"""Quickstart: decentralized Bayesian learning in ~60 lines.

Four agents, a star network, non-IID label partition of a synthetic
classification task.  Each round every agent runs a few Bayes-by-Backprop
steps on its LOCAL data, then precision-averages posteriors with its
neighbors (eq. 6).  Watch the edge agents learn labels they have NEVER
seen.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graphs import star_w
from repro.core.simulated import init_network, make_round_fn, run_rounds
from repro.core.theory import stationary_distribution
from repro.data.partition import star_partition
from repro.data.pipeline import AgentDataset, make_round_batches
from repro.data.synthetic import make_synthetic_classification
from repro.optim import adam
from repro.optim.schedules import exponential_decay
from repro.vi.bayes_by_backprop import mc_predict


def mlp_init(key, dim=32, hidden=32, classes=4):
    ks = jax.random.split(key, 2)
    return {
        "w1": jax.random.normal(ks[0], (dim, hidden)) / np.sqrt(dim),
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(ks[1], (hidden, classes)) / np.sqrt(hidden),
        "b2": jnp.zeros((classes,)),
    }


def logits_fn(theta, x):
    return jax.nn.relu(x @ theta["w1"] + theta["b1"]) @ theta["w2"] + theta["b2"]


def nll_fn(theta, batch):
    lg = logits_fn(theta, batch["x"])
    logz = jax.nn.logsumexp(lg, -1)
    gold = jnp.take_along_axis(lg, batch["y"][..., None], -1)[..., 0]
    return jnp.sum(logz - gold)


def main():
    ds = make_synthetic_classification(n_classes=4, dim=32, n_train_per_class=150)
    # star: agent 0 (center) holds labels {1,2,3}; 3 edge agents share label 0
    shards = star_partition(ds.x_train, ds.y_train, [1, 2, 3], [0], n_edge=3)
    data = AgentDataset.from_shards(shards)
    W = star_w(3, a=0.5)
    print("eigenvector centrality:", np.round(stationary_distribution(W), 3))

    opt = adam()
    round_fn = make_round_fn(nll_fn, opt, exponential_decay(5e-3, 0.99),
                             kl_scale=1e-3)
    state = init_network(jax.random.key(0), 4, mlp_init, opt)
    sampler = make_round_batches(data, batch_size=16, n_local_updates=4)

    def evaluate(state):
        accs = []
        for i in range(4):
            post = jax.tree.map(lambda l: l[i], state.posterior)
            probs = mc_predict(post, logits_fn, jnp.asarray(ds.x_test),
                               jax.random.key(1), n_mc=4)
            accs.append(float((np.argmax(np.asarray(probs), -1) == ds.y_test).mean()))
        return {"acc": accs}

    state, hist = run_rounds(round_fn, state, sampler, np.asarray(W), 20,
                             jax.random.key(2), eval_fn=evaluate, eval_every=5)
    for rec in hist:
        accs = ", ".join(f"{a:.2f}" for a in rec["acc"])
        print(f"round {rec['round']:3d}  loss {rec['loss']:7.3f}  per-agent acc [{accs}]")
    final = np.mean(hist[-1]["acc"])
    print(f"\nfinal average accuracy {final:.3f} — edge agents classify labels "
          "1-3 they never observed locally (the paper's central claim).")


if __name__ == "__main__":
    main()
