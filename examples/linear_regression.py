"""Paper Example 1 / Fig 1 exactly: decentralized Bayesian linear regression
with theta* = [-0.3, 0.5, 0.5, 0.1, 0.2], noise 0.8, each of the 4 agents
observing only ONE input coordinate (extreme non-IID), using the paper's own
social-interaction matrix from supplementary 1.3.

    PYTHONPATH=src python examples/linear_regression.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.graphs import check_w
from repro.core.posterior import (
    FullCovGaussian,
    consensus_full_cov,
    linreg_bayes_update,
)
from repro.core.theory import lambda_max, stationary_distribution
from repro.data.linreg import make_linreg_task

# supplementary 1.3 weights (4 agents)
W = np.array([
    [0.5, 0.5, 0.0, 0.0],
    [0.3, 0.1, 0.3, 0.3],
    [0.0, 0.5, 0.5, 0.0],
    [0.0, 0.5, 0.0, 0.5],
])


def main():
    check_w(W)
    print("centrality:", np.round(stationary_distribution(W), 3),
          " lambda_max:", round(lambda_max(W), 3))
    task = make_linreg_task()
    rng = np.random.default_rng(0)
    n, d = 4, task.d
    posts = FullCovGaussian(
        mean=jnp.zeros((n, d)),
        prec=jnp.broadcast_to(jnp.eye(d) / 0.5, (n, d, d)),
    )
    phi_t, y_t = task.sample_global(rng, 4000)
    for r in range(200):
        means, precs = [], []
        for i in range(n):
            phi, y = task.sample_local(rng, i, 10)
            p = linreg_bayes_update(
                FullCovGaussian(posts.mean[i], posts.prec[i]),
                jnp.asarray(phi), jnp.asarray(y), task.noise_std**2,
            )
            means.append(p.mean)
            precs.append(p.prec)
        posts = consensus_full_cov(
            FullCovGaussian(jnp.stack(means), jnp.stack(precs)), jnp.asarray(W)
        )
        if (r + 1) % 40 == 0:
            mses = [float(np.mean((phi_t @ np.asarray(posts.mean[i]) - y_t) ** 2))
                    for i in range(n)]
            print(f"round {r + 1:4d}  per-agent test MSE "
                  + " ".join(f"{m:.4f}" for m in mses)
                  + f"   (noise floor {task.noise_std**2:.3f})")
    print("\ntheta*      =", np.round(task.theta_star, 3))
    print("agent 0 mu  =", np.round(np.asarray(posts.mean[0]), 3))
    print("every agent recovered theta* despite observing a single coordinate.")


if __name__ == "__main__":
    main()
