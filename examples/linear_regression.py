"""Paper Example 1 / Fig 1 exactly: decentralized Bayesian linear regression
with theta* = [-0.3, 0.5, 0.5, 0.1, 0.2], noise 0.8, each of the 4 agents
observing only ONE input coordinate (extreme non-IID), using the paper's own
social-interaction matrix from supplementary 1.3 — declared as one
``ExperimentSpec`` with the exact-conjugate inference family
(``InferenceSpec(method="conjugate_linreg")``, full-covariance posteriors,
eq. 2 local updates + eq. 6 consensus).

    PYTHONPATH=src python examples/linear_regression.py
"""
import numpy as np

from repro.api import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    RunSpec,
    TopologySpec,
    build_session,
)
from repro.core.theory import lambda_max, stationary_distribution

# supplementary 1.3 weights (4 agents)
W = np.array([
    [0.5, 0.5, 0.0, 0.0],
    [0.3, 0.1, 0.3, 0.3],
    [0.0, 0.5, 0.5, 0.0],
    [0.0, 0.5, 0.0, 0.5],
])

SPEC = ExperimentSpec(
    topology=TopologySpec.explicit(W),
    data=DataSpec(dataset="linreg", batch_size=10),
    inference=InferenceSpec(method="conjugate_linreg", prior_var=0.5),
    run=RunSpec(n_rounds=200, seed=0),
)


def main():
    print("centrality:", np.round(stationary_distribution(W), 3),
          " lambda_max:", round(lambda_max(W), 3))
    session = build_session(SPEC)  # validates W (Assumption 1) eagerly
    task = session.data.dataset
    for _ in range(5):
        session.run(40)
        mses = session.evaluate()["mse"]
        print(f"round {session.round_idx:4d}  per-agent test MSE "
              + " ".join(f"{m:.4f}" for m in mses)
              + f"   (noise floor {task.noise_std**2:.3f})")
    posts = session.posterior()
    print("\ntheta*      =", np.round(task.theta_star, 3))
    print("agent 0 mu  =", np.round(np.asarray(posts.mean[0]), 3))
    print("every agent recovered theta* despite observing a single coordinate.")


if __name__ == "__main__":
    main()
