"""End-to-end driver: decentralized Bayesian training of a ~100M-parameter
decoder-only LM (repro-100m: 12L x 768d) for a few hundred rounds across 2
agents, using the SAME production step functions that the multi-pod dry-run
lowers for TPU.

On this CPU container the default invocation trains a width/depth-reduced
variant for speed; pass --full --rounds 300 on real hardware for the full
100M run (the step function is identical — only the config changes).

    PYTHONPATH=src python examples/train_decentralized_lm.py --rounds 30
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import REPRO_100M
from repro.core.graphs import bidirectional_ring_w, complete_w
from repro.data.pipeline import make_lm_batch_sampler
from repro.launch.steps import init_train_state, make_train_round_step
from repro.optim import adam
from repro.optim.schedules import warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--agents", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4, help="per-agent")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full 100M config (use on real hardware)")
    ap.add_argument("--topology", choices=["complete", "ring"], default="complete")
    args = ap.parse_args()

    cfg = REPRO_100M if args.full else dataclasses.replace(
        REPRO_100M, n_layers=4, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=1024, vocab_size=4096, name="repro-100m-cpu",
    )
    a = args.agents
    W = jnp.asarray(
        complete_w(a) if args.topology == "complete" else bidirectional_ring_w(a)
    )
    opt = adam()
    sched = warmup_cosine(3e-4, 20, args.rounds * 2)
    step = jax.jit(make_train_round_step(cfg, W, opt=opt, lr_schedule=sched,
                                         kl_scale=1e-5, remat=not args.full))
    key = jax.random.key(0)
    state = init_train_state(key, cfg, a, opt)
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(state.posterior.mean)) // a
    print(f"model {cfg.name}: {n:,} params/agent, {a} agents, W={args.topology}")

    sampler = make_lm_batch_sampler(cfg.vocab_size, args.batch, args.seq, n_agents=a)
    t0 = time.time()
    for r in range(args.rounds):
        key, k1, k2 = jax.random.split(key, 3)
        state, m = step(state, sampler(k1, r), k2)
        if (r + 1) % 5 == 0 or r == 0:
            nll = float(jnp.mean(m["nll"]))
            kl = float(jnp.mean(m["kl"]))
            print(f"round {r + 1:4d}  nll/token {nll:7.4f}  KL {kl:10.1f}  "
                  f"({time.time() - t0:5.1f}s)", flush=True)
    nll_final = float(jnp.mean(m["nll"]))
    print(f"\nuniform-prediction nll = {np.log(cfg.vocab_size):.3f}; the token "
          f"stream is Zipfian (entropy below that); reached {nll_final:.3f} "
          "with fully decentralized Bayesian training.")


if __name__ == "__main__":
    main()
