"""Sparse-first topology layer: CSR graphs, generators, iterative
strong-connectivity, segment-sum consensus, thinned-Poisson clocks, and
the ``TopologySpec(kind="sparse")`` surface.

The dense [N, N] path stays the reference everywhere: sparse builders are
pinned BITWISE to their dense counterparts, the segment-sum consensus to
the dense flat reference (fp32 reduction-order tolerance), and the
iterative Kosaraju check to ``networkx.is_strongly_connected``.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    RunSpec,
    build_session,
)
from repro.api.spec import SPARSE_DENSE_GUARD, TopologySpec
from repro.core.flat import (
    FlatLayout,
    FlatPosterior,
    consensus_flat_reference,
    consensus_flat_segments,
    neighbor_tables,
)
from repro.core.graphs import (
    SPARSE_GENERATORS,
    SparseGraph,
    barabasi_albert_sparse,
    bidirectional_ring_sparse,
    bidirectional_ring_w,
    build_sparse,
    complete_w,
    erdos_w,
    grid_sparse,
    grid_w,
    max_in_degree,
    neighbor_lists,
    ring_sparse,
    ring_w,
    star_sparse,
    star_w,
    strongly_connected_csr,
    torus_sparse,
    torus_w,
    watts_strogatz_sparse,
)
from repro.gossip.clocks import (
    PoissonClock,
    SparseAllEdgesClock,
    SparseFailureInjectedClock,
    SparsePoissonClock,
    SparseWindow,
    build_sparse_clock,
    thinned_poisson_indices,
)


def _posts(n: int, p: int, seed: int = 0) -> FlatPosterior:
    ks = jax.random.split(jax.random.key(seed), 2)
    layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
    return FlatPosterior(
        mean=jax.random.normal(ks[0], (n, p)),
        rho=jax.random.normal(ks[1], (n, p)) * 0.4 - 1.0,
        layout=layout,
    )

# every named dense builder the API exposes, with small-but-nontrivial
# parameters — the neighbor-extraction consistency sweep runs over ALL of
# them (satellite: one CSR construction behind every extraction helper)
NAMED_DENSE = {
    "star": star_w(5, 0.3),
    "grid": grid_w(3, 4),
    "ring": ring_w(7),
    "bidirectional_ring": bidirectional_ring_w(8),
    "torus": torus_w(3, 4),
    "complete": complete_w(6),
    "erdos": erdos_w(12, 0.5, seed=3),
    "watts_strogatz": watts_strogatz_sparse(20, k=4, beta=0.2, seed=1).to_dense(),
    "barabasi_albert": barabasi_albert_sparse(20, m=2, seed=1).to_dense(),
}


# -- sparse builders vs dense counterparts (bitwise) -------------------------


@pytest.mark.parametrize("sparse_g,dense_w", [
    (ring_sparse(7), ring_w(7)),
    (bidirectional_ring_sparse(8), bidirectional_ring_w(8)),
    (grid_sparse(3, 4), grid_w(3, 4)),
    (torus_sparse(3, 4), torus_w(3, 4)),
    (star_sparse(5, 0.3), star_w(5, 0.3)),
], ids=["ring", "bidirectional_ring", "grid", "torus", "star"])
def test_sparse_builder_matches_dense_bitwise(sparse_g, dense_w):
    # the sparse builders never allocate [N, N]; their densification must
    # still reproduce the seed dense builders EXACTLY (same weight arithmetic)
    assert np.array_equal(sparse_g.to_dense(), dense_w)
    sparse_g.validate()


def test_from_dense_round_trip():
    W = erdos_w(15, 0.4, seed=7)
    g = SparseGraph.from_dense(W)
    assert np.array_equal(g.to_dense(), W)
    assert g.n_edges == int(np.count_nonzero(W))
    g.validate()


def test_generator_registry_and_build_sparse():
    for name in ("ring", "bidirectional_ring", "grid", "torus", "star",
                 "watts_strogatz", "barabasi_albert"):
        assert name in SPARSE_GENERATORS
    g = build_sparse("watts_strogatz", n=40, k=4, beta=0.1, seed=2)
    assert g.n_agents == 40
    g.validate()
    with pytest.raises(ValueError, match="unknown sparse generator"):
        build_sparse("moebius", n=4)


def test_small_world_generators_are_valid_and_deterministic():
    for mk in (lambda s: watts_strogatz_sparse(60, k=6, beta=0.3, seed=s),
               lambda s: barabasi_albert_sparse(60, m=3, seed=s)):
        a, b = mk(4), mk(4)
        assert np.array_equal(a.indices, b.indices)
        assert np.array_equal(a.weights, b.weights)
        a.validate()  # row-stochastic + self-loops + strongly connected
        assert not np.array_equal(a.indices, mk(5).indices) or \
            not np.array_equal(a.weights, mk(5).weights)


# -- iterative strong connectivity vs networkx -------------------------------


def _random_support(rng, n, p):
    A = rng.random((n, n)) < p
    np.fill_diagonal(A, True)
    indptr = np.zeros(n + 1, np.int64)
    indptr[1:] = np.cumsum(A.sum(1))
    indices = np.concatenate([np.nonzero(A[i])[0] for i in range(n)])
    return A, indptr, indices.astype(np.int32)


def test_strong_connectivity_matches_networkx_seeded():
    nx = pytest.importorskip("networkx")
    rng = np.random.default_rng(0)
    agree_true = agree_false = 0
    for _ in range(60):
        n = int(rng.integers(2, 25))
        p = float(rng.uniform(0.02, 0.4))
        A, indptr, indices = _random_support(rng, n, p)
        got = strongly_connected_csr(indptr, indices, n)
        ref = nx.is_strongly_connected(nx.from_numpy_array(
            A.astype(float), create_using=nx.DiGraph))
        assert got == ref
        agree_true += ref
        agree_false += not ref
    # the sweep must exercise BOTH verdicts, else it proves nothing
    assert agree_true > 0 and agree_false > 0


def test_strong_connectivity_property_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    nx = pytest.importorskip("networkx")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(2, 20),
           st.floats(0.02, 0.5))
    def prop(seed, n, p):
        A, indptr, indices = _random_support(
            np.random.default_rng(seed), n, p)
        assert strongly_connected_csr(indptr, indices, n) == \
            nx.is_strongly_connected(nx.from_numpy_array(
                A.astype(float), create_using=nx.DiGraph))

    prop()


def test_strong_connectivity_edge_cases():
    assert strongly_connected_csr(np.array([0, 1]), np.array([0]), 1)
    # two nodes, no cross edges: disconnected
    indptr = np.array([0, 1, 2])
    indices = np.array([0, 1], np.int32)
    assert not strongly_connected_csr(indptr, indices, 2)
    # directed ring IS strongly connected; drop one edge and it is not
    g = ring_sparse(30)
    assert g.strongly_connected()


# -- one CSR construction behind every neighbor extraction -------------------


@pytest.mark.parametrize("name", sorted(NAMED_DENSE))
def test_neighbor_extraction_consistency(name):
    """neighbor_lists / max_in_degree / neighbor_tables must all agree
    with the single SparseGraph.from_dense construction on every named
    topology (the satellite dedupe: no per-helper nonzero scans left)."""
    W = NAMED_DENSE[name]
    g = SparseGraph.from_dense(W)
    lists = neighbor_lists(W)
    assert lists == [list(g.row(i)[0]) for i in range(g.n_agents)]
    assert max_in_degree(W) == g.max_in_degree
    nbrs, wts = neighbor_tables(W)
    g_nbrs, g_wts = g.neighbor_tables()
    assert np.array_equal(nbrs, g_nbrs) and np.array_equal(wts, g_wts)
    # tables are self-padded with zero weight; real entries match W rows
    for i in range(g.n_agents):
        row_idx, row_w = g.row(i)
        deg = row_idx.size
        assert np.array_equal(nbrs[i, :deg], row_idx)
        np.testing.assert_allclose(wts[i, :deg], row_w, rtol=0, atol=1e-7)
        assert np.all(nbrs[i, deg:] == i) and np.all(wts[i, deg:] == 0.0)


# -- segment-sum consensus vs the dense flat reference -----------------------


@pytest.mark.parametrize("wire", ["f32", "bf16", "f16"])
def test_segments_matches_dense_reference_per_wire(wire):
    n, p = 18, 96
    g = watts_strogatz_sparse(n, k=4, beta=0.3, seed=9)
    posts = _posts(n, p, seed=2)
    dst, src, w = g.edge_arrays()
    got = consensus_flat_segments(
        posts, jnp.asarray(dst), jnp.asarray(src), jnp.asarray(w),
        wire_dtype=wire)
    ref_mean, ref_rho = consensus_flat_reference(
        posts.mean, posts.rho, jnp.asarray(g.to_dense(), jnp.float32),
        wire_dtype=wire)
    # same op chain, different reduction order (edge-order scatter vs
    # column-order matmul): fp32 tolerance, not bitwise
    assert float(jnp.max(jnp.abs(got.mean - ref_mean))) <= 1e-4
    assert float(jnp.max(jnp.abs(got.rho - ref_rho))) <= 1e-4


def test_segments_active_mask_passthrough_bitwise():
    n, p = 12, 33
    g = bidirectional_ring_sparse(n)
    posts = _posts(n, p, seed=5)
    dst, src, w = g.edge_arrays()
    active = np.zeros(n, bool)
    active[[2, 3, 7]] = True
    out = consensus_flat_segments(
        posts, jnp.asarray(dst), jnp.asarray(src), jnp.asarray(w),
        active=jnp.asarray(active))
    # inactive rows pass through BITWISE — the gossip conserve rule
    # depends on exact passthrough, not approximate
    inact = ~active
    assert bool(jnp.all(out.mean[inact] == posts.mean[inact]))
    assert bool(jnp.all(out.rho[inact] == posts.rho[inact]))
    assert not bool(jnp.all(out.mean[active] == posts.mean[active]))


def test_segments_blocked_matches_single_call():
    n, p = 10, 96
    g = torus_sparse(2, 5)
    posts = _posts(n, p, seed=11)
    dst, src, w = g.edge_arrays()
    args = (posts, jnp.asarray(dst), jnp.asarray(src), jnp.asarray(w))
    whole = consensus_flat_segments(*args)
    blocked = consensus_flat_segments(*args, block=32)
    # the param-axis loop changes nothing about per-column arithmetic
    assert bool(jnp.all(whole.mean == blocked.mean))
    assert bool(jnp.all(whole.rho == blocked.rho))


# -- thinned-Poisson clocks --------------------------------------------------


def test_thinned_poisson_pure_function_of_seed_round():
    n_edges, mu = 5000, 0.03
    for r in range(4):
        a = thinned_poisson_indices(np.random.default_rng([7, r]), n_edges, mu)
        b = thinned_poisson_indices(np.random.default_rng([7, r]), n_edges, mu)
        assert np.array_equal(a, b), "same (seed, round) must be bitwise"
        assert a.size == np.unique(a).size and np.all(np.diff(a) > 0)
        assert a.size == 0 or (a.min() >= 0 and a.max() < n_edges)
    r0 = thinned_poisson_indices(np.random.default_rng([7, 0]), n_edges, mu)
    r1 = thinned_poisson_indices(np.random.default_rng([7, 1]), n_edges, mu)
    assert not np.array_equal(r0, r1), "distinct rounds must differ"


def test_thinned_poisson_marginal_rate():
    # per-edge firing probability under thinning is 1 - exp(-mu); check
    # the empirical mean over many windows (law of large numbers, wide tol)
    n_edges, mu, windows = 400, 0.5, 400
    hits = 0
    for r in range(windows):
        hits += thinned_poisson_indices(
            np.random.default_rng([13, r]), n_edges, mu).size
    p_emp = hits / (n_edges * windows)
    assert abs(p_emp - (1.0 - np.exp(-mu))) < 0.02


def test_poisson_clock_e_max_cap():
    W = bidirectional_ring_w(6)
    # a declared cap shrinks the static [E_max] window buffers the engine
    # jits over (default would be all 18 directed edges)
    c = PoissonClock(W, rate=0.5, seed=3, e_max=12)
    for r in range(5):
        win = c.window(r)
        assert win.edges.shape[0] == 12 and win.n_events <= 12
    # cap of 1 with a hot clock: some window must overflow and raise
    hot = PoissonClock(W, rate=50.0, seed=3, e_max=1)
    with pytest.raises(ValueError, match="e_max"):
        for r in range(20):
            hot.window(r)
    with pytest.raises(ValueError):
        PoissonClock(W, rate=0.5, seed=0, e_max=0)


# -- erdos_w rich failure ----------------------------------------------------


def test_erdos_w_unsatisfiable_raises_rich_error():
    with pytest.raises(RuntimeError) as ei:
        erdos_w(60, 0.001, seed=0, attempts=4)
    msg = str(ei.value)
    assert "n=60" in msg and "p=0.001" in msg and "4 attempts" in msg
    assert "log(n)/n" in msg  # the actionable threshold hint


def test_erdos_w_retries_until_connected():
    # p below a single-shot sure thing but workable within the budget:
    # the retry loop must land on a connected sample deterministically
    W = erdos_w(25, 0.25, seed=1, attempts=200)
    assert SparseGraph.from_dense(W).strongly_connected()


# -- TopologySpec(kind="sparse") ---------------------------------------------


def test_sparse_spec_validate_and_dense_bridge():
    spec = TopologySpec.sparse("watts_strogatz", n=50, k=4, beta=0.2, seed=1)
    spec.validate()
    assert spec.n_agents() == 50
    g = spec.sparse_graph()
    assert g is spec.sparse_graph()  # memoized: one construction
    W = spec.w_schedule()(0)
    assert np.array_equal(W, g.to_dense())


def test_sparse_spec_dense_guard():
    n = SPARSE_DENSE_GUARD + 1
    spec = TopologySpec.sparse("ring", n=n)
    assert spec.n_agents() == n  # metadata never materializes W
    with pytest.raises(ValueError, match="guard"):
        spec.w_schedule()


def test_sparse_spec_checkpoint_embeddable():
    spec = TopologySpec.sparse("barabasi_albert", n=30, m=2, seed=5)
    doc = json.loads(json.dumps(dataclasses.asdict(spec)))
    back = TopologySpec(**doc)
    back.validate()
    g0, g1 = spec.sparse_graph(), back.sparse_graph()
    assert np.array_equal(g0.indptr, g1.indptr)
    assert np.array_equal(g0.indices, g1.indices)
    assert np.array_equal(g0.weights, g1.weights)


def test_sparse_spec_unknown_generator():
    with pytest.raises(ValueError, match="generator"):
        TopologySpec.sparse("kleinberg", n=10).sparse_graph()


# -- edge-native sparse clocks (SparseWindow, no [N, N] anywhere) ------------


def _win_equal(a: SparseWindow, b: SparseWindow) -> bool:
    return (a.index == b.index and a.n_events == b.n_events
            and np.array_equal(a.dst, b.dst)
            and np.array_equal(a.src, b.src)
            and np.array_equal(a.weights, b.weights)
            and np.array_equal(a.self_weight, b.self_weight)
            and np.array_equal(a.active, b.active))


def test_sparse_clock_window_pure_function_of_seed_round():
    g = watts_strogatz_sparse(30, k=4, beta=0.3, seed=2)
    a = SparsePoissonClock(g, rate=0.7, seed=5)
    b = SparsePoissonClock(g, rate=0.7, seed=5)
    # out-of-order access defeats the one-slot memo: windows must still be
    # bitwise functions of (seed, round), never of call history
    for r in (0, 3, 1, 3, 0):
        assert _win_equal(a.window(r), b.window(r))
    assert not _win_equal(a.window(0), a.window(1))
    assert not _win_equal(
        a.window(2), SparsePoissonClock(g, rate=0.7, seed=6).window(2)
    )


def test_sparse_all_edges_window_self_weight_is_base_diagonal_bitwise():
    g = watts_strogatz_sparse(20, k=4, beta=0.2, seed=1)
    c = SparseAllEdgesClock(g)
    c.validate()
    win = c.window(0)
    W = g.to_dense()
    # the sparse ladder anchor: every non-self edge fires, so the conserve
    # self-weights equal the base diagonal EXACTLY and everyone is active
    assert win.n_events == c.n_edges
    assert np.array_equal(win.self_weight, np.diagonal(W))
    assert win.active.all() and win.max_lag == 0
    assert np.array_equal(win.w_eff != 0.0, W != 0.0)
    np.testing.assert_allclose(win.w_eff, W, rtol=0, atol=1e-7)
    # rows conserve: w_eff stays row-stochastic up to the f32 cast of the
    # off-diagonal wire weights (the f64 self-weights are exact)
    np.testing.assert_allclose(win.w_eff.sum(1), 1.0, rtol=0, atol=1e-6)


def test_sparse_failure_injected_drops_fired_edges():
    g = watts_strogatz_sparse(30, k=4, beta=0.2, seed=3)
    mk_inner = lambda: SparsePoissonClock(g, rate=2.0, seed=4)
    dropped = SparseFailureInjectedClock(mk_inner(), drop_rate=0.5, seed=9)
    again = SparseFailureInjectedClock(mk_inner(), drop_rate=0.5, seed=9)
    inner = mk_inner()
    strictly_fewer = False
    for r in range(6):
        wi, wd = inner.window(r), dropped.window(r)
        surv = set(zip(wd.dst[:wd.n_events], wd.src[:wd.n_events]))
        full = set(zip(wi.dst[:wi.n_events], wi.src[:wi.n_events]))
        assert surv <= full  # drops only remove events, never invent them
        strictly_fewer |= wd.n_events < wi.n_events
        assert _win_equal(wd, again.window(r))  # salted stream: bitwise
    assert strictly_fewer
    with pytest.raises(ValueError, match="drop_rate"):
        SparseFailureInjectedClock(mk_inner(), drop_rate=1.0)


def test_sparse_poisson_e_max_cap_and_overflow():
    g = bidirectional_ring_sparse(8)
    base = SparsePoissonClock(g, rate=1.0)
    assert base.e_max == base.n_edges  # default cap: every non-self edge
    small = SparsePoissonClock(g, rate=0.2, seed=3, e_max=4)
    for r in range(5):
        win = small.window(r)
        assert win.e_max == 4 and win.n_events <= 4
    hot = SparsePoissonClock(g, rate=60.0, seed=3, e_max=2)
    with pytest.raises(ValueError, match="e_max"):
        for r in range(20):
            hot.window(r)
    with pytest.raises(ValueError, match="e_max"):
        SparsePoissonClock(g, rate=1.0, e_max=0)
    with pytest.raises(ValueError, match="e_max"):
        SparsePoissonClock(g, rate=1.0, e_max=base.n_edges + 1)


def test_sparse_clock_faults_filter_crashed_agents():
    g = watts_strogatz_sparse(24, k=4, beta=0.2, seed=5)
    doc = {"kind": "poisson", "rate": 3.0, "seed": 2,
           "faults": {"crash_rate": 0.3, "recover_rate": 0.5, "seed": 7}}
    c = build_sparse_clock(doc, g)
    saw_crash = False
    for r in range(8):
        win = c.window(r)
        down = c.crashed(r)
        saw_crash |= bool(down.any())
        # a fired edge never touches a crashed endpoint, and the conserve
        # rule keeps crashed rows idle (active False, self-weight 1.0)
        assert not down[win.dst[:win.n_events]].any()
        assert not down[win.src[:win.n_events]].any()
        assert not win.active[down].any()
        np.testing.assert_array_equal(win.self_weight[down], 1.0)
    assert saw_crash
    with pytest.raises(ValueError, match="OUTERMOST"):
        build_sparse_clock(
            {"kind": "failure_injected", "drop_rate": 0.2,
             "inner": {"kind": "poisson", "faults": {"crash_rate": 0.1}}}, g)
    with pytest.raises(ValueError, match="unknown sparse clock"):
        build_sparse_clock({"kind": "metronome"}, g)


def test_sparse_window_w_eff_refuses_above_guard():
    n = SPARSE_DENSE_GUARD + 1
    win = SparseWindow(
        index=0, dst=np.zeros(1, np.int32), src=np.zeros(1, np.int32),
        weights=np.zeros(1, np.float32), self_weight=np.ones(n),
        active=np.zeros(n, bool), n_agents=n, n_events=0,
    )
    with pytest.raises(ValueError, match="segments"):
        win.w_eff


# -- segments engine vs the dense masked engine (below the guard) ------------


def _clocked_spec(n, impl, wire="f32", n_rounds=2, **clock_extra):
    topo = TopologySpec.sparse(
        "watts_strogatz", n=n, k=4, beta=0.2, seed=1,
        clock={"kind": "poisson", "rate": 1.0, "seed": 3, **clock_extra},
    )
    return ExperimentSpec(
        topology=topo,
        data=DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
            partition="iid", partition_params=dict(n_agents=n),
            batch_size=4, local_updates=2,
        ),
        inference=InferenceSpec(
            hidden=8, depth=1, lr=1e-2,
            consensus_impl=impl, wire_dtype=wire,
        ),
        run=RunSpec(n_rounds=n_rounds, seed=0),
    )


@pytest.mark.parametrize("wire", ["f32", "bf16", "f16"])
def test_segments_engine_matches_masked_engine_per_wire(wire):
    """Below SPARSE_DENSE_GUARD the same SparseWindow executes two ways:
    edge-native segments, or densified (w_eff) through the masked engine.
    Both cast payloads to the wire dtype BEFORE reduction, so they sum the
    same quantized values — only edge-order vs column-order differs, which
    is fp32 reduction tolerance, not wire tolerance."""
    s_seg = build_session(_clocked_spec(16, "segments", wire=wire))
    s_msk = build_session(_clocked_spec(16, "masked", wire=wire))
    s_seg.run()
    s_msk.run()
    d_mean = np.max(np.abs(np.asarray(s_seg.posterior().mean)
                           - np.asarray(s_msk.posterior().mean)))
    d_rho = np.max(np.abs(np.asarray(s_seg.posterior().rho)
                          - np.asarray(s_msk.posterior().rho)))
    assert d_mean <= 1e-4 and d_rho <= 1e-4
    assert np.array_equal(np.asarray(s_seg.state.n_merges),
                          np.asarray(s_msk.state.n_merges))


def test_sparse_clock_spec_auto_selects_segments():
    spec = _clocked_spec(12, "auto", n_rounds=1)
    spec.validate()
    s = build_session(spec)
    assert s.engine.consensus_impl == "segments"
    s.run()
    assert int(s.state.round) == 1


def test_sparse_spec_clock_validation_and_errors():
    spec = _clocked_spec(12, "segments")
    dataclasses.replace(spec, run=RunSpec(n_rounds=2, seed=0,
                                          engine="gossip")).validate()
    # segments needs edge-native windows: dense gossip clocks emit [N, N]
    dense = TopologySpec.gossip(
        "bidirectional_ring", base_params={"n": 8},
        clock={"kind": "poisson", "rate": 1.0})
    with pytest.raises(ValueError, match="edge-native"):
        dataclasses.replace(spec, topology=dense).validate()
    with pytest.raises(ValueError, match="mean_only"):
        dataclasses.replace(
            spec, inference=dataclasses.replace(
                spec.inference, consensus="mean_only", wire_dtype="f32"),
        ).validate()
    # a clockless sparse topology is synchronous: no window execution to pick
    clockless = TopologySpec.sparse("watts_strogatz", n=12, k=4, beta=0.2,
                                    seed=1)
    with pytest.raises(ValueError, match="consensus_impl"):
        dataclasses.replace(spec, topology=clockless).validate()
    with pytest.raises(ValueError, match="no clock"):
        clockless.gossip_clock()
    # ppermute shards dense EventWindows; sparse clocks have none
    with pytest.raises(ValueError, match="EventWindows"):
        build_session(_clocked_spec(12, "ppermute"))


def test_sparse_clock_w_schedule_emits_sparse_windows():
    spec = _clocked_spec(10, "segments")
    sched = spec.topology.w_schedule()
    for r in (0, 2):
        win = sched(r)
        assert isinstance(win, SparseWindow) and win.index == r
        assert win.n_agents == 10
    clock = spec.topology.gossip_clock()
    assert clock is spec.topology.gossip_clock()  # memoized: one build
    assert _win_equal(sched(1), clock.window(1))
