"""Observability layer (ROADMAP "Observability"; ``repro.obs``).

Pins the layer's two-sided contract plus the unit behavior of each pillar:

* **Zero perturbation** — with ``ObsSpec`` unset nothing is recorded and
  nothing changes; with it enabled the TRAINING MATH is still bitwise
  identical (posteriors, trace counts) because every instrument observes
  already-materialized host values.
* **Namespaced telemetry** — ``evaluate()`` puts engine telemetry under
  ``out["engine"]``; a telemetry key can never clobber a metric key
  (regression for the pre-obs ``out.update(...)`` merge).
* Registry / exporter / tracer / convergence-tracker / roofline units,
  and the ``ObsSpec`` doc + checkpoint round trip.
"""
import dataclasses
import json
import math

import jax
import numpy as np
import pytest

from repro.core.numerics import softplus_inv
from repro.obs.convergence import ConvergenceTracker, network_stats
from repro.obs.metrics import (
    JsonlSink,
    MetricsRegistry,
    escape_label_value,
    sanitize_name,
)
from repro.obs.roofline import (
    attainment,
    consensus_attainment,
    window_attainment,
)
from repro.obs.trace import CompileWarmTimer, Tracer

# ---------------------------------------------------------------------------
# metrics registry


def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value() == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g")
    g.set(1.0)
    g.set(4.0)
    assert g.value() == 4.0
    h = reg.histogram("h", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 3 and s["min"] == 0.5 and s["max"] == 50.0
    assert s["sum"] == pytest.approx(55.5)
    assert reg.histogram("h").summary(mc="8") == {"count": 0}


def test_labels_are_independent_series():
    reg = MetricsRegistry()
    c = reg.counter("req")
    c.inc(1, mc="1")
    c.inc(5, mc="8")
    assert c.value(mc="1") == 1 and c.value(mc="8") == 5
    assert c.value() == 0  # unlabeled series untouched


def test_instrument_kind_collision_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    assert reg.counter("x") is reg.counter("x")  # idempotent
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x")


def test_ingest_flattens_telemetry_vocabulary():
    reg = MetricsRegistry()
    reg.ingest("engine", {
        "staleness": {"p50": 1.0, "p90": 3},
        "per_agent": [2, 4],
        "wire_dtype": "bf16",
        "ok": True,
        "skipped": None,
    })
    got = reg.collect()
    assert got["engine.staleness.p50"] == 1.0
    assert got["engine.staleness.p90"] == 3.0
    assert got["engine.per_agent.0"] == 2.0
    assert got["engine.per_agent.1"] == 4.0
    assert got["engine.ok"] == 1.0
    assert got["engine.wire_dtype"] == "bf16"  # info entry
    assert "engine.skipped" not in got


def test_prometheus_export_deterministic_and_sane():
    def build(order):
        reg = MetricsRegistry()
        for name in order:
            reg.counter(name).inc(1)
        reg.gauge("z.gauge").set(2.5)
        return reg.to_prometheus()

    a = build(["b.n", "a.n"])
    b = build(["a.n", "b.n"])  # insertion order must not matter
    assert a == b
    assert "a_n_total 1\n" in a and "z_gauge 2.5\n" in a


def test_sanitize_name():
    assert sanitize_name("gossip.window-time") == "gossip_window_time"
    assert sanitize_name("0bad") == "_0bad"


def test_escape_label_value():
    assert escape_label_value('plain') == 'plain'
    assert escape_label_value('a"b') == 'a\\"b'
    assert escape_label_value('a\\b') == 'a\\\\b'
    assert escape_label_value('a\nb') == 'a\\nb'
    # backslash first, so the escapes it INTRODUCES are not re-escaped
    assert escape_label_value('\\"') == '\\\\\\"'


def test_prometheus_label_values_escaped():
    reg = MetricsRegistry()
    reg.counter("req").inc(3, path='say "hi"\n@C:\\tmp')
    reg.ingest("build", {"flags": 'x="1"\\y'})
    text = reg.to_prometheus()
    # every emitted line stays one line: raw newlines never leak into the
    # exposition body
    assert all(line.count('"') % 2 == 0 or "\\" in line
               for line in text.splitlines())
    assert 'req_total{path="say \\"hi\\"\\n@C:\\\\tmp"} 3\n' in text
    assert 'build_flags_info{value="x=\\"1\\"\\\\y"} 1\n' in text
    assert "\nsay" not in text  # the label newline was escaped, not emitted


def test_jsonl_sink_records_events(tmp_path):
    path = str(tmp_path / "events.jsonl")
    sink = JsonlSink(path)
    reg = MetricsRegistry(sink=sink)
    reg.counter("c").inc(2, mc="8")
    reg.gauge("g").set(1.5)
    sink.close()
    lines = [json.loads(l) for l in open(path)]
    assert sink.n_events == len(lines) == 2
    assert lines[0] == {"kind": "counter", "name": "c",
                        "labels": {"mc": "8"}, "value": 2}


# ---------------------------------------------------------------------------
# tracer


def test_disabled_tracer_records_nothing_and_reuses_null_span():
    tr = Tracer(enabled=False)
    s1, s2 = tr.span("a"), tr.span("b", k=1)
    assert s1 is s2  # the shared no-op context: zero allocation per span
    with s1:
        pass
    assert tr.spans == [] and tr.summary() == {}


def test_tracer_nesting_depth_and_order():
    tr = Tracer(enabled=True)
    with tr.span("outer"):
        with tr.span("inner"):
            pass
    # inner closes first; depth is relative to the enclosing span
    assert [(s.name, s.depth) for s in tr.spans] == [("inner", 1), ("outer", 0)]
    assert tr.spans[1].dur_us >= tr.spans[0].dur_us


def test_tracer_summary_splits_compile_from_warm():
    tr = Tracer(enabled=True)
    with tr.span("round", compile=True):
        pass
    for _ in range(3):
        with tr.span("round"):
            pass
    summ = tr.summary()["round"]
    assert summ["compile"]["n"] == 1 and summ["warm"]["n"] == 3
    assert summ["warm"]["p50_us"] <= summ["warm"]["max_us"]


def test_tracer_flush_is_incremental(tmp_path):
    sink = JsonlSink(str(tmp_path / "t.jsonl"))
    tr = Tracer(enabled=True, sink=sink)
    with tr.span("a"):
        pass
    assert tr.flush() == 1
    assert tr.flush() == 0  # already flushed
    with tr.span("b"):
        pass
    assert tr.flush() == 1


def test_compile_warm_timer_accumulates():
    t = CompileWarmTimer()
    with t.compile():
        pass
    with t.warm():
        pass
    with t.warm():
        pass
    assert t.compile_us > 0 and t.warm_us > 0
    assert t.warm_us_per(4) == pytest.approx(t.warm_us / 4)
    assert set(t.as_dict()) == {"compile_us", "warm_us"}


# ---------------------------------------------------------------------------
# convergence tracking


def test_network_stats_hand_computed():
    # two agents, one param: means +/-1, both sigmas = 1
    mean = np.array([[1.0], [-1.0]], np.float32)
    rho = np.full((2, 1), float(softplus_inv(1.0)), np.float32)
    got = network_stats(mean, rho)
    assert got["disagreement"] == pytest.approx(1.0, rel=1e-6)
    assert got["rho_disagreement"] == pytest.approx(0.0, abs=1e-7)
    # KL(q_i || q_bar): var ratio 1 -> 0.5 * dev^2 / var_bar = 0.5 each
    assert got["kl_to_mean"] == pytest.approx(0.5, rel=1e-5)
    # mean-only posterior: disagreement only
    assert set(network_stats(mean)) == {"disagreement"}


def test_tracker_measures_synthetic_decay_rate():
    class _P:
        def __init__(self, d):
            self.mean = np.array([[d], [-d]], np.float32)

    tracker = ConvergenceTracker(K=0.7)
    for r in range(8):
        tracker.update(_P(math.exp(-0.7 * r)), r)
    rep = tracker.report()
    assert rep["measured_rate"] == pytest.approx(0.7, rel=1e-2)
    assert rep["rate_attainment"] == pytest.approx(1.0, rel=1e-2)
    # overlay is anchored at the first measured point
    first = rep["overlay"][0]
    assert first["predicted"] == pytest.approx(first["measured"])
    assert len(rep["overlay"]) == rep["n_rounds"] == 8


def test_tracker_explicit_K_wins_over_W():
    W = np.full((3, 3), 1.0 / 3.0)
    assert ConvergenceTracker(W=W, K=2.0).theory_rate == 2.0
    assert ConvergenceTracker().theory_rate is None
    assert ConvergenceTracker().measured_rate() is None  # no points


def test_tracker_series_columns():
    tracker = ConvergenceTracker()
    tracker.update(np.zeros((2, 3), np.float32))
    cols = tracker.series()
    assert cols["round"] == [0]
    assert cols["disagreement"] == [0.0]


# ---------------------------------------------------------------------------
# roofline attainment


def test_attainment_ratio_and_degenerate():
    assert attainment(100.0, 50e-6) == pytest.approx(0.5)
    assert attainment(0.0, 1.0) == 0.0
    assert attainment(1.0, 0.0) == 0.0


def test_consensus_and_window_attainment():
    a = consensus_attainment(1e4, n_agents=8, n_params=1 << 16)
    # modeled best-case never beats a measured CPU time
    assert 0.0 < a["attainment"] < 1.0
    assert a["modeled_us"] == pytest.approx(a["attainment"] * 1e4)
    w = window_attainment(1e4, n_agents=8, n_params=1 << 16,
                          n_participating=4)
    assert 0.0 < w["attainment"] < 1.0
    assert w["participating_fraction"] == pytest.approx(0.5)
    with pytest.raises(ValueError, match="unknown"):
        window_attainment(1e4, n_agents=8, n_params=1 << 16,
                          n_participating=4, strategy="nope")


# ---------------------------------------------------------------------------
# ObsSpec: validation, doc round trip


def _tiny_spec(obs=None, n_rounds=3):
    from repro.api import (
        DataSpec, ExperimentSpec, InferenceSpec, ObsSpec, RunSpec,
        TopologySpec,
    )

    kw = {} if obs is None else {"obs": obs}
    return ExperimentSpec(
        topology=TopologySpec(kind="bidirectional_ring", params={"n": 4}),
        data=DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=20),
            partition="iid", partition_params=dict(n_agents=4),
            batch_size=4, local_updates=1,
        ),
        inference=InferenceSpec(hidden=4, depth=1, lr=1e-2),
        run=RunSpec(n_rounds=n_rounds, seed=0),
        **kw,
    )


def test_obs_spec_validation():
    from repro.api import ObsSpec

    ObsSpec().validate()
    with pytest.raises(ValueError, match="convergence_every"):
        ObsSpec(convergence_every=0).validate()
    with pytest.raises(ValueError, match="jsonl_path"):
        ObsSpec(jsonl_path=7).validate()


def test_obs_spec_doc_round_trip(tmp_path):
    from repro.api import ExperimentSpec, ObsSpec

    spec = _tiny_spec(obs=ObsSpec(enabled=True, convergence_every=2,
                                  jsonl_path=str(tmp_path / "t.jsonl")))
    back = ExperimentSpec.from_doc(spec.to_doc())
    assert back.obs == spec.obs
    # docs written before the obs field existed still load (default ObsSpec)
    doc = spec.to_doc()
    doc.pop("obs")
    assert ExperimentSpec.from_doc(doc).obs == ObsSpec()


# ---------------------------------------------------------------------------
# session integration: zero perturbation, namespacing, checkpoint, dashboard


def test_obs_enabled_is_bitwise_identical():
    from repro.api import ObsSpec, build_session

    posts = {}
    for enabled in (False, True):
        obs = ObsSpec(enabled=True) if enabled else None
        s = build_session(_tiny_spec(obs=obs))
        s.run()
        posts[enabled] = s.posterior()
    np.testing.assert_array_equal(
        np.asarray(posts[False].mean), np.asarray(posts[True].mean)
    )
    np.testing.assert_array_equal(
        np.asarray(posts[False].rho), np.asarray(posts[True].rho)
    )


def test_obs_disabled_session_records_nothing():
    from repro.api import build_session

    s = build_session(_tiny_spec())
    s.run()
    assert s.obs is None
    assert "observability disabled" in s.dashboard()


def test_obs_session_counters_convergence_and_dashboard():
    from repro.api import ObsSpec, build_session

    s = build_session(_tiny_spec(obs=ObsSpec(enabled=True)))
    s.run()
    reg = s.obs.registry
    assert reg.counter("session.rounds").value() == 3
    # static named topology -> spectral theory rate on the tracker
    rep = s.obs.convergence.report()
    assert rep["n_rounds"] == 3
    assert rep["theory_rate"] is not None and rep["theory_rate"] > 0
    names = {sp.name for sp in s.obs.tracer.spans}
    assert {"session.run", "session.round"} <= names
    # first round is compile-attributed, the rest warm
    summ = s.obs.tracer.summary()["session.round"]
    assert summ["compile"]["n"] == 1 and summ["warm"]["n"] == 2
    dash = s.dashboard()
    assert "convergence:" in dash and "span session.round" in dash


def test_obs_gossip_engine_counters_and_spans():
    from repro.api import (
        DataSpec, ExperimentSpec, InferenceSpec, ObsSpec, RunSpec,
        TopologySpec, build_session,
    )

    spec = ExperimentSpec(
        topology=TopologySpec.gossip(
            "bidirectional_ring", {"n": 4},
            clock={"kind": "poisson", "rate": 0.8, "seed": 0},
        ),
        data=DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=20),
            partition="iid", partition_params=dict(n_agents=4),
            batch_size=4, local_updates=1,
        ),
        inference=InferenceSpec(hidden=4, depth=1, lr=1e-2),
        run=RunSpec(n_rounds=3, seed=0),
        obs=ObsSpec(enabled=True),
    )
    s = build_session(spec)
    s.run()
    reg = s.obs.registry
    assert reg.counter("gossip.windows").value() == 3
    assert reg.gauge("gossip.jit_traces").value() == s.engine.n_traces == 1
    names = {sp.name for sp in s.obs.tracer.spans}
    assert "gossip.window" in names


def test_evaluate_namespaces_engine_telemetry():
    """Regression: engine telemetry used to be update()-splatted into the
    metrics dict, so a telemetry key named like a metric clobbered it."""
    from repro.api import build_session

    s = build_session(_tiny_spec())
    s.run()
    s.engine.telemetry = lambda state: {"acc": "CLOBBER", "avg_acc": -1.0}
    out = s.evaluate(n_mc=1)
    assert isinstance(out["acc"], list) and out["avg_acc"] >= 0.0
    assert out["engine"] == {"acc": "CLOBBER", "avg_acc": -1.0}


def test_obs_checkpoint_round_trip(tmp_path):
    from repro.api import ObsSpec, Session, build_session

    path = str(tmp_path / "obs.ckpt")
    s = build_session(_tiny_spec(obs=ObsSpec(enabled=True)))
    plain = build_session(_tiny_spec())
    # observability adds NO state leaves: identical checkpoint structure
    assert (jax.tree.structure(s.state) == jax.tree.structure(plain.state))
    s.run()
    s.save(path)
    back = Session.load(path)
    assert back.spec.obs.enabled and back.obs is not None
    np.testing.assert_array_equal(
        np.asarray(back.posterior().mean), np.asarray(s.posterior().mean)
    )
    assert back.round_idx == s.round_idx


def test_obs_jsonl_path_writes_trace(tmp_path):
    from repro.api import ObsSpec, build_session

    path = str(tmp_path / "trace.jsonl")
    s = build_session(_tiny_spec(obs=ObsSpec(enabled=True,
                                             jsonl_path=path)))
    s.run()
    s.dashboard()  # flushes
    events = [json.loads(l) for l in open(path)]
    kinds = {e["kind"] for e in events}
    assert "span" in kinds and ("counter" in kinds or "gauge" in kinds)
