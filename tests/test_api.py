"""The declarative API (repro.api): spec validation, engine equivalence,
session checkpoint round-trip with the embedded ExperimentSpec, first-class
topology schedules, and the flat-default satellite flips."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    RunSpec,
    Session,
    TopologySpec,
    build_session,
)
from repro.core.flat import FlatPosterior


def _tiny_spec(engine="simulated", n_rounds=3, seed=0):
    """3-agent star, 8-dim 3-class synthetic task, 2 local steps of batch 4 —
    small enough that an engine-equivalence round trip runs in seconds."""
    return ExperimentSpec(
        topology=TopologySpec.star(n_edge=2, a=0.5),
        data=DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
            partition="star",
            partition_params=dict(center_labels=[1, 2], edge_labels=[0], n_edge=2),
            batch_size=4,
            local_updates=2,
        ),
        inference=InferenceSpec(hidden=8, depth=1, lr=1e-2),
        run=RunSpec(n_rounds=n_rounds, seed=seed, engine=engine),
    )


# ---------------------------------------------------------------------------
# engine equivalence: the acceptance gate for the launch-path rewiring
# ---------------------------------------------------------------------------


def test_simulated_and_launch_engines_agree():
    """SimulatedEngine (core.simulated flat runtime) and LaunchEngine
    (launch.steps make_local_step/make_consensus_step on FlatPosterior)
    produce allclose posteriors over 3 rounds on a tiny star network — the
    production hot loop runs the same math as the reference runtime, flat
    end-to-end."""
    from repro.launch.steps import BayesTrainState

    s_sim = build_session(_tiny_spec(engine="simulated"))
    s_launch = build_session(_tiny_spec(engine="launch"))
    h_sim = s_sim.run()
    h_launch = s_launch.run()
    del h_sim, h_launch

    assert isinstance(s_launch.state, BayesTrainState)
    p_sim, p_launch = s_sim.posterior(), s_launch.posterior()
    # no pytree posterior in the launch hot loop
    assert isinstance(p_launch, FlatPosterior)
    assert isinstance(p_sim, FlatPosterior)
    np.testing.assert_allclose(
        np.asarray(p_sim.mean), np.asarray(p_launch.mean), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(p_sim.rho), np.asarray(p_launch.rho), atol=1e-5, rtol=1e-5
    )
    # and the training actually moved the posterior
    fresh = build_session(_tiny_spec(engine="simulated")).posterior()
    assert float(jnp.max(jnp.abs(p_sim.mean - fresh.mean))) > 1e-4


def test_launch_engine_evaluate_matches_simulated():
    s_sim = build_session(_tiny_spec(engine="simulated"))
    s_launch = build_session(_tiny_spec(engine="launch"))
    s_sim.run()
    s_launch.run()
    ev_sim = s_sim.evaluate()
    ev_launch = s_launch.evaluate()
    np.testing.assert_allclose(ev_sim["acc"], ev_launch["acc"], atol=1e-6)


# ---------------------------------------------------------------------------
# eager spec validation
# ---------------------------------------------------------------------------


def _iid_spec(topology, n_agents):
    return ExperimentSpec(
        topology=topology,
        data=DataSpec(
            dataset_params=dict(n_classes=2, dim=4, n_train_per_class=10),
            partition="iid",
            partition_params=dict(n_agents=n_agents),
        ),
    )


def test_disconnected_w_rejected():
    bad = np.eye(2)  # two isolated agents: no strongly connected support
    with pytest.raises(ValueError, match="strongly connected"):
        build_session(_iid_spec(TopologySpec.explicit(bad), 2))


def test_non_row_stochastic_w_rejected():
    bad = np.array([[0.5, 0.6], [0.5, 0.5]])
    with pytest.raises(ValueError, match="row-stochastic"):
        build_session(_iid_spec(TopologySpec.explicit(bad), 2))


def test_agent_count_mismatch_rejected():
    with pytest.raises(ValueError, match="3 agents"):
        build_session(_iid_spec(TopologySpec.complete(3), 4))


def test_schedule_union_connectivity_enforced():
    # two slots whose union still leaves agent 2 isolated
    w_a = np.array([[0.5, 0.5, 0.0], [0.5, 0.5, 0.0], [0.0, 0.0, 1.0]])
    with pytest.raises(ValueError, match="union"):
        TopologySpec.from_schedule([w_a, w_a]).validate()


def test_unknown_enum_fields_rejected():
    with pytest.raises(ValueError, match="engine"):
        _tiny_spec().run.__class__(engine="warp").validate()
    with pytest.raises(ValueError, match="consensus"):
        InferenceSpec(consensus="median").validate()
    with pytest.raises(ValueError, match="dataset"):
        DataSpec(dataset="imagenet").validate()


def test_callable_topology_not_checkpoint_embeddable():
    spec = dataclasses.replace(
        _tiny_spec(),
        topology=TopologySpec.from_callable(lambda r: np.eye(3), n_agents=3),
    )
    with pytest.raises(ValueError, match="callable"):
        spec.to_doc()


# ---------------------------------------------------------------------------
# first-class topology schedules (Callable[[int], W])
# ---------------------------------------------------------------------------


def test_run_rounds_accepts_callable_schedule():
    from repro.core.simulated import as_w_schedule

    mats = [np.eye(2), np.full((2, 2), 0.5)]
    fn = as_w_schedule(lambda r: mats[r % 2])
    np.testing.assert_array_equal(fn(0), mats[0])
    np.testing.assert_array_equal(fn(3), mats[1])
    # list and static forms normalize through the same helper
    np.testing.assert_array_equal(as_w_schedule(mats)(1), mats[1])
    np.testing.assert_array_equal(as_w_schedule(mats[0])(7), mats[0])


def test_session_run_callable_schedule_matches_list_schedule():
    """Session.run(w_schedule=callable) == the same schedule as a list —
    the table3 time-varying port relies on this."""
    from repro.core.graphs import time_varying_star_schedule

    mats = time_varying_star_schedule(2, 1, a=0.5)

    def build(n_agents=3):
        return build_session(ExperimentSpec(
            topology=TopologySpec.time_varying_star(2, 1, a=0.5),
            data=DataSpec(
                dataset_params=dict(n_classes=2, dim=4, n_train_per_class=12),
                partition="iid",
                partition_params=dict(n_agents=3),
                batch_size=4,
                local_updates=1,
            ),
            inference=InferenceSpec(hidden=4, depth=1),
            run=RunSpec(n_rounds=4, seed=1),
        ))

    s_list = build()
    s_callable = build()
    s_list.run(w_schedule=[np.asarray(m) for m in mats])
    s_callable.run(w_schedule=lambda r: mats[r % len(mats)])
    np.testing.assert_array_equal(
        np.asarray(s_list.posterior().mean), np.asarray(s_callable.posterior().mean)
    )


# ---------------------------------------------------------------------------
# self-describing session checkpoints (embedded ExperimentSpec)
# ---------------------------------------------------------------------------


def test_session_checkpoint_roundtrip_and_resume(tmp_path):
    """save -> load rebuilds the session FROM THE EMBEDDED SPEC (no `like`
    tree) and resuming both sessions stays bit-identical."""
    s = build_session(_tiny_spec(n_rounds=5))
    s.run(2)
    path = os.path.join(tmp_path, "sess.ckpt")
    s.save(path)

    s2 = Session.load(path)
    assert s2.round_idx == 2
    assert s2.spec == s.spec  # the embedded spec round-trips exactly
    np.testing.assert_array_equal(
        np.asarray(s2.posterior().mean), np.asarray(s.posterior().mean)
    )
    s.run(2)
    s2.run(2)
    np.testing.assert_array_equal(
        np.asarray(s2.posterior().mean), np.asarray(s.posterior().mean)
    )
    np.testing.assert_array_equal(
        np.asarray(s2.posterior().rho), np.asarray(s.posterior().rho)
    )


def test_session_checkpoint_zlib_fallback(tmp_path, monkeypatch):
    """Regression for the zstandard-less container: the session document
    compresses via zlib and the reader sniffs the frame either way."""
    import repro.checkpoint.io as io

    monkeypatch.setattr(io, "zstandard", None)
    s = build_session(_tiny_spec(n_rounds=2))
    s.run()
    path = os.path.join(tmp_path, "sess_zlib.ckpt")
    s.save(path)
    with open(path, "rb") as f:
        assert f.read(4) != io._ZSTD_MAGIC  # actually took the zlib path
    s2 = Session.load(path)
    np.testing.assert_array_equal(
        np.asarray(s2.posterior().mean), np.asarray(s.posterior().mean)
    )
    assert s2.spec == s.spec


def test_spec_doc_roundtrip_explicit_w():
    W = np.array([[0.5, 0.5], [0.25, 0.75]])
    spec = dataclasses.replace(
        _tiny_spec(),
        topology=TopologySpec.explicit(W),
        data=DataSpec(
            dataset_params=dict(n_classes=2, dim=4, n_train_per_class=10),
            partition="iid",
            partition_params=dict(n_agents=2),
        ),
    )
    doc = spec.to_doc()
    back = ExperimentSpec.from_doc(doc)
    np.testing.assert_array_equal(np.asarray(back.topology.w), W)
    assert back.inference == spec.inference
    assert back.run == spec.run


# ---------------------------------------------------------------------------
# conjugate linreg engine (paper Example 1 through the same front door)
# ---------------------------------------------------------------------------


def test_conjugate_linreg_session_reaches_noise_floor():
    spec = ExperimentSpec(
        topology=TopologySpec.complete(4),
        data=DataSpec(dataset="linreg", batch_size=10),
        inference=InferenceSpec(method="conjugate_linreg"),
        run=RunSpec(n_rounds=60, seed=0),
    )
    s = build_session(spec)
    s.run()
    ev = s.evaluate()
    noise_floor = float(s.data.dataset.noise_std) ** 2
    assert ev["avg_mse"] < noise_floor * 1.2, ev


def test_linreg_requires_conjugate_method():
    with pytest.raises(ValueError, match="conjugate_linreg"):
        ExperimentSpec(data=DataSpec(dataset="linreg")).validate()


# ---------------------------------------------------------------------------
# satellite: flat-by-default flips
# ---------------------------------------------------------------------------


def test_init_network_flat_default_and_deprecation():
    from repro.core.simulated import init_network
    from repro.optim import adam

    def init_params(key):
        return {"w": jax.random.normal(key, (4, 2))}

    opt = adam()
    state = init_network(jax.random.key(0), 3, init_params, opt)
    assert isinstance(state.posterior, FlatPosterior)  # flat IS the default
    with pytest.warns(DeprecationWarning, match="flat"):
        legacy = init_network(jax.random.key(0), 3, init_params, opt, flat=False)
    assert not isinstance(legacy.posterior, FlatPosterior)
    # both hold the same values
    np.testing.assert_allclose(
        np.asarray(state.posterior.mean),
        np.asarray(legacy.posterior.mean["w"].reshape(3, -1)),
        atol=1e-6,
    )


def test_launch_init_train_state_flat_default():
    from repro.configs import get_config
    from repro.launch.steps import init_train_state, serve_params
    from repro.optim import adam

    cfg = get_config("repro-100m").reduced()
    state = init_train_state(jax.random.key(0), cfg, 2, adam())
    assert isinstance(state.posterior, FlatPosterior)
    assert state.posterior.mean.ndim == 2  # [A, P]
    sp = serve_params(state.posterior)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(sp))


def test_quickstart_runs_on_the_spec_api():
    """Acceptance: the quickstart has no direct simulated-runtime wiring."""
    src = open(os.path.join(os.path.dirname(__file__), "..",
                            "examples", "quickstart.py")).read()
    assert "init_network" not in src
    assert "make_round_fn" not in src
    assert "ExperimentSpec(" in src and "build_session" in src
