"""Per-architecture smoke tests (reduced configs: 2 layers, d_model<=256,
<=4 experts) + family-level numerical consistency checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.models import decode_step, forward, init_cache, init_params, nll_loss
from repro.optim import adam, apply_updates

ASSIGNED = [a for a in list_archs() if a != "repro-100m"]


def _batch(cfg, b=2, s=16, key=jax.random.key(0)):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    if cfg.frontend == "audio_stub":
        batch["frames"] = (
            jnp.ones((b, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.1
        )
    if cfg.frontend == "vision_stub":
        batch["patches"] = (
            jnp.ones((b, cfg.n_patches, cfg.d_model), jnp.float32) * 0.1
        )
        batch["targets"] = jax.random.randint(
            key, (b, s + cfg.n_patches), 0, cfg.vocab_size
        )
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_forward_shapes_and_no_nans(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    logits, _, aux = forward(
        params, cfg, batch["tokens"],
        frames=batch.get("frames"), patches=batch.get("patches"),
    )
    s_total = 16 + (cfg.n_patches if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (2, s_total, cfg.padded_vocab)
    assert not np.any(np.isnan(np.asarray(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_one_train_step(arch):
    """One Adam step on the NLL reduces loss on the same batch (sanity of
    grads through every block kind)."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(1))
    batch = _batch(cfg, key=jax.random.key(2))

    def loss_fn(p):
        nll, aux = nll_loss(p, cfg, batch)
        return nll / batch["targets"].size + 0.01 * aux

    loss0, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss0))
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0 and np.isfinite(gnorm)
    opt = adam()
    upd, _ = opt.update(grads, opt.init(params), jnp.asarray(0), jnp.asarray(1e-2))
    loss1 = loss_fn(apply_updates(params, upd))
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("arch", ["qwen3-8b", "xlstm-1.3b", "recurrentgemma-9b",
                                  "granite-20b", "whisper-tiny"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(1))
    b, s = 2, 12
    toks = jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab_size)
    fr = (jnp.ones((b, cfg.encoder_seq, cfg.d_model)) * 0.1
          if cfg.is_encdec else None)
    full, _, _ = forward(params, cfg, toks, frames=fr)
    cache = init_cache(cfg, b, capacity=s, dtype=jnp.float32)
    outs = []
    for t in range(s):
        lg, cache = decode_step(
            params, cfg, toks[:, t : t + 1], jnp.asarray(t), cache,
            enc_out_frames=fr,
        )
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(dec, full, atol=5e-2, rtol=5e-2)


def test_prefill_then_decode_continuation():
    """Prefill builds a cache the decode path can continue from."""
    cfg = get_config("qwen3-8b").reduced()
    params = init_params(cfg, jax.random.key(3))
    b, s = 2, 10
    toks = jax.random.randint(jax.random.key(4), (b, s + 2), 0, cfg.vocab_size)
    full, _, _ = forward(params, cfg, toks)
    cache = init_cache(cfg, b, capacity=s + 2, dtype=jnp.float32)
    _, cache, _ = forward(params, cfg, toks[:, :s], cache=cache)
    lg1, cache = decode_step(params, cfg, toks[:, s : s + 1], jnp.asarray(s), cache)
    lg2, cache = decode_step(
        params, cfg, toks[:, s + 1 : s + 2], jnp.asarray(s + 1), cache
    )
    np.testing.assert_allclose(lg1[:, 0], full[:, s], atol=5e-2, rtol=5e-2)
    np.testing.assert_allclose(lg2[:, 0], full[:, s + 1], atol=5e-2, rtol=5e-2)


def test_sliding_window_ring_buffer_decode():
    """SWA ring-buffer cache (long-context decode) == full-cache decode with
    window masking."""
    cfg = dataclasses.replace(
        get_config("qwen3-8b").reduced(), sliding_window=8,
        pattern=("local_attn", "local_attn"),
    )
    cfg.validate()
    params = init_params(cfg, jax.random.key(5))
    b, s = 1, 24
    toks = jax.random.randint(jax.random.key(6), (b, s), 0, cfg.vocab_size)
    # reference: full forward with window masking
    full, _, _ = forward(params, cfg, toks)
    # ring buffer: capacity == window
    cache = init_cache(cfg, b, capacity=8, dtype=jnp.float32)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cfg, toks[:, t : t + 1], jnp.asarray(t), cache)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(dec, full, atol=5e-2, rtol=5e-2)


def test_window_override_matches_local_attn():
    """window_override on 'attn' == a config with local_attn of that window."""
    base = get_config("deepseek-7b").reduced()
    params = init_params(base, jax.random.key(7))
    toks = jax.random.randint(jax.random.key(8), (2, 20), 0, base.vocab_size)
    out_override, _, _ = forward(params, base, toks, window_override=6)
    local = dataclasses.replace(base, pattern=("local_attn", "local_attn"),
                                sliding_window=6)
    # same weights, reindexed under the local_attn kind
    params_local = dict(params)
    params_local["stacks"] = {"local_attn": params["stacks"]["attn"]}
    out_local, _, _ = forward(params_local, local, toks)
    np.testing.assert_allclose(out_override, out_local, atol=1e-5, rtol=1e-5)


def test_moe_capacity_factor_effect():
    """Higher capacity factor -> fewer dropped tokens -> different output;
    at cf large the dispatch is exact vs the dense reference."""
    from repro.models.moe import moe_ffn, moe_init

    cfg = dataclasses.replace(
        get_config("olmoe-1b-7b").reduced(), capacity_factor=16.0
    )
    p = moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    y, aux = moe_ffn(p, x, cfg)

    def dense_ref(p, x):
        b, s, d = x.shape
        xt = x.reshape(-1, d)
        probs = jax.nn.softmax(xt @ p["router"], -1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
        w = w / w.sum(-1, keepdims=True)
        g = jnp.einsum("td,edf->tef", xt, p["w_gate"])
        u = jnp.einsum("td,edf->tef", xt, p["w_up"])
        yo = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, p["w_down"])
        sel = jnp.take_along_axis(yo, idx[:, :, None], axis=1)
        return (sel * w[:, :, None]).sum(1).reshape(b, s, d)

    np.testing.assert_allclose(y, dense_ref(p, x), atol=1e-4, rtol=1e-4)
    assert float(aux) > 0


def test_vocab_padding_multiple_of_256():
    for arch in ASSIGNED:
        cfg = get_config(arch)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size


def test_vlm_prefill_then_decode():
    """Pixtral path: patch embeddings prepended in prefill; decode continues
    from the cache at post-patch positions."""
    cfg = get_config("pixtral-12b").reduced()
    params = init_params(cfg, jax.random.key(9))
    b, s = 2, 8
    toks = jax.random.randint(jax.random.key(10), (b, s + 2), 0, cfg.vocab_size)
    patches = jax.random.normal(jax.random.key(11), (b, cfg.n_patches, cfg.d_model)) * 0.1
    full, _, _ = forward(params, cfg, toks, patches=patches)
    total0 = cfg.n_patches + s
    cache = init_cache(cfg, b, capacity=cfg.n_patches + s + 2, dtype=jnp.float32)
    _, cache, _ = forward(params, cfg, toks[:, :s], patches=patches, cache=cache)
    lg, cache = decode_step(params, cfg, toks[:, s : s + 1], jnp.asarray(total0), cache)
    np.testing.assert_allclose(lg[:, 0], full[:, total0], atol=5e-2, rtol=5e-2)


def test_encdec_decode_with_frames():
    """Whisper decode consumes fresh encoder output each step (cross-attn)."""
    cfg = get_config("whisper-tiny").reduced()
    params = init_params(cfg, jax.random.key(12))
    b = 2
    fr = jax.random.normal(jax.random.key(13), (b, cfg.encoder_seq, cfg.d_model)) * 0.1
    cache = init_cache(cfg, b, capacity=8, dtype=jnp.float32)
    tok = jnp.zeros((b, 1), jnp.int32)
    for t in range(4):
        lg, cache = decode_step(params, cfg, tok, jnp.asarray(t), cache,
                                enc_out_frames=fr)
        assert lg.shape == (b, 1, cfg.padded_vocab)
        assert not np.any(np.isnan(np.asarray(lg, np.float32)))
        tok = jnp.argmax(lg[..., : cfg.vocab_size], -1).astype(jnp.int32)
