"""Graph/W builders + Theorem 1 quantities, validated against the numbers
the paper itself reports."""
import numpy as np
import pytest

from repro.core.graphs import (
    bidirectional_ring_w,
    check_w,
    complete_w,
    erdos_w,
    grid_w,
    max_in_degree,
    neighbor_lists,
    ring_w,
    star_w,
    time_varying_star_schedule,
    torus_w,
)
from repro.core.theory import (
    consensus_contraction_rate,
    lambda_max,
    predicted_decay_curve,
    rate_K,
    sample_complexity,
    spectral_gap,
    stationary_distribution,
)

# a 3-agent path graph, hand-diagonalizable: eigenvalues {1, 1/2, 0},
# stationary distribution (1/4, 1/2, 1/4) (solve v = vW by hand)
W_CHAIN3 = np.array([
    [0.50, 0.50, 0.00],
    [0.25, 0.50, 0.25],
    [0.00, 0.50, 0.50],
])


def test_star_centrality_matches_paper():
    """Supplementary 1.4.1: a in [0.1,0.2,0.3,0.5,0.7] ->
    v_center in [0.1, 0.18, 0.25, 0.36, 0.44]."""
    expected = {0.1: 0.10, 0.2: 0.18, 0.3: 0.25, 0.5: 0.36, 0.7: 0.44}
    for a, v_exp in expected.items():
        v = stationary_distribution(star_w(8, a))
        assert abs(v[0] - v_exp) < 0.01, (a, v[0])


def test_star_centrality_monotone_in_a():
    vs = [stationary_distribution(star_w(8, a))[0] for a in (0.1, 0.3, 0.5, 0.7, 0.9)]
    assert all(v2 > v1 for v1, v2 in zip(vs, vs[1:]))


def test_grid_centrality_proportional_to_degree():
    """Paper Sec 4.2.2: with W_ij = 1/|N(i)| the centrality of agent i is
    proportional to its degree."""
    W = grid_w(3, 3)
    v = stationary_distribution(W)
    deg = np.array([len(nb) for nb in neighbor_lists(W)])
    ratio = v / deg
    assert np.allclose(ratio, ratio[0], rtol=1e-6)
    # center (position 4) is the most central
    assert np.argmax(v) == 4


@pytest.mark.parametrize(
    "builder",
    [
        lambda: star_w(8, 0.5),
        lambda: grid_w(3, 3),
        lambda: ring_w(7),
        lambda: bidirectional_ring_w(6),
        lambda: torus_w(4, 4),
        lambda: complete_w(5),
        lambda: erdos_w(10, 0.4, seed=3),
    ],
)
def test_builders_valid(builder):
    W = builder()
    check_w(W)
    v = stationary_distribution(W)
    assert np.all(v > 0) and abs(v.sum() - 1) < 1e-9
    assert 0.0 <= lambda_max(W) < 1.0  # aperiodic + irreducible


def test_stationarity_equation():
    W = star_w(8, 0.3)
    v = stationary_distribution(W)
    np.testing.assert_allclose(v @ W, v, atol=1e-10)


def test_spectral_gap_complete_graph_is_one():
    assert abs(spectral_gap(complete_w(6)) - 1.0) < 1e-9


def test_time_varying_schedule_union_connected():
    mats = time_varying_star_schedule(25, 5, a=0.5)
    assert len(mats) == 5
    for W in mats:
        assert np.allclose(W.sum(1), 1.0)


def test_rate_K_weights_informative_central_agents():
    """Remark 3: K grows when the informative agent is more central."""
    W = star_w(8, 0.5)
    v = stationary_distribution(W)
    n = 9
    # agent 0 (center) can distinguish; others cannot
    I_center_informed = np.zeros((n, 1, 1))
    I_center_informed[0] = 1.0
    I_edge_informed = np.zeros((n, 1, 1))
    I_edge_informed[3] = 1.0
    assert rate_K(v, I_center_informed) > rate_K(v, I_edge_informed)


def test_rate_K_increases_with_centrality_a():
    n = 9
    I = np.zeros((n, 1, 1))
    I[0] = 1.0  # center informative
    ks = []
    for a in (0.1, 0.3, 0.5, 0.7):
        v = stationary_distribution(star_w(8, a))
        ks.append(rate_K(v, I))
    assert all(k2 > k1 for k1, k2 in zip(ks, ks[1:]))


def test_sample_complexity_scales_with_gap():
    Wa = star_w(8, 0.5)
    Wb = complete_w(9)
    na = sample_complexity(9, 10, 0.05, 0.1, 2.0, Wa)
    nb = sample_complexity(9, 10, 0.05, 0.1, 2.0, Wb)
    assert nb < na  # larger spectral gap -> fewer samples


def test_three_agent_chain_hand_computed():
    """Every Theorem-1 graph quantity on a W small enough to diagonalize by
    hand: eigenvalues {1, 1/2, 0}, stationary (1/4, 1/2, 1/4)."""
    np.testing.assert_allclose(
        stationary_distribution(W_CHAIN3), [0.25, 0.5, 0.25], atol=1e-10
    )
    assert lambda_max(W_CHAIN3) == pytest.approx(0.5, abs=1e-10)
    assert spectral_gap(W_CHAIN3) == pytest.approx(0.5, abs=1e-10)


def test_three_agent_rate_K_hand_computed():
    """K = min over wrong hypotheses of the v-weighted divergence sum:
    with v = (1/4, 1/2, 1/4) and two wrong hypotheses whose per-agent gaps
    sum to 0.25 and 0.30, K is the smaller (eq. 7)."""
    v = stationary_distribution(W_CHAIN3)
    I = np.array([          # [N=3, n_star=1, n_wrong=2]
        [[0.4, 0.2]],       # agent 0: gaps to wrong hypotheses t=0, t=1
        [[0.1, 0.4]],       # agent 1 (most central)
        [[0.4, 0.2]],       # agent 2
    ])
    # hand sums: t=0: .25*.4 + .5*.1 + .25*.4 = 0.25
    #            t=1: .25*.2 + .5*.4 + .25*.2 = 0.30  ->  K = min = 0.25
    assert rate_K(v, I) == pytest.approx(0.25, abs=1e-12)


def test_predicted_decay_curve_hand_computed():
    np.testing.assert_allclose(
        predicted_decay_curve(0.5, np.array([0, 1, 2])),
        [1.0, np.exp(-0.5), np.exp(-1.0)],
    )
    # the eps slack slows the predicted decay
    assert predicted_decay_curve(0.5, 2, eps=0.1) == pytest.approx(
        np.exp(-0.8)
    )


def test_consensus_contraction_rate_edges_and_consistency():
    # chain: rate = -log(1/2); one averaging pass shrinks disagreement 2x
    assert consensus_contraction_rate(W_CHAIN3) == pytest.approx(np.log(2.0))
    assert np.exp(-consensus_contraction_rate(W_CHAIN3)) == pytest.approx(
        lambda_max(W_CHAIN3)
    )
    # disconnected (identity): lambda_max = 1, nothing contracts
    assert consensus_contraction_rate(np.eye(3)) == 0.0
    # complete uniform: lambda_max = 0, one pass reaches exact consensus
    assert consensus_contraction_rate(complete_w(4)) == np.inf
    # the empirical power-iteration check: disagreement after n averaging
    # passes decays like exp(-n * rate)
    x = np.array([1.0, 0.0, -1.0])
    rate = consensus_contraction_rate(W_CHAIN3)
    for n in (1, 4, 8):
        y = np.linalg.matrix_power(W_CHAIN3, n) @ x
        spread = np.abs(y - y.mean()).max()
        assert spread <= np.abs(x - x.mean()).max() * np.exp(-n * rate) + 1e-12


def test_max_in_degree():
    assert max_in_degree(star_w(8, 0.5)) == 9  # center listens to everyone
    assert max_in_degree(ring_w(5)) == 2
