"""Production step functions, data pipeline, optimizers, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.configs import get_config
from repro.core.graphs import complete_w, star_w
from repro.data.partition import partition_by_label, partition_iid, star_partition
from repro.data.pipeline import AgentDataset, make_lm_batch_sampler, make_round_batches
from repro.data.synthetic import fmnist_like, make_synthetic_classification
from repro.launch.steps import (
    init_train_state,
    make_consensus_step,
    make_decode_step,
    make_prefill_step,
    make_agent_cache,
    make_train_round_step,
    serve_params,
)
from repro.optim import adam, apply_updates, clip_by_global_norm, global_norm, sgd
from repro.optim.schedules import exponential_decay, warmup_cosine


# ---------------------------------------------------------------------------
# production steps
# ---------------------------------------------------------------------------


def test_train_round_step_loss_decreases():
    cfg = get_config("repro-100m").reduced()
    a = 2
    opt = adam()
    W = jnp.asarray(complete_w(a))
    step = jax.jit(make_train_round_step(cfg, W, opt=opt, remat=False,
                                         kl_scale=1e-5))
    state = init_train_state(jax.random.key(0), cfg, a, opt)
    sampler = make_lm_batch_sampler(cfg.vocab_size, 4, 32, n_agents=a)
    key = jax.random.key(1)
    batch0 = sampler(key, 0)
    losses = []
    for i in range(30):
        key, k = jax.random.split(key)
        state, m = step(state, batch0, k)  # same batch: loss must decrease
        losses.append(float(jnp.mean(m["loss"])))
    assert losses[-1] < losses[0]
    assert int(state.step) == 30


def test_consensus_step_brings_agents_together():
    cfg = get_config("repro-100m").reduced()
    a = 4
    opt = adam()
    state = init_train_state(jax.random.key(0), cfg, a, opt)
    # perturb each agent differently
    post = state.posterior
    noise = jax.tree.map(
        lambda m: m + jax.random.normal(jax.random.key(1), m.shape) * 0.1, post.mean
    )
    post = jax.tree.map(lambda x: x, post)
    post.mean = noise
    W = jnp.asarray(complete_w(a))
    consensus = jax.jit(make_consensus_step(cfg, W))

    def spread(p):
        return float(
            sum(jnp.sum(jnp.var(l, axis=0)) for l in jax.tree.leaves(p.mean))
        )

    s0 = spread(post)
    post2 = consensus(post)
    assert spread(post2) < 1e-9  # complete uniform graph: one-step agreement
    assert s0 > 0


def test_consensus_respects_w_zero_entries():
    """Agents with no path exchange nothing in one round (star W)."""
    cfg = get_config("repro-100m").reduced()
    a = 3
    opt = adam()
    state = init_train_state(jax.random.key(0), cfg, a, opt)
    post = state.posterior
    bumped = jax.tree.map(
        lambda m: m.at[2].add(1.0), post.mean
    )  # bump edge agent 2
    post.mean = bumped
    # W: edge agents only listen to center(0) and self; edge2's bump must not
    # reach edge1 in a single round
    W = jnp.asarray(star_w(2, a=0.5))
    post2 = jax.jit(make_consensus_step(cfg, W))(post)
    leaf0 = jax.tree.leaves(post.mean)[0]
    leaf2 = jax.tree.leaves(post2.mean)[0]
    np.testing.assert_allclose(leaf2[1], leaf0[1], atol=1e-6)  # edge1 unchanged


def test_deterministic_mode_runs():
    cfg = get_config("repro-100m").reduced()
    a = 2
    opt = adam()
    W = jnp.asarray(complete_w(a))
    step = jax.jit(make_train_round_step(cfg, W, opt=opt, remat=False,
                                         bayesian=False))
    state = init_train_state(jax.random.key(0), cfg, a, opt)
    sampler = make_lm_batch_sampler(cfg.vocab_size, 2, 16, n_agents=a)
    state, m = step(state, sampler(jax.random.key(1), 0), jax.random.key(2))
    assert np.isfinite(float(jnp.mean(m["loss"])))
    assert float(jnp.mean(m["kl"])) == 0.0


def test_prefill_and_decode_steps_agent_axis():
    cfg = get_config("qwen3-8b").reduced()
    a, b, s = 2, 2, 8
    from repro.models import init_params

    params = jax.vmap(lambda k: init_params(cfg, k))(
        jax.random.split(jax.random.key(0), a)
    )
    cache = make_agent_cache(cfg, a, b, capacity=s + 4, dtype=jnp.float32)
    toks = jax.random.randint(jax.random.key(1), (a, b, s), 0, cfg.vocab_size)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    logits, cache = prefill(params, {"tokens": toks}, cache)
    assert logits.shape == (a, b, 1, cfg.padded_vocab)
    lg, cache = decode(
        params, toks[:, :, :1], jnp.asarray(s, jnp.int32), cache, None
    )
    assert lg.shape == (a, b, 1, cfg.padded_vocab)
    assert not np.any(np.isnan(np.asarray(lg, np.float32)))


def test_serve_params_casts_mean():
    cfg = get_config("repro-100m").reduced()
    state = init_train_state(jax.random.key(0), cfg, 1, adam())
    sp = serve_params(state.posterior)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(sp))


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_partition_by_label_disjoint_and_complete():
    ds = make_synthetic_classification(n_classes=6, dim=8, n_train_per_class=50)
    shards = partition_by_label(ds.x_train, ds.y_train, [[0, 1], [2, 3], [4, 5]])
    assert sum(len(y) for _, y in shards) == len(ds.y_train)
    assert set(np.unique(shards[0][1])) == {0, 1}
    assert set(np.unique(shards[2][1])) == {4, 5}


def test_star_partition_matches_paper_structure():
    ds = make_synthetic_classification(n_classes=10, dim=8, n_train_per_class=80)
    shards = star_partition(ds.x_train, ds.y_train, list(range(2, 10)), [0, 1], 8)
    assert len(shards) == 9
    assert set(np.unique(shards[0][1])) == set(range(2, 10))
    sizes = [len(y) for _, y in shards[1:]]
    assert max(sizes) - min(sizes) <= 1  # equal edge shards


def test_partition_iid_even():
    ds = make_synthetic_classification(n_classes=4, dim=4, n_train_per_class=25)
    shards = partition_iid(ds.x_train, ds.y_train, 5)
    assert sum(len(y) for _, y in shards) == 100
    assert max(len(y) for _, y in shards) - min(len(y) for _, y in shards) <= 1


def test_round_batches_shapes_and_validity():
    ds = make_synthetic_classification(n_classes=4, dim=6, n_train_per_class=30)
    shards = partition_by_label(ds.x_train, ds.y_train, [[0], [1], [2, 3]])
    data = AgentDataset.from_shards(shards)
    sampler = make_round_batches(data, batch_size=5, n_local_updates=3)
    batch = sampler(jax.random.key(0), 0)
    assert batch["x"].shape == (3, 3, 5, 6)
    assert batch["y"].shape == (3, 3, 5)
    # agent 0 only sees label 0
    assert set(np.unique(batch["y"][0])) == {0}


def test_fmnist_like_group_structure():
    ds = fmnist_like(dim=16)
    protos = ds.prototypes
    shirt = [0, 2, 3, 4, 6]
    intra = np.mean([
        np.linalg.norm(protos[i] - protos[j]) for i in shirt for j in shirt if i < j
    ])
    inter = np.mean([np.linalg.norm(protos[i] - protos[1]) for i in shirt])
    assert intra < inter  # shirt-like family is clustered


# ---------------------------------------------------------------------------
# optim + checkpoint
# ---------------------------------------------------------------------------


def test_adam_converges_quadratic():
    opt = adam()
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for i in range(500):
        grads = jax.tree.map(lambda p: 2 * p, params)
        upd, state = opt.update(grads, state, jnp.asarray(i), jnp.asarray(0.05))
        params = apply_updates(params, upd)
    assert float(jnp.max(jnp.abs(params["x"]))) < 1e-2


def test_sgd_momentum_and_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    opt = sgd(momentum=0.9)
    st = opt.init(g)
    upd, st = opt.update(g, st, jnp.asarray(0), jnp.asarray(0.1))
    np.testing.assert_allclose(np.asarray(upd["a"]), -1.0, rtol=1e-6)


def test_schedules():
    s = exponential_decay(1e-3, 0.99)
    assert np.isclose(float(s(jnp.asarray(0))), 1e-3)
    assert np.isclose(float(s(jnp.asarray(100))), 1e-3 * 0.99**100, rtol=1e-5)
    w = warmup_cosine(1.0, 10, 110)
    assert float(w(jnp.asarray(5))) == pytest.approx(0.5, rel=1e-5)
    assert float(w(jnp.asarray(110))) == pytest.approx(0.0, abs=1e-6)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "meta": {"step": 7, "name": "x"},
        "b": np.ones((2,), np.int32),
    }
    path = os.path.join(tmp_path, "t.ckpt")
    save_pytree(path, tree)
    like = {
        "w": jnp.zeros((3, 4), jnp.float32),
        "meta": {"step": 0, "name": ""},
        "b": np.zeros((2,), np.int32),
    }
    out = restore_pytree(path, like)
    np.testing.assert_allclose(out["w"], tree["w"])
    assert out["meta"]["step"] == 7 and out["meta"]["name"] == "x"


def test_checkpoint_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), max_to_keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"v": jnp.asarray([float(s)])})
    assert mgr.all_steps() == [3, 4]
    step, out = mgr.restore({"v": jnp.zeros((1,))})
    assert step == 4 and float(out["v"][0]) == 4.0


def test_checkpoint_restore_train_state(tmp_path):
    cfg = get_config("repro-100m").reduced()
    state = init_train_state(jax.random.key(0), cfg, 2, adam())
    path = os.path.join(tmp_path, "s.ckpt")
    save_pytree(path, state)
    out = restore_pytree(path, state)
    np.testing.assert_allclose(
        jax.tree.leaves(out.posterior.mean)[0],
        jax.tree.leaves(state.posterior.mean)[0],
    )
