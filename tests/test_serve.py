"""Posterior serving tier (``repro.serve``) — the ISSUE-7 contracts.

The load-bearing assertions:

* snapshot ISOLATION: a published snapshot is bit-stable under continued
  training, and a training run with serving readers attached is BITWISE
  identical to one without (the double-buffered swap never touches
  training state);
* bf16 snapshots are exactly HALF the fp32 resident bytes — live
  (``PosteriorSnapshot.nbytes``) and modeled (``serve_roofline``);
* the staleness SLO refuses (strict) or flags (policy="flag") answers
  from a snapshot older than ``max_staleness`` windows;
* the padding-bucket apply cache compiles one program per touched
  ``(bucket, shape, mc)`` key — trace count pinned, replays add zero.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    RunSpec,
    ServeSpec,
    TopologySpec,
    build_session,
)
from repro.launch.costmodel import serve_roofline
from repro.serve import (
    PosteriorSnapshot,
    SnapshotStore,
    StalenessSLOError,
)

N_AGENTS = 3


def _tiny_spec(n_rounds=3, seed=0, serve=None, gossip=True):
    """3-agent ring (gossip: snapshots carry real staleness telemetry) or
    star (synchronous), dim-8 3-class task — seconds on CPU."""
    if gossip:
        topo = TopologySpec.gossip("ring", {"n": N_AGENTS})
        data = DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
            partition_params=dict(n_agents=N_AGENTS),
            batch_size=4, local_updates=2,
        )
    else:
        topo = TopologySpec.star(n_edge=2, a=0.5)
        data = DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
            partition="star",
            partition_params=dict(center_labels=[1, 2], edge_labels=[0],
                                  n_edge=2),
            batch_size=4, local_updates=2,
        )
    return ExperimentSpec(
        topology=topo,
        data=data,
        inference=InferenceSpec(hidden=8, depth=1, lr=1e-2),
        run=RunSpec(n_rounds=n_rounds, seed=seed),
        serve=serve or ServeSpec(),
    )


@pytest.fixture(scope="module")
def trained():
    sess = build_session(_tiny_spec())
    sess.run()
    return sess


# ---------------------------------------------------------------------------
# snapshot isolation
# ---------------------------------------------------------------------------


def test_snapshot_bit_stable_under_training():
    """ISSUE acceptance (a-half): mutating training state after snapshot()
    never changes the snapshot's buffers or served outputs."""
    sess = build_session(_tiny_spec())
    sess.run()
    snap = sess.snapshot()
    mean0 = np.asarray(snap.posterior.mean).copy()
    rho0 = np.asarray(snap.posterior.rho).copy()
    # mc=0: the deterministic point estimate — any drift in served outputs
    # can only come from the snapshot buffers themselves
    server = sess.attach_server(mc_samples=0, bucket_sizes=(4,))
    x = np.asarray(sess.data.x_test[:4])
    probs0, _ = server.query(x, agent=0)
    probs0 = np.asarray(probs0).copy()

    sess.run(n_rounds=3)  # trains on — the published snapshot must not move
    assert not np.array_equal(
        np.asarray(sess.posterior().mean), mean0
    ), "training should have moved the live posterior"
    np.testing.assert_array_equal(np.asarray(snap.posterior.mean), mean0)
    np.testing.assert_array_equal(np.asarray(snap.posterior.rho), rho0)
    probs1, _ = server.query(x, agent=0)
    np.testing.assert_array_equal(np.asarray(probs1), probs0)


def test_training_bitwise_identical_with_serving_attached():
    """ISSUE acceptance (a): the training trajectory with a serving reader
    attached (snapshots published + queries served mid-run) is BITWISE the
    trajectory without one."""
    plain = build_session(_tiny_spec(n_rounds=0))
    served = build_session(_tiny_spec(n_rounds=0))
    server = None
    x = np.asarray(served.data.x_test[:3])
    for r in range(4):
        plain.round()
        served.round()
        # reader activity between every round: publish + serve
        served.snapshot(dtype="bf16" if r % 2 else "f32")
        if server is None:
            server = served.attach_server(mc_samples=2, bucket_sizes=(2, 4))
        server.query(x, agent=r % N_AGENTS)
    p, s = plain.posterior(), served.posterior()
    np.testing.assert_array_equal(np.asarray(p.mean), np.asarray(s.mean))
    np.testing.assert_array_equal(np.asarray(p.rho), np.asarray(s.rho))
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(plain.key)),
        np.asarray(jax.random.key_data(served.key)),
    )


def test_double_buffer_swap_keeps_old_reader():
    """A reader holding the previous snapshot keeps serving it after a new
    publish (the double buffer's whole point)."""
    sess = build_session(_tiny_spec())
    sess.run()
    old = sess.snapshot()
    sess.run(n_rounds=2)
    new = sess.snapshot()
    assert new.version == old.version + 1
    assert sess.serve_store.current() is new
    # the old reference is untouched and distinct
    assert old.window != new.window
    assert not np.array_equal(
        np.asarray(old.posterior.mean), np.asarray(new.posterior.mean)
    )


# ---------------------------------------------------------------------------
# bf16 residency
# ---------------------------------------------------------------------------


def test_bf16_snapshot_halves_live_and_modeled_hbm(trained):
    """ISSUE acceptance (b): bf16 snapshots halve the snapshot HBM — in the
    live buffers and in serve_roofline's model."""
    s32 = trained.snapshot(dtype="f32")
    s16 = trained.snapshot(dtype="bf16")
    assert s32.nbytes() == 2 * s16.nbytes()
    assert s16.posterior.mean.dtype == jnp.bfloat16
    n_params = int(s32.posterior.mean.shape[1])
    r32 = serve_roofline(N_AGENTS, n_params, snapshot_dtype="f32")
    r16 = serve_roofline(N_AGENTS, n_params, snapshot_dtype="bf16")
    assert r32["snapshot_hbm_bytes"] == 2 * r16["snapshot_hbm_bytes"]
    assert r16["snapshot_saving_vs_f32"] == 2.0
    # the live resident bytes match the model exactly
    assert s16.nbytes() == r16["snapshot_hbm_bytes"]
    assert s32.nbytes() == r32["snapshot_hbm_bytes"]


def test_bf16_snapshot_serves_close_to_f32(trained):
    """The bf16-resident snapshot decodes to fp32 inside the apply: served
    probabilities stay close to the f32 snapshot's (loose tolerance — bf16
    has ~3 decimal digits)."""
    x = np.asarray(trained.data.x_test[:6])
    trained.snapshot(dtype="f32")
    server = trained.attach_server(mc_samples=0, bucket_sizes=(8,))
    p32, _ = server.query(x, agent=0)
    trained.snapshot(dtype="bf16")
    p16, _ = server.query(x, agent=0)
    np.testing.assert_allclose(
        np.asarray(p32), np.asarray(p16), atol=5e-2
    )
    np.testing.assert_allclose(np.asarray(p16).sum(-1), 1.0, atol=1e-3)


def test_f32_snapshot_is_identity_dtype(trained):
    snap = trained.snapshot(dtype="f32")
    assert snap.dtype == "f32"
    assert snap.posterior.mean.dtype == jnp.float32
    np.testing.assert_array_equal(
        np.asarray(snap.posterior.mean), np.asarray(trained.posterior().mean)
    )


# ---------------------------------------------------------------------------
# staleness SLO
# ---------------------------------------------------------------------------


def test_staleness_slo_strict_refuses():
    """ISSUE acceptance (c): a snapshot older than max_staleness windows is
    refused under the strict policy."""
    sess = build_session(_tiny_spec(
        serve=ServeSpec(max_staleness=2, staleness_policy="strict",
                        mc_samples=1),
    ))
    sess.run()
    sess.snapshot()
    server = sess.attach_server()
    x = np.asarray(sess.data.x_test[:2])
    probs, meta = server.query(x)  # age 0: fine
    assert meta["slo_ok"] and meta["snapshot_age"] == 0
    sess.run(n_rounds=2)
    _, meta = server.query(x)  # age 2 == bound: still fine
    assert meta["slo_ok"] and meta["snapshot_age"] == 2
    sess.run(n_rounds=1)
    with pytest.raises(StalenessSLOError, match="3 windows stale"):
        server.query(x)
    assert server.n_slo_breaches == 1
    # republishing restores service
    sess.snapshot()
    _, meta = server.query(x)
    assert meta["slo_ok"] and meta["snapshot_age"] == 0


def test_staleness_slo_flag_serves_marked():
    """ISSUE acceptance (c): policy="flag" serves the stale answer but marks
    it slo_ok=False and counts the breach."""
    sess = build_session(_tiny_spec(
        serve=ServeSpec(max_staleness=1, staleness_policy="flag",
                        mc_samples=1),
    ))
    sess.run()
    sess.snapshot()
    server = sess.attach_server()
    sess.run(n_rounds=3)
    x = np.asarray(sess.data.x_test[:2])
    probs, meta = server.query(x)
    assert not meta["slo_ok"]
    assert meta["snapshot_age"] == 3
    assert server.n_slo_breaches == 1
    np.testing.assert_allclose(np.asarray(probs).sum(-1), 1.0, atol=1e-5)


def test_unbounded_slo_never_breaches(trained):
    trained.snapshot()
    server = trained.attach_server(max_staleness=None, mc_samples=0,
                                   bucket_sizes=(4,))
    ok, age = server.check_slo()
    assert ok and server.n_slo_breaches == 0


def test_query_before_publish_raises():
    sess = build_session(_tiny_spec(n_rounds=0))
    server = sess.attach_server()
    with pytest.raises(RuntimeError, match="no snapshot published"):
        server.query(np.zeros((2, 8), np.float32))


# ---------------------------------------------------------------------------
# padding buckets + the compiled-once apply cache
# ---------------------------------------------------------------------------


def test_bucket_trace_count_pinned(trained):
    """ISSUE satellite: arbitrary ragged request streams hit a SMALL fixed
    set of compiled programs — one trace per touched (bucket, shape, mc)
    key, zero retraces on replay."""
    trained.snapshot(dtype="f32")
    server = trained.attach_server(mc_samples=2, bucket_sizes=(2, 4, 8))
    x = np.asarray(trained.data.x_test)
    stream = [x[: n % 9 + 1] for n in range(17)]  # sizes 1..9, ragged
    for rows in stream:
        server.query(rows, agent=0)
    # sizes 1..9 under buckets (2,4,8): plans touch buckets {2, 4, 8} only
    assert server.n_traces == 3
    before = server.n_traces
    for rows in stream:  # replay: every program already compiled
        server.query(rows, agent=1)  # different agent row: same programs
    assert server.n_traces == before
    # a new mc size touches the same buckets -> new keys, new traces
    server.query(x[:5], agent=0, mc_samples=5)
    assert server.n_traces == before + 1  # plan for 5 rows: one slab of 8


def test_bucket_plan_shapes(trained):
    trained.snapshot()
    server = trained.attach_server(bucket_sizes=(2, 4, 8))
    assert server._bucket_plan(0) == []
    assert server._bucket_plan(1) == [2]
    assert server._bucket_plan(8) == [8]
    assert server._bucket_plan(9) == [8, 2]
    assert server._bucket_plan(21) == [8, 8, 8]  # 16 full + 5 -> pad to 8


def test_request_reassembly_matches_unbatched(trained):
    """Micro-batched ragged requests come back per request, in order, equal
    to serving each alone (same snapshot, mc=0 so no key sensitivity)."""
    trained.snapshot(dtype="f32")
    server = trained.attach_server(mc_samples=0, bucket_sizes=(2, 4))
    x = np.asarray(trained.data.x_test)
    reqs = [x[:3], x[3:4], x[4:9]]
    outs, _ = server.serve(reqs, agents=[0, 1, 0])
    for r, out in zip(reqs, outs):
        assert out.shape == (r.shape[0], 3)
    solo0, _ = server.query(reqs[0], agent=0)
    np.testing.assert_allclose(
        np.asarray(outs[0]), np.asarray(solo0), rtol=1e-6, atol=1e-7
    )
    solo1, _ = server.query(reqs[1], agent=1)
    np.testing.assert_allclose(
        np.asarray(outs[1]), np.asarray(solo1), rtol=1e-6, atol=1e-7
    )


def test_point_estimate_matches_session_predictive(trained):
    """The served L=0 path is the Session's own n_mc=0 point estimate."""
    trained.snapshot(dtype="f32")
    server = trained.attach_server(mc_samples=0, bucket_sizes=(8,))
    x = np.asarray(trained.data.x_test[:6])
    for agent in range(N_AGENTS):
        served, _ = server.query(x, agent=agent)
        direct = trained.predictive(agent, x, n_mc=0)
        np.testing.assert_allclose(
            np.asarray(served), np.asarray(direct), rtol=1e-6, atol=1e-7
        )


def test_bad_requests_rejected(trained):
    trained.snapshot()
    server = trained.attach_server(bucket_sizes=(4,))
    x = np.zeros((2, 8), np.float32)
    with pytest.raises(ValueError, match="agent 7 out of range"):
        server.query(x, agent=7)
    with pytest.raises(ValueError, match="agent ids"):
        server.serve([x, x], agents=[0])
    with pytest.raises(ValueError, match="wrap single rows"):
        server.serve([np.zeros((8,), np.float32)])
    with pytest.raises(ValueError, match="ascending"):
        trained.attach_server(bucket_sizes=(4, 2))
    with pytest.raises(ValueError, match="staleness_policy"):
        trained.attach_server(staleness_policy="maybe")


# ---------------------------------------------------------------------------
# spec plumbing + telemetry + checkpoints
# ---------------------------------------------------------------------------


def test_serve_spec_validation_and_doc_roundtrip():
    spec = _tiny_spec(serve=ServeSpec(
        snapshot_dtype="bf16", mc_samples=4, bucket_sizes=[2, 8],
        max_staleness=3, staleness_policy="flag",
    ))
    spec.validate()
    assert spec.serve.bucket_sizes == (2, 8)  # list normalized to tuple
    spec2 = ExperimentSpec.from_doc(spec.to_doc())
    assert spec2.serve == spec.serve
    # a pre-serving checkpoint doc (no "serve" key) gets the defaults
    doc = spec.to_doc()
    del doc["serve"]
    spec3 = ExperimentSpec.from_doc(doc)
    assert spec3.serve == ServeSpec()
    for bad in (
        ServeSpec(snapshot_dtype="f64"),
        ServeSpec(mc_samples=-1),
        ServeSpec(bucket_sizes=()),
        ServeSpec(bucket_sizes=(4, 4)),
        ServeSpec(max_staleness=-2),
        ServeSpec(staleness_policy="never"),
    ):
        with pytest.raises(ValueError):
            bad.validate()


def test_snapshot_carries_gossip_telemetry(trained):
    snap = trained.snapshot()
    assert snap.telemetry["window"] == trained.round_idx
    assert "staleness" in snap.telemetry
    assert {"p50", "p90", "max"} <= set(snap.telemetry["staleness"])
    assert snap.telemetry["merges_total"] >= 0


def test_evaluate_exposes_serving_block():
    """ISSUE satellite: Session.evaluate() surfaces the serving telemetry
    (snapshot age, SLO breaches) next to the staleness/fault metrics."""
    sess = build_session(_tiny_spec(
        serve=ServeSpec(max_staleness=0, staleness_policy="flag",
                        mc_samples=1),
    ))
    sess.run()
    assert "serving" not in sess.evaluate(n_mc=1)  # no tier attached yet
    sess.snapshot()
    server = sess.attach_server()
    sess.run(n_rounds=1)
    server.query(np.asarray(sess.data.x_test[:2]))  # 1 window stale: breach
    out = sess.evaluate(n_mc=1)
    serving = out["serving"]
    assert serving["slo"]["breaches"] == 1
    assert serving["snapshot_age"] == 1
    assert serving["published"] == 1
    assert serving["requests"] == 1
    # the gossip block still rides alongside, namespaced under "engine"
    # (PR 8: engine telemetry no longer splats into the top level)
    assert "staleness" in out["engine"]


def test_snapshot_checkpoint_roundtrip(tmp_path, trained):
    """save/restore_snapshot round-trips both residencies bit-exactly,
    provenance included."""
    for dt in ("f32", "bf16"):
        snap = trained.snapshot(dtype=dt)
        path = os.path.join(tmp_path, f"snap_{dt}.ckpt")
        snap.save(path)
        back = PosteriorSnapshot.load(path)
        assert back.dtype == dt
        assert back.window == snap.window
        assert back.version == snap.version
        assert back.telemetry == snap.telemetry
        assert back.posterior.mean.dtype == snap.posterior.mean.dtype
        np.testing.assert_array_equal(
            np.asarray(back.posterior.mean.astype(jnp.float32)),
            np.asarray(snap.posterior.mean.astype(jnp.float32)),
        )
        assert (back.posterior.layout.to_doc()
                == snap.posterior.layout.to_doc())
    with pytest.raises(ValueError, match="not a posterior-snapshot"):
        trained.save(os.path.join(tmp_path, "sess.ckpt"))
        PosteriorSnapshot.load(os.path.join(tmp_path, "sess.ckpt"))


def test_store_age_and_version():
    store = SnapshotStore()
    with pytest.raises(RuntimeError, match="no snapshot published"):
        store.current()
    assert store.telemetry() == {"published": 0}
    sess = build_session(_tiny_spec(n_rounds=0))
    sess.round()
    snap = sess.snapshot()
    st = sess.serve_store
    assert st.age() == 0
    sess.round()
    sess.round()
    assert st.age() == 2
    assert st.age(now=10) == 9
    sess.snapshot()
    assert st.version == 2 and st.age() == 0


def test_synchronous_engine_serves_too():
    """The serving tier is engine-agnostic: the synchronous star engine has
    no gossip telemetry but snapshots and serves the same way."""
    sess = build_session(_tiny_spec(gossip=False))
    sess.run()
    snap = sess.snapshot(dtype="bf16")
    assert snap.telemetry == {}  # no snapshot_meta hook on this engine
    server = sess.attach_server(mc_samples=1, bucket_sizes=(4,))
    probs, meta = server.query(np.asarray(sess.data.x_test[:3]), agent=1)
    assert np.asarray(probs).shape == (3, 3)
    assert meta["slo_ok"]


def test_conjugate_linreg_has_no_serving_path():
    spec = ExperimentSpec(
        topology=TopologySpec.complete(4),
        data=DataSpec(dataset="linreg", batch_size=10),
        inference=InferenceSpec(method="conjugate_linreg"),
        run=RunSpec(n_rounds=1, seed=0),
    )
    sess = build_session(spec)
    sess.run()
    with pytest.raises(ValueError, match="serves flat"):
        sess.snapshot()
    with pytest.raises(ValueError, match="classification model"):
        sess.attach_server()
