"""Per-kernel allclose sweeps (shapes x dtypes) against the ref.py oracles,
interpret=True on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.posterior import GaussianPosterior, init_posterior
from repro.kernels import ref
from repro.kernels.consensus import consensus_fused
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gauss_vi import sample_and_kl_fused
from repro.kernels.ops import consensus_posterior, sample_and_kl


@pytest.mark.parametrize("n", [1, 3, 9, 16])
@pytest.mark.parametrize("p", [17, 2048, 5000])
def test_consensus_kernel_shapes(n, p):
    ks = jax.random.split(jax.random.key(p * 31 + n), 3)
    w = jax.nn.softmax(jax.random.normal(ks[0], (n,)))
    mean = jax.random.normal(ks[1], (n, p))
    rho = jax.random.normal(ks[2], (n, p)) * 0.5 - 1.0
    mo, ro = consensus_fused(w, mean, rho, block=1024)
    mr, rr = ref.consensus_ref(w, mean, rho)
    np.testing.assert_allclose(mo, mr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ro, rr, rtol=1e-5, atol=1e-5)


def test_consensus_kernel_sparse_weights():
    """Zero-weight neighbors (sparse topologies) contribute nothing."""
    n, p = 4, 300
    ks = jax.random.split(jax.random.key(0), 2)
    mean = jax.random.normal(ks[0], (n, p))
    rho = jax.random.normal(ks[1], (n, p)) * 0.3
    w = jnp.asarray([0.5, 0.5, 0.0, 0.0])
    mo, ro = consensus_fused(w, mean, rho)
    mr, rr = ref.consensus_ref(w[:2], mean[:2], rho[:2])
    np.testing.assert_allclose(mo, mr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ro, rr, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("p", [5, 1000, 4096, 10000])
def test_gauss_vi_kernel(p):
    ks = jax.random.split(jax.random.key(p), 5)
    mu = jax.random.normal(ks[0], (p,))
    rho = jax.random.normal(ks[1], (p,)) * 0.3 - 1.0
    eps = jax.random.normal(ks[2], (p,))
    mu_p = jax.random.normal(ks[3], (p,)) * 0.1
    rho_p = jax.random.normal(ks[4], (p,)) * 0.1
    th, kl = sample_and_kl_fused(mu, rho, eps, mu_p, rho_p, block=512)
    thr, klr = ref.sample_and_kl_ref(mu, rho, eps, mu_p, rho_p)
    np.testing.assert_allclose(th, thr, rtol=1e-5, atol=1e-6)
    assert np.isclose(float(kl), float(klr), rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "s,bq,bk,causal,window",
    [
        (128, 64, 64, True, 0),
        (128, 128, 64, False, 0),
        (256, 64, 64, True, 100),
        (256, 128, 128, True, 0),
        (64, 64, 64, True, 16),
    ],
)
def test_flash_attention_sweep(dtype, s, bq, bk, causal, window):
    ks = jax.random.split(jax.random.key(s + bq), 3)
    hd = 64
    q = jax.random.normal(ks[0], (2, 2, s, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (2, 2, s, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (2, 2, s, hd)).astype(dtype)
    o = flash_attention(q, k, v, causal=causal, window=window, block_q=bq, block_k=bk)
    r = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_matches_chunked_model_path():
    """The Pallas kernel and the model's pure-JAX chunked path agree."""
    from repro.models.attention import chunked_attention

    ks = jax.random.split(jax.random.key(7), 3)
    b, h, s, hd = 2, 3, 128, 32
    q = jax.random.normal(ks[0], (b, h, s, hd))
    k = jax.random.normal(ks[1], (b, h, s, hd))
    v = jax.random.normal(ks[2], (b, h, s, hd))
    o_pallas = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    o_chunked = chunked_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True, chunk_size=64,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(o_pallas, o_chunked, atol=1e-5, rtol=1e-5)


def test_ops_consensus_posterior_pytree():
    """ops.consensus_posterior == core consensus on a full pytree."""
    from repro.core.posterior import consensus_mean_field

    n = 4
    params = {"a": jnp.zeros((3, 5)), "b": jnp.zeros((7,))}
    stacked = jax.tree.map(lambda p: jnp.zeros((n,) + p.shape), params)
    rng = np.random.default_rng(0)
    posts = GaussianPosterior(
        mean=jax.tree.map(lambda p: jnp.asarray(rng.normal(size=p.shape), jnp.float32), stacked),
        rho=jax.tree.map(lambda p: jnp.asarray(rng.normal(size=p.shape) * 0.3, jnp.float32), stacked),
    )
    w = jnp.asarray([0.4, 0.3, 0.2, 0.1])
    out_k = consensus_posterior(posts, w, interpret=True)
    out_r = consensus_mean_field(posts, w)
    for ka in ("a", "b"):
        np.testing.assert_allclose(out_k.mean[ka], out_r.mean[ka], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(out_k.rho[ka], out_r.rho[ka], rtol=1e-4, atol=1e-4)


def test_ops_sample_and_kl_pytree():
    params = {"w": jnp.zeros((10, 3)), "b": jnp.zeros((4,))}
    post = init_posterior(
        jax.tree.map(lambda p: p + 0.3, params), init_sigma=0.2
    )
    prior = init_posterior(params, init_sigma=0.1)
    theta, kl = sample_and_kl(post, prior, jax.random.key(0), interpret=True)
    from repro.core.posterior import kl_gaussian

    assert jax.tree.structure(theta) == jax.tree.structure(params)
    assert np.isclose(float(kl), float(kl_gaussian(post, prior)), rtol=1e-4)
