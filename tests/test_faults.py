"""Fault-tolerant gossip: churn, corruption, and the quarantine guard.

The ladder this file pins (ROADMAP "Robustness"):

* the fault model is DETERMINISTIC — every crash/corruption decision is a
  pure function of (fault seed, round), so resumed sessions regenerate the
  identical schedule and a crashed-and-resumed run is bit-identical to an
  uninterrupted one;
* a crashed agent freezes: no local training, no fired edges, W-tilde row
  exactly e_i (the conserve rule keeps every row row-stochastic);
* under ``fault_policy="quarantine"`` injected NaN/Inf/huge payloads NEVER
  reach a healthy resident posterior, on every consensus execution;
* with ZERO faults the quarantined path is BITWISE identical to strict on
  every execution (dense masked, sparse masked, delayed; the sharded
  ppermute rung runs under the ``multidevice`` marker);
* the Pallas validity kernel agrees with the XLA reference exactly.
"""
import dataclasses
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flat import (
    FlatLayout,
    FlatPosterior,
    consensus_flat_masked,
    consensus_flat_masked_quarantined,
    consensus_flat_masked_sparse,
    consensus_flat_masked_sparse_quarantined,
    neighbor_tables,
    payload_validity,
    quarantine_w,
)
from repro.core.graphs import bidirectional_ring_w
from repro.core.posterior import softplus
from repro.gossip.clocks import PoissonClock, build_clock
from repro.gossip.faults import FaultModel, FaultSpec


def _posts(n, p, seed=0):
    rng = np.random.default_rng(seed)
    layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
    return FlatPosterior(
        mean=jnp.asarray(rng.normal(size=(n, p)), jnp.float32),
        rho=jnp.asarray(rng.normal(size=(n, p)) * 0.4 - 1.0, jnp.float32),
        layout=layout,
    )


def _mkspec(policy="strict", faults=None, clock=None, n=5, n_rounds=4,
            **inf_kw):
    from repro.api import (
        DataSpec, ExperimentSpec, InferenceSpec, RunSpec, TopologySpec,
    )

    clock = dict(clock or {"kind": "poisson", "rate": 0.8, "seed": 3})
    if faults is not None:
        clock["faults"] = dict(faults)
    return ExperimentSpec(
        topology=TopologySpec.gossip("bidirectional_ring", {"n": n},
                                     clock=clock),
        data=DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
            partition="iid", partition_params=dict(n_agents=n),
            batch_size=4, local_updates=2,
        ),
        inference=InferenceSpec(hidden=8, depth=1, lr=1e-2,
                                fault_policy=policy, **inf_kw),
        run=RunSpec(n_rounds=n_rounds, seed=0),
    )


_FAULTS = {"crash_rate": 0.25, "recover_rate": 0.5, "corrupt_rate": 0.3,
           "corrupt_kind": "mix", "seed": 7}


# ---------------------------------------------------------------------------
# fault model: determinism, Markov semantics, spec validation
# ---------------------------------------------------------------------------


def test_fault_stream_is_pure_function_of_seed_and_round():
    """Two independently built models replay the identical schedule, and
    the access ORDER (sequential vs random, fresh vs warm memo) is
    irrelevant — the resume contract."""
    spec = FaultSpec(crash_rate=0.3, recover_rate=0.4, corrupt_rate=0.5,
                     seed=11)
    a = FaultModel(spec, 6)
    b = FaultModel(spec, 6)
    rounds = [9, 0, 4, 9, 2, 7]  # deliberately out of order on b
    for r in sorted(set(rounds)):
        _ = a.up(r)
    for r in rounds:
        np.testing.assert_array_equal(a.up(r), b.up(r))
        np.testing.assert_array_equal(a.corrupted(r), b.corrupted(r))
        fm_a, fr_a = a.fills(r)
        fm_b, fr_b = b.fills(r)
        np.testing.assert_array_equal(fm_a, fm_b)
        np.testing.assert_array_equal(fr_a, fr_b)


def test_fault_model_markov_semantics():
    """All agents start UP; crash_rate=0 never crashes; crash_rate>0 with
    recover_rate=1 means every down spell lasts exactly one window."""
    n = 8
    none = FaultModel(FaultSpec(), n)
    assert all(none.up(r).all() for r in range(5))
    assert not none.corrupted(3).any()
    flappy = FaultModel(
        FaultSpec(crash_rate=0.5, recover_rate=1.0, seed=3), n
    )
    assert flappy.up(0).all()
    for r in range(1, 12):
        down_prev = ~flappy.up(r - 1)
        # recover_rate=1: every agent down at r-1 is up at r
        assert flappy.up(r)[down_prev].all()
    # corruption only hits UP agents
    noisy = FaultModel(
        FaultSpec(crash_rate=0.4, recover_rate=0.3, corrupt_rate=0.9,
                  seed=5), n
    )
    for r in range(8):
        assert not (noisy.corrupted(r) & noisy.crashed(r)).any()


def test_fault_spec_validation_and_doc_roundtrip():
    with pytest.raises(ValueError, match="crash_rate"):
        FaultSpec(crash_rate=1.0).validate()
    with pytest.raises(ValueError, match="recover_rate"):
        FaultSpec(crash_rate=0.2, recover_rate=0.0).validate()
    with pytest.raises(ValueError, match="corrupt_kind"):
        FaultSpec(corrupt_kind="zeros").validate()
    with pytest.raises(ValueError, match="unknown FaultSpec keys"):
        FaultSpec.from_doc({"crash_rate": 0.1, "typo": 1})
    spec = FaultSpec(crash_rate=0.2, recover_rate=0.7, corrupt_rate=0.1,
                     corrupt_kind="nan", seed=9)
    assert FaultSpec.from_doc(spec.to_doc()) == spec


def test_faults_rejected_on_inner_clock_doc():
    """The fault model must sit on the OUTERMOST clock (wrappers bypass the
    inner clock's window construction) — loud error, not silent no-op."""
    W = bidirectional_ring_w(4)
    with pytest.raises(ValueError, match="OUTERMOST"):
        build_clock(
            {"kind": "failure_injected", "drop_rate": 0.1,
             "inner": {"kind": "poisson", "rate": 1.0,
                       "faults": {"crash_rate": 0.1}}},
            W,
        )
    # on the outermost doc it attaches fine, wrapper or not
    clock = build_clock(
        {"kind": "failure_injected", "drop_rate": 0.1,
         "inner": {"kind": "poisson", "rate": 1.0},
         "faults": {"crash_rate": 0.1, "seed": 2}},
        W,
    )
    assert clock.faults is not None


def test_crashed_agent_rows_are_identity_and_row_stochastic():
    """Clock-level churn: a crashed agent fires nothing and receives
    nothing — its W-tilde row is EXACTLY e_i — and every row of every
    window stays row-stochastic."""
    W = bidirectional_ring_w(6)
    clock = PoissonClock(W, rate=1.5, seed=1)
    clock.attach_faults(FaultModel(
        FaultSpec(crash_rate=0.4, recover_rate=0.5, seed=13), 6
    ))
    saw_crash = False
    for r in range(12):
        win = clock.window(r)
        crashed = clock.crashed(r)
        saw_crash |= bool(crashed.any())
        np.testing.assert_allclose(win.w_eff.sum(axis=1), 1.0, atol=1e-12)
        np.testing.assert_array_equal(
            win.w_eff[crashed], np.eye(6)[crashed]
        )
        assert not win.active[crashed].any()
    assert saw_crash  # the regime actually exercised a crash


# ---------------------------------------------------------------------------
# quarantine guard: validity, hand-computed window, kernel parity
# ---------------------------------------------------------------------------


def test_payload_validity_flags():
    p = 6
    layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
    mean = np.zeros((5, p), np.float32)
    rho = np.zeros((5, p), np.float32)
    mean[1, 2] = np.nan          # non-finite prec*mu
    mean[2, 0] = np.inf          # non-finite prec*mu
    mean[3, 4] = 1.0e30          # finite but beyond the magnitude bound
    rho[4, 1] = np.nan           # non-finite prec
    ok = np.asarray(payload_validity(jnp.asarray(mean), jnp.asarray(rho)))
    np.testing.assert_array_equal(ok, [True, False, False, False, False])
    del layout


def test_payload_validity_fused_matches_xla_reference():
    """The Pallas single-pass validity kernel (interpret mode on CPU) is
    bit-equal to the XLA reference, including on garbage inputs."""
    rng = np.random.default_rng(5)
    n, p = 6, 512
    mean = rng.normal(size=(n, p)).astype(np.float32)
    rho = (rng.normal(size=(n, p)) * 0.4).astype(np.float32)
    mean[1, 100] = np.nan
    mean[2, 0] = np.inf
    mean[3, 511] = 5.0e29  # large but within bound * prec scale
    rho[4, 7] = np.inf     # prec -> 0: positivity violation
    ref = payload_validity(jnp.asarray(mean), jnp.asarray(rho), mode="xla")
    got = payload_validity(
        jnp.asarray(mean), jnp.asarray(rho), mode="interpret", block=128
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_quarantine_w_reassigns_dropped_mass_to_self():
    rng = np.random.default_rng(3)
    n = 5
    W = rng.random((n, n)) + 0.1
    W = (W / W.sum(1, keepdims=True)).astype(np.float32)
    valid = np.array([True, False, True, True, False])
    Wq = np.asarray(quarantine_w(jnp.asarray(W), jnp.asarray(valid)))
    np.testing.assert_allclose(Wq.sum(axis=1), 1.0, atol=1e-6)
    # invalid columns zeroed everywhere except the self-loop
    for j in np.nonzero(~valid)[0]:
        off = [i for i in range(n) if i != j]
        assert (Wq[off, j] == 0.0).all()
        assert Wq[j, j] > 0.0  # an agent never quarantines itself
    # all-valid is the identity
    np.testing.assert_array_equal(
        np.asarray(quarantine_w(jnp.asarray(W),
                                jnp.ones(n, bool))), W
    )


def test_quarantined_window_hand_computed_three_agents():
    """A 3-agent window with agent 2's WIRE payload poisoned: receivers 0
    and 1 must reproduce the hand-derived eq.-(6) merge with agent 2's
    weight moved to their self-loops; agent 2's own resident state (still
    healthy — only its transmission was garbage) merges from its TRUE
    stats and the healthy neighbors."""
    n, p = 3, 4
    posts = _posts(n, p, seed=42)
    W = jnp.asarray(
        [[0.6, 0.2, 0.2], [0.3, 0.5, 0.2], [0.25, 0.25, 0.5]], jnp.float32
    )
    active = jnp.ones((n,), bool)
    mean_src = posts.mean.at[2].set(jnp.nan)  # poisoned transmission
    rho_src = posts.rho
    out, valid = consensus_flat_masked_quarantined(
        posts, W, active, mean_src=mean_src, rho_src=rho_src
    )
    np.testing.assert_array_equal(np.asarray(valid), [True, True, False])

    mean = np.asarray(posts.mean, np.float64)
    sig = np.asarray(softplus(posts.rho), np.float64)
    prec = 1.0 / sig**2
    Wq = np.asarray(W, np.float64).copy()
    for i in range(n):
        if i != 2:
            Wq[i, i] += Wq[i, 2]
            Wq[i, 2] = 0.0
    # agent 2's own row: its self-contribution falls back to its TRUE
    # resident stats (it is healthy; only the wire copy was poisoned)
    exp_prec = Wq @ prec
    exp_mean = (Wq @ (prec * mean)) / exp_prec
    got_sig = np.asarray(softplus(out.rho), np.float64)
    np.testing.assert_allclose(np.asarray(out.mean), exp_mean,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(1.0 / got_sig**2, exp_prec,
                               rtol=1e-4, atol=1e-5)
    assert np.isfinite(np.asarray(out.mean)).all()


def test_zero_fault_quarantine_bitwise_dense_and_sparse_kernels():
    """Kernel rung of the ladder: with every payload valid the quarantined
    wrappers are BITWISE the plain masked kernels (dense and sparse, xla
    and interpreted-Pallas modes)."""
    n, p = 6, 256
    posts = _posts(n, p, seed=9)
    win = PoissonClock(bidirectional_ring_w(n), rate=0.7, seed=2).window(0)
    W = jnp.asarray(win.w_eff, jnp.float32)
    active = jnp.asarray(win.active)
    for mode in ("xla", "interpret"):
        ref = consensus_flat_masked(posts, W, active, mode=mode, block=128)
        got, valid = consensus_flat_masked_quarantined(
            posts, W, active, mode=mode, block=128
        )
        assert bool(jnp.all(valid))
        np.testing.assert_array_equal(np.asarray(got.mean),
                                      np.asarray(ref.mean))
        np.testing.assert_array_equal(np.asarray(got.rho),
                                      np.asarray(ref.rho))
    neighbors, weights = neighbor_tables(np.asarray(win.w_eff))
    for mode in ("xla", "interpret"):
        ref = consensus_flat_masked_sparse(
            posts, jnp.asarray(neighbors), jnp.asarray(weights, jnp.float32),
            active, mode=mode, block=128,
        )
        got, valid = consensus_flat_masked_sparse_quarantined(
            posts, jnp.asarray(neighbors), jnp.asarray(weights, jnp.float32),
            active, mode=mode, block=128,
        )
        assert bool(jnp.all(valid))
        np.testing.assert_array_equal(np.asarray(got.mean),
                                      np.asarray(ref.mean))
        np.testing.assert_array_equal(np.asarray(got.rho),
                                      np.asarray(ref.rho))


def test_sparse_quarantine_drops_invalid_neighbor_mass_to_self():
    n, p = 5, 32
    posts = _posts(n, p, seed=21)
    win = PoissonClock(bidirectional_ring_w(n), rate=2.0, seed=4).window(0)
    W = jnp.asarray(win.w_eff, jnp.float32)
    active = jnp.asarray(win.active)
    mean_src = posts.mean.at[1].set(jnp.inf)
    neighbors, weights = neighbor_tables(np.asarray(win.w_eff))
    got_s, valid_s = consensus_flat_masked_sparse_quarantined(
        posts, jnp.asarray(neighbors), jnp.asarray(weights, jnp.float32),
        active, mean_src=mean_src, rho_src=posts.rho,
    )
    got_d, valid_d = consensus_flat_masked_quarantined(
        posts, W, active, mean_src=mean_src, rho_src=posts.rho,
    )
    np.testing.assert_array_equal(np.asarray(valid_s), np.asarray(valid_d))
    np.testing.assert_allclose(np.asarray(got_s.mean),
                               np.asarray(got_d.mean), rtol=1e-6, atol=1e-6)
    assert np.isfinite(np.asarray(got_s.mean)).all()


# ---------------------------------------------------------------------------
# engine / session: poison containment, bitwise ladder, resume, telemetry
# ---------------------------------------------------------------------------


def test_quarantine_contains_injection_strict_propagates():
    """Acceptance: under quarantine the injected NaN/Inf NEVER reaches a
    resident posterior; the identical chaos under strict poisons agents —
    the guard, not luck, is doing the work."""
    from repro.api import build_session

    n, rounds = 5, 5
    s_q = build_session(_mkspec("quarantine", _FAULTS, n=n))
    s_s = build_session(_mkspec("strict", _FAULTS, n=n))
    for _ in range(rounds):
        rec = s_q.round()
        if rec["loss"] is not None:
            assert np.isfinite(rec["loss"])
        s_s.round()
    hq, hs = s_q.health(), s_s.health()
    assert hq["all_ok"], f"quarantine leaked garbage: {hq}"
    assert np.isfinite(np.asarray(s_q.posterior().mean)).all()
    assert np.isfinite(np.asarray(s_q.posterior().rho)).all()
    assert hs["n_healthy"] < n, "strict survived: injection too weak"
    # telemetry: the guard counted its drops
    tel = s_q.evaluate(n_mc=1)["engine"]
    assert tel["faults"]["policy"] == "quarantine"
    assert tel["faults"]["quarantined"]["total"] > 0
    assert len(tel["faults"]["uptime"]["per_agent"]) == n


def test_zero_fault_quarantine_bitwise_engine_instant_and_delayed():
    """Engine rung: no fault model => quarantine sessions are BITWISE the
    strict sessions, on the instant-masked AND the delayed-gather paths."""
    from repro.api import build_session

    instant = {"kind": "poisson", "rate": 0.8, "seed": 3}
    delayed = {"kind": "delayed", "max_delay": 2, "seed": 5,
               "inner": instant}
    for clock in (instant, delayed):
        posts = {}
        for policy in ("strict", "quarantine"):
            s = build_session(_mkspec(policy, None, clock=clock))
            for _ in range(4):
                s.round()
            posts[policy] = s.posterior()
        np.testing.assert_array_equal(
            np.asarray(posts["strict"].mean),
            np.asarray(posts["quarantine"].mean),
        )
        np.testing.assert_array_equal(
            np.asarray(posts["strict"].rho),
            np.asarray(posts["quarantine"].rho),
        )


def test_delayed_chaos_quarantine_stays_finite():
    """Delivery latency + churn + corruption + quarantine: the gathered
    stale payloads are validated per EVENT; posteriors stay finite."""
    from repro.api import build_session

    clock = {"kind": "delayed", "max_delay": 2, "seed": 5,
             "inner": {"kind": "poisson", "rate": 0.9, "seed": 3}}
    s = build_session(_mkspec("quarantine", _FAULTS, clock=clock,
                              n_rounds=6))
    for _ in range(6):
        s.round()
    assert s.health()["all_ok"]
    assert int(np.asarray(s.state.n_quarantined).sum()) > 0


def test_crashed_and_resumed_run_is_bit_identical(tmp_path):
    """Acceptance: save mid-run under active churn+corruption, reload, run
    to the end — posterior, quarantine counters and fault schedule all
    BIT-identical to the uninterrupted run (the fault stream is a pure
    function of (seed, round), not of process history)."""
    from repro.api import Session, build_session

    mk = lambda: build_session(_mkspec("quarantine", _FAULTS, n_rounds=6))
    s_ref = mk()
    for _ in range(6):
        s_ref.round()

    s_a = mk()
    for _ in range(3):
        s_a.round()
    path = str(tmp_path / "chaos.ckpt")
    s_a.save(path)
    s_b = Session.load(path)
    crashes = []
    for _ in range(3):
        rec = s_b.round()
        crashes.append(rec["n_crashed"])
    # the resumed process replays the identical crash schedule
    s_c = mk()
    for i in range(6):
        rec = s_c.round()
        if i >= 3:
            assert rec["n_crashed"] == crashes[i - 3]
    np.testing.assert_array_equal(np.asarray(s_b.posterior().mean),
                                  np.asarray(s_ref.posterior().mean))
    np.testing.assert_array_equal(np.asarray(s_b.posterior().rho),
                                  np.asarray(s_ref.posterior().rho))
    np.testing.assert_array_equal(np.asarray(s_b.state.n_quarantined),
                                  np.asarray(s_ref.state.n_quarantined))


def test_session_round_reports_n_crashed_and_nan_safe_loss():
    """Satellite: ``Session.round`` reports n_crashed under a fault model
    and the loss mean excludes crashed agents (their NaN sentinel)."""
    from repro.api import build_session

    faults = {"crash_rate": 0.4, "recover_rate": 0.5, "seed": 13}
    s = build_session(_mkspec("strict", faults))
    saw = False
    for _ in range(5):
        rec = s.round()
        assert rec["n_crashed"] + rec["n_trained"] == 5
        if rec["n_crashed"]:
            saw = True
            assert rec["loss"] is None or np.isfinite(rec["loss"])
    assert saw, "churn regime never crashed an agent in 5 windows"
    # no fault model => the key is absent (dict contract unchanged)
    s0 = build_session(_mkspec("strict", None))
    assert "n_crashed" not in s0.round()


def test_session_health_probe():
    from repro.api import build_session

    s = build_session(_mkspec("strict", None))
    s.round()
    h = s.health()
    assert h == {"ok": [True] * 5, "n_healthy": 5, "all_ok": True}
    # poison one resident posterior by hand: the probe localizes it
    bad = s.state.posterior.mean.at[2, 0].set(jnp.nan)
    s.state = dataclasses.replace(
        s.state, posterior=dataclasses.replace(s.state.posterior, mean=bad)
    )
    h = s.health()
    assert h["ok"] == [True, True, False, True, True]
    assert h["n_healthy"] == 4 and not h["all_ok"]


def test_fault_policy_spec_validation():
    from repro.api import InferenceSpec

    with pytest.raises(ValueError, match="fault_policy"):
        InferenceSpec(fault_policy="lenient").validate()
    with pytest.raises(ValueError, match="quarantine"):
        InferenceSpec(fault_policy="quarantine",
                      consensus="mean_only").validate()
    spec = _mkspec("quarantine", None)
    spec.validate()
    # quarantine without a gossip topology is rejected eagerly
    from repro.api import TopologySpec

    with pytest.raises(ValueError, match="gossip"):
        dataclasses.replace(
            spec, topology=TopologySpec.complete(5)
        ).validate()
    # corruption without a gaussian exchange is rejected at engine build
    from repro.api import build_session

    bad = _mkspec("strict",
                  {"corrupt_rate": 0.5, "seed": 1}, consensus="none")
    with pytest.raises(ValueError, match="corruption"):
        build_session(bad)


def test_strict_no_fault_state_structure_unchanged():
    """Structural gate: a strict no-fault gossip state has NO extra leaves
    (n_quarantined is an empty subtree), so pre-fault checkpoints keep
    loading positionally."""
    from repro.api import build_session

    s = build_session(_mkspec("strict", None))
    assert s.state.n_quarantined is None
    assert not s.engine._guarded
    sq = build_session(_mkspec("quarantine", None))
    assert sq.engine._guarded
    leaves_strict = len(jax.tree.leaves(s.state))
    leaves_q = len(jax.tree.leaves(sq.state))
    assert leaves_q == leaves_strict + 1


# ---------------------------------------------------------------------------
# sharded rung: ppermute quarantine under 8 virtual devices
# ---------------------------------------------------------------------------

_SHARD_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_ppermute_quarantine_zero_fault_bitwise_and_containment():
    """Sharded rung of the ladder: on an 8-virtual-device agent mesh the
    quarantined ppermute window is (a) BITWISE the strict ppermute window
    with every payload valid, and (b) finite + equal to the dense
    quarantined merge when one agent's payload is poisoned."""
    from conftest import run_multidevice_subprocess

    run_multidevice_subprocess(_SHARD_PRELUDE + textwrap.dedent("""
    from repro.core.flat import (FlatLayout, FlatPosterior,
                                 consensus_flat_masked,
                                 consensus_flat_masked_quarantined)
    from repro.core.graphs import bidirectional_ring_w
    from repro.gossip.clocks import PoissonClock

    n, p = 8, 192
    ks = jax.random.split(jax.random.key(0), 2)
    layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
    posts = FlatPosterior(
        mean=jax.random.normal(ks[0], (n, p)),
        rho=jax.random.normal(ks[1], (n, p)) * 0.4 - 1.0,
        layout=layout,
    )
    clock = PoissonClock(bidirectional_ring_w(n), rate=0.7, seed=2)
    for S in (2, 4, 8):
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:S]), ("agents",))
        for r in range(3):
            win = clock.window(r)
            W = jnp.asarray(win.w_eff, jnp.float32)
            act = jnp.asarray(win.active)
            ref = consensus_flat_masked(
                posts, W, act, mode="ppermute", mesh=mesh, axis="agents",
                window=win)
            got, valid = consensus_flat_masked_quarantined(
                posts, W, act, mode="ppermute", mesh=mesh, axis="agents",
                window=win)
            assert bool(jnp.all(valid)), (S, r)
            assert bool(jnp.all(got.mean == ref.mean)), (S, r)
            assert bool(jnp.all(got.rho == ref.rho)), (S, r)
            # poison one agent's wire payload: sharded quarantine must
            # agree with the dense quarantined merge and stay finite
            mean_src = posts.mean.at[3].set(jnp.nan)
            gq, vq = consensus_flat_masked_quarantined(
                posts, W, act, mean_src=mean_src, rho_src=posts.rho,
                mode="ppermute", mesh=mesh, axis="agents", window=win)
            dq, vd = consensus_flat_masked_quarantined(
                posts, W, act, mean_src=mean_src, rho_src=posts.rho)
            assert bool(jnp.all(vq == vd)), (S, r)
            assert bool(jnp.all(jnp.isfinite(gq.mean))), (S, r)
            assert bool(jnp.all(gq.mean == dq.mean)), (S, r)
            assert bool(jnp.all(gq.rho == dq.rho)), (S, r)
    print("OK")
    """))


@pytest.mark.slow
@pytest.mark.multidevice
def test_gossip_engine_ppermute_quarantine_session():
    """Engine level, sharded: a quarantined chaos session on
    consensus_impl='ppermute' stays finite, and its zero-fault twin is
    BITWISE the strict ppermute session."""
    from conftest import run_multidevice_subprocess

    run_multidevice_subprocess(_SHARD_PRELUDE + textwrap.dedent("""
    from repro.api import (DataSpec, ExperimentSpec, InferenceSpec, RunSpec,
                           TopologySpec, build_session)

    n = 8
    def spec(policy, faults):
        clock = {"kind": "poisson", "rate": 0.7, "seed": 3}
        if faults:
            clock["faults"] = dict(faults)
        return ExperimentSpec(
            topology=TopologySpec.gossip("bidirectional_ring", {"n": n},
                                         clock=clock),
            data=DataSpec(
                dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
                partition="iid", partition_params=dict(n_agents=n),
                batch_size=4, local_updates=2),
            inference=InferenceSpec(hidden=8, depth=1, lr=1e-2,
                                    consensus_impl="ppermute",
                                    fault_policy=policy),
            run=RunSpec(n_rounds=3, seed=0),
        )

    posts = {}
    for policy in ("strict", "quarantine"):
        s = build_session(spec(policy, None))
        for _ in range(3):
            s.round()
        posts[policy] = s.posterior()
    assert bool(jnp.all(posts["strict"].mean == posts["quarantine"].mean))
    assert bool(jnp.all(posts["strict"].rho == posts["quarantine"].rho))

    faults = {"crash_rate": 0.25, "recover_rate": 0.5, "corrupt_rate": 0.3,
              "seed": 7}
    s = build_session(spec("quarantine", faults))
    for _ in range(4):
        s.round()
    assert s.health()["all_ok"], s.health()
    tel = s.evaluate(n_mc=1)["engine"]
    assert tel["faults"]["quarantined"]["total"] >= 0
    print("OK")
    """))


def test_edge_keep_mask_matches_per_event_loop():
    """The vectorized edge-list crash filter must agree event-by-event with
    the obvious per-event loop — instant delivery AND lagged fire times."""
    from repro.gossip.faults import edge_keep_mask

    model = FaultModel(
        FaultSpec(crash_rate=0.4, recover_rate=0.5, seed=11), 10)
    rng = np.random.default_rng(5)
    for r in range(3, 8):
        e = 40
        dst = rng.integers(0, 10, e)
        src = rng.integers(0, 10, e)
        lags = rng.integers(0, 3, e)
        got_instant = edge_keep_mask(model, r, dst, src)
        got_lagged = edge_keep_mask(model, r, dst, src, lags=lags)
        up = {k: model.up(k) for k in range(r - 2, r + 1)}
        for i in range(e):
            assert got_instant[i] == (up[r][dst[i]] and up[r][src[i]])
            assert got_lagged[i] == (
                up[r][dst[i]] and up[r - int(lags[i])][src[i]]
            )
        # with crash_rate 0.4 over 40 edges some must drop, some survive
        assert got_lagged.any() and not got_lagged.all()
