import os

# Smoke tests and benches must see 1 CPU device (the dry-run, and ONLY the
# dry-run, sets --xla_force_host_platform_device_count=512 itself).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
