import os
import subprocess
import sys

# Smoke tests and benches must see 1 CPU device (the dry-run, and ONLY the
# dry-run, sets --xla_force_host_platform_device_count=512 itself).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
    config.addinivalue_line(
        "markers",
        "multidevice: spawns an 8-virtual-device XLA subprocess "
        "(deselected from the default tier-1 run via pytest.ini addopts; "
        "CI runs `-m multidevice` as its own step)",
    )


def run_multidevice_subprocess(code: str, timeout: int = 420) -> None:
    """Run ``code`` in a fresh interpreter so it can claim its own XLA
    device count (``--xla_force_host_platform_device_count`` must be set
    before jax initializes; the main pytest process keeps its single CPU
    device).  Shared by the distributed-substrate and sharded-gossip test
    suites — the multi-device harness lives HERE, once."""
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={
            "PYTHONPATH": "src",
            "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
            "JAX_PLATFORMS": "cpu",
            "HOME": os.environ.get("HOME", os.path.expanduser("~")),
        },
        cwd=_REPO_ROOT,
        timeout=timeout,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
