"""End-to-end behaviour tests: the paper's core claims reproduce on
CPU-scale versions of its experiments.

1. Decentralized Bayesian linear regression (Fig 1): with extreme non-IID
   feature partitions, cooperation reaches the centralized MSE; isolation
   does not.
2. Decentralized Bayesian NN classification (Sec 4.2): star network with
   non-overlapping label partitions — cooperating agents predict OOD labels
   far above chance, isolated agents cannot.
3. Eigenvector-centrality phenomenology (Fig 2): higher confidence a on the
   informative center -> better edge accuracy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graphs import complete_w, star_w
from repro.core.posterior import (
    FullCovGaussian,
    consensus_full_cov,
    linreg_bayes_update,
)
from repro.core.simulated import init_network, make_round_fn, run_rounds
from repro.data.linreg import make_linreg_task
from repro.data.partition import star_partition
from repro.data.pipeline import AgentDataset, make_round_batches
from repro.data.synthetic import make_synthetic_classification
from repro.optim import adam
from repro.optim.schedules import exponential_decay
from repro.vi.bayes_by_backprop import mc_predict


def _run_linreg(W, rounds=150, seed=0):
    task = make_linreg_task()
    rng = np.random.default_rng(seed)
    n, d = 4, 5
    posts = FullCovGaussian(
        mean=jnp.zeros((n, d)),
        prec=jnp.broadcast_to(jnp.eye(d) / 0.5, (n, d, d)),
    )
    Wj = jnp.asarray(W)
    for _ in range(rounds):
        means, precs = [], []
        for i in range(n):
            phi, y = task.sample_local(rng, i, 10)
            p = linreg_bayes_update(
                FullCovGaussian(posts.mean[i], posts.prec[i]),
                jnp.asarray(phi), jnp.asarray(y), task.noise_std**2,
            )
            means.append(p.mean)
            precs.append(p.prec)
        posts = consensus_full_cov(
            FullCovGaussian(jnp.stack(means), jnp.stack(precs)), Wj
        )
    phi_t, y_t = task.sample_global(rng, 3000)
    mses = [
        float(np.mean((phi_t @ np.asarray(posts.mean[i]) - y_t) ** 2))
        for i in range(n)
    ]
    return np.asarray(mses), task


def test_linreg_cooperation_reaches_centralized_mse():
    """Paper Fig 1c: decentralized MSE ~= centralized MSE (noise floor)."""
    W = complete_w(4)
    mses, task = _run_linreg(W)
    floor = task.noise_std**2
    assert np.all(mses < floor * 1.15), mses


def test_linreg_isolation_fails():
    """Paper Fig 1b: without cooperation the non-IID agents stay far from
    the global model."""
    mses_coop, task = _run_linreg(complete_w(4), rounds=80)
    mses_iso, _ = _run_linreg(np.eye(4), rounds=80)
    floor = task.noise_std**2
    # every isolated agent stays measurably above the floor; cooperation wins
    assert mses_iso.mean() > floor * 1.15, mses_iso
    assert np.all(mses_iso > mses_coop + 0.05), (mses_iso, mses_coop)
    assert mses_coop.mean() < floor * 1.1


# ---------------------------------------------------------------------------
# Bayesian NN classification on the star network
# ---------------------------------------------------------------------------


def _mlp_init(dim, hidden, n_classes):
    def init(key):
        ks = jax.random.split(key, 3)
        s = 1.0
        return {
            "w1": jax.random.normal(ks[0], (dim, hidden)) * s / np.sqrt(dim),
            "b1": jnp.zeros((hidden,)),
            "w2": jax.random.normal(ks[1], (hidden, hidden)) * s / np.sqrt(hidden),
            "b2": jnp.zeros((hidden,)),
            "w3": jax.random.normal(ks[2], (hidden, n_classes)) * s / np.sqrt(hidden),
            "b3": jnp.zeros((n_classes,)),
        }

    return init


def _mlp_logits(theta, x):
    h = jax.nn.relu(x @ theta["w1"] + theta["b1"])
    h = jax.nn.relu(h @ theta["w2"] + theta["b2"])
    return h @ theta["w3"] + theta["b3"]


def _mlp_nll(theta, batch):
    logits = _mlp_logits(theta, batch["x"])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][..., None], axis=-1)[..., 0]
    return jnp.sum(logz - gold)


def _train_star(a, rounds=25, consensus="gaussian", seed=0, n_edge=4):
    ds = make_synthetic_classification(
        n_classes=6, dim=16, n_train_per_class=120, noise=0.5, seed=seed
    )
    shards = star_partition(
        ds.x_train, ds.y_train, center_labels=[2, 3, 4, 5],
        edge_labels=[0, 1], n_edge=n_edge,
    )
    data = AgentDataset.from_shards(
        [(x.astype(np.float32), y.astype(np.int32)) for x, y in shards]
    )
    n_agents = n_edge + 1
    W = star_w(n_edge, a)
    sampler = make_round_batches(data, batch_size=16, n_local_updates=4)
    opt = adam()
    round_fn = make_round_fn(
        _mlp_nll, opt, exponential_decay(5e-3, 0.99), kl_scale=1e-3,
        consensus=consensus,
    )
    state = init_network(
        jax.random.key(seed), n_agents, _mlp_init(16, 32, 6), opt,
        init_sigma=0.05,
    )
    state, _ = run_rounds(
        round_fn, state, sampler, np.asarray(W), rounds, jax.random.key(seed + 1)
    )
    # evaluate every agent on the GLOBAL test set via the MC predictive
    xt = jnp.asarray(ds.x_test)
    yt = np.asarray(ds.y_test)
    accs, ood_accs = [], []
    for i in range(n_agents):
        post_i = jax.tree.map(lambda l: l[i], state.posterior)
        probs = mc_predict(post_i, _mlp_logits, xt, jax.random.key(9), n_mc=4)
        pred = np.asarray(jnp.argmax(probs, -1))
        accs.append(float((pred == yt).mean()))
        if i > 0:  # edge agent: labels 2..5 are OOD
            ood = np.isin(yt, [2, 3, 4, 5])
            ood_accs.append(float((pred[ood] == yt[ood]).mean()))
    return np.asarray(accs), np.asarray(ood_accs)


@pytest.mark.slow
def test_star_cooperation_learns_ood_labels():
    accs, ood = _train_star(a=0.5, rounds=25)
    assert accs.mean() > 0.8, accs
    assert ood.mean() > 0.7, ood  # OOD >> chance (1/6)


@pytest.mark.slow
def test_star_isolation_cannot_predict_ood():
    _, ood = _train_star(a=0.5, rounds=25, consensus="none")
    assert ood.mean() < 0.3, ood  # edge agents never saw labels 2-5


@pytest.mark.slow
def test_centrality_improves_edge_accuracy():
    """Paper Fig 2: larger a (central agent more influential) -> higher
    accuracy when the center holds the informative data."""
    acc_lo, _ = _train_star(a=0.1, rounds=15, seed=3)
    acc_hi, _ = _train_star(a=0.5, rounds=15, seed=3)
    assert acc_hi[1:].mean() > acc_lo[1:].mean()  # edge agents improve


@pytest.mark.slow
def test_remark7_shared_initialization_required():
    """Paper Remark 7: consensus averaging of DIFFERENTLY-initialized local
    models produces an arbitrarily bad model (different random inits land in
    different minima whose weight-space average is meaningless); shared
    first-round initialization fixes it."""
    from benchmarks.common import mlp_init as bmlp_init, mlp_nll, network_accuracy

    ds = make_synthetic_classification(
        n_classes=10, dim=64, n_train_per_class=200, noise=0.55, seed=0
    )
    shards = star_partition(ds.x_train, ds.y_train, list(range(2, 10)), [0, 1], 8)
    data = AgentDataset.from_shards(
        [(x.astype(np.float32), y.astype(np.int32)) for x, y in shards]
    )
    W = np.asarray(star_w(8, 0.5))
    sampler = make_round_batches(data, 16, 4)
    opt = adam()
    round_fn = make_round_fn(
        mlp_nll, opt, exponential_decay(5e-3, 0.99), kl_scale=1e-3
    )
    accs = {}
    for shared in (True, False):
        st = init_network(jax.random.key(0), 9, bmlp_init(64, 48, 10), opt,
                          shared_init=shared)
        st, _ = run_rounds(round_fn, st, sampler, W, 12, jax.random.key(1))
        accs[shared] = network_accuracy(st, ds.x_test, ds.y_test)
    assert accs[True] > accs[False] + 0.3, accs
