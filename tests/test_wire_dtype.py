"""Wire-dtype compressed consensus (bf16/f16 exchange, fp32 accumulate).

Pins the two halves of the ROADMAP "Wire precision" contract on EVERY
kernel path (dense, sparse, masked, masked_sparse, ppermute window,
delayed gather):

1. ``wire_dtype="f32"`` (the default) is a STRUCTURAL no-op — output
   bitwise identical to the pre-wire kernels (``assert_array_equal``,
   no tolerance), so the whole PR-3/PR-4 equivalence ladder is untouched.
2. A compressed wire dtype agrees with the fp32 reference within the
   DERIVED error bound: one cast at the exchange boundary perturbs each
   exchanged scalar by a relative error <= u = ``core.numerics
   .wire_error_bound(dtype)`` (round-to-nearest unit roundoff eps/2:
   2^-8 for bf16's 7 stored mantissa bits, 2^-11 for f16's 10).  Since
   eq. (6) accumulates convex combinations of POSITIVE rounded precisions,

       |d new_prec|  <=  u * sum_j W_ij prec_j          (relative u)
       |d mean_out|  <=  u * (W @ |prec*mu| + |mean_out| * W @ prec)
                          / new_prec
       |d rho_out|   <=  (u/2) * sigma_out / sigmoid(rho_out)

   (second-order and fp32-accumulation terms absorbed into the slack
   factor C).  The fixtures span EXTREME posterior scales (sigma 1e-4 ..
   1e4, the ``softplus_inv`` extreme-sigma regime) for bf16, whose
   exponent range matches fp32; f16 is validated at moderate scales (its
   range caps the representable precision at ~6e4).

Plus the cost-model halving assertions and the InferenceSpec plumbing
(engine-level f32 bitwise identity, bf16 session sanity, eager
validation), and the optional bf16-resident delivery-latency history ring.
"""
import dataclasses
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    RunSpec,
    Session,
    TopologySpec,
    build_session,
)
from repro.core.flat import (
    FlatLayout,
    FlatPosterior,
    consensus_flat,
    consensus_flat_delayed,
    consensus_flat_masked,
    consensus_flat_masked_sparse,
    consensus_flat_sparse,
    neighbor_tables,
)
from repro.core.graphs import bidirectional_ring_w, complete_w
from repro.core.numerics import (
    canonical_wire_dtype,
    softplus,
    softplus_inv,
    wire_dtype_name,
    wire_error_bound,
    wire_itemsize,
    wire_roundtrip,
)
from repro.gossip.clocks import PoissonClock, window_from_events
from repro.launch.consensus_opt import consensus_ppermute_window
from repro.launch.costmodel import consensus_roofline, gossip_window_roofline

# slack factor absorbing second-order roundoff, the output division, and
# the fp32 accumulation itself (measured headroom ~2x at C=4; see the
# derivation in the module docstring)
SLACK = 4.0


def _flat(mean, rho):
    layout = FlatLayout.for_pytree({"w": jnp.zeros((mean.shape[-1],))})
    return FlatPosterior(
        mean=jnp.asarray(mean), rho=jnp.asarray(rho), layout=layout
    )


def _extreme_posts(n, p, seed=0, scales=None):
    """[N, P] posterior whose per-agent sigma spans the softplus_inv
    extreme-sigma regime (1e-4 .. 1e4) — the fixtures the wire rounding
    must survive.  Means scale with sigma so prec*mu stays interesting."""
    rng = np.random.default_rng(seed)
    if scales is None:
        scales = [1e-4, 1e-2, 1.0, 1.0, 1e2, 1e4]
    assert len(scales) == n
    rho = np.zeros((n, p), np.float32)
    for i, s in enumerate(scales):
        sig = s * np.exp(rng.normal(size=p).astype(np.float32) * 0.3)
        rho[i] = np.asarray(softplus_inv(jnp.asarray(sig)))
    mean = (
        rng.normal(size=(n, p)) * np.maximum(np.asarray(scales)[:, None], 1.0)
    ).astype(np.float32)
    return _flat(mean, rho)


def _moderate_posts(n, p, seed=0):
    """Moderate-sigma fixture for f16 (prec = sigma^-2 must stay under
    f16's ~6.5e4 ceiling)."""
    return _extreme_posts(n, p, seed=seed, scales=[0.1, 0.3, 1.0, 1.0, 3.0, 10.0][:n])


def _assert_within_wire_bound(out, ref, W_eff, posts, wire, active=None):
    """The derived error bound (module docstring) per element, from the
    fp32 reference intermediates.  ``active=None`` checks every row;
    otherwise only active rows (inactive rows are asserted bitwise by the
    caller)."""
    u = wire_error_bound(wire)
    Wn = np.asarray(W_eff, np.float64)
    prec = np.asarray(1.0 / jnp.square(softplus(posts.rho)), np.float64)
    mean_in = np.asarray(posts.mean, np.float64)
    new_prec = Wn @ prec
    mean_ref = np.asarray(ref.mean, np.float64)
    rho_ref = np.asarray(ref.rho, np.float64)
    bound_mean = (
        SLACK * u * (Wn @ (prec * np.abs(mean_in))
                     + np.abs(mean_ref) * new_prec) / new_prec
    )
    sig_ref = np.asarray(softplus(ref.rho), np.float64)
    sigmoid = 1.0 / (1.0 + np.exp(-rho_ref))
    bound_rho = SLACK * 0.5 * u * sig_ref / sigmoid
    rows = slice(None) if active is None else np.asarray(active, bool)
    d_mean = np.abs(np.asarray(out.mean, np.float64) - mean_ref)
    d_rho = np.abs(np.asarray(out.rho, np.float64) - rho_ref)
    assert (d_mean[rows] <= bound_mean[rows]).all(), (
        f"mean error exceeds the derived bound: "
        f"max ratio {(d_mean[rows] / bound_mean[rows]).max():.3f}"
    )
    assert (d_rho[rows] <= bound_rho[rows]).all(), (
        f"rho error exceeds the derived bound: "
        f"max ratio {(d_rho[rows] / bound_rho[rows]).max():.3f}"
    )
    # the compressed output must actually differ (the cast is real)
    if u > 0:
        assert d_mean[rows].max() > 0


# ---------------------------------------------------------------------------
# per-path f32 bitwise identity + bf16/f16 error bounds
# ---------------------------------------------------------------------------


N, P = 6, 384


def _paths(posts, win):
    """Every kernel path as (name, fn(wire_dtype) -> FlatPosterior, W_eff
    of the rows it computes, active mask or None)."""
    W_ring = jnp.asarray(bidirectional_ring_w(N), jnp.float32)
    W_eff = jnp.asarray(win.w_eff, jnp.float32)
    act = jnp.asarray(win.active)
    nbr, wts = neighbor_tables(np.asarray(bidirectional_ring_w(N)))
    nbr_w, wts_w = neighbor_tables(win.w_eff)
    mesh1 = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("agents",))
    return [
        ("dense_xla",
         lambda wd: consensus_flat(posts, W_ring, mode="xla", wire_dtype=wd),
         W_ring, None),
        ("dense_interpret",
         lambda wd: consensus_flat(posts, W_ring, mode="interpret",
                                   block=128, wire_dtype=wd),
         W_ring, None),
        ("sparse",
         lambda wd: consensus_flat_sparse(
             posts, jnp.asarray(nbr), jnp.asarray(wts), wire_dtype=wd),
         W_ring, None),
        ("sparse_interpret",
         lambda wd: consensus_flat_sparse(
             posts, jnp.asarray(nbr), jnp.asarray(wts), mode="interpret",
             block=128, wire_dtype=wd),
         W_ring, None),
        ("masked",
         lambda wd: consensus_flat_masked(posts, W_eff, act, wire_dtype=wd),
         W_eff, win.active),
        ("masked_interpret",
         lambda wd: consensus_flat_masked(posts, W_eff, act, mode="interpret",
                                          block=128, wire_dtype=wd),
         W_eff, win.active),
        ("masked_sparse",
         lambda wd: consensus_flat_masked_sparse(
             posts, jnp.asarray(nbr_w), jnp.asarray(wts_w), act, wire_dtype=wd),
         W_eff, win.active),
        ("ppermute_window",
         lambda wd: consensus_ppermute_window(
             posts, win, mesh1, "agents", wire_dtype=wd),
         W_eff, win.active),
    ]


def _partial_window():
    win = PoissonClock(bidirectional_ring_w(N), rate=0.5, seed=7).window(0)
    assert 0 < win.active.sum() < N  # genuinely partial
    return win


def test_wire_f32_is_bitwise_noop_on_every_path():
    """Acceptance: wire_dtype="f32" output is BIT-identical to calling the
    kernel without the argument, on every consensus path."""
    posts = _extreme_posts(N, P)
    win = _partial_window()
    for name, fn, _, _ in _paths(posts, win):
        base = fn(None)
        f32 = fn("f32")
        np.testing.assert_array_equal(
            np.asarray(base.mean), np.asarray(f32.mean), err_msg=name
        )
        np.testing.assert_array_equal(
            np.asarray(base.rho), np.asarray(f32.rho), err_msg=name
        )


@pytest.mark.parametrize("wire", ["bf16", "f16"])
def test_wire_error_bound_on_every_path(wire):
    """Acceptance: every kernel path's compressed output stays within the
    derived bound vs its own fp32 reference — bf16 at EXTREME posterior
    scales (sigma 1e-4 .. 1e4), f16 at moderate scales (range-limited)."""
    posts = _extreme_posts(N, P) if wire == "bf16" else _moderate_posts(N, P)
    win = _partial_window()
    for name, fn, W_eff, active in _paths(posts, win):
        ref = fn(None)
        out = fn(wire)
        _assert_within_wire_bound(out, ref, W_eff, posts, wire, active=active)
        if active is not None:
            # inactive rows never touch the wire: bitwise passthrough
            inactive = ~np.asarray(active, bool)
            np.testing.assert_array_equal(
                np.asarray(out.mean)[inactive],
                np.asarray(posts.mean)[inactive], err_msg=name,
            )
            np.testing.assert_array_equal(
                np.asarray(out.rho)[inactive],
                np.asarray(posts.rho)[inactive], err_msg=name,
            )


def test_wire_impl_agreement_bf16():
    """The same wire dtype gives the SAME bits across executions of the
    same math: interpret==xla on the dense path, and the (single-shard)
    ppermute window == the masked xla path — the equivalence ladder
    extends one rung per wire dtype."""
    posts = _extreme_posts(N, P)
    win = _partial_window()
    W_ring = jnp.asarray(bidirectional_ring_w(N), jnp.float32)
    a = consensus_flat(posts, W_ring, mode="xla", wire_dtype="bf16")
    b = consensus_flat(posts, W_ring, mode="interpret", block=128,
                       wire_dtype="bf16")
    np.testing.assert_array_equal(np.asarray(a.mean), np.asarray(b.mean))
    np.testing.assert_array_equal(np.asarray(a.rho), np.asarray(b.rho))
    W_eff = jnp.asarray(win.w_eff, jnp.float32)
    act = jnp.asarray(win.active)
    masked = consensus_flat_masked(posts, W_eff, act, wire_dtype="bf16")
    mesh1 = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("agents",))
    shard = consensus_ppermute_window(posts, win, mesh1, "agents",
                                      wire_dtype="bf16")
    np.testing.assert_array_equal(np.asarray(masked.mean), np.asarray(shard.mean))
    np.testing.assert_array_equal(np.asarray(masked.rho), np.asarray(shard.rho))


# ---------------------------------------------------------------------------
# delayed event-gather path
# ---------------------------------------------------------------------------


def _delayed_fixture(wire_hist="f32", seed=3):
    """A hand-built delayed window: K=3 ring slots of stale posteriors,
    events with mixed lags."""
    n, p, k = 5, 256, 3
    rng = np.random.default_rng(seed)
    posts = _extreme_posts(n, p, seed=seed, scales=[1e-3, 0.5, 1.0, 10.0, 1e3])
    W_base = bidirectional_ring_w(n)
    events = [(0, 1), (2, 3), (4, 0)]
    lags = [0, 1, 2]
    win = window_from_events(W_base, events, e_max=4, rule="conserve",
                             delays=lags)
    hd = canonical_wire_dtype(wire_hist)
    hist_mean = jnp.asarray(
        rng.normal(size=(k, n, p)).astype(np.float32)).astype(hd)
    hist_rho = jnp.asarray(
        (rng.normal(size=(k, n, p)) * 0.3 - 1.0).astype(np.float32)).astype(hd)
    args = (
        jnp.asarray(win.w_eff, jnp.float32),
        jnp.asarray(win.active),
        jnp.asarray(win.edges),
        jnp.asarray(win.weights),
        jnp.asarray(win.delays),
        hist_mean,
        hist_rho,
        jnp.asarray(2, jnp.int32),  # round index
    )
    return posts, win, args


def test_delayed_gather_wire_f32_bitwise_and_bf16_bound():
    posts, win, args = _delayed_fixture()
    base = consensus_flat_delayed(posts, *args)
    f32 = consensus_flat_delayed(posts, *args, wire_dtype="f32")
    np.testing.assert_array_equal(np.asarray(base.mean), np.asarray(f32.mean))
    np.testing.assert_array_equal(np.asarray(base.rho), np.asarray(f32.rho))

    out = consensus_flat_delayed(posts, *args, wire_dtype="bf16")
    # derived bound via the gather accumulate itself, run on fp32 inputs
    u = wire_error_bound("bf16")
    W, active, edges, weights, lags, hist_mean, hist_rho, r = args
    k = hist_mean.shape[0]
    slot = np.mod(int(r) - np.asarray(lags), k)
    dst, src = np.asarray(edges)[:, 0], np.asarray(edges)[:, 1]
    h_mean = np.asarray(hist_mean, np.float64)[slot, src]
    h_prec = 1.0 / np.square(
        np.asarray(softplus(jnp.asarray(hist_rho, jnp.float32)), np.float64)[slot, src]
    )
    w_e = np.asarray(weights, np.float64)[:, None]
    prec_now = np.asarray(1.0 / jnp.square(softplus(posts.rho)), np.float64)
    diag = np.diagonal(np.asarray(W, np.float64))[:, None]
    acc_prec = diag * prec_now
    acc_abs_pm = diag * prec_now * np.abs(np.asarray(posts.mean, np.float64))
    np.add.at(acc_prec, dst, w_e * h_prec)
    np.add.at(acc_abs_pm, dst, w_e * h_prec * np.abs(h_mean))
    mean_ref = np.asarray(base.mean, np.float64)
    rho_ref = np.asarray(base.rho, np.float64)
    bound_mean = SLACK * u * (acc_abs_pm + np.abs(mean_ref) * acc_prec) / acc_prec
    sig_ref = np.asarray(softplus(base.rho), np.float64)
    bound_rho = SLACK * 0.5 * u * sig_ref * (1.0 + np.exp(-rho_ref))
    act = np.asarray(win.active, bool)
    d_mean = np.abs(np.asarray(out.mean, np.float64) - mean_ref)
    d_rho = np.abs(np.asarray(out.rho, np.float64) - rho_ref)
    assert (d_mean[act] <= bound_mean[act]).all()
    assert (d_rho[act] <= bound_rho[act]).all()
    assert d_mean[act].max() > 0
    # inactive rows: bitwise passthrough
    np.testing.assert_array_equal(
        np.asarray(out.mean)[~act], np.asarray(posts.mean)[~act]
    )
    np.testing.assert_array_equal(
        np.asarray(out.rho)[~act], np.asarray(posts.rho)[~act]
    )


def test_delayed_gather_bf16_resident_history_decodes():
    """A bf16-RESIDENT history ring (history_dtype) is decoded to fp32
    before the gather math; the result tracks the f32-resident reference
    to bf16 storage precision (rho rounding is u-relative in rho, so the
    tolerance scales with |rho| — looser than the wire bound)."""
    posts, win, args32 = _delayed_fixture(wire_hist="f32")
    _, _, args16 = _delayed_fixture(wire_hist="bf16")
    ref = consensus_flat_delayed(posts, *args32)
    out = consensus_flat_delayed(posts, *args16)
    assert args16[5].dtype == jnp.bfloat16
    act = np.asarray(win.active, bool)
    np.testing.assert_allclose(
        np.asarray(out.mean)[act], np.asarray(ref.mean)[act],
        rtol=3e-2, atol=3e-2,
    )
    # untouched rows identical regardless of residency
    np.testing.assert_array_equal(
        np.asarray(out.mean)[~act], np.asarray(ref.mean)[~act]
    )


# ---------------------------------------------------------------------------
# cost model: bf16 halves the modeled collective / ICI bytes
# ---------------------------------------------------------------------------


def test_consensus_roofline_wire_bytes_halve_at_bf16():
    n, p = 16, 1 << 14
    f32 = consensus_roofline(n, p, n_leaves=8)["wire"]
    bf16 = consensus_roofline(n, p, n_leaves=8, wire_dtype="bf16")["wire"]
    f16 = consensus_roofline(n, p, n_leaves=8, wire_dtype="f16")["wire"]
    assert f32["dtype"] == "f32" and f32["model_saving_vs_f32"] == 1.0
    assert bf16["collective_bytes"] == 0.5 * f32["collective_bytes"]
    assert f16["collective_bytes"] == 0.5 * f32["collective_bytes"]
    assert bf16["model_saving_vs_f32"] == 2.0
    with pytest.raises(ValueError, match="wire_dtype"):
        consensus_roofline(n, p, n_leaves=8, wire_dtype="f64")


def test_gossip_window_roofline_ici_bytes_halve_at_bf16():
    n, p, s = 16, 1 << 14, 8
    kw = dict(n_participating=8, n_shards=s, n_cross_offsets=3)
    f32 = gossip_window_roofline(n, p, **kw)
    bf16 = gossip_window_roofline(n, p, wire_dtype="bf16", **kw)
    for key in ("window_ppermute", "dense_allgather"):
        assert bf16["ici_bytes"][key] == 0.5 * f32["ici_bytes"][key]
    # HBM terms are fp32-resident: untouched by the wire dtype
    assert bf16["hbm_bytes"] == f32["hbm_bytes"]
    assert bf16["wire_dtype"] == "bf16"
    # the history ring residency halves independently
    d32 = gossip_window_roofline(n, p, n_participating=8, delay_depth=2,
                                 n_stale_events=4)
    d16 = gossip_window_roofline(n, p, n_participating=8, delay_depth=2,
                                 n_stale_events=4, history_dtype="bf16")
    assert d16["hist_resident_bytes"] == 0.5 * d32["hist_resident_bytes"]
    assert d16["hbm_bytes"]["history"] == 0.5 * d32["hbm_bytes"]["history"]
    assert d16["hbm_bytes"]["window_masked"] == d32["hbm_bytes"]["window_masked"]


# ---------------------------------------------------------------------------
# numerics helpers
# ---------------------------------------------------------------------------


def test_wire_dtype_helpers():
    assert canonical_wire_dtype(None) == jnp.float32
    assert canonical_wire_dtype("bf16") == jnp.bfloat16
    assert canonical_wire_dtype(jnp.float16) == jnp.float16
    assert wire_dtype_name(jnp.bfloat16) == "bf16"
    assert wire_itemsize("f32") == 4 and wire_itemsize("bf16") == 2
    # u = eps/2: round-to-nearest halves the machine epsilon
    assert wire_error_bound("f32") == 0.0
    assert wire_error_bound("bf16") == float(jnp.finfo(jnp.bfloat16).eps) / 2
    assert wire_error_bound("bf16") == 2.0 ** -8
    assert wire_error_bound("f16") == float(jnp.finfo(jnp.float16).eps) / 2
    assert wire_error_bound("f16") == 2.0 ** -11
    with pytest.raises(ValueError, match="wire_dtype"):
        canonical_wire_dtype("f64")
    # dtype-likes outside the wire set are rejected like their spellings
    # (an int/f64 wire would corrupt, not compress)
    with pytest.raises(ValueError, match="wire_dtype"):
        canonical_wire_dtype(jnp.float64)
    with pytest.raises(ValueError, match="wire_dtype"):
        canonical_wire_dtype(jnp.int32)
    x = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    assert wire_roundtrip(x, "f32") is x  # STRUCTURAL no-op, same object
    y = wire_roundtrip(jnp.asarray([1.0 + 2.0 ** -10]), "bf16")
    assert y.dtype == jnp.float32 and float(y[0]) == 1.0  # really rounded
    # the worst-case single cast stays within u (midpoint rounding)
    z = jnp.asarray([1.0 + 2.0 ** -8], jnp.float32)
    rel = abs(float(wire_roundtrip(z, "bf16")[0]) - float(z[0])) / float(z[0])
    assert rel <= wire_error_bound("bf16")


# ---------------------------------------------------------------------------
# InferenceSpec plumbing: engines, sessions, validation
# ---------------------------------------------------------------------------


def _gossip_session_spec(wire="f32", clock=None, n=4, n_rounds=3, **inf_kw):
    return ExperimentSpec(
        topology=TopologySpec.gossip(
            "bidirectional_ring", {"n": n},
            clock=clock or {"kind": "poisson", "rate": 0.8, "seed": 1},
        ),
        data=DataSpec(
            dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
            partition="iid", partition_params=dict(n_agents=n),
            batch_size=4, local_updates=2,
        ),
        inference=InferenceSpec(hidden=8, depth=1, lr=1e-2, wire_dtype=wire,
                                **inf_kw),
        run=RunSpec(n_rounds=n_rounds, seed=0),
    )


def test_wire_spec_validation():
    with pytest.raises(ValueError, match="wire_dtype"):
        InferenceSpec(wire_dtype="f64").validate()
    with pytest.raises(ValueError, match="mean_only"):
        InferenceSpec(wire_dtype="bf16", consensus="mean_only").validate()
    with pytest.raises(ValueError, match="exchanges nothing"):
        InferenceSpec(wire_dtype="bf16", consensus="none").validate()
    with pytest.raises(ValueError, match="conjugate_linreg"):
        InferenceSpec(wire_dtype="bf16", method="conjugate_linreg").validate()
    with pytest.raises(ValueError, match="history_dtype"):
        InferenceSpec(history_dtype="f64").validate()
    # history_dtype without a gossip topology is silently-dead config
    with pytest.raises(ValueError, match="history_dtype"):
        ExperimentSpec(
            topology=TopologySpec.complete(4),
            data=DataSpec(partition_params=dict(n_agents=4)),
            inference=InferenceSpec(history_dtype="bf16"),
        ).validate()
    # ... and a gossip clock without delay rejects it at engine build
    with pytest.raises(ValueError, match="delay"):
        build_session(_gossip_session_spec(history_dtype="bf16"))
    InferenceSpec(wire_dtype="bf16").validate()


def test_gossip_engine_wire_f32_bitwise_and_bf16_runs():
    """Engine plumbing: wire_dtype="f32" session is bit-identical to the
    default; a bf16 session runs finite, reports its wire dtype in the
    telemetry, and tracks the f32 trajectory closely."""
    s_def = build_session(_gossip_session_spec())
    s_f32 = build_session(_gossip_session_spec(wire="f32"))
    s_bf = build_session(_gossip_session_spec(wire="bf16"))
    s_def.run()
    s_f32.run()
    hist = s_bf.run(eval_every=1)
    np.testing.assert_array_equal(
        np.asarray(s_def.posterior().mean), np.asarray(s_f32.posterior().mean)
    )
    np.testing.assert_array_equal(
        np.asarray(s_def.posterior().rho), np.asarray(s_f32.posterior().rho)
    )
    assert np.isfinite(hist[-1]["loss"])
    assert s_bf.evaluate()["engine"]["wire_dtype"] == "bf16"
    assert "wire_dtype" not in s_f32.evaluate()["engine"]
    np.testing.assert_allclose(
        np.asarray(s_bf.posterior().mean), np.asarray(s_f32.posterior().mean),
        rtol=0.1, atol=0.1,
    )
    assert s_bf.engine.n_traces == 1  # wire rounding adds no retrace


def test_simulated_engine_wire_f32_bitwise():
    """The synchronous SimulatedEngine consensus also routes the wire dtype
    (core.flat dispatch): f32 is bitwise the default."""
    def spec(wire):
        return ExperimentSpec(
            topology=TopologySpec(kind="bidirectional_ring", params={"n": 4}),
            data=DataSpec(
                dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
                partition="iid", partition_params=dict(n_agents=4),
                batch_size=4, local_updates=2,
            ),
            inference=InferenceSpec(hidden=8, depth=1, lr=1e-2,
                                    wire_dtype=wire),
            run=RunSpec(n_rounds=2, seed=0),
        )

    s_def, s_bf = build_session(spec("f32")), build_session(spec("bf16"))
    s_def.run()
    s_bf.run()
    base = build_session(spec("f32"))
    base.run()
    np.testing.assert_array_equal(
        np.asarray(s_def.posterior().mean), np.asarray(base.posterior().mean)
    )
    # bf16 genuinely compresses (different bits) but stays close
    assert not np.array_equal(
        np.asarray(s_bf.posterior().mean), np.asarray(s_def.posterior().mean)
    )
    np.testing.assert_allclose(
        np.asarray(s_bf.posterior().mean), np.asarray(s_def.posterior().mean),
        rtol=0.1, atol=0.1,
    )


def test_bf16_history_ring_session_and_checkpoint(tmp_path):
    """The delayed engine's [K, N, P] ring can be bf16-resident
    (history_dtype): state leaves carry the narrow dtype (half the resident
    bytes), the run stays finite, and save/load resumes BIT-identically
    (the checkpoint round-trips extension dtypes by name)."""
    clock = {"kind": "delayed",
             "inner": {"kind": "poisson", "rate": 0.9, "seed": 2},
             "latency": {"kind": "constant", "delay": 2}}
    s = build_session(
        _gossip_session_spec(clock=clock, n_rounds=6, history_dtype="bf16")
    )
    assert s.state.hist_mean.dtype == jnp.bfloat16
    assert s.evaluate()["engine"]["history_dtype"] == "bf16"
    s.run(3)
    path = os.path.join(tmp_path, "bf16hist.ckpt")
    s.save(path)
    s2 = Session.load(path)
    assert s2.state.hist_mean.dtype == jnp.bfloat16
    s.run(3)
    s2.run(3)
    np.testing.assert_array_equal(
        np.asarray(s.posterior().mean), np.asarray(s2.posterior().mean)
    )
    np.testing.assert_array_equal(
        np.asarray(s.state.hist_mean), np.asarray(s2.state.hist_mean)
    )
    # f32 residency stays the default with unchanged leaf dtype
    s32 = build_session(_gossip_session_spec(clock=clock))
    assert s32.state.hist_mean.dtype == jnp.float32


# ---------------------------------------------------------------------------
# sharded wire exchange: real multi-device ppermute payload
# ---------------------------------------------------------------------------


_SHARD_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_ppermute_window_wire_bitwise_vs_masked_multidevice():
    """Acceptance (8 virtual devices): the sharded window consensus with a
    compressed ppermute payload is BIT-identical to the dense masked kernel
    at the same wire dtype, for several shard counts and windows — and the
    f32 wire is bit-identical to the no-argument baseline."""
    from conftest import run_multidevice_subprocess

    run_multidevice_subprocess(_SHARD_PRELUDE + textwrap.dedent("""
    from repro.core.flat import (FlatLayout, FlatPosterior,
                                 consensus_flat_masked)
    from repro.core.graphs import bidirectional_ring_w
    from repro.gossip.clocks import PoissonClock
    from repro.launch.consensus_opt import consensus_ppermute_window

    n, p = 8, 200
    ks = jax.random.split(jax.random.key(5), 2)
    layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
    posts = FlatPosterior(
        mean=jax.random.normal(ks[0], (n, p)) * 3.0,
        # moderate sigma so the f16 sweep's precisions stay in range
        rho=jax.random.normal(ks[1], (n, p)) * 0.5 - 1.0,
        layout=layout,
    )
    W_base = bidirectional_ring_w(n)
    clock = PoissonClock(W_base, rate=0.7, seed=3)
    for S in (2, 4, 8):
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:S]), ("agents",))
        for r in range(3):
            win = clock.window(r)
            for wire in (None, "f32", "bf16", "f16"):
                ref = consensus_flat_masked(
                    posts, jnp.asarray(win.w_eff, jnp.float32),
                    jnp.asarray(win.active), mode="xla", wire_dtype=wire)
                out = consensus_ppermute_window(
                    posts, win, mesh, "agents", wire_dtype=wire)
                assert bool(jnp.all(out.mean == ref.mean)), (S, r, wire)
                assert bool(jnp.all(out.rho == ref.rho)), (S, r, wire)
    print("OK")
    """))


@pytest.mark.slow
@pytest.mark.multidevice
def test_gossip_engine_ppermute_bf16_matches_masked_bf16():
    """Engine-level ladder rung: a sharded (ppermute) bf16-wire gossip run
    equals the dense masked bf16 run bit-identically over the 8-device
    agent mesh."""
    from conftest import run_multidevice_subprocess

    run_multidevice_subprocess(_SHARD_PRELUDE + textwrap.dedent("""
    from repro.api import (DataSpec, ExperimentSpec, InferenceSpec, RunSpec,
                           TopologySpec, build_session)

    n = 8
    def spec(impl):
        return ExperimentSpec(
            topology=TopologySpec.gossip(
                "bidirectional_ring", {"n": n},
                clock={"kind": "poisson", "rate": 0.7, "seed": 3}),
            data=DataSpec(
                dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
                partition="iid", partition_params=dict(n_agents=n),
                batch_size=4, local_updates=2),
            inference=InferenceSpec(hidden=8, depth=1, lr=1e-2,
                                    consensus_impl=impl, wire_dtype="bf16"),
            run=RunSpec(n_rounds=3, seed=0),
        )

    s_m = build_session(spec("masked"))
    s_p = build_session(spec("ppermute"))
    s_m.run(); s_p.run()
    np.testing.assert_array_equal(np.asarray(s_m.posterior().mean),
                                  np.asarray(s_p.posterior().mean))
    np.testing.assert_array_equal(np.asarray(s_m.posterior().rho),
                                  np.asarray(s_p.posterior().rho))
    assert s_p.evaluate()["engine"]["wire_dtype"] == "bf16"
    print("OK")
    """))
