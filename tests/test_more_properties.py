"""Additional property-based tests for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this image")
from hypothesis import given, settings, strategies as st

from repro.core.discrete import local_bayes_update, social_learning_round
from repro.core.graphs import bidirectional_ring_w, complete_w
from repro.core.posterior import (
    GaussianPosterior,
    consensus_all_agents,
    init_posterior,
    softplus,
)
from repro.core.theory import stationary_distribution


def _posts(n, p, seed):
    rng = np.random.default_rng(seed)
    return GaussianPosterior(
        mean={"w": jnp.asarray(rng.normal(size=(n, p)), jnp.float32)},
        rho={"w": jnp.asarray(rng.normal(size=(n, p)) * 0.3, jnp.float32)},
    )


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 6), st.integers(1, 12), st.integers(0, 50))
def test_consensus_permutation_equivariant(n, p, seed):
    """Relabeling agents commutes with consensus: C(P W P^T, P q) = P C(W, q)."""
    rng = np.random.default_rng(seed)
    posts = _posts(n, p, seed)
    W = rng.random((n, n)) + 0.1
    W = W / W.sum(1, keepdims=True)
    perm = rng.permutation(n)
    Pm = np.eye(n)[perm]
    posts_p = GaussianPosterior(
        mean={"w": posts.mean["w"][perm]}, rho={"w": posts.rho["w"][perm]}
    )
    # consensus(permuted inputs, permuted W) == permuted consensus(inputs, W)
    outp = consensus_all_agents(posts_p, jnp.asarray(Pm @ W @ Pm.T, jnp.float32))
    ref = consensus_all_agents(posts, jnp.asarray(W, jnp.float32))
    np.testing.assert_allclose(
        np.asarray(outp.mean["w"]), np.asarray(ref.mean["w"])[perm],
        rtol=1e-4, atol=1e-4,
    )


@settings(max_examples=10, deadline=None)
@given(st.integers(3, 6), st.integers(0, 30))
def test_repeated_consensus_reaches_agreement(n, seed):
    """Iterating eq. (6) with a fixed primitive W drives the network to
    agreement (spread -> 0) — the paper's consensus-contraction property."""
    posts = _posts(n, 8, seed)
    W = jnp.asarray(complete_w(n) * 0.5 + bidirectional_ring_w(n) * 0.5)
    spread0 = float(jnp.sum(jnp.var(posts.mean["w"], axis=0)))
    for _ in range(60):
        posts = consensus_all_agents(posts, W)
    spread = float(jnp.sum(jnp.var(posts.mean["w"], axis=0)))
    assert spread < spread0 * 1e-4 + 1e-10


def test_repeated_consensus_fixed_point_is_v_weighted():
    """The agreement point of pure averaging-of-log-densities has precision
    prec* = sum_i v_i prec_i under repeated application (v = centrality)."""
    n, p = 5, 6
    posts = _posts(n, p, 3)
    Wnp = complete_w(n) * 0.3 + bidirectional_ring_w(n) * 0.7
    v = stationary_distribution(Wnp)
    prec0 = 1.0 / np.square(np.asarray(softplus(posts.rho["w"])))
    expected = np.einsum("i,ip->p", v, prec0)
    W = jnp.asarray(Wnp)
    for _ in range(200):
        posts = consensus_all_agents(posts, W)
    prec = 1.0 / np.square(np.asarray(softplus(posts.rho["w"])))
    np.testing.assert_allclose(prec[0], expected, rtol=1e-3)
    np.testing.assert_allclose(prec, np.broadcast_to(expected, prec.shape), rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 5), st.integers(2, 6),
       st.floats(-20.0, 20.0, allow_nan=False), st.integers(0, 40))
def test_discrete_update_shift_invariant(n, t, shift, seed):
    """Adding a constant to every log-likelihood (per agent) must not change
    the posterior (normalization invariance of eq. 2)."""
    rng = np.random.default_rng(seed)
    logq = jnp.log(jax.nn.softmax(jnp.asarray(rng.normal(size=(n, t)), jnp.float32)))
    loglik = jnp.asarray(rng.normal(size=(n, t)), jnp.float32)
    b1 = local_bayes_update(logq, loglik)
    b2 = local_bayes_update(logq, loglik + shift)
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 5), st.integers(2, 5), st.integers(0, 30))
def test_round_with_identity_w_is_pure_bayes(n, t, seed):
    """W = I: the decentralized round degenerates to independent Bayes."""
    rng = np.random.default_rng(seed)
    logq = jnp.log(jax.nn.softmax(jnp.asarray(rng.normal(size=(n, t)), jnp.float32)))
    loglik = jnp.asarray(rng.normal(size=(n, t)), jnp.float32)
    logq2, logb = social_learning_round(logq, loglik, jnp.eye(n))
    np.testing.assert_allclose(np.asarray(logq2), np.asarray(logb), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.floats(0.01, 2.0), st.integers(1, 64), st.integers(0, 20))
def test_init_posterior_sigma(sigma, p, seed):
    post = init_posterior({"w": jnp.zeros((p,))}, init_sigma=float(sigma))
    got = np.asarray(softplus(post.rho["w"]))
    np.testing.assert_allclose(got, sigma, rtol=1e-4)


def test_moe_dropless_at_high_capacity_property():
    """At capacity_factor high enough, NO assignment is dropped: the MoE
    output is independent of capacity_factor beyond that point."""
    import dataclasses

    from repro.configs import get_config
    from repro.models.moe import moe_ffn, moe_init

    base = get_config("olmoe-1b-7b").reduced()
    p = moe_init(jax.random.key(0), base)
    x = jax.random.normal(jax.random.key(1), (2, 8, base.d_model))
    outs = []
    for cf in (8.0, 16.0, 64.0):
        cfg = dataclasses.replace(base, capacity_factor=cf)
        y, _ = moe_ffn(p, x, cfg)
        outs.append(np.asarray(y, np.float32))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
    np.testing.assert_allclose(outs[1], outs[2], atol=1e-5)
