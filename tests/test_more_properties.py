"""Additional property-based tests for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this image")
from hypothesis import given, settings, strategies as st

from repro.core.discrete import local_bayes_update, social_learning_round
from repro.core.graphs import bidirectional_ring_w, complete_w
from repro.core.posterior import (
    GaussianPosterior,
    consensus_all_agents,
    init_posterior,
    softplus,
)
from repro.core.theory import stationary_distribution


def _posts(n, p, seed):
    rng = np.random.default_rng(seed)
    return GaussianPosterior(
        mean={"w": jnp.asarray(rng.normal(size=(n, p)), jnp.float32)},
        rho={"w": jnp.asarray(rng.normal(size=(n, p)) * 0.3, jnp.float32)},
    )


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 6), st.integers(1, 12), st.integers(0, 50))
def test_consensus_permutation_equivariant(n, p, seed):
    """Relabeling agents commutes with consensus: C(P W P^T, P q) = P C(W, q)."""
    rng = np.random.default_rng(seed)
    posts = _posts(n, p, seed)
    W = rng.random((n, n)) + 0.1
    W = W / W.sum(1, keepdims=True)
    perm = rng.permutation(n)
    Pm = np.eye(n)[perm]
    posts_p = GaussianPosterior(
        mean={"w": posts.mean["w"][perm]}, rho={"w": posts.rho["w"][perm]}
    )
    # consensus(permuted inputs, permuted W) == permuted consensus(inputs, W)
    outp = consensus_all_agents(posts_p, jnp.asarray(Pm @ W @ Pm.T, jnp.float32))
    ref = consensus_all_agents(posts, jnp.asarray(W, jnp.float32))
    np.testing.assert_allclose(
        np.asarray(outp.mean["w"]), np.asarray(ref.mean["w"])[perm],
        rtol=1e-4, atol=1e-4,
    )


@settings(max_examples=10, deadline=None)
@given(st.integers(3, 6), st.integers(0, 30))
def test_repeated_consensus_reaches_agreement(n, seed):
    """Iterating eq. (6) with a fixed primitive W drives the network to
    agreement (spread -> 0) — the paper's consensus-contraction property."""
    posts = _posts(n, 8, seed)
    W = jnp.asarray(complete_w(n) * 0.5 + bidirectional_ring_w(n) * 0.5)
    spread0 = float(jnp.sum(jnp.var(posts.mean["w"], axis=0)))
    for _ in range(60):
        posts = consensus_all_agents(posts, W)
    spread = float(jnp.sum(jnp.var(posts.mean["w"], axis=0)))
    assert spread < spread0 * 1e-4 + 1e-10


def test_repeated_consensus_fixed_point_is_v_weighted():
    """The agreement point of pure averaging-of-log-densities has precision
    prec* = sum_i v_i prec_i under repeated application (v = centrality)."""
    n, p = 5, 6
    posts = _posts(n, p, 3)
    Wnp = complete_w(n) * 0.3 + bidirectional_ring_w(n) * 0.7
    v = stationary_distribution(Wnp)
    prec0 = 1.0 / np.square(np.asarray(softplus(posts.rho["w"])))
    expected = np.einsum("i,ip->p", v, prec0)
    W = jnp.asarray(Wnp)
    for _ in range(200):
        posts = consensus_all_agents(posts, W)
    prec = 1.0 / np.square(np.asarray(softplus(posts.rho["w"])))
    np.testing.assert_allclose(prec[0], expected, rtol=1e-3)
    np.testing.assert_allclose(prec, np.broadcast_to(expected, prec.shape), rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 5), st.integers(2, 6),
       st.floats(-20.0, 20.0, allow_nan=False), st.integers(0, 40))
def test_discrete_update_shift_invariant(n, t, shift, seed):
    """Adding a constant to every log-likelihood (per agent) must not change
    the posterior (normalization invariance of eq. 2)."""
    rng = np.random.default_rng(seed)
    logq = jnp.log(jax.nn.softmax(jnp.asarray(rng.normal(size=(n, t)), jnp.float32)))
    loglik = jnp.asarray(rng.normal(size=(n, t)), jnp.float32)
    b1 = local_bayes_update(logq, loglik)
    b2 = local_bayes_update(logq, loglik + shift)
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 5), st.integers(2, 5), st.integers(0, 30))
def test_round_with_identity_w_is_pure_bayes(n, t, seed):
    """W = I: the decentralized round degenerates to independent Bayes."""
    rng = np.random.default_rng(seed)
    logq = jnp.log(jax.nn.softmax(jnp.asarray(rng.normal(size=(n, t)), jnp.float32)))
    loglik = jnp.asarray(rng.normal(size=(n, t)), jnp.float32)
    logq2, logb = social_learning_round(logq, loglik, jnp.eye(n))
    np.testing.assert_allclose(np.asarray(logq2), np.asarray(logb), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.floats(0.01, 2.0), st.integers(1, 64), st.integers(0, 20))
def test_init_posterior_sigma(sigma, p, seed):
    post = init_posterior({"w": jnp.zeros((p,))}, init_sigma=float(sigma))
    got = np.asarray(softplus(post.rho["w"]))
    np.testing.assert_allclose(got, sigma, rtol=1e-4)


# ---------------------------------------------------------------------------
# gossip-clock properties (wire-dtype PR satellites)
# ---------------------------------------------------------------------------


def _random_row_stochastic(n, seed):
    """A dense row-stochastic base W with self-loops (every off-diagonal a
    potential gossip edge)."""
    rng = np.random.default_rng(seed)
    W = rng.random((n, n)) + 0.05
    W = W / W.sum(1, keepdims=True)
    return W


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 7), st.integers(0, 200), st.integers(0, 60))
def test_conserve_w_tilde_row_stochastic_for_arbitrary_subsets(
    n, subset_seed, w_seed
):
    """Property: under the "conserve" rule, EVERY fired-edge subset yields
    a row-stochastic W-tilde whose inactive rows are exactly e_i and whose
    active rows keep the base weight on fired in-edges (idle in-edge mass
    on self)."""
    from repro.gossip.clocks import _directed_edges, window_from_events

    W = _random_row_stochastic(n, w_seed)
    edges = _directed_edges(W)
    rng = np.random.default_rng(subset_seed)
    fired = [e for e in edges if rng.random() < 0.4]
    win = window_from_events(W, fired, e_max=max(len(edges), 1))
    np.testing.assert_allclose(win.w_eff.sum(axis=1), 1.0, atol=1e-12)
    assert (win.w_eff >= 0).all()
    inactive = ~win.active
    np.testing.assert_array_equal(win.w_eff[inactive], np.eye(n)[inactive])
    for i in np.nonzero(win.active)[0]:
        fired_in = {j for (d, j) in fired if d == i}
        for j in fired_in:
            assert win.w_eff[i, j] == W[i, j]  # base weight, exactly
        idle_mass = sum(W[i, j] for j in range(n)
                        if j != i and j not in fired_in)
        np.testing.assert_allclose(
            win.w_eff[i, i], W[i, i] + idle_mass, atol=1e-12
        )


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.integers(0, 40),
       st.floats(0.1, 3.0, allow_nan=False))
def test_event_window_stream_is_pure_function_of_seed_and_round(
    seed, r, rate
):
    """Property: window(r) is a pure function of (clock seed, r) — two
    independently constructed clocks replay the identical window, and
    regenerating from ONE clock twice (memo evicted in between) is
    bitwise identical."""
    from repro.gossip.clocks import PoissonClock
    from repro.core.graphs import bidirectional_ring_w

    W = bidirectional_ring_w(6)
    a = PoissonClock(W, rate=rate, seed=seed).window(r)
    b = PoissonClock(W, rate=rate, seed=seed).window(r)
    np.testing.assert_array_equal(a.edges, b.edges)
    np.testing.assert_array_equal(a.weights, b.weights)
    np.testing.assert_array_equal(a.active, b.active)
    np.testing.assert_array_equal(a.w_eff, b.w_eff)
    c = PoissonClock(W, rate=rate, seed=seed)
    first = c.window(r)
    c.window(r + 1)  # advance the one-slot memo so (r) is reconstructed
    again = c.window(r)
    assert again is not first  # really regenerated, not the memo
    np.testing.assert_array_equal(first.edges, again.edges)
    np.testing.assert_array_equal(first.w_eff, again.w_eff)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 500), st.integers(1, 500), st.integers(0, 12),
       st.floats(0.5, 4.0, allow_nan=False))
def test_failure_drop_decisions_independent_of_inner_clock(
    seed_a, seed_delta, r, rate_b
):
    """Property: the failure_injected drop stream is salted on (outer seed,
    0xFA11ED, r) ALONE — swapping the inner clock (different seed AND
    rate) leaves the per-slot keep/drop prefix unchanged."""
    from repro.core.graphs import complete_w
    from repro.gossip.clocks import FailureInjectedClock, PoissonClock

    W = complete_w(5)
    drop = 0.5
    inner_a = PoissonClock(W, rate=2.0, seed=seed_a)
    inner_b = PoissonClock(W, rate=rate_b, seed=seed_a + seed_delta)
    c_a = FailureInjectedClock(inner_a, drop_rate=drop, seed=7)
    c_b = FailureInjectedClock(inner_b, drop_rate=drop, seed=7)
    ev_a, ev_b = inner_a.window(r), inner_b.window(r)
    mask = np.random.default_rng([7, 0xFA11ED, r]).random(
        max(ev_a.n_events, ev_b.n_events)
    ) >= drop
    for ev, c in ((ev_a, c_a), (ev_b, c_b)):
        kept = [tuple(e) for e, k in
                zip(ev.edges[: ev.n_events].tolist(), mask) if k]
        win = c.window(r)
        assert kept == [tuple(e) for e in win.edges[: win.n_events].tolist()]


# ---------------------------------------------------------------------------
# agent-fault properties (fault-tolerant gossip PR satellites)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.integers(0, 25),
       st.floats(0.05, 0.6, allow_nan=False),
       st.floats(0.2, 1.0, allow_nan=False))
def test_fault_stream_is_pure_function_of_seed_and_round(
    seed, r, crash_rate, recover_rate
):
    """Property: the crash/corruption schedule for round r depends ONLY on
    (fault seed, r) — independently built models, queried in different
    orders, replay the identical stream (the resume contract)."""
    from repro.gossip.faults import FaultModel, FaultSpec

    spec = FaultSpec(crash_rate=crash_rate, recover_rate=recover_rate,
                     corrupt_rate=0.4, seed=seed)
    a, b = FaultModel(spec, 7), FaultModel(spec, 7)
    _ = b.up(r + 3)  # warm b's memo past r: access order must not matter
    np.testing.assert_array_equal(a.up(r), b.up(r))
    np.testing.assert_array_equal(a.corrupted(r), b.corrupted(r))
    fm_a, fr_a = a.fills(r)
    fm_b, fr_b = b.fills(r)
    np.testing.assert_array_equal(fm_a, fm_b)
    np.testing.assert_array_equal(fr_a, fr_b)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 500), st.integers(0, 30))
def test_fault_salts_pairwise_independent_streams(seed, r):
    """Property: the crash (0xC7A54), corruption (0xBADBAD), link-drop
    (0xFA11ED) and delay (0xDE1A7) streams are DISTINCT Philox counter
    streams for the same (seed, r) — no salt pair ever yields the same
    draw vector (which would couple two fault concerns)."""
    from repro.gossip.clocks import DELAY_SALT
    from repro.gossip.faults import CORRUPT_SALT, CRASH_SALT

    salts = (CRASH_SALT, CORRUPT_SALT, 0xFA11ED, DELAY_SALT)
    assert len(set(salts)) == 4
    draws = [np.random.default_rng([seed, s, r]).random(16) for s in salts]
    for i in range(len(salts)):
        for j in range(i + 1, len(salts)):
            assert not np.array_equal(draws[i], draws[j]), (
                f"salt streams {salts[i]:#x} and {salts[j]:#x} collided"
            )


@settings(max_examples=20, deadline=None)
@given(st.integers(3, 7), st.integers(0, 12), st.integers(0, 60),
       st.floats(0.1, 0.7, allow_nan=False))
def test_conserve_w_tilde_row_stochastic_under_arbitrary_crash_subsets(
    n, r, seed, crash_rate
):
    """Property: whatever agent subset the Markov churn crashes in window
    r, the conserve-rule W-tilde stays row-stochastic, crashed rows are
    EXACTLY e_i, and crashed columns carry no off-diagonal mass (a
    crashed agent neither fires nor receives)."""
    from repro.gossip.clocks import PoissonClock
    from repro.gossip.faults import FaultModel, FaultSpec

    W = _random_row_stochastic(n, seed)
    clock = PoissonClock(W, rate=1.2, seed=seed)
    clock.attach_faults(FaultModel(
        FaultSpec(crash_rate=crash_rate, recover_rate=0.5, seed=seed + 1), n
    ))
    win = clock.window(r)
    crashed = clock.crashed(r)
    np.testing.assert_allclose(win.w_eff.sum(axis=1), 1.0, atol=1e-12)
    assert (win.w_eff >= 0).all()
    np.testing.assert_array_equal(win.w_eff[crashed], np.eye(n)[crashed])
    assert not win.active[crashed].any()
    off_diag = win.w_eff - np.diag(np.diag(win.w_eff))
    assert (off_diag[:, crashed] == 0.0).all()


def test_moe_dropless_at_high_capacity_property():
    """At capacity_factor high enough, NO assignment is dropped: the MoE
    output is independent of capacity_factor beyond that point."""
    import dataclasses

    from repro.configs import get_config
    from repro.models.moe import moe_ffn, moe_init

    base = get_config("olmoe-1b-7b").reduced()
    p = moe_init(jax.random.key(0), base)
    x = jax.random.normal(jax.random.key(1), (2, 8, base.d_model))
    outs = []
    for cf in (8.0, 16.0, 64.0):
        cfg = dataclasses.replace(base, capacity_factor=cf)
        y, _ = moe_ffn(p, x, cfg)
        outs.append(np.asarray(y, np.float32))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
    np.testing.assert_allclose(outs[1], outs[2], atol=1e-5)
