"""Theorem 1 validation: the finite-Theta learning rule converges at (at
least) the predicted exponential rate K(Theta), and the centrality/
informativeness phenomenology of Remark 3 holds."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.discrete import (
    run_social_learning,
    social_learning_round,
    wrong_belief_trajectory,
)
from repro.core.graphs import complete_w, ring_w, star_w
from repro.core.theory import rate_K, stationary_distribution


def _gaussian_loglik_sampler(key, means, noise_std, n_agents, batch=4):
    """Agents observe y ~ N(means[j, true], noise); loglik over candidate
    thetas: means[j, t].  means: [N, T] per-agent per-theta predicted mean
    (theta*=index 0)."""
    y = means[:, 0:1] + noise_std * jax.random.normal(key, (n_agents, batch))
    # log l(y | theta) summed over batch, [N, T]
    ll = -0.5 * jnp.sum(
        ((y[:, :, None] - means[:, None, :]) / noise_std) ** 2, axis=1
    )
    return ll


def _run(W, means, noise_std, rounds, seed=0):
    n_agents, n_theta = means.shape

    def sampler(k):
        return _gaussian_loglik_sampler(k, means, noise_std, n_agents)

    traj = run_social_learning(
        jax.random.key(seed), jnp.asarray(W), sampler, rounds, n_theta
    )
    wrong = wrong_belief_trajectory(traj, jnp.arange(1, n_theta))
    return np.asarray(wrong)


def test_converges_to_truth_when_jointly_identifiable():
    """No single agent can identify theta*, the network jointly can
    (Assumption 2): agent 0 distinguishes theta1, agent 1 distinguishes
    theta2."""
    # rows: agents; cols: candidate thetas (0 = truth)
    means = jnp.asarray(
        [
            [0.0, 1.0, 0.0],  # agent 0: theta2 indistinguishable from truth
            [0.0, 0.0, 1.0],  # agent 1: theta1 indistinguishable
        ]
    )
    W = np.array([[0.5, 0.5], [0.5, 0.5]])
    wrong = _run(W, means, noise_std=1.0, rounds=300)
    assert wrong[-1] < 1e-3, wrong[-1]


def test_isolated_agents_fail_without_cooperation():
    means = jnp.asarray([[0.0, 1.0, 0.0], [0.0, 0.0, 1.0]])
    W = np.eye(2)
    wrong = _run(W, means, noise_std=1.0, rounds=300)
    assert wrong[-1] > 0.3  # the ambiguous theta keeps high belief


def test_empirical_rate_close_to_K():
    """Empirical decay slope of max wrong belief ~ K(Theta) (eq. 7)."""
    n, t = 4, 3
    rng = np.random.default_rng(0)
    means = jnp.asarray(rng.normal(0, 1.0, (n, t)).astype(np.float32))
    means = means.at[:, 0].set(0.0)
    noise = 1.0
    W = complete_w(n)
    v = stationary_distribution(W)
    # I_j(theta*, theta_t) = (mu_true - mu_t)^2/(2 s^2) * batch(=4)
    I = np.zeros((n, 1, t - 1))
    for j in range(n):
        for tt in range(1, t):
            I[j, 0, tt - 1] = 4 * float((means[j, 0] - means[j, tt]) ** 2) / (2 * noise**2)
    K = rate_K(v, I)
    rounds = 150
    wrong = _run(W, means, noise, rounds, seed=1)
    # fit slope on log-beliefs over the tail
    tail = np.arange(rounds // 3, rounds)
    valid = wrong[tail] > 1e-30
    slope = -np.polyfit(tail[valid], np.log(wrong[tail][valid]), 1)[0]
    # Theorem 1: wrong belief < exp(-n(K - eps)); empirically slope >= ~K
    assert slope > 0.5 * K, (slope, K)
    assert wrong[-1] < wrong[0]


def test_centrality_speeds_convergence():
    """Remark 3: informative agent at the CENTER of a star converges faster
    than the same agent at an edge (compare log-belief decay, several
    seeds — the effect is about rates, not single-run endpoints)."""
    n = 5
    means = np.zeros((n, 2), np.float32)
    rounds = 25  # before float32 underflow (K*rounds stays representable)

    def decay_slope(idx, seed):
        m = means.copy()
        m[idx, 1] = 1.0  # only agent idx distinguishes theta1
        W = star_w(n - 1, a=0.5)  # center has high centrality
        wrong = _run(W, jnp.asarray(m), 1.0, rounds, seed)
        t = np.arange(5, rounds)
        lb = np.log(np.maximum(wrong[t], 1e-40))
        return -np.polyfit(t, lb, 1)[0]

    s_center = np.mean([decay_slope(0, s) for s in range(5)])
    s_edge = np.mean([decay_slope(2, s) for s in range(5)])
    # K_center = 0.77, K_edge = 0.31 for this setup: clear separation
    assert s_center > s_edge * 1.3, (s_center, s_edge)


def test_round_preserves_normalization():
    key = jax.random.key(0)
    logq = jnp.log(jnp.asarray([[0.2, 0.5, 0.3], [0.6, 0.2, 0.2]]))
    loglik = jax.random.normal(key, (2, 3))
    W = jnp.asarray(ring_w(2))
    logq2, logb = social_learning_round(logq, loglik, W)
    np.testing.assert_allclose(np.exp(logq2).sum(-1), 1.0, rtol=1e-5)
    np.testing.assert_allclose(np.exp(logb).sum(-1), 1.0, rtol=1e-5)
