"""Multi-device numeric tests for the production distribution layer.

These run in SUBPROCESSES with ``--xla_force_host_platform_device_count=8``
(a (2, 2, 2) pod/data/model mini-mesh) so the main pytest process keeps its
single CPU device.  They verify that the SHARDED production steps compute
the same numbers as the unsharded reference:

* eq.-(6) consensus over a sharded pod axis == single-device consensus
* the bf16 ppermute consensus == f32 einsum consensus up to bf16 rounding
* one fused train round on the mini-mesh == the same round on one device
"""
import textwrap

import pytest

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
"""


def _run(body: str) -> None:
    from conftest import run_multidevice_subprocess

    run_multidevice_subprocess(_PRELUDE + textwrap.dedent(body))


@pytest.mark.slow
@pytest.mark.multidevice
def test_consensus_einsum_sharded_matches_unsharded():
    _run("""
    from repro.core.posterior import GaussianPosterior, consensus_all_agents
    a, p = 2, 4096
    rng = np.random.default_rng(0)
    mean = jnp.asarray(rng.normal(size=(a, p)), jnp.float32)
    rho = jnp.asarray(rng.normal(size=(a, p)) * 0.3, jnp.float32)
    W = jnp.asarray([[0.7, 0.3], [0.4, 0.6]], jnp.float32)
    posts = GaussianPosterior(mean={"w": mean}, rho={"w": rho})
    ref = consensus_all_agents(posts, W)

    sh = NamedSharding(mesh, P("pod", ("data", "model")))
    posts_sh = GaussianPosterior(
        mean={"w": jax.device_put(mean, sh)}, rho={"w": jax.device_put(rho, sh)}
    )
    with mesh:
        out = jax.jit(lambda q: consensus_all_agents(q, W))(posts_sh)
    np.testing.assert_allclose(np.asarray(out.mean["w"]), np.asarray(ref.mean["w"]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out.rho["w"]), np.asarray(ref.rho["w"]),
                               rtol=1e-4, atol=1e-4)
    print("OK")
    """)


@pytest.mark.slow
@pytest.mark.multidevice
def test_consensus_ppermute_matches_einsum():
    # seed xfail removed: the failure was jax.shard_map missing on jax 0.4.x;
    # consensus_opt now falls back to jax.experimental.shard_map
    _run("""
    from repro.core.posterior import GaussianPosterior, consensus_all_agents
    from repro.launch.consensus_opt import consensus_ppermute_pod
    a, p = 2, 2048
    rng = np.random.default_rng(1)
    mean = jnp.asarray(rng.normal(size=(a, p)), jnp.float32)
    rho = jnp.asarray(rng.normal(size=(a, p)) * 0.3, jnp.float32)
    W = jnp.asarray([[0.6, 0.4], [0.25, 0.75]], jnp.float32)
    sh = NamedSharding(mesh, P("pod", ("data", "model")))
    posts = GaussianPosterior(
        mean={"w": jax.device_put(mean, sh)}, rho={"w": jax.device_put(rho, sh)}
    )
    shardings = GaussianPosterior(mean={"w": sh}, rho={"w": sh})
    ref = consensus_all_agents(posts, W)
    with mesh:
        out = jax.jit(lambda q: consensus_ppermute_pod(
            q, W, mesh, shardings, wire_dtype=jnp.bfloat16))(posts)
    # bf16 wire: ~3 decimal digits on the exchanged sufficient statistics
    np.testing.assert_allclose(np.asarray(out.mean["w"]), np.asarray(ref.mean["w"]),
                               rtol=2e-2, atol=2e-2)
    # f32 wire: exact
    with mesh:
        out32 = jax.jit(lambda q: consensus_ppermute_pod(
            q, W, mesh, shardings, wire_dtype=jnp.float32))(posts)
    np.testing.assert_allclose(np.asarray(out32.mean["w"]), np.asarray(ref.mean["w"]),
                               rtol=1e-5, atol=1e-5)
    print("OK")
    """)


@pytest.mark.slow
@pytest.mark.multidevice
def test_consensus_ppermute_ring_flat_matches_reference():
    """The FLAT ppermute route (one shard_map over the [N, P] buffers, ring
    weights read from W rows) == the fused flat consensus reference — the
    path make_train_round_step(consensus_impl="ppermute") now takes for
    FlatPosterior states (ROADMAP open item closed by ISSUE 3)."""
    _run("""
    from repro.core.flat import FlatLayout, FlatPosterior, consensus_flat
    from repro.launch.consensus_opt import consensus_ppermute_ring_flat
    a, p = 2, 2048
    rng = np.random.default_rng(4)
    mean = jnp.asarray(rng.normal(size=(a, p)), jnp.float32)
    rho = jnp.asarray(rng.normal(size=(a, p)) * 0.3, jnp.float32)
    W = jnp.asarray([[0.6, 0.4], [0.25, 0.75]], jnp.float32)
    layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
    sh = NamedSharding(mesh, P("pod", None))
    posts = FlatPosterior(mean=jax.device_put(mean, sh),
                          rho=jax.device_put(rho, sh), layout=layout)
    ref = consensus_flat(FlatPosterior(mean=mean, rho=rho, layout=layout), W)
    with mesh:
        out = jax.jit(lambda q: consensus_ppermute_ring_flat(
            q, mesh, "pod", wire_dtype=jnp.float32, W=W))(posts)
    np.testing.assert_allclose(np.asarray(out.mean), np.asarray(ref.mean),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out.rho), np.asarray(ref.rho),
                               rtol=1e-4, atol=1e-4)
    print("OK")
    """)


@pytest.mark.slow
@pytest.mark.multidevice
@pytest.mark.xfail(
    reason="pre-existing seed failure (numerical mismatch on the single-CPU-device substrate); identical at seed commit e353c71",
    strict=False,
)
def test_train_round_step_sharded_matches_single_device():
    _run("""
    from repro.configs import get_config
    from repro.core.graphs import complete_w
    from repro.launch.steps import init_train_state, make_train_round_step
    from repro.launch.sharding import param_shardings
    from repro.data.pipeline import make_lm_batch_sampler
    from repro.optim import adam

    cfg = get_config("repro-100m").reduced()
    a = 2
    opt = adam()
    W = jnp.asarray(complete_w(a))
    step = make_train_round_step(cfg, W, opt=opt, remat=False, kl_scale=1e-5)
    state = init_train_state(jax.random.key(0), cfg, a, opt)
    batch = make_lm_batch_sampler(cfg.vocab_size, 4, 32, n_agents=a)(
        jax.random.key(1), 0)
    key = jax.random.key(2)
    ref_state, ref_m = jax.jit(step)(state, batch, key)

    shardings = param_shardings(jax.eval_shape(lambda: state), mesh,
                                agent_leading=True)
    state_sh = jax.tree.map(jax.device_put, state, shardings)
    with mesh:
        out_state, out_m = jax.jit(step)(state_sh, batch, key)
    np.testing.assert_allclose(float(jnp.mean(out_m["loss"])),
                               float(jnp.mean(ref_m["loss"])), rtol=1e-4)
    l_ref = jax.tree.leaves(ref_state.posterior.mean)[0]
    l_out = jax.tree.leaves(out_state.posterior.mean)[0]
    # Adam turns bf16 reduction-order noise on ~0 grads into +-lr sign flips
    # (|delta| <= 2*lr = 2e-3) on a tiny fraction of elements; bound both the
    # per-element deviation and how many elements deviate at all.
    diff = np.abs(np.asarray(l_out) - np.asarray(l_ref))
    assert diff.max() <= 2.5e-3, diff.max()
    assert (diff > 1e-4).mean() < 5e-3, (diff > 1e-4).mean()
    print("OK")
    """)


@pytest.mark.slow
@pytest.mark.multidevice
def test_decode_step_sharded_matches_single_device():
    _run("""
    from repro.configs import get_config
    from repro.launch.steps import make_agent_cache, make_decode_step, make_prefill_step
    from repro.launch.sharding import cache_shardings, param_shardings
    from repro.models import init_params

    cfg = get_config("qwen3-8b").reduced()
    a, b, s = 2, 4, 8
    params = jax.vmap(lambda k: init_params(cfg, k))(
        jax.random.split(jax.random.key(0), a))
    toks = jax.random.randint(jax.random.key(1), (a, b, s), 0, cfg.vocab_size)
    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg)
    cache = make_agent_cache(cfg, a, b, capacity=s + 2, dtype=jnp.float32)
    lg_ref, cache_ref = jax.jit(prefill)(params, {"tokens": toks}, cache)
    d_ref, _ = jax.jit(decode)(params, toks[:, :, :1],
                               jnp.asarray(s, jnp.int32), cache_ref, None)

    psh = param_shardings(jax.eval_shape(lambda: params), mesh, agent_leading=True)
    csh = cache_shardings(jax.eval_shape(lambda: cache), mesh, agent_leading=True)
    params_sh = jax.tree.map(jax.device_put, params, psh)
    cache_sh = jax.tree.map(jax.device_put, cache, csh)
    tok_sh = jax.device_put(toks, NamedSharding(mesh, P("pod", "data", None)))
    with mesh:
        lg, cache2 = jax.jit(prefill)(params_sh, {"tokens": tok_sh}, cache_sh)
        d, _ = jax.jit(decode)(params_sh, tok_sh[:, :, :1],
                               jnp.asarray(s, jnp.int32), cache2, None)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(lg_ref, np.float32), atol=5e-2, rtol=5e-2)
    np.testing.assert_allclose(np.asarray(d, np.float32),
                               np.asarray(d_ref, np.float32), atol=5e-2, rtol=5e-2)
    print("OK")
    """)


@pytest.mark.slow
@pytest.mark.multidevice
@pytest.mark.xfail(
    reason="pre-existing seed failure (numerical mismatch on the single-CPU-device substrate); identical at seed commit e353c71",
    strict=False,
)
def test_expert_parallel_matches_reference():
    _run("""
    import dataclasses
    from repro.configs import get_config
    from repro.models.moe import moe_ffn, moe_init
    from repro.launch.expert_parallel import moe_ffn_expert_parallel

    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(
        get_config("olmoe-1b-7b").reduced(), n_experts=8, top_k=2,
        capacity_factor=16.0,  # no drops: exact comparison
    )
    p = moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y_ref, aux_ref = moe_ffn(p, x, cfg)
    with mesh2:
        y_ep, aux_ep = jax.jit(
            lambda p_, x_: moe_ffn_expert_parallel(p_, x_, cfg, mesh2)
        )(p, x)
    np.testing.assert_allclose(np.asarray(y_ep, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=2e-3, rtol=2e-3)
    assert np.isclose(float(aux_ep), float(aux_ref), rtol=0.3)
    print("OK")
    """)
