"""Property tests for the flat-buffer posterior + fused network consensus:
flat-fused (XLA and Pallas-interpret, dense and sparse) must agree with the
``consensus_all_agents`` leaf-loop einsum reference to <= 1e-6 on ragged
mixed-shape pytrees, sparse W rows, and non-divisible P % BLOCK padding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_flat_posterior, save_flat_posterior
from repro.core.flat import (
    FlatLayout,
    FlatPosterior,
    consensus_flat,
    consensus_flat_sparse,
    flat_posterior_from_pytree,
    init_flat_posterior,
    make_flat_nll,
    neighbor_tables,
)
from repro.core.graphs import bidirectional_ring_w, complete_w, star_w
from repro.core.numerics import softplus, softplus_inv
from repro.core.posterior import (
    GaussianPosterior,
    consensus_all_agents,
    init_posterior,
)
from repro.kernels.consensus import consensus_fused, consensus_fused_network


def _ragged_posts(n, seed=0, dtypes=None):
    """Deliberately ragged mixed-shape (optionally mixed-dtype) pytree with
    nested containers — scalars, odd 1-D, 2-D, 3-D leaves."""
    rng = np.random.default_rng(seed)
    shapes = {"s": (), "v": (17,), "m": (3, 5), "t": (2, 3, 7), "odd": (129,)}
    dtypes = dtypes or {k: jnp.float32 for k in shapes}
    mean = {
        k: jnp.asarray(rng.normal(size=(n,) + shp), dtypes[k])
        for k, shp in shapes.items()
    }
    rho = {
        k: jnp.asarray(rng.normal(size=(n,) + shp) * 0.3 - 0.5, dtypes[k])
        for k, shp in shapes.items()
    }
    # nest one branch to exercise non-trivial treedefs
    mean["nested"] = (mean.pop("t"), [mean.pop("odd")])
    rho["nested"] = (rho.pop("t"), [rho.pop("odd")])
    return GaussianPosterior(mean=mean, rho=rho)


def _assert_tree_close(a, b, atol=1e-6, rtol=1e-5):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(
            np.asarray(la, np.float32), np.asarray(lb, np.float32),
            atol=atol, rtol=rtol,
        )


def test_flat_roundtrip_mixed_dtypes():
    posts = _ragged_posts(
        3, dtypes={"s": jnp.float32, "v": jnp.bfloat16, "m": jnp.float32,
                   "t": jnp.float16, "odd": jnp.float32},
    )
    flat = flat_posterior_from_pytree(posts, leading_axes=1)
    assert flat.mean.dtype == jnp.float32 and flat.mean.ndim == 2
    rt = flat.to_pytree()
    assert jax.tree.structure(rt.mean) == jax.tree.structure(posts.mean)
    for orig, back in zip(jax.tree.leaves(posts.mean), jax.tree.leaves(rt.mean)):
        assert orig.dtype == back.dtype  # no silent promotion
        np.testing.assert_allclose(
            np.asarray(orig, np.float32), np.asarray(back, np.float32), atol=1e-3
        )


@pytest.mark.parametrize("topology", ["complete", "ring", "star"])
@pytest.mark.parametrize("mode", ["xla", "interpret"])
def test_flat_consensus_matches_leaf_loop_reference(topology, mode):
    n = 6
    W = {
        "complete": complete_w(n),
        "ring": bidirectional_ring_w(n),
        "star": star_w(n - 1, a=0.4),
    }[topology]
    W = jnp.asarray(W, jnp.float32)
    posts = _ragged_posts(n, seed=topology.__hash__() % 97)
    flat = flat_posterior_from_pytree(posts, leading_axes=1)
    assert flat.layout.n_params % 128 != 0  # padding lanes ARE exercised
    ref = consensus_all_agents(posts, W)
    out = consensus_flat(flat, W, mode=mode, block=128).to_pytree()
    _assert_tree_close(out.mean, ref.mean)
    _assert_tree_close(out.rho, ref.rho)


@pytest.mark.parametrize("mode", ["xla", "interpret"])
def test_flat_sparse_consensus_skips_zero_rows(mode):
    """CSR neighbor-table path == dense reference on sparse W (zero-weight
    entries contribute exactly nothing)."""
    n = 8
    W = jnp.asarray(bidirectional_ring_w(n), jnp.float32)
    posts = _ragged_posts(n, seed=5)
    flat = flat_posterior_from_pytree(posts, leading_axes=1)
    nbr, wts = neighbor_tables(np.asarray(W))
    assert nbr.shape[1] == 3  # ring: self + 2 neighbors, NOT n
    ref = consensus_all_agents(posts, W)
    out = consensus_flat_sparse(
        flat, jnp.asarray(nbr), jnp.asarray(wts), mode=mode, block=128
    ).to_pytree()
    _assert_tree_close(out.mean, ref.mean)
    _assert_tree_close(out.rho, ref.rho)


def test_network_kernel_rows_match_per_agent_kernel():
    """consensus_fused_network row i == consensus_fused with w_row = W[i]."""
    n, p = 5, 300
    ks = jax.random.split(jax.random.key(3), 3)
    mean = jax.random.normal(ks[0], (n, p))
    rho = jax.random.normal(ks[1], (n, p)) * 0.4 - 1.0
    W = jax.nn.softmax(jax.random.normal(ks[2], (n, n)), axis=1)
    mo, ro = consensus_fused_network(W, mean, rho, block=128, interpret=True)
    for i in range(n):
        mi, ri = consensus_fused(W[i], mean, rho, block=128, interpret=True)
        np.testing.assert_allclose(mo[i], mi, atol=1e-6, rtol=1e-5)
        np.testing.assert_allclose(ro[i], ri, atol=1e-6, rtol=1e-5)


def test_consensus_identity_and_fixed_point_flat():
    n = 4
    posts = _ragged_posts(n, seed=11)
    flat = flat_posterior_from_pytree(posts, leading_axes=1)
    out = consensus_flat(flat, jnp.eye(n), mode="xla")
    np.testing.assert_allclose(out.mean, flat.mean, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(out.rho, flat.rho, atol=1e-4, rtol=1e-4)
    # identical agents: any row-stochastic W is a fixed point
    same = FlatPosterior(
        mean=jnp.broadcast_to(flat.mean[:1], flat.mean.shape),
        rho=jnp.broadcast_to(flat.rho[:1], flat.rho.shape),
        layout=flat.layout,
    )
    W = jax.nn.softmax(jax.random.normal(jax.random.key(0), (n, n)), axis=1)
    out = consensus_flat(same, W, mode="xla")
    np.testing.assert_allclose(out.mean, same.mean, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(out.rho, same.rho, atol=1e-4, rtol=1e-4)


def test_softplus_inv_extreme_sigma_regression():
    """Satellite regression: the shared stable softplus^-1 at tiny/huge
    sigma, and the fused kernel staying finite there."""
    tiny = jnp.asarray([1e-7, 1e-5, 1e-3], jnp.float32)
    huge = jnp.asarray([1e2, 1e4, 3e8], jnp.float32)
    for y in (tiny, huge):
        x = softplus_inv(y)
        assert np.all(np.isfinite(np.asarray(x)))
        np.testing.assert_allclose(np.asarray(softplus(x)), np.asarray(y), rtol=1e-5)
    # kernel round-trip with rho chosen so sigma spans tiny..huge
    n, p = 3, 256
    rho = jnp.stack([
        jnp.full((p,), softplus_inv(jnp.float32(1e-4))),
        jnp.full((p,), softplus_inv(jnp.float32(1.0))),
        jnp.full((p,), jnp.float32(1e4)),  # softplus(x) ~ x for huge x
    ])
    mean = jnp.ones((n, p))
    W = jnp.asarray(complete_w(n), jnp.float32)
    mo, ro = consensus_fused_network(W, mean, rho, block=128, interpret=True)
    assert np.all(np.isfinite(np.asarray(mo)))
    assert np.all(np.isfinite(np.asarray(ro)))


def test_flat_vi_round_and_dispatch():
    """End-to-end flat runtime: init_network(flat=True) + param_layout round
    steps under vmap, consensus_all_agents auto-dispatches on FlatPosterior."""
    from repro.core.simulated import init_network, make_round_fn
    from repro.optim import adam
    from repro.optim.schedules import constant_schedule

    n_agents, dim = 4, 8

    def init_params(key):
        k1, k2 = jax.random.split(key)
        return {
            "w": jax.random.normal(k1, (dim, 2)) * 0.1,
            "b": jnp.zeros((2,)),
        }

    def nll(theta, batch):
        logits = batch["x"] @ theta["w"] + theta["b"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["y"][..., None], -1)[..., 0]
        return jnp.sum(logz - gold)

    opt = adam()
    state = init_network(jax.random.key(0), n_agents, init_params, opt, flat=True)
    assert isinstance(state.posterior, FlatPosterior)
    layout = state.posterior.layout
    round_fn = jax.jit(
        make_round_fn(nll, opt, constant_schedule(1e-2), param_layout=layout)
    )
    rng = np.random.default_rng(0)
    batches = {
        "x": jnp.asarray(rng.normal(size=(n_agents, 2, 6, dim)), jnp.float32),
        "y": jnp.asarray(rng.integers(0, 2, size=(n_agents, 2, 6)), jnp.int32),
    }
    W = jnp.asarray(bidirectional_ring_w(n_agents), jnp.float32)
    losses = None
    for r in range(3):
        state, losses = round_fn(state, batches, W, jax.random.key(r + 1))
    assert isinstance(state.posterior, FlatPosterior)
    assert np.all(np.isfinite(np.asarray(losses)))
    assert int(state.round) == 3
    # the consensus inside the round used the flat dispatch; check the
    # explicit dispatch path agrees with the leaf-loop reference too
    ref = consensus_all_agents(state.posterior.to_pytree(), W)
    out = consensus_all_agents(state.posterior, W).to_pytree()
    _assert_tree_close(out.mean, ref.mean, atol=1e-5)


def test_flat_checkpoint_roundtrip(tmp_path):
    posts = _ragged_posts(5, seed=2)
    flat = flat_posterior_from_pytree(posts, leading_axes=1)
    path = os.path.join(tmp_path, "flat.ckpt")
    save_flat_posterior(path, flat)
    back = restore_flat_posterior(path)
    assert back.layout == flat.layout  # offsets/shapes/dtypes/treedef intact
    np.testing.assert_array_equal(np.asarray(back.mean), np.asarray(flat.mean))
    np.testing.assert_array_equal(np.asarray(back.rho), np.asarray(flat.rho))
    # restored posterior still unflattens to the original structure
    assert jax.tree.structure(back.to_pytree().mean) == jax.tree.structure(posts.mean)


def test_ops_flatten_preserves_mixed_dtypes():
    """Satellite regression: ops._flatten/_unflatten round-trips dtypes
    (jnp.concatenate used to silently promote mixed-dtype leaves)."""
    from repro.kernels.ops import _flatten, _unflatten

    tree = {
        "a": jnp.ones((3, 2), jnp.bfloat16),
        "b": jnp.arange(4, dtype=jnp.float32),
        "c": jnp.ones((2,), jnp.float16),
    }
    flat, treedef, shapes, dtypes = _flatten(tree)
    assert flat.dtype == jnp.float32
    back = _unflatten(flat, treedef, shapes, dtypes)
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_allclose(
            np.asarray(back[k], np.float32), np.asarray(tree[k], np.float32)
        )


def test_make_flat_nll_boundary():
    params = {"w": jnp.ones((3, 4)), "b": jnp.zeros((4,))}
    layout = FlatLayout.for_pytree(params)
    flat_post = init_flat_posterior(params, init_sigma=0.1)

    def nll(theta, batch):
        assert set(theta) == {"w", "b"}  # model sees a pytree, not the buffer
        return jnp.sum(theta["w"]) + jnp.sum(theta["b"]) + batch

    fnll = make_flat_nll(nll, layout)
    val = fnll(flat_post.mean, 0.0)
    np.testing.assert_allclose(float(val), 12.0, atol=1e-5)


def test_bench_harness_smoke(tmp_path, capsys):
    """CI/tooling satellite: the `bench` subcommand runs the consensus sweep
    quickly (interpret-mode probe included) and writes valid JSON."""
    import json
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks import run as bench_run

    out = os.path.join(tmp_path, "BENCH_consensus.json")
    bench_run.main(["bench", "--json-out", out])
    doc = json.load(open(out))
    assert doc["benchmark"] == "consensus_eq6" and doc["quick"]
    rec = doc["results"][0]
    assert rec["us"]["flat_fused"] > 0 and rec["us"]["leaf_loop"] > 0
    assert rec["roofline"]["model_speedup_fused_vs_leaf_loop"] >= 3.0
    for err in rec["interpret_max_err"].values():
        assert err < 1e-5
