"""Property-based tests for the paper's core objects: mean-field/full-cov
Gaussian posteriors and the eq.-(6) consensus operator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this image")
from hypothesis import given, settings, strategies as st

from repro.core.posterior import (
    FullCovGaussian,
    GaussianPosterior,
    consensus_all_agents,
    consensus_full_cov,
    consensus_mean_field,
    init_posterior,
    kl_gaussian,
    linreg_bayes_update,
    softplus,
    softplus_inv,
)

finite_f = st.floats(-5.0, 5.0, allow_nan=False, allow_infinity=False)
pos_f = st.floats(0.05, 3.0, allow_nan=False)


def _posts(n, p, seed=0, sigma_scale=1.0):
    rng = np.random.default_rng(seed)
    mean = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    rho = jnp.asarray(rng.normal(size=(n, p)) * 0.3 * sigma_scale, jnp.float32)
    return GaussianPosterior(mean={"w": mean}, rho={"w": rho})


@settings(max_examples=30, deadline=None)
@given(st.floats(0.01, 10.0))
def test_softplus_inverse_roundtrip(y):
    x = softplus_inv(jnp.asarray(y, jnp.float32))
    assert np.isclose(float(softplus(x)), y, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(1, 40), st.integers(0, 100))
def test_consensus_identity_w(n, p, seed):
    """W = I must leave every agent's posterior unchanged."""
    posts = _posts(n, p, seed)
    out = consensus_all_agents(posts, jnp.eye(n))
    np.testing.assert_allclose(out.mean["w"], posts.mean["w"], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out.rho["w"], posts.rho["w"], rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(1, 20), st.integers(0, 100))
def test_consensus_consensus_fixed_point(n, p, seed):
    """If all agents hold the SAME posterior, any row-stochastic W fixes it."""
    rng = np.random.default_rng(seed)
    one = rng.normal(size=(1, p))
    posts = GaussianPosterior(
        mean={"w": jnp.asarray(np.repeat(one, n, 0), jnp.float32)},
        rho={"w": jnp.full((n, p), -1.0, jnp.float32)},
    )
    W = rng.random((n, n)) + 0.1
    W = jnp.asarray(W / W.sum(1, keepdims=True), jnp.float32)
    out = consensus_all_agents(posts, W)
    np.testing.assert_allclose(out.mean["w"], posts.mean["w"], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(out.rho["w"], posts.rho["w"], rtol=1e-3, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(1, 16), st.integers(0, 50))
def test_consensus_precision_is_convex_combo(n, p, seed):
    """Output precision = W-weighted combination => bounded by neighbor
    min/max precision (positivity + boundedness invariant)."""
    posts = _posts(n, p, seed)
    rng = np.random.default_rng(seed + 1)
    W = rng.random((n, n)) + 0.05
    W = jnp.asarray(W / W.sum(1, keepdims=True), jnp.float32)
    out = consensus_all_agents(posts, W)
    prec_in = 1.0 / np.square(np.asarray(softplus(posts.rho["w"])))
    prec_out = 1.0 / np.square(np.asarray(softplus(out.rho["w"])))
    assert np.all(prec_out > 0)
    assert np.all(prec_out <= prec_in.max(0) * (1 + 1e-4))
    assert np.all(prec_out >= prec_in.min(0) * (1 - 1e-4))


def test_consensus_matches_log_pool_numerically():
    """Eq. (4) log-linear pooling of Gaussian pdfs == eq. (6) closed form,
    checked by numeric integration on a 1-d grid."""
    mus = np.array([0.5, -1.0, 2.0])
    sigmas = np.array([0.7, 1.3, 0.4])
    w = np.array([0.2, 0.5, 0.3])
    grid = np.linspace(-10, 10, 20001)
    logp = sum(
        wi * (-0.5 * ((grid - m) / s) ** 2 - np.log(s))
        for wi, m, s in zip(w, mus, sigmas)
    )
    p = np.exp(logp - logp.max())
    p /= np.trapezoid(p, grid)
    mean_num = np.trapezoid(p * grid, grid)
    var_num = np.trapezoid(p * (grid - mean_num) ** 2, grid)

    posts = GaussianPosterior(
        mean={"w": jnp.asarray(mus[:, None], jnp.float32)},
        rho={"w": jnp.asarray(softplus_inv(jnp.asarray(sigmas))[:, None], jnp.float32)},
    )
    out = consensus_mean_field(posts, jnp.asarray(w, jnp.float32))
    sigma_out = float(softplus(out.rho["w"][0]))
    assert np.isclose(float(out.mean["w"][0]), mean_num, atol=1e-3)
    assert np.isclose(sigma_out**2, var_num, rtol=1e-3)


@settings(max_examples=25, deadline=None)
@given(finite_f, pos_f, finite_f, pos_f)
def test_kl_nonnegative_and_zero_iff_equal(m1, s1, m2, s2):
    q = GaussianPosterior(
        mean={"w": jnp.asarray([m1], jnp.float32)},
        rho={"w": softplus_inv(jnp.asarray([s1], jnp.float32))},
    )
    p = GaussianPosterior(
        mean={"w": jnp.asarray([m2], jnp.float32)},
        rho={"w": softplus_inv(jnp.asarray([s2], jnp.float32))},
    )
    kl = float(kl_gaussian(q, p))
    assert kl >= -1e-5
    assert np.isclose(float(kl_gaussian(q, q)), 0.0, atol=1e-6)


def test_full_cov_consensus_reduces_to_mean_field_on_diagonals():
    rng = np.random.default_rng(0)
    n, d = 3, 4
    mus = rng.normal(size=(n, d))
    sig = rng.uniform(0.3, 2.0, size=(n, d))
    W = rng.random((n, n)) + 0.1
    W = W / W.sum(1, keepdims=True)
    fc = FullCovGaussian(
        mean=jnp.asarray(mus, jnp.float32),
        prec=jnp.asarray(np.stack([np.diag(1 / s**2) for s in sig]), jnp.float32),
    )
    out_fc = consensus_full_cov(fc, jnp.asarray(W, jnp.float32))
    mf = GaussianPosterior(
        mean={"w": jnp.asarray(mus, jnp.float32)},
        rho={"w": softplus_inv(jnp.asarray(sig, jnp.float32))},
    )
    out_mf = consensus_all_agents(mf, jnp.asarray(W, jnp.float32))
    np.testing.assert_allclose(out_fc.mean, out_mf.mean["w"], rtol=1e-4, atol=1e-5)
    var_fc = np.stack([np.diag(np.linalg.inv(p)) for p in np.asarray(out_fc.prec)])
    var_mf = np.square(np.asarray(softplus(out_mf.rho["w"])))
    np.testing.assert_allclose(var_fc, var_mf, rtol=1e-3)


def test_linreg_bayes_update_matches_closed_form():
    rng = np.random.default_rng(1)
    d, b = 3, 20
    phi = rng.normal(size=(b, d))
    theta = rng.normal(size=d)
    y = phi @ theta + rng.normal(0, 0.5, b)
    prior = FullCovGaussian(
        mean=jnp.zeros(d, jnp.float32), prec=jnp.eye(d, dtype=jnp.float32) * 2.0
    )
    post = linreg_bayes_update(prior, jnp.asarray(phi, jnp.float32),
                               jnp.asarray(y, jnp.float32), 0.25)
    prec_ref = 2.0 * np.eye(d) + phi.T @ phi / 0.25
    mean_ref = np.linalg.solve(prec_ref, phi.T @ y / 0.25)
    np.testing.assert_allclose(np.asarray(post.prec), prec_ref, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(post.mean), mean_ref, rtol=1e-3, atol=1e-4)


def test_posterior_sample_statistics():
    post = init_posterior({"w": jnp.zeros((2000,))}, init_sigma=0.5)
    s = post.sample(jax.random.key(0))
    assert abs(float(jnp.mean(s["w"]))) < 0.05
    assert np.isclose(float(jnp.std(s["w"])), 0.5, rtol=0.1)
