"""Event-driven gossip runtime (repro.gossip): clock determinism and
Assumption-1 validation, the masked active-edge consensus kernels
(bit-identical all-active equivalence + bit-stable passthrough), the
GossipEngine on the Engine protocol (one jitted call per window, resume,
staleness telemetry), the time_varying_star re-expression, and the
gossip-window roofline satellite."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    RunSpec,
    Session,
    TopologySpec,
    build_session,
)
from repro.core.flat import (
    consensus_flat,
    consensus_flat_masked,
    consensus_flat_masked_sparse,
    neighbor_tables,
)
from repro.core.graphs import (
    bidirectional_ring_w,
    complete_w,
    time_varying_star_schedule,
)
from repro.gossip.clocks import (
    FailureInjectedClock,
    PoissonClock,
    RoundRobinClock,
    TraceClock,
    all_edges_trace,
    build_clock,
    trace_from_schedule,
    window_from_events,
    _directed_edges,
)
from repro.kernels.consensus import (
    consensus_fused_masked,
    consensus_fused_network,
)
from repro.launch.costmodel import consensus_roofline, gossip_window_roofline


def _rand_posts(n, p, seed=0):
    ks = jax.random.split(jax.random.key(seed), 2)
    mean = jax.random.normal(ks[0], (n, p))
    rho = jax.random.normal(ks[1], (n, p)) * 0.4 - 1.0
    return mean, rho


def _gossip_data(n_agents, local_updates=2):
    return DataSpec(
        dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
        partition="iid",
        partition_params=dict(n_agents=n_agents),
        batch_size=4,
        local_updates=local_updates,
    )


def _gossip_spec(topology, n_agents, n_rounds=3, seed=0, **inf_kw):
    return ExperimentSpec(
        topology=topology,
        data=_gossip_data(n_agents),
        inference=InferenceSpec(hidden=8, depth=1, lr=1e-2, **inf_kw),
        run=RunSpec(n_rounds=n_rounds, seed=seed),
    )


# ---------------------------------------------------------------------------
# clocks: determinism, windows, validation
# ---------------------------------------------------------------------------


def test_poisson_clock_deterministic_and_row_stochastic():
    W = bidirectional_ring_w(6)
    c = PoissonClock(W, rate=0.8, seed=3)
    for r in range(6):
        a, b = c.window(r), c.window(r)
        np.testing.assert_array_equal(a.edges, b.edges)
        np.testing.assert_array_equal(a.w_eff, b.w_eff)
        np.testing.assert_allclose(a.w_eff.sum(axis=1), 1.0, atol=1e-12)
        # inactive rows are EXACTLY e_i (the engine's mask contract)
        inactive = ~a.active
        np.testing.assert_array_equal(
            a.w_eff[inactive], np.eye(6)[inactive]
        )
        assert a.edges.shape == (c.e_max, 2)  # static shapes across windows


def test_round_robin_cycles_all_edges():
    W = bidirectional_ring_w(4)
    c = RoundRobinClock(W, edges_per_window=2)
    fired = set()
    for r in range(len(_directed_edges(W)) // 2):
        w = c.window(r)
        fired.update(map(tuple, w.edges[: w.n_events].tolist()))
    assert fired == set(_directed_edges(W))  # one cycle covers the graph


def test_failure_injection_drops_but_preserves_union():
    W = complete_w(5)
    inner = PoissonClock(W, rate=5.0, seed=1)
    c = FailureInjectedClock(inner, drop_rate=0.5, seed=2)
    dropped = sum(
        inner.window(r).n_events - c.window(r).n_events for r in range(8)
    )
    assert dropped > 0
    np.testing.assert_array_equal(c.union_support(), inner.union_support())
    c.validate()  # union still satisfies Assumption 1


def test_window_feasibility_and_event_checks():
    W = bidirectional_ring_w(4)
    with pytest.raises(ValueError, match="self-event"):
        window_from_events(W, [(1, 1)], e_max=4)
    with pytest.raises(ValueError, match="not an edge"):
        window_from_events(W, [(0, 2)], e_max=4)  # ring: 0-2 not adjacent
    # weight-table row over-commitment is rejected
    table = np.array([[1.0, 0.6, 0.6], [0.5, 1.0, 0.0], [0.5, 0.0, 1.0]])
    with pytest.raises(ValueError, match="row-feasible"):
        window_from_events(table, [(0, 1), (0, 2)], e_max=4, rule="table")


def test_trace_clock_conserve_requires_row_stochastic_base():
    """Review regression: a non-row-stochastic base under rule="conserve"
    would silently produce non-row-stochastic windows."""
    W_bad = bidirectional_ring_w(4) * 1.5
    with pytest.raises(ValueError, match="row-stochastic"):
        TraceClock(W_bad, [[(0, 1)]], rule="conserve")


def test_gossip_convenience_rejects_w_with_named_base():
    """Review regression: gossip(w=...) with a named base would silently
    drop the user's matrix."""
    with pytest.raises(ValueError, match="explicit"):
        TopologySpec.gossip("bidirectional_ring", {"n": 4},
                            w=bidirectional_ring_w(4))


def test_failure_drop_stream_independent_of_inner_stream():
    """Review regression: with equal (default) seeds the drop uniforms must
    NOT come from the same generator state as the inner firing draws."""
    W = complete_w(5)
    inner = PoissonClock(W, rate=5.0, seed=0)
    c = FailureInjectedClock(inner, drop_rate=0.5, seed=0)
    outer_stream = np.random.default_rng([0, 0])
    inner_stream = np.random.default_rng([0, 0])
    assert outer_stream.bit_generator.state == inner_stream.bit_generator.state
    # the clock still drops ~half the edges deterministically per (seed, r)
    kept = [c.window(r).n_events for r in range(6)]
    fired = [inner.window(r).n_events for r in range(6)]
    assert kept == [c.window(r).n_events for r in range(6)]
    assert sum(kept) < sum(fired)
    # drop decisions replayed from the salted stream match the clock output
    ev0 = inner.window(0)
    drops = np.random.default_rng([0, 0xFA11ED, 0]).random(ev0.n_events) < 0.5
    assert c.window(0).n_events == int((~drops).sum())


def test_gossip_topology_validates_union_connectivity():
    # two disconnected ring components: union can never be strongly connected
    blocks = np.zeros((6, 6))
    blocks[:3, :3] = bidirectional_ring_w(3)
    blocks[3:, 3:] = bidirectional_ring_w(3)
    topo = TopologySpec.gossip("explicit", w=blocks,
                               clock={"kind": "poisson", "rate": 1.0})
    with pytest.raises(ValueError, match="strongly connected"):
        _gossip_spec(topo, 6).validate()


def test_gossip_engine_field_cross_validation():
    topo = TopologySpec.gossip("bidirectional_ring", {"n": 4})
    spec = _gossip_spec(topo, 4)
    # gossip topology + launch engine is contradictory
    with pytest.raises(ValueError, match="GossipEngine"):
        dataclasses.replace(
            spec, run=dataclasses.replace(spec.run, engine="launch")
        ).validate()
    # engine="gossip" without a gossip topology is contradictory
    with pytest.raises(ValueError, match="kind='gossip'"):
        ExperimentSpec(
            topology=TopologySpec.complete(4),
            data=_gossip_data(4),
            run=RunSpec(engine="gossip"),
        ).validate()


def test_clock_doc_registry_roundtrip():
    W = bidirectional_ring_w(4)
    doc = {
        "kind": "failure_injected",
        "inner": {"kind": "poisson", "rate": 0.5, "seed": 7},
        "drop_rate": 0.25,
        "seed": 9,
    }
    c = build_clock(doc, W)
    assert isinstance(c, FailureInjectedClock)
    np.testing.assert_array_equal(
        c.window(2).edges, build_clock(doc, W).window(2).edges
    )
    with pytest.raises(ValueError, match="unknown clock kind"):
        build_clock({"kind": "quartz"}, W)


# ---------------------------------------------------------------------------
# masked consensus kernels: all-active bit-identity + bit-stable passthrough
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["xla", "interpret"])
def test_all_active_window_equals_network_kernel_bitwise(mode):
    """Acceptance: the all-edges-active window == consensus_fused_network /
    consensus_flat OUTPUT BIT-IDENTICALLY (assert_array_equal, no atol)."""
    from repro.core.flat import FlatPosterior, FlatLayout

    n, p = 5, 300
    mean, rho = _rand_posts(n, p)
    W = jnp.asarray(bidirectional_ring_w(n), jnp.float32)
    layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
    posts = FlatPosterior(mean=mean, rho=rho, layout=layout)
    allmask = jnp.ones((n,), bool)
    out = consensus_flat_masked(posts, W, allmask, mode=mode, block=128)
    ref = consensus_flat(posts, W, mode=mode, block=128)
    np.testing.assert_array_equal(np.asarray(out.mean), np.asarray(ref.mean))
    np.testing.assert_array_equal(np.asarray(out.rho), np.asarray(ref.rho))
    if mode == "interpret":
        mn, rn = consensus_fused_network(W, mean, rho, block=128, interpret=True)
        mm, rm = consensus_fused_masked(W, allmask, mean, rho, block=128,
                                        interpret=True)
        np.testing.assert_array_equal(np.asarray(mm), np.asarray(mn))
        np.testing.assert_array_equal(np.asarray(rm), np.asarray(rn))


@pytest.mark.parametrize("mode", ["xla", "interpret"])
def test_partial_window_passthrough_and_active_rows(mode):
    """Inactive agents pass through BITWISE (no softplus round trip); active
    rows match the dense reference on the window's W-tilde.  Dense-masked
    and CSR-masked paths agree."""
    from repro.core.flat import FlatPosterior, FlatLayout

    n, p = 6, 260
    mean, rho = _rand_posts(n, p, seed=4)
    layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
    posts = FlatPosterior(mean=mean, rho=rho, layout=layout)
    win = PoissonClock(bidirectional_ring_w(n), rate=0.4, seed=7).window(0)
    assert 0 < win.active.sum() < n  # genuinely partial
    W = jnp.asarray(win.w_eff, jnp.float32)
    act = jnp.asarray(win.active)

    out = consensus_flat_masked(posts, W, act, mode=mode, block=128)
    inactive = ~win.active
    np.testing.assert_array_equal(
        np.asarray(out.mean)[inactive], np.asarray(mean)[inactive]
    )
    np.testing.assert_array_equal(
        np.asarray(out.rho)[inactive], np.asarray(rho)[inactive]
    )
    ref = consensus_flat(posts, W, mode="xla", block=128)
    active = win.active
    np.testing.assert_allclose(
        np.asarray(out.mean)[active], np.asarray(ref.mean)[active],
        atol=1e-6, rtol=1e-5,
    )

    nbr, wts = neighbor_tables(win.w_eff)
    sp = consensus_flat_masked_sparse(
        posts, jnp.asarray(nbr), jnp.asarray(wts), act, mode=mode, block=128
    )
    np.testing.assert_allclose(
        np.asarray(sp.mean), np.asarray(out.mean), atol=1e-6, rtol=1e-5
    )
    np.testing.assert_array_equal(
        np.asarray(sp.mean)[inactive], np.asarray(mean)[inactive]
    )
    np.testing.assert_array_equal(
        np.asarray(sp.rho)[inactive], np.asarray(rho)[inactive]
    )


# ---------------------------------------------------------------------------
# GossipEngine: protocol, equivalence, compile count, resume, staleness
# ---------------------------------------------------------------------------


def _all_edges_topo(n):
    edges = [[int(i), int(j)] for i, j in _directed_edges(bidirectional_ring_w(n))]
    return TopologySpec(
        kind="gossip",
        params={"base": "bidirectional_ring", "base_params": {"n": n}},
        clock={"kind": "trace", "trace": [edges]},
    )


def test_all_edges_gossip_reproduces_synchronous_bitwise():
    """Property (acceptance): a gossip trace with ALL edges active every
    window is bit-identical to the synchronous SimulatedEngine run — the
    synchronous runtime is the all-edges special case of the gossip one."""
    n = 4
    s_g = build_session(_gossip_spec(_all_edges_topo(n), n))
    s_s = build_session(
        ExperimentSpec(
            topology=TopologySpec(kind="bidirectional_ring", params={"n": n}),
            data=_gossip_data(n),
            inference=InferenceSpec(hidden=8, depth=1, lr=1e-2),
            run=RunSpec(n_rounds=3, seed=0),
        )
    )
    s_g.run()
    s_s.run()
    np.testing.assert_array_equal(
        np.asarray(s_g.posterior().mean), np.asarray(s_s.posterior().mean)
    )
    np.testing.assert_array_equal(
        np.asarray(s_g.posterior().rho), np.asarray(s_s.posterior().rho)
    )
    tel = s_g.evaluate()
    assert tel["staleness"]["max"] == 0  # every agent merged every window
    assert tel["merges"]["min"] == 3


def test_gossip_window_is_one_jitted_call():
    """Acceptance: a full event window executes as ONE jitted call — the
    per-window transition traces exactly once across the whole run (static
    window shapes; no per-event Python dispatch)."""
    n = 4
    topo = TopologySpec.gossip(
        "bidirectional_ring", {"n": n}, clock={"kind": "poisson", "rate": 0.7}
    )
    s = build_session(_gossip_spec(topo, n, n_rounds=5))
    s.run()
    assert s.engine.n_traces == 1
    assert int(s.state.round) == 5


def test_gossip_session_save_load_resume_bitwise(tmp_path):
    """Acceptance: Engine protocol end-to-end — build_session -> run ->
    save/load resumes bit-identically (the clock regenerates the identical
    event stream from the embedded spec + round index)."""
    n = 5
    topo = TopologySpec.gossip(
        "bidirectional_ring", {"n": n},
        clock={"kind": "poisson", "rate": 0.6, "seed": 11},
    )
    s = build_session(_gossip_spec(topo, n, n_rounds=6, seed=2))
    s.run(3)
    path = os.path.join(tmp_path, "gossip.ckpt")
    s.save(path)
    s2 = Session.load(path)
    assert s2.round_idx == 3
    assert s2.spec == s.spec
    s.run(3)
    s2.run(3)
    np.testing.assert_array_equal(
        np.asarray(s.posterior().mean), np.asarray(s2.posterior().mean)
    )
    np.testing.assert_array_equal(
        np.asarray(s.posterior().rho), np.asarray(s2.posterior().rho)
    )
    np.testing.assert_array_equal(
        np.asarray(s.state.last_merge), np.asarray(s2.state.last_merge)
    )
    np.testing.assert_array_equal(
        np.asarray(s.state.n_merges), np.asarray(s2.state.n_merges)
    )


def test_time_varying_star_as_gossip_trace_matches_table3_path():
    """Property (satellite): the paper's time-varying star schedule
    re-expressed as a gossip trace matches the existing table3 execution
    (SimulatedEngine cycling the slot W's)."""
    mats = time_varying_star_schedule(4, 2, a=0.5)
    n = 5
    # per-window w_eff reproduces each slot W exactly
    table, trace = trace_from_schedule(mats)
    tc = TraceClock(table, trace, rule="table")
    for k, m in enumerate(mats):
        np.testing.assert_allclose(tc.window(k).w_eff, m, atol=1e-12)

    data = _gossip_data(n, local_updates=1)
    inf = InferenceSpec(hidden=6, depth=1, lr=1e-2)
    s_g = build_session(ExperimentSpec(
        topology=TopologySpec.gossip_from_schedule(mats),
        data=data, inference=inf, run=RunSpec(n_rounds=4, seed=1),
    ))
    s_s = build_session(ExperimentSpec(
        topology=TopologySpec.time_varying_star(4, 2, a=0.5),
        data=data, inference=inf, run=RunSpec(n_rounds=4, seed=1),
    ))
    s_g.run()
    s_s.run()
    # identical up to the passthrough: the scheduled path round-trips idle
    # agents through softplus(softplus^-1(.)), the gossip path does not
    np.testing.assert_allclose(
        np.asarray(s_g.posterior().mean), np.asarray(s_s.posterior().mean),
        atol=1e-4, rtol=1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(s_g.posterior().rho), np.asarray(s_s.posterior().rho),
        atol=1e-4, rtol=1e-4,
    )


def test_staleness_telemetry_counts_unmerged_windows():
    """An agent whose edges never fire stays bit-frozen in consensus and its
    staleness equals the whole run length."""
    n = 4
    W = bidirectional_ring_w(n)
    # only the 0<->1 edges ever fire; agents 2 and 3 never merge
    trace = [[[0, 1], [1, 0]]]
    topo = TopologySpec(
        kind="gossip",
        params={"base": "bidirectional_ring", "base_params": {"n": n}},
        clock={"kind": "trace", "trace": trace},
    )
    spec = _gossip_spec(topo, n, n_rounds=4)
    with pytest.raises(ValueError, match="strongly connected"):
        spec.validate()  # such a trace violates Assumption 1 eagerly ...
    # ... so bypass the spec layer and drive the clock directly
    s = build_session(_gossip_spec(_all_edges_topo(n), n, n_rounds=4))
    clock = TraceClock(W, [[(0, 1), (1, 0)]])
    s.run(w_schedule=lambda r: clock.window(r).w_eff)
    age = s.engine.staleness(s.state)
    assert age[2] == 4 and age[3] == 4  # never merged: age == run length
    assert age[0] == 0 and age[1] == 0
    merges = np.asarray(s.state.n_merges)
    np.testing.assert_array_equal(merges, [4, 4, 0, 0])
    tel = s.evaluate()
    assert tel["staleness"]["max"] == 4 and tel["windows"] == 4


def test_wake_on_event_policy_freezes_sleeping_agents():
    """local_policy="active": agents with no incoming event skip their local
    steps too — posterior, optimizer state and step counter all pass through
    bitwise."""
    n = 4
    W = bidirectional_ring_w(n)
    topo = TopologySpec(
        kind="gossip",
        params={"base": "bidirectional_ring", "base_params": {"n": n}},
        clock={"kind": "poisson", "rate": 0.4, "seed": 3,
               "local_policy": "active"},
    )
    s = build_session(_gossip_spec(topo, n, n_rounds=1))
    post0 = s.posterior()
    clock = s.spec.topology.gossip_clock()
    win = clock.window(0)
    s.round()
    sleeping = ~win.active
    assert sleeping.any()
    np.testing.assert_array_equal(
        np.asarray(s.posterior().mean)[sleeping],
        np.asarray(post0.mean)[sleeping],
    )
    np.testing.assert_array_equal(
        np.asarray(s.state.step)[sleeping], np.zeros(int(sleeping.sum()))
    )
    awake = win.active
    assert np.all(np.asarray(s.state.step)[awake] == 2)  # u local steps ran
    assert float(
        np.abs(np.asarray(s.posterior().mean)[awake]
               - np.asarray(post0.mean)[awake]).max()
    ) > 0
    # phantom losses of sleeping agents are NaN-masked (review regression:
    # they must not pollute the loss telemetry); Session aggregates nanmean
    _, losses = s.engine.run_round(
        s.state, s.data.sampler(jax.random.key(5), 1),
        jnp.asarray(win.w_eff), jax.random.key(6),
    )
    assert np.isnan(np.asarray(losses)[sleeping]).all()
    assert np.isfinite(np.asarray(losses)[awake]).all()


# ---------------------------------------------------------------------------
# satellites: roofline monotonicity + ppermute flat routing
# ---------------------------------------------------------------------------


def test_gossip_window_roofline_monotone_vs_dense():
    """Satellite: window HBM bytes are monotone in the active-edge fraction
    and meet the dense ``consensus_roofline`` flat_fused bytes exactly at
    full participation."""
    n, p = 16, 1 << 14
    dense = consensus_roofline(n, p, n_leaves=8)["hbm_bytes"]["flat_fused"]
    prev = -1.0
    for k in range(n + 1):
        rec = gossip_window_roofline(n, p, n_participating=k)
        b = rec["hbm_bytes"]["window_masked"]
        assert b >= prev  # monotone in active fraction
        assert b <= dense
        prev = b
    full = gossip_window_roofline(n, p, n_participating=n)
    assert full["hbm_bytes"]["window_masked"] == dense
    assert full["hbm_passes"]["window_masked"] == 1.0
    # fewer merging agents than participants can only reduce traffic
    half = gossip_window_roofline(n, p, n_participating=n, n_merging=n // 2)
    assert half["hbm_bytes"]["window_masked"] < dense
    with pytest.raises(ValueError, match="n_merging"):
        gossip_window_roofline(n, p, n_participating=2, n_merging=3)


def test_ppermute_flat_routes_through_single_shard_map(monkeypatch):
    """Satellite (ROADMAP open item): make_train_round_step(consensus_impl=
    "ppermute") on a FLAT posterior routes through
    consensus_ppermute_ring_flat (one shard_map over the [A, P] buffers),
    not the leaf-wise pod ppermute."""
    import repro.launch.consensus_opt as co
    from repro.configs import get_config
    from repro.launch.steps import init_train_state, make_train_round_step
    from repro.optim import adam

    calls = {}

    def fake_ring_flat(posts, mesh, axis, self_weight=1.0 / 3.0,
                      wire_dtype=jnp.float32, W=None):
        calls["axis"] = axis
        calls["W"] = W
        calls["flat"] = hasattr(posts, "layout")
        return posts  # identity consensus: enough to prove the routing

    def fail_pod(*a, **k):  # the leaf-wise path must NOT run for flat states
        raise AssertionError("leaf-wise consensus_ppermute_pod was called")

    monkeypatch.setattr(co, "consensus_ppermute_ring_flat", fake_ring_flat)
    monkeypatch.setattr(co, "consensus_ppermute_pod", fail_pod)

    cfg = get_config("repro-100m").reduced()
    a = 2
    opt = adam()
    state = init_train_state(jax.random.key(0), cfg, a, opt)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    W = jnp.asarray(complete_w(a))
    step = make_train_round_step(
        cfg, W, opt=opt, remat=False, consensus_impl="ppermute",
        mesh=mesh, posterior_shardings=None,
    )
    from repro.data.pipeline import make_lm_batch_sampler

    batch = make_lm_batch_sampler(cfg.vocab_size, 2, 16, n_agents=a)(
        jax.random.key(1), 0
    )
    step(state, batch, jax.random.key(2))
    assert calls["flat"] and calls["axis"] == "pod"
    assert calls["W"] is W
