"""Event-driven gossip runtime (repro.gossip): clock determinism and
Assumption-1 validation, the masked active-edge consensus kernels
(bit-identical all-active equivalence + bit-stable passthrough), the
GossipEngine on the Engine protocol (one jitted call per window, resume,
staleness telemetry), the time_varying_star re-expression, the
delivery-latency runtime (DelayedClock + [K, N, P] history ring), the
sharded window consensus (consensus_ppermute_window equivalence ladder,
8-virtual-device subprocess), and the gossip-window roofline satellite."""
import dataclasses
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    RunSpec,
    Session,
    TopologySpec,
    build_session,
)
from repro.core.flat import (
    consensus_flat,
    consensus_flat_masked,
    consensus_flat_masked_sparse,
    neighbor_tables,
)
from repro.core.graphs import (
    bidirectional_ring_w,
    complete_w,
    time_varying_star_schedule,
)
from repro.core.numerics import softplus, softplus_inv
from repro.gossip.clocks import (
    DelayedClock,
    FailureInjectedClock,
    PoissonClock,
    RoundRobinClock,
    TraceClock,
    all_edges_trace,
    build_clock,
    trace_from_schedule,
    window_from_events,
    _directed_edges,
)
from repro.kernels.consensus import (
    consensus_fused_masked,
    consensus_fused_network,
)
from repro.launch.costmodel import consensus_roofline, gossip_window_roofline


def _rand_posts(n, p, seed=0):
    ks = jax.random.split(jax.random.key(seed), 2)
    mean = jax.random.normal(ks[0], (n, p))
    rho = jax.random.normal(ks[1], (n, p)) * 0.4 - 1.0
    return mean, rho


def _gossip_data(n_agents, local_updates=2):
    return DataSpec(
        dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
        partition="iid",
        partition_params=dict(n_agents=n_agents),
        batch_size=4,
        local_updates=local_updates,
    )


def _gossip_spec(topology, n_agents, n_rounds=3, seed=0, **inf_kw):
    return ExperimentSpec(
        topology=topology,
        data=_gossip_data(n_agents),
        inference=InferenceSpec(hidden=8, depth=1, lr=1e-2, **inf_kw),
        run=RunSpec(n_rounds=n_rounds, seed=seed),
    )


# ---------------------------------------------------------------------------
# clocks: determinism, windows, validation
# ---------------------------------------------------------------------------


def test_poisson_clock_deterministic_and_row_stochastic():
    W = bidirectional_ring_w(6)
    c = PoissonClock(W, rate=0.8, seed=3)
    for r in range(6):
        a, b = c.window(r), c.window(r)
        np.testing.assert_array_equal(a.edges, b.edges)
        np.testing.assert_array_equal(a.w_eff, b.w_eff)
        np.testing.assert_allclose(a.w_eff.sum(axis=1), 1.0, atol=1e-12)
        # inactive rows are EXACTLY e_i (the engine's mask contract)
        inactive = ~a.active
        np.testing.assert_array_equal(
            a.w_eff[inactive], np.eye(6)[inactive]
        )
        assert a.edges.shape == (c.e_max, 2)  # static shapes across windows


def test_round_robin_cycles_all_edges():
    W = bidirectional_ring_w(4)
    c = RoundRobinClock(W, edges_per_window=2)
    fired = set()
    for r in range(len(_directed_edges(W)) // 2):
        w = c.window(r)
        fired.update(map(tuple, w.edges[: w.n_events].tolist()))
    assert fired == set(_directed_edges(W))  # one cycle covers the graph


def test_failure_injection_drops_but_preserves_union():
    W = complete_w(5)
    inner = PoissonClock(W, rate=5.0, seed=1)
    c = FailureInjectedClock(inner, drop_rate=0.5, seed=2)
    dropped = sum(
        inner.window(r).n_events - c.window(r).n_events for r in range(8)
    )
    assert dropped > 0
    np.testing.assert_array_equal(c.union_support(), inner.union_support())
    c.validate()  # union still satisfies Assumption 1


def test_window_feasibility_and_event_checks():
    W = bidirectional_ring_w(4)
    with pytest.raises(ValueError, match="self-event"):
        window_from_events(W, [(1, 1)], e_max=4)
    with pytest.raises(ValueError, match="not an edge"):
        window_from_events(W, [(0, 2)], e_max=4)  # ring: 0-2 not adjacent
    # weight-table row over-commitment is rejected
    table = np.array([[1.0, 0.6, 0.6], [0.5, 1.0, 0.0], [0.5, 0.0, 1.0]])
    with pytest.raises(ValueError, match="row-feasible"):
        window_from_events(table, [(0, 1), (0, 2)], e_max=4, rule="table")


def test_trace_clock_conserve_requires_row_stochastic_base():
    """Review regression: a non-row-stochastic base under rule="conserve"
    would silently produce non-row-stochastic windows."""
    W_bad = bidirectional_ring_w(4) * 1.5
    with pytest.raises(ValueError, match="row-stochastic"):
        TraceClock(W_bad, [[(0, 1)]], rule="conserve")


def test_gossip_convenience_rejects_w_with_named_base():
    """Review regression: gossip(w=...) with a named base would silently
    drop the user's matrix."""
    with pytest.raises(ValueError, match="explicit"):
        TopologySpec.gossip("bidirectional_ring", {"n": 4},
                            w=bidirectional_ring_w(4))


def test_failure_drop_stream_independent_of_inner_stream():
    """Review regression: with equal (default) seeds the drop uniforms must
    NOT come from the same generator state as the inner firing draws."""
    W = complete_w(5)
    inner = PoissonClock(W, rate=5.0, seed=0)
    c = FailureInjectedClock(inner, drop_rate=0.5, seed=0)
    outer_stream = np.random.default_rng([0, 0])
    inner_stream = np.random.default_rng([0, 0])
    assert outer_stream.bit_generator.state == inner_stream.bit_generator.state
    # the clock still drops ~half the edges deterministically per (seed, r)
    kept = [c.window(r).n_events for r in range(6)]
    fired = [inner.window(r).n_events for r in range(6)]
    assert kept == [c.window(r).n_events for r in range(6)]
    assert sum(kept) < sum(fired)
    # drop decisions replayed from the salted stream match the clock output
    ev0 = inner.window(0)
    drops = np.random.default_rng([0, 0xFA11ED, 0]).random(ev0.n_events) < 0.5
    assert c.window(0).n_events == int((~drops).sum())


def test_gossip_topology_validates_union_connectivity():
    # two disconnected ring components: union can never be strongly connected
    blocks = np.zeros((6, 6))
    blocks[:3, :3] = bidirectional_ring_w(3)
    blocks[3:, 3:] = bidirectional_ring_w(3)
    topo = TopologySpec.gossip("explicit", w=blocks,
                               clock={"kind": "poisson", "rate": 1.0})
    with pytest.raises(ValueError, match="strongly connected"):
        _gossip_spec(topo, 6).validate()


def test_gossip_engine_field_cross_validation():
    topo = TopologySpec.gossip("bidirectional_ring", {"n": 4})
    spec = _gossip_spec(topo, 4)
    # gossip topology + launch engine is contradictory
    with pytest.raises(ValueError, match="GossipEngine"):
        dataclasses.replace(
            spec, run=dataclasses.replace(spec.run, engine="launch")
        ).validate()
    # engine="gossip" without a gossip topology is contradictory
    with pytest.raises(ValueError, match="kind='gossip'"):
        ExperimentSpec(
            topology=TopologySpec.complete(4),
            data=_gossip_data(4),
            run=RunSpec(engine="gossip"),
        ).validate()


def test_clock_doc_registry_roundtrip():
    W = bidirectional_ring_w(4)
    doc = {
        "kind": "failure_injected",
        "inner": {"kind": "poisson", "rate": 0.5, "seed": 7},
        "drop_rate": 0.25,
        "seed": 9,
    }
    c = build_clock(doc, W)
    assert isinstance(c, FailureInjectedClock)
    np.testing.assert_array_equal(
        c.window(2).edges, build_clock(doc, W).window(2).edges
    )
    with pytest.raises(ValueError, match="unknown clock kind"):
        build_clock({"kind": "quartz"}, W)


# ---------------------------------------------------------------------------
# masked consensus kernels: all-active bit-identity + bit-stable passthrough
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["xla", "interpret"])
def test_all_active_window_equals_network_kernel_bitwise(mode):
    """Acceptance: the all-edges-active window == consensus_fused_network /
    consensus_flat OUTPUT BIT-IDENTICALLY (assert_array_equal, no atol)."""
    from repro.core.flat import FlatPosterior, FlatLayout

    n, p = 5, 300
    mean, rho = _rand_posts(n, p)
    W = jnp.asarray(bidirectional_ring_w(n), jnp.float32)
    layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
    posts = FlatPosterior(mean=mean, rho=rho, layout=layout)
    allmask = jnp.ones((n,), bool)
    out = consensus_flat_masked(posts, W, allmask, mode=mode, block=128)
    ref = consensus_flat(posts, W, mode=mode, block=128)
    np.testing.assert_array_equal(np.asarray(out.mean), np.asarray(ref.mean))
    np.testing.assert_array_equal(np.asarray(out.rho), np.asarray(ref.rho))
    if mode == "interpret":
        mn, rn = consensus_fused_network(W, mean, rho, block=128, interpret=True)
        mm, rm = consensus_fused_masked(W, allmask, mean, rho, block=128,
                                        interpret=True)
        np.testing.assert_array_equal(np.asarray(mm), np.asarray(mn))
        np.testing.assert_array_equal(np.asarray(rm), np.asarray(rn))


@pytest.mark.parametrize("mode", ["xla", "interpret"])
def test_partial_window_passthrough_and_active_rows(mode):
    """Inactive agents pass through BITWISE (no softplus round trip); active
    rows match the dense reference on the window's W-tilde.  Dense-masked
    and CSR-masked paths agree."""
    from repro.core.flat import FlatPosterior, FlatLayout

    n, p = 6, 260
    mean, rho = _rand_posts(n, p, seed=4)
    layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
    posts = FlatPosterior(mean=mean, rho=rho, layout=layout)
    win = PoissonClock(bidirectional_ring_w(n), rate=0.4, seed=7).window(0)
    assert 0 < win.active.sum() < n  # genuinely partial
    W = jnp.asarray(win.w_eff, jnp.float32)
    act = jnp.asarray(win.active)

    out = consensus_flat_masked(posts, W, act, mode=mode, block=128)
    inactive = ~win.active
    np.testing.assert_array_equal(
        np.asarray(out.mean)[inactive], np.asarray(mean)[inactive]
    )
    np.testing.assert_array_equal(
        np.asarray(out.rho)[inactive], np.asarray(rho)[inactive]
    )
    ref = consensus_flat(posts, W, mode="xla", block=128)
    active = win.active
    np.testing.assert_allclose(
        np.asarray(out.mean)[active], np.asarray(ref.mean)[active],
        atol=1e-6, rtol=1e-5,
    )

    nbr, wts = neighbor_tables(win.w_eff)
    sp = consensus_flat_masked_sparse(
        posts, jnp.asarray(nbr), jnp.asarray(wts), act, mode=mode, block=128
    )
    np.testing.assert_allclose(
        np.asarray(sp.mean), np.asarray(out.mean), atol=1e-6, rtol=1e-5
    )
    np.testing.assert_array_equal(
        np.asarray(sp.mean)[inactive], np.asarray(mean)[inactive]
    )
    np.testing.assert_array_equal(
        np.asarray(sp.rho)[inactive], np.asarray(rho)[inactive]
    )


# ---------------------------------------------------------------------------
# GossipEngine: protocol, equivalence, compile count, resume, staleness
# ---------------------------------------------------------------------------


def _all_edges_topo(n):
    edges = [[int(i), int(j)] for i, j in _directed_edges(bidirectional_ring_w(n))]
    return TopologySpec(
        kind="gossip",
        params={"base": "bidirectional_ring", "base_params": {"n": n}},
        clock={"kind": "trace", "trace": [edges]},
    )


def test_all_edges_gossip_reproduces_synchronous_bitwise():
    """Property (acceptance): a gossip trace with ALL edges active every
    window is bit-identical to the synchronous SimulatedEngine run — the
    synchronous runtime is the all-edges special case of the gossip one."""
    n = 4
    s_g = build_session(_gossip_spec(_all_edges_topo(n), n))
    s_s = build_session(
        ExperimentSpec(
            topology=TopologySpec(kind="bidirectional_ring", params={"n": n}),
            data=_gossip_data(n),
            inference=InferenceSpec(hidden=8, depth=1, lr=1e-2),
            run=RunSpec(n_rounds=3, seed=0),
        )
    )
    s_g.run()
    s_s.run()
    np.testing.assert_array_equal(
        np.asarray(s_g.posterior().mean), np.asarray(s_s.posterior().mean)
    )
    np.testing.assert_array_equal(
        np.asarray(s_g.posterior().rho), np.asarray(s_s.posterior().rho)
    )
    tel = s_g.evaluate()["engine"]
    assert tel["staleness"]["max"] == 0  # every agent merged every window
    assert tel["merges"]["min"] == 3


def test_gossip_window_is_one_jitted_call():
    """Acceptance: a full event window executes as ONE jitted call — the
    per-window transition traces exactly once across the whole run (static
    window shapes; no per-event Python dispatch)."""
    n = 4
    topo = TopologySpec.gossip(
        "bidirectional_ring", {"n": n}, clock={"kind": "poisson", "rate": 0.7}
    )
    s = build_session(_gossip_spec(topo, n, n_rounds=5))
    s.run()
    assert s.engine.n_traces == 1
    assert int(s.state.round) == 5


def test_gossip_session_save_load_resume_bitwise(tmp_path):
    """Acceptance: Engine protocol end-to-end — build_session -> run ->
    save/load resumes bit-identically (the clock regenerates the identical
    event stream from the embedded spec + round index)."""
    n = 5
    topo = TopologySpec.gossip(
        "bidirectional_ring", {"n": n},
        clock={"kind": "poisson", "rate": 0.6, "seed": 11},
    )
    s = build_session(_gossip_spec(topo, n, n_rounds=6, seed=2))
    s.run(3)
    path = os.path.join(tmp_path, "gossip.ckpt")
    s.save(path)
    s2 = Session.load(path)
    assert s2.round_idx == 3
    assert s2.spec == s.spec
    s.run(3)
    s2.run(3)
    np.testing.assert_array_equal(
        np.asarray(s.posterior().mean), np.asarray(s2.posterior().mean)
    )
    np.testing.assert_array_equal(
        np.asarray(s.posterior().rho), np.asarray(s2.posterior().rho)
    )
    np.testing.assert_array_equal(
        np.asarray(s.state.last_merge), np.asarray(s2.state.last_merge)
    )
    np.testing.assert_array_equal(
        np.asarray(s.state.n_merges), np.asarray(s2.state.n_merges)
    )


def test_time_varying_star_as_gossip_trace_matches_table3_path():
    """Property (satellite): the paper's time-varying star schedule
    re-expressed as a gossip trace matches the existing table3 execution
    (SimulatedEngine cycling the slot W's)."""
    mats = time_varying_star_schedule(4, 2, a=0.5)
    n = 5
    # per-window w_eff reproduces each slot W exactly
    table, trace = trace_from_schedule(mats)
    tc = TraceClock(table, trace, rule="table")
    for k, m in enumerate(mats):
        np.testing.assert_allclose(tc.window(k).w_eff, m, atol=1e-12)

    data = _gossip_data(n, local_updates=1)
    inf = InferenceSpec(hidden=6, depth=1, lr=1e-2)
    s_g = build_session(ExperimentSpec(
        topology=TopologySpec.gossip_from_schedule(mats),
        data=data, inference=inf, run=RunSpec(n_rounds=4, seed=1),
    ))
    s_s = build_session(ExperimentSpec(
        topology=TopologySpec.time_varying_star(4, 2, a=0.5),
        data=data, inference=inf, run=RunSpec(n_rounds=4, seed=1),
    ))
    s_g.run()
    s_s.run()
    # identical up to the passthrough: the scheduled path round-trips idle
    # agents through softplus(softplus^-1(.)), the gossip path does not
    np.testing.assert_allclose(
        np.asarray(s_g.posterior().mean), np.asarray(s_s.posterior().mean),
        atol=1e-4, rtol=1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(s_g.posterior().rho), np.asarray(s_s.posterior().rho),
        atol=1e-4, rtol=1e-4,
    )


def test_staleness_telemetry_counts_unmerged_windows():
    """An agent whose edges never fire stays bit-frozen in consensus and its
    staleness equals the whole run length."""
    n = 4
    W = bidirectional_ring_w(n)
    # only the 0<->1 edges ever fire; agents 2 and 3 never merge
    trace = [[[0, 1], [1, 0]]]
    topo = TopologySpec(
        kind="gossip",
        params={"base": "bidirectional_ring", "base_params": {"n": n}},
        clock={"kind": "trace", "trace": trace},
    )
    spec = _gossip_spec(topo, n, n_rounds=4)
    with pytest.raises(ValueError, match="strongly connected"):
        spec.validate()  # such a trace violates Assumption 1 eagerly ...
    # ... so bypass the spec layer and drive the clock directly
    s = build_session(_gossip_spec(_all_edges_topo(n), n, n_rounds=4))
    clock = TraceClock(W, [[(0, 1), (1, 0)]])
    s.run(w_schedule=lambda r: clock.window(r).w_eff)
    age = s.engine.staleness(s.state)
    assert age[2] == 4 and age[3] == 4  # never merged: age == run length
    assert age[0] == 0 and age[1] == 0
    merges = np.asarray(s.state.n_merges)
    np.testing.assert_array_equal(merges, [4, 4, 0, 0])
    tel = s.evaluate()["engine"]
    assert tel["staleness"]["max"] == 4 and tel["windows"] == 4


def test_wake_on_event_policy_freezes_sleeping_agents():
    """local_policy="active": agents with no incoming event skip their local
    steps too — posterior, optimizer state and step counter all pass through
    bitwise."""
    n = 4
    W = bidirectional_ring_w(n)
    topo = TopologySpec(
        kind="gossip",
        params={"base": "bidirectional_ring", "base_params": {"n": n}},
        clock={"kind": "poisson", "rate": 0.4, "seed": 3,
               "local_policy": "active"},
    )
    s = build_session(_gossip_spec(topo, n, n_rounds=1))
    post0 = s.posterior()
    clock = s.spec.topology.gossip_clock()
    win = clock.window(0)
    s.round()
    sleeping = ~win.active
    assert sleeping.any()
    np.testing.assert_array_equal(
        np.asarray(s.posterior().mean)[sleeping],
        np.asarray(post0.mean)[sleeping],
    )
    np.testing.assert_array_equal(
        np.asarray(s.state.step)[sleeping], np.zeros(int(sleeping.sum()))
    )
    awake = win.active
    assert np.all(np.asarray(s.state.step)[awake] == 2)  # u local steps ran
    assert float(
        np.abs(np.asarray(s.posterior().mean)[awake]
               - np.asarray(post0.mean)[awake]).max()
    ) > 0
    # phantom losses of sleeping agents are NaN-masked (review regression:
    # they must not pollute the loss telemetry); Session aggregates nanmean
    _, losses = s.engine.run_round(
        s.state, s.data.sampler(jax.random.key(5), 1),
        jnp.asarray(win.w_eff), jax.random.key(6),
    )
    assert np.isnan(np.asarray(losses)[sleeping]).all()
    assert np.isfinite(np.asarray(losses)[awake]).all()


# ---------------------------------------------------------------------------
# satellites: roofline monotonicity + ppermute flat routing
# ---------------------------------------------------------------------------


def test_gossip_window_roofline_monotone_vs_dense():
    """Satellite: window HBM bytes are monotone in the active-edge fraction
    and meet the dense ``consensus_roofline`` flat_fused bytes exactly at
    full participation."""
    n, p = 16, 1 << 14
    dense = consensus_roofline(n, p, n_leaves=8)["hbm_bytes"]["flat_fused"]
    prev = -1.0
    for k in range(n + 1):
        rec = gossip_window_roofline(n, p, n_participating=k)
        b = rec["hbm_bytes"]["window_masked"]
        assert b >= prev  # monotone in active fraction
        assert b <= dense
        prev = b
    full = gossip_window_roofline(n, p, n_participating=n)
    assert full["hbm_bytes"]["window_masked"] == dense
    assert full["hbm_passes"]["window_masked"] == 1.0
    # fewer merging agents than participants can only reduce traffic
    half = gossip_window_roofline(n, p, n_participating=n, n_merging=n // 2)
    assert half["hbm_bytes"]["window_masked"] < dense
    with pytest.raises(ValueError, match="n_merging"):
        gossip_window_roofline(n, p, n_participating=2, n_merging=3)


def test_gossip_window_roofline_latency_and_interconnect_terms():
    """Satellite: the sharded/delayed extensions — ICI bytes are monotone in
    the fired-offset count, ppermute never exceeds the dense all-gather,
    and the history term appears exactly when delay_depth > 0 (its resident
    footprint scaling with the ring depth)."""
    n, p = 16, 1 << 14
    base = gossip_window_roofline(n, p, n_participating=8)
    assert "ici_bytes" not in base and "history" not in base["hbm_bytes"]

    s = 8
    allgather = gossip_window_roofline(
        n, p, n_participating=8, n_shards=s, n_cross_offsets=s - 1
    )["ici_bytes"]["dense_allgather"]
    prev = -1.0
    for k in range(s):
        rec = gossip_window_roofline(
            n, p, n_participating=8, n_shards=s, n_cross_offsets=k
        )
        ici = rec["ici_bytes"]["window_ppermute"]
        assert ici >= prev  # monotone in the fired-offset schedule
        assert ici <= allgather  # never worse than the dense layout
        # HBM terms are untouched by the interconnect extension
        assert rec["hbm_bytes"] == base["hbm_bytes"]
        prev = ici
    idle = gossip_window_roofline(
        n, p, n_participating=0, n_shards=s, n_cross_offsets=0
    )
    assert idle["ici_bytes"]["window_ppermute"] == 0.0  # idle windows: no wire

    d1 = gossip_window_roofline(
        n, p, n_participating=8, delay_depth=1, n_stale_events=4
    )
    d3 = gossip_window_roofline(
        n, p, n_participating=8, delay_depth=3, n_stale_events=4
    )
    assert d1["hbm_bytes"]["history"] == d3["hbm_bytes"]["history"] > 0
    assert d3["hist_resident_bytes"] == 2.0 * d1["hist_resident_bytes"]
    assert d1["hbm_bytes"]["window_masked"] == base["hbm_bytes"]["window_masked"]

    with pytest.raises(ValueError, match="n_cross_offsets"):
        gossip_window_roofline(n, p, n_participating=2, n_shards=4,
                               n_cross_offsets=4)
    with pytest.raises(ValueError, match=">= 0"):
        gossip_window_roofline(n, p, n_participating=2, delay_depth=-1)


# ---------------------------------------------------------------------------
# delivery latency: DelayedClock + history-ring engine
# ---------------------------------------------------------------------------


def _delayed_clock_doc(delay, inner=None):
    return {
        "kind": "delayed",
        "inner": inner or {"kind": "poisson", "rate": 0.8, "seed": 1},
        "latency": {"kind": "constant", "delay": delay},
    }


def test_delayed_clock_latency_zero_matches_inner_windows():
    """{"kind": "constant", "delay": 0} delivers every firing instantly:
    every window's (w_eff, active, event set) equals the inner clock's and
    all lags are 0."""
    W = bidirectional_ring_w(6)
    inner = PoissonClock(W, rate=0.8, seed=3)
    c0 = DelayedClock(inner, {"kind": "constant", "delay": 0})
    for r in range(6):
        a, b = c0.window(r), inner.window(r)
        np.testing.assert_array_equal(a.w_eff, b.w_eff)
        np.testing.assert_array_equal(a.active, b.active)
        assert a.max_lag == 0
        assert (
            set(map(tuple, a.edges[: a.n_events].tolist()))
            == set(map(tuple, b.edges[: b.n_events].tolist()))
        )


def test_delayed_clock_constant_k_shifts_delivery():
    """Constant latency k: window r delivers exactly the firings of window
    r - k (each at lag k); the first k windows deliver nothing."""
    W = bidirectional_ring_w(5)
    inner = PoissonClock(W, rate=0.9, seed=7)
    k = 2
    c = DelayedClock(inner, {"kind": "constant", "delay": k})
    assert c.max_delay == k
    for r in range(k):
        assert c.window(r).n_events == 0
    for r in range(k, 7):
        win, fired = c.window(r), inner.window(r - k)
        assert (
            set(map(tuple, win.edges[: win.n_events].tolist()))
            == set(map(tuple, fired.edges[: fired.n_events].tolist()))
        )
        assert (win.delays[: win.n_events] == k).all()


def test_delayed_clock_geometric_and_per_edge_models():
    W = bidirectional_ring_w(6)
    inner = PoissonClock(W, rate=1.2, seed=1)
    cg = build_clock(
        {"kind": "delayed", "inner": {"kind": "poisson", "rate": 1.2, "seed": 1},
         "latency": {"kind": "geometric", "p": 0.4, "max": 3}, "seed": 5},
        W,
    )
    lags = [cg.window(r).max_lag for r in range(12)]
    assert max(lags) <= 3  # truncation bound
    assert lags == [cg.window(r).max_lag for r in range(12)]  # deterministic
    cg.validate()  # union delegates to the inner clock

    mat = np.zeros((6, 6), int)
    mat[0, 1] = 2
    cp = DelayedClock(
        PoissonClock(W, rate=50.0, seed=2),  # all edges fire ~every window
        {"kind": "per_edge", "delays": mat.tolist()},
    )
    assert cp.max_delay == 2
    win = cp.window(4)
    ev = {tuple(e): int(d) for e, d in
          zip(win.edges[: win.n_events].tolist(), win.delays[: win.n_events])}
    assert ev[(0, 1)] == 2
    assert all(d == 0 for e, d in ev.items() if e != (0, 1))

    with pytest.raises(ValueError, match="latency"):
        DelayedClock(inner, {"kind": "tachyonic"})
    with pytest.raises(ValueError, match="shape"):
        DelayedClock(inner, {"kind": "per_edge", "delays": [[0]]})
    with pytest.raises(ValueError, match=">= 0"):
        DelayedClock(inner, {"kind": "constant", "delay": -1})


def _delayed_spec(clock, n=6, n_rounds=4, seed=0, **inf_kw):
    return _gossip_spec(
        TopologySpec.gossip("bidirectional_ring", {"n": n}, clock=clock),
        n, n_rounds=n_rounds, seed=seed, **inf_kw,
    )


def test_delayed_latency_zero_reproduces_engine_bitwise():
    """Acceptance: DelayedClock with latency 0 reproduces today's
    GossipEngine run BITWISE from the same seed (the k=0 reduction)."""
    inner = {"kind": "poisson", "rate": 0.8, "seed": 1}
    s_plain = build_session(_delayed_spec(inner))
    s_d0 = build_session(_delayed_spec(_delayed_clock_doc(0, inner)))
    s_plain.run()
    s_d0.run()
    assert s_d0.engine.hist_slots == 0  # no ring buffer at depth 0
    assert s_d0.state.hist_mean is None  # ... and no extra state leaves
    np.testing.assert_array_equal(
        np.asarray(s_plain.posterior().mean), np.asarray(s_d0.posterior().mean)
    )
    np.testing.assert_array_equal(
        np.asarray(s_plain.posterior().rho), np.asarray(s_d0.posterior().rho)
    )
    np.testing.assert_array_equal(
        np.asarray(s_plain.state.n_merges), np.asarray(s_d0.state.n_merges)
    )
    assert s_d0.engine.n_traces == 1


def test_delayed_engine_merges_posterior_as_of_fire_time():
    """The delivered merge uses the SRC posterior as of FIRE time, not as of
    delivery: with lr=0 (locals are no-ops) and constant latency 1, agent
    1's merge of the edge fired at window 1 must mix agent 0's INITIAL
    posterior, even though agent 0 itself merged at window 1."""
    n = 3
    W = complete_w(n)
    trace = [[[0, 2]], [[1, 0]], [[2, 1]]]  # union = 3-cycle: connected
    clock = {"kind": "delayed",
             "inner": {"kind": "trace", "trace": trace},
             "latency": {"kind": "constant", "delay": 1}}
    topo = TopologySpec.gossip("complete", {"n": n}, clock=clock)
    spec = ExperimentSpec(
        topology=topo,
        data=_gossip_data(n),
        # lr=0: local steps are bitwise no-ops, so posteriors change ONLY
        # through merges; distinct inits make the stale merge observable
        inference=InferenceSpec(hidden=8, depth=1, lr=0.0, shared_init=False),
        run=RunSpec(n_rounds=3, seed=0),
    )
    s = build_session(spec)
    post0 = s.posterior()
    mean0 = np.asarray(post0.mean)
    prec0 = np.asarray(1.0 / jnp.square(softplus(post0.rho)))
    s.run()  # w0: no delivery; w1: (0,2)@lag1; w2: (1,0)@lag1
    out = s.posterior()

    # conserve-rule weights of a single fired in-edge (dst, src) on W
    def merge(dst, src, mean_dst, prec_dst, mean_src, prec_src):
        w_self = 1.0 - W[dst, src]
        p = np.float32(w_self) * prec_dst + np.float32(W[dst, src]) * prec_src
        m = (np.float32(w_self) * prec_dst * mean_dst
             + np.float32(W[dst, src]) * prec_src * mean_src) / p
        return m, p

    # window 2: agent 1 merges agent 0 AS OF window 1 = initial (lr == 0,
    # history holds the PRE-merge post-local value)
    m1, p1 = merge(1, 0, mean0[1], prec0[1], mean0[0], prec0[0])
    np.testing.assert_allclose(
        np.asarray(out.mean)[1], m1, atol=1e-6, rtol=1e-6
    )
    rho1 = np.asarray(softplus_inv(jax.lax.rsqrt(jnp.asarray(p1))))
    np.testing.assert_allclose(
        np.asarray(out.rho)[1], rho1, atol=1e-6, rtol=1e-6
    )
    # counterfactual: merging agent 0 AS OF DELIVERY (its window-1-merged
    # value) gives a DIFFERENT posterior — the staleness is real
    m0w1, p0w1 = merge(0, 2, mean0[0], prec0[0], mean0[2], prec0[2])
    np.testing.assert_allclose(np.asarray(out.mean)[0], m0w1, atol=1e-6,
                               rtol=1e-6)
    m1_fresh, _ = merge(1, 0, mean0[1], prec0[1], m0w1, p0w1)
    assert float(np.abs(m1_fresh - m1).max()) > 1e-6


def test_delayed_session_save_load_resume_bitwise(tmp_path):
    """The history ring buffer rides in the checkpoint: a resumed delayed
    session continues bit-identically (stale merges included)."""
    clock = {"kind": "delayed",
             "inner": {"kind": "poisson", "rate": 0.9, "seed": 2},
             "latency": {"kind": "geometric", "p": 0.5, "max": 3}}
    s = build_session(_delayed_spec(clock, n_rounds=6, seed=2))
    assert s.engine.hist_slots == 4  # max_delay + 1 ring slots
    s.run(3)
    path = os.path.join(tmp_path, "delayed.ckpt")
    s.save(path)
    s2 = Session.load(path)
    s.run(3)
    s2.run(3)
    np.testing.assert_array_equal(
        np.asarray(s.posterior().mean), np.asarray(s2.posterior().mean)
    )
    np.testing.assert_array_equal(
        np.asarray(s.state.hist_mean), np.asarray(s2.state.hist_mean)
    )
    np.testing.assert_array_equal(
        np.asarray(s.state.last_merge), np.asarray(s2.state.last_merge)
    )
    assert s.engine.n_traces == s2.engine.n_traces == 1


def test_instant_gossip_state_keeps_pre_latency_leaf_structure():
    """Review regression (checkpoint back-compat): instant-delivery gossip
    states carry ``None`` history leaves — an EMPTY pytree subtree — so
    they flatten to exactly the pre-latency structure and gossip
    checkpoints saved before the latency feature keep loading."""
    s = build_session(_delayed_spec({"kind": "poisson", "rate": 0.8}))
    st = s.state
    assert st.hist_mean is None and st.hist_rho is None
    n_core = (
        len(jax.tree.leaves(st.posterior))
        + len(jax.tree.leaves(st.opt_state))
        + 4  # step, round, last_merge, n_merges
    )
    assert len(jax.tree.leaves(st)) == n_core  # no latency leaves
    # a delayed engine's state DOES carry the two ring leaves
    s_d = build_session(_delayed_spec(_delayed_clock_doc(1)))
    assert len(jax.tree.leaves(s_d.state)) == n_core + 2


def test_delayed_table_rule_lag_mixing_checked_eagerly():
    """Review regression: a lag-MIXING latency over a weight-table trace can
    co-deliver fire windows whose combined in-weights reach >= 1 — rejected
    at DelayedClock construction instead of crashing mid-run; constant
    latency (never mixes: each window is one shifted inner window) and
    feasible tables stay accepted."""
    table = np.array([
        [1.0, 0.6, 0.6],
        [0.5, 1.0, 0.0],
        [0.5, 0.0, 1.0],
    ])
    trace = [[(0, 1)], [(0, 2)], [(1, 0)], [(2, 0)]]  # each window feasible
    inner = TraceClock(table, trace, rule="table")
    lags = np.zeros((3, 3), int)
    lags[0, 1] = 1  # (0,1)@lag1 can land on (0,2)@lag0: 0.6 + 0.6 >= 1
    with pytest.raises(ValueError, match="co-deliver"):
        DelayedClock(inner, {"kind": "per_edge", "delays": lags.tolist()})
    with pytest.raises(ValueError, match="co-deliver"):
        DelayedClock(inner, {"kind": "geometric", "p": 0.5, "max": 2})
    # constant latency only shifts inner windows — accepted, and runs
    c = DelayedClock(inner, {"kind": "constant", "delay": 2})
    for r in range(6):
        c.window(r)
    # the hazard is PER ROW: the heavy row's own in-edges sharing one lag
    # can never co-deliver two fire windows, whatever the rest of the graph
    # carries — accepted, and every window stays feasible
    uniform_row = np.zeros((3, 3), int)
    uniform_row[0, 1] = uniform_row[0, 2] = 1  # row 0 uniform; others lag 0
    c_row = DelayedClock(inner, {"kind": "per_edge",
                                 "delays": uniform_row.tolist()})
    for r in range(8):
        c_row.window(r)
    # a feasible table (worst-case combined rows < 1) accepts mixing lags
    feasible = TraceClock(
        np.array([[1.0, 0.4, 0.4], [0.5, 1.0, 0.0], [0.5, 0.0, 1.0]]),
        trace, rule="table",
    )
    DelayedClock(feasible, {"kind": "geometric", "p": 0.5, "max": 2})


def test_delayed_clock_must_be_outermost_wrapper():
    """Review regression: burying a DelayedClock inside another wrapper
    would silently strip its lags (wrappers see only ``_events``) and run
    the instant engine on time-shifted events — rejected eagerly, both
    directly and via the doc registry."""
    W = bidirectional_ring_w(4)
    delayed = DelayedClock(
        PoissonClock(W, rate=1.0), {"kind": "constant", "delay": 2}
    )
    with pytest.raises(ValueError, match="OUTERMOST"):
        FailureInjectedClock(delayed, drop_rate=0.1)
    with pytest.raises(ValueError, match="OUTERMOST"):
        DelayedClock(delayed, {"kind": "constant", "delay": 1})
    with pytest.raises(ValueError, match="OUTERMOST"):
        build_clock(
            {"kind": "failure_injected", "drop_rate": 0.1,
             "inner": {"kind": "delayed",
                       "inner": {"kind": "poisson", "rate": 1.0},
                       "latency": {"kind": "constant", "delay": 2}}},
            W,
        )
    # the supported order (delays outermost) still composes
    ok = build_clock(
        {"kind": "delayed",
         "inner": {"kind": "failure_injected", "drop_rate": 0.1,
                   "inner": {"kind": "poisson", "rate": 1.0}},
         "latency": {"kind": "constant", "delay": 2}},
        W,
    )
    assert ok.max_delay == 2
    # delay 0 is not a delayed clock for composition purposes
    zero = DelayedClock(PoissonClock(W, rate=1.0),
                        {"kind": "constant", "delay": 0})
    FailureInjectedClock(zero, drop_rate=0.1)


def test_clock_window_memo_returns_identical_windows():
    """Review regression: window(r) is memoized one round deep (Session and
    engine both ask for the same window each round) and repeated calls stay
    deterministic across the memo boundary."""
    W = bidirectional_ring_w(5)
    c = DelayedClock(PoissonClock(W, rate=0.8, seed=3),
                     {"kind": "constant", "delay": 1})
    w_a = c.window(4)
    assert c.window(4) is w_a  # memo hit: no second construction
    w_b = c.window(5)  # memo moves on ...
    assert c.window(4) is not w_a  # ... old slot evicted
    np.testing.assert_array_equal(c.window(4).edges, w_a.edges)
    np.testing.assert_array_equal(c.window(4).w_eff, w_a.w_eff)
    np.testing.assert_array_equal(c.window(5).edges, w_b.edges)


def test_delayed_engine_rejects_w_override():
    """Delayed windows carry static event structure the W matrix alone
    cannot express — per-round W overrides are rejected loudly instead of
    silently merging the wrong stream."""
    s = build_session(_delayed_spec(_delayed_clock_doc(1)))
    with pytest.raises(ValueError, match="spec clock"):
        s.run(w_schedule=lambda r: complete_w(6))


# ---------------------------------------------------------------------------
# async edge cases: zero-event windows, drop-stream independence, table rule
# ---------------------------------------------------------------------------


def test_zero_event_window_is_bitwise_passthrough():
    """A zero-event window under local_policy="active" leaves posterior,
    optimizer state and step counters bit-untouched (trace count still 1),
    and Session.round reports the all-idle window honestly instead of
    writing NaN into the history (n_trained=0, loss=None)."""
    n = 4
    all_edges = [[int(i), int(j)]
                 for i, j in _directed_edges(bidirectional_ring_w(n))]
    topo = TopologySpec(
        kind="gossip",
        params={"base": "bidirectional_ring", "base_params": {"n": n}},
        # window 0 fires everything (union: connected), window 1 is EMPTY
        clock={"kind": "trace", "trace": [all_edges, []],
               "local_policy": "active"},
    )
    s = build_session(_gossip_spec(topo, n, n_rounds=2))
    rec0 = s.round()
    assert rec0["n_trained"] == n and np.isfinite(rec0["loss"])
    post1 = s.posterior()
    opt1 = s.state.opt_state
    step1 = np.asarray(s.state.step)
    rec1 = s.round()  # the all-idle window
    assert rec1["n_trained"] == 0
    assert rec1["loss"] is None  # NOT a silent NaN
    np.testing.assert_array_equal(
        np.asarray(s.posterior().mean), np.asarray(post1.mean)
    )
    np.testing.assert_array_equal(
        np.asarray(s.posterior().rho), np.asarray(post1.rho)
    )
    for a, b in zip(jax.tree.leaves(s.state.opt_state), jax.tree.leaves(opt1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(s.state.step), step1)
    assert int(s.state.round) == 2  # the window still counted
    assert s.engine.n_traces == 1  # no retrace for the empty window
    # history aggregation over the mixed run stays NaN-free
    losses = [r["loss"] for r in (rec0, rec1) if r["n_trained"]]
    assert np.isfinite(np.mean(losses))


def test_failure_drop_stream_independent_of_inner_firings():
    """Satellite regression for the 0xFA11ED salt: the drop decisions for
    window r are a pure function of (outer seed, r) — swapping the INNER
    clock (different seed and rate) leaves the kept/dropped prefix pattern
    unchanged."""
    W = complete_w(5)
    drop = 0.5

    def keep_mask(inner, r, n_events):
        rng = np.random.default_rng([0, 0xFA11ED, r])
        return rng.random(n_events) >= drop

    inner_a = PoissonClock(W, rate=5.0, seed=1)
    inner_b = PoissonClock(W, rate=2.0, seed=9)
    c_a = FailureInjectedClock(inner_a, drop_rate=drop, seed=0)
    c_b = FailureInjectedClock(inner_b, drop_rate=drop, seed=0)
    for r in range(6):
        ev_a, ev_b = inner_a.window(r), inner_b.window(r)
        mask_a = keep_mask(inner_a, r, ev_a.n_events)
        mask_b = keep_mask(inner_b, r, ev_b.n_events)
        # the salted stream is shared: same prefix regardless of the inner
        m = min(ev_a.n_events, ev_b.n_events)
        np.testing.assert_array_equal(mask_a[:m], mask_b[:m])
        # and each clock's output is exactly its inner events + that mask
        kept_a = [tuple(e) for e, k in
                  zip(ev_a.edges[: ev_a.n_events].tolist(), mask_a) if k]
        win_a = c_a.window(r)
        assert kept_a == [tuple(e) for e in
                          win_a.edges[: win_a.n_events].tolist()]
        kept_b = [tuple(e) for e, k in
                  zip(ev_b.edges[: ev_b.n_events].tolist(), mask_b) if k]
        win_b = c_b.window(r)
        assert kept_b == [tuple(e) for e in
                          win_b.edges[: win_b.n_events].tolist()]


def test_trace_clock_table_rule_row_infeasibility_errors_eagerly():
    """A weight-table trace whose fired in-weights sum to >= 1 on some row
    is rejected at TraceClock CONSTRUCTION (eager per-window feasibility),
    not midway through a run."""
    table = np.array([
        [1.0, 0.6, 0.6],
        [0.5, 1.0, 0.0],
        [0.5, 0.0, 1.0],
    ])
    # single fired in-edge per window: feasible
    TraceClock(table, [[(0, 1)], [(0, 2)]], rule="table")
    # both of row 0's in-edges in ONE window: 0.6 + 0.6 >= 1
    with pytest.raises(ValueError, match="row-feasible"):
        TraceClock(table, [[(0, 1), (0, 2)]], rule="table")
    # the report names the offending window row
    with pytest.raises(ValueError, match="window row 0"):
        TraceClock(table, [[(0, 1)], [(0, 1), (0, 2)]], rule="table")


# ---------------------------------------------------------------------------
# sharded window consensus: the equivalence ladder under 8 virtual devices
# ---------------------------------------------------------------------------

_SHARD_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
"""


def _run_sharded(body: str) -> None:
    from conftest import run_multidevice_subprocess

    run_multidevice_subprocess(_SHARD_PRELUDE + textwrap.dedent(body))


@pytest.mark.slow
@pytest.mark.multidevice
def test_ppermute_window_bitwise_all_clocks_and_topologies():
    """Acceptance: consensus_ppermute_window == consensus_flat_masked
    BIT-identically for EVERY window of poisson / round_robin / trace
    clocks on ring, torus and time-varying-star topologies, on an
    8-virtual-device host mesh (several shard counts per topology)."""
    _run_sharded("""
    from repro.core.flat import FlatLayout, FlatPosterior, consensus_flat_masked
    from repro.core.graphs import (bidirectional_ring_w, torus_w,
                                   time_varying_star_schedule)
    from repro.gossip.clocks import (PoissonClock, RoundRobinClock,
                                     TraceClock, all_edges_trace,
                                     trace_from_schedule)
    from repro.launch.consensus_opt import consensus_ppermute_window

    def clocks_for(W, row_stochastic):
        if row_stochastic:
            return [PoissonClock(W, rate=0.6, seed=1),
                    RoundRobinClock(W, edges_per_window=3),
                    all_edges_trace(W)]
        table, trace = W
        return [TraceClock(table, trace, rule="table")]

    ring = bidirectional_ring_w(8)
    torus = torus_w(2, 4)
    tvs = trace_from_schedule(time_varying_star_schedule(4, 2, a=0.5))
    cases = [("ring", ring, True, (2, 4, 8)),
             ("torus", torus, True, (2, 8)),
             ("time_varying_star", tvs, False, (5,))]  # 5 agents

    p = 200
    for name, W, rs, shard_counts in cases:
        n = (W if rs else W[0]).shape[0]
        ks = jax.random.split(jax.random.key(n), 2)
        layout = FlatLayout.for_pytree({"w": jnp.zeros((p,))})
        posts = FlatPosterior(
            mean=jax.random.normal(ks[0], (n, p)),
            rho=jax.random.normal(ks[1], (n, p)) * 0.4 - 1.0,
            layout=layout,
        )
        for clock in clocks_for(W, rs):
            for S in shard_counts:
                mesh = jax.sharding.Mesh(
                    np.asarray(jax.devices()[:S]), ("agents",))
                for r in range(4):
                    win = clock.window(r)
                    ref = consensus_flat_masked(
                        posts, jnp.asarray(win.w_eff, jnp.float32),
                        jnp.asarray(win.active), mode="xla")
                    out = consensus_ppermute_window(posts, win, mesh, "agents")
                    assert bool(jnp.all(out.mean == ref.mean)), (name, S, r)
                    assert bool(jnp.all(out.rho == ref.rho)), (name, S, r)
        print(name, "ok")
    print("OK")
    """)


@pytest.mark.slow
@pytest.mark.multidevice
def test_gossip_engine_ppermute_impl_bitwise_vs_masked():
    """Acceptance (engine level): a gossip session on
    InferenceSpec(consensus_impl="ppermute") over the 8-device agent mesh
    produces the BIT-identical posterior trajectory to the default dense
    masked execution — instant gossip and sharded gossip are the same
    point on the equivalence ladder."""
    _run_sharded("""
    import dataclasses
    from repro.api import (DataSpec, ExperimentSpec, InferenceSpec, RunSpec,
                           TopologySpec, build_session)

    n = 8
    def spec(impl):
        return ExperimentSpec(
            topology=TopologySpec.gossip(
                "bidirectional_ring", {"n": n},
                clock={"kind": "poisson", "rate": 0.7, "seed": 3}),
            data=DataSpec(
                dataset_params=dict(n_classes=3, dim=8, n_train_per_class=30),
                partition="iid", partition_params=dict(n_agents=n),
                batch_size=4, local_updates=2),
            inference=InferenceSpec(hidden=8, depth=1, lr=1e-2,
                                    consensus_impl=impl),
            run=RunSpec(n_rounds=3, seed=0),
        )

    s_m = build_session(spec("masked"))
    s_p = build_session(spec("ppermute"))
    s_m.run(); s_p.run()
    assert s_p.engine.n_shards == 8
    assert s_p.engine.n_traces == 1  # local phase still traces once
    np.testing.assert_array_equal(np.asarray(s_m.posterior().mean),
                                  np.asarray(s_p.posterior().mean))
    np.testing.assert_array_equal(np.asarray(s_m.posterior().rho),
                                  np.asarray(s_p.posterior().rho))
    assert s_p.evaluate()["engine"]["consensus_shards"] == 8
    print("OK")
    """)


def test_consensus_impl_spec_validation():
    """consensus_impl is a gossip-window execution choice: eager errors for
    non-gossip topologies and for non-gaussian ppermute."""
    topo = TopologySpec.gossip("bidirectional_ring", {"n": 4})
    _gossip_spec(topo, 4, consensus_impl="ppermute").validate()
    with pytest.raises(ValueError, match="gossip"):
        ExperimentSpec(
            topology=TopologySpec.complete(4),
            data=_gossip_data(4),
            inference=InferenceSpec(consensus_impl="ppermute"),
        ).validate()
    with pytest.raises(ValueError, match="ppermute"):
        _gossip_spec(
            topo, 4, consensus_impl="ppermute", consensus="mean_only"
        ).validate()
    with pytest.raises(ValueError, match="unknown consensus_impl"):
        InferenceSpec(consensus_impl="carrier_pigeon").validate()
    # consensus_shards without the ppermute impl would be silently ignored
    with pytest.raises(ValueError, match="consensus_shards"):
        InferenceSpec(consensus_shards=4).validate()
    InferenceSpec(consensus_impl="ppermute", consensus_shards=4).validate()
    # a delayed clock cannot take the instant-delivery sharded path
    delayed = TopologySpec.gossip(
        "bidirectional_ring", {"n": 4},
        clock={"kind": "delayed", "inner": {"kind": "poisson", "rate": 1.0},
               "latency": {"kind": "constant", "delay": 1}},
    )
    with pytest.raises(ValueError, match="instant delivery"):
        build_session(_gossip_spec(delayed, 4, consensus_impl="ppermute"))


def test_window_shard_offsets_schedule():
    """The static permutation schedule: only offsets crossed by fired edges
    appear; intra-shard edges contribute nothing; an idle window's schedule
    is empty."""
    from repro.launch.consensus_opt import window_shard_offsets

    W = bidirectional_ring_w(8)
    # ring edges cross adjacent shards only: offsets {1, S-1}
    win = all_edges_trace(W).window(0)
    assert window_shard_offsets(win, 4) == (1, 3)
    assert window_shard_offsets(win, 8) == (1, 7)
    assert window_shard_offsets(win, 1) == ()  # one shard: all local
    # single intra-shard edge (agents 0 and 1 share shard 0 at S=4)
    single = window_from_events(W, [(0, 1)], e_max=2)
    assert window_shard_offsets(single, 4) == ()
    empty = window_from_events(W, [], e_max=2)
    assert window_shard_offsets(empty, 4) == ()


def test_ppermute_flat_routes_through_single_shard_map(monkeypatch):
    """Satellite (ROADMAP open item): make_train_round_step(consensus_impl=
    "ppermute") on a FLAT posterior routes through
    consensus_ppermute_ring_flat (one shard_map over the [A, P] buffers),
    not the leaf-wise pod ppermute."""
    import repro.launch.consensus_opt as co
    from repro.configs import get_config
    from repro.launch.steps import init_train_state, make_train_round_step
    from repro.optim import adam

    calls = {}

    def fake_ring_flat(posts, mesh, axis, self_weight=1.0 / 3.0,
                      wire_dtype=jnp.float32, W=None):
        calls["axis"] = axis
        calls["W"] = W
        calls["flat"] = hasattr(posts, "layout")
        return posts  # identity consensus: enough to prove the routing

    def fail_pod(*a, **k):  # the leaf-wise path must NOT run for flat states
        raise AssertionError("leaf-wise consensus_ppermute_pod was called")

    monkeypatch.setattr(co, "consensus_ppermute_ring_flat", fake_ring_flat)
    monkeypatch.setattr(co, "consensus_ppermute_pod", fail_pod)

    cfg = get_config("repro-100m").reduced()
    a = 2
    opt = adam()
    state = init_train_state(jax.random.key(0), cfg, a, opt)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    W = jnp.asarray(complete_w(a))
    step = make_train_round_step(
        cfg, W, opt=opt, remat=False, consensus_impl="ppermute",
        mesh=mesh, posterior_shardings=None,
    )
    from repro.data.pipeline import make_lm_batch_sampler

    batch = make_lm_batch_sampler(cfg.vocab_size, 2, 16, n_agents=a)(
        jax.random.key(1), 0
    )
    step(state, batch, jax.random.key(2))
    assert calls["flat"] and calls["axis"] == "pod"
    assert calls["W"] is W


# ---------------------------------------------------------------------------
# activity-mask exactness, f64 schedule identity, window contracts
# ---------------------------------------------------------------------------


def test_active_mask_survives_subresolution_weight():
    """Headline mask regression: a fired in-edge with weight 1e-8 is below
    f32 resolution at the diagonal (1 - 1e-8 rounds back to exactly 1.0 in
    float32), so deriving activity as diag(W_f32) < 1 silently drops the
    merge — and under local_policy="active" the agent does not even train.
    The engine must thread the clock's host-exact mask instead."""
    eps = 1e-8
    W = np.array([[1.0 - eps, eps], [0.4, 0.6]])
    # the bug's exact mechanism, pinned: the f32 diagonal is indistinguishable
    # from an idle row, only the host-side f64 mask can see the fired edge
    assert np.float32(W[0, 0]) == np.float32(1.0)
    spec = _gossip_spec(
        TopologySpec.gossip(
            "explicit", w=W,
            clock={"kind": "trace", "trace": [[[0, 1]], [[1, 0]]],
                   "local_policy": "active"},
        ),
        n_agents=2, n_rounds=1,
    )
    s = build_session(spec)
    rec = s.round()
    # agent 0 (the sub-resolution merge target) trained AND merged; agent 1
    # (no incoming event) slept
    assert rec["n_trained"] == 1
    np.testing.assert_array_equal(np.asarray(s.state.n_merges), [1, 0])
    np.testing.assert_array_equal(np.asarray(s.state.last_merge), [0, -1])
    u = spec.data.local_updates
    np.testing.assert_array_equal(np.asarray(s.state.step), [u, 0])


def test_window_for_rejects_f32_colliding_schedule():
    """_window_for must compare the Session's W against the clock stream in
    float64: a foreign schedule differing by less than one f32 ulp collides
    with the stream at float32 and was previously false-accepted — then
    silently merged with the STREAM's event structure instead of the
    caller's matrix."""
    s = build_session(_delayed_spec(_delayed_clock_doc(1)))
    w0 = np.asarray(s.spec.topology.w_schedule()(0), np.float64)
    w2 = w0.copy()
    w2[0, 0] -= 1e-9  # ~2^-30: far below the f32 ulp at 1.0 (2^-24)
    assert not np.array_equal(w2, w0)
    # the collision this test exists for: bitwise equal after the f32 cast
    assert np.array_equal(w2.astype(np.float32), w0.astype(np.float32))
    batches = s.data.sampler(jax.random.key(1), 0)
    with pytest.raises(ValueError, match="spec clock"):
        s.engine.run_round(s.state, batches, w2, jax.random.key(2))


def test_window_from_events_duplicate_collapse_first_wins():
    """Duplicate (dst, src) events within a window collapse to ONE merge,
    and the FIRST occurrence wins — including its delivery delay."""
    W = bidirectional_ring_w(4)
    win = window_from_events(
        W, [(0, 1), (0, 3), (0, 1)], e_max=4, delays=[2, 0, 5]
    )
    assert win.n_events == 2
    assert win.edges[:2].tolist() == [[0, 1], [0, 3]]
    # the duplicate's lag-5 redelivery is dropped with it
    assert win.delays[:2].tolist() == [2, 0]
    # the collapsed edge carries the base weight ONCE
    assert win.w_eff[0, 1] == W[0, 1]
    np.testing.assert_allclose(win.w_eff.sum(axis=1), 1.0, atol=1e-12)
    # pad slots beyond the collapsed count stay zero
    assert (win.weights[2:] == 0.0).all()


def test_thinned_poisson_e_max_boundary():
    """fired == e_max fits the static window shape; e_max + 1 must raise
    (never silently truncate the realization)."""
    from repro.gossip.clocks import thinned_poisson_indices

    class _StubRng:
        """Deterministic stand-in: k distinct uniform picks."""

        def __init__(self, k):
            self._k = k

        def poisson(self, mu):
            return self._k

        def integers(self, lo, hi, size):
            return np.arange(size, dtype=np.int64) % (hi - lo)

    fired = thinned_poisson_indices(_StubRng(5), 100, 0.05, e_max=5)
    assert fired.size == 5  # exactly at the cap: passes
    with pytest.raises(ValueError, match="e_max=5"):
        thinned_poisson_indices(_StubRng(6), 100, 0.05, e_max=5)
