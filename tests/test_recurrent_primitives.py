"""Recurrent primitives vs. step-by-step sequential references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.rglru import CONV_WIDTH, causal_conv1d, rglru_scan
from repro.models.xlstm import mlstm_scan, slstm_scan, slstm_init, slstm_state_init


def _mlstm_seq_ref(q, k, v, ig, fg):
    b, s, h, hd = q.shape
    C = np.zeros((b, h, hd, hd))
    n = np.zeros((b, h, hd))
    m = np.full((b, h), -1e30)
    out = np.zeros((b, s, h, hd))
    q, k, v, ig, fg = map(np.asarray, (q, k, v, ig, fg))
    for t in range(s):
        logf = np.log(1 / (1 + np.exp(-fg[:, t])))
        m_new = np.maximum(logf + m, ig[:, t])
        i_s = np.exp(ig[:, t] - m_new)
        f_s = np.exp(logf + m - m_new)
        C = f_s[..., None, None] * C + i_s[..., None, None] * np.einsum(
            "bhd,bhe->bhde", k[:, t], v[:, t]
        )
        n = f_s[..., None] * n + i_s[..., None] * k[:, t]
        qn = np.einsum("bhd,bhd->bh", q[:, t], n)
        den = np.maximum(np.abs(qn), np.exp(-m_new))
        out[:, t] = np.einsum("bhd,bhde->bhe", q[:, t], C) / den[..., None]
        m = m_new
    return out, C, n, m


@pytest.mark.parametrize("chunk", [1, 4, 7, 8, 24])
def test_mlstm_chunkwise_vs_sequential(chunk):
    key = jax.random.key(0)
    b, s, h, hd = 2, 24, 2, 8
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd)) / np.sqrt(hd)
    v = jax.random.normal(ks[2], (b, s, h, hd))
    ig = jax.random.normal(ks[3], (b, s, h)) * 2
    fg = jax.random.normal(ks[4], (b, s, h)) * 2 + 1
    ref_out, refC, refn, refm = _mlstm_seq_ref(q, k, v, ig, fg)
    state = {
        "C": jnp.zeros((b, h, hd, hd)),
        "n": jnp.zeros((b, h, hd)),
        "m": jnp.full((b, h), -1e30),
    }
    out, st = mlstm_scan(q, k, v, ig, fg, state, chunk_size=chunk)
    np.testing.assert_allclose(out, ref_out, atol=1e-4)
    np.testing.assert_allclose(st["C"], refC, atol=1e-5)
    np.testing.assert_allclose(st["m"], refm, atol=1e-5)


def test_mlstm_state_continuation():
    """Split-sequence evaluation (decode semantics) == one-shot."""
    key = jax.random.key(1)
    b, s, h, hd = 1, 20, 2, 4
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    ig = jax.random.normal(ks[3], (b, s, h))
    fg = jax.random.normal(ks[4], (b, s, h)) + 1
    state = {
        "C": jnp.zeros((b, h, hd, hd)),
        "n": jnp.zeros((b, h, hd)),
        "m": jnp.full((b, h), -1e30),
    }
    full, _ = mlstm_scan(q, k, v, ig, fg, state, chunk_size=5)
    o1, st = mlstm_scan(q[:, :8], k[:, :8], v[:, :8], ig[:, :8], fg[:, :8], state, 4)
    o2, _ = mlstm_scan(q[:, 8:], k[:, 8:], v[:, 8:], ig[:, 8:], fg[:, 8:], st, 4)
    np.testing.assert_allclose(jnp.concatenate([o1, o2], 1), full, atol=1e-5)


def test_rglru_scan_vs_sequential():
    key = jax.random.key(2)
    b, s, d = 2, 17, 8
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, d))
    r = jax.nn.sigmoid(jax.random.normal(ks[1], (b, s, d)))
    i = jax.nn.sigmoid(jax.random.normal(ks[2], (b, s, d)))
    lam = jax.random.normal(ks[3], (d,))
    h0 = jnp.full((b, d), 0.3)
    hs, hl = rglru_scan(x, r, i, lam, h0)
    a = np.exp(-8 * np.log1p(np.exp(np.asarray(lam)))[None, None] * np.asarray(r))
    g = np.sqrt(1 - a**2) * (np.asarray(i) * np.asarray(x))
    h = np.full((b, d), 0.3)
    ref = np.zeros((b, s, d))
    for t in range(s):
        h = a[:, t] * h + g[:, t]
        ref[:, t] = h
    np.testing.assert_allclose(hs, ref, atol=1e-5)
    np.testing.assert_allclose(hl, ref[:, -1], atol=1e-5)


def test_causal_conv_continuation():
    key = jax.random.key(3)
    b, s, d = 2, 12, 6
    x = jax.random.normal(key, (b, s, d))
    w = jax.random.normal(jax.random.key(4), (CONV_WIDTH, d))
    bb = jnp.zeros((d,))
    full, _ = causal_conv1d(x, w, bb)
    o1, hist = causal_conv1d(x[:, :7], w, bb)
    o2, _ = causal_conv1d(x[:, 7:], w, bb, hist)
    np.testing.assert_allclose(jnp.concatenate([o1, o2], 1), full, atol=1e-6)


def test_slstm_scan_stability_and_continuation():
    class Cfg:
        d_model = 8
        n_heads = 2
        norm_eps = 1e-6

    cfg = Cfg()
    params = slstm_init(jax.random.key(0), cfg)
    b, s, d = 2, 14, 8
    ks = jax.random.split(jax.random.key(1), 4)
    xz, xi, xf, xo = (jax.random.normal(k, (b, s, d)) for k in ks)
    st0 = slstm_state_init(cfg, b)
    full, _ = slstm_scan(params, xz, xi, xf, xo, st0, cfg.n_heads)
    assert not np.any(np.isnan(np.asarray(full)))
    o1, st = slstm_scan(
        params, xz[:, :6], xi[:, :6], xf[:, :6], xo[:, :6], st0, cfg.n_heads
    )
    o2, _ = slstm_scan(
        params, xz[:, 6:], xi[:, 6:], xf[:, 6:], xo[:, 6:], st, cfg.n_heads
    )
    np.testing.assert_allclose(jnp.concatenate([o1, o2], 1), full, atol=1e-5)
