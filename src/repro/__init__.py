"""repro: production-grade JAX framework implementing
"Decentralized Bayesian Learning over Graphs" (Lalitha et al., 2019).

Layers:
  core/       the paper's contribution: posteriors, consensus, graphs, theory
  vi/         Bayes-by-Backprop variational inference
  models/     architecture zoo (dense / MoE / SSM / hybrid / enc-dec / VLM)
  optim/      optimizers + schedules
  data/       synthetic datasets + non-IID partitioners + pipeline
  checkpoint/ msgpack pytree checkpointing
  kernels/    Pallas TPU kernels (consensus, gauss_vi, flash_attention)
  launch/     production mesh, multi-pod dry-run, train/serve drivers
  configs/    assigned architecture configs
"""

__version__ = "1.0.0"
