from repro.optim.optimizers import (
    Optimizer,
    OptState,
    adam,
    sgd,
    apply_updates,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.schedules import (
    constant_schedule,
    exponential_decay,
    cosine_schedule,
    warmup_cosine,
)

__all__ = [
    "Optimizer",
    "OptState",
    "adam",
    "sgd",
    "apply_updates",
    "clip_by_global_norm",
    "global_norm",
    "constant_schedule",
    "exponential_decay",
    "cosine_schedule",
    "warmup_cosine",
]
