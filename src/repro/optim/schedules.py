"""Learning-rate schedules.  The paper uses Adam with initial lr 1e-3 and a
multiplicative decay of 0.99 per communication round (supplementary
Tables 1-3) — that is ``exponential_decay(1e-3, 0.99)``."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def exponential_decay(lr: float, decay: float) -> Schedule:
    """lr * decay^step (step = communication round in the paper)."""
    return lambda step: jnp.asarray(lr, jnp.float32) * decay ** step.astype(jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.0) -> Schedule:
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return lr * (final_frac + (1.0 - final_frac) * cos)

    return fn


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int) -> Schedule:
    cosine = cosine_schedule(lr, max(total_steps - warmup_steps, 1))

    def fn(step):
        step_f = step.astype(jnp.float32)
        warm = lr * step_f / max(warmup_steps, 1)
        return jnp.where(step_f < warmup_steps, warm, cosine(step - warmup_steps))

    return fn
