"""Minimal optax-style optimizers built from scratch (optax is not available
offline).  An ``Optimizer`` is an (init, update) pair over pytrees; ``update``
takes (grads, state, step, lr) and returns (updates, new_state) so learning-
rate schedules stay outside the state (important for the paper's per-round
lr decay, supplementary Tables 1-3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, jax.Array, jax.Array], tuple[PyTree, PyTree]]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamState:
    mu: PyTree
    nu: PyTree


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SgdState:
    momentum: PyTree


OptState = Any


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    """Adam (Kingma & Ba, 2015) — the paper's optimizer for all NN runs."""

    def init(params: PyTree) -> AdamState:
        zeros = jax.tree.map(jnp.zeros_like, params)
        return AdamState(mu=zeros, nu=jax.tree.map(jnp.zeros_like, params))

    def update(grads, state: AdamState, step, lr):
        step = step + 1  # 1-indexed for bias correction
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads)
        bc1 = 1.0 - b1**step.astype(jnp.float32)
        bc2 = 1.0 - b2**step.astype(jnp.float32)
        updates = jax.tree.map(
            lambda m, v: -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps), mu, nu
        )
        return updates, AdamState(mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def sgd(momentum: float = 0.0) -> Optimizer:
    def init(params: PyTree) -> SgdState:
        return SgdState(momentum=jax.tree.map(jnp.zeros_like, params))

    def update(grads, state: SgdState, step, lr):
        del step
        mom = jax.tree.map(lambda m, g: momentum * m + g, state.momentum, grads)
        updates = jax.tree.map(lambda m: -lr * m, mom)
        return updates, SgdState(momentum=mom)

    return Optimizer(init=init, update=update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads)
