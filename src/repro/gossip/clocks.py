"""Activation clocks: continuous-time gossip discretized into event windows.

The asynchronous model (paper Sec 1/2; BayGo, Lalitha et al. 2019) lets each
directed edge (i <- j) of the communication graph fire on its own clock.  A
naive simulation dispatches Python per event — unjittable and orders of
magnitude too slow.  Instead a clock discretizes time into **event
windows**: all edge activations inside one window are applied as one masked
consensus over the flat [N, P] posterior, so every window is the SAME jitted
program (static shapes) and the runtime does zero per-event dispatch.

An ``EventWindow`` carries

* ``edges [E_max, 2]`` int32 — the window's directed activation events
  ``(dst, src)`` (dst merges src's posterior), zero-padded to the clock's
  static ``e_max``;
* ``weights [E_max]`` — the base mixing weight of each event edge (0.0 on
  pad slots);
* ``active [N]`` bool — agents with at least one incoming event (only these
  merge; everyone else passes through the window untouched);
* ``w_eff [N, N]`` — the window's effective row-stochastic W-tilde (see
  below), the matrix handed to ``Session``/``Engine.run_round``;
* ``delays [E_max]`` int32 — per-event delivery lag in windows (0 = the
  classic instant-delivery model).  A lag-k event delivers the SRC POSTERIOR
  AS OF FIRE TIME: the engine merges src's post-local-step (pre-merge)
  posterior of window ``index - k``, read from a bounded [K, N, P] history
  ring buffer (``repro.gossip.engine``).  Only ``DelayedClock`` emits
  nonzero lags.

W-tilde construction, two rules:

* ``"conserve"`` (default; requires a row-stochastic base W): an active
  row keeps the base weight on each fired in-edge and moves every
  non-fired in-edge's weight onto SELF —
  ``w_eff[i,i] = W[i,i] + sum_{j not fired} W[i,j]``.  With ALL edges
  fired, ``w_eff == W`` exactly (bitwise), which is what makes the
  all-active gossip window reproduce the synchronous fused consensus
  bit-identically.
* ``"table"`` (for weight-table traces, e.g. a re-expressed
  ``time_varying_star_schedule`` whose base rows need not sum to 1):
  ``w_eff[i,i] = 1 - sum_{j fired} W[i,j]``.

Rows with no event are EXACTLY ``e_i`` (diag 1.0) either way.  The
window's host-computed ``active`` mask is the AUTHORITATIVE activity
signal: the engine threads it into the jitted window as an explicit
argument (re-deriving it from the float32-cast diagonal would silently
drop any fired in-edge whose weight is below f32 resolution — ``1.0 - w``
rounds back to exactly 1.0 for w < 2^-24) and the masked consensus kernel
passes inactive rows through without touching them.

Population scale (``SparseWindow`` / ``SparseClock``): above
``SPARSE_DENSE_GUARD`` agents no ``[N, N]`` matrix may exist, so the
edge-native clock family samples fired edges directly from a CSR
``SparseGraph``'s non-self edge list and emits ``SparseWindow``s — fired
``[E_w]`` dst/src/weight arrays plus the per-agent conserve-rule
self-weight vector and the explicit ``active`` mask, built in O(fired + N)
host work per window.  The dense ``w_eff`` survives only as a derived view
below the guard (the equivalence ladder against the dense masked engine).

Determinism contract: ``window(r)`` is a pure function of ``(seed, r)``
(fresh ``np.random.default_rng([seed, r])`` per window), so a resumed
session regenerates the identical event stream from any round index.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import graphs


@dataclasses.dataclass(frozen=True)
class EventWindow:
    """One jit-ready event window (see module docstring)."""

    index: int
    edges: np.ndarray  # [E_max, 2] int32 (dst, src), zero-padded
    weights: np.ndarray  # [E_max] float32, 0.0 on pad slots
    active: np.ndarray  # [N] bool
    w_eff: np.ndarray  # [N, N] float64 row-stochastic
    n_events: int  # real events before padding
    delays: np.ndarray = None  # [E_max] int32 delivery lag, 0 on pad slots

    def __post_init__(self):
        if self.delays is None:
            object.__setattr__(
                self, "delays", np.zeros((self.edges.shape[0],), np.int32)
            )

    @property
    def n_agents(self) -> int:
        return self.w_eff.shape[0]

    @property
    def active_fraction(self) -> float:
        return float(self.active.mean())

    @property
    def max_lag(self) -> int:
        """Largest delivery lag carried by a real (non-pad) event."""
        if not self.n_events:
            return 0
        return int(self.delays[: self.n_events].max())

    def participating(self) -> np.ndarray:
        """[N] bool: agents touched by any event (as dst or src) — the rows a
        traffic-optimal window kernel must read (see
        ``launch.costmodel.gossip_window_roofline``)."""
        part = self.active.copy()
        if self.n_events:
            part[self.edges[: self.n_events, 1]] = True
        return part


@dataclasses.dataclass(frozen=True)
class SparseWindow:
    """One edge-native event window: no ``[N, N]`` anywhere.

    The population-scale counterpart of ``EventWindow``: the window is the
    fired edge LIST itself — ``[E_max]`` dst/src/weight arrays (zero-padded
    to the clock's static capacity so every window shares one jit trace) —
    plus the per-agent ``"conserve"``-rule self-weight vector and the
    EXPLICIT host-exact ``active`` mask.  The engine folds ``self_weight``
    into the segment-sum consensus (``core.flat.consensus_flat_segments``)
    as N additional self edges; an all-fired window's self-weights equal
    the base diagonal EXACTLY (bitwise), mirroring ``EventWindow``'s
    all-fired ``w_eff == W`` contract.

    ``active`` is authoritative: inactive rows carry ``self_weight`` 1.0
    and zero fired in-edges, but the engine never re-derives activity from
    those weights (the f32 diagonal trick loses sub-2^-24 in-weights).

    ``w_eff`` exists only as a derived dense view BELOW the spec's
    ``SPARSE_DENSE_GUARD`` — the equivalence-ladder bridge that lets the
    dense masked engine execute the same window for comparison.
    """

    index: int
    dst: np.ndarray  # [E_max] int32 fired-edge destinations, zero-padded
    src: np.ndarray  # [E_max] int32 fired-edge sources, zero-padded
    weights: np.ndarray  # [E_max] float32 base mixing weights, 0.0 on pads
    self_weight: np.ndarray  # [N] float64 conserve diagonal (1.0 on idle rows)
    active: np.ndarray  # [N] bool, host-exact
    n_agents: int
    n_events: int  # real events before padding

    @property
    def e_max(self) -> int:
        return int(self.dst.shape[0])

    @property
    def active_fraction(self) -> float:
        return float(self.active.mean())

    @property
    def max_lag(self) -> int:
        """Sparse clocks are instant-delivery (no latency wrapper yet)."""
        return 0

    def participating(self) -> np.ndarray:
        """[N] bool: agents touched by any fired event (as dst or src)."""
        part = self.active.copy()
        if self.n_events:
            part[self.src[: self.n_events]] = True
        return part

    @property
    def w_eff(self) -> np.ndarray:
        """Derived dense [N, N] view (memoized) — the equivalence-ladder
        bridge to the dense masked engine.  Refuses above the spec's
        ``SPARSE_DENSE_GUARD``: past it this window must execute
        edge-native (``consensus_impl="segments"``)."""
        cached = getattr(self, "_w_eff_cache", None)
        if cached is not None:
            return cached
        from repro.api.spec import SPARSE_DENSE_GUARD

        n = self.n_agents
        if n > SPARSE_DENSE_GUARD:
            raise ValueError(
                f"SparseWindow has N={n} agents, above the dense-"
                f"materialization guard ({SPARSE_DENSE_GUARD}): refusing to "
                "derive [N, N] w_eff; execute the window edge-native "
                "(consensus_impl='segments')"
            )
        w = np.zeros((n, n), np.float64)
        idx = np.arange(n)
        w[idx, idx] = self.self_weight
        e = self.n_events
        w[self.dst[:e], self.src[:e]] = self.weights[:e].astype(np.float64)
        object.__setattr__(self, "_w_eff_cache", w)
        return w


def window_from_events(
    W_base: np.ndarray,
    events: Sequence[tuple[int, int]],
    e_max: int,
    index: int = 0,
    rule: str = "conserve",
    delays: Sequence[int] | None = None,
) -> EventWindow:
    """Build one ``EventWindow`` from a list of fired ``(dst, src)`` edges.

    Events must be edges of the base support (``W_base[dst, src] > 0``,
    ``dst != src``); duplicates within a window collapse to one merge (the
    FIRST occurrence wins, including its delay — callers wanting a different
    collapse rule, e.g. ``DelayedClock``'s most-recent-firing, dedup before
    calling).  ``delays`` (parallel to ``events``) records each delivery's
    lag in windows; ``None`` means instant delivery (all zeros).
    """
    Wb = np.asarray(W_base, np.float64)
    n = Wb.shape[0]
    lag_of = list(delays) if delays is not None else [0] * len(events)
    if len(lag_of) != len(events):
        raise ValueError(
            f"{len(lag_of)} delays for {len(events)} events — must be parallel"
        )
    uniq: list[tuple[int, int]] = []
    uniq_lags: list[int] = []
    seen = set()
    for (i, j), lag in zip(events, lag_of):
        i, j, lag = int(i), int(j), int(lag)
        if i == j:
            raise ValueError(f"self-event ({i}, {j}): self-loops are implicit")
        if Wb[i, j] <= 0:
            raise ValueError(f"event ({i}, {j}) is not an edge of the base graph")
        if lag < 0:
            raise ValueError(f"event ({i}, {j}) has negative delivery lag {lag}")
        if (i, j) not in seen:
            seen.add((i, j))
            uniq.append((i, j))
            uniq_lags.append(lag)
    if len(uniq) > e_max:
        raise ValueError(f"{len(uniq)} events exceed the clock's e_max={e_max}")
    if rule not in ("conserve", "table"):
        raise ValueError(f"unknown w_eff rule {rule!r}")

    active = np.zeros((n,), bool)
    w_eff = np.eye(n)
    for i, j in uniq:
        active[i] = True
    for i in np.nonzero(active)[0]:
        fired = [j for (d, j) in uniq if d == i]
        if rule == "conserve":
            # base weight on fired edges; every NON-fired in-edge's weight
            # moves onto self -> all-fired reproduces the base row bitwise
            support = [j for j in np.nonzero(Wb[i])[0] if j != i]
            idle = [j for j in support if j not in fired]
            w_eff[i, i] = Wb[i, i] + sum(Wb[i, j] for j in idle)
        else:  # "table": leftover mass on self (weight-table traces)
            w_eff[i, i] = 1.0 - sum(Wb[i, j] for j in fired)
        for j in fired:
            w_eff[i, j] = Wb[i, j]
        if w_eff[i, i] <= 0:
            raise ValueError(
                f"window row {i}: fired in-weights sum to "
                f"{1.0 - w_eff[i, i]:.6f} >= 1 (weight table not row-feasible)"
            )

    edges = np.zeros((max(e_max, 1), 2), np.int32)
    weights = np.zeros((max(e_max, 1),), np.float32)
    lags = np.zeros((max(e_max, 1),), np.int32)
    for k, (i, j) in enumerate(uniq):
        edges[k] = (i, j)
        weights[k] = Wb[i, j]
        lags[k] = uniq_lags[k]
    return EventWindow(
        index=index, edges=edges, weights=weights, active=active,
        w_eff=w_eff, n_events=len(uniq), delays=lags,
    )


def _directed_edges(W_base: np.ndarray) -> list[tuple[int, int]]:
    """Non-self directed edges (dst, src) of the base support, fixed order."""
    Wb = np.asarray(W_base)
    return [
        (i, j)
        for i in range(Wb.shape[0])
        for j in np.nonzero(Wb[i])[0]
        if i != int(j)
    ]


def thinned_poisson_indices(
    rng: np.random.Generator, n_edges: int, mu: float, e_max: int | None = None
) -> np.ndarray:
    """O(fired) Poisson edge sampling by superposition thinning.

    The union of ``n_edges`` independent Poisson(mu) edge processes is one
    Poisson(n_edges * mu) process whose firings land on uniformly chosen
    edges: draw the window's TOTAL firing count K ~ Poisson(E * mu), then K
    uniform edge picks.  Each edge's firing count is then exactly
    Poisson(mu), independent across edges — the same per-window event-set
    law as an O(E) pass of per-edge draws, in O(K) work.  At the sparse
    scales this serves (E = 10^5+, mu << 1) the window cost is proportional
    to what actually fires, not to the graph.

    Returns the sorted unique fired edge indices ([K'] int64).  Consumes
    only ``rng``, so a ``default_rng([seed, r])`` caller keeps every window
    a pure function of ``(seed, round)``.  ``e_max`` is the clock-declared
    unique-edge cap: exceeding it raises (the static window shape cannot
    hold the realization) rather than silently truncating.
    """
    if n_edges <= 0:
        return np.zeros(0, np.int64)
    k = int(rng.poisson(n_edges * mu))
    if k == 0:
        return np.zeros(0, np.int64)
    fired = np.unique(rng.integers(0, n_edges, size=k))
    if e_max is not None and fired.size > e_max:
        raise ValueError(
            f"thinned Poisson window fired {fired.size} unique edges, above "
            f"the clock-declared cap e_max={e_max}; raise e_max or lower "
            "rate * window_len"
        )
    return fired


class GossipClock:
    """Base class: a deterministic stream of fixed-shape event windows.

    Subclasses implement ``_events(r, rng) -> list[(dst, src)]``; everything
    else (padding, w_eff, union validation) is shared.  ``e_max`` is the
    static per-window edge capacity — identical across windows so one jit
    trace serves the whole run.  It is a CLOCK-DECLARED cap, not "all
    directed edges": subclasses that know their per-window support
    (``RoundRobinClock``, ``TraceClock``) or accept a declared bound
    (``PoissonClock(e_max=...)``) shrink it, and with it every static
    ``[E_max]`` window buffer the engine jits over.
    """

    rule = "conserve"

    def __init__(self, W_base: np.ndarray, seed: int = 0):
        self.W_base = np.asarray(W_base, np.float64)
        self.n_agents = self.W_base.shape[0]
        self.seed = int(seed)
        self.e_max = max(len(_directed_edges(self.W_base)), 1)
        # agent-level fault model (gossip.faults.FaultModel) — attached on
        # the OUTERMOST clock only (build_clock enforces this; wrappers reach
        # inner clocks through _events, which carries no fault filtering)
        self.faults = None

    # -- subclass hook -------------------------------------------------------

    def _events(self, r: int, rng: np.random.Generator) -> list[tuple[int, int]]:
        raise NotImplementedError

    # -- shared machinery ----------------------------------------------------

    def window(self, r: int) -> EventWindow:
        # one-slot memo: the Session builds window r for its W-tilde and the
        # engine's delayed/sharded paths immediately ask for the same window
        # again — don't pay the (DelayedClock: K+1 inner scans) construction
        # twice per round
        cached = getattr(self, "_last_window", None)
        if cached is not None and cached[0] == int(r):
            return cached[1]
        win = self._build_window(int(r))
        self._last_window = (int(r), win)
        return win

    def _build_window(self, r: int) -> EventWindow:
        rng = np.random.default_rng([self.seed, r])
        events, _ = self._filter_crashed(r, self._events(r, rng))
        return window_from_events(
            self.W_base, events, self.e_max, index=r, rule=self.rule,
        )

    def windows(self, n: int) -> list[EventWindow]:
        return [self.window(r) for r in range(n)]

    # -- agent churn (gossip.faults) -----------------------------------------

    def attach_faults(self, model) -> None:
        """Attach a ``FaultModel`` (see ``gossip.faults``).  A crashed agent
        fires no out-edges and receives nothing: every event whose src was
        down at FIRE time or whose dst is down at DELIVERY time is removed
        before the W-tilde build, so the ``"conserve"`` rule moves the
        dropped in-edge mass onto self and rows stay row-stochastic."""
        self.faults = model
        self._last_window = None  # invalidate the one-slot window memo

    def crashed(self, r: int) -> np.ndarray:
        """[N] bool: agents down during window ``r`` (all-False unfaulted)."""
        if self.faults is None:
            return np.zeros((self.n_agents,), bool)
        return self.faults.crashed(r)

    def _filter_crashed(self, r: int, events, lags=None):
        """Drop events touching crashed agents; returns ``(events, lags)``
        filtered in parallel (``lags`` may be None for instant delivery).

        src must be up at fire time ``r - lag``, dst at delivery time ``r``.
        """
        if self.faults is None or not events:
            return events, lags
        lag_of = [0] * len(events) if lags is None else [int(d) for d in lags]
        up_now = self.faults.up(r)
        keep_e, keep_l = [], []
        for (i, j), d in zip(events, lag_of):
            if up_now[int(i)] and self.faults.up(r - d)[int(j)]:
                keep_e.append((i, j))
                keep_l.append(d)
        return keep_e, (None if lags is None else keep_l)

    def union_support(self) -> np.ndarray:
        """[N, N] 0/1 adjacency of every edge that can EVER activate (self
        loops included) — the graph Assumption 1 is checked against."""
        return (self.W_base > 0).astype(float) + np.eye(self.n_agents)

    def validate(self) -> None:
        """Eager Assumption-1 check on the activation union (the
        time-varying relaxation: each window need not be connected, the
        union must be strongly connected)."""
        graphs.check_schedule_union([self.union_support()])


class PoissonClock(GossipClock):
    """Independent Poisson clock per directed edge (the classic asynchronous
    gossip model): edge (i <- j) fires ~ Poisson(rate * window_len) per
    window; >= 1 firing activates the edge for that window (multiple firings
    within one window collapse — the discretization this module trades for
    jittability).  Base W must be row-stochastic (``rule="conserve"``).

    Sampling is by superposition thinning (``thinned_poisson_indices``):
    O(fired) per window instead of an O(E) per-edge draw, same event-set
    law, still a pure function of ``(seed, round)``.  ``e_max`` optionally
    declares the per-window unique-edge cap (shrinking the engine's static
    window buffers); a window whose realization exceeds it raises rather
    than truncating.  Default: all directed edges (the cap never binds).
    """

    def __init__(
        self,
        W_base: np.ndarray,
        rate: float = 1.0,
        window_len: float = 1.0,
        seed: int = 0,
        e_max: int | None = None,
    ):
        super().__init__(W_base, seed)
        graphs.check_w(self.W_base, require_connected=False)
        if rate <= 0 or window_len <= 0:
            raise ValueError("rate and window_len must be positive")
        self.rate = float(rate)
        self.window_len = float(window_len)
        self._edges = _directed_edges(self.W_base)
        if e_max is not None:
            if not 1 <= int(e_max) <= len(self._edges):
                raise ValueError(
                    f"e_max must be in [1, {len(self._edges)}] (the directed "
                    f"edge count), got {e_max}"
                )
            self.e_max = int(e_max)

    def _events(self, r, rng):
        fired = thinned_poisson_indices(
            rng, len(self._edges), self.rate * self.window_len, e_max=self.e_max
        )
        return [self._edges[int(k)] for k in fired]


class RoundRobinClock(GossipClock):
    """Deterministic cyclic activation: ``edges_per_window`` consecutive
    edges of the base support fire each window, cycling in fixed order.  The
    union over one full cycle is the whole base graph — the minimal
    scheduled-gossip baseline (and a deterministic stand-in for Poisson in
    tests)."""

    def __init__(self, W_base: np.ndarray, edges_per_window: int = 1, seed: int = 0):
        super().__init__(W_base, seed)
        graphs.check_w(self.W_base, require_connected=False)
        if edges_per_window <= 0:
            raise ValueError("edges_per_window must be positive")
        self._edges = _directed_edges(self.W_base)
        self.edges_per_window = int(min(edges_per_window, len(self._edges)))
        self.e_max = self.edges_per_window

    def _events(self, r, rng):
        del rng  # deterministic
        k, m = self.edges_per_window, len(self._edges)
        start = (r * k) % m
        return [self._edges[(start + t) % m] for t in range(k)]


class TraceClock(GossipClock):
    """Explicit per-window edge lists, cycled over rounds — the replay /
    re-expression form (e.g. ``trace_from_schedule`` turns the paper's
    ``time_varying_star_schedule`` into a gossip trace).  ``rule="table"``
    accepts weight-table bases whose rows need not sum to 1; every distinct
    window is validated eagerly at construction."""

    def __init__(
        self,
        W_base: np.ndarray,
        trace: Sequence[Sequence[tuple[int, int]]],
        rule: str = "conserve",
        seed: int = 0,
    ):
        super().__init__(W_base, seed)
        if not trace:
            raise ValueError("TraceClock requires a non-empty trace")
        if rule == "conserve":
            # the conserve rule moves idle in-edge mass onto self, which is
            # only weight-conserving for a row-stochastic base; weight
            # tables (rows may exceed 1) must use rule="table"
            graphs.check_w(self.W_base, require_connected=False)
        self.rule = rule
        self.trace = [[(int(i), int(j)) for i, j in slot] for slot in trace]
        self.e_max = max(max((len(s) for s in self.trace), default=1), 1)
        for k, slot in enumerate(self.trace):  # eager per-window feasibility
            window_from_events(self.W_base, slot, self.e_max, index=k, rule=rule)

    def _events(self, r, rng):
        del rng
        return self.trace[r % len(self.trace)]

    def union_support(self) -> np.ndarray:
        adj = np.eye(self.n_agents)
        for slot in self.trace:
            for i, j in slot:
                adj[i, j] = 1.0
        return adj


class FailureInjectedClock(GossipClock):
    """Wrap any clock and drop each of its fired edges i.i.d. with
    probability ``drop_rate`` — the unreliable-link scenario.  The
    activation UNION is unchanged (every edge still fires infinitely often
    a.s. for drop_rate < 1), so Assumption 1 validation delegates to the
    inner clock."""

    def __init__(self, inner: GossipClock, drop_rate: float, seed: int = 0):
        if not 0.0 <= drop_rate < 1.0:
            raise ValueError("drop_rate must be in [0, 1)")
        _reject_wrapped_delay(inner, "failure_injected")
        super().__init__(inner.W_base, seed)
        self.inner = inner
        self.drop_rate = float(drop_rate)
        self.rule = inner.rule
        self.e_max = inner.e_max

    def _events(self, r, rng):
        del rng  # the shared [seed, r] stream family collides with the
        #          inner clock's when both seeds are equal (the default),
        #          which would make drops a deterministic function of the
        #          firings; salt the drop stream with a distinct word
        events = self.inner._events(r, np.random.default_rng([self.inner.seed, r]))
        drop_rng = np.random.default_rng([self.seed, 0xFA11ED, r])
        keep = drop_rng.random(len(events)) >= self.drop_rate
        return [e for e, k in zip(events, keep) if k]

    def union_support(self) -> np.ndarray:
        return self.inner.union_support()


def _reject_wrapped_delay(inner: GossipClock, outer_kind: str) -> None:
    """Delivery latency must be the OUTERMOST wrapper: every wrapper reaches
    its inner clock through ``_events``, which carries only the delivered
    edges — a ``DelayedClock`` buried inside another wrapper would have its
    lags silently stripped (the engine sees no ``max_delay`` on the outer
    clock and runs the instant path on time-shifted events: neither model).
    Reject the composition loudly instead."""
    if getattr(inner, "max_delay", 0) > 0:
        raise ValueError(
            f"a delayed clock cannot be wrapped inside {outer_kind!r}: the "
            "wrapper would silently drop its delivery lags.  Make 'delayed' "
            "the OUTERMOST wrapper (e.g. delayed(failure_injected(poisson)))"
        )


# salt word for the delivery-latency stream — like FailureInjectedClock's
# 0xFA11ED drop salt, it keeps the delay draws independent of the inner
# clock's firing draws even when both use the same (default) seed
DELAY_SALT = 0xDE1A7


class DelayedClock(GossipClock):
    """Wrap any clock with per-event DELIVERY LATENCY: an edge fired at
    window r is delivered (merged) at window ``r + d``, with d drawn from the
    latency model.  The delivered merge uses the SRC POSTERIOR AS OF FIRE
    TIME — src's post-local-step, pre-merge posterior of window r — which the
    engine reads from a bounded ``[K, N, P]`` history ring buffer
    (K = ``max_delay + 1`` slots).  This is the staleness regime the async
    analyses (BayGo arXiv:2011.04345; Lalitha et al. arXiv:1901.11173)
    bound: consensus mixes k-window-old information.

    latency models (checkpoint-embeddable plain dicts):

    * ``{"kind": "constant", "delay": k}`` — every message takes exactly k
      windows; k=0 reduces BITWISE to the inner clock (and the engine to the
      instant-delivery path).
    * ``{"kind": "geometric", "p": q, "max": k}`` — i.i.d. truncated
      geometric per event (support 0..k): memoryless per-hop retransmission.
    * ``{"kind": "per_edge", "delays": [[...]]}`` — an [N, N] int matrix of
      constant per-directed-edge lags (heterogeneous interconnect: slow WAN
      links next to fast local ones).

    Delay draws come from the salted stream ``[seed, DELAY_SALT, r_fire]``
    so they are deterministic per (seed, fire window) and independent of the
    inner clock's firing draws.  If one edge's firings from several windows
    pile up into the same delivery window, the MOST RECENT firing wins (one
    merge per in-edge per window keeps W-tilde row-feasible).  The
    activation UNION is the inner clock's — every fired edge still delivers
    within ``max_delay`` windows — so Assumption-1 validation delegates.
    Must be the OUTERMOST wrapper (``delayed(failure_injected(...))``, never
    the reverse): wrappers reach their inner clock through ``_events``,
    which strips lags — the inverted composition is rejected eagerly.
    """

    def __init__(self, inner: GossipClock, latency: dict, seed: int = 0):
        _reject_wrapped_delay(inner, "delayed")  # lags do not compose
        super().__init__(inner.W_base, seed)
        self.inner = inner
        self.rule = inner.rule
        if not isinstance(latency, dict) or "kind" not in latency:
            raise ValueError("latency must be a dict with a 'kind' key")
        self.latency = dict(latency)
        kind = self.latency["kind"]
        if kind == "constant":
            self.max_delay = int(self.latency.get("delay", 1))
            if self.max_delay < 0:
                raise ValueError("constant latency delay must be >= 0")
        elif kind == "geometric":
            p = float(self.latency.get("p", 0.5))
            if not 0.0 < p <= 1.0:
                raise ValueError("geometric latency p must be in (0, 1]")
            self.max_delay = int(self.latency.get("max", 4))
            if self.max_delay < 0:
                raise ValueError("geometric latency max must be >= 0")
        elif kind == "per_edge":
            mat = np.asarray(self.latency.get("delays"), np.int64)
            if mat.shape != self.W_base.shape:
                raise ValueError(
                    f"per_edge latency matrix shape {mat.shape} != base W "
                    f"shape {self.W_base.shape}"
                )
            if (mat < 0).any():
                raise ValueError("per_edge latency delays must be >= 0")
            self._delay_matrix = mat
            support = (self.W_base > 0) & ~np.eye(self.n_agents, dtype=bool)
            self.max_delay = int(mat[support].max()) if support.any() else 0
        else:
            raise ValueError(
                f"unknown latency kind {kind!r}; known: "
                "constant | geometric | per_edge"
            )
        # deliveries dedup to one merge per directed edge per window, so the
        # base-graph edge count bounds every window regardless of pile-up
        # (GossipClock.__init__ already set e_max to exactly that)

        # A lag-MIXING latency (geometric, or per_edge with unequal lags
        # WITHIN one row's in-edges) can re-combine individually-feasible
        # fire windows into one delivery window; under rule="table" the
        # combined in-weights could reach >= 1 and crash mid-run AFTER
        # eager validation.  Check the worst case (a row's whole in-edge
        # support delivered together) eagerly, per row.  Constant/uniform
        # latency never mixes lags — deliveries are exactly one
        # (already-validated) inner window — so it needs no check, and
        # rule="conserve" rows are feasible under ANY subset (in-weights
        # sum to 1 - W[i,i] < 1 by row-stochasticity).
        if self.rule == "table":
            off_diag = self.W_base * (1.0 - np.eye(self.n_agents))
            worst = off_diag.sum(axis=1)
            bad = np.nonzero(self._row_mixes_lags() & (worst >= 1.0))[0]
            if bad.size:
                raise ValueError(
                    f"delaying this weight-table trace with a lag-mixing "
                    f"latency ({kind!r}) can co-deliver row "
                    f"{int(bad[0])}'s in-edges (combined weight "
                    f"{worst[bad[0]]:.6f} >= 1); use a constant delay, or "
                    "a table whose rows stay feasible under simultaneous "
                    "delivery"
                )

    def _row_mixes_lags(self) -> np.ndarray:
        """[N] bool: rows whose deliveries within one window can come from
        DIFFERENT fire windows (the re-combination hazard the table-rule
        eager check guards against).  Per row: a row whose own in-edges all
        share one lag only ever receives one shifted fire window, no matter
        what lags the rest of the graph carries."""
        kind = self.latency["kind"]
        n = self.n_agents
        if kind == "geometric":
            return np.full((n,), self.max_delay > 0)
        if kind == "constant":
            return np.zeros((n,), bool)
        support = (self.W_base > 0) & ~np.eye(n, dtype=bool)
        out = np.zeros((n,), bool)
        for i in range(n):
            lags = self._delay_matrix[i, support[i]]
            out[i] = lags.size > 1 and int(lags.min()) != int(lags.max())
        return out

    def _fire_delays(self, r_fire: int, events: list) -> np.ndarray:
        """Per-event delivery lag for the firings of window ``r_fire``."""
        kind = self.latency["kind"]
        if kind == "constant":
            return np.full((len(events),), self.max_delay, np.int64)
        if kind == "per_edge":
            return np.asarray(
                [self._delay_matrix[i, j] for i, j in events], np.int64
            )
        rng = np.random.default_rng([self.seed, DELAY_SALT, r_fire])
        p = float(self.latency.get("p", 0.5))
        return np.minimum(
            rng.geometric(p, size=len(events)) - 1, self.max_delay
        )

    def _events(self, r, rng):
        del rng
        return [e for e, _ in self._deliveries(int(r))]

    def _deliveries(self, r: int) -> list[tuple[tuple[int, int], int]]:
        """[(edge, lag)] delivered at window r, most-recent firing per edge."""
        latest: dict[tuple[int, int], int] = {}
        for r_fire in range(max(0, r - self.max_delay), r + 1):
            fired = self.inner._events(
                r_fire, np.random.default_rng([self.inner.seed, r_fire])
            )
            lags = self._fire_delays(r_fire, fired)
            for e, d in zip(fired, lags):
                if r_fire + int(d) == r:
                    latest[(int(e[0]), int(e[1]))] = r - r_fire
        return [(e, lag) for e, lag in latest.items()]

    def _build_window(self, r: int) -> EventWindow:
        deliveries = self._deliveries(r)
        events, lags = self._filter_crashed(
            r, [e for e, _ in deliveries], [lag for _, lag in deliveries]
        )
        return window_from_events(
            self.W_base, events, self.e_max,
            index=r, rule=self.rule, delays=lags,
        )

    def union_support(self) -> np.ndarray:
        return self.inner.union_support()


# ---------------------------------------------------------------------------
# edge-native clocks (population scale: SparseGraph -> SparseWindow streams)
# ---------------------------------------------------------------------------


class SparseClock:
    """Base class: a deterministic stream of edge-native ``SparseWindow``s.

    The sparse analogue of ``GossipClock``, built over a CSR
    ``SparseGraph`` (arriving pre-validated from the spec layer) instead
    of a dense base W.  Subclasses implement ``_fired(r, rng) -> [K]
    int64`` — indices into the graph's NON-SELF directed edge list, unique
    within a window — and the shared machinery assembles the window in
    O(fired + N) host work: the conserve-rule self-weights come from two
    ``np.bincount`` passes over the fired edges against per-graph
    precomputed off-diagonal row sums, never from a per-row scan (let
    alone an ``np.eye``).  ``rule="conserve"`` only: an all-fired row's
    self-weight is EXACTLY the base diagonal (bitwise), a partial row adds
    its idle in-edge mass onto self, an idle row is exactly ``e_i``
    (self-weight 1.0, active False).

    Determinism contract: identical to ``GossipClock`` — ``window(r)`` is
    a pure function of ``(seed, r)`` via ``default_rng([seed, r])``, with
    the same one-slot memo, fault attachment (vectorized edge-list crash
    filtering, ``gossip.faults.edge_keep_mask``) and Assumption-1
    validation (O(E) iterative strong connectivity on the CSR arrays).
    """

    rule = "conserve"

    def __init__(self, graph: graphs.SparseGraph, seed: int = 0):
        self.graph = graph
        self.n_agents = graph.n_agents
        self.seed = int(seed)
        self.faults = None
        self.max_delay = 0
        dst, src, w32 = graph.edge_arrays()
        ns = dst != src
        # fired-edge tables (non-self, edge_arrays order — CSR row-major)
        self._ns_dst = dst[ns]
        self._ns_src = src[ns]
        self._ns_w32 = w32[ns]
        # f64 twins for exact conserve-rule self-weight arithmetic (the CSR
        # weights array shares edge_arrays' ordering)
        w64 = np.asarray(graph.weights, np.float64)
        self._ns_w64 = w64[ns]
        n = self.n_agents
        diag = np.zeros(n, np.float64)
        diag[dst[~ns]] = w64[~ns]
        self._w_diag = diag
        self._offdiag_sum = np.bincount(
            self._ns_dst, weights=self._ns_w64, minlength=n
        )
        self._deg_offdiag = np.bincount(self._ns_dst, minlength=n)
        #: non-self directed edge count — the fired-index space of _fired
        self.n_edges = int(self._ns_dst.shape[0])
        self.e_max = max(self.n_edges, 1)

    # -- subclass hook -------------------------------------------------------

    def _fired(self, r: int, rng: np.random.Generator) -> np.ndarray:
        """[K] int64 unique indices into the non-self edge list."""
        raise NotImplementedError

    # -- shared machinery ----------------------------------------------------

    def window(self, r: int) -> SparseWindow:
        cached = getattr(self, "_last_window", None)
        if cached is not None and cached[0] == int(r):
            return cached[1]
        win = self._build_window(int(r))
        self._last_window = (int(r), win)
        return win

    def _build_window(self, r: int) -> SparseWindow:
        rng = np.random.default_rng([self.seed, r])
        fired = np.asarray(self._fired(r, rng), np.int64)
        f_dst = self._ns_dst[fired]
        f_src = self._ns_src[fired]
        if self.faults is not None:
            from repro.gossip.faults import edge_keep_mask

            keep = edge_keep_mask(self.faults, r, f_dst, f_src)
            fired, f_dst, f_src = fired[keep], f_dst[keep], f_src[keep]
        n_ev = int(fired.shape[0])
        if n_ev > self.e_max:
            raise ValueError(
                f"window {r} fired {n_ev} edges, above the clock's static "
                f"e_max={self.e_max}"
            )
        n = self.n_agents
        fired_count = np.bincount(f_dst, minlength=n)
        fired_sum = np.bincount(
            f_dst, weights=self._ns_w64[fired], minlength=n
        )
        active = fired_count > 0
        # all-fired rows keep EXACTLY the base diagonal (the bitwise
        # all-edges contract); partial rows add idle in-edge mass onto self
        w_self = np.where(
            fired_count == self._deg_offdiag,
            self._w_diag,
            self._w_diag + (self._offdiag_sum - fired_sum),
        )
        w_self = np.where(active, w_self, 1.0)
        if np.any(w_self[active] <= 0.0):
            bad = int(np.nonzero(active & (w_self <= 0.0))[0][0])
            raise ValueError(
                f"window row {bad}: conserve self-weight "
                f"{w_self[bad]:.6g} <= 0 (base graph is not row-stochastic?)"
            )
        cap = self.e_max
        dst_p = np.zeros(cap, np.int32)
        src_p = np.zeros(cap, np.int32)
        wts_p = np.zeros(cap, np.float32)
        dst_p[:n_ev] = f_dst
        src_p[:n_ev] = f_src
        wts_p[:n_ev] = self._ns_w32[fired]
        return SparseWindow(
            index=r, dst=dst_p, src=src_p, weights=wts_p,
            self_weight=w_self, active=active, n_agents=n, n_events=n_ev,
        )

    def windows(self, n: int) -> list[SparseWindow]:
        return [self.window(r) for r in range(n)]

    # -- agent churn (gossip.faults) -----------------------------------------

    def attach_faults(self, model) -> None:
        """Attach a ``FaultModel``: fired edges touching a crashed agent are
        filtered (vectorized, on the edge list) before the self-weight
        build, so the conserve rule moves their mass onto self exactly as
        the dense clocks do."""
        self.faults = model
        self._last_window = None

    def crashed(self, r: int) -> np.ndarray:
        if self.faults is None:
            return np.zeros((self.n_agents,), bool)
        return self.faults.crashed(r)

    def validate(self) -> None:
        """Assumption 1 on the activation union — the base graph's own
        support, checked in O(E) on the CSR arrays (never a dense union
        matrix)."""
        if not self.graph.strongly_connected():
            raise ValueError(
                "sparse gossip base graph must be strongly connected "
                "(Assumption 1 on the activation union)"
            )


class SparsePoissonClock(SparseClock):
    """Independent Poisson clock per non-self directed edge over a
    ``SparseGraph`` — ``PoissonClock`` without the dense base.  Sampling is
    the same superposition thinning (``thinned_poisson_indices``): O(fired)
    per window, a pure function of ``(seed, round)``.  ``e_max`` optionally
    declares the per-window unique-edge cap, shrinking the engine's static
    ``[E_max]`` buffers; an overflowing realization raises rather than
    truncating."""

    def __init__(
        self,
        graph: graphs.SparseGraph,
        rate: float = 1.0,
        window_len: float = 1.0,
        seed: int = 0,
        e_max: int | None = None,
    ):
        super().__init__(graph, seed)
        if rate <= 0 or window_len <= 0:
            raise ValueError("rate and window_len must be positive")
        self.rate = float(rate)
        self.window_len = float(window_len)
        if e_max is not None:
            if not 1 <= int(e_max) <= self.n_edges:
                raise ValueError(
                    f"e_max must be in [1, {self.n_edges}] (the non-self "
                    f"directed edge count), got {e_max}"
                )
            self.e_max = int(e_max)

    def _fired(self, r, rng):
        return thinned_poisson_indices(
            rng, self.n_edges, self.rate * self.window_len, e_max=self.e_max
        )


class SparseAllEdgesClock(SparseClock):
    """Every non-self edge fires every window — the sparse ladder anchor:
    each window's self-weights equal the base diagonal bitwise, so the
    segment-sum window reproduces the synchronous segment consensus over
    ``SparseGraph.edge_arrays()`` exactly (same edge set, same weights)."""

    def __init__(self, graph: graphs.SparseGraph, seed: int = 0):
        super().__init__(graph, seed)
        self._all = np.arange(self.n_edges, dtype=np.int64)

    def _fired(self, r, rng):
        del rng  # deterministic
        return self._all


class SparseFailureInjectedClock(SparseClock):
    """Drop each of the inner sparse clock's fired edges i.i.d. with
    probability ``drop_rate`` — ``FailureInjectedClock`` on edge lists.
    The drop stream is salted with the same ``0xFA11ED`` word so drops
    stay independent of the inner clock's firing draws."""

    def __init__(self, inner: SparseClock, drop_rate: float, seed: int = 0):
        if not 0.0 <= drop_rate < 1.0:
            raise ValueError("drop_rate must be in [0, 1)")
        super().__init__(inner.graph, seed)
        self.inner = inner
        self.drop_rate = float(drop_rate)
        self.e_max = inner.e_max

    def _fired(self, r, rng):
        del rng  # salted stream, as in FailureInjectedClock
        fired = np.asarray(
            self.inner._fired(r, np.random.default_rng([self.inner.seed, r])),
            np.int64,
        )
        drop_rng = np.random.default_rng([self.seed, 0xFA11ED, r])
        return fired[drop_rng.random(fired.shape[0]) >= self.drop_rate]


def build_sparse_clock(
    doc: dict, graph: graphs.SparseGraph, _inner: bool = False
) -> SparseClock:
    """Build an edge-native clock from a plain dict (the
    ``TopologySpec.clock`` form on ``kind="sparse"`` topologies).  Same
    conventions as ``build_clock``: keys beyond the per-kind parameters
    (``local_policy``) are ignored here, and a top-level ``"faults"`` key
    attaches agent churn — rejected on inner docs for the same
    silently-ignored reason.

    kinds:
      ``poisson``           rate, window_len, seed, e_max (optional cap)
      ``all_edges``         every non-self edge every window (ladder anchor)
      ``failure_injected``  inner=<sparse clock doc>, drop_rate, seed
    """
    if not isinstance(doc, dict) or "kind" not in doc:
        raise ValueError("clock must be a dict with a 'kind' key")
    if "faults" in doc and _inner:
        raise ValueError(
            "'faults' must sit on the OUTERMOST clock doc: an inner clock's "
            "fault model would be silently ignored"
        )
    kind = doc["kind"]
    if kind == "poisson":
        clock: SparseClock = SparsePoissonClock(
            graph,
            rate=doc.get("rate", 1.0),
            window_len=doc.get("window_len", 1.0),
            seed=doc.get("seed", 0),
            e_max=doc.get("e_max"),
        )
    elif kind == "all_edges":
        clock = SparseAllEdgesClock(graph, seed=doc.get("seed", 0))
    elif kind == "failure_injected":
        if "inner" not in doc:
            raise ValueError("clock kind='failure_injected' requires 'inner'")
        clock = SparseFailureInjectedClock(
            build_sparse_clock(doc["inner"], graph, _inner=True),
            drop_rate=doc.get("drop_rate", 0.1),
            seed=doc.get("seed", 0),
        )
    else:
        raise ValueError(
            f"unknown sparse clock kind {kind!r}; known: "
            "poisson | all_edges | failure_injected"
        )
    if doc.get("faults") is not None:
        from repro.gossip import faults as _faults

        clock.attach_faults(
            _faults.build_faults(doc["faults"], clock.n_agents)
        )
    return clock


# ---------------------------------------------------------------------------
# trace builders
# ---------------------------------------------------------------------------


def all_edges_trace(W_base: np.ndarray) -> TraceClock:
    """The degenerate trace where EVERY base edge fires EVERY window — each
    window's w_eff equals the base W bitwise (``rule="conserve"``), so the
    gossip runtime reproduces the synchronous fused consensus bit-identically
    (the equivalence property the tests pin)."""
    return TraceClock(W_base, [_directed_edges(W_base)], rule="conserve")


def trace_from_schedule(mats: Sequence[np.ndarray]) -> tuple[np.ndarray, list]:
    """Re-express a W schedule (e.g. ``graphs.time_varying_star_schedule``)
    as (weight table, per-window edge list) for a ``TraceClock(rule="table")``.

    Requires each directed edge to carry the SAME weight in every slot where
    it is active (true for the paper's time-varying star); the table's row
    sums may exceed 1 — only the per-window fired subsets must be feasible.
    """
    mats = [np.asarray(m, np.float64) for m in mats]
    n = mats[0].shape[0]
    table = np.zeros((n, n))
    np.fill_diagonal(table, 1.0)  # placeholder; diag comes from the rule
    trace = []
    for W in mats:
        slot = []
        for i in range(n):
            for j in np.nonzero(W[i])[0]:
                j = int(j)
                if i == j:
                    continue
                if table[i, j] != 0.0 and not np.isclose(table[i, j], W[i, j]):
                    raise ValueError(
                        f"edge ({i}, {j}) has inconsistent weights across "
                        f"slots: {table[i, j]} vs {W[i, j]}"
                    )
                table[i, j] = W[i, j]
                slot.append((i, j))
        trace.append(slot)
    return table, trace


# ---------------------------------------------------------------------------
# spec-dict registry (checkpoint-embeddable clock descriptions)
# ---------------------------------------------------------------------------


def build_clock(doc: dict, W_base: np.ndarray, _inner: bool = False) -> GossipClock:
    """Build a clock from a plain dict (the ``TopologySpec.clock`` form that
    rides in session checkpoints).  Keys beyond the per-kind parameters
    (e.g. ``local_policy``, consumed by the engine) are ignored here.

    A TOP-LEVEL ``"faults"`` key (a ``gossip.faults.FaultSpec`` doc) attaches
    agent churn to the built clock: crashed agents fire no out-edges and
    receive nothing (their in-edge mass moves to self via the w_eff rule).
    ``"faults"`` on an INNER clock doc is rejected — wrappers reach inner
    clocks through ``_events``, which carries no fault filtering, so a
    nested fault model would be silently ignored.

    kinds:
      ``poisson``           rate, window_len, seed, e_max (optional declared
                            per-window unique-edge cap; default all edges)
      ``round_robin``       edges_per_window, seed
      ``trace``             trace=[[[dst, src], ...], ...], rule, seed
      ``failure_injected``  inner=<clock doc>, drop_rate, seed
      ``delayed``           inner=<clock doc>, latency=<latency doc>, seed
                            (latency: constant | geometric | per_edge —
                            see ``DelayedClock``)
    """
    if not isinstance(doc, dict) or "kind" not in doc:
        raise ValueError("clock must be a dict with a 'kind' key")
    if "faults" in doc and _inner:
        raise ValueError(
            "'faults' must sit on the OUTERMOST clock doc: an inner clock's "
            "fault model would be silently ignored (wrappers reach inner "
            "clocks through _events, which carries no fault filtering)"
        )
    kind = doc["kind"]
    clock = None
    if kind == "poisson":
        clock = PoissonClock(
            W_base,
            rate=doc.get("rate", 1.0),
            window_len=doc.get("window_len", 1.0),
            seed=doc.get("seed", 0),
            e_max=doc.get("e_max"),
        )
    elif kind == "round_robin":
        clock = RoundRobinClock(
            W_base,
            edges_per_window=doc.get("edges_per_window", 1),
            seed=doc.get("seed", 0),
        )
    elif kind == "trace":
        if "trace" not in doc:
            raise ValueError("clock kind='trace' requires a 'trace' list")
        clock = TraceClock(
            W_base,
            trace=[[(e[0], e[1]) for e in slot] for slot in doc["trace"]],
            rule=doc.get("rule", "conserve"),
            seed=doc.get("seed", 0),
        )
    elif kind == "failure_injected":
        if "inner" not in doc:
            raise ValueError("clock kind='failure_injected' requires 'inner'")
        clock = FailureInjectedClock(
            build_clock(doc["inner"], W_base, _inner=True),
            drop_rate=doc.get("drop_rate", 0.1),
            seed=doc.get("seed", 0),
        )
    elif kind == "delayed":
        if "inner" not in doc:
            raise ValueError("clock kind='delayed' requires 'inner'")
        clock = DelayedClock(
            build_clock(doc["inner"], W_base, _inner=True),
            latency=doc.get("latency", {"kind": "constant", "delay": 1}),
            seed=doc.get("seed", 0),
        )
    else:
        raise ValueError(
            f"unknown clock kind {kind!r}; known: "
            "poisson | round_robin | trace | failure_injected | delayed"
        )
    if doc.get("faults") is not None:
        from repro.gossip import faults as _faults

        clock.attach_faults(
            _faults.build_faults(doc["faults"], clock.n_agents)
        )
    return clock
