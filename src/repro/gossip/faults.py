"""Deterministic agent-level fault model for the gossip runtime.

The clock layer (``gossip.clocks``) already models *link*-level faults:
``failure_injected`` drops fired edges i.i.d. and ``delayed`` delivers them
late.  This module adds the *agent*-level failure regime — churn (crash /
recover) and payload corruption — as a deterministic, checkpoint-embeddable
layer that composes with every clock kind.

Determinism contract (mirrors the EventWindow contract): every fault
decision for window ``r`` is a pure function of ``(spec.seed, r)`` drawn
from salted counter streams, so

* windows remain pure functions of ``(seed, round)`` — a crashed-and-resumed
  session regenerates the identical crash/corruption schedule;
* the crash stream ``[seed, 0xC7A54, r]``, the corruption stream
  ``[seed, 0xBADBAD, r]``, the link-drop stream ``[seed, 0xFA11ED, r]``
  and the delay stream ``[seed, 0xDE1A7, r]`` are pairwise independent
  (distinct salt words on independent Philox streams).

Churn is a per-agent two-state Markov chain: an UP agent crashes with
probability ``crash_rate`` per window, a DOWN agent recovers with
probability ``recover_rate`` per window; all agents start UP at window 0.
The chain is replayed from window 0 on demand (memoized prefix), so
``up(r)`` is independent of access order.

A crashed agent skips local training, fires no out-edges, receives
nothing (its in-edge W-tilde mass moves to self via the ``"conserve"``
rule — rows stay row-stochastic), and its resident posterior is frozen.

Corruption models a flaky/adversarial *sender*: a corrupted-but-up agent's
exchanged ``(prec, prec*mu)`` statistics are replaced by NaN / Inf /
huge-magnitude garbage at the exchange boundary while its resident state
stays intact.  The quarantine guard (``core.flat.payload_validity``) is the
defense; ``fault_policy="strict"`` shows the undefended failure mode.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

# Salt words for the per-concern counter streams.  CRASH_SALT is fixed by
# the issue contract; the link-drop (0xFA11ED) and delay (0xDE1A7) salts
# live in gossip.clocks.  All four must stay pairwise distinct — the
# property tests assert pairwise independence of the streams.
CRASH_SALT = 0xC7A54
CORRUPT_SALT = 0xBADBAD

_CORRUPT_KINDS = ("nan", "inf", "huge", "mix")

# Garbage magnitudes injected by kind "huge": far above any sane posterior
# statistic yet still finite — caught only by the magnitude bound, not the
# finiteness check.
HUGE_FILL = 1.0e30


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Checkpoint-embeddable agent-fault configuration.

    Rides inside the clock doc as ``clock={"kind": ..., "faults": {...}}``
    so it lands in the self-describing session checkpoint next to the clock
    parameters and resumes bit-identically.
    """

    crash_rate: float = 0.0
    recover_rate: float = 0.5
    corrupt_rate: float = 0.0
    corrupt_kind: str = "mix"
    seed: int = 0

    def validate(self) -> None:
        if not (0.0 <= self.crash_rate < 1.0):
            raise ValueError(
                f"crash_rate must be in [0, 1), got {self.crash_rate}"
            )
        if not (0.0 <= self.corrupt_rate <= 1.0):
            raise ValueError(
                f"corrupt_rate must be in [0, 1], got {self.corrupt_rate}"
            )
        if self.crash_rate > 0.0 and not (0.0 < self.recover_rate <= 1.0):
            raise ValueError(
                "recover_rate must be in (0, 1] when crash_rate > 0 "
                f"(agents must be able to rejoin), got {self.recover_rate}"
            )
        if not (0.0 <= self.recover_rate <= 1.0):
            raise ValueError(
                f"recover_rate must be in [0, 1], got {self.recover_rate}"
            )
        if self.corrupt_kind not in _CORRUPT_KINDS:
            raise ValueError(
                f"corrupt_kind must be one of {_CORRUPT_KINDS}, "
                f"got {self.corrupt_kind!r}"
            )

    def to_doc(self) -> Dict[str, Any]:
        return {
            "crash_rate": float(self.crash_rate),
            "recover_rate": float(self.recover_rate),
            "corrupt_rate": float(self.corrupt_rate),
            "corrupt_kind": str(self.corrupt_kind),
            "seed": int(self.seed),
        }

    @classmethod
    def from_doc(cls, doc: Dict[str, Any]) -> "FaultSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        extra = set(doc) - known
        if extra:
            raise ValueError(f"unknown FaultSpec keys: {sorted(extra)}")
        spec = cls(**doc)
        spec.validate()
        return spec


class FaultModel:
    """Replayable realization of a :class:`FaultSpec` over ``n_agents``.

    All queries are pure functions of ``(spec.seed, r)``: the Markov up/down
    chain is replayed from window 0 (memoized prefix, O(1) amortized for
    sequential access), and the corruption draws are per-window salted
    streams, so any access order — including a resume from an arbitrary
    round — yields the identical schedule.
    """

    def __init__(self, spec: FaultSpec, n_agents: int):
        spec.validate()
        self.spec = spec
        self.n_agents = int(n_agents)
        # memoized up/down prefix; index r holds the state DURING window r
        self._up: list = [np.ones(self.n_agents, dtype=bool)]

    # -- churn ------------------------------------------------------------
    def up(self, r: int) -> np.ndarray:
        """[n_agents] bool: agent is up during window ``r`` (all up at 0)."""
        if r < 0:
            raise ValueError(f"round index must be >= 0, got {r}")
        while len(self._up) <= r:
            t = len(self._up)  # transition INTO window t
            rng = np.random.default_rng([self.spec.seed, CRASH_SALT, t])
            u = rng.random(self.n_agents)
            prev = self._up[t - 1]
            nxt = np.where(prev, u >= self.spec.crash_rate,
                           u < self.spec.recover_rate)
            self._up.append(nxt)
        return self._up[r].copy()

    def crashed(self, r: int) -> np.ndarray:
        """[n_agents] bool: agent is down during window ``r``."""
        return ~self.up(r)

    # -- corruption -------------------------------------------------------
    def corrupted(self, r: int) -> np.ndarray:
        """[n_agents] bool: agent emits garbage statistics in window ``r``.

        Only UP agents corrupt — a crashed agent emits nothing at all.
        """
        if self.spec.corrupt_rate <= 0.0:
            return np.zeros(self.n_agents, dtype=bool)
        rng = np.random.default_rng([self.spec.seed, CORRUPT_SALT, r])
        draw = rng.random(self.n_agents) < self.spec.corrupt_rate
        return draw & self.up(r)

    def fills(self, r: int):
        """Per-agent garbage fill values for window ``r``.

        Returns ``(fill_mean, fill_rho)`` float32 [n_agents] arrays: the
        values a corrupted agent's (mean, rho) wire payload is replaced
        with.  ``nan`` poisons via non-finite prec*mu, ``inf`` via
        non-finite mean, ``huge`` stays finite but blows the magnitude
        bound; ``mix`` cycles all three deterministically (second draw of
        the same salted stream as :meth:`corrupted`).
        """
        kind = self.spec.corrupt_kind
        n = self.n_agents
        if kind == "mix":
            rng = np.random.default_rng([self.spec.seed, CORRUPT_SALT, r])
            rng.random(n)  # skip the corrupted() draw
            pick = rng.integers(0, 3, n)
        else:
            pick = np.full(n, _CORRUPT_KINDS.index(kind), dtype=np.int64)
        # kind 0 = nan, 1 = inf, 2 = huge.  rho stays benign (0.0 →
        # prec ~ 2.08) for inf/huge so the poison arrives via the mean.
        fill_mean = np.choose(pick, [np.nan, np.inf, HUGE_FILL])
        fill_rho = np.choose(pick, [np.nan, 0.0, 0.0])
        return (fill_mean.astype(np.float32), fill_rho.astype(np.float32))

    # -- telemetry --------------------------------------------------------
    def uptime(self, n_rounds: int) -> np.ndarray:
        """[n_agents] int: windows each agent was up in [0, n_rounds)."""
        total = np.zeros(self.n_agents, dtype=np.int64)
        for r in range(int(n_rounds)):
            total += self.up(r)
        return total

    def to_doc(self) -> Dict[str, Any]:
        return self.spec.to_doc()


def build_faults(doc: Optional[Dict[str, Any]],
                 n_agents: int) -> Optional[FaultModel]:
    """Build a FaultModel from a clock-doc ``"faults"`` entry (or None)."""
    if doc is None:
        return None
    return FaultModel(FaultSpec.from_doc(dict(doc)), n_agents)


def edge_keep_mask(
    model: FaultModel, r: int, dst: np.ndarray, src: np.ndarray,
    lags: Optional[np.ndarray] = None,
) -> np.ndarray:
    """[E] bool: which fired edges survive the crash filter for window ``r``.

    The vectorized edge-list form of ``GossipClock._filter_crashed`` (the
    only form usable at population scale — no per-event Python loop): an
    edge survives iff its dst is up at DELIVERY time ``r`` and its src was
    up at FIRE time ``r - lag`` (``lags=None`` = instant delivery, fire
    time == delivery time).  Fancy-indexing the memoized up/down chain keeps
    the whole filter O(fired) host work.
    """
    dst = np.asarray(dst)
    src = np.asarray(src)
    up_now = model.up(r)
    keep = up_now[dst]
    if lags is None:
        return keep & up_now[src]
    lags = np.asarray(lags)
    for lag in np.unique(lags):
        sel = lags == lag
        keep[sel] &= model.up(r - int(lag))[src[sel]]
    return keep
