"""``repro.gossip`` — event-driven asynchronous gossip runtime.

The paper's communication model is *asynchronous*: each agent updates its
posterior from local data plus asynchronous aggregation with 1-hop
neighbors.  This package closes the gap between that model and the
synchronous lockstep rounds of the simulated/launch runtimes:

* ``clocks`` — per-edge activation clocks (``poisson | round_robin |
  trace | failure_injected``) that discretize continuous-time gossip into
  fixed-size **event windows**: each window is a padded ``[E_max, 2]`` edge
  list + per-agent activity mask + effective row-stochastic W-tilde, so a
  whole window jit-compiles with static shapes (no per-event Python
  dispatch).
* ``engine`` — ``GossipEngine``, the ``repro.api`` Engine-protocol runtime
  that executes one event window per ``run_round`` call as ONE jitted
  program: local VI steps, active-edge consensus
  (``kernels.consensus.consensus_fused_masked``; inactive agents pass
  through bit-identically), and per-agent staleness telemetry.

A gossip experiment is declared like any other: ``TopologySpec.gossip(...)``
inside an ``ExperimentSpec`` — ``build_session`` validates the activation
union against Assumption 1 and ``Session.evaluate`` reports staleness
percentiles.
"""
from repro.gossip.clocks import (
    EventWindow,
    FailureInjectedClock,
    GossipClock,
    PoissonClock,
    RoundRobinClock,
    TraceClock,
    all_edges_trace,
    build_clock,
    trace_from_schedule,
    window_from_events,
)
from repro.gossip.engine import GossipEngine, GossipState

__all__ = [
    "EventWindow",
    "FailureInjectedClock",
    "GossipClock",
    "GossipEngine",
    "GossipState",
    "PoissonClock",
    "RoundRobinClock",
    "TraceClock",
    "all_edges_trace",
    "build_clock",
    "trace_from_schedule",
    "window_from_events",
]
