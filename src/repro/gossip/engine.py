"""``GossipEngine`` — the event-driven asynchronous runtime behind
``api.Session``.

One ``run_round`` call executes one EVENT WINDOW (``gossip.clocks``) as ONE
jitted program: per-agent local Bayes-by-Backprop steps, then the masked
active-edge consensus (``core.flat.consensus_flat_masked`` — Pallas
``consensus_fused_masked`` on TPU, masked fused XLA elsewhere).  The
``Engine`` protocol is unchanged — the Session hands the engine the
window's effective W-tilde exactly as it hands the synchronous engines a
scheduled W — so specs, checkpoints, and the round loop all work
untouched.  The activity mask is recovered from W-tilde itself: an agent
is active iff its row is not ``e_i`` (``diag(W) < 1``), which the clock
construction guarantees exactly.

Two local-step policies (``TopologySpec.clock["local_policy"]``):

* ``"all"`` (default) — every agent trains locally every window and only
  the MERGES are event-driven (the paper's time-varying model: idle agents
  keep learning on local data; ``time_varying_star_schedule`` re-expressed
  as a gossip trace reproduces the table3 runs).
* ``"active"`` — wake-on-event: agents without an incoming activation
  sleep the whole window (posterior, optimizer state and step counter all
  pass through bit-identically) — the fully asynchronous regime where
  staleness is visible in the *local* state too.

Staleness telemetry rides in the state: per-agent window index of the last
merge and total merge count; ``Session.evaluate`` surfaces the percentiles
via ``telemetry``.

Equivalence contract (pinned by tests/test_gossip.py): with an
``all_edges_trace`` clock every window's W-tilde equals the base W bitwise
and every agent is active, so the GossipEngine's posterior trajectory is
BIT-IDENTICAL to ``SimulatedEngine`` on the same spec — the synchronous
runtime is literally the all-edges special case of this one.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flat import (
    FlatPosterior,
    consensus_flat_masked,
    make_flat_nll,
)
from repro.core.simulated import init_network, network_local_steps

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GossipState:
    """Network state + per-agent gossip telemetry (all leaves agent-leading,
    checkpointed leaf-wise like every engine state)."""

    posterior: FlatPosterior
    opt_state: Any
    step: jax.Array  # [N] per-agent local step counter
    round: jax.Array  # scalar int32 window counter
    last_merge: jax.Array  # [N] int32 window index of last merge (-1 = never)
    n_merges: jax.Array  # [N] int32 total merges per agent


def _agent_select(active: jax.Array, new: PyTree, old: PyTree) -> PyTree:
    """Per-leaf ``where`` over agent-leading leaves (wake-on-event policy)."""

    def sel(a, b):
        mask = active.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.where(mask, a, b)

    return jax.tree.map(sel, new, old)


class GossipEngine:
    """Event-driven gossip runtime behind the Engine protocol.

    The per-window transition is traced ONCE (all windows share static
    shapes: [E_max] edge capacity -> fixed [N, N] W-tilde + [N] mask);
    ``n_traces`` counts retraces so tests can pin the one-jitted-call-per-
    window contract.
    """

    name = "gossip"
    # wake-on-event windows report NaN losses for sleeping agents;
    # Session.round aggregates with nanmean for engines that set this
    loss_nan_is_sentinel = True

    def __init__(self, spec, model, n_agents: int):
        from repro.api.engines import build_optimizer, build_schedule

        inf = spec.inference
        self.n_agents = n_agents
        self.model = model
        self.opt = build_optimizer(inf.optimizer)
        self.init_sigma = inf.init_sigma
        self.shared_init = inf.shared_init
        self.consensus_mode = inf.consensus
        clock_doc = spec.topology.clock or {}
        self.local_policy = clock_doc.get("local_policy", "all")
        if self.local_policy not in ("all", "active"):
            raise ValueError(
                f"unknown gossip local_policy {self.local_policy!r}; "
                "known: all | active"
            )
        lr_schedule = build_schedule(inf.lr, inf.lr_decay)
        nll_fn = model.nll_fn
        n_mc, kl_scale = inf.n_mc_samples, inf.kl_scale
        opt = self.opt
        policy, consensus_mode = self.local_policy, self.consensus_mode
        self.n_traces = 0

        def window_fn(state: GossipState, batches, W, key):
            self.n_traces += 1  # trace-time side effect: retrace telemetry
            nll = make_flat_nll(nll_fn, state.posterior.layout)
            # clock contract: inactive rows of W-tilde are EXACTLY e_i
            active = jnp.diagonal(W) < 1.0
            lr = lr_schedule(state.round)
            prior = state.posterior
            # the SHARED local phase (simulated.network_local_steps): the
            # all-edges-active window is bit-identical to the synchronous
            # round because both runtimes run this exact derivation
            post, opt_state, losses = network_local_steps(
                state.posterior, prior, opt, state.opt_state, nll, batches,
                key, lr, state.step, n_samples=n_mc, kl_scale=kl_scale,
            )
            u = jax.tree.leaves(batches)[0].shape[1]
            if policy == "active":
                # wake-on-event: sleeping agents' local state passes through,
                # and their (discarded) phantom losses must not pollute the
                # loss telemetry — NaN marks "did not train this window"
                # (Session.round aggregates with nanmean)
                post = _agent_select(active, post, state.posterior)
                opt_state = _agent_select(active, opt_state, state.opt_state)
                step = jnp.where(active, state.step + u, state.step)
                losses = jnp.where(active, losses, jnp.nan)
            else:
                step = state.step + u
            if consensus_mode == "gaussian":
                post = consensus_flat_masked(post, W, active)
            elif consensus_mode == "mean_only":
                act = active[:, None]
                post = dataclasses.replace(
                    post,
                    mean=jnp.where(act, W @ post.mean, post.mean),
                    rho=jnp.where(act, W @ post.rho, post.rho),
                )
            merged = active if consensus_mode != "none" else jnp.zeros_like(active)
            new_state = GossipState(
                posterior=post,
                opt_state=opt_state,
                step=step,
                round=state.round + 1,
                last_merge=jnp.where(merged, state.round, state.last_merge),
                n_merges=state.n_merges + merged.astype(jnp.int32),
            )
            return new_state, losses

        self._window = jax.jit(window_fn) if spec.run.jit else window_fn

    # -- Engine protocol -----------------------------------------------------

    def init(self, key: jax.Array) -> GossipState:
        ns = init_network(
            key,
            self.n_agents,
            self.model.init_fn,
            self.opt,
            init_sigma=self.init_sigma,
            shared_init=self.shared_init,
            flat=True,
        )
        return GossipState(
            posterior=ns.posterior,
            opt_state=ns.opt_state,
            step=ns.step,
            round=ns.round,
            last_merge=jnp.full((self.n_agents,), -1, jnp.int32),
            n_merges=jnp.zeros((self.n_agents,), jnp.int32),
        )

    def run_round(self, state, batches, W, key):
        return self._window(state, batches, jnp.asarray(W), key)

    def posterior(self, state) -> FlatPosterior:
        return state.posterior

    # -- telemetry -----------------------------------------------------------

    def staleness(self, state) -> np.ndarray:
        """[N] windows since each agent's last merge (never merged = age of
        the whole run) — the per-agent posterior age the async analyses
        (BayGo; Lalitha et al. 2019) bound."""
        n = int(state.round)
        last = np.asarray(state.last_merge)
        return np.where(last >= 0, (n - 1) - last, n).astype(np.int64)

    def telemetry(self, state) -> dict:
        """Merged into ``Session.evaluate`` output: staleness percentiles +
        merge counts over the run so far."""
        age = self.staleness(state)
        merges = np.asarray(state.n_merges)
        return {
            "staleness": {
                "p50": float(np.percentile(age, 50)),
                "p90": float(np.percentile(age, 90)),
                "max": int(age.max()),
                "mean": float(age.mean()),
            },
            "merges": {
                "per_agent_mean": float(merges.mean()),
                "min": int(merges.min()),
                "total": int(merges.sum()),
            },
            "windows": int(state.round),
        }
