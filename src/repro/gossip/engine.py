"""``GossipEngine`` — the event-driven asynchronous runtime behind
``api.Session``.

One ``run_round`` call executes one EVENT WINDOW (``gossip.clocks``) as ONE
jitted program: per-agent local Bayes-by-Backprop steps, then the masked
active-edge consensus (``core.flat.consensus_flat_masked`` — Pallas
``consensus_fused_masked`` on TPU, masked fused XLA elsewhere).  The
``Engine`` protocol is unchanged — the Session hands the engine the
window's effective W-tilde exactly as it hands the synchronous engines a
scheduled W — so specs, checkpoints, and the round loop all work
untouched.  The activity mask is the clock's host-exact ``window.active``
threaded into the jitted window as an explicit argument — it is NOT
re-derived from the float32-cast W-tilde diagonal, which would silently
drop any fired in-edge below f32 resolution (``1.0 - w`` rounds back to
exactly 1.0 for ``w < 2^-24``, misclassifying an active agent as idle and
skipping its merge — and, under ``local_policy="active"``, its training).

Four window EXECUTIONS, all the same eq.-(6) math (the equivalence
ladder pinned by tests/test_gossip.py — synchronous == instant gossip ==
sharded gossip, bitwise):

* dense masked (default, ``InferenceSpec.consensus_impl="auto"|"masked"``)
  — the whole window inside one jitted call;
* sharded ppermute (``consensus_impl="ppermute"``) — the flat [N, P]
  buffers are block-sharded over the local devices on an ``("agents",)``
  mesh and each window executes as one ``shard_map`` that ppermutes only
  the window's fired shard offsets
  (``launch.consensus_opt.consensus_ppermute_window``; the static
  per-window permutation schedule derives from ``EventWindow.edges``, so
  the local phase still traces once and each distinct window support
  compiles one cached consensus program);
* edge-native segments (``consensus_impl="segments"``, auto-chosen for
  ``kind="sparse"`` topologies driven by a clock) — the window is a
  ``gossip.clocks.SparseWindow`` (fired ``[E_w]`` dst/src/weight arrays +
  the per-agent conserve-rule self-weight vector + the explicit host-exact
  active mask; no ``[N, N]`` anywhere) executed through
  ``core.flat.consensus_flat_segments`` with the self terms folded into
  the segment-sum as N extra self-loop slots.  The only execution that
  runs above ``SPARSE_DENSE_GUARD`` — Watts-Strogatz / Barabási-Albert
  populations at N = 10^4+ gossip with O(E) host work and O(E·P) device
  work per window;
* delivery latency (a ``DelayedClock`` in the spec) — events merge the SRC
  POSTERIOR AS OF FIRE TIME from a bounded ``[K, N, P]`` posterior history
  ring buffer carried in ``GossipState`` (K = max_delay + 1; slot
  ``r mod K`` holds window r's post-local-step, pre-merge posterior, so a
  lag-0 event reads the current value and latency 0 reduces BITWISE to the
  instant-delivery engine).  The consensus is the event-gather
  ``core.flat.consensus_flat_delayed``; the window's static [E_max] event
  arrays ride as traced arguments, so the whole run still traces once.

Two local-step policies (``TopologySpec.clock["local_policy"]``):

* ``"all"`` (default) — every agent trains locally every window and only
  the MERGES are event-driven (the paper's time-varying model: idle agents
  keep learning on local data; ``time_varying_star_schedule`` re-expressed
  as a gossip trace reproduces the table3 runs).
* ``"active"`` — wake-on-event: agents without an incoming activation
  sleep the whole window (posterior, optimizer state and step counter all
  pass through bit-identically) — the fully asynchronous regime where
  staleness is visible in the *local* state too.

Staleness telemetry rides in the state: per-agent window index of the last
merge and total merge count; ``Session.evaluate`` surfaces the percentiles
via ``telemetry``.

Fault tolerance (ROADMAP "Robustness"): a ``"faults"`` entry in the clock
doc attaches a deterministic agent-level fault model (``gossip.faults``) —
Markov crash/recover churn (the clock filters a crashed agent's events, so
its W-tilde row collapses to ``e_i`` and its local state freezes) and
payload corruption (a corrupted agent's WIRE (prec, prec*mu) statistics
are replaced by NaN/Inf/huge garbage at the exchange boundary; resident
state intact).  ``InferenceSpec.fault_policy`` picks the defense:
``"strict"`` trusts the wire verbatim (the undefended baseline — injected
garbage propagates), ``"quarantine"`` validates every incoming
contribution (``core.flat.payload_validity``), drops invalid ones and
reassigns their row mass to self, counting drops per agent in
``GossipState.n_quarantined``.  The fault machinery is structurally gated:
with no fault model and the strict policy the pre-fault window functions
are built verbatim, and the zero-fault quarantined window is bitwise the
strict one (tests/test_faults.py).

Equivalence contract (pinned by tests/test_gossip.py): with an
``all_edges_trace`` clock every window's W-tilde equals the base W bitwise
and every agent is active, so the GossipEngine's posterior trajectory is
BIT-IDENTICAL to ``SimulatedEngine`` on the same spec — the synchronous
runtime is literally the all-edges special case of this one.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flat import (
    FlatPosterior,
    consensus_flat_delayed,
    consensus_flat_delayed_quarantined,
    consensus_flat_masked,
    consensus_flat_masked_quarantined,
    consensus_flat_segments,
    consensus_flat_segments_quarantined,
    make_flat_nll,
)
from repro.core.numerics import canonical_wire_dtype, wire_dtype_name
from repro.core.simulated import init_network, network_local_steps
from repro.gossip.clocks import SparseClock, SparseWindow

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GossipState:
    """Network state + per-agent gossip telemetry (all leaves agent-leading,
    checkpointed leaf-wise like every engine state).

    ``hist_mean`` / ``hist_rho`` are the delivery-latency history ring
    buffers ([K, N, P]; slot ``r mod K`` = window r's post-local-step,
    pre-merge posterior).  Instant-delivery clocks carry ``None`` — an
    EMPTY pytree subtree, so their state flattens to exactly the pre-
    latency leaf structure and old gossip checkpoints keep loading.
    ``n_quarantined`` (fault_policy="quarantine" only, else ``None`` — the
    same empty-subtree trick) counts, per agent, the incoming consensus
    contributions dropped by the exchange-boundary validity guard."""

    posterior: FlatPosterior
    opt_state: Any
    step: jax.Array  # [N] per-agent local step counter
    round: jax.Array  # scalar int32 window counter
    last_merge: jax.Array  # [N] int32 window index of last merge (-1 = never)
    n_merges: jax.Array  # [N] int32 total merges per agent
    hist_mean: Any  # [K, N, P] stale-posterior ring buffer; None if instant
    hist_rho: Any  # [K, N, P] or None
    n_quarantined: Any = None  # [N] int32 dropped contributions; None if strict


def _agent_select(active: jax.Array, new: PyTree, old: PyTree) -> PyTree:
    """Per-leaf ``where`` over agent-leading leaves (wake-on-event policy)."""

    def sel(a, b):
        mask = active.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.where(mask, a, b)

    return jax.tree.map(sel, new, old)


def _largest_divisor_leq(n: int, cap: int) -> int:
    for s in range(min(n, cap), 0, -1):
        if n % s == 0:
            return s
    return 1


_NO_SPAN = contextlib.nullcontext()


def _span(obs, name: str, **attrs):
    """A tracer span when an ``Observability`` is attached, else a shared
    no-op context — the uninstrumented path pays one ``is None`` check."""
    return obs.tracer.span(name, **attrs) if obs is not None else _NO_SPAN


class GossipEngine:
    """Event-driven gossip runtime behind the Engine protocol.

    The per-window transition is traced ONCE (all windows share static
    shapes: [E_max] edge capacity -> fixed [N, N] W-tilde + [N] mask + the
    delayed path's [E_max] event arrays); ``n_traces`` counts retraces so
    tests can pin the one-jitted-call-per-window contract.  (The sharded
    ppermute consensus additionally compiles one cached program per
    distinct window support — see ``consensus_ppermute_window``.)
    """

    name = "gossip"
    # wake-on-event windows report NaN losses for sleeping agents;
    # Session.round aggregates NaN-safely for engines that set this
    loss_nan_is_sentinel = True
    # the Session must hand run_round the w_schedule value VERBATIM (host
    # float64 w_eff, or a SparseWindow object) — a jnp.asarray at the
    # Session boundary would round to f32 and destroy both the exact
    # active-mask lookup and the float64 schedule-identity check; the
    # engine casts to the device itself, after the host-side work
    wants_host_w = True

    def __init__(self, spec, model, n_agents: int):
        from repro.api.engines import build_optimizer, build_schedule

        inf = spec.inference
        self.n_agents = n_agents
        self.model = model
        self.opt = build_optimizer(inf.optimizer)
        self.init_sigma = inf.init_sigma
        self.shared_init = inf.shared_init
        self.consensus_mode = inf.consensus
        clock_doc = spec.topology.clock or {}
        self.local_policy = clock_doc.get("local_policy", "all")
        if self.local_policy not in ("all", "active"):
            raise ValueError(
                f"unknown gossip local_policy {self.local_policy!r}; "
                "known: all | active"
            )
        self.clock = spec.topology.gossip_clock()
        # agent-level fault model (gossip.faults), attached by build_clock
        # from the clock doc's top-level "faults" entry; None = no churn or
        # corruption.  fault_policy picks the consensus defense.
        self.faults = getattr(self.clock, "faults", None)
        self.fault_policy = inf.fault_policy
        self.quarantine = inf.fault_policy == "quarantine"
        if (self.faults is not None
                and self.faults.spec.corrupt_rate > 0.0
                and self.consensus_mode != "gaussian"):
            raise ValueError(
                "payload corruption targets the gaussian (prec, prec*mu) "
                f"exchange; consensus={self.consensus_mode!r} exchanges no "
                "such payload (drop corrupt_rate or use gaussian consensus)"
            )
        self.max_delay = int(getattr(self.clock, "max_delay", 0))
        self.hist_slots = self.max_delay + 1 if self.max_delay > 0 else 0
        if self.max_delay > 0 and self.consensus_mode == "mean_only":
            raise ValueError(
                "delivery-latency gossip implements gaussian/none consensus; "
                "mean_only (the FedAvg baseline) runs on instant delivery"
            )
        # wire precision of the consensus exchange (ROADMAP "Wire
        # precision"): "f32" is the bitwise-uncompressed default
        self.wire_dtype = inf.wire_dtype
        # resident dtype of the [K, N, P] delivery-latency history ring
        # (bf16 halves its HBM footprint; gathered rows decode to fp32)
        if inf.history_dtype is not None and not self.hist_slots:
            raise ValueError(
                "history_dtype sizes the delivery-latency posterior "
                "history ring; this clock has no delay (wrap it in "
                '{"kind": "delayed", ...} or drop history_dtype)'
            )
        self.hist_dtype = canonical_wire_dtype(inf.history_dtype)
        from repro.api.spec import SPARSE_DENSE_GUARD

        impl = inf.consensus_impl
        sparse_clock = isinstance(self.clock, SparseClock)
        if impl == "auto":
            impl = "segments" if sparse_clock else "masked"
        self.consensus_impl = impl
        if impl == "segments":
            if not sparse_clock:
                raise ValueError(
                    "consensus_impl='segments' executes edge-native "
                    "SparseWindows; this topology's clock emits dense "
                    "EventWindows (use TopologySpec kind='sparse' with a "
                    "clock doc, or consensus_impl='masked')"
                )
            if self.consensus_mode == "mean_only":
                raise ValueError(
                    "consensus_impl='segments' implements gaussian/none "
                    "consensus; mean_only (the FedAvg baseline) runs on "
                    "the dense masked path"
                )
        elif sparse_clock:
            # dense view of a sparse clock: legal below the guard (the
            # segments-vs-masked equivalence ladder trains on exactly this),
            # eagerly rejected above it — SparseWindow.w_eff would raise on
            # the first window anyway, but fail at build time with the fix
            if self.consensus_impl == "ppermute":
                raise ValueError(
                    "consensus_impl='ppermute' shards dense EventWindows "
                    "by their static edge schedule; a sparse clock emits "
                    "edge-native SparseWindows (use 'segments', or "
                    "'masked' below the dense guard)"
                )
            if n_agents > SPARSE_DENSE_GUARD:
                raise ValueError(
                    "consensus_impl='masked' materializes the dense "
                    f"[N, N] window view; N={n_agents} is above "
                    f"SPARSE_DENSE_GUARD={SPARSE_DENSE_GUARD} "
                    "(use consensus_impl='segments')"
                )
        self._mesh = None
        if self.consensus_impl == "ppermute":
            if self.max_delay > 0:
                raise ValueError(
                    "consensus_impl='ppermute' implements instant delivery; "
                    "a DelayedClock runs the history-gather path (drop the "
                    "latency wrapper or use consensus_impl='masked')"
                )
            devices = jax.devices()
            shards = inf.consensus_shards
            if shards is None:
                shards = _largest_divisor_leq(n_agents, len(devices))
            if shards > len(devices):
                raise ValueError(
                    f"consensus_shards={shards} exceeds the {len(devices)} "
                    "local devices"
                )
            if n_agents % shards:
                raise ValueError(
                    f"consensus_shards={shards} must divide "
                    f"n_agents={n_agents}"
                )
            self.n_shards = shards
            self._mesh = jax.sharding.Mesh(
                np.asarray(devices[:shards]), ("agents",)
            )
        lr_schedule = build_schedule(inf.lr, inf.lr_decay)
        nll_fn = model.nll_fn
        n_mc, kl_scale = inf.n_mc_samples, inf.kl_scale
        opt = self.opt
        policy, consensus_mode = self.local_policy, self.consensus_mode
        hist_slots = self.hist_slots
        wire_dtype, hist_dtype = self.wire_dtype, self.hist_dtype
        merge_in_jit = self.consensus_impl != "ppermute"
        quarantine = self.quarantine
        # structural gate: with no fault model and the strict policy the
        # ORIGINAL window functions are built verbatim — the fault machinery
        # adds zero ops (and zero trace changes) to existing runs
        self._guarded = guarded = self.quarantine or self.faults is not None
        self.n_traces = 0
        # host-side observability hook (repro.obs.Observability), attached
        # by build_session when ObsSpec is enabled; never touches the jitted
        # window — spans/counters record at the dispatch boundary only
        self.obs = None

        def local_phase(state: GossipState, batches, active, key, up=None):
            """Shared pre-consensus window phase: per-agent local VI steps +
            the wake-on-event policy select + staleness bookkeeping inputs.
            Identical (bitwise) across all four window executions.

            ``active`` is the clock's HOST-EXACT [N] bool mask, threaded in
            as a traced argument (``run_round._host_active``) — never
            re-derived from the float32-cast W-tilde diagonal, where a
            fired in-edge with weight < 2^-24 rounds the diagonal back to
            exactly 1.0 and silently drops the agent's merge."""
            self.n_traces += 1  # trace-time side effect: retrace telemetry
            nll = make_flat_nll(nll_fn, state.posterior.layout)
            active = active > 0
            lr = lr_schedule(state.round)
            prior = state.posterior
            # the SHARED local phase (simulated.network_local_steps): the
            # all-edges-active window is bit-identical to the synchronous
            # round because both runtimes run this exact derivation
            post, opt_state, losses = network_local_steps(
                state.posterior, prior, opt, state.opt_state, nll, batches,
                key, lr, state.step, n_samples=n_mc, kl_scale=kl_scale,
            )
            u = jax.tree.leaves(batches)[0].shape[1]
            if up is not None:
                # fault-aware (guarded windows only): crashed agents freeze —
                # no local training, no merge, NaN loss ("did not train").
                # With up all-True every select is where(True, x, .), so the
                # zero-fault guarded window stays value-identical to the
                # unguarded one (the bitwise ladder in tests/test_faults.py).
                train = (active & up) if policy == "active" else up
                post = _agent_select(train, post, state.posterior)
                opt_state = _agent_select(train, opt_state, state.opt_state)
                step = jnp.where(train, state.step + u, state.step)
                losses = jnp.where(train, losses, jnp.nan)
                active = active & up
            elif policy == "active":
                # wake-on-event: sleeping agents' local state passes through,
                # and their (discarded) phantom losses must not pollute the
                # loss telemetry — NaN marks "did not train this window"
                # (Session.round aggregates NaN-safely and reports n_trained)
                post = _agent_select(active, post, state.posterior)
                opt_state = _agent_select(active, opt_state, state.opt_state)
                step = jnp.where(active, state.step + u, state.step)
                losses = jnp.where(active, losses, jnp.nan)
            else:
                step = state.step + u
            return post, opt_state, step, active, losses

        def finish(state, post, opt_state, step, active):
            merged = active if consensus_mode != "none" else jnp.zeros_like(active)
            return dataclasses.replace(
                state,
                posterior=post,
                opt_state=opt_state,
                step=step,
                round=state.round + 1,
                last_merge=jnp.where(merged, state.round, state.last_merge),
                n_merges=state.n_merges + merged.astype(jnp.int32),
            )

        def window_fn(state: GossipState, batches, W, active, key):
            post, opt_state, step, active, losses = local_phase(
                state, batches, active, key
            )
            if consensus_mode == "gaussian" and merge_in_jit:
                post = consensus_flat_masked(
                    post, W, active, wire_dtype=wire_dtype
                )
            elif consensus_mode == "mean_only":
                act = active[:, None]
                post = dataclasses.replace(
                    post,
                    mean=jnp.where(act, W @ post.mean, post.mean),
                    rho=jnp.where(act, W @ post.rho, post.rho),
                )
            return finish(state, post, opt_state, step, active), losses

        def window_fn_delayed(
            state: GossipState, batches, W, active, key, edges, weights, lags
        ):
            post, opt_state, step, active, losses = local_phase(
                state, batches, active, key
            )
            # record this window's post-local, PRE-merge posterior in its
            # ring slot FIRST: a lag-0 event then gathers the current value,
            # which is exactly what instant delivery merges
            slot = jnp.mod(state.round, hist_slots)
            # the ring may be resident in a narrower dtype (history_dtype);
            # astype is a no-op at the fp32 default
            hist_mean = jax.lax.dynamic_update_index_in_dim(
                state.hist_mean, post.mean.astype(hist_dtype), slot, 0
            )
            hist_rho = jax.lax.dynamic_update_index_in_dim(
                state.hist_rho, post.rho.astype(hist_dtype), slot, 0
            )
            if consensus_mode == "gaussian":
                post = consensus_flat_delayed(
                    post, W, active, edges, weights, lags,
                    hist_mean, hist_rho, state.round,
                    wire_dtype=wire_dtype,
                )
            new_state = finish(state, post, opt_state, step, active)
            return dataclasses.replace(
                new_state, hist_mean=hist_mean, hist_rho=hist_rho
            ), losses

        def window_fn_guarded(
            state: GossipState, batches, W, active, key, up, corrupt,
            fill_mean, fill_rho,
        ):
            """Fault-aware instant window.  ``up`` gates local training
            (crashed agents freeze; the clock already rewired their W-tilde
            rows to e_i), ``corrupt`` + fills replace the corrupted agents'
            WIRE payloads at the exchange boundary (resident state intact);
            ``quarantine`` swaps in the validated consensus.  All-up /
            no-corruption inputs make every extra op a value-identity, so
            the zero-fault guarded trajectory is bitwise the strict one."""
            post, opt_state, step, active, losses = local_phase(
                state, batches, active, key, up
            )
            n_q = state.n_quarantined
            if consensus_mode == "gaussian" and merge_in_jit:
                c = corrupt[:, None]
                mean_src = jnp.where(c, fill_mean[:, None], post.mean)
                rho_src = jnp.where(c, fill_rho[:, None], post.rho)
                if quarantine:
                    post, valid_src = consensus_flat_masked_quarantined(
                        post, W, active,
                        mean_src=mean_src, rho_src=rho_src,
                        wire_dtype=wire_dtype,
                    )
                    n_q = n_q + (~valid_src).astype(jnp.int32)
                else:
                    # strict: the wire buffer is trusted verbatim, so the
                    # injected garbage reaches every receiving agent (the
                    # undefended baseline); only the exchange is poisoned —
                    # non-merging agents keep their true resident state
                    merged = consensus_flat_masked(
                        dataclasses.replace(post, mean=mean_src, rho=rho_src),
                        W, active, wire_dtype=wire_dtype,
                    )
                    act = active[:, None]
                    post = dataclasses.replace(
                        post,
                        mean=jnp.where(act, merged.mean, post.mean),
                        rho=jnp.where(act, merged.rho, post.rho),
                    )
            elif consensus_mode == "mean_only":
                act = active[:, None]
                post = dataclasses.replace(
                    post,
                    mean=jnp.where(act, W @ post.mean, post.mean),
                    rho=jnp.where(act, W @ post.rho, post.rho),
                )
            new_state = finish(state, post, opt_state, step, active)
            return dataclasses.replace(new_state, n_quarantined=n_q), losses

        def window_fn_delayed_guarded(
            state: GossipState, batches, W, active, key, edges, weights,
            lags, up, corrupt, fill_mean, fill_rho,
        ):
            """Fault-aware delayed window: corruption applies at DELIVERY
            time by source id (every event gathered FROM a corrupted agent
            this window reads garbage, whatever its fire time); the history
            ring always records the TRUE resident posterior."""
            post, opt_state, step, active, losses = local_phase(
                state, batches, active, key, up
            )
            slot = jnp.mod(state.round, hist_slots)
            hist_mean = jax.lax.dynamic_update_index_in_dim(
                state.hist_mean, post.mean.astype(hist_dtype), slot, 0
            )
            hist_rho = jax.lax.dynamic_update_index_in_dim(
                state.hist_rho, post.rho.astype(hist_dtype), slot, 0
            )
            n_q = state.n_quarantined
            if consensus_mode == "gaussian":
                if quarantine:
                    post, valid_e = consensus_flat_delayed_quarantined(
                        post, W, active, edges, weights, lags,
                        hist_mean, hist_rho, state.round,
                        corrupt=corrupt, fill_mean=fill_mean,
                        fill_rho=fill_rho, wire_dtype=wire_dtype,
                    )
                    # count only REAL dropped events — [E_max] padding rows
                    # carry zero weight and must not inflate the telemetry
                    bad = ((~valid_e) & (weights > 0.0)).astype(jnp.int32)
                    n_q = n_q.at[edges[:, 0]].add(bad)
                else:
                    # strict: poison the gathered copies (by src id, every
                    # ring slot) — the state's ring keeps the true values
                    c = corrupt[None, :, None]
                    hm = jnp.where(
                        c, fill_mean.astype(hist_mean.dtype)[None, :, None],
                        hist_mean,
                    )
                    hr = jnp.where(
                        c, fill_rho.astype(hist_rho.dtype)[None, :, None],
                        hist_rho,
                    )
                    post = consensus_flat_delayed(
                        post, W, active, edges, weights, lags,
                        hm, hr, state.round, wire_dtype=wire_dtype,
                    )
            new_state = finish(state, post, opt_state, step, active)
            return dataclasses.replace(
                new_state, hist_mean=hist_mean, hist_rho=hist_rho,
                n_quarantined=n_q,
            ), losses

        def _self_loops(dst, src, w_e, w_self):
            """Fold the conserve-rule self terms into the edge list as N
            trailing self-loop slots — ``consensus_flat_segments``' contract
            is that self-loops ride IN the [E] arrays."""
            ar = jnp.arange(w_self.shape[0], dtype=dst.dtype)
            return (jnp.concatenate([dst, ar]), jnp.concatenate([src, ar]),
                    jnp.concatenate([w_e, w_self]))

        def window_fn_segments(
            state: GossipState, batches, dst, src, w_e, w_self, active, key
        ):
            """Edge-native window: [E_max] fired dst/src/weight arrays +
            [N] self-weights + the host-exact active mask ride as traced
            arguments (static shapes — one trace for the whole run); no
            [N, N] is ever materialized, host or device."""
            post, opt_state, step, active, losses = local_phase(
                state, batches, active, key
            )
            if consensus_mode == "gaussian":
                d_all, s_all, w_all = _self_loops(dst, src, w_e, w_self)
                post = consensus_flat_segments(
                    post, d_all, s_all, w_all,
                    active=active, wire_dtype=wire_dtype,
                )
            return finish(state, post, opt_state, step, active), losses

        def window_fn_segments_guarded(
            state: GossipState, batches, dst, src, w_e, w_self, active,
            key, up, corrupt, fill_mean, fill_rho,
        ):
            """Fault-aware edge-native window.  The clock already filtered
            crashed agents' fired edges (``faults.edge_keep_mask``), so
            ``up`` only gates local training; quarantine validates every
            fired edge's wire payload and moves dropped in-edge mass to the
            dst's self term.  All-up / no-corruption inputs reduce to the
            unguarded call bitwise (the same equivalence-ladder rung the
            dense guarded windows pin)."""
            post, opt_state, step, active, losses = local_phase(
                state, batches, active, key, up
            )
            n_q = state.n_quarantined
            if consensus_mode == "gaussian":
                c = corrupt[:, None]
                mean_src = jnp.where(c, fill_mean[:, None], post.mean)
                rho_src = jnp.where(c, fill_rho[:, None], post.rho)
                if quarantine:
                    post, valid_e = consensus_flat_segments_quarantined(
                        post, dst, src, w_e, w_self, active=active,
                        mean_src=mean_src, rho_src=rho_src,
                        wire_dtype=wire_dtype,
                    )
                    # count only REAL dropped edges — [E_max] padding slots
                    # carry zero weight and must not inflate the telemetry
                    bad = ((~valid_e) & (w_e > 0.0)).astype(jnp.int32)
                    n_q = n_q.at[dst].add(bad)
                else:
                    # strict: the wire is trusted verbatim — the corrupted
                    # sources' garbage reaches every receiving agent
                    d_all, s_all, w_all = _self_loops(dst, src, w_e, w_self)
                    merged = consensus_flat_segments(
                        dataclasses.replace(post, mean=mean_src, rho=rho_src),
                        d_all, s_all, w_all,
                        active=active, wire_dtype=wire_dtype,
                    )
                    act = active[:, None]
                    post = dataclasses.replace(
                        post,
                        mean=jnp.where(act, merged.mean, post.mean),
                        rho=jnp.where(act, merged.rho, post.rho),
                    )
            new_state = finish(state, post, opt_state, step, active)
            return dataclasses.replace(new_state, n_quarantined=n_q), losses

        if self.consensus_impl == "segments":
            fn = window_fn_segments_guarded if guarded else window_fn_segments
        elif guarded:
            fn = window_fn_delayed_guarded if self.hist_slots else window_fn_guarded
        else:
            fn = window_fn_delayed if self.hist_slots else window_fn
        self._window = jax.jit(fn) if spec.run.jit else fn

    # -- Engine protocol -----------------------------------------------------

    def init(self, key: jax.Array) -> GossipState:
        ns = init_network(
            key,
            self.n_agents,
            self.model.init_fn,
            self.opt,
            init_sigma=self.init_sigma,
            shared_init=self.shared_init,
            flat=True,
        )
        hist_shape = (self.hist_slots,) + tuple(ns.posterior.mean.shape)
        return GossipState(
            posterior=ns.posterior,
            opt_state=ns.opt_state,
            step=ns.step,
            round=ns.round,
            last_merge=jnp.full((self.n_agents,), -1, jnp.int32),
            n_merges=jnp.zeros((self.n_agents,), jnp.int32),
            # zero-init is safe — never read before their window is written
            # (window r only gathers slots of windows >= max(0, r -
            # max_delay)); None (empty subtree) when there is no latency so
            # the leaf structure matches pre-latency gossip checkpoints.
            # Resident dtype is history_dtype (fp32 default; bf16 halves
            # the ring's HBM footprint).
            hist_mean=(jnp.zeros(hist_shape, self.hist_dtype)
                       if self.hist_slots else None),
            hist_rho=(jnp.zeros(hist_shape, self.hist_dtype)
                      if self.hist_slots else None),
            # None (empty subtree) under fault_policy="strict" so strict
            # states keep the exact pre-fault leaf structure
            n_quarantined=(jnp.zeros((self.n_agents,), jnp.int32)
                           if self.quarantine else None),
        )

    def _window_for(self, state, W):
        """The engine-side EventWindow for this round — the delayed and
        sharded paths need the static event/edge structure, which the
        Session's W-tilde alone does not carry.  Regenerated from the spec
        clock (windows are pure functions of (seed, round), so this matches
        the Session's stream bitwise — verified here), which also means
        per-round ``W`` overrides cannot be used with these paths."""
        r = int(state.round)
        win = self.clock.window(r)
        # compare in float64 — both sides' native precision.  An f32
        # comparison would false-accept any foreign schedule that merely
        # COLLIDES with the stream at f32 (e.g. weights differing by less
        # than one f32 ulp) and then silently merge with the stream's
        # event structure instead of the caller's.
        if not np.array_equal(
            np.asarray(W, np.float64), np.asarray(win.w_eff, np.float64)
        ):
            raise ValueError(
                "delayed/sharded gossip windows come from the spec clock; "
                f"the W passed for window {r} does not match its stream "
                "(per-round w_schedule overrides are unsupported on these "
                "paths)"
            )
        return win

    def _fault_arrays(self, r: int):
        """Host-side per-window fault draws (pure functions of (seed, r) —
        a resumed session regenerates the identical stream).  Also records
        ``last_crashed`` for ``Session.round``'s n_crashed telemetry."""
        n = self.n_agents
        if self.faults is None:
            up = np.ones(n, dtype=bool)
            corrupt = np.zeros(n, dtype=bool)
            fm = np.zeros(n, np.float32)
            fr = np.zeros(n, np.float32)
        else:
            up = self.faults.up(r)
            corrupt = self.faults.corrupted(r)
            fm, fr = self.faults.fills(r)
        self.last_crashed = ~up
        return (jnp.asarray(up), jnp.asarray(corrupt),
                jnp.asarray(fm), jnp.asarray(fr))

    def _host_active(self, r: int, W, win=None):
        """The HOST-EXACT [N] activity mask for window ``r`` (the headline
        mask fix): when ``W`` is the spec clock's own w_eff (the Session
        passes it verbatim — ``wants_host_w``), thread the clock's
        ``window.active`` through; only a FOREIGN per-round W override (or
        a direct ``run_round`` call with a device array) falls back to the
        diagonal derivation — computed in float64, never on the f32 cast."""
        w64 = np.asarray(W, np.float64)
        if win is None and isinstance(W, np.ndarray) \
                and W.dtype == np.float64:
            # only consult the clock for host float64 W — what the Session
            # hands over verbatim; device arrays are foreign by definition
            win = self.clock.window(r)
        if (win is not None and not isinstance(win, SparseWindow)
                and np.array_equal(w64, np.asarray(win.w_eff, np.float64))):
            return np.asarray(win.active)
        return np.diagonal(w64) < 1.0

    def _segments_round(self, state, batches, W, key, obs, r):
        """Edge-native window execution: no [N, N] is built on the host or
        traced on the device — the fired [E_max] arrays, [N] self-weights
        and [N] active mask are the whole exchange structure."""
        if not isinstance(W, SparseWindow):
            raise ValueError(
                "consensus_impl='segments' executes the spec clock's "
                "SparseWindow stream; run_round received an array-like W "
                "(per-round dense w_schedule overrides are unsupported — "
                "the Session's w_schedule yields the windows verbatim)"
            )
        if int(W.index) != r:
            raise ValueError(
                f"SparseWindow index {int(W.index)} does not match the "
                f"engine round {r} (windows are pure functions of "
                "(seed, round); the stream must be consumed in order)"
            )
        with _span(obs, "gossip.window_build", round=r):
            extra = self._fault_arrays(r) if self._guarded else ()
            args = (
                jnp.asarray(W.dst), jnp.asarray(W.src),
                jnp.asarray(W.weights),
                jnp.asarray(W.self_weight, dtype=jnp.float32),
                jnp.asarray(W.active),
            )
        with _span(obs, "gossip.window", impl="segments", round=r):
            out = self._window(state, batches, *args, key, *extra)
        self._obs_after_window(obs)
        return out

    def run_round(self, state, batches, W, key):
        obs = self.obs
        r = int(state.round)
        if self.consensus_impl == "segments":
            return self._segments_round(state, batches, W, key, obs, r)
        spec_win = None
        if isinstance(W, SparseWindow):
            # dense view of an edge-native window (below the guard only) —
            # the segments-vs-masked equivalence ladder runs on this
            spec_win, W = W, W.w_eff
        ppermute = (self.consensus_impl == "ppermute"
                    and self.consensus_mode == "gaussian")
        with _span(obs, "gossip.window_build", round=r):
            extra = self._fault_arrays(r) if self._guarded else ()
            win = (self._window_for(state, W)
                   if (self.hist_slots or ppermute) else None)
            active = (np.asarray(spec_win.active) if spec_win is not None
                      else self._host_active(r, W, win))
        W = jnp.asarray(W)
        act = jnp.asarray(active)
        if self.hist_slots:
            # ONE fused jitted call: local phase + event-gather consensus
            # (dispatch-side wall clock; Session.round owns the synced span)
            with _span(obs, "gossip.window", impl="delayed", round=r):
                out = self._window(
                    state, batches, W, act, key,
                    jnp.asarray(win.edges), jnp.asarray(win.weights),
                    jnp.asarray(win.delays), *extra,
                )
            self._obs_after_window(obs)
            return out
        if ppermute:
            with _span(obs, "gossip.local_phase", impl="ppermute", round=r):
                state, losses = self._window(
                    state, batches, W, act, key, *extra
                )
            with _span(obs, "gossip.consensus", impl="ppermute", round=r):
                state, losses = self._ppermute_consensus(
                    state, losses, W, win, extra
                )
            self._obs_after_window(obs)
            return state, losses
        # dense masked path: local phase + consensus fused in one call
        with _span(obs, "gossip.window", impl="masked", round=r):
            out = self._window(state, batches, W, act, key, *extra)
        self._obs_after_window(obs)
        return out

    def _obs_after_window(self, obs) -> None:
        """Registry bookkeeping after one window (host-side, pure observer)."""
        if obs is None:
            return
        obs.registry.counter(
            "gossip.windows", "event windows executed"
        ).inc()
        obs.registry.gauge(
            "gossip.jit_traces", "distinct window traces (retrace telemetry)"
        ).set(self.n_traces)

    def _ppermute_consensus(self, state, losses, W, win, extra):
        """The host-level sharded consensus dispatch (the one window
        execution whose consensus is a separate program from the local
        phase — which is why it gets its own span in ``run_round``)."""
        post = state.posterior
        if not self._guarded:
            post = consensus_flat_masked(
                post, W, jnp.asarray(win.active),
                mode="ppermute", mesh=self._mesh, axis="agents",
                window=win, wire_dtype=self.wire_dtype,
            )
            return dataclasses.replace(state, posterior=post), losses
        up, corrupt, fm, fr = extra
        c = corrupt[:, None]
        mean_src = jnp.where(c, fm[:, None], post.mean)
        rho_src = jnp.where(c, fr[:, None], post.rho)
        active = jnp.asarray(win.active)
        if self.quarantine:
            post, valid_src = consensus_flat_masked_quarantined(
                post, W, active, mean_src=mean_src, rho_src=rho_src,
                mode="ppermute", mesh=self._mesh, axis="agents",
                window=win, wire_dtype=self.wire_dtype,
            )
            state = dataclasses.replace(
                state, posterior=post,
                n_quarantined=(state.n_quarantined
                               + (~valid_src).astype(jnp.int32)),
            )
        else:
            merged = consensus_flat_masked(
                dataclasses.replace(post, mean=mean_src, rho=rho_src),
                W, active, mode="ppermute", mesh=self._mesh,
                axis="agents", window=win, wire_dtype=self.wire_dtype,
            )
            act = active[:, None]
            post = dataclasses.replace(
                post,
                mean=jnp.where(act, merged.mean, post.mean),
                rho=jnp.where(act, merged.rho, post.rho),
            )
            state = dataclasses.replace(state, posterior=post)
        return state, losses

    def posterior(self, state) -> FlatPosterior:
        return state.posterior

    # -- telemetry -----------------------------------------------------------

    def staleness(self, state) -> np.ndarray:
        """[N] windows since each agent's last merge (never merged = age of
        the whole run) — the per-agent posterior age the async analyses
        (BayGo; Lalitha et al. 2019) bound."""
        n = int(state.round)
        last = np.asarray(state.last_merge)
        return np.where(last >= 0, (n - 1) - last, n).astype(np.int64)

    def telemetry(self, state) -> dict:
        """Merged into ``Session.evaluate`` output: staleness percentiles +
        merge counts over the run so far (plus the delivery-latency depth
        and shard count when those paths are active)."""
        age = self.staleness(state)
        merges = np.asarray(state.n_merges)
        out = {
            "staleness": {
                "p50": float(np.percentile(age, 50)),
                "p90": float(np.percentile(age, 90)),
                "max": int(age.max()),
                "mean": float(age.mean()),
            },
            "merges": {
                "per_agent_mean": float(merges.mean()),
                "min": int(merges.min()),
                "total": int(merges.sum()),
            },
            "windows": int(state.round),
        }
        if self.max_delay:
            out["max_delay"] = self.max_delay
        if self._mesh is not None:
            out["consensus_shards"] = self.n_shards
        if self.wire_dtype != "f32":
            out["wire_dtype"] = self.wire_dtype
        if self.hist_slots and wire_dtype_name(self.hist_dtype) != "f32":
            out["history_dtype"] = wire_dtype_name(self.hist_dtype)
        if self._guarded:
            nw = int(state.round)
            faults: dict = {"policy": self.fault_policy}
            if self.faults is not None:
                uptime = self.faults.uptime(nw)
                faults["uptime"] = {
                    "per_agent": [int(v) for v in uptime],
                    "frac_mean": (float(uptime.mean()) / nw if nw else 1.0),
                    "min": int(uptime.min()) if nw else 0,
                }
                faults["currently_down"] = (
                    int(self.faults.crashed(nw - 1).sum()) if nw else 0
                )
            if getattr(state, "n_quarantined", None) is not None:
                nq = np.asarray(state.n_quarantined)
                faults["quarantined"] = {
                    "per_agent": [int(v) for v in nq],
                    "total": int(nq.sum()),
                }
            out["faults"] = faults
        return out

    def snapshot_meta(self, state) -> dict:
        """The gossip provenance a serving snapshot carries (ROADMAP
        "Serving"): the window index, staleness percentiles, merge counts
        and quarantine totals AT PUBLISH TIME — the raw material of the
        serving tier's bounded-staleness SLO
        (``serve.PredictiveServer(max_staleness=k)``).  Plain data,
        checkpoint-embeddable next to the snapshot buffers."""
        age = self.staleness(state)
        merges = np.asarray(state.n_merges)
        meta = {
            "window": int(state.round),
            "staleness": {
                "p50": float(np.percentile(age, 50)),
                "p90": float(np.percentile(age, 90)),
                "max": int(age.max()),
            },
            "merges_total": int(merges.sum()),
        }
        if getattr(state, "n_quarantined", None) is not None:
            meta["quarantined_total"] = int(
                np.asarray(state.n_quarantined).sum()
            )
        return meta
