"""msgpack-based pytree checkpointing (orbax/flax are not available offline).

Arrays are serialized as (dtype, shape, raw bytes) with zstd compression
(zlib fallback when the ``zstandard`` wheel is absent — the reader sniffs
the frame magic, so either build restores both formats it can decode);
the pytree structure is serialized as a nested msgpack document.  Restore
optionally re-shards onto a ``jax.sharding.NamedSharding`` tree via
``jax.device_put`` (production path), or returns numpy arrays (host path).

``FlatPosterior`` checkpoints (``save_flat_posterior``) are
self-describing: the layout doc (leaf paths/shapes/dtypes/offsets) rides in
the document, so restore needs no ``like`` tree and hands back the exact
[N, P] buffers — no flatten/unflatten round-trip on the save/restore path.

``CheckpointManager`` adds step-numbered directories, retention, and an
atomic-rename commit protocol so a preempted writer never leaves a corrupt
latest checkpoint.
"""
from __future__ import annotations

import os
import shutil
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional: not in every offline image
    import zstandard
except ImportError:  # pragma: no cover - depends on the container
    zstandard = None

PyTree = Any

_ARR = "__arr__"
_SCALAR = "__scalar__"
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(raw: bytes, level: int) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=level).compress(raw)
    return zlib.compress(raw, level)


def _decompress(comp: bytes) -> bytes:
    if comp[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                "checkpoint is zstd-compressed but the zstandard module is "
                "not installed in this environment"
            )
        return zstandard.ZstdDecompressor().decompress(comp)
    return zlib.decompress(comp)


def _pack_leaf(leaf):
    if isinstance(leaf, (jax.Array, np.ndarray)):
        arr = np.asarray(leaf)
        # extension dtypes (bfloat16 and friends) have a lossy numpy byte
        # string ('<V2'): store the NAME, which jnp.dtype round-trips — the
        # bf16-resident gossip history ring checkpoints through here
        dt = arr.dtype
        return {
            _ARR: True,
            "dtype": dt.name if dt.kind == "V" else dt.str,
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    if isinstance(leaf, (int, float, bool, str)) or leaf is None:
        return {_SCALAR: True, "value": leaf}
    raise TypeError(f"unsupported checkpoint leaf type {type(leaf)}")


def _leaf_dtype(tag: str) -> np.dtype:
    """Decode a packed dtype tag: numpy byte strings directly, extension
    dtype NAMES (e.g. 'bfloat16') through jnp.dtype."""
    dt = np.dtype(tag) if not tag[:1].isalpha() else None
    if dt is not None and dt.kind != "V":
        return dt
    return jnp.dtype(tag)


def _unpack_leaf(doc):
    if isinstance(doc, dict) and doc.get(_ARR):
        return np.frombuffer(doc["data"], dtype=_leaf_dtype(doc["dtype"])).reshape(
            doc["shape"]
        )
    if isinstance(doc, dict) and doc.get(_SCALAR):
        return doc["value"]
    return doc


def save_pytree(path: str, tree: PyTree, compress_level: int = 3) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    doc = {
        "treedef": str(treedef),
        "leaves": [_pack_leaf(l) for l in leaves],
    }
    _write_doc(path, doc, compress_level)


def _write_doc(path: str, doc: dict, compress_level: int = 3) -> None:
    raw = msgpack.packb(doc, use_bin_type=True)
    comp = _compress(raw, compress_level)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(comp)
    os.replace(tmp, path)  # atomic commit


def _read_doc(path: str) -> dict:
    with open(path, "rb") as f:
        raw = _decompress(f.read())
    return msgpack.unpackb(raw, raw=False)


def restore_leaf(stored, ref, shard=None):
    """Restore ONE stored leaf into the shape/dtype of reference leaf
    ``ref`` (shared by ``restore_pytree`` and ``api.Session.load`` so there
    is a single restore semantics).  Non-array references pass the stored
    value through; ``shard`` optionally device_puts the result."""
    if isinstance(ref, (jax.Array, np.ndarray, jnp.ndarray)):
        arr = np.asarray(stored)
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"shape mismatch: {arr.shape} vs {np.shape(ref)}")
        arr = arr.astype(np.asarray(ref).dtype, copy=False)
        return jax.device_put(arr, shard) if shard is not None else arr
    return stored


def restore_pytree(path: str, like: PyTree, shardings: PyTree | None = None) -> PyTree:
    """Restore into the structure of ``like``.  If ``shardings`` (a pytree of
    jax.sharding.Sharding matching ``like``) is given, leaves are placed
    directly onto devices with those shardings."""
    doc = _read_doc(path)
    leaves = [_unpack_leaf(d) for d in doc["leaves"]]
    like_leaves, treedef = jax.tree.flatten(like)
    if len(leaves) != len(like_leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, expected {len(like_leaves)}"
        )
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves)
    )
    out = [
        restore_leaf(stored, ref, shard)
        for stored, ref, shard in zip(leaves, like_leaves, shard_leaves)
    ]
    return jax.tree.unflatten(treedef, out)


_FLAT = "__flat_posterior__"


def save_flat_posterior(path: str, post, compress_level: int = 3) -> None:
    """Checkpoint a ``core.flat.FlatPosterior`` with its layout doc inline.

    The [N, P] mean/rho buffers are written contiguously (no per-leaf
    packing) and the ``FlatLayout`` rides along as a self-describing doc, so
    ``restore_flat_posterior`` needs no ``like`` tree.
    """
    doc = {
        _FLAT: True,
        "layout": post.layout.to_doc(),
        "mean": _pack_leaf(post.mean),
        "rho": _pack_leaf(post.rho),
    }
    _write_doc(path, doc, compress_level)


def restore_flat_posterior(path: str, sharding=None):
    """Restore a ``FlatPosterior`` saved by ``save_flat_posterior``.

    ``sharding`` (optional jax.sharding.Sharding) places both buffers on
    device; otherwise numpy arrays are wrapped as-is.
    """
    from repro.core.flat import FlatLayout, FlatPosterior

    doc = _read_doc(path)
    if not doc.get(_FLAT):
        raise ValueError(f"{path} is not a flat-posterior checkpoint")
    layout = FlatLayout.from_doc(doc["layout"])
    mean = _unpack_leaf(doc["mean"])
    rho = _unpack_leaf(doc["rho"])
    if sharding is not None:
        mean = jax.device_put(mean, sharding)
        rho = jax.device_put(rho, sharding)
    else:
        mean = jnp.asarray(mean)
        rho = jnp.asarray(rho)
    return FlatPosterior(mean=mean, rho=rho, layout=layout)


_SNAPSHOT = "__posterior_snapshot__"


def save_snapshot(path: str, snap, compress_level: int = 3) -> None:
    """Checkpoint a ``serve.PosteriorSnapshot`` next to the session state.

    The (possibly bf16-resident) buffers go through ``_pack_leaf`` — which
    stores extension dtype NAMES, so a narrow snapshot round-trips in its
    resident dtype — and the provenance (window / version / dtype /
    telemetry) rides in the document.  A serving replica restores the exact
    served posterior without any training state."""
    doc = {
        _SNAPSHOT: True,
        "layout": snap.posterior.layout.to_doc(),
        "mean": _pack_leaf(snap.posterior.mean),
        "rho": _pack_leaf(snap.posterior.rho),
        "window": int(snap.window),
        "version": int(snap.version),
        "dtype": snap.dtype,
        "telemetry": snap.telemetry,
    }
    _write_doc(path, doc, compress_level)


def restore_snapshot(path: str):
    """Restore a ``serve.PosteriorSnapshot`` saved by ``save_snapshot``."""
    from repro.core.flat import FlatLayout, FlatPosterior
    from repro.serve.snapshot import PosteriorSnapshot

    doc = _read_doc(path)
    if not doc.get(_SNAPSHOT):
        raise ValueError(f"{path} is not a posterior-snapshot checkpoint")
    post = FlatPosterior(
        mean=jnp.asarray(_unpack_leaf(doc["mean"])),
        rho=jnp.asarray(_unpack_leaf(doc["rho"])),
        layout=FlatLayout.from_doc(doc["layout"]),
    )
    return PosteriorSnapshot(
        posterior=post,
        window=int(doc["window"]),
        version=int(doc["version"]),
        dtype=doc["dtype"],
        telemetry=dict(doc.get("telemetry") or {}),
    )


_SESSION = "__session__"


def save_session(
    path: str,
    spec_doc: dict,
    state,
    *,
    round_idx: int,
    key_data,
    compress_level: int = 3,
) -> None:
    """Self-describing ``api.Session`` checkpoint: the ``ExperimentSpec``
    doc (plain data, see ``ExperimentSpec.to_doc``) rides in the document
    next to the engine-state leaves, so ``restore_session`` +
    ``Session.load`` can rebuild the engine and resume with no ``like``
    tree.  Static state metadata (e.g. the ``FlatLayout``) is NOT stored —
    it is reconstructed by re-building the session from the spec."""
    doc = {
        _SESSION: True,
        "spec": spec_doc,
        "round": int(round_idx),
        "key_data": _pack_leaf(np.asarray(key_data)),
        "leaves": [_pack_leaf(l) for l in jax.tree.leaves(state)],
    }
    _write_doc(path, doc, compress_level)


def restore_session(path: str) -> tuple[dict, list, int, np.ndarray]:
    """-> (spec_doc, state_leaves, round_idx, key_data).  Use
    ``api.Session.load`` for the full rebuild."""
    doc = _read_doc(path)
    if not doc.get(_SESSION):
        raise ValueError(f"{path} is not a session checkpoint")
    leaves = [_unpack_leaf(d) for d in doc["leaves"]]
    return doc["spec"], leaves, doc["round"], np.asarray(_unpack_leaf(doc["key_data"]))


class CheckpointManager:
    """Step-numbered checkpoints with retention and atomic commit."""

    def __init__(self, root: str, max_to_keep: int = 3):
        self.root = root
        self.max_to_keep = max_to_keep
        os.makedirs(root, exist_ok=True)

    def _step_path(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}.ckpt")

    def save(self, step: int, tree: PyTree) -> str:
        path = self._step_path(step)
        save_pytree(path, tree)
        self._gc()
        return path

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and name.endswith(".ckpt"):
                steps.append(int(name[len("step_"):-len(".ckpt")]))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: PyTree, step: int | None = None, shardings=None) -> tuple[int, PyTree]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        return step, restore_pytree(self._step_path(step), like, shardings)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.max_to_keep]:
            p = self._step_path(s)
            if os.path.isdir(p):
                shutil.rmtree(p)
            else:
                os.remove(p)
