from repro.checkpoint.io import save_pytree, restore_pytree, CheckpointManager

__all__ = ["save_pytree", "restore_pytree", "CheckpointManager"]
