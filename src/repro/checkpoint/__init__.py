from repro.checkpoint.io import (
    CheckpointManager,
    restore_flat_posterior,
    restore_pytree,
    save_flat_posterior,
    save_pytree,
)

__all__ = [
    "save_pytree",
    "restore_pytree",
    "save_flat_posterior",
    "restore_flat_posterior",
    "CheckpointManager",
]
