from repro.checkpoint.io import (
    CheckpointManager,
    restore_flat_posterior,
    restore_pytree,
    restore_session,
    save_flat_posterior,
    save_pytree,
    save_session,
)

__all__ = [
    "save_pytree",
    "restore_pytree",
    "save_flat_posterior",
    "restore_flat_posterior",
    "save_session",
    "restore_session",
    "CheckpointManager",
]
