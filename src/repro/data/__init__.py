from repro.data.linreg import LinRegTask, make_linreg_task
from repro.data.synthetic import SyntheticClassification, make_synthetic_classification
from repro.data.partition import (
    partition_by_label,
    partition_iid,
    star_partition,
    grid_partition,
)
from repro.data.pipeline import AgentDataset, make_round_batches, make_lm_batch_sampler

__all__ = [
    "LinRegTask",
    "make_linreg_task",
    "SyntheticClassification",
    "make_synthetic_classification",
    "partition_by_label",
    "partition_iid",
    "star_partition",
    "grid_partition",
    "AgentDataset",
    "make_round_batches",
    "make_lm_batch_sampler",
]
