"""Batching pipeline.

The paper equalizes the number of local updates per communication round:
u = floor(n_edge / B) * E for every agent, so the (larger) central agent
trains each round on a RANDOM SUBSET of its local data (supplementary
1.4.1).  ``make_round_batches`` implements exactly that: every agent
contributes u minibatches of size B per round, stacked to [N, u, B, ...].

For the production LM runtime, ``make_lm_batch_sampler`` yields synthetic
token batches (the container is offline; real corpora plug in behind the
same interface).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class AgentDataset:
    """Per-agent local shards, padded to a common backing size for vmap."""

    x: jnp.ndarray  # [N, max_n, ...]
    y: jnp.ndarray  # [N, max_n]
    n: jnp.ndarray  # [N] true (unpadded) shard sizes

    @property
    def n_agents(self) -> int:
        return int(self.x.shape[0])

    @staticmethod
    def from_shards(shards: list[tuple[np.ndarray, np.ndarray]]) -> "AgentDataset":
        max_n = max(len(y) for _, y in shards)
        xs, ys, ns = [], [], []
        for x, y in shards:
            pad = max_n - len(y)
            # pad by repeating from the start (padded rows are never sampled:
            # sampling indices are taken modulo the true size n)
            reps = int(np.ceil(max_n / max(len(y), 1)))
            xs.append(np.concatenate([x] * reps)[:max_n])
            ys.append(np.concatenate([y] * reps)[:max_n])
            ns.append(len(y))
            del pad
        return AgentDataset(
            x=jnp.asarray(np.stack(xs)),
            y=jnp.asarray(np.stack(ys)),
            n=jnp.asarray(ns, jnp.int32),
        )


def make_round_batches(
    data: AgentDataset, batch_size: int, n_local_updates: int
):
    """Returns sampler(key, round) -> dict(x=[N,u,B,...], y=[N,u,B]).

    Each agent draws u*B sample indices uniformly from its true shard
    (with replacement across rounds, without within a round when possible) —
    the paper's random-subset-per-round behaviour for the big agent.
    """
    n_agents = data.n_agents
    u, b = n_local_updates, batch_size

    @jax.jit
    def sampler_impl(key):
        keys = jax.random.split(key, n_agents)

        def per_agent(k, x_a, y_a, n_a):
            idx = jax.random.randint(k, (u * b,), 0, n_a)
            return x_a[idx].reshape((u, b) + x_a.shape[1:]), y_a[idx].reshape(u, b)

        xs, ys = jax.vmap(per_agent)(keys, data.x, data.y, data.n)
        return {"x": xs, "y": ys}

    def sampler(key, round_idx: int):
        del round_idx
        return sampler_impl(key)

    return sampler


def make_lm_batch_sampler(
    vocab_size: int, batch_size: int, seq_len: int, n_agents: int = 0,
    distribution: str = "zipf",
):
    """Synthetic LM token pipeline: sampler(key, round) -> dict with
    ``tokens`` [(N,) B, S] and ``targets`` (next-token shift).  Used by the
    production train driver and the ~100M end-to-end example.

    ``distribution``: "zipf" (learnable unigram structure, entropy below
    log V — training visibly reduces NLL) or "uniform"."""

    shape = ((n_agents, batch_size, seq_len + 1) if n_agents
             else (batch_size, seq_len + 1))
    if distribution == "zipf":
        w = 1.0 / (np.arange(1, vocab_size + 1) ** 1.2)
        logits = jnp.asarray(np.log(w / w.sum()), jnp.float32)
    elif distribution == "uniform":
        logits = jnp.zeros((vocab_size,), jnp.float32)
    else:
        raise ValueError(distribution)

    @jax.jit
    def sampler_impl(key):
        toks = jax.random.categorical(
            key, jnp.broadcast_to(logits, shape + (vocab_size,))
        ).astype(jnp.int32)
        return {"tokens": toks[..., :-1], "targets": toks[..., 1:]}

    def sampler(key, round_idx: int):
        del round_idx
        return sampler_impl(key)

    return sampler
