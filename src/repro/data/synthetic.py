"""Synthetic clustered image-classification dataset: the offline stand-in for
MNIST / Fashion-MNIST (the container has no network access).

Each class c has a prototype p_c in R^dim; a sample is p_c + noise.  The
geometry is controllable so the paper's data-partition phenomenology is
reproducible:

* ``confusable_pairs``: class pairs whose prototypes are placed at small
  distance (the paper's {4, 9} MNIST ambiguity, Sec 4.2.2) — agents that
  never see both classes cannot learn to separate them.
* ``groups``: clusters of classes sharing a common direction (the FMNIST
  "shirt-like" family: t-shirt / pullover / dress / coat / shirt).

Distances are chosen so a 2-layer MLP trained on all classes separates
everything, while the confusable pairs are only separable along one specific
low-variance direction (only visible when both classes are in-domain).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticClassification:
    x_train: np.ndarray  # [n_train, dim] float32
    y_train: np.ndarray  # [n_train] int32
    x_test: np.ndarray
    y_test: np.ndarray
    n_classes: int
    dim: int
    prototypes: np.ndarray  # [n_classes, dim]


def make_synthetic_classification(
    n_classes: int = 10,
    dim: int = 64,
    n_train_per_class: int = 600,
    n_test_per_class: int = 100,
    noise: float = 0.55,
    proto_scale: float = 1.0,
    confusable_pairs: tuple[tuple[int, int], ...] = (),
    confusable_gap: float = 0.35,
    groups: tuple[tuple[int, ...], ...] = (),
    group_spread: float = 0.5,
    seed: int = 0,
) -> SyntheticClassification:
    rng = np.random.default_rng(seed)
    protos = rng.normal(0.0, proto_scale, (n_classes, dim))
    # group members share a common center with a small per-class offset
    for g in groups:
        center = rng.normal(0.0, proto_scale, dim)
        for c in g:
            protos[c] = center + rng.normal(0.0, group_spread * proto_scale, dim)
    # confusable pairs: second member = first + small offset in ONE direction
    for a, b in confusable_pairs:
        direction = np.zeros(dim)
        direction[rng.integers(dim)] = 1.0
        protos[b] = protos[a] + confusable_gap * proto_scale * direction

    def sample(n_per_class: int, salt: int):
        xs, ys = [], []
        for c in range(n_classes):
            e = rng.normal(0.0, noise, (n_per_class, dim))
            xs.append(protos[c] + e)
            ys.append(np.full(n_per_class, c))
        x = np.concatenate(xs).astype(np.float32)
        y = np.concatenate(ys).astype(np.int32)
        perm = rng.permutation(len(y))
        return x[perm], y[perm]

    x_train, y_train = sample(n_train_per_class, 0)
    x_test, y_test = sample(n_test_per_class, 1)
    return SyntheticClassification(
        x_train=x_train,
        y_train=y_train,
        x_test=x_test,
        y_test=y_test,
        n_classes=n_classes,
        dim=dim,
        prototypes=protos,
    )


def mnist_like(seed: int = 0, **kw) -> SyntheticClassification:
    """MNIST stand-in with the {4, 9} confusable pair from the paper."""
    kw.setdefault("confusable_pairs", ((4, 9),))
    return make_synthetic_classification(seed=seed, **kw)


def fmnist_like(seed: int = 0, **kw) -> SyntheticClassification:
    """FMNIST stand-in.  Label order matches the paper:
    0 t-shirt, 1 trouser, 2 pullover, 3 dress, 4 coat, 5 sandal, 6 shirt,
    7 sneaker, 8 bag, 9 ankle-boot.  Shirt-like family grouped: {0,2,3,4,6};
    shoe-like family grouped: {5,7,9}."""
    kw.setdefault("groups", ((0, 2, 3, 4, 6), (5, 7, 9)))
    return make_synthetic_classification(seed=seed, **kw)


FMNIST_LABELS = [
    "t-shirt",
    "trouser",
    "pullover",
    "dress",
    "coat",
    "sandal",
    "shirt",
    "sneaker",
    "bag",
    "ankle-boot",
]
