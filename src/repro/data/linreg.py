"""Paper Example 1 / Sec 4.1: decentralized Bayesian linear regression with
extreme non-IID feature partition.

True model: y = theta*^T phi(x) + eta, eta ~ N(0, alpha^2); agent i observes
inputs along ONLY coordinate i:  x = [0,...,0, x_i, 0,...,0], x_i ~
Unif[-r_i, r_i].  Supplementary 1.3 gives theta* = [-0.3, 0.5, 0.5, 0.1, 0.2]
(d=5), alpha=0.8, ranges r = [1, 1.5, 1.25, 0.75] for the 4 agents, prior
N(0, diag 0.5).  We default to the identity basis phi(x)=x, matching the
coordinate-observation description.
"""
from __future__ import annotations

import dataclasses

import numpy as np

THETA_STAR = np.array([-0.3, 0.5, 0.5, 0.1, 0.2])
NOISE_STD = 0.8
AGENT_RANGES = np.array([1.0, 1.5, 1.25, 0.75])
PRIOR_VAR = 0.5


@dataclasses.dataclass
class LinRegTask:
    theta_star: np.ndarray  # [d]
    noise_std: float
    agent_coords: list[list[int]]  # coordinates observable by each agent
    agent_ranges: np.ndarray  # [N] uniform half-ranges
    d: int

    @property
    def n_agents(self) -> int:
        return len(self.agent_coords)

    def sample_local(
        self, rng: np.random.Generator, agent: int, n: int
    ) -> tuple[np.ndarray, np.ndarray]:
        """Draw n (phi(x), y) pairs for one agent (only its coordinates active)."""
        phi = np.zeros((n, self.d))
        for c in self.agent_coords[agent]:
            phi[:, c] = rng.uniform(-self.agent_ranges[agent], self.agent_ranges[agent], n)
        y = phi @ self.theta_star + rng.normal(0.0, self.noise_std, n)
        return phi, y

    def sample_global(
        self, rng: np.random.Generator, n: int
    ) -> tuple[np.ndarray, np.ndarray]:
        """Global test set: all coordinates active (the centralized view)."""
        phi = rng.uniform(-1.0, 1.0, (n, self.d))
        y = phi @ self.theta_star + rng.normal(0.0, self.noise_std, n)
        return phi, y


def make_linreg_task(
    d: int = 5, n_agents: int = 4, theta_star: np.ndarray | None = None
) -> LinRegTask:
    """Default = the paper's exact setup: 4 agents, d=5, each agent sees one
    coordinate (agent i -> coordinate i); coordinate d-1=4 is observed by no
    single agent alone in the paper's text, we give it to agent 3 together
    with coordinate 3 so the union covers all of R^d (Assumption 2)."""
    theta = THETA_STAR[:d] if theta_star is None else np.asarray(theta_star)
    coords: list[list[int]] = [[i] for i in range(n_agents)]
    # distribute any remaining coordinates round-robin so the union spans R^d
    for c in range(n_agents, d):
        coords[c % n_agents].append(c)
    return LinRegTask(
        theta_star=theta,
        noise_std=NOISE_STD,
        agent_coords=coords,
        agent_ranges=AGENT_RANGES[:n_agents]
        if n_agents <= len(AGENT_RANGES)
        else np.ones(n_agents),
        d=d,
    )
