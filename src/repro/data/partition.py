"""Non-IID data partitioners (paper Secs 4.2.1-4.2.2, supplementary 1.4).

The paper's partitions assign disjoint LABEL subsets to agents:
  MNIST-Setup1:  center {2..9},     each edge agent a shard of {0,1}
  MNIST-Setup2:  center {0..7},     edges shards of {8,9}
  MNIST-Setup3:  center others,     edges shards of {4,9}
  FMNIST-Setup1: center {t-shirt,pullover,dress,coat,shirt,bag},
                 edges shards of {trouser,sandal,sneaker,ankle-boot}
  FMNIST-Setup2: center {t-shirt,trouser,dress,coat,shirt,bag},
                 edges shards of {pullover,sandal,sneaker,ankle-boot}
"""
from __future__ import annotations

import numpy as np


def partition_iid(
    x: np.ndarray, y: np.ndarray, n_agents: int, seed: int = 0
) -> list[tuple[np.ndarray, np.ndarray]]:
    """Shuffle and split evenly (paper Sec 1.4.3 time-varying experiment)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(y))
    shards = np.array_split(perm, n_agents)
    return [(x[s], y[s]) for s in shards]


def partition_by_label(
    x: np.ndarray,
    y: np.ndarray,
    label_sets: list[list[int]],
    seed: int = 0,
) -> list[tuple[np.ndarray, np.ndarray]]:
    """Assign each agent all samples whose label is in its label set.  If a
    label appears in k>1 sets, its samples are split into k shards."""
    rng = np.random.default_rng(seed)
    owners: dict[int, list[int]] = {}
    for a, ls in enumerate(label_sets):
        for l in ls:
            owners.setdefault(l, []).append(a)
    per_agent_idx: list[list[np.ndarray]] = [[] for _ in label_sets]
    for l, agents in owners.items():
        idx = np.nonzero(y == l)[0]
        idx = rng.permutation(idx)
        for a, shard in zip(agents, np.array_split(idx, len(agents))):
            per_agent_idx[a].append(shard)
    out = []
    for chunks in per_agent_idx:
        idx = np.concatenate(chunks) if chunks else np.zeros(0, np.int64)
        idx = rng.permutation(idx)
        out.append((x[idx], y[idx]))
    return out


def star_partition(
    x: np.ndarray,
    y: np.ndarray,
    center_labels: list[int],
    edge_labels: list[int],
    n_edge: int,
    seed: int = 0,
) -> list[tuple[np.ndarray, np.ndarray]]:
    """Paper star partitions: agent 0 (center) owns ``center_labels``; the
    ``edge_labels`` samples are shuffled and split into n_edge equal shards."""
    rng = np.random.default_rng(seed)
    center_idx = np.nonzero(np.isin(y, center_labels))[0]
    edge_idx = rng.permutation(np.nonzero(np.isin(y, edge_labels))[0])
    shards = np.array_split(edge_idx, n_edge)
    out = [(x[center_idx], y[center_idx])]
    out += [(x[s], y[s]) for s in shards]
    return out


def grid_partition(
    x: np.ndarray,
    y: np.ndarray,
    type1_labels: list[int],
    type2_labels: list[int],
    type1_position: int,
    n_agents: int = 9,
    seed: int = 0,
) -> list[tuple[np.ndarray, np.ndarray]]:
    """Paper Sec 4.2.2 grid: the Type-1 (informative) agent at
    ``type1_position`` owns ``type1_labels``; the other 8 agents share equal
    shards of ``type2_labels``."""
    rng = np.random.default_rng(seed)
    t1_idx = np.nonzero(np.isin(y, type1_labels))[0]
    t2_idx = rng.permutation(np.nonzero(np.isin(y, type2_labels))[0])
    shards = np.array_split(t2_idx, n_agents - 1)
    out: list[tuple[np.ndarray, np.ndarray]] = []
    s = 0
    for a in range(n_agents):
        if a == type1_position:
            out.append((x[t1_idx], y[t1_idx]))
        else:
            out.append((x[shards[s]], y[shards[s]]))
            s += 1
    return out
