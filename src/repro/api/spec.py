"""Declarative experiment specification — the paper's whole pipeline as data.

An ``ExperimentSpec`` is a pure-data description of one decentralized-
Bayesian-learning experiment (Sec 2.1): WHO talks to whom (``TopologySpec``,
the row-stochastic W of eq. 6 — static, scheduled, or round-indexed), WHAT
each agent observes (``DataSpec``, dataset + non-IID partition strategy),
HOW each agent updates its posterior (``InferenceSpec``, Bayes-by-Backprop
hyperparameters or the conjugate linear-regression family of Example 1),
and the run envelope (``RunSpec``, rounds / seed / engine).

``build_session`` (see ``api.session``) validates the whole spec EAGERLY —
connectivity (Assumption 1), row-stochasticity, agent-count and shape
agreement — before any compute, and returns a ``Session`` backed by an
engine.  Specs round-trip through ``to_doc``/``from_doc`` so checkpoints are
self-describing (``Session.save`` embeds the doc; ``Session.load`` rebuilds
the session from it).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import numpy as np

from repro.core import graphs

PyTree = Any

_NAMED_TOPOLOGIES = {
    "star": graphs.star_w,
    "grid": graphs.grid_w,
    "ring": graphs.ring_w,
    "bidirectional_ring": graphs.bidirectional_ring_w,
    "torus": graphs.torus_w,
    "complete": graphs.complete_w,
    "erdos": graphs.erdos_w,
    # dense bridges of the sparse small-world generators, so they work as
    # named kinds and gossip bases at moderate N; use kind="sparse" at scale
    "watts_strogatz": graphs.watts_strogatz_w,
    "barabasi_albert": graphs.barabasi_albert_w,
}

#: Above this agent count a ``kind="sparse"`` topology refuses to derive a
#: dense W: a [4096, 4096] f64 matrix is 128 MiB and anything past it is the
#: O(N^2) regime the edge-native runtime exists to avoid.
SPARSE_DENSE_GUARD = 4096


def _freeze(d: dict | None) -> dict:
    return dict(d) if d else {}


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """The communication graph: a named builder, an explicit W, or a
    round-indexed schedule (subsumes ``time_varying_star_schedule``).

    kind:
      one of ``star | grid | ring | bidirectional_ring | torus | complete |
      erdos`` (named static builders, parameterized by ``params``),
      ``explicit`` (``w`` holds the [N, N] matrix), ``schedule`` (``schedule``
      holds a list of W's cycled over rounds), ``time_varying_star`` (paper
      Sec 1.4.3, ``params`` = n_agents/n_active/a), ``callable``
      (``schedule`` holds a ``Callable[[int], W]``; requires ``agents`` and
      is not checkpoint-embeddable), or ``gossip`` (event-driven
      asynchronous runtime: ``params`` names the base graph —
      ``{"base": <named kind>, "base_params": {...}}`` or
      ``{"base": "explicit", "w": [[...]]}`` — and ``clock`` is the plain-
      dict activation-clock description of ``repro.gossip.clocks
      .build_clock``; selects the ``GossipEngine``, one event window per
      round), or ``sparse`` (edge-native CSR topology: ``params`` carries a
      generator name + its kwargs, e.g. ``{"generator": "watts_strogatz",
      "n": 10_000, "k": 6, "beta": 0.1}``; see below).

    kind="sparse" (population scale, N = 10^4+):
      ``params["generator"]`` names a ``repro.core.graphs.SPARSE_GENERATORS``
      builder — ``ring | bidirectional_ring | grid | torus | star`` (the
      named topologies without the [N, N] allocation) or the small-world
      generators ``watts_strogatz`` (n, k, beta, seed) and
      ``barabasi_albert`` (n, m, seed); the remaining params are the
      builder's kwargs.  The doc is plain data (checkpoint-embeddable) and
      ``validate()`` runs entirely on the CSR arrays — row-stochasticity and
      the iterative strong-connectivity check — without materializing W.
      ``sparse_graph()`` returns the memoized ``SparseGraph``; a dense W is
      derived lazily (``w_schedule()``/``_static_list()``) and ONLY below
      ``SPARSE_DENSE_GUARD`` agents — above it, drive the edge-native
      runtime directly (``SparseGraph.edge_arrays()`` +
      ``core.flat.consensus_flat_segments``).

      An optional ``clock`` dict (``repro.gossip.clocks.build_sparse_clock``
      kinds: ``poisson | all_edges | failure_injected``, plus a top-level
      ``"faults"`` entry) turns the sparse topology into the EDGE-NATIVE
      gossip runtime: ``w_schedule()`` yields the clock's ``SparseWindow``
      stream (fired [E_w] edge arrays + self-weights + the exact active
      mask — never a dense W) and the ``GossipEngine`` executes each window
      through ``core.flat.consensus_flat_segments``
      (``InferenceSpec.consensus_impl="segments"``, the ``"auto"`` choice
      for this shape) — the only gossip path that runs above the guard.
    """

    kind: str = "complete"
    params: dict = dataclasses.field(default_factory=dict)
    w: Any = None
    schedule: Any = None
    agents: int | None = None  # only needed for kind="callable"
    clock: dict | None = None  # kind="gossip" | kind="sparse" (edge-native)

    # -- conveniences --------------------------------------------------------

    @classmethod
    def star(cls, n_edge: int, a: float) -> "TopologySpec":
        return cls(kind="star", params={"n_edge": n_edge, "a": a})

    @classmethod
    def grid(cls, rows: int, cols: int) -> "TopologySpec":
        return cls(kind="grid", params={"rows": rows, "cols": cols})

    @classmethod
    def complete(cls, n: int) -> "TopologySpec":
        return cls(kind="complete", params={"n": n})

    @classmethod
    def explicit(cls, w) -> "TopologySpec":
        return cls(kind="explicit", w=np.asarray(w, np.float64))

    @classmethod
    def from_schedule(cls, mats: Sequence) -> "TopologySpec":
        return cls(kind="schedule", schedule=[np.asarray(m, np.float64) for m in mats])

    @classmethod
    def time_varying_star(cls, n_agents: int, n_active: int, a: float = 0.5) -> "TopologySpec":
        return cls(
            kind="time_varying_star",
            params={"n_agents": n_agents, "n_active": n_active, "a": a},
        )

    @classmethod
    def from_callable(cls, fn: Callable[[int], Any], n_agents: int) -> "TopologySpec":
        return cls(kind="callable", schedule=fn, agents=n_agents)

    @classmethod
    def sparse(
        cls, generator: str, clock: dict | None = None, **params
    ) -> "TopologySpec":
        """Edge-native CSR topology (``kind="sparse"``): ``generator`` names
        a ``graphs.SPARSE_GENERATORS`` builder, ``params`` are its kwargs —
        e.g. ``TopologySpec.sparse("watts_strogatz", n=10_000, k=6,
        beta=0.1, seed=0)``.  Pass ``clock`` (a ``build_sparse_clock`` doc,
        e.g. ``{"kind": "poisson", "rate": 1.0}``) to gossip on the graph
        with edge-native event windows."""
        return cls(
            kind="sparse",
            params={"generator": generator, **params},
            clock=dict(clock) if clock else None,
        )

    @classmethod
    def gossip(
        cls,
        base: str,
        base_params: dict | None = None,
        clock: dict | None = None,
        w=None,
    ) -> "TopologySpec":
        """Event-driven gossip on a base graph: ``base`` names a builder
        (``ring | grid | ...``) parameterized by ``base_params``, or
        ``base="explicit"`` with ``w``; ``clock`` is the activation-clock
        dict (default: unit-rate Poisson).  Fully checkpoint-embeddable."""
        if w is not None and base != "explicit":
            raise ValueError(
                f"gossip(w=...) requires base='explicit'; base={base!r} "
                "would silently ignore the provided matrix"
            )
        params: dict = {"base": base, "base_params": dict(base_params or {})}
        if w is not None:
            params["w"] = np.asarray(w, np.float64).tolist()
        return cls(
            kind="gossip",
            params=params,
            clock=dict(clock) if clock else {"kind": "poisson", "rate": 1.0},
        )

    @classmethod
    def gossip_from_schedule(
        cls, mats: Sequence, clock_extra: dict | None = None
    ) -> "TopologySpec":
        """Re-express a W schedule (e.g. ``time_varying_star_schedule``) as a
        gossip trace: the schedule's per-slot active edges become per-window
        activation events over the shared weight table.  The resulting spec
        runs on the ``GossipEngine`` and reproduces the scheduled runs."""
        from repro.gossip.clocks import trace_from_schedule

        table, trace = trace_from_schedule([np.asarray(m) for m in mats])
        clock = {
            "kind": "trace",
            "trace": [[[int(i), int(j)] for i, j in slot] for slot in trace],
            "rule": "table",
        }
        clock.update(clock_extra or {})
        return cls(
            kind="gossip",
            params={"base": "explicit", "w": table.tolist()},
            clock=clock,
        )

    # -- materialization -----------------------------------------------------

    def base_w(self) -> np.ndarray:
        """kind="gossip": the base graph / weight table the clock fires on."""
        if self.kind != "gossip":
            raise ValueError("base_w() is only defined for kind='gossip'")
        base = self.params.get("base")
        if base is None:
            raise ValueError(
                "TopologySpec(kind='gossip') requires params={'base': ...}"
            )
        if base == "explicit":
            if self.params.get("w") is None:
                raise ValueError("gossip base='explicit' requires params['w']")
            return np.asarray(self.params["w"], np.float64)
        if base not in _NAMED_TOPOLOGIES:
            raise ValueError(
                f"unknown gossip base {base!r}; known: "
                f"{sorted(_NAMED_TOPOLOGIES) + ['explicit']}"
            )
        try:
            return _NAMED_TOPOLOGIES[base](**_freeze(self.params.get("base_params")))
        except TypeError as e:
            raise ValueError(f"gossip base={base!r} params mismatch: {e}") from e

    def gossip_clock(self):
        """kind="gossip" | kind="sparse"+clock: build the activation clock.

        kind="gossip" builds a dense EventWindow clock over ``base_w()``
        (``build_clock``); kind="sparse" with a ``clock`` dict builds an
        edge-native ``SparseClock`` over the CSR graph
        (``build_sparse_clock`` — windows are ``SparseWindow`` objects).

        Memoized on the (frozen) spec: construction eagerly validates every
        distinct trace window, so ``validate()`` and ``w_schedule()`` must
        not each pay it again."""
        cached = getattr(self, "_clock_cache", None)
        if cached is not None:
            return cached
        if self.kind == "sparse":
            if self.clock is None:
                raise ValueError(
                    "this sparse topology has no clock dict; gossip_clock() "
                    "needs one (e.g. {'kind': 'poisson', 'rate': 1.0})"
                )
            from repro.gossip.clocks import build_sparse_clock

            clock = build_sparse_clock(self.clock, self.sparse_graph())
            object.__setattr__(self, "_clock_cache", clock)
            return clock
        from repro.gossip.clocks import build_clock

        if self.clock is None:
            raise ValueError("TopologySpec(kind='gossip') requires a clock dict")
        clock = build_clock(self.clock, self.base_w())
        object.__setattr__(self, "_clock_cache", clock)
        return clock

    def sparse_graph(self):
        """kind="sparse": the memoized, eagerly validated ``SparseGraph``.

        Construction runs the generator AND its Assumption-1 validation on
        the CSR arrays (O(E) memory, iterative connectivity check) — the
        sparse analogue of ``check_w`` on the named dense builders."""
        if self.kind != "sparse":
            raise ValueError("sparse_graph() is only defined for kind='sparse'")
        cached = getattr(self, "_sparse_cache", None)
        if cached is not None:
            return cached
        params = _freeze(self.params)
        generator = params.pop("generator", None)
        if generator is None:
            raise ValueError(
                "TopologySpec(kind='sparse') requires params={'generator': "
                f"...}}; known generators: {sorted(graphs.SPARSE_GENERATORS)}"
            )
        try:
            graph = graphs.build_sparse(generator, **params)
        except TypeError as e:
            raise ValueError(
                f"sparse generator {generator!r} params mismatch: {e}"
            ) from e
        object.__setattr__(self, "_sparse_cache", graph)
        return graph

    def _static_list(self) -> list | None:
        """The full W list for non-callable kinds (None for ``callable``).

        kind="sparse" derives its dense W HERE — lazily, and only below
        ``SPARSE_DENSE_GUARD`` agents."""
        if self.kind == "sparse":
            graph = self.sparse_graph()
            if graph.n_agents > SPARSE_DENSE_GUARD:
                raise ValueError(
                    f"sparse topology has N={graph.n_agents} agents, above "
                    f"the dense-materialization guard ({SPARSE_DENSE_GUARD}): "
                    "refusing to allocate [N, N]; drive the edge-native "
                    "runtime instead (sparse_graph().edge_arrays() + "
                    "core.flat.consensus_flat_segments)"
                )
            return [graph.to_dense()]
        if self.kind in _NAMED_TOPOLOGIES:
            try:
                return [_NAMED_TOPOLOGIES[self.kind](**_freeze(self.params))]
            except TypeError as e:
                raise ValueError(
                    f"TopologySpec(kind={self.kind!r}) params mismatch: {e}"
                ) from e
        if self.kind == "explicit":
            if self.w is None:
                raise ValueError("TopologySpec(kind='explicit') requires w")
            return [np.asarray(self.w, np.float64)]
        if self.kind == "schedule":
            if not self.schedule:
                raise ValueError("TopologySpec(kind='schedule') requires a non-empty schedule")
            return [np.asarray(m, np.float64) for m in self.schedule]
        if self.kind == "time_varying_star":
            return graphs.time_varying_star_schedule(**_freeze(self.params))
        if self.kind in ("callable", "gossip"):
            return None
        raise ValueError(
            f"unknown topology kind {self.kind!r}; known: "
            f"{sorted(_NAMED_TOPOLOGIES) + ['explicit', 'schedule', 'time_varying_star', 'callable', 'gossip', 'sparse']}"
        )

    def w_schedule(self) -> Callable[[int], np.ndarray]:
        """Round-indexed ``Callable[[int], W]`` (the canonical form).  For
        kind="gossip" this is the clock's window stream: round r's matrix is
        window r's effective W-tilde (a pure function of the clock seed and
        r, so resumed sessions regenerate the identical event stream)."""
        if self.kind == "callable":
            return self.schedule
        if self.kind == "gossip":
            clock = self.gossip_clock()
            return lambda r: clock.window(r).w_eff
        if self.kind == "sparse" and self.clock is not None:
            # edge-native stream: the schedule yields the SparseWindow
            # OBJECTS themselves (the GossipEngine consumes them verbatim —
            # ``wants_host_w``); no dense W exists on this path
            clock = self.gossip_clock()
            return lambda r: clock.window(r)
        mats = self._static_list()
        return lambda r: mats[r % len(mats)]

    def n_agents(self) -> int:
        if self.kind == "callable":
            if self.agents is None:
                raise ValueError(
                    "TopologySpec(kind='callable') requires the explicit "
                    "``agents`` count (the schedule length is unknowable)"
                )
            return self.agents
        if self.kind == "gossip":
            return int(self.base_w().shape[0])
        if self.kind == "sparse":
            return self.sparse_graph().n_agents
        return int(np.asarray(self._static_list()[0]).shape[0])

    def validate(self) -> None:
        """Paper Assumption 1 prerequisites, eagerly.

        Static kinds: W square, nonnegative, row-stochastic, self-loops,
        strongly connected.  Schedules: every slot row-stochastic; the UNION
        over the schedule strongly connected (the time-varying relaxation).
        Callable: round-0 W checked without the connectivity requirement
        (the union over an unbounded schedule cannot be enumerated).
        Gossip: the clock is built eagerly (per-kind parameter/feasibility
        checks) and the expected activation-graph UNION must be strongly
        connected (the time-varying relaxation of Assumption 1).
        """
        if self.kind == "gossip":
            self.gossip_clock().validate()
            return
        if self.kind == "sparse":
            # O(E) throughout: generator + CSR validation, never a dense W
            self.sparse_graph().validate(require_connected=True)
            if self.clock is not None:
                self.gossip_clock().validate()
            return
        if self.kind == "callable":
            W0 = np.asarray(self.schedule(0), np.float64)
            graphs.check_w(W0, require_connected=False)
            if self.agents is not None and W0.shape[0] != self.agents:
                raise ValueError(
                    f"callable topology produced a {W0.shape[0]}-agent W but "
                    f"the spec declares agents={self.agents}"
                )
            return
        mats = self._static_list()
        if len(mats) == 1:
            graphs.check_w(mats[0], require_connected=True)
            return
        for m in mats:
            graphs.check_w(m, require_connected=False)
        graphs.check_schedule_union(mats)


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """What each agent observes: dataset family + non-IID partition strategy
    + the per-round batching contract (u local minibatches of size B).

    dataset: ``synthetic_classification | mnist_like | fmnist_like``
    (classification stand-ins, ``dataset_params`` forwarded to
    ``data.synthetic``) or ``linreg`` (paper Example 1,
    ``dataset_params`` forwarded to ``data.linreg.make_linreg_task``).

    partition (classification only): ``iid | by_label | star | grid``
    (``partition_params`` forwarded to ``data.partition``).
    """

    dataset: str = "synthetic_classification"
    dataset_params: dict = dataclasses.field(default_factory=dict)
    partition: str = "iid"
    partition_params: dict = dataclasses.field(default_factory=dict)
    batch_size: int = 16
    local_updates: int = 4

    def validate(self) -> None:
        if self.dataset not in (
            "synthetic_classification", "mnist_like", "fmnist_like", "linreg",
        ):
            raise ValueError(f"unknown dataset {self.dataset!r}")
        if self.dataset != "linreg" and self.partition not in (
            "iid", "by_label", "star", "grid",
        ):
            raise ValueError(f"unknown partition {self.partition!r}")
        if self.batch_size <= 0 or self.local_updates <= 0:
            raise ValueError("batch_size and local_updates must be positive")


@dataclasses.dataclass(frozen=True)
class InferenceSpec:
    """How each agent updates its posterior between consensus steps.

    method="bbb": Bayes-by-Backprop (paper Remark 1 / eq. 5) on the model
    from the registry (``api.models``) — the NN experiments.
    method="conjugate_linreg": the exact conjugate full-covariance update of
    Example 1 (eq. 2); model/optimizer fields are ignored.

    ``consensus_impl`` picks the EXECUTION of the (gossip) consensus, not
    its math — every impl is bit-identical by test:
      ``auto``      the dense masked window kernel (default);
      ``masked``    force the dense masked kernel;
      ``ppermute``  shard the agent axis over the local devices and execute
                    each event window as one ``shard_map`` that ppermutes
                    only the window's fired shard offsets
                    (``launch.consensus_opt.consensus_ppermute_window``);
                    ``consensus_shards`` caps/pins the shard count (None =
                    the largest divisor of n_agents <= local device count).

    ``wire_dtype`` (``"f32" | "bf16" | "f16"``) picks the PRECISION of the
    consensus exchange, orthogonal to ``consensus_impl``: the (prec,
    prec*mu) sufficient statistics are cast to the wire dtype at the
    exchange boundary and accumulated fp32 (ROADMAP "Wire precision") —
    at bf16 the collective/ICI bytes halve.  ``"f32"`` (default) is
    bitwise the uncompressed path on every impl; narrower dtypes agree
    with it within the derived bound (``core.numerics.wire_error_bound``,
    tests/test_wire_dtype.py).  ``history_dtype`` (None = fp32) optionally
    stores the delivery-latency [K, N, P] posterior history ring in a
    narrower resident dtype (halving its HBM footprint at bf16); only
    meaningful with a delayed gossip clock.

    ``fault_policy`` picks the consensus defense against corrupted
    exchange payloads (ROADMAP "Robustness"):
      ``strict``      trust every incoming contribution verbatim (default;
                      an injected NaN/Inf poisons every reachable agent —
                      the undefended failure mode);
      ``quarantine``  validate every incoming (prec, prec*mu) contribution
                      at the exchange boundary (finite, prec > 0, magnitude
                      bound — ``core.flat.payload_validity``), drop invalid
                      ones and reassign their W-tilde row mass to self.
                      With zero faults the quarantined path is BITWISE
                      identical to strict on every consensus impl.
    """

    method: str = "bbb"
    model: str = "mlp"
    hidden: int = 48
    depth: int = 2
    init_sigma: float = 0.05
    shared_init: bool = True
    optimizer: str = "adam"
    lr: float = 5e-3
    lr_decay: float = 0.99  # multiplicative, per communication round (paper)
    kl_scale: float = 1e-3
    n_mc_samples: int = 1
    consensus: str = "gaussian"  # gaussian | mean_only | none
    consensus_impl: str = "auto"  # auto | masked | ppermute | segments (gossip)
    consensus_shards: int | None = None  # ppermute only; None = auto
    wire_dtype: str = "f32"  # f32 | bf16 | f16: consensus exchange precision
    history_dtype: str | None = None  # delayed gossip ring residency (None=f32)
    fault_policy: str = "strict"  # strict | quarantine: exchange validation
    prior_var: float = 0.5  # conjugate_linreg prior N(0, prior_var I)

    def validate(self) -> None:
        if self.method not in ("bbb", "conjugate_linreg"):
            raise ValueError(f"unknown inference method {self.method!r}")
        if self.optimizer not in ("adam", "sgd"):
            raise ValueError(f"unknown optimizer {self.optimizer!r}")
        if self.consensus not in ("gaussian", "mean_only", "none"):
            raise ValueError(f"unknown consensus mode {self.consensus!r}")
        if self.consensus_impl not in ("auto", "masked", "ppermute", "segments"):
            raise ValueError(
                f"unknown consensus_impl {self.consensus_impl!r}; known: "
                "auto | masked | ppermute | segments"
            )
        if self.wire_dtype not in ("f32", "bf16", "f16"):
            raise ValueError(
                f"unknown wire_dtype {self.wire_dtype!r}; known: "
                "f32 | bf16 | f16"
            )
        if self.history_dtype not in (None, "f32", "bf16", "f16"):
            raise ValueError(
                f"unknown history_dtype {self.history_dtype!r}; known: "
                "None | f32 | bf16 | f16"
            )
        if self.wire_dtype != "f32" and self.consensus != "gaussian":
            raise ValueError(
                "wire_dtype compresses the gaussian (prec, prec*mu) "
                f"exchange; consensus={self.consensus!r} (mean_only has no "
                "wire-compressed path, none exchanges nothing) would "
                "silently ignore it"
            )
        if self.wire_dtype != "f32" and self.method == "conjugate_linreg":
            raise ValueError(
                "wire_dtype applies to the mean-field consensus exchange; "
                "the conjugate_linreg engine would silently ignore it"
            )
        if self.fault_policy not in ("strict", "quarantine"):
            raise ValueError(
                f"unknown fault_policy {self.fault_policy!r}; known: "
                "strict | quarantine"
            )
        if self.fault_policy == "quarantine" and self.consensus != "gaussian":
            raise ValueError(
                "fault_policy='quarantine' validates the gaussian (prec, "
                f"prec*mu) exchange; consensus={self.consensus!r} has no "
                "quarantined path and would silently ignore it"
            )
        if self.consensus_shards is not None:
            if self.consensus_shards <= 0:
                raise ValueError(
                    "consensus_shards must be a positive int or None"
                )
            if self.consensus_impl != "ppermute":
                raise ValueError(
                    "consensus_shards only applies to consensus_impl="
                    "'ppermute' (it would be silently ignored otherwise)"
                )


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """The serving-tier contract (ROADMAP "Serving"; ``repro.serve``).

    ``snapshot_dtype`` picks the RESIDENCY of published posterior snapshots
    (``"f32" | "bf16" | "f16"`` — the shared ``core.numerics`` wire-dtype
    vocabulary): a bf16-resident snapshot halves the serving HBM
    (``launch.costmodel.serve_roofline``) and is decoded to fp32 inside the
    jitted apply.  ``mc_samples`` is the default predictive ensemble size L
    (0 = point estimate at the posterior mean); ``bucket_sizes`` the
    ascending padding buckets the request micro-batcher compiles for;
    ``max_staleness`` the SLO bound in training windows (None = unbounded)
    enforced under ``staleness_policy`` (``"strict"`` refuses with
    ``serve.StalenessSLOError``, ``"flag"`` serves with ``slo_ok=False``).
    """

    snapshot_dtype: str = "f32"  # f32 | bf16 | f16: snapshot residency
    mc_samples: int = 8
    bucket_sizes: Sequence[int] = (1, 2, 4, 8, 16, 32)
    max_staleness: int | None = None  # SLO bound in windows (None = off)
    staleness_policy: str = "strict"  # strict | flag

    def __post_init__(self):
        # normalize to tuple so from_doc(to_doc(spec)) == spec (the doc
        # format lowers tuples to lists)
        object.__setattr__(self, "bucket_sizes", tuple(
            int(b) for b in self.bucket_sizes
        ))

    def validate(self) -> None:
        if self.snapshot_dtype not in ("f32", "bf16", "f16"):
            raise ValueError(
                f"unknown snapshot_dtype {self.snapshot_dtype!r}; known: "
                "f32 | bf16 | f16"
            )
        if self.mc_samples < 0:
            raise ValueError("mc_samples must be >= 0 (0 = point estimate)")
        if (not self.bucket_sizes
                or any(b <= 0 for b in self.bucket_sizes)
                or list(self.bucket_sizes) != sorted(set(self.bucket_sizes))):
            raise ValueError(
                "bucket_sizes must be a strictly ascending sequence of "
                f"positive ints, got {self.bucket_sizes!r}"
            )
        if self.max_staleness is not None and self.max_staleness < 0:
            raise ValueError("max_staleness must be >= 0 windows (or None)")
        if self.staleness_policy not in ("strict", "flag"):
            raise ValueError(
                f"unknown staleness_policy {self.staleness_policy!r}; "
                "known: strict | flag"
            )


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """The observability contract (ROADMAP "Observability"; ``repro.obs``).

    OFF by default, and a pure observer when on: enabling observability
    never changes trajectories, jit trace counts, or checkpoint leaf
    structure (pinned by ``tests/test_obs.py``).  With ``enabled=True`` the
    session carries an ``Observability`` bundle (``session.obs``): a
    ``MetricsRegistry`` every telemetry number lands in, a wall-clock
    ``Tracer`` over the round lifecycle (``trace``), and a
    ``ConvergenceTracker`` sampling network disagreement / KL-to-network-
    mean every ``convergence_every`` rounds (``convergence``) — overlaid
    against ``core.theory``'s predicted decay for static topologies.
    ``jsonl_path`` streams metric events and spans to an append-only JSONL
    file.  ``session.dashboard()`` renders the compact terminal summary.
    """

    enabled: bool = False
    trace: bool = True  # wall-clock spans (compile-vs-warm attributed)
    convergence: bool = True  # per-round disagreement/KL tracking
    convergence_every: int = 1  # rounds between convergence samples
    jsonl_path: str | None = None  # stream events/spans to this JSONL file

    def validate(self) -> None:
        if self.convergence_every < 1:
            raise ValueError("convergence_every must be >= 1 (rounds)")
        if self.jsonl_path is not None and not isinstance(self.jsonl_path, str):
            raise ValueError("jsonl_path must be a path string or None")


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Run envelope: length, seed, engine, eval cadence."""

    n_rounds: int = 20
    seed: int = 0
    engine: str = "simulated"  # simulated | launch | gossip
    eval_every: int = 0
    jit: bool = True

    def validate(self) -> None:
        if self.engine not in ("simulated", "launch", "gossip"):
            raise ValueError(f"unknown engine {self.engine!r}")
        if self.n_rounds < 0:
            raise ValueError("n_rounds must be nonnegative")


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One experiment = topology x data x inference x run (+ serving,
    observability)."""

    topology: TopologySpec = dataclasses.field(default_factory=TopologySpec)
    data: DataSpec = dataclasses.field(default_factory=DataSpec)
    inference: InferenceSpec = dataclasses.field(default_factory=InferenceSpec)
    run: RunSpec = dataclasses.field(default_factory=RunSpec)
    serve: ServeSpec = dataclasses.field(default_factory=ServeSpec)
    obs: ObsSpec = dataclasses.field(default_factory=ObsSpec)

    def validate(self) -> None:
        self.data.validate()
        self.inference.validate()
        self.run.validate()
        self.serve.validate()
        self.obs.validate()
        if self.inference.method == "conjugate_linreg" and self.data.dataset != "linreg":
            raise ValueError("conjugate_linreg inference requires dataset='linreg'")
        if self.data.dataset == "linreg" and self.inference.method != "conjugate_linreg":
            raise ValueError("dataset='linreg' requires method='conjugate_linreg'")
        if self.inference.method == "conjugate_linreg" and self.run.engine == "launch":
            raise ValueError("the launch engine backs Bayes-by-Backprop inference only")
        # "gossiping" = the GossipEngine drives the run: a dense gossip
        # topology, or a sparse topology with an edge-native clock attached
        gossiping = (self.topology.kind == "gossip"
                     or (self.topology.kind == "sparse"
                         and self.topology.clock is not None))
        if gossiping:
            if self.run.engine == "launch":
                raise ValueError(
                    "a gossip topology runs on the GossipEngine (engine="
                    "'gossip' or the 'simulated' default, auto-upgraded); "
                    "the launch engine is synchronous"
                )
            if self.inference.method == "conjugate_linreg":
                raise ValueError(
                    "the gossip runtime backs Bayes-by-Backprop inference only"
                )
        elif self.run.engine == "gossip":
            raise ValueError(
                "engine='gossip' requires a TopologySpec(kind='gossip') "
                "or kind='sparse' with a clock "
                "(the event windows come from the activation clock)"
            )
        if (self.inference.history_dtype is not None
                and self.topology.kind != "gossip"):
            raise ValueError(
                "history_dtype controls the delayed-gossip posterior "
                "history ring and requires a TopologySpec(kind='gossip') "
                "with a delayed clock (it would be silently ignored "
                "otherwise)"
            )
        if self.inference.fault_policy != "strict" and not gossiping:
            raise ValueError(
                "fault_policy='quarantine' guards the gossip consensus "
                "exchange and requires a TopologySpec(kind='gossip') (the "
                "synchronous engines have no exchange boundary to validate)"
            )
        if self.inference.consensus_impl != "auto":
            if not gossiping:
                raise ValueError(
                    "consensus_impl selects the gossip window execution and "
                    "requires a TopologySpec(kind='gossip') or kind='sparse' "
                    "with a clock; the synchronous engines dispatch via "
                    "core.posterior.consensus_all_agents"
                )
            if (self.inference.consensus_impl == "ppermute"
                    and self.inference.consensus != "gaussian"):
                raise ValueError(
                    "consensus_impl='ppermute' shards the gaussian eq.-(6) "
                    "window; mean_only/none consensus run the dense path"
                )
            if (self.inference.consensus_impl == "segments"
                    and self.topology.kind != "sparse"):
                raise ValueError(
                    "consensus_impl='segments' executes edge-native "
                    "SparseWindows and requires a TopologySpec(kind="
                    "'sparse') with a clock (dense gossip clocks emit "
                    "[N, N] EventWindows — use 'masked' or 'ppermute')"
                )
            if (self.inference.consensus_impl == "segments"
                    and self.inference.consensus == "mean_only"):
                raise ValueError(
                    "consensus_impl='segments' implements gaussian/none "
                    "consensus; mean_only (the FedAvg baseline) runs on "
                    "the dense masked path"
                )
        self.topology.validate()

    # -- checkpoint doc (msgpack-able plain data) ----------------------------

    def to_doc(self) -> dict:
        if self.topology.kind == "callable":
            raise ValueError(
                "a callable topology schedule cannot be embedded in a "
                "checkpoint; use kind='schedule' (materialized W list) for "
                "resumable runs"
            )
        doc = dataclasses.asdict(self)
        return _plainify(doc)

    @classmethod
    def from_doc(cls, doc: dict) -> "ExperimentSpec":
        topo = dict(doc["topology"])
        if topo.get("w") is not None:
            topo["w"] = np.asarray(topo["w"], np.float64)
        if topo.get("schedule") is not None:
            topo["schedule"] = [np.asarray(m, np.float64) for m in topo["schedule"]]
        return cls(
            topology=TopologySpec(**topo),
            data=DataSpec(**doc["data"]),
            inference=InferenceSpec(**doc["inference"]),
            run=RunSpec(**doc["run"]),
            # absent in pre-serving / pre-observability checkpoints: defaults
            serve=ServeSpec(**doc.get("serve") or {}),
            obs=ObsSpec(**doc.get("obs") or {}),
        )


def _plainify(node):
    """Recursively lower numpy arrays/scalars and tuples to msgpack-able
    lists/py-scalars (the checkpoint document format)."""
    if isinstance(node, dict):
        return {k: _plainify(v) for k, v in node.items()}
    if isinstance(node, (list, tuple)):
        return [_plainify(v) for v in node]
    if isinstance(node, np.ndarray):
        return _plainify(node.tolist())
    if isinstance(node, np.generic):
        return node.item()
    if node is None or isinstance(node, (bool, int, float, str)):
        return node
    raise TypeError(f"spec field of type {type(node)} is not checkpoint-embeddable")
