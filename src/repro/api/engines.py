"""Engine implementations behind ``api.Session``.

An Engine owns the state layout and the per-round transition; the Session
owns the loop, the data, and the RNG stream.  All engines run the SAME
paper round (u local updates against the round prior, then eq.-(6)
consensus) on the SAME key derivation, so their posteriors agree to
numerical precision — enforced by the engine-equivalence test:

* ``SimulatedEngine`` — the ``core.simulated`` flat runtime: one jitted
  ``round_fn`` (vmap over agents, scan over local steps), consensus as the
  single fused network-wide pass.  The default.
* ``LaunchEngine`` — the production path: ``launch.steps.make_local_step`` /
  ``make_consensus_step`` on a ``BayesTrainState`` whose posterior is a
  ``FlatPosterior`` end-to-end (the ROADMAP "drive the flat runtime through
  the launch path" item).  Same math, production step functions.
* ``ConjugateLinregEngine`` — paper Example 1: exact conjugate
  full-covariance updates + eq.-(6) full-covariance consensus.
* ``repro.gossip.engine.GossipEngine`` — the event-driven asynchronous
  runtime (selected by ``TopologySpec(kind="gossip")``): one event window
  per round, masked active-edge consensus, staleness telemetry.  An engine
  may additionally expose ``telemetry(state) -> dict``; ``Session.evaluate``
  merges it into its result.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.data import DataBundle
from repro.api.models import ModelFns
from repro.api.spec import ExperimentSpec
from repro.core.flat import FlatPosterior
from repro.core.posterior import (
    FullCovGaussian,
    consensus_full_cov,
    linreg_bayes_update,
)
from repro.core.simulated import init_network, make_round_fn
from repro.optim import Optimizer, adam, sgd
from repro.optim.schedules import Schedule, constant_schedule, exponential_decay

PyTree = Any


class Engine(Protocol):
    """Contract between ``Session`` and a runtime.

    ``init(key) -> state``; ``run_round(state, batches, W, key) ->
    (state, per_agent_losses)``; ``posterior(state)`` -> the network
    posterior (``FlatPosterior`` for the BbB engines).  State must be a
    pytree (it is checkpointed leaf-wise with the spec doc riding along).
    """

    name: str

    def init(self, key: jax.Array) -> Any: ...

    def run_round(
        self, state: Any, batches: Any, W: jax.Array, key: jax.Array
    ) -> tuple[Any, jax.Array]: ...

    def posterior(self, state: Any) -> Any: ...


def build_optimizer(name: str) -> Optimizer:
    return {"adam": adam, "sgd": sgd}[name]()


def build_schedule(lr: float, decay: float) -> Schedule:
    if decay == 1.0:
        return constant_schedule(lr)
    return exponential_decay(lr, decay)


class SimulatedEngine:
    """``core.simulated`` flat runtime behind the Engine protocol."""

    name = "simulated"

    def __init__(self, spec: ExperimentSpec, model: ModelFns, n_agents: int):
        inf = spec.inference
        self.n_agents = n_agents
        self.model = model
        self.opt = build_optimizer(inf.optimizer)
        self.init_sigma = inf.init_sigma
        self.shared_init = inf.shared_init
        round_fn = make_round_fn(
            model.nll_fn,
            self.opt,
            build_schedule(inf.lr, inf.lr_decay),
            n_mc_samples=inf.n_mc_samples,
            kl_scale=inf.kl_scale,
            consensus=inf.consensus,
            wire_dtype=inf.wire_dtype,
        )
        self._round = jax.jit(round_fn) if spec.run.jit else round_fn

    def init(self, key: jax.Array):
        return init_network(
            key,
            self.n_agents,
            self.model.init_fn,
            self.opt,
            init_sigma=self.init_sigma,
            shared_init=self.shared_init,
            flat=True,
        )

    def run_round(self, state, batches, W, key):
        return self._round(state, batches, jnp.asarray(W), key)

    def posterior(self, state) -> FlatPosterior:
        return state.posterior


class LaunchEngine:
    """Production ``launch.steps`` path behind the Engine protocol.

    The hot loop is flat end-to-end: ``BayesTrainState.posterior`` is a
    ``FlatPosterior``, the local VI step samples/updates the [A, P] buffers
    (pytree only inside the model apply), and ``make_consensus_step``
    dispatches to the fused network-wide consensus.  The key derivation
    mirrors ``simulated.make_round_fn`` exactly (per-agent keys, then
    per-local-step, then per-MC-sample), so both engines produce the same
    posterior from the same Session stream.
    """

    name = "launch"

    def __init__(self, spec: ExperimentSpec, model: ModelFns, n_agents: int):
        from repro.launch.steps import make_consensus_step, make_local_step

        inf = spec.inference
        if inf.consensus == "mean_only":
            raise ValueError(
                "the launch engine implements gaussian/none consensus; "
                "mean_only (the FedAvg baseline) runs on the simulated engine"
            )
        self.n_agents = n_agents
        self.model = model
        self.opt = build_optimizer(inf.optimizer)
        self.init_sigma = inf.init_sigma
        self.shared_init = inf.shared_init
        self.consensus_mode = inf.consensus
        self.u = spec.data.local_updates
        base_sched = build_schedule(inf.lr, inf.lr_decay)
        # the paper decays lr per communication ROUND; the launch step
        # counter ticks per LOCAL step
        u = self.u
        local_step = make_local_step(
            None,
            self.opt,
            lambda step: base_sched(step // u),
            kl_scale=inf.kl_scale,
            nll_fn=model.nll_fn,
            n_mc_samples=inf.n_mc_samples,
        )
        wire_dtype = inf.wire_dtype
        consensus = lambda post, W: make_consensus_step(
            None, W, wire_dtype=wire_dtype
        )(post)
        if spec.run.jit:
            local_step = jax.jit(local_step)
            consensus = jax.jit(consensus)
        self._local_step = local_step
        self._consensus = consensus

    def init(self, key: jax.Array):
        from repro.launch.steps import BayesTrainState

        ns = init_network(
            key,
            self.n_agents,
            self.model.init_fn,
            self.opt,
            init_sigma=self.init_sigma,
            shared_init=self.shared_init,
            flat=True,
        )
        return BayesTrainState(
            posterior=ns.posterior,
            opt_state=ns.opt_state,
            step=jnp.asarray(0, jnp.int32),
        )

    def run_round(self, state, batches, W, key):
        u = jax.tree.leaves(batches)[0].shape[1]
        # per-(agent, local-step) keys, exactly as simulated.make_round_fn:
        # split over agents first, then over the u local steps
        agent_keys = jax.random.split(key, self.n_agents)
        step_keys = jax.vmap(lambda k: jax.random.split(k, u))(agent_keys)
        prior = state.posterior  # q_i^{(n-1)}: consensus result of last round
        losses = []
        for t in range(u):
            batch_t = jax.tree.map(lambda x: x[:, t], batches)
            state, loss_t = self._local_step(state, prior, batch_t, step_keys[:, t])
            losses.append(loss_t)
        post = state.posterior
        if self.consensus_mode == "gaussian":
            post = self._consensus(post, jnp.asarray(W))
        state = dataclasses.replace(state, posterior=post)
        return state, jnp.mean(jnp.stack(losses), axis=0)

    def posterior(self, state) -> FlatPosterior:
        return state.posterior


class ConjugateLinregEngine:
    """Paper Example 1: exact conjugate Bayesian linear regression (eq. 2)
    with full-covariance consensus (eq. 6)."""

    name = "conjugate_linreg"

    def __init__(self, spec: ExperimentSpec, data: DataBundle):
        self.n_agents = data.n_agents
        self.d = data.dim
        self.noise_var = float(data.dataset.noise_std) ** 2
        self.prior_var = spec.inference.prior_var
        self.consensus_mode = spec.inference.consensus

        def round_fn(posts: FullCovGaussian, batches, W):
            upd = jax.vmap(
                lambda m, p, phi, y: linreg_bayes_update(
                    FullCovGaussian(m, p), phi, y, self.noise_var
                )
            )(posts.mean, posts.prec, batches["phi"], batches["y"])
            if self.consensus_mode != "none":
                upd = consensus_full_cov(upd, W)
            err = jnp.einsum("nbd,nd->nb", batches["phi"], upd.mean) - batches["y"]
            return upd, jnp.mean(jnp.square(err), axis=-1)

        self._round = jax.jit(round_fn) if spec.run.jit else round_fn

    def init(self, key: jax.Array) -> FullCovGaussian:
        del key  # the conjugate prior is deterministic
        n, d = self.n_agents, self.d
        return FullCovGaussian(
            mean=jnp.zeros((n, d)),
            prec=jnp.broadcast_to(jnp.eye(d) / self.prior_var, (n, d, d)),
        )

    def run_round(self, state, batches, W, key):
        del key
        return self._round(state, batches, jnp.asarray(W))

    def posterior(self, state) -> FullCovGaussian:
        return state
