"""Model registry for the declarative API.

The paper's NN experiments all use a small ReLU MLP trained with
Bayes-by-Backprop (Sec 4.2: 2 hidden layers, 200 units on MNIST).  The
registry maps ``InferenceSpec.model`` names to a ``ModelFns`` triple; the
input/output dimensions always come from the ``DataSpec`` at
``build_session`` time, so spec and dataset cannot disagree on shapes.

Everything here keeps the PYTREE parameter signature — the flat runtime
wraps ``nll_fn`` through ``FlatLayout.unflatten`` at the model-apply
boundary (``core.flat.make_flat_nll``), never the other way around.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelFns:
    """(init, logits, nll) for one model family at fixed dimensions."""

    init_fn: Callable[[jax.Array], PyTree]
    logits_fn: Callable[[PyTree, jax.Array], jax.Array]
    nll_fn: Callable[[PyTree, Any], jax.Array]


def mlp_init(dim: int, hidden: int, n_classes: int, depth: int = 2):
    """``depth``-hidden-layer ReLU MLP, 1/sqrt(fan_in) init (the paper's
    architecture; ``depth=2`` matches Sec 4.2 / the benchmark drivers)."""

    sizes = [dim] + [hidden] * depth + [n_classes]

    def init(key):
        ks = jax.random.split(key, len(sizes) - 1)
        params = {}
        for i, (k, fan_in, fan_out) in enumerate(zip(ks, sizes[:-1], sizes[1:]), 1):
            params[f"w{i}"] = jax.random.normal(k, (fan_in, fan_out)) / np.sqrt(fan_in)
            params[f"b{i}"] = jnp.zeros((fan_out,))
        return params

    return init


def mlp_logits(theta: PyTree, x: jax.Array) -> jax.Array:
    n_layers = len(theta) // 2
    h = x
    for i in range(1, n_layers):
        h = jax.nn.relu(h @ theta[f"w{i}"] + theta[f"b{i}"])
    return h @ theta[f"w{n_layers}"] + theta[f"b{n_layers}"]


def mlp_nll(theta: PyTree, batch: dict) -> jax.Array:
    """Total (summed) softmax cross-entropy over the batch."""
    logits = mlp_logits(theta, batch["x"])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][..., None], axis=-1)[..., 0]
    return jnp.sum(logz - gold)


def _build_mlp(dim: int, n_classes: int, hidden: int, depth: int) -> ModelFns:
    return ModelFns(
        init_fn=mlp_init(dim, hidden, n_classes, depth=depth),
        logits_fn=mlp_logits,
        nll_fn=mlp_nll,
    )


MODELS: dict[str, Callable[..., ModelFns]] = {
    "mlp": _build_mlp,
}


def build_model(name: str, dim: int, n_classes: int, *, hidden: int, depth: int) -> ModelFns:
    if name not in MODELS:
        raise ValueError(f"unknown model {name!r}; known: {sorted(MODELS)}")
    return MODELS[name](dim, n_classes, hidden=hidden, depth=depth)
