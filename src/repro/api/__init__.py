"""``repro.api`` — the declarative front door (the supported entry point).

One ``ExperimentSpec`` describes a full decentralized-Bayesian-learning
experiment (topology x data x inference x run); ``build_session`` validates
it eagerly and returns an engine-backed ``Session``:

    from repro.api import (
        DataSpec, ExperimentSpec, InferenceSpec, RunSpec, TopologySpec,
        build_session,
    )

    spec = ExperimentSpec(
        topology=TopologySpec.star(n_edge=3, a=0.5),
        data=DataSpec(
            dataset_params=dict(n_classes=4, dim=32, n_train_per_class=150),
            partition="star",
            partition_params=dict(center_labels=[1, 2, 3], edge_labels=[0],
                                  n_edge=3),
        ),
        inference=InferenceSpec(hidden=32, depth=1, lr=5e-3),
        run=RunSpec(n_rounds=20, seed=0),
    )
    session = build_session(spec)
    session.run()
    print(session.evaluate())

Engines: ``RunSpec.engine="simulated"`` (flat vmap runtime, default) or
``"launch"`` (production ``launch.steps`` on the flat posterior); the
conjugate linear-regression family of paper Example 1 is selected by
``InferenceSpec(method="conjugate_linreg")``; a
``TopologySpec(kind="gossip", clock=...)`` selects the event-driven
asynchronous ``GossipEngine`` (``repro.gossip``) — one Poisson/trace event
window per round, active-edge masked consensus, staleness telemetry in
``Session.evaluate``.

Serving (``repro.serve``): ``session.snapshot()`` publishes the consensus
posterior into an immutable double-buffered serving copy (``ServeSpec``
picks residency/defaults) and ``session.attach_server()`` returns a
``PredictiveServer`` — batched MC-predictive inference under a
bounded-staleness SLO (see ``examples/serve_batched.py``).
"""
from repro.api.data import DataBundle, build_data
from repro.api.engines import (
    ConjugateLinregEngine,
    Engine,
    LaunchEngine,
    SimulatedEngine,
)
from repro.api.models import MODELS, ModelFns, build_model, mlp_init, mlp_logits, mlp_nll
from repro.api.session import Session, build_session
from repro.gossip.engine import GossipEngine
from repro.api.spec import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    ObsSpec,
    RunSpec,
    ServeSpec,
    TopologySpec,
)

__all__ = [
    "ConjugateLinregEngine",
    "DataBundle",
    "DataSpec",
    "Engine",
    "ExperimentSpec",
    "GossipEngine",
    "InferenceSpec",
    "LaunchEngine",
    "MODELS",
    "ModelFns",
    "ObsSpec",
    "RunSpec",
    "ServeSpec",
    "Session",
    "SimulatedEngine",
    "TopologySpec",
    "build_data",
    "build_model",
    "build_session",
    "mlp_init",
    "mlp_logits",
    "mlp_nll",
]
