"""DataSpec -> concrete data: dataset, per-agent shards, round sampler,
held-out test set.  One builder per dataset family; every builder enforces
the spec/topology agent-count agreement eagerly."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.spec import DataSpec
from repro.data import linreg as linreg_mod
from repro.data import partition as partition_mod
from repro.data import synthetic
from repro.data.pipeline import AgentDataset, make_round_batches

_DATASETS = {
    "synthetic_classification": synthetic.make_synthetic_classification,
    "mnist_like": synthetic.mnist_like,
    "fmnist_like": synthetic.fmnist_like,
}


@dataclasses.dataclass
class DataBundle:
    """Concrete data behind a Session: sampler(key, round) -> batches pytree
    with leading [N, u, B] axes, plus the test set for ``evaluate``."""

    kind: str  # "classification" | "linreg"
    n_agents: int
    sampler: Callable[[jax.Array, int], Any]
    x_test: np.ndarray | None = None
    y_test: np.ndarray | None = None
    dim: int = 0
    n_classes: int = 0
    dataset: Any = None  # the underlying SyntheticClassification / LinRegTask
    test_phi: np.ndarray | None = None  # linreg global test features
    test_y: np.ndarray | None = None


def _partition(spec: DataSpec, ds) -> list:
    params = dict(spec.partition_params)
    if spec.partition == "iid":
        return partition_mod.partition_iid(ds.x_train, ds.y_train, **params)
    if spec.partition == "by_label":
        return partition_mod.partition_by_label(ds.x_train, ds.y_train, **params)
    if spec.partition == "star":
        return partition_mod.star_partition(ds.x_train, ds.y_train, **params)
    if spec.partition == "grid":
        return partition_mod.grid_partition(ds.x_train, ds.y_train, **params)
    raise ValueError(f"unknown partition {spec.partition!r}")


def build_data(spec: DataSpec, n_agents: int) -> DataBundle:
    if spec.dataset == "linreg":
        return _build_linreg(spec, n_agents)
    ds = _DATASETS[spec.dataset](**dict(spec.dataset_params))
    shards = _partition(spec, ds)
    if len(shards) != n_agents:
        raise ValueError(
            f"partition {spec.partition!r} produced {len(shards)} agent "
            f"shards but the topology has {n_agents} agents"
        )
    data = AgentDataset.from_shards(
        [(x.astype(np.float32), y.astype(np.int32)) for x, y in shards]
    )
    sampler = make_round_batches(data, spec.batch_size, spec.local_updates)
    return DataBundle(
        kind="classification",
        n_agents=n_agents,
        sampler=sampler,
        x_test=ds.x_test,
        y_test=ds.y_test,
        dim=ds.dim,
        n_classes=ds.n_classes,
        dataset=ds,
    )


def _build_linreg(spec: DataSpec, n_agents: int) -> DataBundle:
    params = dict(spec.dataset_params)
    params.setdefault("n_agents", n_agents)
    task = linreg_mod.make_linreg_task(**params)
    if task.n_agents != n_agents:
        raise ValueError(
            f"linreg task has {task.n_agents} agents but the topology has {n_agents}"
        )
    b = spec.batch_size

    def sampler(key: jax.Array, round_idx: int):
        # np-backed task sampling, deterministically keyed per round
        seed = int(jax.random.randint(key, (), 0, np.iinfo(np.int32).max))
        rng = np.random.default_rng(seed)
        phis, ys = [], []
        for i in range(n_agents):
            phi, y = task.sample_local(rng, i, b)
            phis.append(phi)
            ys.append(y)
        return {
            "phi": jnp.asarray(np.stack(phis), jnp.float32),
            "y": jnp.asarray(np.stack(ys), jnp.float32),
        }

    rng_test = np.random.default_rng(10_000)
    phi_t, y_t = task.sample_global(rng_test, 4000)
    return DataBundle(
        kind="linreg",
        n_agents=n_agents,
        sampler=sampler,
        dim=task.d,
        dataset=task,
        test_phi=phi_t,
        test_y=y_t,
    )
