"""``build_session(spec)`` — the supported front door.

Validates an ``ExperimentSpec`` eagerly (topology connectivity and
row-stochasticity, agent-count agreement between topology and partition,
dataset/model shape agreement by construction), builds the data and the
engine, and returns a ``Session``:

    spec = ExperimentSpec(
        topology=TopologySpec.star(n_edge=3, a=0.5),
        data=DataSpec(partition="star", partition_params=...),
        inference=InferenceSpec(hidden=32),
        run=RunSpec(n_rounds=20, seed=0),
    )
    session = build_session(spec)
    session.run()                    # the whole experiment, or
    session.round()                  # one communication round at a time
    session.evaluate()               # per-agent test metrics (MC predictive)
    session.save("exp.ckpt")         # self-describing: spec embedded
    session = Session.load("exp.ckpt")   # rebuild + resume

The engine behind the session (``RunSpec.engine``) is swappable without
touching the loop: ``simulated`` (flat vmap runtime) or ``launch``
(production step functions) — plus the conjugate linear-regression engine,
selected automatically by ``InferenceSpec.method``.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.data import DataBundle, build_data
from repro.api.engines import (
    ConjugateLinregEngine,
    Engine,
    LaunchEngine,
    SimulatedEngine,
)
from repro.api.models import ModelFns, build_model
from repro.api.spec import ExperimentSpec
from repro.core.simulated import as_w_schedule
from repro.gossip.engine import GossipEngine
from repro.vi.bayes_by_backprop import mc_predict


def build_session(spec: ExperimentSpec) -> "Session":
    """Validate ``spec`` eagerly and return a ready-to-run ``Session``."""
    spec.validate()
    n_agents = spec.topology.n_agents()
    data = build_data(spec.data, n_agents)

    model: ModelFns | None = None
    if spec.inference.method == "conjugate_linreg":
        engine: Engine = ConjugateLinregEngine(spec, data)
    else:
        model = build_model(
            spec.inference.model,
            data.dim,
            data.n_classes,
            hidden=spec.inference.hidden,
            depth=spec.inference.depth,
        )
        if spec.topology.kind == "gossip" or (
            spec.topology.kind == "sparse"
            and spec.topology.clock is not None
        ):
            # a gossip topology IS an execution model: one event window per
            # round on the GossipEngine (validate() already rejected other
            # explicit engine choices).  A sparse topology with a clock is
            # the edge-native form of the same thing — SparseWindow streams
            # executed through consensus_flat_segments.
            engine = GossipEngine(spec, model, n_agents)
        elif spec.run.engine == "launch":
            engine = LaunchEngine(spec, model, n_agents)
        else:
            engine = SimulatedEngine(spec, model, n_agents)

    key = jax.random.key(spec.run.seed)
    key, k_init = jax.random.split(key)
    state = engine.init(k_init)
    obs = None
    if spec.obs.enabled:
        from repro.obs import Observability

        obs = Observability.from_spec(spec)
        # engines expose a host-side hook; attaching is a pure-observer
        # operation (the engine only reads it at dispatch boundaries)
        engine.obs = obs
    return Session(
        spec=spec,
        engine=engine,
        model=model,
        data=data,
        state=state,
        key=key,
        round_idx=0,
        _obs=obs,
    )


_NO_SPAN = contextlib.nullcontext()


def _span(obs, name: str, **attrs):
    """A tracer span when observability is on, else a shared no-op
    context (one ``is None`` check on the uninstrumented path)."""
    return obs.tracer.span(name, **attrs) if obs is not None else _NO_SPAN


@dataclasses.dataclass
class Session:
    """A running experiment: engine-backed state + the round loop."""

    spec: ExperimentSpec
    engine: Engine
    model: ModelFns | None
    data: DataBundle
    state: Any
    key: jax.Array
    round_idx: int = 0
    history: list = dataclasses.field(default_factory=list)
    _w_schedule: Any = dataclasses.field(default=None, repr=False)
    _serve_store: Any = dataclasses.field(default=None, repr=False)
    _server: Any = dataclasses.field(default=None, repr=False)
    _obs: Any = dataclasses.field(default=None, repr=False)

    @property
    def obs(self):
        """The session's ``repro.obs.Observability`` bundle (registry,
        tracer, convergence tracker), or ``None`` when ``spec.obs`` is
        disabled — the default, in which case nothing is recorded and the
        run is bitwise identical to an uninstrumented build."""
        return self._obs

    def _spec_w_schedule(self):
        """The topology's round-indexed W callable, materialized once (the
        schedule list can be expensive to rebuild every round)."""
        if self._w_schedule is None:
            self._w_schedule = self.spec.topology.w_schedule()
        return self._w_schedule

    # -- the loop ------------------------------------------------------------

    def round(self, W=None) -> dict:
        """One communication round (u local steps + consensus).  Returns
        ``{"round", "loss", "n_trained"}``; ``W`` overrides the spec
        topology for this round only (ad-hoc time-varying experiments).

        ``n_trained`` counts agents reporting a finite loss.  Engines whose
        per-agent losses use NaN as a "did not train this round" sentinel
        (gossip wake-on-event) aggregate over the trained agents only, and
        an ALL-IDLE window (a zero-event window under
        ``local_policy="active"``) reports ``loss=None`` / ``n_trained=0``
        instead of silently writing NaN into the history; for the
        synchronous engines a NaN loss stays a loud NaN (divergence
        signal).

        Fault-aware engines (a gossip clock with a ``"faults"`` model)
        additionally report ``n_crashed`` — agents down this window.  A
        crashed agent skips local training, so its NaN sentinel loss is
        already excluded from the ``loss`` mean like any idle agent's.

        With observability enabled (``spec.obs``) the round is wrapped in a
        ``session.round`` tracer span — END-TO-END accurate wall clock (the
        loss materialization below synchronizes with the device) with
        compile-vs-warm attribution from the engine's retrace counter — and
        the loop counters/gauges land in the metrics registry.  All of it
        observes values this method computes anyway: the training math is
        identical either way (pinned by tests/test_obs.py)."""
        obs = self._obs
        if obs is None:
            return self._round_impl(W)
        tr = obs.tracer
        n_traces0 = getattr(self.engine, "n_traces", None)
        first = obs.registry.counter("session.rounds").value() == 0
        with tr.span("session.round", round=self.round_idx):
            rec = self._round_impl(W)
        if tr.enabled and tr.spans:
            retraced = (n_traces0 is not None
                        and getattr(self.engine, "n_traces") > n_traces0)
            if retraced or (n_traces0 is None and first):
                tr.spans[-1].attrs["compile"] = True
        self._obs_after_round(rec)
        return rec

    def _round_impl(self, W=None) -> dict:
        r = self.round_idx
        if W is None:
            with _span(self._obs, "session.w_build", round=r):
                W = self._spec_w_schedule()(r)
        self.key, k_batch, k_round = jax.random.split(self.key, 3)
        with _span(self._obs, "session.batches", round=r):
            batches = self.data.sampler(k_batch, r)
        # engines that declare wants_host_w take the schedule value VERBATIM
        # (the GossipEngine: host float64 w_eff for the exact active-mask /
        # f64 schedule-identity checks, or a SparseWindow object on the
        # edge-native path — jnp.asarray would round to f32 / reject it);
        # they cast to the device themselves, after the host-side work
        w_arg = (W if getattr(self.engine, "wants_host_w", False)
                 else jnp.asarray(W))
        self.state, losses = self.engine.run_round(
            self.state, batches, w_arg, k_round
        )
        self.round_idx = r + 1
        losses = np.asarray(losses)
        n_trained = int(np.isfinite(losses).sum())
        if getattr(self.engine, "loss_nan_is_sentinel", False):
            loss = float(np.nanmean(losses)) if n_trained else None
        else:
            loss = float(losses.mean())
        rec = {"round": self.round_idx, "loss": loss, "n_trained": n_trained}
        crashed = getattr(self.engine, "last_crashed", None)
        if crashed is not None:
            rec["n_crashed"] = int(np.asarray(crashed).sum())
        return rec

    def _obs_after_round(self, rec: dict) -> None:
        """Post-round registry/convergence bookkeeping (obs enabled only).
        Pure observer: reads ``rec`` and (on convergence-sample rounds) the
        posterior buffers."""
        obs = self._obs
        reg = obs.registry
        reg.counter("session.rounds", "communication rounds run").inc()
        reg.gauge("session.n_trained", "agents trained last round").set(
            rec["n_trained"]
        )
        if rec["loss"] is not None:
            reg.gauge("session.loss", "mean trained-agent loss").set(
                rec["loss"]
            )
            reg.histogram("session.loss_dist", "per-round loss").observe(
                rec["loss"]
            )
        if "n_crashed" in rec:
            reg.counter(
                "session.crashed_agent_windows", "agent-windows down"
            ).inc(rec["n_crashed"])
        conv = obs.convergence
        if conv is not None and (
            (rec["round"] - 1) % obs.spec.convergence_every == 0
        ):
            with obs.tracer.span("obs.convergence", round=rec["round"]):
                stats = conv.update(self.posterior(), rec["round"])
            reg.ingest("convergence", stats)

    def run(
        self,
        n_rounds: int | None = None,
        w_schedule=None,
        eval_fn: Callable[["Session"], dict] | None = None,
        eval_every: int | None = None,
    ) -> list[dict]:
        """Run ``n_rounds`` rounds (default: ``spec.run.n_rounds``).

        ``w_schedule`` overrides the spec topology and accepts all three
        forms — a static W, a list cycled over rounds, or a round-indexed
        ``Callable[[int], W]``.  The override is PER CALL and is not
        checkpointed: a session restored via ``Session.load`` resumes on the
        spec topology, so put a resumable schedule in the spec itself
        (``TopologySpec(kind="schedule", ...)``).  ``eval_fn(session)`` is
        merged into the history every ``eval_every`` rounds (default
        ``spec.run.eval_every``; always on the final round when enabled).
        """
        n = self.spec.run.n_rounds if n_rounds is None else n_rounds
        w_for_round = (
            as_w_schedule(w_schedule)
            if w_schedule is not None
            else self._spec_w_schedule()
        )
        eval_every = (
            self.spec.run.eval_every if eval_every is None else eval_every
        )
        history: list[dict] = []
        with _span(self._obs, "session.run", n_rounds=n):
            for i in range(n):
                rec = self.round(W=w_for_round(self.round_idx))
                if eval_every and ((i + 1) % eval_every == 0 or i == n - 1):
                    if eval_fn is not None:
                        rec.update(eval_fn(self))
                    history.append(rec)
        self.history.extend(history)
        return history

    # -- results -------------------------------------------------------------

    def posterior(self):
        """The network posterior (``FlatPosterior`` [N, P] for BbB engines,
        stacked ``FullCovGaussian`` for the conjugate linreg engine)."""
        return self.engine.posterior(self.state)

    def agent_posterior(self, agent: int):
        """One agent's posterior (leading agent axis indexed away)."""
        return jax.tree.map(lambda l: l[agent], self.posterior())

    def predictive(self, agent: int, x, n_mc: int = 8, key=None):
        """MC predictive class probabilities for one agent (paper Sec 4.2).

        ``n_mc=0`` is the deterministic point estimate: one softmax at the
        posterior MEAN (the paper's L=1 serving fast path / the non-Bayesian
        confidence baseline) — no sampling, ``key`` ignored."""
        if self.model is None:
            raise ValueError("predictive() requires a classification model")
        post = self.agent_posterior(agent)
        if n_mc == 0:
            from repro.core.flat import FlatPosterior

            mean = (post.layout.unflatten(post.mean)
                    if isinstance(post, FlatPosterior) else post.mean)
            return jax.nn.softmax(self.model.logits_fn(mean, jnp.asarray(x)), -1)
        key = jax.random.key(97) if key is None else key
        return mc_predict(
            post, self.model.logits_fn, jnp.asarray(x), key, n_mc=n_mc,
        )

    # -- serving (ROADMAP "Serving"; repro.serve) ----------------------------

    @property
    def serve_store(self):
        """The session's ``serve.SnapshotStore`` (lazy; clock = the round
        counter, so snapshot AGE is measured in training windows)."""
        if self._serve_store is None:
            from repro.serve import SnapshotStore

            self._serve_store = SnapshotStore(clock=lambda: self.round_idx)
        return self._serve_store

    def snapshot(self, dtype=None):
        """Publish the consensus posterior into the serving double buffer.

        Copies the live ``FlatPosterior`` into an immutable
        ``PosteriorSnapshot`` (optionally ``dtype="bf16"``-resident — half
        the serving HBM; default: ``spec.serve.snapshot_dtype``), stamps it
        with the current window index and the engine's gossip telemetry
        (``snapshot_meta``: staleness percentiles, quarantine counts), and
        atomically swaps it in as the served front buffer.  Pure READ of
        training state: a run with serving readers attached stays bitwise
        identical to one without (pinned by tests/test_serve.py)."""
        from repro.core.flat import FlatPosterior

        post = self.posterior()
        if not isinstance(post, FlatPosterior):
            raise ValueError(
                "Session.snapshot() serves flat BbB posteriors; the "
                f"{type(self.engine).__name__} posterior is not a "
                "FlatPosterior"
            )
        if dtype is None:
            dtype = self.spec.serve.snapshot_dtype
        meta_fn = getattr(self.engine, "snapshot_meta", None)
        telemetry = meta_fn(self.state) if meta_fn is not None else {}
        obs = self._obs
        with _span(obs, "serve.publish", window=self.round_idx, dtype=dtype):
            snap = self.serve_store.publish(
                post, window=self.round_idx, dtype=dtype, telemetry=telemetry,
            )
        if obs is not None:
            obs.registry.counter(
                "serve.published", "snapshots published"
            ).inc()
            obs.registry.gauge(
                "serve.snapshot_bytes", "front-buffer residency"
            ).set(snap.nbytes())
        return snap

    def attach_server(self, **overrides):
        """A ``serve.PredictiveServer`` bound to this session's snapshot
        store and model apply.  Defaults come from ``spec.serve``
        (``mc_samples`` / ``bucket_sizes`` / ``max_staleness`` /
        ``staleness_policy``); keyword ``overrides`` win.  The server reads
        only published snapshots — call ``snapshot()`` first (and again
        whenever the served posterior should roll forward).  The attached
        server's telemetry shows up in ``evaluate()``."""
        if self.model is None:
            raise ValueError(
                "attach_server() requires a classification model (the "
                "conjugate linreg engine has no serving path)"
            )
        from repro.serve import PredictiveServer

        s = self.spec.serve
        kwargs = dict(
            mc_samples=s.mc_samples,
            bucket_sizes=s.bucket_sizes,
            max_staleness=s.max_staleness,
            staleness_policy=s.staleness_policy,
        )
        kwargs.update(overrides)
        self._server = PredictiveServer(
            self.serve_store, self.model.logits_fn, **kwargs
        )
        # host-side observer hook: request spans + counters in the registry
        self._server.obs = self._obs
        return self._server

    def health(self) -> dict:
        """Per-agent posterior health probe (ROADMAP "Robustness").

        Flat BbB posteriors run the same finiteness / positivity /
        magnitude validity check the quarantine guard applies at the
        consensus exchange boundary (``core.flat.payload_validity``), so
        ``ok[i]`` is exactly "agent i's posterior would be accepted by a
        quarantined peer".  Other engines (conjugate linreg) fall back to
        an all-leaves-finite probe.  Pure read — no state is modified."""
        post = self.posterior()
        from repro.core.flat import FlatPosterior, payload_validity

        if isinstance(post, FlatPosterior):
            ok = np.asarray(payload_validity(post.mean, post.rho))
        else:
            flags = [
                np.isfinite(
                    np.asarray(leaf).reshape(np.asarray(leaf).shape[0], -1)
                ).all(axis=1)
                for leaf in jax.tree.leaves(post)
            ]
            ok = np.logical_and.reduce(flags)
        return {
            "ok": [bool(v) for v in ok],
            "n_healthy": int(ok.sum()),
            "all_ok": bool(ok.all()),
        }

    def evaluate(self, n_mc: int = 4, key=None) -> dict:
        """Held-out test metrics per agent: MC-predictive accuracy for
        classification, global-test MSE for linreg.  Engines exposing a
        ``telemetry(state)`` hook (the gossip runtime: staleness percentiles,
        merge counts, fault/quarantine counters) contribute an ``"engine"``
        block, and a serving tier (published snapshots / an attached
        ``PredictiveServer``) a ``"serving"`` block — snapshot
        age/version/bytes and SLO breach counts next to the fault and
        staleness metrics.

        Each producer owns its NAMESPACE: engine telemetry lands under
        ``out["engine"]``, never splatted into the top level — a telemetry
        key can therefore never clobber a metric key (or vice versa;
        regression-pinned by tests/test_obs.py).  With observability
        enabled every block is also ingested into the metrics registry
        under the same namespace, so the dashboard/exporter read the exact
        numbers returned here."""
        obs = self._obs
        with _span(obs, "session.evaluate", n_mc=n_mc):
            out = self._evaluate_metrics(n_mc=n_mc, key=key)
            telemetry = getattr(self.engine, "telemetry", None)
            if telemetry is not None:
                out["engine"] = telemetry(self.state)
            if self._server is not None:
                out["serving"] = self._server.telemetry()
            elif self._serve_store is not None:
                out["serving"] = self._serve_store.telemetry()
        if obs is not None:
            for ns in ("engine", "serving"):
                if ns in out:
                    obs.registry.ingest(ns, out[ns])
            for k in ("avg_acc", "avg_mse"):
                if k in out:
                    obs.registry.gauge(f"eval.{k}").set(out[k])
        return out

    def dashboard(self) -> str:
        """Compact terminal summary of the run so far: loop counters, the
        engine's staleness/merge/fault registry reads, serving state, the
        convergence verdict (measured decay rate vs the graph's theoretical
        rate), and the warm/compile span table.  Returns a printable string;
        works with observability disabled (a one-line pointer at
        ``ObsSpec``) so examples can call it unconditionally."""
        lines = [
            f"=== session dashboard · engine={self.engine.name} "
            f"round={self.round_idx} ==="
        ]
        obs = self._obs
        if obs is None:
            lines.append(
                "observability disabled — enable with "
                "ExperimentSpec(obs=ObsSpec(enabled=True))"
            )
            return "\n".join(lines)
        reg = obs.registry
        loss = reg.gauge("session.loss").value()
        n_tr = reg.gauge("session.n_trained").value()
        lines.append(
            f"rounds {int(reg.counter('session.rounds').value())}"
            f"  loss {loss:.4f}  n_trained {int(n_tr)}"
        )
        g_windows = reg.counter("gossip.windows").value()
        if g_windows:
            lines.append(
                f"gossip: windows {int(g_windows)}"
                f"  jit_traces {int(reg.gauge('gossip.jit_traces').value())}"
                f"  staleness p50/p90/max "
                f"{reg.gauge('engine.staleness.p50').value():.0f}/"
                f"{reg.gauge('engine.staleness.p90').value():.0f}/"
                f"{reg.gauge('engine.staleness.max').value():.0f}"
                f"  merges {int(reg.gauge('engine.merges.total').value())}"
            )
        published = reg.counter("serve.published").value()
        if published:
            lines.append(
                f"serving: published {int(published)}"
                f"  snapshot_bytes "
                f"{int(reg.gauge('serve.snapshot_bytes').value())}"
                f"  requests {int(reg.counter('serve.requests').value())}"
                f"  slo_breaches "
                f"{int(reg.gauge('serving.slo.breaches').value())}"
            )
        if obs.convergence is not None and obs.convergence.stats:
            rep = obs.convergence.report()
            latest = rep["latest"]
            line = (
                f"convergence: disagreement {latest['disagreement']:.3e}"
            )
            if "kl_to_mean" in latest:
                line += f"  KL(q_i||q_bar) {latest['kl_to_mean']:.3e}"
            if rep["measured_rate"] is not None:
                line += f"  measured_rate {rep['measured_rate']:.4f}"
            if rep["theory_rate"] is not None:
                line += f"  theory_rate {rep['theory_rate']:.4f}"
            if rep["rate_attainment"] is not None:
                line += f"  rate_attainment {rep['rate_attainment']:.2f}"
            lines.append(line)
        summ = obs.tracer.summary()
        for name in sorted(summ):
            for mode in ("warm", "compile"):
                if mode in summ[name]:
                    s = summ[name][mode]
                    lines.append(
                        f"span {name:<22s} {mode:<7s} n {s['n']:>4d}"
                        f"  p50 {s['p50_us']:>10.1f}us"
                        f"  max {s['max_us']:>10.1f}us"
                    )
        obs.flush()
        return "\n".join(lines)

    def _evaluate_metrics(self, n_mc: int = 4, key=None) -> dict:
        if self.data.kind == "linreg":
            phi_t, y_t = self.data.test_phi, self.data.test_y
            mean = np.asarray(self.posterior().mean)
            mses = [
                float(np.mean((phi_t @ mean[i] - y_t) ** 2))
                for i in range(self.data.n_agents)
            ]
            return {"mse": mses, "avg_mse": float(np.mean(mses))}
        key = jax.random.key(99) if key is None else key
        yt = np.asarray(self.data.y_test)
        accs = []
        for i in range(self.data.n_agents):
            probs = self.predictive(i, self.data.x_test, n_mc=n_mc, key=key)
            pred = np.asarray(jnp.argmax(probs, -1))
            accs.append(float((pred == yt).mean()))
        return {"acc": accs, "avg_acc": float(np.mean(accs))}

    # -- checkpointing -------------------------------------------------------

    def save(self, path: str) -> None:
        """Self-describing checkpoint: the spec doc + engine-state leaves +
        loop counters.  ``Session.load(path)`` needs nothing else.  Only the
        SPEC is persisted — per-call ``run(w_schedule=...)`` overrides are
        not (see ``run``); resume is bit-identical for spec-driven runs."""
        from repro.checkpoint.io import save_session

        save_session(
            path,
            self.spec.to_doc(),
            self.state,
            round_idx=self.round_idx,
            key_data=np.asarray(jax.random.key_data(self.key)),
        )

    @classmethod
    def load(cls, path: str) -> "Session":
        """Rebuild the session from an embedded spec and resume: the engine
        is reconstructed from the spec, then the saved state leaves are
        restored into its (identical) state structure."""
        from repro.checkpoint.io import restore_leaf, restore_session

        spec_doc, leaves, round_idx, key_data = restore_session(path)
        session = build_session(ExperimentSpec.from_doc(spec_doc))
        ref_leaves, treedef = jax.tree.flatten(session.state)
        if len(leaves) != len(ref_leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} state leaves, the rebuilt "
                f"engine expects {len(ref_leaves)}"
            )
        session.state = jax.tree.unflatten(
            treedef,
            [restore_leaf(s, ref) for s, ref in zip(leaves, ref_leaves)],
        )
        session.round_idx = int(round_idx)
        session.key = jax.random.wrap_key_data(jnp.asarray(np.asarray(key_data)))
        return session
