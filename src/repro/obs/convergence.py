"""Theory-vs-measured convergence tracking (paper Theorem 1, live).

The paper's headline result is a RATE: beliefs concentrate like
``exp(-n K)`` with ``K`` a pure function of the graph (``core.theory``).
This module measures the live network's convergence every round and
overlays it against that prediction:

* ``network_stats(mean, rho)`` — ONE fused jitted reduction over the flat
  ``[N, P]`` posterior buffers (the canonical runtime format; no pytree
  round trips, no per-leaf dispatch) producing:

  - ``disagreement``: RMS deviation of the per-agent mean vectors from the
    network average — the consensus residual whose decay slope is the
    measured contraction rate;
  - ``rho_disagreement``: same reduction over the rho buffer;
  - ``kl_to_mean``: mean over agents of ``KL(q_i || q_bar)`` where
    ``q_bar`` is the moment-matched network-average diagonal Gaussian —
    the distribution-level distance the paper's consensus claim is about.

* ``ConvergenceTracker`` — accumulates the per-round stats and reports the
  measured log-linear decay slope next to the theoretical rate: an
  explicit ``K`` (e.g. ``core.theory.rate_K`` from divergence gaps) or,
  for a static W, the spectral consensus rate
  ``core.theory.consensus_contraction_rate(W)``.  ``report()`` returns the
  ``predicted_decay_curve`` overlay anchored at the first measured point
  and the ``rate_attainment`` ratio (measured / theory; ~1.0 means the
  live network contracts exactly as fast as the graph says it must).

The tracker is a pure observer: it only ever READS posterior buffers, and
its jitted reduction is a separate program from the training step, so
enabling it cannot perturb the training math (pinned by
``tests/test_obs.py``).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.numerics import COMPUTE_DTYPE, softplus
from repro.core.theory import consensus_contraction_rate, predicted_decay_curve

_TINY = 1e-30


@jax.jit
def _gaussian_stats(mean: jax.Array, rho: jax.Array):
    """Fused disagreement + KL reduction over the [N, P] buffers."""
    mean = mean.astype(COMPUTE_DTYPE)
    rho = rho.astype(COMPUTE_DTYPE)
    mu_bar = jnp.mean(mean, axis=0, keepdims=True)            # [1, P]
    dev = mean - mu_bar
    disagreement = jnp.sqrt(jnp.mean(jnp.square(dev)))
    rho_bar = jnp.mean(rho, axis=0, keepdims=True)
    rho_dis = jnp.sqrt(jnp.mean(jnp.square(rho - rho_bar)))
    # moment-matched network-average Gaussian: var_bar = mean_i var_i
    var = jnp.square(softplus(rho))                           # [N, P]
    var_bar = jnp.mean(var, axis=0, keepdims=True)            # [1, P]
    # KL(q_i || q_bar) for diagonal Gaussians, summed over P, meaned over N
    ratio = var / var_bar
    kl_per_agent = 0.5 * jnp.sum(
        ratio - 1.0 - jnp.log(ratio) + jnp.square(dev) / var_bar, axis=-1
    )
    return disagreement, rho_dis, jnp.mean(kl_per_agent)


@jax.jit
def _mean_stats(mean: jax.Array):
    """Disagreement-only reduction (posteriors without a rho buffer)."""
    mean = mean.astype(COMPUTE_DTYPE)
    mean = mean.reshape(mean.shape[0], -1)
    mu_bar = jnp.mean(mean, axis=0, keepdims=True)
    return jnp.sqrt(jnp.mean(jnp.square(mean - mu_bar)))


def network_stats(mean, rho=None) -> dict:
    """Per-round network convergence stats from flat buffers (one fused
    jitted reduction; see module docstring for the three quantities)."""
    if rho is not None:
        d, rd, kl = _gaussian_stats(jnp.asarray(mean), jnp.asarray(rho))
        return {
            "disagreement": float(d),
            "rho_disagreement": float(rd),
            "kl_to_mean": float(kl),
        }
    return {"disagreement": float(_mean_stats(jnp.asarray(mean)))}


class ConvergenceTracker:
    """Accumulate per-round network stats; overlay measured decay against
    the theoretical rate.

    ``W``: static mixing matrix — theory rate is
    ``consensus_contraction_rate(W)``.  ``K``: explicit rate (wins over
    ``W``; pass ``core.theory.rate_K(...)`` here for the belief-decay
    overlay).  ``eps``: the Theorem-1 slack forwarded to
    ``predicted_decay_curve``.
    """

    def __init__(self, W=None, K: float | None = None, eps: float = 0.0):
        if K is not None:
            self.theory_rate: float | None = float(K)
        elif W is not None:
            self.theory_rate = consensus_contraction_rate(np.asarray(W))
        else:
            self.theory_rate = None
        self.eps = float(eps)
        self.rounds: list[int] = []
        self.stats: list[dict] = []

    # -- accumulation --------------------------------------------------------

    def update(self, posterior: Any, round_idx: int | None = None) -> dict:
        """Record one round.  ``posterior`` is anything with a flat
        ``[N, P]`` ``.mean`` buffer (``FlatPosterior`` also contributes its
        ``.rho`` for the KL stat); returns the stats dict recorded."""
        mean = getattr(posterior, "mean", None)
        if mean is None or callable(mean):  # raw [N, P] buffer (ndarray.mean
            mean = posterior                # is a method, not a field)
        rho = getattr(posterior, "rho", None)
        rec = network_stats(mean, rho)
        self.rounds.append(
            len(self.rounds) if round_idx is None else int(round_idx)
        )
        self.stats.append(rec)
        return rec

    def series(self) -> dict:
        """Column view: ``{"round": [...], "disagreement": [...], ...}``."""
        out: dict[str, list] = {"round": list(self.rounds)}
        for k in ("disagreement", "rho_disagreement", "kl_to_mean"):
            if self.stats and k in self.stats[0]:
                out[k] = [s[k] for s in self.stats]
        return out

    # -- theory overlay ------------------------------------------------------

    def measured_rate(self, metric: str = "disagreement") -> float | None:
        """Log-linear decay slope of ``metric`` (per round), least-squares
        over the recorded points; None with < 2 usable points or a
        flat/degenerate series."""
        pts = [
            (r, s[metric]) for r, s in zip(self.rounds, self.stats)
            if metric in s and math.isfinite(s[metric]) and s[metric] > _TINY
        ]
        if len(pts) < 2:
            return None
        t = np.asarray([p[0] for p in pts], np.float64)
        logd = np.log(np.asarray([p[1] for p in pts], np.float64))
        slope = np.polyfit(t, logd, 1)[0]
        return float(-slope)

    def overlay(self, metric: str = "disagreement") -> list[dict]:
        """Measured vs predicted rows: the ``predicted_decay_curve`` of the
        theory rate, anchored at the first measured point."""
        if self.theory_rate is None or not self.stats:
            return []
        pts = [
            (r, s[metric]) for r, s in zip(self.rounds, self.stats)
            if metric in s
        ]
        if not pts:
            return []
        t0, d0 = pts[0]
        rows = []
        for r, d in pts:
            pred = d0 * float(
                predicted_decay_curve(self.theory_rate, r - t0, self.eps)
            )
            rows.append({"round": r, "measured": d, "predicted": pred})
        return rows

    def report(self, metric: str = "disagreement") -> dict:
        """The convergence verdict: measured rate, theory rate, their ratio
        (``rate_attainment``), the overlay rows, and the latest stats."""
        measured = self.measured_rate(metric)
        attainment = None
        if (measured is not None and self.theory_rate is not None
                and math.isfinite(self.theory_rate) and self.theory_rate > 0):
            attainment = measured / self.theory_rate
        return {
            "metric": metric,
            "n_rounds": len(self.rounds),
            "measured_rate": measured,
            "theory_rate": self.theory_rate,
            "rate_attainment": attainment,
            "overlay": self.overlay(metric),
            "latest": self.stats[-1] if self.stats else None,
        }
