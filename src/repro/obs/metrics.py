"""Unified metrics registry: counters, gauges, and histograms with labels.

One registry per ``Session`` (``obs.Observability.registry``) is the single
source of truth for every number observability reports: the round loop and
the engines write into it through namespaced instruments
(``session.rounds``, ``gossip.windows``, ``serve.requests``, ...), the
``evaluate()`` telemetry blocks are ingested under their namespace
(``ingest``), and every consumer — the terminal dashboard, the
Prometheus-style text exporter, the JSONL event sink — READS the registry
instead of re-deriving its own copy.

Design constraints, in order:

* **Pure observer.**  Instruments only ever receive already-materialized
  Python numbers; nothing here touches jax values, so recording can never
  perturb a trace or force a device sync.
* **Deterministic export.**  ``to_prometheus()`` sorts metrics and label
  sets, so identical runs produce byte-identical exporter output — pinned
  by a golden check in ``benchmarks/bench_obs.py``.
* **Plain data out.**  ``collect()`` returns nested plain dicts (the same
  vocabulary ``Session.evaluate()`` speaks), and the JSONL sink writes one
  self-describing event object per line.
"""
from __future__ import annotations

import dataclasses
import io
import json
import math
import threading
from typing import Any, Iterable


def _label_key(labels: dict) -> tuple:
    """Canonical hashable form of a label set (sorted items)."""
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def sanitize_name(name: str) -> str:
    """Lower a dotted metric name to the Prometheus charset
    (``[a-zA-Z_:][a-zA-Z0-9_:]*``): dots and dashes become underscores."""
    out = name.replace(".", "_").replace("-", "_")
    if out and out[0].isdigit():
        out = "_" + out
    return out


def escape_label_value(value: str) -> str:
    """Escape a label VALUE per the Prometheus text exposition format:
    backslash, double-quote and newline must be escaped inside the quoted
    value (``\\`` -> ``\\\\``, ``"`` -> ``\\"``, LF -> ``\\n``) — an
    ingested telemetry string containing any of them would otherwise emit
    unparseable exposition text.  Names go through ``sanitize_name``;
    values are free-form and only need this quoting."""
    return (str(value)
            .replace("\\", "\\\\")
            .replace('"', '\\"')
            .replace("\n", "\\n"))


# Default histogram buckets: wall-clock microseconds from 1us to ~1e7us
# (10s), decade-spaced with a 1-2-5 ladder — wide enough for both a
# disabled-span probe (~ns) and a cold jit compile (~s).
DEFAULT_BUCKETS = tuple(
    float(m * 10**e) for e in range(0, 7) for m in (1, 2, 5)
) + (float("inf"),)


@dataclasses.dataclass
class _Series:
    """One (metric, label-set) time series."""

    value: float = 0.0
    # histogram-only fields
    count: int = 0
    total: float = 0.0
    minimum: float = math.inf
    maximum: float = -math.inf
    bucket_counts: list | None = None


class _Instrument:
    """Shared machinery behind Counter / Gauge / Histogram handles."""

    kind = "untyped"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str = ""):
        self.registry = registry
        self.name = name
        self.help = help
        self.series: dict[tuple, _Series] = {}

    def _series(self, labels: dict) -> _Series:
        key = _label_key(labels)
        s = self.series.get(key)
        if s is None:
            s = self.series[key] = _Series()
        return s

    def labelsets(self) -> Iterable[tuple]:
        return sorted(self.series)


class Counter(_Instrument):
    """Monotone accumulator (``inc``)."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease")
        self._series(labels).value += value
        self.registry._emit("counter", self.name, labels, value)

    def value(self, **labels) -> float:
        s = self.series.get(_label_key(labels))
        return 0.0 if s is None else s.value


class Gauge(_Instrument):
    """Last-write-wins instantaneous value (``set``)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series(labels).value = float(value)
        self.registry._emit("gauge", self.name, labels, value)

    def value(self, **labels) -> float:
        s = self.series.get(_label_key(labels))
        return 0.0 if s is None else s.value


class Histogram(_Instrument):
    """Distribution sketch: count/sum/min/max + fixed cumulative buckets."""

    kind = "histogram"

    def __init__(self, registry, name, help="", buckets=DEFAULT_BUCKETS):
        super().__init__(registry, name, help)
        bs = tuple(float(b) for b in buckets)
        if list(bs) != sorted(set(bs)):
            raise ValueError(f"histogram {name!r} buckets must be ascending")
        if not bs or bs[-1] != math.inf:
            bs = bs + (math.inf,)
        self.buckets = bs

    def observe(self, value: float, **labels) -> None:
        v = float(value)
        s = self._series(labels)
        if s.bucket_counts is None:
            s.bucket_counts = [0] * len(self.buckets)
        s.count += 1
        s.total += v
        s.minimum = min(s.minimum, v)
        s.maximum = max(s.maximum, v)
        for i, edge in enumerate(self.buckets):
            if v <= edge:
                s.bucket_counts[i] += 1
                break
        self.registry._emit("histogram", self.name, labels, v)

    def summary(self, **labels) -> dict:
        s = self.series.get(_label_key(labels))
        if s is None or s.count == 0:
            return {"count": 0}
        return {
            "count": s.count,
            "sum": s.total,
            "mean": s.total / s.count,
            "min": s.minimum,
            "max": s.maximum,
        }


class JsonlSink:
    """Append-only JSONL event sink: one object per metric write / span.

    Events are self-describing (``{"kind", "name", "labels", "value"}``
    for metrics, ``{"kind": "span", ...}`` for tracer spans) so the file
    needs no side schema.  Buffered in-process; ``flush()``/``close()``
    push to disk (the registry flushes on ``export`` and the session on
    ``dashboard()``)."""

    def __init__(self, path: str):
        self.path = path
        self._fh = open(path, "w")
        self._lock = threading.Lock()
        self.n_events = 0

    def emit(self, event: dict) -> None:
        line = json.dumps(event, sort_keys=True)
        with self._lock:
            self._fh.write(line + "\n")
            self.n_events += 1

    def flush(self) -> None:
        with self._lock:
            self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.flush()
                self._fh.close()


class MetricsRegistry:
    """Namespace of instruments; the one place observability numbers live.

    ``counter``/``gauge``/``histogram`` create-or-return an instrument by
    dotted name (idempotent, kind-checked); ``ingest`` flattens a nested
    telemetry dict into gauges under a namespace prefix; ``collect`` returns
    the whole registry as plain nested dicts; ``to_prometheus`` renders the
    deterministic text exposition format.
    """

    def __init__(self, sink: JsonlSink | None = None):
        self._instruments: dict[str, _Instrument] = {}
        self._info: dict[str, str] = {}
        self.sink = sink

    # -- instrument construction (idempotent) --------------------------------

    def _get(self, cls, name: str, help: str, **kw) -> _Instrument:
        inst = self._instruments.get(name)
        if inst is None:
            inst = self._instruments[name] = cls(self, name, help, **kw)
        elif not isinstance(inst, cls):
            raise ValueError(
                f"metric {name!r} already registered as {inst.kind}"
            )
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(
        self, name: str, help: str = "", buckets=DEFAULT_BUCKETS
    ) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def info(self, name: str, value: str) -> None:
        """Non-numeric annotation (wire dtype, policy names) exported as a
        ``name{value="..."} 1`` info-style series."""
        self._info[name] = str(value)
        self._emit("info", name, {}, value)

    # -- bulk ingest ---------------------------------------------------------

    def ingest(self, namespace: str, doc: Any) -> None:
        """Flatten a nested telemetry dict (the ``evaluate()`` vocabulary)
        into gauges/infos under ``namespace.``: numeric leaves become gauge
        values, strings/bools become info/0-1 gauges, lists become indexed
        leaves.  This is how the existing staleness / faults / serving
        blocks land in the registry without each producer learning the
        instrument API."""
        def walk(prefix: str, node: Any) -> None:
            if isinstance(node, dict):
                for k, v in node.items():
                    walk(f"{prefix}.{k}", v)
            elif isinstance(node, (list, tuple)):
                for i, v in enumerate(node):
                    walk(f"{prefix}.{i}", v)
            elif isinstance(node, bool):
                self.gauge(prefix).set(1.0 if node else 0.0)
            elif isinstance(node, (int, float)):
                self.gauge(prefix).set(float(node))
            elif node is None:
                pass
            else:
                self.info(prefix, str(node))

        walk(namespace, doc)

    # -- event plumbing ------------------------------------------------------

    def _emit(self, kind: str, name: str, labels: dict, value) -> None:
        if self.sink is not None:
            self.sink.emit(
                {"kind": kind, "name": name,
                 "labels": {str(k): str(v) for k, v in labels.items()},
                 "value": value if isinstance(value, (int, float, str)) else float(value)}
            )

    # -- export --------------------------------------------------------------

    def collect(self) -> dict:
        """The registry as plain nested data: ``{name: value}`` for
        counters/gauges (label sets keyed by their sorted repr),
        ``{name: summary_dict}`` for histograms."""
        out: dict[str, Any] = {}
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            per_labels = {}
            for key in inst.labelsets():
                label_repr = ",".join(f"{k}={v}" for k, v in key) or ""
                if inst.kind == "histogram":
                    s = inst.series[key]
                    per_labels[label_repr] = {
                        "count": s.count, "sum": s.total,
                        "mean": (s.total / s.count) if s.count else 0.0,
                        "min": s.minimum if s.count else 0.0,
                        "max": s.maximum if s.count else 0.0,
                    }
                else:
                    per_labels[label_repr] = inst.series[key].value
            out[name] = per_labels.get("") if list(per_labels) == [""] else per_labels
        for name in sorted(self._info):
            out[name] = self._info[name]
        return out

    def to_prometheus(self) -> str:
        """Deterministic Prometheus text exposition (sorted names, sorted
        label sets; counters get the ``_total`` suffix, histograms the
        ``_bucket``/``_sum``/``_count`` triple)."""
        buf = io.StringIO()
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            pname = sanitize_name(name)
            if inst.help:
                buf.write(f"# HELP {pname} {inst.help}\n")
            buf.write(f"# TYPE {pname} {inst.kind}\n")
            for key in inst.labelsets():
                s = inst.series[key]
                lbl = ",".join(
                    f'{sanitize_name(k)}="{escape_label_value(v)}"'
                    for k, v in key
                )

                def wrap(extra: str = "") -> str:
                    parts = ",".join(x for x in (lbl, extra) if x)
                    return "{" + parts + "}" if parts else ""

                if inst.kind == "counter":
                    buf.write(f"{pname}_total{wrap()} {_fmt(s.value)}\n")
                elif inst.kind == "gauge":
                    buf.write(f"{pname}{wrap()} {_fmt(s.value)}\n")
                else:  # histogram
                    cum = 0
                    for edge, n in zip(inst.buckets, s.bucket_counts or []):
                        cum += n
                        le = "+Inf" if edge == math.inf else _fmt(edge)
                        le_lbl = 'le="' + le + '"'
                        buf.write(f"{pname}_bucket{wrap(le_lbl)} {cum}\n")
                    buf.write(f"{pname}_sum{wrap()} {_fmt(s.total)}\n")
                    buf.write(f"{pname}_count{wrap()} {s.count}\n")
        for name in sorted(self._info):
            pname = sanitize_name(name)
            buf.write(f"# TYPE {pname}_info gauge\n")
            buf.write(
                f'{pname}_info{{value='
                f'"{escape_label_value(self._info[name])}"}} 1\n'
            )
        if self.sink is not None:
            self.sink.flush()
        return buf.getvalue()


def _fmt(v: float) -> str:
    """Prometheus number formatting: integers bare, floats via repr."""
    f = float(v)
    if f == math.inf:
        return "+Inf"
    if f == -math.inf:
        return "-Inf"
    if f.is_integer() and abs(f) < 1e15:
        return str(int(f))
    return repr(f)
