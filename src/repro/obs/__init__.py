"""``repro.obs`` — the pure-observer observability layer (ROADMAP
"Observability").

Three pillars, one contract:

* ``obs.metrics`` — the unified ``MetricsRegistry`` (counters / gauges /
  histograms with labels, JSONL event sink, Prometheus-style exporter);
* ``obs.trace`` — nested wall-clock spans with compile-vs-warm
  attribution (plus the ``CompileWarmTimer`` / ``median_us`` bench
  helpers the benchmarks build on);
* ``obs.convergence`` + ``obs.roofline`` — theory-vs-measured: live
  network disagreement / KL against ``core.theory``'s predicted decay,
  measured window time against the ``launch.costmodel`` rooflines.

The contract: observability is READ-ONLY and OFF by default.  With
``ObsSpec`` unset a run is bitwise identical to an uninstrumented build
(same trajectories, same jit trace counts, same checkpoint leaves); with
it enabled the training math is still bit-identical — the instruments only
ever observe already-materialized host values.  ``tests/test_obs.py`` pins
both directions.

Front door: ``ExperimentSpec(obs=ObsSpec(enabled=True))`` →
``session.obs`` (an ``Observability``) → ``session.dashboard()``.
"""
from __future__ import annotations

from repro.obs.convergence import ConvergenceTracker, network_stats
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    JsonlSink,
    MetricsRegistry,
)
from repro.obs.roofline import (
    attainment,
    consensus_attainment,
    window_attainment,
)
from repro.obs.trace import (
    CompileWarmTimer,
    Tracer,
    compile_warm_split,
    median_us,
)

__all__ = [
    "ConvergenceTracker",
    "network_stats",
    "Counter",
    "Gauge",
    "Histogram",
    "JsonlSink",
    "MetricsRegistry",
    "attainment",
    "consensus_attainment",
    "window_attainment",
    "CompileWarmTimer",
    "Tracer",
    "compile_warm_split",
    "median_us",
    "Observability",
]


class Observability:
    """One session's observability bundle: registry + tracer (+ optional
    convergence tracker), wired to a shared JSONL sink.

    Built by ``api.session.build_session`` when ``spec.obs.enabled``; the
    session and the engines talk to THIS object (never to the spec), and
    everything on it is a pure observer of already-computed host values.
    """

    def __init__(self, obs_spec, static_w=None):
        self.spec = obs_spec
        self.sink = (
            JsonlSink(obs_spec.jsonl_path) if obs_spec.jsonl_path else None
        )
        self.registry = MetricsRegistry(sink=self.sink)
        self.tracer = Tracer(enabled=obs_spec.trace, sink=self.sink)
        self.convergence = (
            ConvergenceTracker(W=static_w) if obs_spec.convergence else None
        )

    @classmethod
    def from_spec(cls, spec) -> "Observability | None":
        """``None`` unless ``spec.obs.enabled``.  For the convergence
        tracker's theory overlay, a STATIC topology (named builder /
        explicit / single-matrix schedule) contributes its W; scheduled,
        callable, and gossip topologies track measured decay only (their
        per-round W varies, so the spectral rate is not a constant)."""
        if not spec.obs.enabled:
            return None
        static_w = None
        if spec.obs.convergence:
            try:
                mats = spec.topology._static_list()
            except ValueError:
                mats = None
            if mats is not None and len(mats) == 1:
                static_w = mats[0]
        return cls(spec.obs, static_w=static_w)

    def flush(self) -> None:
        """Push buffered spans/events to the JSONL sink, if one is set."""
        self.tracer.flush()
        if self.sink is not None:
            self.sink.flush()
