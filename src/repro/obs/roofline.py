"""Roofline attainment: measured wall-clock vs the analytic bytes models.

``launch.costmodel`` predicts what one consensus round / gossip window /
serve batch MUST cost on the memory-bound roofline (modeled bytes over
``HBM_BW``/``ICI_BW``).  This module closes the loop: given a MEASURED
wall-clock (a tracer span, a bench median), it reports

    attainment = modeled_roofline_seconds / measured_seconds

— the fraction of the roofline the live run achieves (1.0 = running at the
model, << 1 = leaving bandwidth on the table, > 1 = the model's bandwidth
assumption is conservative for this host).  On interpret-mode/CPU hosts
attainment is tiny and only the RELATIVE trajectory across runs is
meaningful — which is exactly what ``benchmarks/run.py bench-diff`` tracks.

Pure functions of plain numbers; nothing here touches jax.
"""
from __future__ import annotations

from typing import Any

from repro.launch.costmodel import consensus_roofline, gossip_window_roofline


def attainment(measured_us: float, modeled_seconds: float) -> float:
    """``modeled_seconds / measured_seconds`` (0.0 for degenerate inputs)."""
    if measured_us <= 0 or modeled_seconds <= 0:
        return 0.0
    return modeled_seconds / (measured_us * 1e-6)


def consensus_attainment(
    measured_us: float,
    n_agents: int,
    n_params: int,
    n_leaves: int = 1,
    strategy: str = "flat_fused",
    **model_kwargs: Any,
) -> dict:
    """Measured consensus-round time vs ``consensus_roofline``.

    ``strategy`` picks the modeled execution (``leaf_loop | flat_fused |
    flat_sparse``); extra kwargs forward to the model (``max_degree``,
    ``wire_dtype``)."""
    model = consensus_roofline(n_agents, n_params, n_leaves, **model_kwargs)
    modeled = model["roofline_seconds"][strategy]
    return {
        "measured_us": float(measured_us),
        "modeled_us": modeled * 1e6,
        "modeled_bytes": model["hbm_bytes"][strategy],
        "strategy": strategy,
        "attainment": attainment(measured_us, modeled),
    }


def window_attainment(
    measured_us: float,
    n_agents: int,
    n_params: int,
    n_participating: int,
    strategy: str = "window_masked",
    **model_kwargs: Any,
) -> dict:
    """Measured gossip-window time vs ``gossip_window_roofline``.

    ``strategy`` is a ``roofline_seconds`` key of the window model
    (``window_masked | dense_fused``, plus ``history`` /
    ``ici_window_ppermute`` when the model is built with ``delay_depth`` /
    ``n_shards``); extra kwargs forward to the model."""
    model = gossip_window_roofline(
        n_agents, n_params, n_participating, **model_kwargs
    )
    secs = model["roofline_seconds"]
    if strategy not in secs:
        raise ValueError(
            f"unknown window strategy {strategy!r}; model offers "
            f"{sorted(secs)} (shard/delay strategies need the matching "
            "model kwargs)"
        )
    modeled = secs[strategy]
    return {
        "measured_us": float(measured_us),
        "modeled_us": modeled * 1e6,
        "strategy": strategy,
        "participating_fraction": model["participating_fraction"],
        "attainment": attainment(measured_us, modeled),
    }
