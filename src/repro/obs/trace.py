"""Nested wall-clock spans with explicit compile-vs-warm attribution.

The gossip/serve benches all reinvented the same timing discipline by hand:
run the first call separately (it pays the jit trace + XLA compile), THEN
start the timer, and report ``compile_us`` next to the warm median — because
a mean over calls that includes the compile is off by orders of magnitude.
This module promotes that discipline into a reusable API:

* ``Tracer`` — nested ``span(name, **attrs)`` context managers recording
  wall-clock intervals into an in-process buffer.  Spans carry arbitrary
  attributes; the ``compile=True`` attribute marks a span as
  compile-attributed, and ``summary()`` splits every aggregate into
  ``compile`` / ``warm`` groups so steady-state numbers are never polluted.
  A DISABLED tracer's ``span`` is a reusable no-op context manager — the
  instrumented hot path pays one attribute check and an empty
  ``with`` (asserted ~0 by ``benchmarks/bench_obs.py``).
* ``CompileWarmTimer`` — the two-phase bench pattern as an object: time
  the compiling call under ``with t.compile():``, the steady-state run
  under ``with t.warm():``.
* ``median_us(fn, *args)`` — median-of-warm-calls microbenchmark helper
  (blocks on jax values so device work is actually counted).

Spans measure HOST wall-clock at the dispatch boundary.  Calls that return
before the device finishes (jax async dispatch) are only fully counted
when something downstream synchronizes — ``Session.round`` does
(``np.asarray(losses)``), so the ``session.round`` span is end-to-end
accurate; inner engine spans are dispatch-side and documented as such.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Any, Callable


@dataclasses.dataclass
class Span:
    """One recorded interval (microseconds since the tracer epoch)."""

    name: str
    t0_us: float
    dur_us: float
    depth: int
    attrs: dict

    def to_event(self) -> dict:
        ev = {"kind": "span", "name": self.name, "t0_us": round(self.t0_us, 3),
              "dur_us": round(self.dur_us, 3), "depth": self.depth}
        if self.attrs:
            ev["attrs"] = {k: _plain(v) for k, v in self.attrs.items()}
        return ev


def _plain(v):
    return v if isinstance(v, (bool, int, float, str, type(None))) else str(v)


class _NullSpan:
    """Reusable no-op context manager (the disabled-tracer fast path)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _LiveSpan:
    """An open span: closes itself into the tracer buffer on ``__exit__``."""

    __slots__ = ("tracer", "name", "attrs", "t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        self.tracer._depth += 1
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        tr = self.tracer
        tr._depth -= 1
        tr.spans.append(Span(
            name=self.name,
            t0_us=(self.t0 - tr._epoch) * 1e6,
            dur_us=(t1 - self.t0) * 1e6,
            depth=tr._depth,
            attrs=self.attrs,
        ))
        return False


class Tracer:
    """In-process span recorder; disabled by default and free when so."""

    def __init__(self, enabled: bool = True, sink=None):
        self.enabled = enabled
        self.spans: list[Span] = []
        self.sink = sink  # optional metrics.JsonlSink; spans land as events
        self._depth = 0
        self._flushed = 0
        self._epoch = time.perf_counter()

    def span(self, name: str, **attrs):
        """``with tracer.span("gossip.window", impl="masked"): ...`` —
        records nothing (and allocates nothing) when the tracer is off."""
        if not self.enabled:
            return _NULL_SPAN
        return _LiveSpan(self, name, attrs)

    # -- aggregation ---------------------------------------------------------

    def summary(self) -> dict:
        """Per span name: count / total / mean / p50 / max (us), split into
        ``warm`` and ``compile`` groups by the ``compile`` attribute."""
        grouped: dict[str, dict[str, list[float]]] = {}
        for s in self.spans:
            mode = "compile" if s.attrs.get("compile") else "warm"
            grouped.setdefault(s.name, {}).setdefault(mode, []).append(s.dur_us)
        out: dict[str, dict] = {}
        for name, modes in sorted(grouped.items()):
            out[name] = {}
            for mode, durs in modes.items():
                durs = sorted(durs)
                n = len(durs)
                out[name][mode] = {
                    "n": n,
                    "total_us": sum(durs),
                    "mean_us": sum(durs) / n,
                    "p50_us": durs[n // 2],
                    "max_us": durs[-1],
                }
        return out

    def flush(self) -> int:
        """Push buffered spans to the JSONL sink (if any); returns the
        number of spans written this call."""
        if self.sink is None:
            return 0
        n = 0
        for s in self.spans[self._flushed:]:
            self.sink.emit(s.to_event())
            n += 1
        self._flushed = len(self.spans)
        return n

    def to_jsonl(self, path: str) -> int:
        """Write every recorded span to ``path`` (one JSON object per
        line); returns the span count."""
        with open(path, "w") as fh:
            for s in self.spans:
                fh.write(json.dumps(s.to_event(), sort_keys=True) + "\n")
        return len(self.spans)


class CompileWarmTimer:
    """The bench_gossip ad-hoc split as a reusable object.

        t = CompileWarmTimer()
        with t.compile():
            session.round()          # pays trace + XLA compile
        with t.warm():
            session.run(n_rounds=m)  # steady state
        t.compile_us, t.warm_us, t.warm_us_per(m)

    Multiple ``compile()``/``warm()`` blocks accumulate (re-traces under
    distinct shapes all belong to the compile bucket)."""

    def __init__(self):
        self.compile_us = 0.0
        self.warm_us = 0.0

    @contextlib.contextmanager
    def compile(self):
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            self.compile_us += (time.perf_counter() - t0) * 1e6

    @contextlib.contextmanager
    def warm(self):
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            self.warm_us += (time.perf_counter() - t0) * 1e6

    def warm_us_per(self, n_calls: int) -> float:
        return self.warm_us / max(1, n_calls)

    def as_dict(self) -> dict:
        return {"compile_us": self.compile_us, "warm_us": self.warm_us}


def _block(x) -> None:
    try:
        import jax

        jax.block_until_ready(x)
    except (ImportError, TypeError):
        pass


def median_us(fn: Callable[..., Any], *args, iters: int = 5) -> float:
    """Median warm wall-clock of ``fn(*args)`` in microseconds.  The caller
    is responsible for warming ``fn`` first (or use ``compile_warm_split``);
    jax return values are blocked on so device time is counted."""
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        _block(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return sorted(times)[len(times) // 2]


def compile_warm_split(
    fn: Callable[..., Any], *args, iters: int = 5
) -> dict:
    """Time ``fn(*args)``'s first call (compile) apart from its warm
    median: ``{"compile_us", "warm_us_median"}``."""
    t0 = time.perf_counter()
    _block(fn(*args))
    compile_us = (time.perf_counter() - t0) * 1e6
    return {
        "compile_us": compile_us,
        "warm_us_median": median_us(fn, *args, iters=iters),
    }
