from repro.vi.bayes_by_backprop import (
    free_energy,
    free_energy_and_grad,
    local_vi_steps,
    mc_predict,
    predictive_confidence,
)

__all__ = [
    "free_energy",
    "free_energy_and_grad",
    "local_vi_steps",
    "mc_predict",
    "predictive_confidence",
]
