"""Bayes-by-Backprop variational inference (Blundell et al. [10]), the
computational realization of the paper's steps 2+3 (Remark 1, eq. 5):

    b_i^{(n)} = argmin_{pi in Q}  KL(pi || q_i^{(n-1)})
                                  + E_pi[ -log l_i(Y | . , X) ]

The KL term is closed-form between mean-field Gaussians; the expected
negative log-likelihood is estimated with simple Monte Carlo through the
reparameterization trick.  The *prior* of round n is the consensus posterior
q_i^{(n-1)} — this is exactly how the paper injects the network's global
information into local training (Remark 7).

Posterior-representation contract: everything here is polymorphic over the
posterior type.  ``post``/``prior`` may be a ``GaussianPosterior`` (pytree
mean/rho; ``post.sample`` returns a parameter pytree) or a
``core.flat.FlatPosterior`` (contiguous [P] fp32 buffers; ``post.sample``
returns a FLAT theta vector).  In the flat case ``nll_fn``/``logits_fn``
must accept the flat theta — wrap a pytree model once with
``core.flat.make_flat_nll`` (or apply ``layout.unflatten`` yourself) so the
flat->pytree conversion happens only at the model-apply boundary.  KL,
gradients, and the optimizer all run directly on the flat buffers.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.posterior import GaussianPosterior, kl_gaussian
from repro.optim import Optimizer, apply_updates

PyTree = Any
# nll_fn(params, batch) -> scalar total negative log-likelihood over the batch
NllFn = Callable[[PyTree, Any], jax.Array]


def free_energy(
    post: GaussianPosterior,
    prior: GaussianPosterior,
    nll_fn: NllFn,
    batch: Any,
    key: jax.Array,
    n_samples: int = 1,
    kl_scale: float = 1.0,
) -> jax.Array:
    """Variational free energy (eq. 5): KL(q||prior) + E_q[-log lik].

    ``kl_scale`` implements minibatch KL reweighting (1/num_batches in [10])
    so that one epoch of minibatch steps applies the KL once in expectation.
    """
    kl = kl_gaussian(post, prior)

    def one(k):
        theta = post.sample(k)
        return nll_fn(theta, batch)

    keys = jax.random.split(key, n_samples)
    enll = jnp.mean(jax.vmap(one)(keys))
    return kl_scale * kl + enll


def free_energy_and_grad(
    post: GaussianPosterior,
    prior: GaussianPosterior,
    nll_fn: NllFn,
    batch: Any,
    key: jax.Array,
    n_samples: int = 1,
    kl_scale: float = 1.0,
) -> tuple[jax.Array, GaussianPosterior]:
    return jax.value_and_grad(free_energy)(
        post, prior, nll_fn, batch, key, n_samples, kl_scale
    )


def local_vi_steps(
    post: GaussianPosterior,
    prior: GaussianPosterior,
    opt: Optimizer,
    opt_state: Any,
    nll_fn: NllFn,
    batches: Any,
    key: jax.Array,
    lr: jax.Array,
    step0: jax.Array,
    n_samples: int = 1,
    kl_scale: float = 1.0,
) -> tuple[GaussianPosterior, Any, jax.Array]:
    """Run u local VI (Bayes-by-Backprop) steps — the paper's ``u`` local
    updates per communication round (supplementary Tables 1-3).

    ``batches``: pytree whose leaves carry a leading axis of length u (one
    slice per local step).  Returns (new_post, new_opt_state, mean_loss).
    """
    u = jax.tree.leaves(batches)[0].shape[0]
    keys = jax.random.split(key, u)

    def body(carry, xs):
        post, opt_state, step = carry
        batch, k = xs
        loss, grads = free_energy_and_grad(
            post, prior, nll_fn, batch, k, n_samples, kl_scale
        )
        updates, opt_state = opt.update(grads, opt_state, step, lr)
        post = apply_updates(post, updates)
        return (post, opt_state, step + 1), loss

    (post, opt_state, _), losses = jax.lax.scan(
        body, (post, opt_state, step0), (batches, keys)
    )
    return post, opt_state, jnp.mean(losses)


def mc_predict(
    post: GaussianPosterior,
    logits_fn: Callable[[PyTree, jax.Array], jax.Array],
    x: jax.Array,
    key: jax.Array,
    n_mc: int = 8,
) -> jax.Array:
    """Paper Sec 4.2: Monte-Carlo predictive distribution
    P(y) = (1/L) sum_k Softmax(y, f_{theta_k}(x)), theta_k ~ b_i^{(n)}.

    Returns the averaged class-probability array [..., n_classes].
    ``logits_fn`` takes a parameter PYTREE; a ``FlatPosterior`` is sampled
    through its layout (``sample_pytree``) so callers never see flat theta.
    """
    keys = jax.random.split(key, n_mc)
    sample = getattr(post, "sample_pytree", post.sample)

    def one(k):
        theta = sample(k)
        return jax.nn.softmax(logits_fn(theta, x), axis=-1)

    return jnp.mean(jax.vmap(one)(keys), axis=0)


def predictive_confidence(probs: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(argmax prediction, confidence = posterior predictive probability)."""
    pred = jnp.argmax(probs, axis=-1)
    conf = jnp.max(probs, axis=-1)
    return pred, conf
