"""Pallas TPU kernels: fused precision-weighted posterior consensus (eq. 6).

Three kernels, all computing

    prec_j   = softplus(rho_j)^-2
    prec_out = sum_j w_j prec_j
    mean_out = sum_j w_j prec_j mean_j / prec_out
    rho_out  = softplus^-1(prec_out^-1/2)

* ``consensus_fused``          — one agent, stacked neighbor posteriors.
* ``consensus_fused_network``  — ALL agents in one ``pallas_call`` over the
  flat network posterior (mean, rho: ``[N, P]``) with the full row-stochastic
  ``W [N, N]`` resident in VMEM.  Grid ``(P // BLOCK,)``: each program loads
  one ``[N, BLOCK]`` column tile of mean and rho ONCE and produces the
  consensus rows for every agent via an MXU matmul ``W @ prec`` — a single
  HBM pass over the network posterior per round, vs (leaves x agents x ~6)
  elementwise round-trips for the unfused leaf-loop einsum.
* ``consensus_fused_sparse``   — CSR-style neighbor-list variant for sparse
  topologies (ring/grid/star): grid ``(N, P // BLOCK, D)`` with the neighbor
  ids scalar-prefetched so each agent reads only its deg(i) <= D neighbor
  tiles instead of all N rows.
* ``consensus_fused_masked``   — the gossip event-window form (repro.gossip):
  the network kernel plus a per-agent activity mask.  ACTIVE rows run the
  identical MXU math as ``consensus_fused_network`` (bitwise: the all-active
  window reproduces the synchronous kernel exactly); INACTIVE rows pass
  their (mean, rho) through UNTOUCHED — no softplus/softplus^-1 round trip,
  so an idle agent's posterior is bit-stable across any number of windows.
* ``consensus_fused_masked_sparse`` — CSR + activity mask: active agents
  read only their deg(i) fired-neighbor tiles, inactive agents copy their
  own row (the self-padded tables guarantee the last gathered tile IS the
  agent's own row), giving HBM traffic proportional to the window's
  active-edge fraction (``launch.costmodel.gossip_window_roofline``).

The padded neighbor tables both sparse kernels scalar-prefetch come from
THE one CSR construction — ``core.graphs.SparseGraph.neighbor_tables()``
(``core.flat.neighbor_tables`` is its dense-W bridge) — so the kernel view
of a topology can never disagree with the graph layer's.  The [N, N]-free
counterpart for N = 10^4+ populations is ``core.flat
.consensus_flat_segments``: a segment-sum over ``SparseGraph.edge_arrays()``
[E] edge lists with the identical exchange-boundary wire contract.  It
stays an XLA scatter path by design — TPU Pallas has no efficient
data-dependent scatter primitive, and at deg(i) << N the gather/segment-sum
is memory-bound XLA already handles well — while these Pallas kernels own
the dense/VMEM-resident regime (N <= a few thousand).

Flat-buffer layout contract (shared with ``core.flat.FlatPosterior``):
  * axis 0 is the agent axis (N rows), axis 1 the flattened parameter axis
    (P fp32 lanes, leaf-major in layout order);
  * the caller's buffers are UNPADDED; kernels pad the lane dim up to a
    BLOCK multiple internally (mean pads 0.0, rho pads 1.0 so pad lanes keep
    finite precision — softplus(1.0) ~ 1.31, so the pad precision ~0.58
    stays finite and exactly representable under EVERY wire dtype,
    including f16's narrow exponent range) and slice the pad back off
    before returning;
  * keep BLOCK a multiple of 128 (TPU lane width); the last dim rides the
    lane dim, agents/neighbors ride sublanes.

Wire-dtype compression (ROADMAP "Wire precision"): every kernel takes a
static ``wire_dtype`` (default fp32).  The exchanged sufficient statistics
(prec, prec*mu) are rounded through the wire dtype AT THE EXCHANGE BOUNDARY
— immediately before the cross-agent contraction — and the contraction
itself ACCUMULATES IN FP32 (``preferred_element_type``).  ``wire_dtype=
jnp.float32`` is a structural no-op: ``core.numerics.wire_roundtrip``
returns its input unchanged, so the f32 kernels are BITWISE identical to
the pre-wire ones (pinned by tests/test_wire_dtype.py).

Unfused, eq. (6) is ~6 elementwise HBM round-trips over tensors the size of
the model; the consensus step is purely memory-bound, so fusing the whole
network into one pass is the entire game (see launch.costmodel
.consensus_roofline for the analytic pass counts the benchmark reports).

``interpret=None`` on every entry point means auto: Pallas-compiled on TPU,
interpreter (CPU-correctness mode) elsewhere — callers on TPU no longer
silently run the interpreter (satellite fix of ISSUE 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.numerics import (
    canonical_wire_dtype,
    softplus_inv,
    wire_roundtrip,
)
from repro.kernels.dispatch import auto_interpret as _auto_interpret

DEFAULT_BLOCK = 2048


def _pad_lanes(mean, rho, block):
    """Pad the lane (last) dim to a BLOCK multiple.  rho pads with 1.0 so the
    pad lanes keep a finite sigma (inf precision would poison the row sums)."""
    p = mean.shape[-1]
    pad = (-p) % block
    if pad:
        widths = ((0, 0),) * (mean.ndim - 1) + ((0, pad),)
        mean = jnp.pad(mean, widths)
        rho = jnp.pad(rho, widths, constant_values=1.0)
    return mean, rho, p + pad


def _consensus_kernel(w_ref, mean_ref, rho_ref, mean_out_ref, rho_out_ref, *,
                      wire_dtype):
    w = w_ref[...]  # [N, 1]
    mean = mean_ref[...]  # [N, BLOCK]
    rho = rho_ref[...]  # [N, BLOCK]
    sigma = jax.nn.softplus(rho)
    prec = 1.0 / (sigma * sigma)
    if wire_dtype == jnp.float32:
        # pre-wire op order, verbatim — f32 stays bitwise identical
        wp = w * prec  # [N, BLOCK]
        prec_out = jnp.sum(wp, axis=0)  # [BLOCK]
        mean_out = jnp.sum(wp * mean, axis=0) / prec_out
    else:
        # exchange boundary: round (prec, prec*mu), accumulate fp32
        prec_w = wire_roundtrip(prec, wire_dtype)
        pm_w = wire_roundtrip(prec * mean, wire_dtype)
        prec_out = jnp.sum(w * prec_w, axis=0)
        mean_out = jnp.sum(w * pm_w, axis=0) / prec_out
    rho_out = softplus_inv(jax.lax.rsqrt(prec_out))
    mean_out_ref[...] = mean_out[None, :]
    rho_out_ref[...] = rho_out[None, :]


@functools.partial(jax.jit, static_argnames=("block", "interpret", "wire_dtype"))
def consensus_fused(
    w_row: jax.Array,  # [N]
    mean_stack: jax.Array,  # [N, P]
    rho_stack: jax.Array,  # [N, P]
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
    wire_dtype=None,
) -> tuple[jax.Array, jax.Array]:
    """Fused consensus over a flat parameter block.  Returns (mean, rho) [P].

    ``interpret=None`` auto-dispatches (compiled on TPU, interpreter
    elsewhere); pass an explicit bool to force either mode.  ``wire_dtype``
    rounds (prec, prec*mu) through the wire dtype at the exchange boundary
    (module docstring); ``None``/f32 is the bitwise-identical uncompressed
    path.
    """
    interpret = _auto_interpret(interpret)
    wire_dtype = canonical_wire_dtype(wire_dtype)
    n, p = mean_stack.shape
    mean_stack, rho_stack, pp = _pad_lanes(mean_stack, rho_stack, block)
    grid = (pp // block,)
    mean_out, rho_out = pl.pallas_call(
        functools.partial(_consensus_kernel, wire_dtype=wire_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),  # w broadcast to all tiles
            pl.BlockSpec((n, block), lambda i: (0, i)),
            pl.BlockSpec((n, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, pp), mean_stack.dtype),
            jax.ShapeDtypeStruct((1, pp), rho_stack.dtype),
        ],
        interpret=interpret,
    )(w_row[:, None], mean_stack, rho_stack)
    return mean_out[0, :p], rho_out[0, :p]


def _consensus_network_kernel(w_ref, mean_ref, rho_ref, mean_out_ref,
                              rho_out_ref, *, wire_dtype):
    w = w_ref[...]  # [N, N], resident in VMEM for every tile
    mean = mean_ref[...]  # [N, BLOCK]
    rho = rho_ref[...]  # [N, BLOCK]
    sigma = jax.nn.softplus(rho)
    prec = 1.0 / (sigma * sigma)
    # exchange boundary: every agent's (prec, prec*mu) contribution crosses
    # through the wire dtype (structural no-op for f32)
    prec_x = wire_roundtrip(prec, wire_dtype)
    pm_x = wire_roundtrip(prec * mean, wire_dtype)
    # new_prec[i] = sum_j W[i,j] prec[j]: one MXU matmul covers every agent,
    # so each [N, BLOCK] column tile is read from HBM exactly once; the
    # contraction accumulates fp32 whatever the wire dtype.
    new_prec = jnp.dot(w, prec_x, preferred_element_type=jnp.float32)
    new_pm = jnp.dot(w, pm_x, preferred_element_type=jnp.float32)
    mean_out_ref[...] = new_pm / new_prec
    rho_out_ref[...] = softplus_inv(jax.lax.rsqrt(new_prec))


@functools.partial(jax.jit, static_argnames=("block", "interpret", "wire_dtype"))
def consensus_fused_network(
    W: jax.Array,  # [N, N] row-stochastic
    mean: jax.Array,  # [N, P] flat network posterior means
    rho: jax.Array,  # [N, P]
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
    wire_dtype=None,
) -> tuple[jax.Array, jax.Array]:
    """Eq. (6) for the WHOLE network in one ``pallas_call``.

    Returns (mean, rho), both [N, P].  One HBM pass: grid ``(P // BLOCK,)``,
    W stays in VMEM, each column tile of (mean, rho) is streamed through
    VMEM once and the per-agent reduction runs on the MXU.  ``wire_dtype``
    rounds (prec, prec*mu) at the exchange boundary (accumulate fp32);
    f32/None is bitwise the uncompressed kernel.
    """
    interpret = _auto_interpret(interpret)
    wire_dtype = canonical_wire_dtype(wire_dtype)
    n, p = mean.shape
    mean, rho, pp = _pad_lanes(mean, rho, block)
    grid = (pp // block,)
    mean_out, rho_out = pl.pallas_call(
        functools.partial(_consensus_network_kernel, wire_dtype=wire_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),  # W resident across tiles
            pl.BlockSpec((n, block), lambda i: (0, i)),
            pl.BlockSpec((n, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((n, block), lambda i: (0, i)),
            pl.BlockSpec((n, block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, pp), mean.dtype),
            jax.ShapeDtypeStruct((n, pp), rho.dtype),
        ],
        interpret=interpret,
    )(W.astype(jnp.float32), mean, rho)
    return mean_out[:, :p], rho_out[:, :p]


def _consensus_masked_kernel(
    w_ref, act_ref, mean_ref, rho_ref, mean_out_ref, rho_out_ref, *, wire_dtype
):
    w = w_ref[...]  # [N, N] effective window W-tilde, resident in VMEM
    act = act_ref[...]  # [N, 1] activity mask (1.0 = merges this window)
    mean = mean_ref[...]  # [N, BLOCK]
    rho = rho_ref[...]  # [N, BLOCK]
    sigma = jax.nn.softplus(rho)
    prec = 1.0 / (sigma * sigma)
    # identical op sequence to _consensus_network_kernel (same exchange-
    # boundary rounding) -> active rows are bitwise-equal to the synchronous
    # fused kernel at every wire dtype; inactive rows never touch the wire
    prec_x = wire_roundtrip(prec, wire_dtype)
    pm_x = wire_roundtrip(prec * mean, wire_dtype)
    new_prec = jnp.dot(w, prec_x, preferred_element_type=jnp.float32)
    new_pm = jnp.dot(w, pm_x, preferred_element_type=jnp.float32)
    mean_out_ref[...] = jnp.where(act > 0, new_pm / new_prec, mean)
    rho_out_ref[...] = jnp.where(
        act > 0, softplus_inv(jax.lax.rsqrt(new_prec)), rho
    )


@functools.partial(jax.jit, static_argnames=("block", "interpret", "wire_dtype"))
def consensus_fused_masked(
    W: jax.Array,  # [N, N] effective window W-tilde (inactive rows = e_i)
    active: jax.Array,  # [N] bool/int/float activity mask
    mean: jax.Array,  # [N, P]
    rho: jax.Array,  # [N, P]
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
    wire_dtype=None,
) -> tuple[jax.Array, jax.Array]:
    """Event-window eq. (6): masked network-wide consensus in ONE
    ``pallas_call``.

    Active rows compute the exact ``consensus_fused_network`` math on the
    window's W-tilde (including its exchange-boundary ``wire_dtype``
    rounding); inactive rows pass (mean, rho) through untouched.  With
    ``active`` all-true and the same W this is bit-identical to
    ``consensus_fused_network`` — the gossip/synchronous equivalence the
    tests pin, at every wire dtype.  Same layout/padding contract as the
    other kernels.
    """
    interpret = _auto_interpret(interpret)
    wire_dtype = canonical_wire_dtype(wire_dtype)
    n, p = mean.shape
    mean, rho, pp = _pad_lanes(mean, rho, block)
    act = active.astype(jnp.float32)[:, None]
    grid = (pp // block,)
    mean_out, rho_out = pl.pallas_call(
        functools.partial(_consensus_masked_kernel, wire_dtype=wire_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),  # W resident across tiles
            pl.BlockSpec((n, 1), lambda i: (0, 0)),  # mask resident too
            pl.BlockSpec((n, block), lambda i: (0, i)),
            pl.BlockSpec((n, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((n, block), lambda i: (0, i)),
            pl.BlockSpec((n, block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, pp), mean.dtype),
            jax.ShapeDtypeStruct((n, pp), rho.dtype),
        ],
        interpret=interpret,
    )(W.astype(jnp.float32), act, mean, rho)
    return mean_out[:, :p], rho_out[:, :p]


def _consensus_sparse_kernel(
    nbr_ref,  # scalar-prefetch [N, D] int32 neighbor ids (self-padded)
    wts_ref,  # scalar-prefetch [N, D] fp32 neighbor weights (0-padded)
    mean_ref,  # [1, BLOCK] — row nbr[i, d], column tile j
    rho_ref,  # [1, BLOCK]
    mean_out_ref,  # [1, BLOCK] — row i, column tile j
    rho_out_ref,  # [1, BLOCK]
    acc_prec,  # VMEM scratch [1, BLOCK]
    acc_pm,  # VMEM scratch [1, BLOCK]
    *,
    wire_dtype,
):
    i = pl.program_id(0)
    d = pl.program_id(2)
    w = wts_ref[i, d]

    @pl.when(d == 0)
    def _init():
        acc_prec[...] = jnp.zeros_like(acc_prec)
        acc_pm[...] = jnp.zeros_like(acc_pm)

    sigma = jax.nn.softplus(rho_ref[...])
    if wire_dtype == jnp.float32:
        # pre-wire op order, verbatim (w/(sigma*sigma) fuses weight and
        # precision) — f32 stays bitwise identical
        wp = w / (sigma * sigma)  # zero-weight pad entries contribute nothing
        acc_prec[...] += wp
        acc_pm[...] += wp * mean_ref[...]
    else:
        # exchange boundary: the gathered neighbor tile's (prec, prec*mu)
        # cross the wire rounded; the scratch accumulators stay fp32
        prec = 1.0 / (sigma * sigma)
        prec_x = wire_roundtrip(prec, wire_dtype)
        pm_x = wire_roundtrip(prec * mean_ref[...], wire_dtype)
        acc_prec[...] += w * prec_x
        acc_pm[...] += w * pm_x

    @pl.when(d == pl.num_programs(2) - 1)
    def _finish():
        prec_out = acc_prec[...]
        mean_out_ref[...] = acc_pm[...] / prec_out
        rho_out_ref[...] = softplus_inv(jax.lax.rsqrt(prec_out))


@functools.partial(jax.jit, static_argnames=("block", "interpret", "wire_dtype"))
def consensus_fused_sparse(
    neighbors: jax.Array,  # [N, D] int32: neighbor ids, padded with self id
    weights: jax.Array,  # [N, D] fp32: W[i, neighbors[i]], padded with 0.0
    mean: jax.Array,  # [N, P]
    rho: jax.Array,  # [N, P]
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
    wire_dtype=None,
) -> tuple[jax.Array, jax.Array]:
    """Sparse-neighborhood eq. (6): each agent reads only deg(i) <= D
    neighbor tiles (D = max in-degree), not all N rows.

    The (neighbors, weights) tables come from ``core.flat.neighbor_tables``
    (rows of W with zero weight are skipped entirely; ragged degrees are
    padded with the self id at weight 0, which reads a tile the agent already
    needs but adds nothing to the sums).  HBM traffic: sum_i deg(i) tiles vs
    N^2 for the dense kernel — the win for ring/grid/star topologies.
    ``wire_dtype`` rounds each gathered tile's (prec, prec*mu) at the
    exchange boundary (fp32 accumulators); f32/None is bitwise the
    uncompressed kernel.
    """
    interpret = _auto_interpret(interpret)
    wire_dtype = canonical_wire_dtype(wire_dtype)
    n, p = mean.shape
    d = neighbors.shape[1]
    mean, rho, pp = _pad_lanes(mean, rho, block)
    grid = (n, pp // block, d)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda i, j, k, nbr, wts: (nbr[i, k], j)),
            pl.BlockSpec((1, block), lambda i, j, k, nbr, wts: (nbr[i, k], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i, j, k, nbr, wts: (i, j)),
            pl.BlockSpec((1, block), lambda i, j, k, nbr, wts: (i, j)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, block), jnp.float32),
            pltpu.VMEM((1, block), jnp.float32),
        ],
    )
    mean_out, rho_out = pl.pallas_call(
        functools.partial(_consensus_sparse_kernel, wire_dtype=wire_dtype),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, pp), mean.dtype),
            jax.ShapeDtypeStruct((n, pp), rho.dtype),
        ],
        interpret=interpret,
    )(neighbors.astype(jnp.int32), weights.astype(jnp.float32), mean, rho)
    return mean_out[:, :p], rho_out[:, :p]


def _payload_validity_kernel(mean_ref, rho_ref, ok_ref, *, wire_dtype, bound):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        ok_ref[...] = jnp.ones_like(ok_ref)

    sigma = jax.nn.softplus(rho_ref[...])
    prec = 1.0 / (sigma * sigma)
    prec_x = wire_roundtrip(prec, wire_dtype)
    pm_x = wire_roundtrip(prec * mean_ref[...], wire_dtype)
    ok = (
        jnp.isfinite(prec_x)
        & (prec_x > 0.0)
        & (prec_x <= bound)
        & jnp.isfinite(pm_x)
        & (jnp.abs(pm_x) <= bound)
    )
    tile_ok = jnp.all(ok, axis=-1, keepdims=True)  # [N, 1]
    ok_ref[...] = ok_ref[...] * tile_ok.astype(jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("bound", "block", "interpret", "wire_dtype")
)
def payload_validity_fused(
    mean: jax.Array,  # [N, P]
    rho: jax.Array,  # [N, P]
    *,
    bound: float,
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
    wire_dtype=None,
) -> jax.Array:
    """Fused exchange-payload sanity probe: ONE streaming pass over the flat
    [N, P] buffers returning a per-agent [N] bool — every wire-rounded
    (prec, prec*mu) lane finite, prec > 0, magnitudes within ``bound``.

    Grid ``(P // BLOCK,)`` with a revisited [N, 1] output: tile 0 seeds the
    flags to 1.0, every subsequent tile ANDs (multiplies) its own all-lanes
    verdict in — the same single-HBM-pass shape as the consensus kernels, so
    the quarantine guard adds one read pass, not a gather storm.  Pad lanes
    (mean 0.0, rho 1.0) are always valid and never flip a flag.  Pinned
    bit-equal to the ``core.flat.payload_validity`` XLA reference.
    """
    interpret = _auto_interpret(interpret)
    wire_dtype = canonical_wire_dtype(wire_dtype)
    n, _ = mean.shape
    mean, rho, pp = _pad_lanes(mean, rho, block)
    grid = (pp // block,)
    ok = pl.pallas_call(
        functools.partial(
            _payload_validity_kernel, wire_dtype=wire_dtype,
            bound=float(bound),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block), lambda i: (0, i)),
            pl.BlockSpec((n, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(mean, rho)
    return ok[:, 0] > 0.0


def _consensus_masked_sparse_kernel(
    nbr_ref,  # scalar-prefetch [N, D] int32 neighbor ids (self-padded)
    wts_ref,  # scalar-prefetch [N, D] fp32 weights (0-padded)
    act_ref,  # scalar-prefetch [N] int32 activity mask
    mean_ref,  # [1, BLOCK] — row nbr[i, d], column tile j
    rho_ref,  # [1, BLOCK]
    mean_out_ref,  # [1, BLOCK] — row i, column tile j
    rho_out_ref,  # [1, BLOCK]
    acc_prec,  # VMEM scratch [1, BLOCK]
    acc_pm,  # VMEM scratch [1, BLOCK]
    *,
    wire_dtype,
):
    i = pl.program_id(0)
    d = pl.program_id(2)
    w = wts_ref[i, d]

    @pl.when(d == 0)
    def _init():
        acc_prec[...] = jnp.zeros_like(acc_prec)
        acc_pm[...] = jnp.zeros_like(acc_pm)

    sigma = jax.nn.softplus(rho_ref[...])
    if wire_dtype == jnp.float32:
        # pre-wire op order, verbatim — f32 stays bitwise identical
        wp = w / (sigma * sigma)
        acc_prec[...] += wp
        acc_pm[...] += wp * mean_ref[...]
    else:
        prec = 1.0 / (sigma * sigma)
        prec_x = wire_roundtrip(prec, wire_dtype)
        pm_x = wire_roundtrip(prec * mean_ref[...], wire_dtype)
        acc_prec[...] += w * prec_x
        acc_pm[...] += w * pm_x

    @pl.when(d == pl.num_programs(2) - 1)
    def _finish():
        # inactive rows are all-self in the tables (w_eff row == e_i), so the
        # tile currently in (mean_ref, rho_ref) IS the agent's own row — the
        # passthrough never touches anyone else's data
        passthrough = act_ref[i] == 0
        prec_out = acc_prec[...]
        mean_out_ref[...] = jnp.where(
            passthrough, mean_ref[...], acc_pm[...] / prec_out
        )
        rho_out_ref[...] = jnp.where(
            passthrough, rho_ref[...], softplus_inv(jax.lax.rsqrt(prec_out))
        )


@functools.partial(jax.jit, static_argnames=("block", "interpret", "wire_dtype"))
def consensus_fused_masked_sparse(
    neighbors: jax.Array,  # [N, D] int32 window neighbor ids (self-padded)
    weights: jax.Array,  # [N, D] fp32 w_eff[i, neighbors[i]] (0-padded)
    active: jax.Array,  # [N] activity mask
    mean: jax.Array,  # [N, P]
    rho: jax.Array,  # [N, P]
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
    wire_dtype=None,
) -> tuple[jax.Array, jax.Array]:
    """Active-edge eq. (6): CSR neighbor tables of the window's W-tilde
    (``core.flat.neighbor_tables(w_eff)``) + per-agent activity mask.

    Active agents accumulate only their deg(i) <= D fired-neighbor tiles;
    inactive agents copy their own (mean, rho) row bit-identically (their
    table rows are all-self, so no foreign tile is ever gathered — and
    never crosses the wire, whatever ``wire_dtype`` says).  HBM traffic
    scales with the window's active-edge fraction instead of N — see
    ``launch.costmodel.gossip_window_roofline``.
    """
    interpret = _auto_interpret(interpret)
    wire_dtype = canonical_wire_dtype(wire_dtype)
    n, p = mean.shape
    d = neighbors.shape[1]
    mean, rho, pp = _pad_lanes(mean, rho, block)
    grid = (n, pp // block, d)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda i, j, k, nbr, wts, act: (nbr[i, k], j)),
            pl.BlockSpec((1, block), lambda i, j, k, nbr, wts, act: (nbr[i, k], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i, j, k, nbr, wts, act: (i, j)),
            pl.BlockSpec((1, block), lambda i, j, k, nbr, wts, act: (i, j)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, block), jnp.float32),
            pltpu.VMEM((1, block), jnp.float32),
        ],
    )
    mean_out, rho_out = pl.pallas_call(
        functools.partial(
            _consensus_masked_sparse_kernel, wire_dtype=wire_dtype
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, pp), mean.dtype),
            jax.ShapeDtypeStruct((n, pp), rho.dtype),
        ],
        interpret=interpret,
    )(
        neighbors.astype(jnp.int32),
        weights.astype(jnp.float32),
        active.astype(jnp.int32),
        mean,
        rho,
    )
    return mean_out[:, :p], rho_out[:, :p]
