"""Pallas TPU kernel: fused precision-weighted posterior consensus (eq. 6).

For one agent, given the stacked neighbor posteriors (mean, rho) and the
agent's W row, compute

    prec_j   = softplus(rho_j)^-2
    prec_out = sum_j w_j prec_j
    mean_out = sum_j w_j prec_j mean_j / prec_out
    rho_out  = softplus^-1(prec_out^-1/2)

Unfused, this is ~6 elementwise HBM round-trips over tensors the size of the
model (hundreds of MB-GB per device); the consensus step is purely
memory-bound, so fusing everything into a single pass is worth ~6x on the
consensus step's HBM traffic.  The parameter vector is processed in VMEM
tiles of [N_neighbors, BLOCK] — with N <= 16 neighbors and BLOCK = 2048
fp32 lanes the working set is N*BLOCK*4B*2 = 256 KiB << 16 MiB VMEM.

Kernel layout notes (TPU):
  * the last dim (BLOCK) is the lane dim — keep it a multiple of 128;
  * the neighbor dim N rides the sublane dim; reductions over it are
    cheap vector-unit reductions, no MXU involvement.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048


def _consensus_kernel(w_ref, mean_ref, rho_ref, mean_out_ref, rho_out_ref):
    w = w_ref[...]  # [N, 1]
    mean = mean_ref[...]  # [N, BLOCK]
    rho = rho_ref[...]  # [N, BLOCK]
    sigma = jax.nn.softplus(rho)
    prec = 1.0 / (sigma * sigma)
    wp = w * prec  # [N, BLOCK]
    prec_out = jnp.sum(wp, axis=0)  # [BLOCK]
    mean_out = jnp.sum(wp * mean, axis=0) / prec_out
    sigma_out = jax.lax.rsqrt(prec_out)
    # softplus^-1(y) = y + log1p(-exp(-y)), stable for y > 0
    rho_out = sigma_out + jnp.log1p(-jnp.exp(-sigma_out))
    mean_out_ref[...] = mean_out[None, :]
    rho_out_ref[...] = rho_out[None, :]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def consensus_fused(
    w_row: jax.Array,  # [N]
    mean_stack: jax.Array,  # [N, P]
    rho_stack: jax.Array,  # [N, P]
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused consensus over a flat parameter block.  Returns (mean, rho) [P].

    ``interpret=True`` executes the kernel body with the Pallas interpreter
    (CPU-correctness mode); on real TPU pass interpret=False.
    """
    n, p = mean_stack.shape
    pad = (-p) % block
    if pad:
        mean_stack = jnp.pad(mean_stack, ((0, 0), (0, pad)))
        # rho pads with 1.0 (finite sigma) to avoid inf precision on pad lanes
        rho_stack = jnp.pad(rho_stack, ((0, 0), (0, pad)), constant_values=1.0)
    pp = p + pad
    grid = (pp // block,)
    mean_out, rho_out = pl.pallas_call(
        _consensus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),  # w broadcast to all tiles
            pl.BlockSpec((n, block), lambda i: (0, i)),
            pl.BlockSpec((n, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, pp), mean_stack.dtype),
            jax.ShapeDtypeStruct((1, pp), rho_stack.dtype),
        ],
        interpret=interpret,
    )(w_row[:, None], mean_stack, rho_stack)
    return mean_out[0, :p], rho_out[0, :p]
