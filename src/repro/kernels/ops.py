"""jit'd public wrappers around the Pallas kernels, with pytree plumbing and
interpret/TPU dispatch.

``on_tpu()`` decides the default execution mode: Pallas-compiled on TPU,
interpret (CPU-correctness) elsewhere.  All wrappers take ``interpret=None``
to mean "auto".
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.consensus import consensus_fused
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gauss_vi import sample_and_kl_fused

PyTree = Any


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _auto(interpret):
    return (not on_tpu()) if interpret is None else interpret


def _flatten(tree: PyTree) -> tuple[jax.Array, Any, list]:
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1) for l in leaves])
    shapes = [l.shape for l in leaves]
    return flat, treedef, shapes


def _unflatten(flat: jax.Array, treedef, shapes) -> PyTree:
    out, off = [], 0
    for shp in shapes:
        n = 1
        for d in shp:
            n *= d
        out.append(flat[off : off + n].reshape(shp))
        off += n
    return jax.tree.unflatten(treedef, out)


def consensus_posterior(posts, w_row: jax.Array, *, interpret: bool | None = None):
    """Fused eq. (6) over a whole posterior pytree with stacked neighbor axis.

    ``posts``: GaussianPosterior whose leaves are [N, ...].  Returns a
    GaussianPosterior without the leading axis (one agent's consensus).
    """
    from repro.core.posterior import GaussianPosterior

    n = w_row.shape[0]
    mean_leaves, treedef = jax.tree.flatten(posts.mean)
    rho_leaves = treedef.flatten_up_to(posts.rho)
    mean_flat = jnp.concatenate([l.reshape(n, -1) for l in mean_leaves], axis=1)
    rho_flat = jnp.concatenate([l.reshape(n, -1) for l in rho_leaves], axis=1)
    mean_o, rho_o = consensus_fused(
        w_row, mean_flat, rho_flat, interpret=_auto(interpret)
    )
    shapes = [l.shape[1:] for l in mean_leaves]
    mean = _unflatten(mean_o, treedef, shapes)
    rho = _unflatten(rho_o, treedef, shapes)
    return GaussianPosterior(mean=mean, rho=rho)


def sample_and_kl(post, prior, key: jax.Array, *, interpret: bool | None = None):
    """Fused reparameterized sample + KL over a whole posterior pytree.

    Returns (theta pytree, kl scalar)."""
    mu_flat, treedef, shapes = _flatten(post.mean)
    rho_flat, _, _ = _flatten(post.rho)
    mu_p_flat, _, _ = _flatten(prior.mean)
    rho_p_flat, _, _ = _flatten(prior.rho)
    eps = jax.random.normal(key, mu_flat.shape, mu_flat.dtype)
    theta_flat, kl = sample_and_kl_fused(
        mu_flat, rho_flat, eps, mu_p_flat, rho_p_flat, interpret=_auto(interpret)
    )
    return _unflatten(theta_flat, treedef, shapes), kl


def attention(
    q, k, v, *, causal=True, window=0, block_q=512, block_k=512,
    interpret: bool | None = None,
):
    """[B,H,S,hd] flash attention (Pallas on TPU, interpret elsewhere)."""
    return flash_attention(
        q, k, v, causal=causal, window=window, block_q=block_q, block_k=block_k,
        interpret=_auto(interpret),
    )
