"""jit'd public wrappers around the Pallas kernels, with pytree plumbing and
interpret/TPU dispatch.

``on_tpu()`` decides the default execution mode: Pallas-compiled on TPU,
interpret (CPU-correctness) elsewhere.  All wrappers take ``interpret=None``
to mean "auto".

Flatten/unflatten here record per-leaf dtypes and cast through a common
fp32 compute dtype: ``jnp.concatenate`` on mixed-dtype leaves silently
promotes (e.g. f32+bf16 -> f32 but int leaves -> f32 with value change, and
bf16-only trees would stay bf16 while the kernels assume fp32), so the
round-trip now casts every leaf back to its recorded dtype (satellite fix
of ISSUE 1).  For the canonical flat runtime use ``core.flat.FlatLayout``,
which caches this layout once instead of rebuilding it per call.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.numerics import COMPUTE_DTYPE
from repro.kernels.dispatch import auto_interpret, on_tpu
from repro.kernels.consensus import (
    consensus_fused,
    consensus_fused_network,
    consensus_fused_sparse,
)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gauss_vi import sample_and_kl_fused

PyTree = Any


def _auto(interpret):
    return auto_interpret(interpret)


def _flatten(tree: PyTree) -> tuple[jax.Array, Any, list, list]:
    """Flatten to a contiguous fp32 vector, recording shapes AND dtypes so
    ``_unflatten`` restores mixed-dtype trees exactly (no silent promotion)."""
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(COMPUTE_DTYPE) for l in leaves])
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    return flat, treedef, shapes, dtypes


def _unflatten(flat: jax.Array, treedef, shapes, dtypes) -> PyTree:
    out, off = [], 0
    for shp, dt in zip(shapes, dtypes):
        n = 1
        for d in shp:
            n *= d
        out.append(flat[off : off + n].reshape(shp).astype(dt))
        off += n
    return jax.tree.unflatten(treedef, out)


def consensus_posterior(posts, w_row: jax.Array, *, interpret: bool | None = None):
    """Fused eq. (6) over a whole posterior pytree with stacked neighbor axis.

    ``posts``: GaussianPosterior whose leaves are [N, ...].  Returns a
    GaussianPosterior without the leading axis (one agent's consensus).
    """
    from repro.core.posterior import GaussianPosterior

    n = w_row.shape[0]
    mean_leaves, treedef = jax.tree.flatten(posts.mean)
    rho_leaves = treedef.flatten_up_to(posts.rho)
    dtypes = [l.dtype for l in mean_leaves]
    mean_flat = jnp.concatenate(
        [l.reshape(n, -1).astype(COMPUTE_DTYPE) for l in mean_leaves], axis=1
    )
    rho_flat = jnp.concatenate(
        [l.reshape(n, -1).astype(COMPUTE_DTYPE) for l in rho_leaves], axis=1
    )
    mean_o, rho_o = consensus_fused(
        w_row, mean_flat, rho_flat, interpret=_auto(interpret)
    )
    shapes = [l.shape[1:] for l in mean_leaves]
    mean = _unflatten(mean_o, treedef, shapes, dtypes)
    rho = _unflatten(rho_o, treedef, shapes, dtypes)
    return GaussianPosterior(mean=mean, rho=rho)


def consensus_network(posts, W: jax.Array, *, interpret: bool | None = None):
    """Single fused network-wide eq. (6) (``consensus_fused_network``) for a
    ``core.flat.FlatPosterior``: one ``pallas_call`` over the whole [N, P]
    network posterior.  Prefer ``core.flat.consensus_flat`` (auto XLA/Pallas
    dispatch); this wrapper forces the Pallas kernel."""
    import dataclasses

    mean, rho = consensus_fused_network(
        W, posts.mean, posts.rho, interpret=_auto(interpret)
    )
    return dataclasses.replace(posts, mean=mean, rho=rho)


def consensus_network_sparse(
    posts, neighbors: jax.Array, weights: jax.Array, *, interpret: bool | None = None
):
    """Sparse-neighborhood variant of ``consensus_network`` (CSR-style
    tables from ``core.flat.neighbor_tables``)."""
    import dataclasses

    mean, rho = consensus_fused_sparse(
        neighbors, weights, posts.mean, posts.rho, interpret=_auto(interpret)
    )
    return dataclasses.replace(posts, mean=mean, rho=rho)


def sample_and_kl(post, prior, key: jax.Array, *, interpret: bool | None = None):
    """Fused reparameterized sample + KL over a whole posterior pytree.

    Returns (theta pytree, kl scalar)."""
    mu_flat, treedef, shapes, dtypes = _flatten(post.mean)
    rho_flat, _, _, _ = _flatten(post.rho)
    mu_p_flat, _, _, _ = _flatten(prior.mean)
    rho_p_flat, _, _, _ = _flatten(prior.rho)
    eps = jax.random.normal(key, mu_flat.shape, mu_flat.dtype)
    theta_flat, kl = sample_and_kl_fused(
        mu_flat, rho_flat, eps, mu_p_flat, rho_p_flat, interpret=_auto(interpret)
    )
    return _unflatten(theta_flat, treedef, shapes, dtypes), kl


def attention(
    q, k, v, *, causal=True, window=0, block_q=512, block_k=512,
    interpret: bool | None = None,
):
    """[B,H,S,hd] flash attention (Pallas on TPU, interpret elsewhere)."""
    return flash_attention(
        q, k, v, causal=causal, window=window, block_q=block_q, block_k=block_k,
        interpret=_auto(interpret),
    )
