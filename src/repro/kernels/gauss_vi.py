"""Pallas TPU kernel: fused Bayes-by-Backprop parameter sampling + KL.

One pass over the posterior (mu, rho), the prior (mu_p, rho_p), and the
standard-normal noise eps produces BOTH

    theta = mu + softplus(rho) * eps                (reparameterized sample)
    kl    = sum [ log(sp/sq) + (sq^2+(mq-mp)^2)/(2 sp^2) - 1/2 ]

Every VI step reads 5 model-sized tensors and writes 1 + a scalar; unfused
XLA materializes sigma twice (sample and KL) and walks the arrays twice.
The fusion halves the VI step's posterior-side HBM traffic — this is the
hot elementwise path of the paper's local-update step (eq. 5).

Tiles: [1, BLOCK] fp32 lanes; per-block KL partials land in a [grid] vector
reduced by the caller (keeps the kernel free of cross-block communication).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import auto_interpret as _auto_interpret

DEFAULT_BLOCK = 2048


def _gauss_vi_kernel(mu_ref, rho_ref, eps_ref, mu_p_ref, rho_p_ref,
                     theta_ref, kl_ref):
    mu = mu_ref[...]
    rho = rho_ref[...]
    eps = eps_ref[...]
    mu_p = mu_p_ref[...]
    rho_p = rho_p_ref[...]
    sq = jax.nn.softplus(rho)
    sp = jax.nn.softplus(rho_p)
    theta_ref[...] = mu + sq * eps
    d = mu - mu_p
    kl = jnp.log(sp / sq) + (sq * sq + d * d) / (2.0 * sp * sp) - 0.5
    kl_ref[0, 0] = jnp.sum(kl)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sample_and_kl_fused(
    mu: jax.Array,  # [P]
    rho: jax.Array,  # [P]
    eps: jax.Array,  # [P]
    mu_p: jax.Array,  # [P]
    rho_p: jax.Array,  # [P]
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (theta [P], kl scalar).  ``interpret=None`` auto-dispatches
    (Pallas-compiled on TPU, interpreter elsewhere)."""
    interpret = _auto_interpret(interpret)
    p = mu.shape[0]
    pad = (-p) % block
    if pad:
        mu = jnp.pad(mu, (0, pad))
        eps = jnp.pad(eps, (0, pad))
        mu_p = jnp.pad(mu_p, (0, pad))
        # pad rho with the PRIOR rho so padded lanes contribute KL == 0
        rho = jnp.pad(rho, (0, pad), constant_values=1.0)
        rho_p = jnp.pad(rho_p, (0, pad), constant_values=1.0)
    pp = p + pad
    grid = (pp // block,)
    spec = pl.BlockSpec((1, block), lambda i: (0, i))
    theta, kl_parts = pl.pallas_call(
        _gauss_vi_kernel,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec, pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((1, pp), mu.dtype),
            jax.ShapeDtypeStruct((grid[0], 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        mu[None, :], rho[None, :], eps[None, :], mu_p[None, :], rho_p[None, :]
    )
    return theta[0, :p], jnp.sum(kl_parts)
