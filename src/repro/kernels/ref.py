"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def consensus_ref(w_row, mean_stack, rho_stack):
    """Eq. (6) over a flat parameter block.  Shapes as consensus_fused."""
    sigma = jax.nn.softplus(rho_stack)
    prec = 1.0 / jnp.square(sigma)
    wp = w_row[:, None] * prec
    prec_out = jnp.sum(wp, axis=0)
    mean_out = jnp.sum(wp * mean_stack, axis=0) / prec_out
    sigma_out = 1.0 / jnp.sqrt(prec_out)
    rho_out = sigma_out + jnp.log1p(-jnp.exp(-sigma_out))
    return mean_out, rho_out


def sample_and_kl_ref(mu, rho, eps, mu_p, rho_p):
    """Reparameterized sample + closed-form Gaussian KL (see gauss_vi)."""
    sq = jax.nn.softplus(rho)
    sp = jax.nn.softplus(rho_p)
    theta = mu + sq * eps
    d = mu - mu_p
    kl = jnp.sum(
        jnp.log(sp / sq) + (jnp.square(sq) + jnp.square(d)) / (2.0 * jnp.square(sp)) - 0.5
    )
    return theta, kl


def attention_ref(q, k, v, *, causal=True, window=0):
    """Naive full-materialization attention.  q,k,v: [B,H,S,hd]."""
    b, h, s, hd = q.shape
    sk = k.shape[2]
    s_mat = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(hd)
    q_idx = jnp.arange(s)[:, None]
    k_idx = jnp.arange(sk)[None, :]
    mask = jnp.ones((s, sk), bool)
    if causal:
        mask = mask & (k_idx <= q_idx)
    if window:
        mask = mask & (k_idx > q_idx - window)
    s_mat = jnp.where(mask[None, None], s_mat, -1e30)
    p = jax.nn.softmax(s_mat, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
