# Pallas TPU kernels for the paper's compute hot-spots:
#   consensus        fused precision-weighted posterior consensus (eq. 6)
#   gauss_vi         fused Bayes-by-Backprop sample + KL (eq. 5)
#   flash_attention  blocked causal/SWA attention (prefill/train hot path)
# Each has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py;
# validated in interpret=True mode on CPU, compiled via Mosaic on TPU.
from repro.kernels import ops, ref
from repro.kernels.consensus import (
    consensus_fused,
    consensus_fused_network,
    consensus_fused_sparse,
)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gauss_vi import sample_and_kl_fused

__all__ = [
    "ops",
    "ref",
    "consensus_fused",
    "consensus_fused_network",
    "consensus_fused_sparse",
    "flash_attention",
    "sample_and_kl_fused",
]
