"""Shared execution-mode dispatch for all Pallas kernels.

Single home for the "Pallas-compiled on TPU, interpreter elsewhere" policy
so the per-kernel wrappers and ops.py cannot drift apart.
"""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def auto_interpret(interpret: bool | None) -> bool:
    """Resolve an ``interpret=None`` auto flag; an explicit bool wins."""
    return (not on_tpu()) if interpret is None else interpret
