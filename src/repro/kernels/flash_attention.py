"""Pallas TPU kernel: blocked flash attention (causal / sliding-window).

This is the TPU-native adaptation of the attention hot path used by
prefill_32k and train_4k: the [S, S] score matrix never exists; the kernel
streams K/V tiles through VMEM while a running (max, denominator,
accumulator) lives in VMEM scratch.

Grid: (B, H, n_q_blocks, n_k_blocks), K innermost.  TPU grid iterations are
sequential per core, so the scratch persists across the K dimension and the
output tile is written once, on the final K block.  Fully-masked K blocks
(beyond the causal frontier or behind the sliding window) are skipped with
``pl.when`` — for causal training this halves the MXU work, and for a
window of w only ceil(w/bk)+1 K blocks per Q block are touched at all.

Block sizes default to 512 (q) x 512 (k): VMEM working set per step =
q(512*hd) + k/v(2*512*hd) + scores(512*512) fp32 ~= 2.3 MB at hd=128, well
under the ~16 MB VMEM budget, and all matmul dims are multiples of 128
(MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import auto_interpret as _auto_interpret
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, causal: bool, window: int, bq: int, bk: int, scale: float
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    # block-level reachability (static shapes, dynamic predicate)
    needed = jnp.asarray(True)
    if causal:
        needed = needed & (k_start <= q_start + bq - 1)
    if window:
        needed = needed & (k_start + bk - 1 > q_start - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]
        q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask = mask & (k_idx <= q_idx)
        if window:
            mask = mask & (k_idx > q_idx - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
        corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,  # [B, H, S, hd]
    k: jax.Array,  # [B, H, Sk, hd]
    v: jax.Array,  # [B, H, Sk, hd]
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    # interpret=None -> auto: Pallas-compiled on TPU, interpreter elsewhere
    interpret = _auto_interpret(interpret)
    b, h, s, hd = q.shape
    sk = k.shape[2]
    bq = min(block_q, s)
    bk = min(block_k, sk)
    assert s % bq == 0 and sk % bk == 0, "seq lens must divide block sizes"
    scale = 1.0 / (hd ** 0.5)
    grid = (b, h, s // bq, sk // bk)
    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, bq=bq, bk=bk, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b_, h_, qi, ki: (b_, h_, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b_, h_, qi, ki: (b_, h_, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # running max m
            pltpu.VMEM((bq, 1), jnp.float32),  # running denominator l
            pltpu.VMEM((bq, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
