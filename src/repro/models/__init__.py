from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    init_params,
    nll_loss,
)
from repro.models import attention, modules, moe, rglru, xlstm

__all__ = [
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "nll_loss",
    "attention",
    "modules",
    "moe",
    "rglru",
    "xlstm",
]
