"""Top-k mixture-of-experts FFN with capacity-based dispatch.

Routing: softmax router -> top-k experts per token -> capacity-limited
dispatch (tokens over capacity are dropped, standard Switch/GShard
semantics) -> batched expert SwiGLU via einsum over the expert dim ->
weighted combine.  The expert dim shards over the ``model`` mesh axis
(expert parallelism); under GSPMD the gather/scatter around the expert
einsum lowers to cross-shard collectives.  The hand-scheduled shard_map
all-to-all variant lives in launch/expert_parallel.py (the beyond-paper
optimization in EXPERIMENTS.md §Perf).

Also emits the load-balancing auxiliary loss (Switch-style
E * sum_e f_e * p_e) — the paper-external but production-required router
regularizer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.modules import truncated_normal_init


def moe_init(key, cfg):
    ks = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": truncated_normal_init(ks[0], (d, e), 1.0),
        "w_gate": truncated_normal_init(ks[1], (e, d, f), 1.0),
        "w_up": truncated_normal_init(ks[2], (e, d, f), 1.0),
        "w_down": truncated_normal_init(ks[3], (e, f, d), 1.0),
    }


def route_topk(router_logits: jax.Array, top_k: int):
    """[T, E] -> (weights [T, k], expert_idx [T, k], probs [T, E]).
    Top-k softmax weights renormalized over the selected experts."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights, idx, probs


def load_balance_loss(probs: jax.Array, idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch-transformer aux loss: E * sum_e (fraction routed to e) * (mean prob e)."""
    t = probs.shape[0]
    counts = jnp.zeros((n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    frac = counts / (t * idx.shape[-1])
    mean_prob = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(frac * mean_prob)


def _capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(n_tokens * top_k * factor / n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8, floor 8


def moe_ffn(params, x: jax.Array, cfg, dtype=None):
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar).

    Dispatch is fully static-shaped: for each (expert, capacity-slot) we
    compute the source token index, gather, run the expert batched matmuls,
    and scatter-add back with the router weights.
    """
    dtype = dtype or x.dtype
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(t, e, k, cfg.capacity_factor)
    xt = x.reshape(t, d)

    logits = xt @ params["router"].astype(dtype)  # [T, E]
    weights, idx, probs = route_topk(logits, k)  # [T,k], [T,k], [T,E]
    aux = load_balance_loss(probs, idx, e)

    # position of each (token, k) assignment within its expert's capacity
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)  # [T, k, E]
    flat = onehot.reshape(t * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=0) * flat - 1  # [T*k, E], -1 elsewhere
    slot = jnp.max(pos_in_expert, axis=-1)  # [T*k] slot id (within expert)
    keep = (slot >= 0) & (slot < cap)
    expert_of = idx.reshape(t * k)
    token_of = jnp.repeat(jnp.arange(t), k)
    w_of = weights.reshape(t * k)

    # scatter (expert, slot) -> token index (+1; 0 = empty, token row T is zeros)
    dispatch = jnp.zeros((e, cap), jnp.int32)
    dispatch = dispatch.at[
        jnp.where(keep, expert_of, 0), jnp.where(keep, slot, 0)
    ].max(jnp.where(keep, token_of + 1, 0))
    xt_pad = jnp.concatenate([jnp.zeros((1, d), xt.dtype), xt], axis=0)
    x_disp = xt_pad[dispatch]  # [E, C, D]

    # batched expert SwiGLU: expert dim shards over "model"
    g = jnp.einsum("ecd,edf->ecf", x_disp, params["w_gate"].astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", x_disp, params["w_up"].astype(dtype))
    yd = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["w_down"].astype(dtype))

    # combine: scatter-add back to tokens with router weights
    out = jnp.zeros((t + 1, d), jnp.float32)
    gathered = yd[jnp.where(keep, expert_of, 0), jnp.where(keep, slot, 0)]  # [T*k, D]
    contrib = jnp.where(keep[:, None], gathered.astype(jnp.float32) * w_of[:, None], 0.0)
    out = out.at[jnp.where(keep, token_of + 1, 0)].add(contrib)
    return out[1:].astype(dtype).reshape(b, s, d), aux
