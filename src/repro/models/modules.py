"""Basic neural modules (functional, dict-of-arrays params).

All weights are stored in ``param_dtype`` (fp32 — the Bayesian posterior
needs fp32 means/rhos) and cast to the compute dtype inside ``apply``.
Initializers return UNSTACKED per-layer params; the transformer assembly
stacks them over periods for scan.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def truncated_normal_init(key, shape, scale, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / jnp.sqrt(fan_in)
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def linear_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    return {"w": truncated_normal_init(key, (d_in, d_out), 1.0, dtype)}


def linear(params, x, dtype):
    return x @ params["w"].astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"emb": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


def embed(params, tokens, dtype):
    return params["emb"].astype(dtype)[tokens]


def unembed(params, x, dtype):
    # logits in fp32 for a stable softmax-xent
    return (x @ params["emb"].astype(dtype).T).astype(jnp.float32)


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding.  x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": truncated_normal_init(k1, (d_model, d_ff), 1.0, dtype),
        "w_up": truncated_normal_init(k2, (d_model, d_ff), 1.0, dtype),
        "w_down": truncated_normal_init(k3, (d_ff, d_model), 1.0, dtype),
    }


def swiglu(params, x, dtype):
    g = x @ params["w_gate"].astype(dtype)
    u = x @ params["w_up"].astype(dtype)
    return (jax.nn.silu(g) * u) @ params["w_down"].astype(dtype)


def softmax_xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Total (summed) cross-entropy; logits [..., V], targets [...] int."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(logz - gold)
