"""Composable transformer assembly.

An architecture is ``n_periods`` repetitions of ``cfg.pattern`` (+ a tail
remainder).  Per-kind parameter stacks carry leaves of shape
[n_periods, c_kind, ...], and the layer loop is ONE ``lax.scan`` over
periods — compile time and HLO size stay O(pattern), not O(n_layers), which
is what makes the 52-layer/42-B dry-runs tractable.  Caches (KV / recurrent
state) are threaded through the same scan as xs/ys.

Supported block kinds: attn, local_attn, moe, mlstm, slstm, rglru,
enc_attn, dec_attn (see configs.base docstring).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models.attention import attention_block, attn_init, init_kv_cache
from repro.models.modules import (
    embed,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    swiglu,
    swiglu_init,
    truncated_normal_init,
    unembed,
)
from repro.models.rglru import rglru_block, rglru_init, rglru_state_init
from repro.models.xlstm import (
    mlstm_block,
    mlstm_init,
    mlstm_state_init,
    slstm_block,
    slstm_init,
    slstm_state_init,
)

PyTree = Any

ATTN_KINDS = ("attn", "local_attn", "moe", "enc_attn", "dec_attn")


# ---------------------------------------------------------------------------
# per-kind init / apply / cache
# ---------------------------------------------------------------------------


def block_init(key, kind: str, cfg):
    if kind in ("attn", "local_attn", "enc_attn", "dec_attn"):
        ks = jax.random.split(key, 5)
        p = {
            "norm1": rmsnorm_init(cfg.d_model),
            "attn": attn_init(ks[0], cfg),
            "norm2": rmsnorm_init(cfg.d_model),
            "mlp": swiglu_init(ks[1], cfg.d_model, cfg.d_ff),
        }
        if kind == "dec_attn":
            p["norm_x"] = rmsnorm_init(cfg.d_model)
            p["xattn"] = attn_init(ks[2], cfg, cross=True)
        return p
    if kind == "moe":
        ks = jax.random.split(key, 2)
        return {
            "norm1": rmsnorm_init(cfg.d_model),
            "attn": attn_init(ks[0], cfg),
            "norm2": rmsnorm_init(cfg.d_model),
            "moe": moe_lib.moe_init(ks[1], cfg),
        }
    if kind == "mlstm":
        return mlstm_init(key, cfg)
    if kind == "slstm":
        return slstm_init(key, cfg)
    if kind == "rglru":
        ks = jax.random.split(key, 2)
        return {
            "rec": rglru_init(ks[0], cfg),
            "norm2": rmsnorm_init(cfg.d_model),
            "mlp": swiglu_init(ks[1], cfg.d_model, cfg.d_ff),
        }
    raise ValueError(f"unknown block kind {kind!r}")


def block_cache_init(kind: str, cfg, batch: int, capacity: int, dtype=jnp.bfloat16):
    """Decode-time cache for one layer of ``kind``."""
    if kind in ("attn", "moe", "dec_attn"):
        return init_kv_cache(cfg, batch, capacity, dtype)
    if kind == "local_attn":
        cap = min(capacity, cfg.sliding_window or capacity)
        return init_kv_cache(cfg, batch, cap, dtype)
    if kind == "mlstm":
        return mlstm_state_init(cfg, batch)
    if kind == "slstm":
        return slstm_state_init(cfg, batch)
    if kind == "rglru":
        return rglru_state_init(cfg, batch)
    raise ValueError(kind)


def block_apply(
    kind: str,
    params,
    x,
    cfg,
    *,
    positions,
    cache=None,
    enc_out=None,
    window_override: int | None = None,
):
    """Returns (x', new_cache, aux_loss)."""
    aux = jnp.asarray(0.0, jnp.float32)
    if kind in ("attn", "local_attn", "moe", "enc_attn", "dec_attn"):
        window = cfg.sliding_window if kind == "local_attn" else 0
        if window_override is not None and kind in ("attn", "local_attn"):
            window = window_override
        h = rmsnorm(params["norm1"], x, cfg.norm_eps)
        y, new_cache = attention_block(
            params["attn"],
            h,
            cfg,
            causal=kind != "enc_attn",
            window=window,
            positions=positions,
            cache=cache,
            use_rope=kind not in ("enc_attn", "dec_attn"),
        )
        x = x + y
        if kind == "dec_attn":
            hx = rmsnorm(params["norm_x"], x, cfg.norm_eps)
            yx, _ = attention_block(
                params["xattn"],
                hx,
                cfg,
                causal=False,
                positions=positions,
                cross_x=enc_out,
                use_rope=False,
            )
            x = x + yx
        h2 = rmsnorm(params["norm2"], x, cfg.norm_eps)
        if kind == "moe":
            y2, aux = moe_lib.moe_ffn(params["moe"], h2, cfg)
        else:
            y2 = swiglu(params["mlp"], h2, x.dtype)
        return x + y2, new_cache, aux
    if kind == "mlstm":
        y, new_state = mlstm_block(params, x, cfg, state=cache)
        return y, new_state, aux
    if kind == "slstm":
        y, new_state = slstm_block(params, x, cfg, state=cache)
        return y, new_state, aux
    if kind == "rglru":
        y, new_state = rglru_block(params["rec"], x, cfg, state=cache)
        h2 = rmsnorm(params["norm2"], y, cfg.norm_eps)
        y2 = swiglu(params["mlp"], h2, x.dtype)
        return y + y2, new_state, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------


def _stack_inits(key, kind: str, cfg, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: block_init(k, kind, cfg))(keys)


def init_params(cfg, key) -> PyTree:
    cfg.validate()
    ks = jax.random.split(key, 8)
    params: dict = {"embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model)}
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": truncated_normal_init(ks[1], (cfg.d_model, cfg.padded_vocab), 1.0)
        }
    params["final_norm"] = rmsnorm_init(cfg.d_model)

    counts = cfg.kind_counts()
    stacks = {}
    kkeys = jax.random.split(ks[2], len(counts))
    for kk, (kind, c) in zip(kkeys, counts.items()):
        n = cfg.n_periods * c
        if n:
            stk = _stack_inits(kk, kind, cfg, n)
            stacks[kind] = jax.tree.map(
                lambda a: a.reshape((cfg.n_periods, c) + a.shape[1:]), stk
            )
    params["stacks"] = stacks
    if cfg.tail:
        tkeys = jax.random.split(ks[3], len(cfg.tail))
        params["tail"] = [
            block_init(tk, kind, cfg) for tk, kind in zip(tkeys, cfg.tail)
        ]
    if cfg.is_encdec:
        ekeys = jax.random.split(ks[4], 2)
        params["enc_stack"] = jax.tree.map(
            lambda a: a[:, None],
            _stack_inits(ekeys[0], "enc_attn", cfg, cfg.encoder_layers),
        )
        params["enc_norm"] = rmsnorm_init(cfg.d_model)
    if cfg.frontend == "vision_stub":
        params["patch_proj"] = {
            "w": truncated_normal_init(ks[5], (cfg.d_model, cfg.d_model), 1.0)
        }
    return params


def init_cache(cfg, batch: int, capacity: int, dtype=jnp.bfloat16) -> PyTree:
    """Stacked decode caches matching the scan layout."""
    counts = cfg.kind_counts()
    cache: dict = {"stacks": {}}
    for kind, c in counts.items():
        if cfg.n_periods:
            one = block_cache_init(kind, cfg, batch, capacity, dtype)
            cache["stacks"][kind] = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (cfg.n_periods, c) + a.shape
                ).copy(),
                one,
            )
    if cfg.tail:
        cache["tail"] = [
            block_cache_init(kind, cfg, batch, capacity, dtype) for kind in cfg.tail
        ]
    return cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _sinusoidal(positions, d_model):
    half = d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * jnp.log(10000.0) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _apply_period(cfg, pattern, stacks_slice, x, positions, cache_slice, enc_out,
                  window_override=None):
    """Apply one period's blocks.  stacks_slice / cache_slice leaves are
    [c_kind, ...]; returns (x, new_cache_slice, aux)."""
    offsets: dict[str, int] = {}
    aux = jnp.asarray(0.0, jnp.float32)
    upd: dict[str, list] = {}
    for kind in pattern:
        o = offsets.get(kind, 0)
        offsets[kind] = o + 1
        p = jax.tree.map(lambda a: a[o], stacks_slice[kind])
        c = (
            jax.tree.map(lambda a: a[o], cache_slice[kind])
            if cache_slice is not None
            else None
        )
        x, nc, a = block_apply(
            kind, p, x, cfg, positions=positions, cache=c, enc_out=enc_out,
            window_override=window_override,
        )
        aux = aux + a
        if cache_slice is not None:
            upd.setdefault(kind, []).append(nc)
    new_cache_slice = None
    if cache_slice is not None:
        new_cache_slice = {
            kind: jax.tree.map(lambda *xs: jnp.stack(xs), *lst)
            for kind, lst in upd.items()
        }
    return x, new_cache_slice, aux


def _scan_layers(cfg, pattern, stacks, x, positions, cache, enc_out, remat=False,
                 window_override=None):
    """lax.scan over periods.  stacks leaves: [n_periods, c_kind, ...]."""

    def body(carry, xs):
        h, aux = carry
        stacks_slice, cache_slice = xs
        h, new_cache_slice, a = _apply_period(
            cfg, pattern, stacks_slice, h, positions, cache_slice, enc_out,
            window_override,
        )
        return (h, aux + a), new_cache_slice

    if remat:
        body = jax.checkpoint(body)

    xs = (stacks, cache)
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.asarray(0.0, jnp.float32)), xs)
    return x, new_cache, aux


def forward(
    params: PyTree,
    cfg,
    tokens: jax.Array,  # [B, S_text]
    *,
    positions: jax.Array | None = None,  # [S_total] absolute positions
    cache: PyTree | None = None,
    frames: jax.Array | None = None,  # audio stub embeddings [B, F, D]
    patches: jax.Array | None = None,  # vision stub embeddings [B, P, D]
    remat: bool = False,
    window_override: int | None = None,
    logits_tail: int = 0,
):
    """Returns (logits [B, S_total, padded_vocab], new_cache, aux_loss).

    ``window_override``: force a sliding window on ``attn``/``local_attn``
    kinds (the dense-arch long_500k SWA variant).
    ``logits_tail``: if > 0, unembed only the last ``logits_tail`` positions
    (prefill returns next-token logits without materializing [S, V]).
    """
    dt = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], tokens, dt)
    if cfg.frontend == "vision_stub" and patches is not None:
        pe = patches.astype(dt) @ params["patch_proj"]["w"].astype(dt)
        x = jnp.concatenate([pe, x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)

    enc_out = None
    if cfg.is_encdec:
        assert frames is not None, "enc-dec model needs frame embeddings"
        fpos = jnp.arange(frames.shape[1])
        ex = frames.astype(dt) + _sinusoidal(fpos, cfg.d_model)[None].astype(dt)
        ex, _, _ = _scan_layers(
            cfg, ("enc_attn",), {"enc_attn": params["enc_stack"]}, ex, fpos, None,
            None, remat,
        )
        enc_out = rmsnorm(params["enc_norm"], ex, cfg.norm_eps)
        x = x + _sinusoidal(positions, cfg.d_model)[None].astype(dt)

    cache_stacks = cache["stacks"] if cache is not None else None
    new_cache = None
    x, new_stack_cache, aux = _scan_layers(
        cfg, cfg.pattern, params["stacks"], x, positions, cache_stacks, enc_out,
        remat, window_override,
    )
    tail_cache = []
    if cfg.tail:
        for i, kind in enumerate(cfg.tail):
            c = cache["tail"][i] if cache is not None else None
            x, nc, a = block_apply(
                kind,
                params["tail"][i],
                x,
                cfg,
                positions=positions,
                cache=c,
                enc_out=enc_out,
                window_override=window_override,
            )
            aux = aux + a
            tail_cache.append(nc)
    if cache is not None:
        new_cache = {"stacks": new_stack_cache}
        if cfg.tail:
            new_cache["tail"] = tail_cache

    if logits_tail:
        x = x[:, -logits_tail:]
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x, dt)
    else:
        logits = (x @ params["lm_head"]["w"].astype(dt)).astype(jnp.float32)
    return logits, new_cache, aux


def nll_loss(params, cfg, batch, remat: bool = False) -> tuple[jax.Array, jax.Array]:
    """Total next-token NLL (summed over tokens) + MoE aux.  Returns
    (total_nll, aux).  ``batch``: dict(tokens, targets[, loss_mask, frames,
    patches])."""
    logits, _, aux = forward(
        params,
        cfg,
        batch["tokens"],
        frames=batch.get("frames"),
        patches=batch.get("patches"),
        remat=remat,
    )
    targets = batch["targets"]
    # vlm: logits cover [patches; text] — take the text tail
    if logits.shape[1] != targets.shape[1]:
        logits = logits[:, logits.shape[1] - targets.shape[1] :]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch.get("loss_mask")
    if mask is not None:
        nll = nll * mask
    return jnp.sum(nll), aux


def decode_step(
    params: PyTree,
    cfg,
    token: jax.Array,  # [B, 1]
    position: jax.Array,  # scalar int32 — absolute position of this token
    cache: PyTree,
    enc_out_frames: jax.Array | None = None,
    window_override: int | None = None,
):
    """One-token autoregressive step against the cache.  Returns
    (logits [B, 1, V], new_cache)."""
    positions = position[None] if position.ndim == 0 else position
    logits, new_cache, _ = forward(
        params,
        cfg,
        token,
        positions=positions,
        cache=cache,
        frames=enc_out_frames,
        window_override=window_override,
    )
    return logits, new_cache
