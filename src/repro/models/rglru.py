"""RecurrentGemma / Griffin recurrent block (De et al., arXiv:2402.19427).

RG-LRU recurrence (diagonal, real-valued):
    r_t = sigmoid(W_r x_t)                    (recurrence gate)
    i_t = sigmoid(W_i x_t)                    (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)    (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The diagonal linear recurrence is evaluated with ``lax.associative_scan``
(log-depth, TPU-parallel) for training/prefill and as a single fused step
for decode.  The block wraps the RG-LRU with the Griffin recurrent-block
structure: linear in, short temporal conv1d (width 4), RG-LRU, gated by a
GeLU branch, linear out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.modules import rmsnorm, rmsnorm_init, truncated_normal_init

_C = 8.0
CONV_WIDTH = 4


def rglru_init(key, cfg):
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    # Lambda init so that a ~ Unif[0.9, 0.999]^(1/(c*0.5)) territory (paper App.)
    lam = jax.random.uniform(ks[0], (d,), minval=0.9, maxval=0.999)
    lam_raw = jnp.log(jnp.expm1(-jnp.log(lam) / (_C * 0.5)))  # softplus^-1
    return {
        "norm": rmsnorm_init(d),
        "w_in": truncated_normal_init(ks[1], (d, d), 1.0),
        "w_gate": truncated_normal_init(ks[2], (d, d), 1.0),
        "conv_w": truncated_normal_init(ks[3], (CONV_WIDTH, d), 1.0),
        "conv_b": jnp.zeros((d,), jnp.float32),
        "w_r": truncated_normal_init(ks[4], (d, d), 1.0),
        "w_i": truncated_normal_init(ks[5], (d, d), 1.0),
        "lam_raw": lam_raw.astype(jnp.float32),
        "w_out": truncated_normal_init(ks[6], (d, d), 1.0),
    }


def rglru_state_init(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    return {
        "h": jnp.zeros((batch, d), dtype),
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, d), dtype),  # last w-1 inputs
    }


def causal_conv1d(x, w, b, history=None):
    """Depthwise causal conv, width W.  x: [B,S,D]; w: [W,D].

    ``history``: [B, W-1, D] inputs preceding x (decode), else zeros."""
    bsz, s, d = x.shape
    if history is None:
        history = jnp.zeros((bsz, CONV_WIDTH - 1, d), x.dtype)
    xx = jnp.concatenate([history.astype(x.dtype), x], axis=1)  # [B, S+W-1, D]
    out = jnp.zeros((bsz, s, d), x.dtype)
    for i in range(CONV_WIDTH):
        out = out + xx[:, i : i + s, :] * w[i].astype(x.dtype)
    new_history = xx[:, -(CONV_WIDTH - 1) :, :]
    return out + b.astype(x.dtype), new_history


def rglru_scan(x, r, i, lam_raw, h0):
    """Associative-scan RG-LRU.  x, r, i: [B,S,D]; h0: [B,D]."""
    a = jnp.exp(-_C * jax.nn.softplus(lam_raw)[None, None, :] * r.astype(jnp.float32))
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (
        i.astype(jnp.float32) * x.astype(jnp.float32)
    )
    # prepend h0 as a pseudo-step: h_0 carried via (a=0 offset) trick
    # associative op over pairs (a, b): (a2*a1, a2*b1 + b2)
    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    a_all = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
    b_all = jnp.concatenate([h0[:, None, :].astype(jnp.float32), gated], axis=1)
    _, hs = jax.lax.associative_scan(op, (a_all, b_all), axis=1)
    return hs[:, 1:], hs[:, -1]  # [B,S,D], final state


def rglru_block(params, x, cfg, state=None):
    """Griffin recurrent block.  x: [B,S,D] -> (y, new_state)."""
    b, s, d = x.shape
    dt = x.dtype
    xin = rmsnorm(params["norm"], x, cfg.norm_eps)
    branch = xin @ params["w_in"].astype(dt)
    gate = jax.nn.gelu(xin @ params["w_gate"].astype(dt))
    if state is None:
        state = rglru_state_init(cfg, b)
    conv_out, new_hist = causal_conv1d(
        branch, params["conv_w"], params["conv_b"], state["conv"]
    )
    r = jax.nn.sigmoid(conv_out @ params["w_r"].astype(dt))
    ig = jax.nn.sigmoid(conv_out @ params["w_i"].astype(dt))
    hs, h_last = rglru_scan(conv_out, r, ig, params["lam_raw"], state["h"])
    y = (hs.astype(dt) * gate) @ params["w_out"].astype(dt)
    return x + y, {"h": h_last, "conv": new_hist}
