"""xLSTM blocks (Beck et al., arXiv:2405.04517): mLSTM (matrix memory,
chunkwise-parallel) and sLSTM (scalar memory, sequential scan).

mLSTM recurrence (per head, stabilized):
    m_t = max(logsig(f_t) + m_{t-1}, i_t)
    C_t = exp(logsig(f_t)+m_{t-1}-m_t) C_{t-1} + exp(i_t - m_t) k_t v_t^T
    n_t = exp(logsig(f_t)+m_{t-1}-m_t) n_{t-1} + exp(i_t - m_t) k_t
    h_t = C_t^T q_t / max(|n_t^T q_t|, exp(-m_t))
The stored state (C, n) is the stabilized one: C_stored = C_true * exp(-m).

TPU adaptation: the mLSTM is evaluated CHUNKWISE — a lax.scan over chunks of
``chunk_size`` carrying (C, n, m); within a chunk the intra-chunk term is a
masked matmul (MXU-friendly) and the inter-chunk term a single [c,dk]@[dk,dv]
matmul.  This is the TPU-native rethinking of the paper's per-step GPU
recurrence: arithmetic intensity scales with the chunk size instead of being
bandwidth-bound at 1 step per HBM round-trip.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.modules import rmsnorm, rmsnorm_init, truncated_normal_init

# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg):
    d = cfg.d_model
    p = 2 * d  # projection factor 2 (xLSTM paper)
    h = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "norm": rmsnorm_init(d),
        "w_up": truncated_normal_init(ks[0], (d, p), 1.0),
        "w_gate": truncated_normal_init(ks[1], (d, p), 1.0),
        "wq": truncated_normal_init(ks[2], (p, p), 1.0),
        "wk": truncated_normal_init(ks[3], (p, p), 1.0),
        "wv": truncated_normal_init(ks[4], (p, p), 1.0),
        "w_i": truncated_normal_init(ks[5], (p, h), 1.0),
        "w_f": truncated_normal_init(ks[6], (p, h), 1.0),
        "w_down": truncated_normal_init(ks[7], (p, d), 1.0),
        "out_norm": rmsnorm_init(p),
    }


def mlstm_state_init(cfg, batch: int, dtype=jnp.float32):
    p = 2 * cfg.d_model
    h = cfg.n_heads
    hd = p // h
    return {
        "C": jnp.zeros((batch, h, hd, hd), dtype),
        "n": jnp.zeros((batch, h, hd), dtype),
        "m": jnp.full((batch, h), -1e30, dtype),
    }


def mlstm_scan(q, k, v, i_gate, f_gate, state, chunk_size: int = 256):
    """Chunkwise stabilized mLSTM.

    q,k,v: [B, S, H, hd] (k pre-scaled by hd^-0.5 by the caller)
    i_gate, f_gate: [B, S, H] raw (pre-activation) gates
    state: dict(C [B,H,hd,hd], n [B,H,hd], m [B,H]) — stabilized carry
    Returns (h [B,S,H,hd], new_state).
    """
    b, s, h, hd = q.shape
    c = min(chunk_size, s)
    n_chunks = -(-s // c)
    pad = n_chunks * c - s
    if pad:
        padq = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, padq)
        k = jnp.pad(k, padq)
        v = jnp.pad(v, padq)
        # padded steps must not raise the stabilizer m: i -> -inf (no input)
        i_gate = jnp.pad(i_gate, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        # pad forget gates with +inf raw -> logsig ~ 0 -> carry decays by 1
        f_gate = jnp.pad(f_gate, ((0, 0), (0, pad), (0, 0)), constant_values=40.0)

    def reshape_chunks(x):
        return x.reshape((b, n_chunks, c) + x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = reshape_chunks(q), reshape_chunks(k), reshape_chunks(v)
    ic, fc = reshape_chunks(i_gate), reshape_chunks(f_gate)

    def chunk_step(carry, xs):
        C0, n0, m0 = carry  # [B,H,hd,hd], [B,H,hd], [B,H]
        qj, kj, vj, ij, fj = xs  # [B,c,H,hd] / [B,c,H]
        qj = qj.astype(jnp.float32)
        kj = kj.astype(jnp.float32)
        vj = vj.astype(jnp.float32)
        logf = jax.nn.log_sigmoid(fj.astype(jnp.float32))  # [B,c,H]
        bcum = jnp.cumsum(logf, axis=1)  # b_j, [B,c,H]
        a = bcum + m0[:, None, :]  # carry-decay log, [B,c,H]
        itb = ij.astype(jnp.float32) - bcum  # i_l - b_l
        local_max = jax.lax.cummax(itb, axis=1)  # [B,c,H]
        m = jnp.maximum(a, bcum + local_max)  # m_j, [B,c,H]

        # intra-chunk: D[j,l] = exp(b_j - b_l + i_l - m_j) for l <= j
        # log D = (b_j - m_j)[:, j] + (i_l - b_l)[:, l]
        logd = (bcum - m)[:, :, None, :] + itb[:, None, :, :]  # [B,j,l,H]
        mask = jnp.tril(jnp.ones((c, c), bool))
        dmat = jnp.where(mask[None, :, :, None], jnp.exp(logd), 0.0)  # [B,j,l,H]
        scores = jnp.einsum("bjhd,blhd->bjlh", qj, kj) * dmat
        h_intra = jnp.einsum("bjlh,blhd->bjhd", scores, vj)
        n_intra = jnp.einsum("bjlh,blhd->bjhd", dmat, kj)

        # inter-chunk: exp(a_j - m_j) * (q_j @ C0)
        w_inter = jnp.exp(a - m)  # [B,c,H]
        h_inter = jnp.einsum("bjhd,bhde->bjhe", qj, C0) * w_inter[..., None]
        n_inter = n0[:, None, :, :] * w_inter[..., None]

        num = h_intra + h_inter
        nvec = n_intra + n_inter
        qn = jnp.einsum("bjhd,bjhd->bjh", qj, nvec)
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m))
        hj = num / denom[..., None]

        # carry update (at j = c-1)
        m_end = m[:, -1, :]  # [B,H]
        w_carry = jnp.exp(a[:, -1, :] - m_end)  # decay of old carry
        w_kv = jnp.exp((bcum[:, -1:, :] - bcum) + ij.astype(jnp.float32) - m_end[:, None, :])
        C_new = C0 * w_carry[..., None, None] + jnp.einsum(
            "blh,blhd,blhe->bhde", w_kv, kj, vj
        )
        n_new = n0 * w_carry[..., None] + jnp.einsum("blh,blhd->bhd", w_kv, kj)
        return (C_new, n_new, m_end), hj

    carry0 = (
        state["C"].astype(jnp.float32),
        state["n"].astype(jnp.float32),
        state["m"].astype(jnp.float32),
    )
    (C, n, m), hs = jax.lax.scan(chunk_step, carry0, (qc, kc, vc, ic, fc))
    out = hs.swapaxes(0, 1).reshape(b, n_chunks * c, h, hd)[:, :s]
    return out.astype(q.dtype), {"C": C, "n": n, "m": m}


def mlstm_block(params, x, cfg, state=None, chunk_size: int = 256):
    """Full mLSTM residual block.  x: [B,S,D].  Returns (y, new_state)."""
    b, s, d = x.shape
    dt = x.dtype
    h = cfg.n_heads
    p = 2 * d
    hd = p // h
    xin = rmsnorm(params["norm"], x, cfg.norm_eps)
    up = xin @ params["w_up"].astype(dt)  # [B,S,p]
    gate = jax.nn.silu(xin @ params["w_gate"].astype(dt))
    q = (up @ params["wq"].astype(dt)).reshape(b, s, h, hd)
    k = (up @ params["wk"].astype(dt)).reshape(b, s, h, hd) / jnp.sqrt(hd).astype(dt)
    v = (up @ params["wv"].astype(dt)).reshape(b, s, h, hd)
    ig = up @ params["w_i"].astype(dt)  # [B,S,H]
    fg = up @ params["w_f"].astype(dt)
    if state is None:
        state = mlstm_state_init(cfg, b)
    hseq, new_state = mlstm_scan(q, k, v, ig, fg, state, chunk_size)
    hseq = rmsnorm(params["out_norm"], hseq.reshape(b, s, p), cfg.norm_eps)
    y = (hseq * gate) @ params["w_down"].astype(dt)
    return x + y, new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ks = jax.random.split(key, 10)
    return {
        "norm": rmsnorm_init(d),
        # input projections for z,i,f,o
        "w_z": truncated_normal_init(ks[0], (d, d), 1.0),
        "w_i": truncated_normal_init(ks[1], (d, d), 1.0),
        "w_f": truncated_normal_init(ks[2], (d, d), 1.0),
        "w_o": truncated_normal_init(ks[3], (d, d), 1.0),
        # block-diagonal (per-head) recurrent matrices
        "r_z": truncated_normal_init(ks[4], (h, hd, hd), 1.0),
        "r_i": truncated_normal_init(ks[5], (h, hd, hd), 1.0),
        "r_f": truncated_normal_init(ks[6], (h, hd, hd), 1.0),
        "r_o": truncated_normal_init(ks[7], (h, hd, hd), 1.0),
        "w_down": truncated_normal_init(ks[8], (d, d), 1.0),
        "out_norm": rmsnorm_init(d),
    }


def slstm_state_init(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), dtype),
        "n": jnp.zeros((batch, d), dtype),
        "h": jnp.zeros((batch, d), dtype),
        "m": jnp.full((batch, d), -1e30, dtype),
    }


def _block_diag_matvec(r, h_vec, n_heads):
    """r: [H, hd, hd]; h_vec: [B, D] -> [B, D] per-head recurrent matvec."""
    b, d = h_vec.shape
    hd = d // n_heads
    hh = h_vec.reshape(b, n_heads, hd)
    return jnp.einsum("bhk,hkl->bhl", hh, r).reshape(b, d)


def slstm_scan(params, xz, xi, xf, xo, state, n_heads):
    """Sequential sLSTM over time (true recurrence — not parallelizable).

    xz..xo: [B, S, D] pre-activation input contributions.
    """

    def step(carry, xs):
        c, n, h, m = carry
        z_in, i_in, f_in, o_in = xs
        z = jnp.tanh(z_in + _block_diag_matvec(params["r_z"], h, n_heads))
        i_raw = i_in + _block_diag_matvec(params["r_i"], h, n_heads)
        f_raw = f_in + _block_diag_matvec(params["r_f"], h, n_heads)
        o = jax.nn.sigmoid(o_in + _block_diag_matvec(params["r_o"], h, n_heads))
        logf = jax.nn.log_sigmoid(f_raw)
        m_new = jnp.maximum(logf + m, i_raw)
        i_s = jnp.exp(i_raw - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_new = f_s * c + i_s * z
        n_new = f_s * n + i_s
        h_new = o * c_new / jnp.maximum(n_new, jnp.exp(-m_new))
        return (c_new, n_new, h_new, m_new), h_new

    xs = tuple(a.swapaxes(0, 1).astype(jnp.float32) for a in (xz, xi, xf, xo))
    carry0 = (state["c"], state["n"], state["h"], state["m"])
    carry, hs = jax.lax.scan(step, carry0, xs)
    c, n, h, m = carry
    return hs.swapaxes(0, 1), {"c": c, "n": n, "h": h, "m": m}


def slstm_block(params, x, cfg, state=None):
    b, s, d = x.shape
    dt = x.dtype
    xin = rmsnorm(params["norm"], x, cfg.norm_eps)
    xz = xin @ params["w_z"].astype(dt)
    xi = xin @ params["w_i"].astype(dt)
    xf = xin @ params["w_f"].astype(dt)
    xo = xin @ params["w_o"].astype(dt)
    if state is None:
        state = slstm_state_init(cfg, b)
    hseq, new_state = slstm_scan(params, xz, xi, xf, xo, state, cfg.n_heads)
    hseq = rmsnorm(params["out_norm"], hseq.astype(dt), cfg.norm_eps)
    y = hseq @ params["w_down"].astype(dt)
    return x + y, new_state
