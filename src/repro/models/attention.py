"""GQA attention with RoPE, optional qk-norm, sliding windows, KV caches.

Training/prefill uses ``chunked_attention`` — the flash-attention algorithm
(running max / running denominator over KV chunks) written in pure JAX so it
(a) never materializes the [S, S] score matrix (required for prefill_32k),
(b) lowers on any backend, and (c) shards under GSPMD.  On real TPU the
Pallas kernel (repro.kernels.flash_attention) implements the same contract
with explicit VMEM tiling; ``ops.attention`` dispatches between them.

Decode uses a fixed-size KV cache: full-length for decode_32k, a ring buffer
of ``window`` slots for sliding-window long-context decode (long_500k).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.modules import rmsnorm, rope, truncated_normal_init

NEG_INF = -1e30


def attn_init(key, cfg, cross: bool = False):
    hd = cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": truncated_normal_init(ks[0], (cfg.d_model, cfg.n_heads * hd), 1.0),
        "wk": truncated_normal_init(ks[1], (cfg.d_model, cfg.n_kv_heads * hd), 1.0),
        "wv": truncated_normal_init(ks[2], (cfg.d_model, cfg.n_kv_heads * hd), 1.0),
        "wo": truncated_normal_init(ks[3], (cfg.n_heads * hd, cfg.d_model), 1.0),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = {"scale": jnp.ones((hd,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((hd,), jnp.float32)}
    return p


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _repeat_kv(k, n_heads):
    """[B, S, kv, hd] -> [B, S, H, hd] by group replication."""
    kv = k.shape[-2]
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=-2)


def chunked_attention(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Sk, H, hd]
    v: jax.Array,  # [B, Sk, H, hd]
    *,
    causal: bool,
    window: int = 0,
    q_offset: jax.Array | int = 0,
    k_valid: jax.Array | None = None,  # [B, Sk] bool (cache slots)
    k_positions: jax.Array | None = None,  # [B, Sk] absolute positions
    chunk_size: int = 512,
) -> jax.Array:
    """Flash-attention algorithm over KV chunks (pure JAX).

    ``q_offset``: absolute position of q[0] (prefill continuation / decode).
    ``window`` > 0 masks keys older than ``window`` positions behind a query.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    n_chunks = -(-sk // chunk_size)
    pad = n_chunks * chunk_size - sk
    if pad:
        padcfg = ((0, 0), (0, pad), (0, 0), (0, 0))
        k = jnp.pad(k, padcfg)
        v = jnp.pad(v, padcfg)
        valid_pad = jnp.zeros((b, pad), bool)
        k_valid = (
            jnp.concatenate([k_valid, valid_pad], axis=1)
            if k_valid is not None
            else jnp.concatenate([jnp.ones((b, sk), bool), valid_pad], axis=1)
        )
        if k_positions is not None:
            k_positions = jnp.pad(k_positions, ((0, 0), (0, pad)))
    skp = k.shape[1]
    if k_positions is None:
        k_positions = jnp.broadcast_to(jnp.arange(skp), (b, skp))
    if k_valid is None:
        k_valid = jnp.ones((b, skp), bool)

    q_pos = q_offset + jnp.arange(sq)  # [Sq]
    kc = k.reshape(b, n_chunks, chunk_size, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk_size, h, hd).transpose(1, 0, 2, 3, 4)
    kpos_c = k_positions.reshape(b, n_chunks, chunk_size).transpose(1, 0, 2)
    kval_c = k_valid.reshape(b, n_chunks, chunk_size).transpose(1, 0, 2)

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, sq, h, hd), jnp.float32)

    def body_fixed(carry, xs):
        m, l, acc = carry
        k_j, v_j, kp_j, kv_j = xs
        s = jnp.einsum(
            "bqhd,bkhd->bhqk", q.astype(jnp.float32), k_j.astype(jnp.float32)
        ) * scale
        mask = kv_j[:, None, None, :]
        if causal:
            mask = mask & (kp_j[:, None, None, :] <= q_pos[None, None, :, None])
        if window:
            mask = mask & (
                kp_j[:, None, None, :] > q_pos[None, None, :, None] - window
            )
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
        corr = jnp.where(m <= NEG_INF / 2, 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bhqk,bkhd->bqhd", p, v_j.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body_fixed, (m0, l0, acc0), (kc, vc, kpos_c, kval_c))
    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def init_kv_cache(cfg, batch: int, capacity: int, dtype=jnp.bfloat16):
    """Fixed-capacity KV cache (ring buffer when capacity < context).

    ``dtype=jnp.int8`` enables quantized storage: per-(slot, head) absmax
    scales dequantize on read — the §Perf memory-bound-decode optimization
    (halves KV HBM traffic vs bf16)."""
    hd = cfg.hd
    cache = {
        "k": jnp.zeros((batch, capacity, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, capacity, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),  # absolute positions
    }
    if dtype == jnp.int8:
        cache["k_scale"] = jnp.zeros((batch, capacity, cfg.n_kv_heads), jnp.float32)
        cache["v_scale"] = jnp.zeros((batch, capacity, cfg.n_kv_heads), jnp.float32)
    return cache


def _quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """[..., hd] bf16/f32 -> (int8, per-[...]-scale fp32)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def cache_update(cache, k_new, v_new, position):
    """Write one decode step (Sq=1) at slot position % capacity."""
    cap = cache["k"].shape[1]
    slot = position % cap
    quant = cache["k"].dtype == jnp.int8
    out = dict(cache)
    if quant:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        out["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_scale"], ks.astype(jnp.float32), slot, axis=1
        )
        out["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v_scale"], vs.astype(jnp.float32), slot, axis=1
        )
        k_new, v_new = kq, vq
    out["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1
    )
    out["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1
    )
    out["pos"] = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"],
        jnp.full((cache["pos"].shape[0], 1), position, jnp.int32),
        slot,
        axis=1,
    )
    return out


def cache_read_kv(cache, dtype):
    """Materialize (k, v) from the cache, dequantizing if int8-stored."""
    if cache["k"].dtype == jnp.int8:
        k = _dequantize_kv(cache["k"], cache["k_scale"], dtype)
        v = _dequantize_kv(cache["v"], cache["v_scale"], dtype)
        return k, v
    return cache["k"].astype(dtype), cache["v"].astype(dtype)


def attention_block(
    params,
    x: jax.Array,  # [B, S, D]
    cfg,
    *,
    causal: bool = True,
    window: int = 0,
    positions: jax.Array | None = None,  # [S] absolute positions
    cache: dict | None = None,  # decode path
    cross_x: jax.Array | None = None,  # encoder output for cross-attn
    use_rope: bool = True,
    chunk_size: int = 512,
):
    """Returns (y [B,S,D], new_cache_or_None)."""
    b, s, d = x.shape
    hd = cfg.hd
    dt = x.dtype
    q = _split_heads(x @ params["wq"].astype(dt), cfg.n_heads, hd)
    kv_src = cross_x if cross_x is not None else x
    k = _split_heads(kv_src @ params["wk"].astype(dt), cfg.n_kv_heads, hd)
    v = _split_heads(kv_src @ params["wv"].astype(dt), cfg.n_kv_heads, hd)

    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)

    if positions is None:
        positions = jnp.arange(s)
    if use_rope and cross_x is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and s > 1:
        # prefill: bulk-write k/v into the cache, attend over the fresh k/v
        cap = cache["k"].shape[1]
        quant = cache["k"].dtype == jnp.int8
        if quant:
            k_st, k_sc = _quantize_kv(k)
            v_st, v_sc = _quantize_kv(v)
        else:
            k_st, v_st = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
            k_sc = v_sc = None
        if cap >= s:
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k_st, 0, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v_st, 0, axis=1),
                "pos": jax.lax.dynamic_update_slice_in_dim(
                    cache["pos"],
                    jnp.broadcast_to(positions[None, :], (b, s)).astype(jnp.int32),
                    0,
                    axis=1,
                ),
            }
            if quant:
                new_cache["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["k_scale"], k_sc, 0, axis=1
                )
                new_cache["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["v_scale"], v_sc, 0, axis=1
                )
        else:
            # ring buffer (sliding-window): keep only the LAST cap positions,
            # each at its slot position % cap (continues seamlessly in decode)
            tail_pos = positions[s - cap :]
            slots = tail_pos % cap
            new_cache = {
                "k": cache["k"].at[:, slots].set(k_st[:, s - cap :]),
                "v": cache["v"].at[:, slots].set(v_st[:, s - cap :]),
                "pos": cache["pos"].at[:, slots].set(
                    jnp.broadcast_to(tail_pos[None, :], (b, cap)).astype(jnp.int32)
                ),
            }
            if quant:
                new_cache["k_scale"] = cache["k_scale"].at[:, slots].set(
                    k_sc[:, s - cap :]
                )
                new_cache["v_scale"] = cache["v_scale"].at[:, slots].set(
                    v_sc[:, s - cap :]
                )
        k = _repeat_kv(k, cfg.n_heads)
        v = _repeat_kv(v, cfg.n_heads)
        out = chunked_attention(
            q, k, v, causal=causal, window=window, q_offset=0, chunk_size=chunk_size
        )
    elif cache is not None:
        # decode: S == 1; append to cache, attend over the whole cache
        new_cache = cache_update(cache, k, v, positions[0])
        k_deq, v_deq = cache_read_kv(new_cache, dt)
        k_full = _repeat_kv(k_deq, cfg.n_heads)
        v_full = _repeat_kv(v_deq, cfg.n_heads)
        out = chunked_attention(
            q,
            k_full,
            v_full,
            causal=causal,
            window=window,
            q_offset=positions[0],
            k_valid=new_cache["pos"] >= 0,
            k_positions=new_cache["pos"],
            chunk_size=chunk_size,
        )
    else:
        k = _repeat_kv(k, cfg.n_heads)
        v = _repeat_kv(v, cfg.n_heads)
        out = chunked_attention(
            q,
            k,
            v,
            causal=causal and cross_x is None,
            window=window,
            q_offset=positions[0] if s != positions.shape[0] else 0,
            chunk_size=chunk_size,
        )
    y = out.reshape(b, s, cfg.n_heads * hd) @ params["wo"].astype(dt)
    return y, new_cache
