"""``PredictiveServer`` — batched MC-predictive inference over a snapshot.

Serves the paper's Monte-Carlo predictive distribution (Sec. 4.2)

    P(y | x) = (1/L) sum_k Softmax(f_{theta_k}(x)),   theta_k ~ snapshot

from the ``SnapshotStore``'s front buffer, with three serving-tier
guarantees:

* **Compiled-once apply cache** — arbitrary request streams execute a
  SMALL, FIXED set of pre-compiled programs.  Incoming request rows are
  coalesced per agent and chopped into PADDING BUCKETS (``bucket_sizes``,
  ascending): full slabs of the largest bucket, then the smallest bucket
  covering the remainder (zero-padded; pad rows are sliced off before any
  value escapes).  Each jitted apply is keyed on
  ``(bucket, request_shape, mc_samples)`` — the trace count equals the
  number of DISTINCT keys the stream touches, pinned by
  tests/test_serve.py, and ``n_traces`` counts retraces exactly like the
  gossip engine's telemetry.
* **fp32 probability accumulation** — per posterior sample the class
  probabilities are computed and accumulated in fp32 regardless of the
  snapshot's resident dtype (a bf16-resident snapshot decodes to fp32
  inside the jitted program, where XLA fuses the widening cast into the
  first read).  ``mc_samples=0`` is the deterministic point estimate (one
  softmax at the posterior mean — the paper's L=1 fast path).
* **Staleness SLO** — ``max_staleness=k`` bounds how out-of-date a served
  posterior may be: a snapshot more than k training windows old is
  REFUSED (``staleness_policy="strict"`` raises ``StalenessSLOError``) or
  FLAGGED (``"flag"``: the response meta carries ``slo_ok=False``), and
  every breach is counted in the serving telemetry that
  ``Session.evaluate`` surfaces next to the fault/staleness metrics.

The server never touches training state: it reads the immutable snapshot
the store currently fronts.  Publish a fresh snapshot
(``Session.snapshot()``) to roll the served posterior forward.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.numerics import COMPUTE_DTYPE, softplus
from repro.serve.snapshot import PosteriorSnapshot, SnapshotStore

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32)


class StalenessSLOError(RuntimeError):
    """The served snapshot is older than the ``max_staleness`` SLO allows."""


def _check_buckets(bucket_sizes) -> tuple[int, ...]:
    buckets = tuple(int(b) for b in bucket_sizes)
    if not buckets or any(b <= 0 for b in buckets):
        raise ValueError(
            f"bucket_sizes must be positive and non-empty, got {bucket_sizes!r}"
        )
    if list(buckets) != sorted(set(buckets)):
        raise ValueError(
            f"bucket_sizes must be strictly ascending, got {bucket_sizes!r}"
        )
    return buckets


class PredictiveServer:
    """Batched MC-predictive serving against a ``SnapshotStore``.

    ``logits_fn(theta_pytree, x) -> logits`` is the model apply (the
    registry signature, ``api.models.ModelFns.logits_fn``); the flat->
    pytree conversion happens once per sample inside the jitted program
    via the snapshot layout.  ``seed`` roots the server's own MC key
    stream: each bucket slab folds a monotone batch counter into the base
    key, so the whole key sequence is a pure function of (seed, request
    history) — two servers built with the same seed and fed the same
    stream sample identically, while successive queries on one server
    draw fresh posterior samples."""

    def __init__(
        self,
        store: SnapshotStore,
        logits_fn: Callable[[Any, jax.Array], jax.Array],
        *,
        mc_samples: int = 8,
        bucket_sizes: Sequence[int] = DEFAULT_BUCKETS,
        max_staleness: int | None = None,
        staleness_policy: str = "strict",
        seed: int = 0,
    ):
        if mc_samples < 0:
            raise ValueError("mc_samples must be >= 0 (0 = point estimate)")
        if staleness_policy not in ("strict", "flag"):
            raise ValueError(
                f"unknown staleness_policy {staleness_policy!r}; known: "
                "strict | flag"
            )
        if max_staleness is not None and max_staleness < 0:
            raise ValueError("max_staleness must be >= 0 windows (or None)")
        self.store = store
        self.logits_fn = logits_fn
        self.mc_samples = int(mc_samples)
        self.bucket_sizes = _check_buckets(bucket_sizes)
        self.max_staleness = max_staleness
        self.staleness_policy = staleness_policy
        self._base_key = jax.random.key(seed)
        self._apply_cache: dict = {}
        # serving telemetry (Session.evaluate merges it)
        self.n_traces = 0
        self.n_requests = 0
        self.n_rows = 0
        self.n_padded_rows = 0
        self.n_batches = 0
        self.n_slo_breaches = 0
        self._batch_counter = 0
        self._lat_us: list[float] = []
        # host-side observability hook (repro.obs.Observability); attached
        # by Session.attach_server — spans/counters only, never in the jit
        self.obs = None

    # -- staleness SLO -------------------------------------------------------

    def check_slo(self, snap: PosteriorSnapshot | None = None) -> tuple[bool, int]:
        """(slo_ok, age).  Counts a breach and — under the strict policy —
        refuses by raising ``StalenessSLOError``.  With no ``max_staleness``
        every snapshot is within SLO (age still reported)."""
        snap = self.store.current() if snap is None else snap
        age = self.store.age() if self.store.clock is not None else 0
        if self.max_staleness is None or age <= self.max_staleness:
            return True, age
        self.n_slo_breaches += 1
        if self.staleness_policy == "strict":
            raise StalenessSLOError(
                f"snapshot of window {snap.window} is {age} windows stale "
                f"(> max_staleness={self.max_staleness}); publish a fresh "
                "snapshot (Session.snapshot()) or serve with "
                "staleness_policy='flag'"
            )
        return False, age

    # -- the compiled-once apply cache ---------------------------------------

    def _apply_for(self, layout, bucket: int, row_shape: tuple, mc: int):
        """The jitted MC-predictive program for one (bucket, row_shape, mc)
        key.  The layout is static closure state (it never changes for a
        fixed model); mean/rho/x/key are traced, so republishing a snapshot
        or switching agents NEVER retraces."""
        key_t = (bucket, row_shape, mc, id(layout))
        cached = self._apply_cache.get(key_t)
        if cached is not None:
            return cached
        logits_fn = self.logits_fn

        def apply(mean_row, rho_row, x, key):
            self.n_traces += 1  # trace-time side effect: retrace telemetry
            mean = mean_row.astype(COMPUTE_DTYPE)
            rho = rho_row.astype(COMPUTE_DTYPE)

            def probs_of(theta_flat):
                logits = logits_fn(layout.unflatten(theta_flat), x)
                return jax.nn.softmax(logits.astype(COMPUTE_DTYPE), axis=-1)

            if mc == 0:
                # deterministic point estimate: one softmax at the mean
                return probs_of(mean)

            def one(k):
                eps = jax.random.normal(k, mean.shape, COMPUTE_DTYPE)
                return probs_of(mean + softplus(rho) * eps)

            keys = jax.random.split(key, mc)
            # fp32 probability accumulation across the posterior ensemble
            return jnp.mean(jax.vmap(one)(keys), axis=0)

        fn = jax.jit(apply)
        self._apply_cache[key_t] = fn
        return fn

    def _bucket_plan(self, total: int) -> list[int]:
        """Chop ``total`` rows into bucket-sized slabs: full slabs of the
        largest bucket, then the smallest bucket covering the remainder."""
        if total <= 0:
            return []
        top = self.bucket_sizes[-1]
        plan = [top] * (total // top)
        rem = total % top
        if rem:
            plan.append(next(b for b in self.bucket_sizes if b >= rem))
        return plan

    # -- serving -------------------------------------------------------------

    def query(self, x, agent: int = 0, *, mc_samples: int | None = None,
              key=None):
        """One request: class probabilities for ``x`` ([n, ...features] or a
        single [...features] row) under ``agent``'s snapshot posterior.
        Returns ``(probs, meta)``; ``meta`` carries the snapshot provenance
        and the SLO verdict."""
        x = jnp.asarray(x)
        single = x.ndim == 1
        outs, meta = self.serve(
            [x[None] if single else x], agents=[agent],
            mc_samples=mc_samples, key=key,
        )
        probs = outs[0][0] if single else outs[0]
        return probs, meta

    def serve(self, requests, agents=None, *, mc_samples: int | None = None,
              key=None):
        """Serve a micro-batch of requests in one pass.

        ``requests``: list of arrays ``[n_i, ...features]`` (ragged leading
        sizes welcome — that is the point).  ``agents``: per-request agent
        id (default: all agent 0).  Rows are coalesced per agent, executed
        through the padding-bucket apply cache, and handed back per request
        in order.  Returns ``(outputs, meta)``.
        """
        snap = self.store.current()
        slo_ok, age = self.check_slo(snap)
        mc = self.mc_samples if mc_samples is None else int(mc_samples)
        if mc < 0:
            raise ValueError("mc_samples must be >= 0")
        reqs = [jnp.asarray(r) for r in requests]
        if any(r.ndim < 2 for r in reqs):
            raise ValueError(
                "each request must be [n, ...features]; wrap single rows "
                "with x[None] (or use query())"
            )
        agents = [0] * len(reqs) if agents is None else list(agents)
        if len(agents) != len(reqs):
            raise ValueError(
                f"{len(reqs)} requests but {len(agents)} agent ids"
            )
        n_agents = snap.n_agents
        for a in agents:
            if not 0 <= int(a) < n_agents:
                raise ValueError(
                    f"agent {a} out of range for a {n_agents}-agent snapshot"
                )
        base = self._base_key if key is None else jnp.asarray(key)
        post = snap.posterior
        t0 = time.perf_counter()

        # coalesce rows per agent (one posterior row per slab), preserving
        # request order within each agent group
        by_agent: dict[int, list[int]] = {}
        for i, a in enumerate(agents):
            by_agent.setdefault(int(a), []).append(i)
        results: list = [None] * len(reqs)
        for a, idxs in by_agent.items():
            rows = jnp.concatenate([reqs[i] for i in idxs], axis=0)
            row_shape = tuple(rows.shape[1:])
            mean_row, rho_row = post.mean[a], post.rho[a]
            chunks, off = [], 0
            for bucket in self._bucket_plan(rows.shape[0]):
                n = min(bucket, rows.shape[0] - off)
                slab = rows[off:off + n]
                if n < bucket:  # zero-pad to the bucket; sliced off below
                    pad = jnp.zeros((bucket - n,) + row_shape, slab.dtype)
                    slab = jnp.concatenate([slab, pad], axis=0)
                    self.n_padded_rows += bucket - n
                fn = self._apply_for(post.layout, bucket, row_shape, mc)
                k = jax.random.fold_in(base, self._batch_counter)
                self._batch_counter += 1
                probs = fn(mean_row, rho_row, slab, k)
                chunks.append(probs[:n])
                off += n
                self.n_batches += 1
            agent_probs = (jnp.concatenate(chunks, axis=0) if chunks
                           else jnp.zeros((0, 0), COMPUTE_DTYPE))
            off = 0
            for i in idxs:
                n = reqs[i].shape[0]
                results[i] = agent_probs[off:off + n]
                off += n
        jax.block_until_ready([r for r in results if r is not None])
        lat_us = (time.perf_counter() - t0) * 1e6
        self._lat_us.append(lat_us)
        self.n_requests += len(reqs)
        self.n_rows += sum(int(r.shape[0]) for r in reqs)
        if self.obs is not None:
            reg = self.obs.registry
            reg.counter("serve.requests", "requests served").inc(len(reqs))
            reg.counter("serve.rows", "rows served").inc(
                sum(int(r.shape[0]) for r in reqs)
            )
            reg.histogram(
                "serve.latency_us", "per-call serve latency"
            ).observe(lat_us, mc=str(mc))
            if not slo_ok:
                reg.counter("serve.slo_breaches").inc()
            tr = self.obs.tracer
            if tr.enabled:
                # the batch already synced (block_until_ready above): record
                # the measured [t0, t0+lat] interval as one span directly
                from repro.obs.trace import Span

                tr.spans.append(Span(
                    name="serve.request",
                    t0_us=(t0 - tr._epoch) * 1e6,
                    dur_us=lat_us,
                    depth=tr._depth,
                    attrs={"rows": sum(int(r.shape[0]) for r in reqs),
                           "mc": mc, "slo_ok": slo_ok},
                ))
        meta = {
            "snapshot_window": snap.window,
            "snapshot_version": snap.version,
            "snapshot_age": age,
            "slo_ok": slo_ok,
            "mc_samples": mc,
            "latency_us": lat_us,
        }
        return results, meta

    # -- telemetry -----------------------------------------------------------

    def latency_percentiles(self) -> dict:
        if not self._lat_us:
            return {}
        lat = np.asarray(self._lat_us)
        return {
            "p50_us": float(np.percentile(lat, 50)),
            "p99_us": float(np.percentile(lat, 99)),
            "mean_us": float(lat.mean()),
            "n": int(lat.size),
        }

    def telemetry(self) -> dict:
        """Plain-data serving block (merged into ``Session.evaluate``):
        snapshot provenance + age, request/batch/padding counters, the SLO
        breach count, and the apply-cache trace count."""
        out = {
            "requests": self.n_requests,
            "rows": self.n_rows,
            "batches": self.n_batches,
            "padded_rows": self.n_padded_rows,
            "traces": self.n_traces,
            "mc_samples": self.mc_samples,
            "bucket_sizes": list(self.bucket_sizes),
            "slo": {
                "max_staleness": self.max_staleness,
                "policy": self.staleness_policy,
                "breaches": self.n_slo_breaches,
            },
        }
        out.update(self.store.telemetry())
        lat = self.latency_percentiles()
        if lat:
            out["latency"] = lat
        return out
