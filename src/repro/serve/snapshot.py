"""Snapshot isolation for the posterior serving tier (ROADMAP "Serving").

The paper's end product is each agent's *predictive distribution* served
from its consensus posterior (Sec. 4.2).  Serving must never interfere
with training, and training must never mutate what a reader is serving —
the classic snapshot-isolation contract, realized here as a DOUBLE BUFFER
over ``core.flat.FlatPosterior``:

* ``SnapshotStore.publish`` copies the live [N, P] (mean, rho) buffers
  into a fresh, immutable ``PosteriorSnapshot`` (the back buffer) and then
  swaps it in as the served front buffer in one atomic reference
  assignment.  Readers holding the previous snapshot keep serving it
  unchanged; new reads see the new one.  Publishing only READS training
  state, so a training run with a serving reader attached stays BITWISE
  identical to one without (pinned by tests/test_serve.py).
* Snapshots may be resident in a narrower dtype
  (``snapshot_dtype="bf16"`` — the ``core.numerics`` wire-dtype machinery,
  shared with the consensus exchange): half the serving HBM, decoded to
  fp32 inside the jitted apply.  ``launch.costmodel.serve_roofline`` models
  the halving; a unit test asserts it exactly.
* Every snapshot carries its provenance: the training WINDOW index it was
  taken at, a monotone version counter, and the gossip staleness telemetry
  (``last_merge`` percentiles, quarantine counts) when the engine exposes
  it — the raw material of the serving tier's staleness SLO
  (``server.PredictiveServer(max_staleness=k)``: refuse/flag answers from
  a snapshot more than k windows stale, the bounded-staleness regime of
  Lalitha et al., arXiv:1901.11173).

Checkpointing: ``PosteriorSnapshot.save``/``load`` persist a snapshot next
to the session checkpoint (``checkpoint.io.save_snapshot``) — a serving
replica can restore the exact served posterior without the training state.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax

from repro.core.flat import FlatPosterior
from repro.core.numerics import COMPUTE_DTYPE, canonical_wire_dtype, wire_dtype_name


@dataclasses.dataclass(frozen=True, eq=False)
class PosteriorSnapshot:
    """One immutable published posterior + its provenance.

    ``posterior`` is a decoupled copy of the training buffers (possibly
    narrow-resident — see ``dtype``); ``window`` is the training round it
    was taken at; ``version`` the store's monotone publish counter;
    ``telemetry`` the engine's staleness block at publish time (plain
    data, checkpoint-embeddable).
    """

    posterior: FlatPosterior
    window: int
    version: int
    dtype: str  # resident dtype name ("f32" | "bf16" | "f16")
    telemetry: dict = dataclasses.field(default_factory=dict)

    @property
    def n_agents(self) -> int:
        return int(self.posterior.mean.shape[0])

    def nbytes(self) -> int:
        """Resident HBM of the snapshot (both buffers) — bf16 snapshots
        are exactly half the fp32 ones (asserted by test)."""
        return int(self.posterior.mean.nbytes + self.posterior.rho.nbytes)

    def decode(self) -> FlatPosterior:
        """The fp32 view served to the apply path (structural no-op for an
        fp32-resident snapshot)."""
        return self.posterior.astype(COMPUTE_DTYPE)

    # -- persistence (next to the session checkpoint) ------------------------

    def save(self, path: str) -> None:
        from repro.checkpoint.io import save_snapshot

        save_snapshot(path, self)

    @classmethod
    def load(cls, path: str) -> "PosteriorSnapshot":
        from repro.checkpoint.io import restore_snapshot

        return restore_snapshot(path)


def take_snapshot(
    post: FlatPosterior,
    *,
    window: int,
    version: int = 0,
    dtype=None,
    telemetry: dict | None = None,
) -> PosteriorSnapshot:
    """Copy ``post`` into an immutable snapshot (see ``FlatPosterior
    .snapshot`` for the decoupling contract).  ``dtype`` is a wire-dtype
    name/dtype (None = fp32-resident)."""
    dt = canonical_wire_dtype(dtype)
    return PosteriorSnapshot(
        posterior=post.snapshot(dt),
        window=int(window),
        version=int(version),
        dtype=wire_dtype_name(dt),
        telemetry=dict(telemetry or {}),
    )


class SnapshotStore:
    """The double buffer: one served front snapshot, atomically swapped.

    ``publish`` builds the new snapshot first (the back buffer — readers
    still see the old front the whole time) and installs it with a single
    reference assignment, which is atomic under the interpreter: a reader
    either gets the complete old snapshot or the complete new one, never a
    half-written mix.  Readers never block training and training never
    blocks readers.

    ``clock`` supplies "now" in training windows (the Session wires it to
    its round counter) so ``age()`` — windows since the served snapshot
    was taken — is the quantity the staleness SLO bounds.
    """

    def __init__(self, clock: Callable[[], int] | None = None):
        self._front: PosteriorSnapshot | None = None
        self._version = 0
        self.clock = clock
        self.n_published = 0

    def publish(
        self,
        post: FlatPosterior,
        *,
        window: int,
        dtype=None,
        telemetry: dict | None = None,
    ) -> PosteriorSnapshot:
        self._version += 1
        snap = take_snapshot(
            post, window=window, version=self._version, dtype=dtype,
            telemetry=telemetry,
        )
        # the copies must have materialized before the swap: a reader that
        # picks up the new front serves finished buffers, not futures that
        # still alias an in-flight donation
        jax.block_until_ready((snap.posterior.mean, snap.posterior.rho))
        self._front = snap  # the atomic swap
        self.n_published += 1
        return snap

    def current(self) -> PosteriorSnapshot:
        if self._front is None:
            raise RuntimeError(
                "no snapshot published yet — call Session.snapshot() (or "
                "SnapshotStore.publish) before serving"
            )
        return self._front

    @property
    def version(self) -> int:
        return self._version

    def age(self, now: int | None = None) -> int:
        """Windows since the served snapshot was taken (>= 0)."""
        snap = self.current()
        if now is None:
            if self.clock is None:
                raise ValueError(
                    "SnapshotStore.age() needs `now` or a wired clock"
                )
            now = self.clock()
        return max(int(now) - snap.window, 0)

    def telemetry(self) -> dict:
        """Plain-data store block (merged into the serving telemetry)."""
        if self._front is None:
            return {"published": 0}
        snap = self._front
        out = {
            "published": self.n_published,
            "snapshot_window": snap.window,
            "snapshot_version": snap.version,
            "snapshot_dtype": snap.dtype,
            "snapshot_bytes": snap.nbytes(),
        }
        if self.clock is not None:
            out["snapshot_age"] = self.age()
        return out
