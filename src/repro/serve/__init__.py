"""``repro.serve`` — the posterior serving tier (ROADMAP "Serving").

Snapshot-isolated, batched MC-predictive inference against a live
``Session``: ``snapshot.SnapshotStore`` double-buffers immutable copies of
the consensus ``FlatPosterior`` (optionally bf16-resident for half the
serving HBM), and ``server.PredictiveServer`` serves the paper's
Monte-Carlo predictive distribution from the front buffer through a
compiled-once padding-bucket apply cache, under a bounded-staleness SLO.

Quickstart (see ``examples/serve_batched.py`` for the full tour)::

    sess = Session.from_spec(spec)
    sess.run(n_rounds=8)
    sess.snapshot(dtype="bf16")            # publish the serving copy
    server = sess.attach_server(mc_samples=8, max_staleness=4)
    probs, meta = server.query(x, agent=0)
"""
from repro.serve.server import (
    DEFAULT_BUCKETS,
    PredictiveServer,
    StalenessSLOError,
)
from repro.serve.snapshot import PosteriorSnapshot, SnapshotStore, take_snapshot

__all__ = [
    "DEFAULT_BUCKETS",
    "PosteriorSnapshot",
    "PredictiveServer",
    "SnapshotStore",
    "StalenessSLOError",
    "take_snapshot",
]
