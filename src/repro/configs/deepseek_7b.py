"""DeepSeek-LLM-7B [arXiv:2401.02954]: llama-arch, 30L, d_model 4096,
32 heads (MHA: kv=32), d_ff 11008, vocab 102400."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    pattern=("attn",),
    source="arXiv:2401.02954",
    long_context_ok=True,  # via SWA window_override
)
