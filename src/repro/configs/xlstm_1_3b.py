"""xLSTM-1.3B [arXiv:2405.04517]: 48 blocks, d_model 2048, 4 heads,
d_ff 0 (blocks carry their own 2x up-projection), vocab 50304.
Pattern: 7 mLSTM (matrix memory) : 1 sLSTM (scalar memory) per period —
6 periods of 8 blocks.  Attention-free: native sub-quadratic long context."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=("mlstm",) * 7 + ("slstm",),
    source="arXiv:2405.04517",
    long_context_ok=True,  # native (O(1) recurrent state)
)
