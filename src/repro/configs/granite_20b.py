"""Granite-20B code model [arXiv:2405.04324]: 52L, d_model 6144, 48 heads
with multi-query attention (kv=1), d_ff 24576, vocab 49152."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    pattern=("attn",),
    source="arXiv:2405.04324",
    long_context_ok=True,  # via SWA window_override
)
