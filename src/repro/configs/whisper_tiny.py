"""Whisper-tiny [arXiv:2212.04356]: encoder-decoder, 4+4 layers, d_model 384,
6 heads, d_ff 1536, vocab 51865.  The mel-spectrogram + conv frontend is a
STUB per the assignment: input_specs() provides precomputed frame embeddings
[B, 1500, 384].  long_500k is SKIPPED (full-attention enc-dec; the model
family's input is <=30 s of audio = 1500 frames — see DESIGN.md §5)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,  # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    pattern=("dec_attn",),
    encoder_layers=4,
    encoder_seq=1500,
    frontend="audio_stub",
    source="arXiv:2212.04356",
    long_context_ok=False,  # skip long_500k (documented in DESIGN.md)
)
