"""The paper's own model configurations.

* ``bayes_mlp``: the 2x200-unit fully-connected ReLU network the paper uses
  for MNIST/FMNIST (same architecture as FedAvg [8]) — trained as a
  mean-field Bayesian NN via Bayes-by-Backprop.
* ``repro_100m``: a ~100M decoder-only transformer for the end-to-end
  decentralized-training example (examples/train_decentralized_lm.py).
"""
from repro.configs.base import ModelConfig

# the ~100M end-to-end training example (examples/)
REPRO_100M = ModelConfig(
    name="repro-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=32768,
    pattern=("attn",),
    source="paper-scale example (this repo)",
)

# paper MLP: 2 hidden layers, 200 units, ReLU (McMahan et al. architecture)
PAPER_MLP_HIDDEN = 200
PAPER_MLP_LAYERS = 2
