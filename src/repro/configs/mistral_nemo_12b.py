"""Mistral-Nemo-Base-2407 [hf:mistralai/Mistral-Nemo-Base-2407]: 40L,
d_model 5120, 32 heads (GQA kv=8, head_dim 128), d_ff 14336, vocab 131072,
128k context."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    pattern=("attn",),
    head_dim=128,
    rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
    long_context_ok=True,  # via SWA window_override
)
