"""OLMoE-1B-7B [arXiv:2409.02060]: 16L, d_model 2048, 16 heads (kv=16),
expert d_ff 1024, vocab 50304, 64 experts top-8 (1B active / 7B total)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    pattern=("moe",),
    n_experts=64,
    top_k=8,
    qk_norm=True,  # OLMoE uses QK-norm
    source="arXiv:2409.02060",
    long_context_ok=True,  # via SWA window_override (noted in DESIGN.md)
)
