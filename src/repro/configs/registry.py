"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.configs import (
    deepseek_7b,
    granite_20b,
    mistral_nemo_12b,
    olmoe_1b_7b,
    phi35_moe_42b_a6_6b,
    pixtral_12b,
    qwen3_8b,
    recurrentgemma_9b,
    whisper_tiny,
    xlstm_1_3b,
)
from repro.configs.paper_models import REPRO_100M

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        olmoe_1b_7b.CONFIG,
        phi35_moe_42b_a6_6b.CONFIG,
        qwen3_8b.CONFIG,
        granite_20b.CONFIG,
        xlstm_1_3b.CONFIG,
        recurrentgemma_9b.CONFIG,
        whisper_tiny.CONFIG,
        pixtral_12b.CONFIG,
        mistral_nemo_12b.CONFIG,
        deepseek_7b.CONFIG,
        REPRO_100M,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    cfg = ARCHS[name]
    cfg.validate()
    return cfg


def list_archs() -> list[str]:
    return sorted(ARCHS)
