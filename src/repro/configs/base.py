"""Architecture config system.

A ``ModelConfig`` fully describes one architecture from the assigned pool.
Layers are organized as ``n_periods`` repetitions of ``pattern`` (a tuple of
block kinds) plus an optional ``tail`` (pattern remainder) — this lets
heterogeneous stacks (RG-LRU 1:2 hybrids, xLSTM 7:1) compile via a single
``lax.scan`` over periods with per-kind parameter stacks.

Block kinds:
  attn        pre-norm GQA attention (+qk-norm, +RoPE) + SwiGLU MLP
  local_attn  same but sliding-window attention
  moe         pre-norm GQA attention + top-k mixture-of-experts FFN
  mlstm       xLSTM matrix-memory block (chunkwise-parallel recurrence)
  slstm       xLSTM scalar-memory block (sequential scan)
  rglru       RecurrentGemma recurrent block (conv1d + RG-LRU) + MLP
  enc_attn    bidirectional encoder attention + MLP (whisper encoder)
  dec_attn    causal self-attn + cross-attn + MLP (whisper decoder)
"""
from __future__ import annotations

import dataclasses


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: tuple[str, ...] = ("attn",)
    head_dim: int = 0  # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- attention options ---
    qk_norm: bool = False
    sliding_window: int = 0  # 0 = full; >0 = window size for local_attn
    rope_theta: float = 10000.0
    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper: 1500 frames after conv frontend
    # --- modality frontend stubs ---
    frontend: str = "none"  # none | audio_stub | vision_stub
    n_patches: int = 0  # vlm: image patch embeddings per sample
    # --- numerics ---
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"  # compute dtype
    param_dtype: str = "float32"
    # --- framework integration ---
    source: str = ""  # paper / model-card citation
    long_context_ok: bool = True  # may run long_500k (sub-quadratic path)
    long_context_window: int = 4096  # SWA window used for long_500k decode
    tie_embeddings: bool = False

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail(self) -> tuple[str, ...]:
        """Pattern remainder when n_layers % len(pattern) != 0."""
        r = self.n_layers % len(self.pattern)
        return self.pattern[:r]

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def kind_counts(self) -> dict[str, int]:
        """Block-kind -> count per period."""
        counts: dict[str, int] = {}
        for k in self.pattern:
            counts[k] = counts.get(k, 0) + 1
        return counts

    def validate(self) -> None:
        assert self.d_model % self.n_heads == 0 or self.head_dim, self.name
        assert self.n_heads % self.n_kv_heads == 0, self.name
        if self.n_experts:
            assert self.top_k > 0 and "moe" in self.pattern, self.name
        assert self.n_periods * len(self.pattern) + len(self.tail) == self.n_layers

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: 2 layers (1 period of a truncated pattern or
        2 periods of single-kind), d_model<=256, <=4 experts."""
        kinds = list(dict.fromkeys(self.pattern))  # preserve kind coverage
        pattern = tuple(kinds[:2]) if len(kinds) >= 2 else (kinds[0],) * 2
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % kv:
            kv -= 1
        base = dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=len(pattern),
            pattern=pattern,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=kv,
            head_dim=d_model // n_heads,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 16),
            n_patches=min(self.n_patches, 16) if self.n_patches else 0,
            sliding_window=min(self.sliding_window, 8) if self.sliding_window else 0,
            long_context_window=64,
        )
        base = dataclasses.replace(base, **overrides)
        base.validate()
        return base


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
