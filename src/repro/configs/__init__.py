"""Assigned-architecture registry.  ``get_config(name)`` returns the full
production config; ``get_config(name).reduced()`` the CPU smoke variant."""
from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.configs.registry import ARCHS, get_config, list_archs

__all__ = [
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "ARCHS",
    "get_config",
    "list_archs",
]
