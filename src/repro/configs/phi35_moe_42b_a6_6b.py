"""Phi-3.5-MoE-instruct [hf:microsoft/Phi-3.5-MoE-instruct]: 32L, d_model
4096, 32 heads (GQA kv=8), expert d_ff 6400, vocab 32064, 16 experts top-2
(42B total / 6.6B active)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    pattern=("moe",),
    n_experts=16,
    top_k=2,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    long_context_ok=True,  # via SWA window_override
)
