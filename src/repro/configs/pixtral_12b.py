"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409]: Pixtral-ViT vision encoder +
Mistral-Nemo-12B decoder (40L, d_model 5120, 32 heads GQA kv=8, head_dim 128,
d_ff 14336, vocab 131072).  The ViT encoder + projector is a STUB per the
assignment: input_specs() provides precomputed patch embeddings
[B, 256, 5120] that are projected and prepended to the token sequence."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    pattern=("attn",),
    head_dim=128,
    rope_theta=1_000_000.0,
    frontend="vision_stub",
    n_patches=256,
    source="hf:mistralai/Pixtral-12B-2409",
    long_context_ok=True,  # via SWA window_override on the decoder
)
