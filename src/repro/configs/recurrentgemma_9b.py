"""RecurrentGemma-9B / Griffin [arXiv:2402.19427]: 38 blocks, d_model 4096,
16 heads (MQA kv=1), d_ff 12288, vocab 256000.  Pattern 2 recurrent
(RG-LRU) : 1 local attention (window 2048) — 12 periods + (rglru, rglru)
tail.  Hybrid: native sub-quadratic long context."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    pattern=("rglru", "rglru", "local_attn"),
    sliding_window=2048,
    tie_embeddings=True,  # Gemma family ties input/output embeddings
    source="arXiv:2402.19427",
    long_context_ok=True,  # native (RG-LRU state + windowed attention)
)
