"""Beyond-paper consensus optimizations (EXPERIMENTS.md §Perf).

The paper-faithful baseline (core.posterior.consensus_all_agents) computes
eq. (6) as an einsum over the agent axis; under GSPMD with the agent dim
sharded this lowers to an ALL-GATHER of the whole posterior (N x params
bytes) on every consensus.  Two optimizations:

1. ``consensus_ppermute`` — for SPARSE W (ring/torus neighborhoods) exchange
   only with actual graph neighbors via ``lax.ppermute`` inside
   ``shard_map``: deg(i) x params bytes instead of N x params.  Exact
   (bitwise same math, different schedule).
2. ``dtype`` compression — exchange (prec, prec*mu) in bf16: halves the
   wire bytes; approximate, error-bounded by ``core.numerics
   .wire_error_bound`` (tests/test_wire_dtype.py).  Since the wire-dtype
   PR this is a first-class knob (``InferenceSpec(wire_dtype=...)``) and
   every cast site here routes through the ONE shared helper
   ``core.numerics.wire_cast_pair`` (previously each function inlined its
   own copy).
3. ``consensus_ppermute_window`` — the SHARDED GOSSIP WINDOW (ROADMAP
   "Gossip scale-out"): one ``shard_map`` over the flat [N, P] buffers,
   sharded on the agent axis, that executes one ``gossip.clocks
   .EventWindow`` by ppermuting ONLY the shard offsets its fired edges
   cross.  Wire bytes scale with the window's active cross-shard offsets
   (idle windows move zero bytes) instead of the dense all-gather's
   N x params.  BIT-IDENTICAL to ``core.flat.consensus_flat_masked`` —
   the equivalence ladder synchronous == instant gossip == sharded gossip
   is enforced by tests/test_gossip.py.

All preserve the fixed point structure of eq. (6): weights stay
row-stochastic, output precision remains a convex combination.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.flat import XLA_BLOCK, _MAX_UNROLL, FlatPosterior
from repro.core.numerics import canonical_wire_dtype, wire_cast_pair
from repro.core.posterior import GaussianPosterior, softplus, softplus_inv

try:  # jax >= 0.5 exports shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # 0.4.x: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map


def consensus_einsum(posts: GaussianPosterior, W: jax.Array,
                     wire_dtype=jnp.float32) -> GaussianPosterior:
    """Dense eq. (6) with optional wire-dtype compression of the exchanged
    sufficient statistics (prec, prec*mean)."""
    wire_dtype = canonical_wire_dtype(wire_dtype)

    def combine(mean_stack, rho_stack):
        prec = 1.0 / jnp.square(softplus(rho_stack))
        # keep the exchanged sufficient statistics in wire_dtype THROUGH the
        # einsum (accumulate in fp32) — casting back before the contraction
        # would let XLA hoist the convert above the all-gather and the wire
        # would stay fp32 (measured: identical collective bytes).
        prec_w, pm = wire_cast_pair(prec, prec * mean_stack, wire_dtype)
        w_cast = W.astype(wire_dtype)
        new_prec = jnp.einsum("ij,j...->i...", w_cast, prec_w,
                              preferred_element_type=jnp.float32)
        new_pm = jnp.einsum("ij,j...->i...", w_cast, pm,
                            preferred_element_type=jnp.float32)
        new_mean = new_pm / new_prec
        new_rho = softplus_inv(jnp.sqrt(1.0 / new_prec))
        return new_mean, new_rho

    flat_mean, treedef = jax.tree.flatten(posts.mean)
    flat_rho = treedef.flatten_up_to(posts.rho)
    out = [combine(m, r) for m, r in zip(flat_mean, flat_rho)]
    return GaussianPosterior(
        mean=jax.tree.unflatten(treedef, [m for m, _ in out]),
        rho=jax.tree.unflatten(treedef, [r for _, r in out]),
    )


def consensus_einsum_flat(
    posts: FlatPosterior, W: jax.Array, wire_dtype=jnp.float32
) -> FlatPosterior:
    """Dense eq. (6) directly on the flat [N, P] buffers: ONE einsum pair for
    the whole network instead of a Python loop over leaves.  Under GSPMD with
    the agent dim sharded this still lowers to an all-gather, but of one
    contiguous buffer — a single collective per round (vs one per leaf), and
    the wire-dtype compression applies to the whole payload at once."""
    wire_dtype = canonical_wire_dtype(wire_dtype)
    prec = 1.0 / jnp.square(softplus(posts.rho))
    prec_w, pm = wire_cast_pair(prec, prec * posts.mean, wire_dtype)
    w_cast = W.astype(wire_dtype)
    new_prec = jnp.einsum("ij,jp->ip", w_cast, prec_w,
                          preferred_element_type=jnp.float32)
    new_pm = jnp.einsum("ij,jp->ip", w_cast, pm,
                        preferred_element_type=jnp.float32)
    return dataclasses.replace(
        posts,
        mean=new_pm / new_prec,
        rho=softplus_inv(jnp.sqrt(1.0 / new_prec)),
    )


def consensus_ppermute_ring_flat(
    posts: FlatPosterior,
    mesh: jax.sharding.Mesh,
    axis: str,
    self_weight: float = 1.0 / 3.0,
    wire_dtype=jnp.float32,
    W: jax.Array | None = None,
) -> FlatPosterior:
    """Bidirectional-ring eq. (6) on the flat buffers: one ``shard_map`` over
    the two [N, P] arrays (the pytree version below issues one shard_map per
    leaf).  Wire bytes per agent: 2 x P (both neighbor directions).

    ``W=None`` uses the uniform ring weights from ``self_weight``;
    passing the [N, N] ring matrix reads each shard's (self, prev, next)
    weights from its own row via ``axis_index`` — the form
    ``make_train_round_step(consensus_impl="ppermute")`` routes flat
    posteriors through (non-ring entries of W are ignored; for n == 2 the
    two neighbor directions coincide and only the fwd direction is mixed,
    exactly like ``consensus_ppermute_pod``).
    """
    wire_dtype = canonical_wire_dtype(wire_dtype)
    n = mesh.shape[axis]
    fwd = [(i, (i + 1) % n) for i in range(n)]  # receive from i-1
    bwd = [(i, (i - 1) % n) for i in range(n)]  # receive from i+1
    if W is None:
        w_static = ring_weights(n, self_weight)
        Wd = None
    else:
        w_static = None
        Wd = jnp.asarray(W, jnp.float32)

    def shard_fn(mean, rho):
        if Wd is None:
            w_self, w_prev, w_next = w_static
        else:
            i = jax.lax.axis_index(axis)
            w_self = Wd[i, i]
            w_prev = Wd[i, (i - 1) % n]
            w_next = Wd[i, (i + 1) % n] if n > 2 else jnp.asarray(0.0)
        prec = 1.0 / jnp.square(softplus(rho))
        pw, pm = wire_cast_pair(prec, prec * mean, wire_dtype)
        prev_p = jax.lax.ppermute(pw, axis, fwd)
        prev_pm = jax.lax.ppermute(pm, axis, fwd)
        next_p = jax.lax.ppermute(pw, axis, bwd)
        next_pm = jax.lax.ppermute(pm, axis, bwd)
        new_prec = (
            w_self * prec
            + w_prev * prev_p.astype(jnp.float32)
            + w_next * next_p.astype(jnp.float32)
        )
        new_pm = (
            w_self * (prec * mean)
            + w_prev * prev_pm.astype(jnp.float32)
            + w_next * next_pm.astype(jnp.float32)
        )
        return new_pm / new_prec, softplus_inv(jnp.sqrt(1.0 / new_prec))

    spec = P(axis, None)
    fn = _shard_map(
        shard_fn, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec)
    )
    mean, rho = fn(posts.mean, posts.rho)
    return dataclasses.replace(posts, mean=mean, rho=rho)


def consensus_ppermute_pod(
    posts: GaussianPosterior,
    W: jax.Array,  # [A, A]
    mesh: jax.sharding.Mesh,
    shardings,  # GaussianPosterior-shaped tree of NamedSharding for posts
    wire_dtype=jnp.bfloat16,
    axis: str = "pod",
) -> GaussianPosterior:
    """Eq. (6) over the pod axis via explicit neighbor ppermute in shard_map.

    Exchanges ONLY the sufficient statistics (prec, prec*mu) with the other
    pod(s), in ``wire_dtype`` — unlike the einsum path, the collective is
    guaranteed to run on the compressed payload (the einsum path lets XLA's
    dot legalization hoist converts above the all-gather; measured:
    identical f32 wire bytes).  Implemented for rings of any A (each agent
    mixes self + both neighbors); for A=2 both neighbors coincide."""
    wire_dtype = canonical_wire_dtype(wire_dtype)
    n = mesh.shape[axis]
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    Wd = jnp.asarray(W, jnp.float32)

    def shard_fn(mean, rho):
        i = jax.lax.axis_index(axis)
        prec = 1.0 / jnp.square(softplus(rho))
        pm = prec * mean
        prec_w, pm_w = wire_cast_pair(prec, pm, wire_dtype)
        prev_p = jax.lax.ppermute(prec_w, axis, fwd).astype(jnp.float32)
        prev_pm = jax.lax.ppermute(pm_w, axis, fwd).astype(jnp.float32)
        w_self = Wd[i, i]
        w_prev = Wd[i, (i - 1) % n]
        if n > 2:
            next_p = jax.lax.ppermute(prec_w, axis, bwd).astype(jnp.float32)
            next_pm = jax.lax.ppermute(pm_w, axis, bwd).astype(jnp.float32)
            w_next = Wd[i, (i + 1) % n]
        else:
            next_p = jnp.zeros_like(prec)
            next_pm = jnp.zeros_like(pm)
            w_next = jnp.asarray(0.0)
        new_prec = w_self * prec + w_prev * prev_p + w_next * next_p
        new_pm = w_self * pm + w_prev * prev_pm + w_next * next_pm
        new_mean = new_pm / new_prec
        new_rho = softplus_inv(jnp.sqrt(1.0 / new_prec))
        return new_mean, new_rho

    flat_mean, treedef = jax.tree.flatten(posts.mean)
    flat_rho = treedef.flatten_up_to(posts.rho)
    flat_shard = treedef.flatten_up_to(shardings.mean)
    outs = []
    for m, r, s in zip(flat_mean, flat_rho, flat_shard):
        spec = s.spec if hasattr(s, "spec") else s
        fn = _shard_map(
            shard_fn, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec)
        )
        outs.append(fn(m, r))
    return GaussianPosterior(
        mean=jax.tree.unflatten(treedef, [m for m, _ in outs]),
        rho=jax.tree.unflatten(treedef, [r for _, r in outs]),
    )


# ---------------------------------------------------------------------------
# sharded gossip event windows (ROADMAP "Gossip scale-out")
# ---------------------------------------------------------------------------


def window_shard_offsets(window, n_shards: int) -> tuple[int, ...]:
    """The static permutation schedule of one event window: the sorted set
    of nonzero shard offsets ``(dst_shard - src_shard) mod n_shards`` crossed
    by the window's fired edges (agents are block-sharded: agent a lives on
    shard ``a // (N // n_shards)``).  One ``lax.ppermute`` rotation per
    offset moves every cross-shard message of that offset at once;
    intra-shard edges (offset 0) need no communication at all.  Derived
    host-side from ``EventWindow.edges`` — the schedule is a pure function
    of the window, so distinct window supports compile distinct (cached)
    programs while repeated supports reuse them."""
    per = window.n_agents // n_shards
    ev = window.edges[: window.n_events]
    return tuple(sorted(
        {(int(d) // per - int(s) // per) % n_shards for d, s in ev} - {0}
    ))


@functools.lru_cache(maxsize=None)
def _window_consensus_fn(mesh, axis, offsets, n, per, p, block, wire_dtype):
    """Build + cache the jitted shard_map program for one (mesh, schedule,
    shape, wire dtype) signature.  The body mirrors ``core.flat
    .consensus_flat_reference`` op for op (same elementwise chain — wire
    rounding included, same [*, N] x [N, cols] matmul contraction, same
    column blocking, same activity select) so the sharded window is
    bit-identical to the masked reference AT EVERY WIRE DTYPE; only the
    data movement differs (buffers assembled from neighbor-shard ppermutes
    instead of being resident — and at bf16/f16 the ppermuted payload
    itself is wire-dtype, halving the ICI bytes per rotation)."""
    n_shards = mesh.shape[axis]
    compressed = wire_dtype != jnp.float32

    def shard_fn(w_rows, act, mean_l, rho_l):
        # w_rows [per, N]: this shard's rows of W-tilde; mean_l/rho_l
        # [per, P]: this shard's agents
        i = jax.lax.axis_index(axis)
        prec = 1.0 / jnp.square(softplus(rho_l))
        pm = prec * mean_l
        if compressed:
            # exchange boundary: the wire payload is the rounded (prec,
            # prec*mu).  The OWN block decodes the same rounded values the
            # neighbors receive, so the assembled buffer is elementwise
            # identical to the dense masked kernel's rounded buffer (the
            # equivalence ladder stays bitwise per wire dtype).
            prec_w, pm_w = wire_cast_pair(prec, pm, wire_dtype)
            prec = prec_w.astype(jnp.float32)
            pm = pm_w.astype(jnp.float32)
        # assemble the [N, P] sufficient-statistic buffers this shard's rows
        # read: own block always (self loops + intra-shard edges), one
        # ppermute rotation per fired cross-shard offset.  Rows of shards at
        # un-fired offsets stay zero — their W-tilde entries are zero, so
        # they contribute exactly 0.0 to the matmul (bit-stable).
        buf_prec = jnp.zeros((n, prec.shape[-1]), prec.dtype)
        buf_pm = jnp.zeros_like(buf_prec)
        buf_prec = jax.lax.dynamic_update_slice(buf_prec, prec, (i * per, 0))
        buf_pm = jax.lax.dynamic_update_slice(buf_pm, pm, (i * per, 0))
        for d in offsets:
            perm = [(s, (s + d) % n_shards) for s in range(n_shards)]
            if compressed:
                # the collective moves the COMPRESSED statistics (half the
                # ICI bytes per rotation at bf16); decode fp32 on receipt
                r_prec = jax.lax.ppermute(prec_w, axis, perm).astype(jnp.float32)
                r_pm = jax.lax.ppermute(pm_w, axis, perm).astype(jnp.float32)
            else:
                r_prec = jax.lax.ppermute(prec, axis, perm)
                r_pm = jax.lax.ppermute(pm, axis, perm)
            src0 = ((i - d) % n_shards) * per
            buf_prec = jax.lax.dynamic_update_slice(buf_prec, r_prec, (src0, 0))
            buf_pm = jax.lax.dynamic_update_slice(buf_pm, r_pm, (src0, 0))
        a = (act > 0)[:, None]

        def blk(s, e):
            new_prec = jnp.matmul(
                w_rows, buf_prec[:, s:e], preferred_element_type=jnp.float32
            )
            new_pm = jnp.matmul(
                w_rows, buf_pm[:, s:e], preferred_element_type=jnp.float32
            )
            m_o = new_pm / new_prec
            r_o = softplus_inv(jax.lax.rsqrt(new_prec))
            return (
                jnp.where(a, m_o, mean_l[:, s:e]),
                jnp.where(a, r_o, rho_l[:, s:e]),
            )

        # identical column blocking to consensus_flat_reference (cache
        # blocking + unroll cap) — required for large-P bit-identity
        blk_cols = block
        if p > blk_cols and -(-p // blk_cols) > _MAX_UNROLL:
            blk_cols = -(-p // _MAX_UNROLL)
        if p <= blk_cols:
            return blk(0, p)
        mean_out = jnp.empty_like(mean_l)
        rho_out = jnp.empty_like(rho_l)
        for s in range(0, p, blk_cols):
            e = min(s + blk_cols, p)
            m_o, r_o = blk(s, e)
            mean_out = jax.lax.dynamic_update_slice(mean_out, m_o, (0, s))
            rho_out = jax.lax.dynamic_update_slice(rho_out, r_o, (0, s))
        return mean_out, rho_out

    spec_np = P(axis, None)
    return jax.jit(_shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(spec_np, P(axis), spec_np, spec_np),
        out_specs=(spec_np, spec_np),
    ))


def consensus_ppermute_window(
    posts: FlatPosterior,
    window,  # gossip.clocks.EventWindow
    mesh: jax.sharding.Mesh,
    axis: str = "agents",
    *,
    block: int | None = None,
    wire_dtype=None,
    w_eff: jax.Array | None = None,
    active: jax.Array | None = None,
) -> FlatPosterior:
    """Execute ONE gossip event window sharded over the agent axis.

    The flat [N, P] posterior buffers are block-sharded on ``mesh``'s
    ``axis`` (N must divide evenly); the window's static edge list is
    lowered to a permutation schedule (``window_shard_offsets``) and the
    whole window runs as one ``shard_map``: per fired cross-shard offset,
    one ``ppermute`` rotation of the (prec, prec*mu) sufficient statistics,
    then each shard reduces its own W-tilde rows locally.  Wire bytes per
    window: ``n_offsets x 2 x N/S x P`` per shard — proportional to the
    window's cross-shard activity, zero for an idle window — vs the dense
    path's full all-gather (``launch.costmodel.gossip_window_roofline``).

    Bit-identical to ``core.flat.consensus_flat_masked`` on the same
    window AND the same ``wire_dtype`` (equivalence-ladder acceptance test
    in tests/test_gossip.py / test_wire_dtype.py): at bf16/f16 the
    ppermuted payload is the compressed (prec, prec*mu) — half the wire
    bytes per rotation — decoded fp32 on receipt.
    Instant-delivery windows only: delayed windows (``window.max_lag > 0``)
    merge history slots and run the gather path in the engine.

    ``w_eff``/``active`` override the window's W-tilde and activity mask
    WITHOUT changing the (static, edge-derived) permutation schedule — the
    quarantine guard's hook: it zeroes an invalid source's columns and moves
    the mass to self, which only ever REMOVES weight from scheduled edges
    (rotating a sanitized zero-weight payload is harmless), so the cached
    shard_map program is reused unchanged.
    """
    n = window.n_agents
    n_shards = mesh.shape[axis]
    if n % n_shards:
        raise ValueError(
            f"agent axis ({n}) must divide evenly over the {n_shards}-shard "
            f"mesh axis {axis!r}"
        )
    if window.max_lag > 0:
        raise ValueError(
            "consensus_ppermute_window implements instant delivery; delayed "
            "windows (max_lag > 0) run the history-gather path "
            "(core.flat.consensus_flat_delayed)"
        )
    per = n // n_shards
    p = posts.mean.shape[-1]
    fn = _window_consensus_fn(
        mesh, axis, window_shard_offsets(window, n_shards), n, per, p,
        XLA_BLOCK if block is None else block,
        canonical_wire_dtype(wire_dtype),
    )
    mean, rho = fn(
        (jnp.asarray(window.w_eff, jnp.float32) if w_eff is None
         else jnp.asarray(w_eff, jnp.float32)),
        jnp.asarray(window.active) if active is None else jnp.asarray(active),
        posts.mean,
        posts.rho,
    )
    return dataclasses.replace(posts, mean=mean, rho=rho)


def ring_weights(n: int, self_weight: float = 1.0 / 3.0) -> tuple[float, float, float]:
    side = (1.0 - self_weight) / 2.0
    return self_weight, side, side


def consensus_ppermute_ring(
    posts: GaussianPosterior,
    mesh: jax.sharding.Mesh,
    axis: str,
    self_weight: float = 1.0 / 3.0,
    wire_dtype=jnp.float32,
) -> GaussianPosterior:
    """Eq. (6) on a bidirectional RING W via neighbor-only ppermute.

    ``posts`` leaves carry a leading agent dim of size mesh.shape[axis],
    sharded over ``axis``.  Wire bytes per agent: 2 x params (vs N x params
    for the dense all-gather) — the §Perf 'sparse consensus' optimization.
    """
    wire_dtype = canonical_wire_dtype(wire_dtype)
    n = mesh.shape[axis]
    w_self, w_prev, w_next = ring_weights(n, self_weight)
    fwd = [(i, (i + 1) % n) for i in range(n)]  # receive from i-1
    bwd = [(i, (i - 1) % n) for i in range(n)]  # receive from i+1

    def shard_fn(mean, rho):
        # per-shard leading agent dim == 1
        prec = 1.0 / jnp.square(softplus(rho))
        pw, pm = wire_cast_pair(prec, prec * mean, wire_dtype)
        prev_p = jax.lax.ppermute(pw, axis, fwd)
        prev_pm = jax.lax.ppermute(pm, axis, fwd)
        next_p = jax.lax.ppermute(pw, axis, bwd)
        next_pm = jax.lax.ppermute(pm, axis, bwd)
        new_prec = (
            w_self * prec
            + w_prev * prev_p.astype(jnp.float32)
            + w_next * next_p.astype(jnp.float32)
        )
        new_pm = (
            w_self * (prec * mean)
            + w_prev * prev_pm.astype(jnp.float32)
            + w_next * next_pm.astype(jnp.float32)
        )
        new_mean = new_pm / new_prec
        new_rho = softplus_inv(jnp.sqrt(1.0 / new_prec))
        return new_mean, new_rho

    def leaf_spec(leaf):
        return P(axis, *([None] * (leaf.ndim - 1)))

    flat_mean, treedef = jax.tree.flatten(posts.mean)
    flat_rho = treedef.flatten_up_to(posts.rho)
    outs = []
    for m, r in zip(flat_mean, flat_rho):
        spec = leaf_spec(m)
        fn = _shard_map(
            shard_fn, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec)
        )
        outs.append(fn(m, r))
    return GaussianPosterior(
        mean=jax.tree.unflatten(treedef, [m for m, _ in outs]),
        rho=jax.tree.unflatten(treedef, [r for _, r in outs]),
    )
