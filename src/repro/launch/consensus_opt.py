"""Beyond-paper consensus optimizations (EXPERIMENTS.md §Perf).

The paper-faithful baseline (core.posterior.consensus_all_agents) computes
eq. (6) as an einsum over the agent axis; under GSPMD with the agent dim
sharded this lowers to an ALL-GATHER of the whole posterior (N x params
bytes) on every consensus.  Two optimizations:

1. ``consensus_ppermute`` — for SPARSE W (ring/torus neighborhoods) exchange
   only with actual graph neighbors via ``lax.ppermute`` inside
   ``shard_map``: deg(i) x params bytes instead of N x params.  Exact
   (bitwise same math, different schedule).
2. ``dtype`` compression — exchange (prec, prec*mu) in bf16: halves the
   wire bytes; approximate (documented, validated to ~1e-2 relative).

Both preserve the fixed point structure of eq. (6): weights stay
row-stochastic, output precision remains a convex combination.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.flat import FlatPosterior
from repro.core.posterior import GaussianPosterior, softplus, softplus_inv

try:  # jax >= 0.5 exports shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # 0.4.x: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map


def consensus_einsum(posts: GaussianPosterior, W: jax.Array,
                     wire_dtype=jnp.float32) -> GaussianPosterior:
    """Dense eq. (6) with optional wire-dtype compression of the exchanged
    sufficient statistics (prec, prec*mean)."""

    def combine(mean_stack, rho_stack):
        prec = 1.0 / jnp.square(softplus(rho_stack))
        # keep the exchanged sufficient statistics in wire_dtype THROUGH the
        # einsum (accumulate in fp32) — casting back before the contraction
        # would let XLA hoist the convert above the all-gather and the wire
        # would stay fp32 (measured: identical collective bytes).
        pm = (prec * mean_stack).astype(wire_dtype)
        prec_w = prec.astype(wire_dtype)
        w_cast = W.astype(wire_dtype)
        new_prec = jnp.einsum("ij,j...->i...", w_cast, prec_w,
                              preferred_element_type=jnp.float32)
        new_pm = jnp.einsum("ij,j...->i...", w_cast, pm,
                            preferred_element_type=jnp.float32)
        new_mean = new_pm / new_prec
        new_rho = softplus_inv(jnp.sqrt(1.0 / new_prec))
        return new_mean, new_rho

    flat_mean, treedef = jax.tree.flatten(posts.mean)
    flat_rho = treedef.flatten_up_to(posts.rho)
    out = [combine(m, r) for m, r in zip(flat_mean, flat_rho)]
    return GaussianPosterior(
        mean=jax.tree.unflatten(treedef, [m for m, _ in out]),
        rho=jax.tree.unflatten(treedef, [r for _, r in out]),
    )


def consensus_einsum_flat(
    posts: FlatPosterior, W: jax.Array, wire_dtype=jnp.float32
) -> FlatPosterior:
    """Dense eq. (6) directly on the flat [N, P] buffers: ONE einsum pair for
    the whole network instead of a Python loop over leaves.  Under GSPMD with
    the agent dim sharded this still lowers to an all-gather, but of one
    contiguous buffer — a single collective per round (vs one per leaf), and
    the wire-dtype compression applies to the whole payload at once."""
    prec = 1.0 / jnp.square(softplus(posts.rho))
    pm = (prec * posts.mean).astype(wire_dtype)
    prec_w = prec.astype(wire_dtype)
    w_cast = W.astype(wire_dtype)
    new_prec = jnp.einsum("ij,jp->ip", w_cast, prec_w,
                          preferred_element_type=jnp.float32)
    new_pm = jnp.einsum("ij,jp->ip", w_cast, pm,
                        preferred_element_type=jnp.float32)
    return dataclasses.replace(
        posts,
        mean=new_pm / new_prec,
        rho=softplus_inv(jnp.sqrt(1.0 / new_prec)),
    )


def consensus_ppermute_ring_flat(
    posts: FlatPosterior,
    mesh: jax.sharding.Mesh,
    axis: str,
    self_weight: float = 1.0 / 3.0,
    wire_dtype=jnp.float32,
    W: jax.Array | None = None,
) -> FlatPosterior:
    """Bidirectional-ring eq. (6) on the flat buffers: one ``shard_map`` over
    the two [N, P] arrays (the pytree version below issues one shard_map per
    leaf).  Wire bytes per agent: 2 x P (both neighbor directions).

    ``W=None`` uses the uniform ring weights from ``self_weight``;
    passing the [N, N] ring matrix reads each shard's (self, prev, next)
    weights from its own row via ``axis_index`` — the form
    ``make_train_round_step(consensus_impl="ppermute")`` routes flat
    posteriors through (non-ring entries of W are ignored; for n == 2 the
    two neighbor directions coincide and only the fwd direction is mixed,
    exactly like ``consensus_ppermute_pod``).
    """
    n = mesh.shape[axis]
    fwd = [(i, (i + 1) % n) for i in range(n)]  # receive from i-1
    bwd = [(i, (i - 1) % n) for i in range(n)]  # receive from i+1
    if W is None:
        w_static = ring_weights(n, self_weight)
        Wd = None
    else:
        w_static = None
        Wd = jnp.asarray(W, jnp.float32)

    def shard_fn(mean, rho):
        if Wd is None:
            w_self, w_prev, w_next = w_static
        else:
            i = jax.lax.axis_index(axis)
            w_self = Wd[i, i]
            w_prev = Wd[i, (i - 1) % n]
            w_next = Wd[i, (i + 1) % n] if n > 2 else jnp.asarray(0.0)
        prec = 1.0 / jnp.square(softplus(rho))
        pm = (prec * mean).astype(wire_dtype)
        pw = prec.astype(wire_dtype)
        prev_p = jax.lax.ppermute(pw, axis, fwd)
        prev_pm = jax.lax.ppermute(pm, axis, fwd)
        next_p = jax.lax.ppermute(pw, axis, bwd)
        next_pm = jax.lax.ppermute(pm, axis, bwd)
        new_prec = (
            w_self * prec
            + w_prev * prev_p.astype(jnp.float32)
            + w_next * next_p.astype(jnp.float32)
        )
        new_pm = (
            w_self * (prec * mean)
            + w_prev * prev_pm.astype(jnp.float32)
            + w_next * next_pm.astype(jnp.float32)
        )
        return new_pm / new_prec, softplus_inv(jnp.sqrt(1.0 / new_prec))

    spec = P(axis, None)
    fn = _shard_map(
        shard_fn, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec)
    )
    mean, rho = fn(posts.mean, posts.rho)
    return dataclasses.replace(posts, mean=mean, rho=rho)


def consensus_ppermute_pod(
    posts: GaussianPosterior,
    W: jax.Array,  # [A, A]
    mesh: jax.sharding.Mesh,
    shardings,  # GaussianPosterior-shaped tree of NamedSharding for posts
    wire_dtype=jnp.bfloat16,
    axis: str = "pod",
) -> GaussianPosterior:
    """Eq. (6) over the pod axis via explicit neighbor ppermute in shard_map.

    Exchanges ONLY the sufficient statistics (prec, prec*mu) with the other
    pod(s), in ``wire_dtype`` — unlike the einsum path, the collective is
    guaranteed to run on the compressed payload (the einsum path lets XLA's
    dot legalization hoist converts above the all-gather; measured:
    identical f32 wire bytes).  Implemented for rings of any A (each agent
    mixes self + both neighbors); for A=2 both neighbors coincide."""
    n = mesh.shape[axis]
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    Wd = jnp.asarray(W, jnp.float32)

    def shard_fn(mean, rho):
        i = jax.lax.axis_index(axis)
        prec = 1.0 / jnp.square(softplus(rho))
        pm = prec * mean
        prec_w = prec.astype(wire_dtype)
        pm_w = pm.astype(wire_dtype)
        prev_p = jax.lax.ppermute(prec_w, axis, fwd).astype(jnp.float32)
        prev_pm = jax.lax.ppermute(pm_w, axis, fwd).astype(jnp.float32)
        w_self = Wd[i, i]
        w_prev = Wd[i, (i - 1) % n]
        if n > 2:
            next_p = jax.lax.ppermute(prec_w, axis, bwd).astype(jnp.float32)
            next_pm = jax.lax.ppermute(pm_w, axis, bwd).astype(jnp.float32)
            w_next = Wd[i, (i + 1) % n]
        else:
            next_p = jnp.zeros_like(prec)
            next_pm = jnp.zeros_like(pm)
            w_next = jnp.asarray(0.0)
        new_prec = w_self * prec + w_prev * prev_p + w_next * next_p
        new_pm = w_self * pm + w_prev * prev_pm + w_next * next_pm
        new_mean = new_pm / new_prec
        new_rho = softplus_inv(jnp.sqrt(1.0 / new_prec))
        return new_mean, new_rho

    flat_mean, treedef = jax.tree.flatten(posts.mean)
    flat_rho = treedef.flatten_up_to(posts.rho)
    flat_shard = treedef.flatten_up_to(shardings.mean)
    outs = []
    for m, r, s in zip(flat_mean, flat_rho, flat_shard):
        spec = s.spec if hasattr(s, "spec") else s
        fn = _shard_map(
            shard_fn, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec)
        )
        outs.append(fn(m, r))
    return GaussianPosterior(
        mean=jax.tree.unflatten(treedef, [m for m, _ in outs]),
        rho=jax.tree.unflatten(treedef, [r for _, r in outs]),
    )


def ring_weights(n: int, self_weight: float = 1.0 / 3.0) -> tuple[float, float, float]:
    side = (1.0 - self_weight) / 2.0
    return self_weight, side, side


def consensus_ppermute_ring(
    posts: GaussianPosterior,
    mesh: jax.sharding.Mesh,
    axis: str,
    self_weight: float = 1.0 / 3.0,
    wire_dtype=jnp.float32,
) -> GaussianPosterior:
    """Eq. (6) on a bidirectional RING W via neighbor-only ppermute.

    ``posts`` leaves carry a leading agent dim of size mesh.shape[axis],
    sharded over ``axis``.  Wire bytes per agent: 2 x params (vs N x params
    for the dense all-gather) — the §Perf 'sparse consensus' optimization.
    """
    n = mesh.shape[axis]
    w_self, w_prev, w_next = ring_weights(n, self_weight)
    fwd = [(i, (i + 1) % n) for i in range(n)]  # receive from i-1
    bwd = [(i, (i - 1) % n) for i in range(n)]  # receive from i+1

    def shard_fn(mean, rho):
        # per-shard leading agent dim == 1
        prec = 1.0 / jnp.square(softplus(rho))
        pm = (prec * mean).astype(wire_dtype)
        pw = prec.astype(wire_dtype)
        prev_p = jax.lax.ppermute(pw, axis, fwd)
        prev_pm = jax.lax.ppermute(pm, axis, fwd)
        next_p = jax.lax.ppermute(pw, axis, bwd)
        next_pm = jax.lax.ppermute(pm, axis, bwd)
        new_prec = (
            w_self * prec
            + w_prev * prev_p.astype(jnp.float32)
            + w_next * next_p.astype(jnp.float32)
        )
        new_pm = (
            w_self * (prec * mean)
            + w_prev * prev_pm.astype(jnp.float32)
            + w_next * next_pm.astype(jnp.float32)
        )
        new_mean = new_pm / new_prec
        new_rho = softplus_inv(jnp.sqrt(1.0 / new_prec))
        return new_mean, new_rho

    def leaf_spec(leaf):
        return P(axis, *([None] * (leaf.ndim - 1)))

    flat_mean, treedef = jax.tree.flatten(posts.mean)
    flat_rho = treedef.flatten_up_to(posts.rho)
    outs = []
    for m, r in zip(flat_mean, flat_rho):
        spec = leaf_spec(m)
        fn = _shard_map(
            shard_fn, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec)
        )
        outs.append(fn(m, r))
    return GaussianPosterior(
        mean=jax.tree.unflatten(treedef, [m for m, _ in outs]),
        rho=jax.tree.unflatten(treedef, [r for _, r in outs]),
    )
