"""Production mesh builders.

Target hardware: TPU v5e pods — 256 chips per pod (16x16), 197 TFLOP/s bf16,
16 GiB / 819 GB/s HBM per chip, ~50 GB/s/link ICI.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get 512 placeholder host devices.

Axis semantics:
  pod    the paper's AGENT axis — each pod is one decentralized-learning
         agent holding its own posterior; consensus (eq. 6) is the only
         cross-pod communication (DCN-friendly: once per round).
  data   batch / FSDP sharding within an agent.
  model  tensor parallelism (heads / d_ff / experts / vocab).
"""
from __future__ import annotations

import jax

# v5e hardware constants (per chip) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_n_agents(mesh: jax.sharding.Mesh) -> int:
    return mesh.shape.get("pod", 1)


def mesh_n_chips(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
