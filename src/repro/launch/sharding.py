"""Sharding rules: parameter / posterior / batch / cache PartitionSpecs.

Policy (baseline; §Perf iterates on it):
  * every >=2D weight shards its last two dims over ("data", "model") —
    FSDP on the penultimate dim, tensor parallelism on the last;
  * MoE expert stacks [.., E, D, F] shard E over "model" (expert
    parallelism) and D over "data";
  * dims that do not divide the axis size are replicated (logged);
  * the leading agent axis (size n_pods) shards over "pod";
  * batch shards over ("pod" agent dim) x ("data");
  * 1D leaves (norm scales, biases, Lambda) replicate.

The posterior (mu, rho), Adam states, and gradients inherit the parameter
specs leaf-wise.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def _divisible(dim: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.shape and dim % mesh.shape[axis] == 0 and dim > 0


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def leaf_pspec(path, leaf, mesh: Mesh, *, agent_leading: bool = False) -> P:
    """PartitionSpec for one parameter leaf (without the agent axis)."""
    name = _path_str(path)
    shape = leaf.shape
    if len(shape) == 0:
        return P()  # scalars (step counters) replicate
    offset = 1 if agent_leading else 0  # leading agent dim handled by caller
    body = list(shape[offset:])
    spec: list = [None] * len(body)

    is_expert = ("w_gate" in name or "w_up" in name or "w_down" in name) and (
        "moe" in name and len(body) >= 3
    )
    if is_expert:
        # [..., E, D, F] (or [..., E, F, D]) — expert parallelism on E
        e_dim = len(body) - 3
        if _divisible(body[e_dim], mesh, "model"):
            spec[e_dim] = "model"
        if _divisible(body[e_dim + 1], mesh, "data"):
            spec[e_dim + 1] = "data"
    elif len(body) >= 2:
        d2, d1 = body[-2], body[-1]
        if _divisible(d2, mesh, "data"):
            spec[-2] = "data"
        if _divisible(d1, mesh, "model"):
            spec[-1] = "model"
        elif spec[-2] is None and _divisible(d1, mesh, "data"):
            # at least FSDP the big dim if TP doesn't divide
            spec[-1] = "data"
    # 1D leaves replicate
    full = ([("pod" if "pod" in mesh.shape else None)] if agent_leading else []) + spec
    return P(*full)


def param_shardings(
    params_shape: PyTree, mesh: Mesh, *, agent_leading: bool = False
) -> PyTree:
    """NamedSharding tree matching ``params_shape`` (a ShapeDtypeStruct tree)."""

    def one(path, leaf):
        return NamedSharding(mesh, leaf_pspec(path, leaf, mesh, agent_leading=agent_leading))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_pspec(mesh: Mesh, shape: tuple, *, agent_leading: bool = True) -> P:
    """Token batches [A, B, S, ...]: A over pod, B over data — each only
    when the dimension size divides the axis."""
    spec: list = [None] * len(shape)
    i = 0
    if agent_leading:
        if _divisible(shape[0], mesh, "pod"):
            spec[0] = "pod"
        i = 1
    if len(shape) > i and _divisible(shape[i], mesh, "data"):
        spec[i] = "data"
    return P(*spec)


# (scheme, leaf-name) -> [(dim-from-end, mesh-axis), ...]
_CACHE_DIMS = {
    ("kv", "k"): [(-4, "data"), (-2, "model")],
    ("kv", "v"): [(-4, "data"), (-2, "model")],
    ("kv", "pos"): [(-2, "data")],
    ("kv", "k_scale"): [(-3, "data"), (-1, "model")],
    ("kv", "v_scale"): [(-3, "data"), (-1, "model")],
    ("mlstm", "C"): [(-4, "data"), (-1, "model")],
    ("mlstm", "n"): [(-3, "data"), (-1, "model")],
    ("mlstm", "m"): [(-2, "data")],
    ("slstm", "c"): [(-2, "data"), (-1, "model")],
    ("slstm", "n"): [(-2, "data"), (-1, "model")],
    ("slstm", "h"): [(-2, "data"), (-1, "model")],
    ("slstm", "m"): [(-2, "data")],
    ("rglru", "h"): [(-2, "data"), (-1, "model")],
    ("rglru", "conv"): [(-3, "data"), (-1, "model")],
}


def cache_pspec(path, leaf, mesh: Mesh, *, agent_leading: bool = True) -> P:
    """Decode caches: batch dim over data, kv-heads / feature dims over
    model, everything guarded by divisibility (B=1 long-context decode
    replicates)."""
    name = _path_str(path)
    parts = name.split("/")
    leaf_name = parts[-1]
    if "mlstm" in parts:
        scheme = "mlstm"
    elif "slstm" in parts:
        scheme = "slstm"
    elif leaf_name in ("k", "v", "pos", "k_scale", "v_scale"):
        scheme = "kv"
    elif leaf_name in ("h", "conv"):
        scheme = "rglru"
    else:
        scheme = None
    shape = leaf.shape
    spec: list = [None] * len(shape)
    for dim, axis in _CACHE_DIMS.get((scheme, leaf_name), []):
        idx = len(shape) + dim
        if 0 <= idx < len(shape) and _divisible(shape[idx], mesh, axis):
            if spec[idx] is None:
                spec[idx] = axis
    if agent_leading and len(shape) >= 1 and spec[0] is None:
        if _divisible(shape[0], mesh, "pod"):
            spec[0] = "pod"
    return P(*spec)


def cache_shardings(cache_shape: PyTree, mesh: Mesh, *, agent_leading: bool = True):
    def one(path, leaf):
        return NamedSharding(mesh, cache_pspec(path, leaf, mesh, agent_leading=agent_leading))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def sharding_report(params_shape: PyTree, mesh: Mesh, agent_leading: bool = False):
    """(n_params, bytes_total, bytes_max_per_device, n_replicated_leaves)."""
    n_params = 0
    total = 0
    per_dev = 0
    n_repl = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        spec = leaf_pspec(path, leaf, mesh, agent_leading=agent_leading)
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        bts = size * leaf.dtype.itemsize
        shard_factor = 1
        for dim_spec in spec:
            if dim_spec is not None:
                shard_factor *= mesh.shape[dim_spec]
        if shard_factor == 1 and len(leaf.shape) >= 2:
            n_repl += 1
        n_params += size
        total += bts
        per_dev += bts // shard_factor
    return n_params, total, per_dev, n_repl
