"""Production step functions: decentralized-Bayesian train round, prefill,
and decode, all vmapped over the agent (pod) axis.

train_round_step — ONE communication round of the paper's rule fused into a
single jitted step (the dry-run target):
  1. consensus (eq. 6) over the agent axis  ->  prior q_i^{(n-1)}
  2. one Bayes-by-Backprop step from that prior (eq. 5): reparameterized
     sample, NLL + KL(q || prior), Adam update on (mu, rho)
The production driver (train.py) runs u local steps per consensus by calling
``local_step`` u-1 additional times against the stored prior — identical
semantics to the paper's u local epochs (supplementary Tables 1-3).

Serving uses the posterior MEAN as the weights (the L=1 fast path of the
paper's MC-predictive serving; --mc-samples exposes L>1).

Posterior format: since PR 2 the launch hot loop runs on the FLAT posterior
(``core.flat.FlatPosterior``, contiguous [A, P] fp32 buffers) end-to-end —
consensus dispatches to the single fused network-wide pass and the model
pytree appears only at the apply boundary (``layout.unflatten`` around
``nll_loss``/``forward``).  Every step function still accepts the legacy
pytree ``GaussianPosterior`` state (``init_train_state(flat=False)``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.flat import FlatPosterior, flat_posterior_from_pytree
from repro.core.posterior import (
    GaussianPosterior,
    consensus_all_agents,
    init_posterior,
    kl_gaussian,
)
from repro.models import forward, init_cache, init_params, nll_loss
from repro.optim import Optimizer, adam, apply_updates
from repro.optim.schedules import Schedule, exponential_decay

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BayesTrainState:
    posterior: GaussianPosterior  # FlatPosterior [A, P] (default) or pytree
    opt_state: Any
    step: jax.Array  # scalar int32


def _unflattener(posterior) -> Callable[[jax.Array], PyTree]:
    """Model-apply-boundary conversion: flat theta [*, P] -> parameter pytree
    (identity for pytree posteriors, whose samples already ARE pytrees)."""
    if isinstance(posterior, FlatPosterior):
        return posterior.layout.unflatten
    return lambda theta: theta


def _n_agents(posterior) -> int:
    return jax.tree.leaves(posterior.mean)[0].shape[0]


def init_train_state(
    key: jax.Array,
    cfg,
    n_agents: int,
    opt: Optimizer,
    init_sigma: float = 0.02,
    flat: bool = True,
) -> BayesTrainState:
    params = init_params(cfg, key)
    stacked = jax.tree.map(
        lambda p: jnp.broadcast_to(p, (n_agents,) + p.shape), params
    )
    post = init_posterior(stacked, init_sigma=init_sigma)
    if flat:
        post = flat_posterior_from_pytree(post, leading_axes=1)
    return BayesTrainState(
        posterior=post,
        opt_state=opt.init(post),
        step=jnp.asarray(0, jnp.int32),
    )


def make_train_round_step(
    cfg,
    W: jax.Array,  # [A, A] row-stochastic agent interaction matrix
    opt: Optimizer | None = None,
    lr_schedule: Schedule | None = None,
    kl_scale: float = 1e-4,
    remat: bool = True,
    bayesian: bool = True,
    consensus_impl: str = "einsum",  # einsum | ppermute | none (§Perf A/B)
    consensus_wire_dtype=None,  # e.g. jnp.bfloat16: §Perf wire compression
    mesh=None,  # required for consensus_impl="ppermute"
    posterior_shardings=None,  # required for consensus_impl="ppermute"
) -> Callable:
    """Build the fused per-round train step (see module docstring).

    ``bayesian=False`` degrades to the deterministic baseline: plain NLL on
    the posterior mean + W-weighted parameter averaging (decentralized
    FedAvg) — the non-Bayesian comparison point.
    """
    opt = opt or adam()
    lr_schedule = lr_schedule or exponential_decay(1e-3, 0.9999)

    def step_fn(state: BayesTrainState, batch: PyTree, key: jax.Array):
        a = W.shape[0]
        lr = lr_schedule(state.step)
        unflatten = _unflattener(state.posterior)
        is_flat = isinstance(state.posterior, FlatPosterior)
        # ---- consensus (eq. 6): the paper's model-aggregation operator ----
        if consensus_impl == "none":
            prior = state.posterior  # pure local step (u>1 rounds / A-B test)
        elif consensus_impl == "ppermute":
            if is_flat:
                # flat posterior: ONE shard_map over the two [A, P] buffers
                # (ROADMAP item closed by ISSUE 3) instead of the leaf-wise
                # pod ppermute; the shard's W row supplies the ring weights
                from repro.launch.consensus_opt import consensus_ppermute_ring_flat

                mean_sh = getattr(posterior_shardings, "mean", None)
                spec0 = getattr(mean_sh, "spec", None)
                axis = (spec0[0] if spec0 and spec0[0] is not None else "pod")
                prior = consensus_ppermute_ring_flat(
                    state.posterior, mesh, axis,
                    wire_dtype=consensus_wire_dtype or jnp.bfloat16,
                    W=W,
                )
            else:
                from repro.launch.consensus_opt import consensus_ppermute_pod

                prior = consensus_ppermute_pod(
                    state.posterior, W, mesh, posterior_shardings,
                    wire_dtype=consensus_wire_dtype or jnp.bfloat16,
                )
        elif consensus_wire_dtype is not None:
            from repro.launch.consensus_opt import (
                consensus_einsum,
                consensus_einsum_flat,
            )

            prior = (
                consensus_einsum_flat(
                    state.posterior, W, wire_dtype=consensus_wire_dtype
                )
                if is_flat
                else consensus_einsum(
                    state.posterior, W, wire_dtype=consensus_wire_dtype
                )
            )
        else:
            prior = consensus_all_agents(state.posterior, W)
        keys = jax.random.split(key, a)

        def loss_fn(post: GaussianPosterior):
            def per_agent(post_a, prior_a, batch_a, key_a):
                if bayesian:
                    theta = post_a.sample(key_a)
                    kl = kl_gaussian(post_a, prior_a)
                else:
                    theta, kl = post_a.mean, jnp.asarray(0.0)
                nll, aux = nll_loss(unflatten(theta), cfg, batch_a, remat=remat)
                ntok = jnp.asarray(batch_a["targets"].size, jnp.float32)
                loss = (nll + cfg.router_aux_weight * aux * ntok) / ntok
                return loss + kl_scale * kl / ntok, (nll / ntok, kl)

            prior_b = jax.lax.stop_gradient(prior)
            losses, metrics = jax.vmap(per_agent)(post, prior_b, batch, keys)
            return jnp.mean(losses), metrics

        (loss, (nll, kl)), grads = jax.value_and_grad(loss_fn, has_aux=True)(prior)
        updates, opt_state = opt.update(grads, state.opt_state, state.step, lr)
        new_post = apply_updates(prior, updates)
        new_state = BayesTrainState(
            posterior=new_post, opt_state=opt_state, step=state.step + 1
        )
        return new_state, {"loss": loss, "nll": nll, "kl": kl}

    return step_fn


def make_local_step(
    cfg,
    opt,
    lr_schedule,
    kl_scale: float = 1e-4,
    remat: bool = True,
    *,
    nll_fn: Callable[[PyTree, Any], jax.Array] | None = None,
    n_mc_samples: int = 1,
):
    """One local VI step against an explicit prior (u>1 rounds in train.py).

    Default (``nll_fn=None``): the LM objective — ``models.nll_loss`` on
    ``cfg``, per-token normalized, averaged over agents.

    ``nll_fn`` (the ``repro.api`` / ``LaunchEngine`` path): an arbitrary
    per-agent pytree NLL.  The loss becomes the paper's un-normalized free
    energy ``kl_scale * KL(q||prior) + E_q[nll]`` (eq. 5, estimated with
    ``n_mc_samples`` MC samples exactly like ``vi.free_energy``), summed over
    agents so each agent's gradient equals its OWN free-energy gradient; the
    returned loss is the per-agent [A] vector.  ``key`` may then be a
    pre-split [A] key array, giving bit-identical RNG to the simulated
    runtime's per-agent key derivation.

    Either way a ``FlatPosterior`` state runs flat end-to-end: sampling, KL,
    the optimizer, and consensus all stay on the [A, P] buffers; the pytree
    appears only inside the model apply (``layout.unflatten``).
    """

    def step_fn(state: BayesTrainState, prior: GaussianPosterior, batch, key):
        a = _n_agents(state.posterior)
        lr = lr_schedule(state.step)
        unflatten = _unflattener(state.posterior)
        # a 1-D array of TYPED keys is a pre-split per-agent batch; anything
        # else (typed scalar, legacy uint32 [2] key) is one key to split
        is_key_batch = (
            jnp.ndim(key) == 1
            and jax.dtypes.issubdtype(key.dtype, jax.dtypes.prng_key)
        )
        keys = key if is_key_batch else jax.random.split(key, a)

        def loss_fn(post):
            def per_agent(post_a, prior_a, batch_a, key_a):
                if nll_fn is not None:
                    from repro.vi.bayes_by_backprop import free_energy

                    return free_energy(
                        post_a,
                        prior_a,
                        lambda theta, b: nll_fn(unflatten(theta), b),
                        batch_a,
                        key_a,
                        n_samples=n_mc_samples,
                        kl_scale=kl_scale,
                    )
                theta = post_a.sample(key_a)
                kl = kl_gaussian(post_a, prior_a)
                nll, aux = nll_loss(unflatten(theta), cfg, batch_a, remat=remat)
                ntok = jnp.asarray(batch_a["targets"].size, jnp.float32)
                return (nll + cfg.router_aux_weight * aux * ntok) / ntok + kl_scale * kl / ntok

            losses = jax.vmap(per_agent)(
                post, jax.lax.stop_gradient(prior), batch, keys
            )
            # sum: d(sum)/d(post_a) = each agent's own gradient (the agents
            # are independent); mean would scale every lr by 1/A
            agg = jnp.sum(losses) if nll_fn is not None else jnp.mean(losses)
            return agg, losses

        (_, losses), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.posterior
        )
        updates, opt_state = opt.update(grads, state.opt_state, state.step, lr)
        new_post = apply_updates(state.posterior, updates)
        loss = losses if nll_fn is not None else jnp.mean(losses)
        return (
            BayesTrainState(posterior=new_post, opt_state=opt_state, step=state.step + 1),
            loss,
        )

    return step_fn


def make_consensus_step(cfg, W: jax.Array, wire_dtype=None):
    """Standalone consensus (eq. 6) over the agent axis — the communication
    phase of a round, applied every u local steps by train.py.  Dispatches on
    the posterior type: a ``FlatPosterior`` runs the single fused
    network-wide pass (Pallas kernel on TPU).  ``wire_dtype`` compresses
    the exchanged (prec, prec*mu) — f32/None is bitwise uncompressed."""
    del cfg  # consensus is model-independent

    def step_fn(posterior: GaussianPosterior) -> GaussianPosterior:
        return consensus_all_agents(posterior, W, wire_dtype=wire_dtype)

    return step_fn


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def serve_params(posterior: GaussianPosterior, dtype=jnp.bfloat16) -> PyTree:
    """Posterior-mean weights cast for serving (paper's L=1 predictive path).
    A flat posterior is unflattened here — serving consumes the model pytree."""
    mean = posterior.mean
    if isinstance(posterior, FlatPosterior):
        mean = posterior.layout.unflatten(mean)
    return jax.tree.map(lambda m: m.astype(dtype), mean)


def make_prefill_step(cfg, window_override: int | None = None):
    """(params [A,...], batch) -> (next-token logits [A,B,1,V], cache)."""

    def step_fn(params: PyTree, batch: PyTree, cache: PyTree):
        def per_agent(p, tokens, frames, patches, cache_a):
            logits, new_cache, _ = forward(
                p,
                cfg,
                tokens,
                cache=cache_a,
                frames=frames,
                patches=patches,
                logits_tail=1,
                window_override=window_override,
            )
            return logits, new_cache

        return jax.vmap(per_agent)(
            params,
            batch["tokens"],
            batch.get("frames"),
            batch.get("patches"),
            cache,
        )

    return step_fn


def make_decode_step(cfg, window_override: int | None = None):
    """(params [A,...], token [A,B,1], position, cache) -> (logits, cache)."""

    def step_fn(params: PyTree, token: jax.Array, position: jax.Array, cache: PyTree,
                frames: jax.Array | None = None):
        def per_agent(p, tok_a, cache_a, frames_a):
            positions = position[None]
            logits, new_cache, _ = forward(
                p,
                cfg,
                tok_a,
                positions=positions,
                cache=cache_a,
                frames=frames_a,
                window_override=window_override,
            )
            return logits, new_cache

        return jax.vmap(per_agent, in_axes=(0, 0, 0, 0 if frames is not None else None))(
            params, token, cache, frames
        )

    return step_fn


def make_agent_cache(cfg, n_agents: int, batch_per_agent: int, capacity: int,
                     dtype=jnp.bfloat16):
    """Agent-stacked decode cache [A, ...]."""
    one = init_cache(cfg, batch_per_agent, capacity, dtype)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n_agents,) + x.shape).copy(), one)
