"""Serving driver: train a small decentralized network, publish a posterior
snapshot, and serve batched MC-predictive traffic against it (the paper's
Sec 4.2 predictive distribution behind the ``repro.serve`` tier).

This replaces the dormant LM prefill/decode seed driver: the repo's end
product is each agent's *classification* predictive served from its
consensus posterior, so the driver now runs the supported path end to end —
``build_session`` -> ``Session.run`` -> ``Session.snapshot`` (the shared
wire-dtype snapshot machinery, not an ad-hoc per-leaf bf16 cast) ->
``PredictiveServer`` request stream — and reports serving latency
percentiles, QPS, and the staleness/SLO telemetry block.

Example (CPU, seconds):
  PYTHONPATH=src python -m repro.launch.serve --rounds 6 --requests 32 \
      --mc-samples 8 --snapshot-dtype bf16 --max-staleness 4
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.api import (
    DataSpec,
    ExperimentSpec,
    InferenceSpec,
    RunSpec,
    ServeSpec,
    TopologySpec,
    build_session,
)


def serving_spec(
    n_agents: int = 4,
    rounds: int = 6,
    seed: int = 0,
    *,
    serve: ServeSpec = ServeSpec(),
) -> ExperimentSpec:
    """A small gossip network whose snapshots carry real staleness
    telemetry — the serving tier's natural substrate."""
    return ExperimentSpec(
        topology=TopologySpec.gossip("ring", {"n": n_agents}),
        data=DataSpec(
            dataset_params=dict(n_classes=4, dim=16, n_train_per_class=60),
            partition_params=dict(n_agents=n_agents),
            batch_size=8,
            local_updates=2,
        ),
        inference=InferenceSpec(hidden=16, depth=1, lr=5e-3),
        run=RunSpec(n_rounds=rounds, seed=seed),
        serve=serve,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--mc-samples", type=int, default=8,
                    help="posterior ensemble size L (0 = point estimate)")
    ap.add_argument("--snapshot-dtype", default="f32",
                    choices=["f32", "bf16", "f16"])
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="SLO bound in training windows (default: off)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = serving_spec(
        args.agents, args.rounds, args.seed,
        serve=ServeSpec(
            snapshot_dtype=args.snapshot_dtype,
            mc_samples=args.mc_samples,
            max_staleness=args.max_staleness,
            staleness_policy="flag",
        ),
    )
    sess = build_session(spec)
    hist = sess.run(eval_every=args.rounds)  # history: final round only
    print(f"trained {args.rounds} windows x {args.agents} agents "
          f"(final loss {hist[-1]['loss'] if hist else None})")

    snap = sess.snapshot()
    print(f"published snapshot: window={snap.window} dtype={snap.dtype} "
          f"resident={snap.nbytes()}B telemetry={snap.telemetry}")

    server = sess.attach_server()
    rng = np.random.default_rng(args.seed)
    x_test = np.asarray(sess.data.x_test)
    # a ragged request stream round-robined over the agents
    sizes = rng.integers(1, 9, size=args.requests)
    for i, n in enumerate(sizes):
        rows = x_test[rng.integers(0, x_test.shape[0], size=int(n))]
        probs, meta = server.query(rows, agent=i % args.agents)
        jax.block_until_ready(probs)

    tel = server.telemetry()
    lat = tel.get("latency", {})
    warm = server._lat_us[len(server.bucket_sizes):]  # skip compile batches
    qps = (1e6 * len(warm) / sum(warm)) if warm else 0.0
    print(f"served {tel['requests']} requests ({tel['rows']} rows, "
          f"{tel['batches']} bucket slabs, {tel['padded_rows']} pad rows, "
          f"{tel['traces']} traces)")
    print(f"latency p50={lat.get('p50_us', 0):.0f}us "
          f"p99={lat.get('p99_us', 0):.0f}us  warm-qps~{qps:.1f}")
    print("telemetry:", json.dumps(tel, default=float))


if __name__ == "__main__":
    main()
