"""Serving driver: batched prefill + autoregressive decode using the
posterior-mean weights (the paper's predictive distribution with L=1; pass
--mc-samples for the full Monte-Carlo predictive averaging).

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.steps import make_agent_cache, make_decode_step, make_prefill_step
from repro.models import init_params


def sample_token(logits: jax.Array, key: jax.Array, temperature: float) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mc-samples", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    a = 1  # serving uses one agent's posterior
    key = jax.random.key(args.seed)
    key, k_init, k_prompt = jax.random.split(key, 3)
    base = jax.vmap(lambda k: init_params(cfg, k))(jax.random.split(k_init, a))
    if args.mc_samples > 1:
        # paper Sec 4.2: Monte-Carlo predictive — L posterior samples served
        # as an ensemble, class probabilities averaged
        from repro.core.posterior import init_posterior

        post = init_posterior(base, init_sigma=0.02)
        keys = jax.random.split(jax.random.key(args.seed + 1), args.mc_samples)
        param_sets = [
            jax.tree.map(
                lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p,
                post.sample(k),
            )
            for k in keys
        ]
    else:
        param_sets = [
            jax.tree.map(
                lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p,
                base,
            )
        ]
    params = param_sets[0]

    b = args.batch
    capacity = args.prompt_len + args.gen
    prompts = jax.random.randint(k_prompt, (a, b, args.prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.zeros((a, b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.frontend == "vision_stub":
        batch["patches"] = jnp.zeros((a, b, cfg.n_patches, cfg.d_model), jnp.float32)

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    # MC-predictive serving: one KV cache per posterior sample (ensemble)
    caches = [make_agent_cache(cfg, a, b, capacity) for _ in param_sets]

    def ensemble_probs(logit_list):
        # paper Sec 4.2: P(y) = (1/L) sum_k Softmax(f_{theta_k}(x))
        ps = [jax.nn.softmax(lg[:, :, -1, : cfg.vocab_size].astype(jnp.float32), -1)
              for lg in logit_list]
        return jnp.log(jnp.mean(jnp.stack(ps), axis=0) + 1e-30)

    t0 = time.time()
    logit_list = []
    for j, p_j in enumerate(param_sets):
        lg, caches[j] = prefill(p_j, batch, caches[j])
        logit_list.append(lg)
    key, k = jax.random.split(key)
    tok = sample_token(ensemble_probs(logit_list), k, args.temperature)
    print(f"prefill {args.prompt_len} tokens x {b} reqs x L={len(param_sets)}: "
          f"{time.time() - t0:.2f}s")

    out_tokens = [tok]
    pos0 = args.prompt_len + (cfg.n_patches if cfg.frontend == "vision_stub" else 0)
    t0 = time.time()
    for i in range(args.gen - 1):
        key, k = jax.random.split(key)
        logit_list = []
        for j, p_j in enumerate(param_sets):
            lg, caches[j] = decode(
                p_j, tok[..., None], jnp.asarray(pos0 + i, jnp.int32), caches[j],
                batch.get("frames"),
            )
            logit_list.append(lg)
        tok = sample_token(ensemble_probs(logit_list), k, args.temperature)
        out_tokens.append(tok)
    dt = time.time() - t0
    gen = jnp.stack(out_tokens, axis=-1)
    print(f"decoded {args.gen - 1} steps x {b} reqs in {dt:.2f}s "
          f"({(args.gen - 1) * b / max(dt, 1e-9):.1f} tok/s)")
    print("sample output ids:", gen[0, 0][:16].tolist())


if __name__ == "__main__":
    main()
