"""Analytic roofline cost model.

WHY THIS EXISTS: XLA's ``compiled.cost_analysis()`` does NOT multiply
``while``-loop bodies by their trip counts (validated: a lax.scan of 10
matmuls reports the FLOPs of ONE — see EXPERIMENTS.md §Dry-run).  Every
production step here wraps layers in a scan (and attention in an inner
KV-chunk scan), so HLO-reported FLOPs/bytes undercount by ~n_layers x
n_chunks.  The dry-run therefore records BOTH the raw HLO numbers (valid
for anything outside the scans — notably the consensus collectives — and
for relative comparisons of same-structure programs) and this analytic
model, which the §Roofline table uses for the three terms.

All quantities are GLOBAL per step (sum over devices).  Coefficients are
deliberately explicit and documented inline so the napkin math in §Perf can
be audited.
"""
from __future__ import annotations

from typing import Any

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ATTN_KINDS = ("attn", "local_attn", "moe", "dec_attn")


def _wire_bytes_per_el(wire_dtype: str) -> int:
    """Bytes per exchanged scalar at a wire dtype (``core.numerics
    .WIRE_DTYPES`` names).  Host-side mirror of ``numerics.wire_itemsize``
    kept in plain ints so the cost model stays jax-free at call time."""
    sizes = {"f32": 4, "bf16": 2, "f16": 2}
    if wire_dtype not in sizes:
        raise ValueError(
            f"unknown wire_dtype {wire_dtype!r}; known: {sorted(sizes)}"
        )
    return sizes[wire_dtype]


def consensus_roofline(
    n_agents: int,
    n_params: int,
    n_leaves: int,
    max_degree: int | None = None,
    bytes_per_el: int = 4,
    *,
    wire_dtype: str = "f32",
    n_edges: int | None = None,
) -> dict[str, Any]:
    """Analytic HBM traffic of one consensus round (eq. 6), per execution
    strategy, for the memory-bound roofline.  Used by
    ``benchmarks/bench_consensus.py`` when interpret-mode wall-clock is not
    meaningful (the Pallas interpreter is orders of magnitude off real HW).

    The posterior state is 2 buffers (mean, rho) of [n_agents, n_params]
    scalars.  Counted array-sized HBM touches (reads + writes), per buffer
    pair:

    * ``leaf_loop``: the unfused per-leaf einsum reference — per leaf the
      chain softplus/square/reciprocal -> einsum -> mul/einsum/div ->
      rsqrt/softplus_inv materializes ~6 round-trips (12 touches) over the
      leaf-sized tensors; XLA fuses within each elementwise group but the
      two einsums force the intermediates (prec, prec*mu, new_prec, new_pm)
      through HBM, and each of the ``n_leaves`` leaves dispatches its own
      kernel chain.
    * ``flat_fused``: the single network-wide kernel — read mean+rho once,
      write mean+rho once: 4 touches, 1 HBM pass, independent of n_leaves.
    * ``flat_sparse``: same, but each agent reads only deg(i) <= max_degree
      neighbor rows instead of all N (identical write traffic).

    Returns bytes per strategy, the pass counts, and the roofline seconds at
    ``HBM_BW`` (single chip).

    WIRE term (``wire_dtype``): with the agent axis sharded, eq. (6)
    all-gathers BOTH sufficient statistics (prec, prec*mu) across agents;
    at a compressed wire dtype the payload is cast at the exchange
    boundary, so the collective bytes scale with ``wire_dtype``'s itemsize
    — bf16 exactly halves them (asserted by unit test).  Reported in the
    ``wire`` block; the HBM terms stay at ``bytes_per_el`` (the buffers
    are fp32-resident, only the exchange compresses).

    E-PARAMETERIZATION (``n_edges`` — self-loops included, i.e.
    ``SparseGraph.n_edges``): every sparse term is really a function of the
    directed edge count E, not of N^2.  ``flat_segments`` is the
    edge-native ``core.flat.consensus_flat_segments`` traffic — gather both
    statistics' source row per edge, write both network buffers — and the
    edge-parameterized wire collective moves only the E - N off-diagonal
    rows instead of the dense N(N-1).  When ``n_edges`` is omitted it is
    derived as ``n_agents * max_degree`` (the padded-table bound), which
    makes ``flat_segments`` coincide with ``flat_sparse``; pass the true E
    for ragged-degree graphs (Watts-Strogatz, Barabasi-Albert), where the
    padded bound overcounts.
    """
    wire_el = _wire_bytes_per_el(wire_dtype)
    row_bytes = n_params * bytes_per_el  # one agent, one buffer
    net_bytes = n_agents * row_bytes  # one buffer for the whole network
    touches_leaf_loop = 12.0  # ~6 round-trips over both buffers
    touches_fused = 4.0  # read mean+rho, write mean+rho
    deg = n_agents if max_degree is None else max_degree
    n_edges_eff = int(n_agents * deg) if n_edges is None else int(n_edges)
    bytes_leaf_loop = touches_leaf_loop * net_bytes
    bytes_fused = touches_fused * net_bytes
    # sparse: each agent reads deg(i) neighbor rows of both buffers; writes
    # are the same 2 network-sized buffers as the dense fused kernel
    bytes_sparse = 2.0 * n_agents * deg * row_bytes + 2.0 * net_bytes
    # segments: 2 E-row gathers (prec, prec*mu sources) + 2 network writes —
    # O(E), never O(N^2); equals bytes_sparse when E = N * deg
    bytes_segments = 2.0 * n_edges_eff * row_bytes + 2.0 * net_bytes
    out = {
        "n_agents": n_agents,
        "n_params": n_params,
        "n_leaves": n_leaves,
        "n_edges": n_edges_eff,
        "hbm_bytes": {
            "leaf_loop": bytes_leaf_loop,
            "flat_fused": bytes_fused,
            "flat_sparse": bytes_sparse,
            "flat_segments": bytes_segments,
        },
        "hbm_passes": {  # in fused-pass units (1.0 = one read+write of both buffers)
            "leaf_loop": touches_leaf_loop / touches_fused,
            "flat_fused": 1.0,
            "flat_sparse": bytes_sparse / bytes_fused,
            "flat_segments": bytes_segments / bytes_fused,
        },
        "roofline_seconds": {
            "leaf_loop": bytes_leaf_loop / HBM_BW,
            "flat_fused": bytes_fused / HBM_BW,
            "flat_sparse": bytes_sparse / HBM_BW,
            "flat_segments": bytes_segments / HBM_BW,
        },
        "model_speedup_fused_vs_leaf_loop": bytes_leaf_loop / bytes_fused,
        # collective exchange of (prec, prec*mu) over a sharded agent axis:
        # ring all-gather of both statistics = 2 x net x (N-1)/N per agent
        # -> 2 x N x (N-1) x row bytes globally, at the WIRE itemsize;
        # the edge-parameterized form moves only the E - N off-diagonal rows
        "wire": {
            "dtype": wire_dtype,
            "bytes_per_el": wire_el,
            "collective_bytes": (
                2.0 * n_agents * (n_agents - 1) * n_params * wire_el
            ),
            "collective_bytes_f32": (
                2.0 * n_agents * (n_agents - 1) * n_params * 4
            ),
            "collective_bytes_edges": (
                2.0 * max(n_edges_eff - n_agents, 0) * n_params * wire_el
            ),
        },
    }
    out["wire"]["model_saving_vs_f32"] = (
        out["wire"]["collective_bytes_f32"] / out["wire"]["collective_bytes"]
        if out["wire"]["collective_bytes"] else 1.0
    )
    return out


def gossip_window_roofline(
    n_agents: int,
    n_params: int,
    n_participating: int,
    n_merging: int | None = None,
    bytes_per_el: int = 4,
    *,
    n_shards: int = 1,
    n_cross_offsets: int = 0,
    delay_depth: int = 0,
    n_stale_events: int = 0,
    wire_dtype: str = "f32",
    history_dtype: str = "f32",
    n_event_edges: int | None = None,
    n_padded_edges: int | None = None,
) -> dict[str, Any]:
    """Analytic HBM traffic of ONE gossip event window (repro.gossip), for
    the active-edge masked consensus (``consensus_fused_masked_sparse``).

    Only agents PARTICIPATING in the window's events (source or target of a
    fired edge) have their (mean, rho) rows read, and only MERGING agents
    (>= 1 incoming event) are written; untouched agents cost nothing (their
    rows pass through in place — a donated-buffer window update never
    streams them).  With every agent participating this degenerates to the
    dense fused number (``consensus_roofline``'s ``flat_fused``: 4 network
    passes' worth of touches), which the monotonicity unit test pins:
    window bytes are monotone in the active fraction and bounded above by
    the dense fused bytes.

    ``n_participating`` / ``n_merging`` come straight from an
    ``EventWindow`` (``window.participating().sum()`` /
    ``window.active.sum()``); ``n_merging`` defaults to
    ``n_participating``.

    INTERCONNECT term (``n_shards > 1`` — the sharded
    ``consensus_ppermute_window`` execution): each of the window's
    ``n_cross_offsets`` fired shard offsets
    (``launch.consensus_opt.window_shard_offsets``) is one ppermute
    rotation moving every shard's [N/S, P] (prec, prec*mu) block —
    ``2 x N x P`` bytes globally per offset — vs the dense layout's
    all-gather of both statistics (``2 x N x P x (S-1)``).  The ppermute
    schedule wins whenever the window crosses fewer than S-1 offsets, and
    an idle window moves ZERO bytes.

    DELIVERY-LATENCY term (``delay_depth > 0`` — a ``DelayedClock``): the
    engine writes each window's post-local (mean, rho) into the [K, N, P]
    history ring (one extra network write, ``2 x N x P`` bytes) and the
    gather consensus reads one stale (mean, rho) row pair per delivered
    event (``n_stale_events``, i.e. ``EventWindow.n_events``).  The ring
    buffer's RESIDENT footprint is ``hist_resident_bytes`` =
    ``2 x (delay_depth + 1) x N x P`` — the capacity planner's number, not
    a per-window traffic term.

    WIRE term (``wire_dtype``): the ppermuted payload and the dense
    all-gather both carry the (prec, prec*mu) statistics AT THE WIRE DTYPE
    (the sharded window casts them at the exchange boundary), so every
    ``ici_bytes`` entry scales with the wire itemsize — bf16 exactly
    halves the interconnect bytes (asserted by unit test).  The HBM terms
    stay at ``bytes_per_el`` (fp32-resident buffers); ``history_dtype``
    independently sizes the ring's resident footprint and its per-window
    traffic (bf16 halves the resident ring).

    EDGE-NATIVE term (``n_event_edges`` — the window's fired NON-SELF event
    count, ``EventWindow.n_events`` or the thinned-Poisson fired count):
    the segment-sum window (``consensus_flat_segments`` over fired edges +
    the merging rows' self edges) gathers one (prec, prec*mu) source row
    pair per fired edge plus each merging row's own pair, and writes the
    merging rows — ``window_segments`` is a pure function of
    (E_fired, n_merging, P), with NO N term at all: the roofline the
    N = 10^4+ sparse sweep in BENCH_gossip.json tracks.

    ``n_padded_edges`` additionally reports the STATIC execution cost the
    jitted engine actually pays: the ``SparseWindow`` rides fixed-shape
    ``[E_max]`` buffers (one trace for the whole run) plus N self-loop
    slots, and a zero-weight pad slot still gathers its source row even
    though it contributes nothing — ``window_segments_padded`` is the
    per-window ceiling ``2 x (E_max + N) x row + 2 x n_merging x row``,
    what a capacity planner should budget (and what shrinking the clock's
    ``e_max`` buys).
    """
    if n_merging is None:
        n_merging = n_participating
    if not 0 <= n_merging <= n_participating <= n_agents:
        raise ValueError(
            "expected 0 <= n_merging <= n_participating <= n_agents, got "
            f"{n_merging} / {n_participating} / {n_agents}"
        )
    if n_shards < 1 or not 0 <= n_cross_offsets <= max(n_shards - 1, 0):
        raise ValueError(
            f"expected n_shards >= 1 and 0 <= n_cross_offsets <= n_shards - 1"
            f", got {n_shards} / {n_cross_offsets}"
        )
    if delay_depth < 0 or n_stale_events < 0:
        raise ValueError("delay_depth and n_stale_events must be >= 0")
    wire_el = _wire_bytes_per_el(wire_dtype)
    hist_el = _wire_bytes_per_el(history_dtype)
    row_bytes = n_params * bytes_per_el
    net_bytes = n_agents * row_bytes
    # read mean+rho of participants, write mean+rho of merging agents
    bytes_window = 2.0 * n_participating * row_bytes + 2.0 * n_merging * row_bytes
    bytes_dense = 4.0 * net_bytes  # consensus_roofline flat_fused
    # history ring (at its RESIDENT dtype): one (mean, rho) network write
    # per window + one stale row pair read per delivered event
    hist_row = n_params * hist_el
    hist_net = n_agents * hist_row
    bytes_history = (
        2.0 * hist_net + 2.0 * n_stale_events * hist_row
        if delay_depth > 0 else 0.0
    )
    # interconnect: ppermute rotations vs the dense all-gather of both
    # sufficient statistics over the agent axis (global bytes, at the WIRE
    # dtype — the payload is cast at the exchange boundary)
    wire_net = n_agents * n_params * wire_el
    ici_ppermute = n_cross_offsets * 2.0 * wire_net
    ici_allgather = 2.0 * wire_net * (n_shards - 1)
    out = {
        "n_agents": n_agents,
        "n_params": n_params,
        "n_participating": n_participating,
        "n_merging": n_merging,
        # NOT EventWindow.active_fraction (the merging-agent mean): this is
        # the fraction of agents whose rows the window kernel must read
        "participating_fraction": n_participating / n_agents if n_agents else 0.0,
        "hbm_bytes": {"window_masked": bytes_window, "dense_fused": bytes_dense},
        # fused-pass units: 1.0 == one read+write of both network buffers
        "hbm_passes": {
            "window_masked": bytes_window / bytes_dense if bytes_dense else 0.0,
            "dense_fused": 1.0,
        },
        "roofline_seconds": {
            "window_masked": bytes_window / HBM_BW,
            "dense_fused": bytes_dense / HBM_BW,
        },
        "model_speedup_window_vs_dense": (
            bytes_dense / bytes_window if bytes_window else float("inf")
        ),
    }
    out["wire_dtype"] = wire_dtype
    if n_event_edges is not None:
        if n_event_edges < 0:
            raise ValueError("n_event_edges must be >= 0")
        bytes_segments = (
            2.0 * (n_event_edges + n_merging) * row_bytes
            + 2.0 * n_merging * row_bytes
        )
        out["n_event_edges"] = int(n_event_edges)
        out["hbm_bytes"]["window_segments"] = bytes_segments
        out["hbm_passes"]["window_segments"] = (
            bytes_segments / bytes_dense if bytes_dense else 0.0
        )
        out["roofline_seconds"]["window_segments"] = bytes_segments / HBM_BW
    if n_padded_edges is not None:
        if n_event_edges is not None and n_padded_edges < n_event_edges:
            raise ValueError(
                f"n_padded_edges={n_padded_edges} is below the fired count "
                f"n_event_edges={n_event_edges} (pads can only add slots)"
            )
        if n_padded_edges < 0:
            raise ValueError("n_padded_edges must be >= 0")
        # static [E_max] buffers + N self-loop slots: pad slots gather their
        # source row like any edge (zero weight, zero contribution)
        bytes_padded = (
            2.0 * (n_padded_edges + n_agents) * row_bytes
            + 2.0 * n_merging * row_bytes
        )
        out["n_padded_edges"] = int(n_padded_edges)
        out["hbm_bytes"]["window_segments_padded"] = bytes_padded
        out["hbm_passes"]["window_segments_padded"] = (
            bytes_padded / bytes_dense if bytes_dense else 0.0
        )
        out["roofline_seconds"]["window_segments_padded"] = (
            bytes_padded / HBM_BW
        )
    if delay_depth > 0:
        out["delay_depth"] = delay_depth
        out["history_dtype"] = history_dtype
        out["hbm_bytes"]["history"] = bytes_history
        out["hist_resident_bytes"] = 2.0 * (delay_depth + 1) * hist_net
        out["roofline_seconds"]["history"] = bytes_history / HBM_BW
    if n_shards > 1:
        out["n_shards"] = n_shards
        out["n_cross_offsets"] = n_cross_offsets
        out["ici_bytes"] = {
            "window_ppermute": ici_ppermute,
            "dense_allgather": ici_allgather,
        }
        out["roofline_seconds"]["ici_window_ppermute"] = ici_ppermute / ICI_BW
        out["roofline_seconds"]["ici_dense_allgather"] = ici_allgather / ICI_BW
        out["model_ici_saving_ppermute_vs_allgather"] = (
            ici_allgather / ici_ppermute if ici_ppermute else float("inf")
        )
    return out


def serve_roofline(
    n_agents: int,
    n_params: int,
    *,
    snapshot_dtype: str = "f32",
    mc_samples: int = 8,
    batch: int = 1,
    dim: int = 1,
    n_classes: int = 2,
    bytes_per_el: int = 4,
) -> dict[str, Any]:
    """Analytic bytes model of the posterior serving tier (``repro.serve``),
    for the memory-bound roofline of one served micro-batch.

    SNAPSHOT term: the published double buffer is 2 x [n_agents, n_params]
    scalars RESIDENT at ``snapshot_dtype`` (the ``core.numerics`` wire
    vocabulary) — a bf16 snapshot is exactly HALF the fp32 HBM (asserted by
    unit test).  ``snapshot_publish_bytes`` is the traffic of one publish:
    read the fp32 training buffers, write the snapshot-resident copy.

    PER-QUERY APPLY term: one micro-batch of ``batch`` rows under one
    agent's posterior draws ``mc_samples`` parameter samples; each sample
    reads the agent's (mean, rho) row pair once (``2 x n_params`` at the
    snapshot dtype — XLA fuses the fp32 widening into the read), streams
    the [batch, dim] inputs and writes [batch, n_classes] fp32
    probabilities.  ``mc_samples=0`` (the point estimate) still reads the
    mean row once.  The serving regime is posterior-row bound whenever
    ``mc_samples x n_params >> batch x dim``, which is the paper's setting
    — so apply bytes scale ~linearly in L, the knob ``BENCH_serve.json``
    sweeps.
    """
    snap_el = _wire_bytes_per_el(snapshot_dtype)
    if mc_samples < 0 or batch <= 0:
        raise ValueError("mc_samples must be >= 0 and batch positive")
    snapshot_bytes = 2.0 * n_agents * n_params * snap_el
    snapshot_bytes_f32 = 2.0 * n_agents * n_params * 4
    publish_bytes = snapshot_bytes_f32 + snapshot_bytes  # read fp32, write resident
    draws = max(mc_samples, 1)  # the point estimate still reads the mean row
    row_reads = (2.0 if mc_samples else 1.0) * draws * n_params * snap_el
    io_bytes = batch * dim * bytes_per_el + batch * n_classes * 4.0
    apply_bytes = row_reads + io_bytes
    out = {
        "n_agents": n_agents,
        "n_params": n_params,
        "snapshot_dtype": snapshot_dtype,
        "mc_samples": mc_samples,
        "batch": batch,
        "snapshot_hbm_bytes": snapshot_bytes,
        "snapshot_hbm_bytes_f32": snapshot_bytes_f32,
        "snapshot_saving_vs_f32": (
            snapshot_bytes_f32 / snapshot_bytes if snapshot_bytes else 1.0
        ),
        "snapshot_publish_bytes": publish_bytes,
        "apply_bytes_per_batch": apply_bytes,
        "apply_bytes_per_row": apply_bytes / batch,
        "posterior_row_bound": row_reads > io_bytes,
        "roofline_seconds": {
            "publish": publish_bytes / HBM_BW,
            "apply_per_batch": apply_bytes / HBM_BW,
        },
    }
    return out


def _layer_kind_counts(cfg) -> dict[str, int]:
    counts: dict[str, int] = {}
    for k in cfg.pattern:
        counts[k] = counts.get(k, 0) + cfg.n_periods
    for k in cfg.tail:
        counts[k] = counts.get(k, 0) + 1
    return counts


def analytic_costs(
    cfg,
    *,
    mode: str,  # train | prefill | decode
    batch_global: int,
    seq_len: int,
    n_agents: int,
    data_shards: int,
    model_shards: int,
    n_matmul_params: int,  # matmul-active params per agent (count_active_params)
    n_total_params: int,  # all params per agent
    window: int | None = None,
    chunk_size: int = 512,
    kv_bytes: float = 2.0,  # bf16 cache; 1.0 + per-head scales for int8
) -> dict[str, Any]:
    a = n_agents
    b = batch_global  # total across agents
    s = seq_len
    hd = cfg.hd
    h = cfg.n_heads
    d = cfg.d_model
    f = 6.0 if mode == "train" else 2.0  # fwd+bwd vs fwd-only multiplier
    counts = _layer_kind_counts(cfg)
    kv_len = s  # cache length for decode
    tokens = b * (1 if mode == "decode" else s)

    # ---------------- FLOPs ----------------
    flops = f * n_matmul_params * tokens  # dense matmul term (2ND fwd, 4ND bwd)
    # attention: 4*B*Sq*Skv_eff*H*hd per layer fwd (scores + PV), f/2 scales bwd
    for kind, n_l in counts.items():
        if kind not in ATTN_KINDS and kind not in ("mlstm", "slstm"):
            continue
        if kind in ATTN_KINDS:
            if mode == "decode":
                skv = min(kv_len, window) if window else kv_len
                attn = 4.0 * b * 1 * skv * h * hd
            else:
                w_eff = cfg.sliding_window if kind == "local_attn" else (window or 0)
                skv_sum = (s * min(w_eff, s)) if w_eff else (s * s * 0.5)  # causal half
                attn = 4.0 * b * skv_sum * h * hd
            flops += (f / 2.0) * attn * n_l
            if kind == "dec_attn" and cfg.is_encdec:
                sq = 1 if mode == "decode" else s
                flops += (f / 2.0) * 4.0 * b * sq * cfg.encoder_seq * h * hd * n_l
        elif kind == "mlstm":
            p = 2 * d
            dk = p // cfg.n_heads
            c = 1 if mode == "decode" else min(chunk_size // 2, s)
            # intra-chunk masked attention (~c keys/query) + state update (dk*dk outer)
            per_tok = 4.0 * c * cfg.n_heads * dk + 4.0 * cfg.n_heads * dk * dk
            flops += (f / 2.0) * per_tok * tokens * n_l
        elif kind == "slstm":
            hd_s = d // cfg.n_heads
            flops += (f / 2.0) * 8.0 * d * hd_s * tokens * n_l  # 4 block-diag matvecs
    if cfg.is_encdec and mode != "decode":
        # encoder self-attention (bidirectional, no causal half)
        flops += (f / 2.0) * 4.0 * b * cfg.encoder_seq**2 * h * hd * cfg.encoder_layers

    # ---------------- HBM bytes ----------------
    param_bytes_bf16 = n_total_params * 2
    if mode == "train":
        # posterior (mu,rho fp32) + grads + Adam (4 fp32) read/write ~= 14 passes
        state = 14.0 * n_total_params * 4 * a
        weights = 3.0 * param_bytes_bf16 * a  # theta sample read fwd + 2x bwd
        # activations: ~8 d-wide tensors/layer/token bf16, ~2.5x for bwd+remat
        act = 2.5 * cfg.n_layers * tokens * 8.0 * d * 2
        hbm = state + weights + act
    elif mode == "prefill":
        weights = param_bytes_bf16 * a
        act = cfg.n_layers * tokens * 8.0 * d * 2
        kv_write = 2.0 * cfg.n_layers * tokens * cfg.n_kv_heads * hd * kv_bytes
        hbm = weights + act + kv_write
    else:  # decode
        weights = param_bytes_bf16 * a
        skv = min(kv_len, window) if window else kv_len
        n_attn = sum(n for k, n in counts.items() if k in ATTN_KINDS)
        kv_read = 2.0 * n_attn * b * skv * cfg.n_kv_heads * hd * kv_bytes
        # recurrent state read/write
        rec = 0.0
        if "mlstm" in counts:
            p = 2 * d
            rec += 2.0 * counts["mlstm"] * b * cfg.n_heads * (p // cfg.n_heads) ** 2 * 4
        if "rglru" in counts:
            rec += 2.0 * counts["rglru"] * b * d * 4
        if "slstm" in counts:
            rec += 2.0 * counts["slstm"] * b * d * 4
        hbm = weights + kv_read + rec + tokens * 8.0 * d * 2 * cfg.n_layers

    # ---------------- collective bytes (ICI) ----------------
    dsh, msh = data_shards, model_shards
    coll = 0.0
    # GLOBAL collective bytes (summed over devices).  Ring collectives: an
    # all-gather/reduce-scatter of a tensor of TOTAL size T over g
    # participants moves T*(g-1)/g per participant -> T*(g-1) global; an
    # all-reduce moves ~2x that.
    # TP activation all-reduces: ~2 per layer; per TP group the tensor is
    # [tokens/dsh, d] bf16 -> global = 2ops * 2x * tokens*d*2B * (m-1)
    if msh > 1:
        coll += (f / 2.0) * 2.0 * 2.0 * cfg.n_layers * tokens * d * 2 * (msh - 1)
    if mode == "train":
        # FSDP param all-gather (1 fwd + 2 bwd passes) + grad reduce-scatter
        # over the data axis; the gathered tensor per model shard is N/msh,
        # msh groups of dsh participants -> global = k * N_bytes * (d-1)
        if dsh > 1:
            per_agent = 3.0 * param_bytes_bf16 * (dsh - 1)  # AG: 1 fwd + 2 bwd
            per_agent += n_total_params * 4 * (dsh - 1)  # grad reduce-scatter fp32
            coll += per_agent * a
        # consensus (eq. 6): exchange (prec, prec*mu) fp32 across agents
        if a > 1:
            coll += 2.0 * 2.0 * n_total_params * 4 * (a - 1) / a * a
        # MoE all-to-all: k copies of each token's d-vector there and back
        if cfg.n_experts:
            coll += 2.0 * tokens * cfg.top_k * d * 2
    elif cfg.n_experts:
        coll += 2.0 * tokens * cfg.top_k * d * 2

    chips = a * dsh * msh if a > 1 else dsh * msh
    t_compute = flops / (chips * PEAK_FLOPS_BF16)
    t_memory = hbm / (chips * HBM_BW)
    t_coll = coll / (chips * ICI_BW)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    return {
        "flops_global": flops,
        "hbm_bytes_global": hbm,
        "collective_bytes_global": coll,
        "roofline_seconds": terms,
        "dominant": max(terms, key=terms.get),
        "chips": chips,
    }
