"""Production training driver: decentralized Bayesian training on a
(pod, data, model) mesh, agents = pods.

Runs the paper's full round structure: u local Bayes-by-Backprop steps per
communication round against the round's consensus prior, then the eq.-(6)
consensus over the pod axis.  Supports the deterministic (non-Bayesian
decentralized-FedAvg) baseline via --no-bayesian.

On this CPU container use small archs / --steps; the same entry point is the
real-TPU launcher (device count and mesh come from the runtime).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch repro-100m \
      --batch 8 --seq 256 --rounds 10 --local-steps 4 --agents 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core.graphs import complete_w
from repro.data.pipeline import make_lm_batch_sampler
from repro.launch.steps import (
    init_train_state,
    make_consensus_step,
    make_local_step,
    make_train_round_step,
)
from repro.optim import adam
from repro.optim.schedules import exponential_decay


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--reduced", action="store_true", help="use the smoke config")
    ap.add_argument("--agents", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8, help="per-agent batch")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=4, help="u per round")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--lr-decay", type=float, default=0.99, help="per round (paper)")
    ap.add_argument("--kl-scale", type=float, default=1e-4)
    ap.add_argument("--no-bayesian", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    a = args.agents
    opt = adam()
    # paper: lr decays per communication round
    sched = exponential_decay(args.lr, args.lr_decay ** (1.0 / max(args.local_steps, 1)))
    W = jnp.asarray(complete_w(a))

    key = jax.random.key(args.seed)
    key, k_init = jax.random.split(key)
    state = init_train_state(k_init, cfg, a, opt)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(state.posterior.mean))
    print(f"arch={cfg.name} agents={a} posterior params={n_params:,}")

    sampler = make_lm_batch_sampler(cfg.vocab_size, args.batch, args.seq, n_agents=a)
    local_step = jax.jit(
        make_local_step(cfg, opt, sched, kl_scale=args.kl_scale, remat=False)
    )
    consensus = jax.jit(make_consensus_step(cfg, W))
    round_step = jax.jit(
        make_train_round_step(
            cfg, W, opt=opt, lr_schedule=sched, kl_scale=args.kl_scale,
            remat=False, bayesian=not args.no_bayesian,
        )
    )

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    t0 = time.time()
    for r in range(args.rounds):
        key, k_round = jax.random.split(key)
        if args.local_steps <= 1 or args.no_bayesian:
            batch = sampler(k_round, r)
            state, metrics = round_step(state, batch, k_round)
            loss = float(jnp.mean(metrics["loss"]))
        else:
            prior = consensus(state.posterior)
            state = jax.tree.map(lambda x: x, state)
            state.posterior = prior
            losses = []
            for u in range(args.local_steps):
                key, k_u = jax.random.split(key)
                batch = sampler(k_u, r * args.local_steps + u)
                state, loss_u = local_step(state, prior, batch, k_u)
                losses.append(float(loss_u))
            loss = float(np.mean(losses))
        dt = time.time() - t0
        print(f"round {r + 1:4d}/{args.rounds}  loss {loss:8.4f}  ({dt:6.1f}s)", flush=True)
        if ckpt and (r + 1) % 10 == 0:
            ckpt.save(r + 1, state)
    if ckpt:
        ckpt.save(args.rounds, state)
        print(f"checkpoint saved to {args.ckpt_dir}")


if __name__ == "__main__":
    main()
